"""Serve a small MoE model with batched requests through the continuous-
batching engine (prefill + decode, per-slot positions).

    PYTHONPATH=src python examples/serve_moe.py
"""

import numpy as np
import jax

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.serve.engine import Engine, Request


def main():
    cfg = get_smoke_config("mixtral-8x7b")
    params = M.init_params(jax.random.key(0), cfg)
    eng = Engine(cfg, params, batch_slots=3, max_seq=64)

    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(1, cfg.vocab, size=4 + i).astype(np.int32),
                max_new_tokens=8)
        for i in range(5)
    ]
    finished = []
    pending = list(reqs)
    while pending or eng.slot_req:
        while pending and eng.free_slots:
            eng.admit(pending.pop(0))
        eng.step()
        finished = [r for r in reqs if r.done]
    for r in reqs:
        assert r.done and len(r.out) == 8, r
        print(f"rid={r.rid} prompt={list(r.prompt)} -> generated {r.out}")
    print(f"{len(finished)} requests served in {eng.steps_run} engine steps "
          f"(continuous batching over 3 slots)")


if __name__ == "__main__":
    main()
