"""Elastic failover demo — paper Property 2 as a fault-tolerance mechanism.

Simulates chip failures on a D3(4,8) pod, finds the largest embeddable
D3(J,L) subnetwork, re-derives the doubly-parallel all-to-all schedule on
the survivors, and verifies it is still conflict-free end to end.

    PYTHONPATH=src python examples/elastic_failover.py
"""

import math

from repro.core.topology import D3
from repro.core.alltoall import DAParams, rounds
from repro.core.routing import vector_path, path_links
from repro.core.simulator import Simulator
from repro.dist.mesh import DeviceLayout
from repro.train.fault_tolerance import ClusterState


def verify_schedule_on_host(host, emb, p):
    """Replay the guest D3(J,L) schedule through the embedding onto the
    HOST graph with PHASE-ALIGNED timing (δ at step 0, γ at 1, π at 2 —
    degenerate hops wait in place, per the paper's synchronous-round
    model); dilation-1 means zero conflicts survive the mapping."""
    guest = emb.guest
    for _, vecs in rounds(p):
        sim = Simulator(host)
        pkt = 0
        for gamma, pi, delta in vecs:
            for r in guest.routers():
                r1 = guest.local_hop(r, delta)
                r2 = guest.global_hop(r1, gamma)
                r3 = guest.local_hop(r2, pi)
                for phase, (a, b) in enumerate([(r, r1), (r1, r2), (r2, r3)]):
                    if a != b:
                        sim.add_hop(phase, emb.map_router(a), emb.map_router(b), pkt)
                pkt += 1
        confs = sim.conflicts()
        assert confs == [], confs[:2]


def main():
    layout = DeviceLayout(D3(4, 8))
    cluster = ClusterState(layout)
    print(f"healthy pod: D3(4,8) = {layout.n} chips, "
          f"all-to-all rounds = {layout.da_params.total_rounds}")

    # two chips die on different cabinets
    for dev in (37, 201):
        cluster.fail(dev)
        print(f"chip {dev} = router {layout.topo.id_router(dev)} FAILED")

    new_layout, index_map = cluster.plan_recovery()
    J, L = new_layout.topo.K, new_layout.topo.M
    print(f"largest embeddable survivor network: D3({J},{L}) = {new_layout.n} chips")

    s = math.gcd(J, L)
    if s > 1:
        p = DAParams(J, L, s)
        from repro.core.emulation import embed
        # reconstruct the embedding used by plan_recovery
        _, _, c_set, p_set = __import__("repro.core.emulation", fromlist=["largest_embeddable"]).largest_embeddable(
            layout.topo, cluster.dead
        )
        emb = embed(layout.topo, J, L, c_set=c_set, p_set=p_set)
        verify_schedule_on_host(layout.topo, emb, p)
        print(f"re-derived doubly-parallel schedule on survivors: "
              f"{p.total_rounds} rounds, conflict-free on the HOST links ✓")
    print(f"device remap entries: {len(index_map)} (guest id -> surviving host id)")


if __name__ == "__main__":
    main()
