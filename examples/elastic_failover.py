"""Elastic failover demo — paper Property 2 as a fault-tolerance mechanism.

Simulates chip failures on a D3(4,8) pod. At bring-up the cluster derives
and lowers the algorithm suite for every fallback shape ONCE
(``prepare_fallbacks``). When chips die, ``plan_recovery`` finds the
largest embeddable D3(J,L) survivor network and REWRITES the already-
lowered guest programs onto it (``runtime.rewrite.emulate``) — the
recovery path never calls back into the core schedule derivations.

The demo then proves the rewrite is sound twice over:

  * conflict-freedom — the rewritten schedule replays through
    ``core.simulator.verify`` on the literal HOST graph (dilation-1 ⇒
    zero conflicts);
  * bit-exactness — the rewritten all-to-all program replays on the
    reference backend against the natively-lowered guest program, and then
    on EVERY registered runtime backend (``runtime.backends``): each one
    must reproduce the reference bits on the optimized rewritten program.

    PYTHONPATH=src python examples/elastic_failover.py
"""

import numpy as np

from repro.core.simulator import verify
from repro.core.topology import D3
from repro.dist.mesh import DeviceLayout
from repro.runtime.backends import available_backends, get_backend
from repro.runtime.backends.reference import NumpyReferenceBackend
from repro.runtime.optimize import optimize
from repro.runtime.rewrite import gather_guest, scatter_guest
from repro.train.fault_tolerance import ClusterState


def main():
    layout = DeviceLayout(D3(4, 8))
    cluster = ClusterState(layout)
    print(f"healthy pod: D3(4,8) = {layout.n} chips, "
          f"all-to-all rounds = {layout.da_params.total_rounds}")

    # bring-up: derive + lower every fallback shape once (the only time the
    # core algorithm derivations run)
    cluster.prepare_fallbacks()
    print(f"program library prepared: {len(cluster.library)} guest shapes, "
          f"{sum(len(s.programs) for s in cluster.library.values())} lowered programs")

    # two chips die on different cabinets
    for dev in (37, 201):
        cluster.fail(dev)
        print(f"chip {dev} = router {layout.topo.id_router(dev)} FAILED")

    plan = cluster.plan_recovery()  # rewrite-only: lookup + relabel
    guest = plan.layout.topo
    print(f"largest embeddable survivor network: D3({guest.K},{guest.M}) "
          f"= {plan.layout.n} chips (c_set={plan.embedding.c_set}, "
          f"p_set={plan.embedding.p_set})")
    print(f"rewritten programs: {sorted(plan.programs)} — "
          f"{sum(p.num_permutes for p in plan.programs.values())} total comm stages, "
          "zero re-derivations")

    # conflict-freedom on the HOST links: replay every rewritten schedule
    # through the unified simulator (dilation-1 ⇒ nothing may collide)
    for kind, sched in sorted(plan.schedules.items()):
        report = verify(layout.topo, sched).raise_on_conflict(f"rewritten {kind}")
        print(f"  {kind:9s} conflict-free on host links "
              f"({report.num_rounds} rounds, {report.num_hop_events} hop events)")

    # bit-exactness: rewritten-on-host all-to-all == natively-lowered guest
    ref = NumpyReferenceBackend()
    native = cluster.library[(guest.K, guest.M)].programs["alltoall"]
    rewritten = plan.programs["alltoall"]
    rng = np.random.default_rng(0)
    x = rng.standard_normal((native.n, native.n, 4)).astype(np.float32)
    want = ref.run_alltoall(x, native)
    got = gather_guest(
        ref.run_alltoall(scatter_guest(x, rewritten, axes=(0, 1)), rewritten),
        rewritten, axes=(0, 1),
    )
    np.testing.assert_array_equal(got, want)
    print("rewritten all-to-all bit-exact vs native guest lowering ✓")

    # every registered backend replays the (optimized) rewritten program to
    # the same bits — the registry is the source of truth, not a stale list
    opt = optimize(rewritten)
    xh = scatter_guest(x, rewritten, axes=(0, 1))
    want_host = ref.run_alltoall(xh, rewritten)
    for name in available_backends():
        backend = get_backend(name)
        out = np.asarray(backend.run_alltoall(xh, opt))
        np.testing.assert_array_equal(out, want_host)
        print(f"  backend {name:13s} ({type(backend).__name__}) "
              "replays the optimized rewrite bit-exact ✓")
    print(f"device remap entries: {len(plan.index_map)} (guest id -> surviving host id)")


if __name__ == "__main__":
    main()
