"""Quickstart: the paper's four algorithms in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core.topology import D3
from repro.core.matmul import MatmulGrid, simulate_matmul
from repro.core.alltoall import DAParams, rounds, verify_vector_coverage, pipeline
from repro.core.hypercube import SBH, simulate_allreduce, check_allreduce_conflicts
from repro.core.broadcast import m_broadcast, check_m_broadcast
from repro.core.simulator import check_vector_round


def main():
    # ---- the network
    t = D3(K=4, M=8)  # one v5e pod: 4 * 8² = 256 chips
    print(f"D3(4,8): {t.num_routers} routers, "
          f"{t.num_local_links} local + {t.num_global_links} global links")

    # ---- A1: matrix product on D3(K²,M) (Theorem 1)
    g = MatmulGrid(K=2, M=3)
    rng = np.random.default_rng(0)
    B = rng.standard_normal((g.n, g.n))
    A = rng.standard_normal((g.n, g.n))
    C = simulate_matmul(g, B, A)
    print(f"A1 matmul on D3({g.K**2},{g.M}): {g.n}x{g.n} in {g.n} rounds of 4 hops, "
          f"max err {np.abs(C - B @ A).max():.2e}")

    # ---- A2: doubly-parallel all-to-all (Theorem 3)
    p = DAParams(4, 8, 4)  # s = gcd(4, 8) = 4
    verify_vector_coverage(p)
    rep = pipeline(p, offset=3)
    print(f"A2 all-to-all on D3(4,8): {p.total_rounds} rounds (= KM²/s), "
          f"schedule-3 makespan {rep.total_steps} hops, 0 conflicts")

    # ---- conflict-freedom is machine-checked, not assumed:
    sends = [(r, (1, 2, 3)) for r in t.routers()]
    conflicts, _ = check_vector_round(t, sends)
    print(f"P1 check: {len(sends)} simultaneous sends, {len(conflicts)} link conflicts")

    # ---- A3: hypercube emulation (ascend all-reduce at ~2x)
    s = SBH(2, 2)  # 64-node D3(4,4) emulating the 6-cube
    vals = rng.standard_normal(s.num_nodes)
    out = simulate_allreduce(s, vals)
    confs, steps = check_allreduce_conflicts(s)
    print(f"A3 SBH(2,2) all-reduce over {s.dims} dims: {steps} hops "
          f"(native {s.dims}), {len(confs)} conflicts, "
          f"err {np.abs(out - vals.sum()).max():.2e}")

    # ---- A4: M simultaneous broadcasts in 5 hops
    confs = check_m_broadcast(t, (0, 0, 0))
    hops = m_broadcast(t, (0, 0, 0))
    print(f"A4 m-broadcast on D3(4,8): {t.M} broadcasts in "
          f"{1 + max(s for s, _, _ in hops)} hops, {len(confs)} conflicts")


if __name__ == "__main__":
    main()
