"""End-to-end driver: train the tinyllama-family reduced model for a few
hundred steps on CPU — loss must drop substantially; checkpoints +
restart-resume exercised along the way.

    PYTHONPATH=src python examples/train_tinyllama.py [--steps 300]
"""

import argparse
import shutil

from repro.launch import train as train_launcher


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()

    ckpt_dir = "/tmp/repro_example_ckpt"
    shutil.rmtree(ckpt_dir, ignore_errors=True)

    # phase 1: train to half, checkpoint
    half = max(args.steps // 2, 1)
    train_launcher.main([
        "--arch", "tinyllama-1.1b", "--smoke",
        "--steps", str(half), "--batch", "8", "--seq", "64",
        "--ckpt-dir", ckpt_dir, "--ckpt-every", "25",
    ])
    # phase 2: RESTART from the checkpoint and finish (fault-tolerance path)
    final_loss = train_launcher.main([
        "--arch", "tinyllama-1.1b", "--smoke",
        "--steps", str(args.steps), "--batch", "8", "--seq", "64",
        "--ckpt-dir", ckpt_dir, "--ckpt-every", "50", "--restore",
    ])
    print(f"final loss after restart-resume: {final_loss:.4f}")


if __name__ == "__main__":
    main()
