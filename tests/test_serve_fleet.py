"""Multi-tenant serving fleet: N models through one combined host program.

Everything here runs device-free on the NumPy reference backend (host
D3(2,2) = 8 routers, guests D3(1,2) = 4 devices each). Bit-exactness
claims compare fleet-vs-fleet through the SAME replay path — a combined
fleet against a single-tenant fleet and against the time-multiplexed arm —
which is the guest-isolation property the combine contract guarantees.
"""

import dataclasses
import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest
import jax

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.serve.fleet import TenantFleet


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("mixtral-8x7b")
    params = [M.init_params(jax.random.key(i), cfg) for i in range(3)]
    return cfg, params


PROMPTS = [[5, 6, 7], [9, 10], [3, 4]]


def solo_tokens(cfg, params, prompt, n_new, *, max_seq=32):
    """The tenant served ALONE on its own single-tenant combined fleet."""
    fleet = TenantFleet((2, 2), max_seq=max_seq)
    tid = fleet.admit_model(cfg, params, guest=(1, 2), slots=2)
    req = fleet.submit(tid, prompt, n_new)
    fleet.run_to_completion()
    assert req.done
    return req.out


def test_combined_fleet_bit_exact_per_tenant(setup):
    """Two tenants through ONE combined program per boundary round produce
    exactly the tokens each produces served alone."""
    cfg, params = setup
    fleet = TenantFleet((2, 2), max_seq=32)
    t0 = fleet.admit_model(cfg, params[0], guest=(1, 2), slots=2)
    t1 = fleet.admit_model(cfg, params[1], guest=(1, 2), slots=2)
    r0 = fleet.submit(t0, PROMPTS[0], 4)
    r1 = fleet.submit(t1, PROMPTS[1], 4)
    fleet.run_to_completion()
    assert r0.done and r1.done
    assert r0.out == solo_tokens(cfg, params[0], PROMPTS[0], 4)
    assert r1.out == solo_tokens(cfg, params[1], PROMPTS[1], 4)
    assert fleet.tokens_out == 8


def test_time_mux_arm_matches_combined(setup):
    """The time-multiplexed control serves the same tokens — the two arms
    differ only in replay count, which is the measured evidence: muxed
    replays ΣT_i rounds where combined replays max(T_i)."""
    cfg, params = setup
    comb = TenantFleet((2, 2), max_seq=32, combined=True)
    mux = TenantFleet((2, 2), max_seq=32, combined=False)
    reqs = {}
    for fleet in (comb, mux):
        for i in range(2):
            tid = fleet.admit_model(cfg, params[i], guest=(1, 2), slots=2)
            reqs[(fleet is mux, i)] = fleet.submit(tid, PROMPTS[i], 4)
        fleet.run_to_completion()
    for i in range(2):
        assert reqs[(False, i)].out == reqs[(True, i)].out
    assert comb.steps_run == mux.steps_run
    # same boundaries serviced, half the replayed rounds when combined
    assert comb.replays < mux.replays
    assert comb.rounds_replayed < mux.rounds_replayed


def test_collective_report_round_evidence(setup):
    """The combined program's round count is max over guests, not the sum
    — the deterministic core of the throughput win — and the autotuner's
    combined-site key carries the guest-set signature."""
    cfg, params = setup
    fleet = TenantFleet((2, 2), max_seq=32)
    for i in range(2):
        fleet.admit_model(cfg, params[i], guest=(1, 2), slots=2)
    from repro.runtime.autotune import Autotuner

    rep = fleet.collective_report(tuner=Autotuner(mode="analytic"))
    assert rep["status"] == "ok"
    assert rep["combined_rounds"] < rep["time_mux_rounds"]
    assert "|combined|" in rep["key"] and "g2xD3(1,2)" in rep["key"]
    assert rep["strategy"] in ("combined", "time_mux")


def test_evict_mid_traffic_survivor_bit_exact(setup):
    """The churn drill: serve two tenants, evict one mid-decode, re-admit
    a third onto the freed cabinets, keep serving. The survivor's in-flight
    request continues BIT-EXACT across both re-combines, and the evicted
    tenant's request is dropped un-done."""
    cfg, params = setup
    fleet = TenantFleet((2, 2), max_seq=32)
    t0 = fleet.admit_model(cfg, params[0], guest=(1, 2), slots=2)
    t1 = fleet.admit_model(cfg, params[1], guest=(1, 2), slots=2)
    r0 = fleet.submit(t0, PROMPTS[0], 8)
    r1 = fleet.submit(t1, PROMPTS[1], 8)
    for _ in range(3):
        fleet.step()
    assert len(r0.out) == 3 and len(r1.out) == 3
    plan = fleet.evict(t1)
    assert plan.surviving == (0,) and plan.evicted == (1,)
    t2 = fleet.admit_model(cfg, params[2], guest=(1, 2), slots=2)
    r2 = fleet.submit(t2, PROMPTS[2], 6)
    fleet.run_to_completion()
    assert r0.done and r2.done and not r1.done
    assert r0.out == solo_tokens(cfg, params[0], PROMPTS[0], 8)
    assert r2.out == solo_tokens(cfg, params[2], PROMPTS[2], 6)


def test_failure_eviction_keeps_survivors(setup):
    """Failure-driven churn: failing a device inside one tenant's image
    evicts exactly that tenant; the survivor's traffic is unaffected."""
    cfg, params = setup
    fleet = TenantFleet((2, 2), max_seq=32)
    t0 = fleet.admit_model(cfg, params[0], guest=(1, 2), slots=2)
    t1 = fleet.admit_model(cfg, params[1], guest=(1, 2), slots=2)
    r0 = fleet.submit(t0, PROMPTS[0], 4)
    fleet.step()
    fleet.fail(int(fleet.tenants[t1].embedding.device_map[0]))
    plan = fleet.plan_eviction()
    assert plan.evicted == (1,) and plan.surviving == (0,)
    assert t1 not in fleet.tenants
    fleet.run_to_completion()
    assert r0.done
    assert r0.out == solo_tokens(cfg, params[0], PROMPTS[0], 4)


def test_queued_requests_drain_through_freed_slots(setup):
    """More requests than slots: the overflow queues and drains into slots
    freed by finished requests, every output bit-exact vs served alone."""
    cfg, params = setup
    fleet = TenantFleet((2, 2), max_seq=32)
    tid = fleet.admit_model(cfg, params[0], guest=(1, 2), slots=2)
    reqs = [fleet.submit(tid, p, 3) for p in PROMPTS]  # 3 reqs, 2 slots
    fleet.run_to_completion()
    assert all(r.done for r in reqs)
    for p, r in zip(PROMPTS, reqs):
        # batch composition differs while the queue drains, but slots are
        # isolated, so each output still matches a solo serve
        assert r.out == solo_tokens(cfg, params[0], p, 3)


def test_admit_rejects_mismatched_signature(setup):
    """One combined replay moves one host array: a tenant whose dispatch
    chunk signature differs from the seated tenants is refused."""
    cfg, params = setup
    fleet = TenantFleet((2, 2), max_seq=32)
    fleet.admit_model(cfg, params[0], guest=(1, 2), slots=2)
    thin = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, d_ff_expert=64))
    with pytest.raises(ValueError, match="signature"):
        fleet.admit_model(thin, params[1], guest=(1, 2), slots=2)


def test_admit_rejects_non_moe_and_full_host(setup):
    cfg, params = setup
    fleet = TenantFleet((2, 2), max_seq=32)
    dense = get_smoke_config("tinyllama-1.1b")
    with pytest.raises(ValueError, match="MoE"):
        fleet.admit_model(dense, None, guest=(1, 2), slots=2)
    fleet.admit_model(cfg, params[0], guest=(1, 2), slots=2)
    fleet.admit_model(cfg, params[1], guest=(1, 2), slots=2)
    with pytest.raises(ValueError, match="free cabinets"):
        fleet.admit_model(cfg, params[2], guest=(1, 2), slots=2)


def test_release_last_tenant_is_legal(setup):
    """Voluntary release differs from failure eviction: releasing the last
    tenant leaves an empty (but servable-again) fleet."""
    cfg, params = setup
    fleet = TenantFleet((2, 2), max_seq=32)
    t0 = fleet.admit_model(cfg, params[0], guest=(1, 2), slots=2)
    r0 = fleet.submit(t0, PROMPTS[0], 2)
    fleet.run_to_completion()
    done_tokens = fleet.tokens_out
    plan = fleet.evict(t0)
    assert plan.surviving == () and plan.programs == {}
    assert fleet.tokens_out == done_tokens  # evicted tokens still counted
    # the freed cabinets seat a new tenant immediately
    t1 = fleet.admit_model(cfg, params[1], guest=(1, 2), slots=2)
    r1 = fleet.submit(t1, PROMPTS[1], 2)
    fleet.run_to_completion()
    assert r0.done and r1.done
    assert r1.out == solo_tokens(cfg, params[1], PROMPTS[1], 2)


# ------------------------------------------- subprocess end-to-end check
@pytest.mark.slow
def test_fleet_smoke_16dev():
    """Device-backed churn drill on a forced 16-device mesh (the CI smoke):
    jax-backend fleet, admit -> serve -> evict -> re-admit, bit-exact vs
    solo through the same replay path."""
    root = pathlib.Path(__file__).resolve().parent.parent
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    env["PYTHONPATH"] = str(root / "src")
    proc = subprocess.run(
        [sys.executable, str(root / "tests" / "serve_fleet_check_script.py")],
        env=env, capture_output=True, text=True, timeout=1200,
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    assert "SERVE FLEET CHECKS PASSED" in proc.stdout
