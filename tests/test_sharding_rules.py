"""Sharding rules + spec-tree/param-tree structural consistency for every
architecture (catches spec/tree drift before the dry-run does)."""

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.dist.sharding import ShardRules
from repro.models import model as M


RULES = ShardRules()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_spec_tree_matches_param_tree(arch):
    cfg = get_config(arch)
    specs = M.param_specs(cfg, RULES)
    shapes = jax.eval_shape(lambda: M.init_params(jax.random.key(0), cfg))
    # identical tree structure
    jax.tree.map(lambda sp, sh: None, specs, shapes,
                 is_leaf=lambda x: isinstance(x, P))
    # every sharded dim actually divides by the axis cardinality
    def check(spec, shaped):
        dims = shaped.shape
        axes = list(spec) + [None] * (len(dims) - len(spec))
        for ax, dim in zip(axes, dims):
            if ax is None:
                continue
            card = 16  # both mesh axes are 16-wide
            assert dim % card == 0, (arch, shaped.shape, spec)
    jax.tree.map(check, specs, shapes, is_leaf=lambda x: isinstance(x, P))


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "deepseek-v3-671b", "jamba-1.5-large-398b"])
def test_expert_parallel_rule(arch):
    cfg = get_config(arch)
    E = cfg.moe.num_experts
    ep = RULES.expert_parallel(E)
    # deepseek 256 and jamba 16 divide the 16-wide axis; mixtral's 8 do not
    assert ep == (E % 16 == 0)
    spec = RULES.expert((E, cfg.d_model, cfg.moe.d_ff_expert), n_experts=E)
    if ep:
        assert spec[0] == "model"
    else:
        assert spec[0] is None and "model" in tuple(spec)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_cache_specs_structure(arch):
    cfg = get_config(arch)
    specs = M.cache_specs(cfg, RULES, long_context=False)
    shapes = jax.eval_shape(lambda: M.init_cache(cfg, 16, 128))
    jax.tree.map(lambda sp, sh: None, specs, shapes,
                 is_leaf=lambda x: isinstance(x, P))


def test_multi_pod_batch_axes():
    r = ShardRules(pod_axis="pod")
    assert r.batch_axes == ("pod", "data")
    assert r.tokens() == P(("pod", "data"), None)
