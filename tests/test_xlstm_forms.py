"""mLSTM form equivalence: chunkwise-parallel == fully-parallel == the
step-recurrent decode form (the three must agree to fp tolerance)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.models import xlstm as XL


def _inputs(B=2, S=64, H=4, dh=16, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((B, S, H, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, H, dh)) / np.sqrt(dh), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, H, dh)), jnp.float32)
    i_pre = jnp.asarray(rng.standard_normal((B, S, H)), jnp.float32)
    f_pre = jnp.asarray(rng.standard_normal((B, S, H)) + 2.0, jnp.float32)
    return q, k, v, i_pre, f_pre


@pytest.mark.parametrize("chunk", [8, 16, 32])
def test_chunked_matches_parallel(chunk):
    q, k, v, i_pre, f_pre = _inputs()
    full = XL.mlstm_parallel_inner(q, k, v, i_pre, f_pre)
    chunked = XL.mlstm_chunked_inner(q, k, v, i_pre, f_pre, chunk)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(full), rtol=2e-4, atol=2e-4)


def test_train_matches_decode_recurrence():
    """Full-block consistency: mlstm_train over a sequence equals stepping
    mlstm_decode token by token."""
    cfg = get_smoke_config("xlstm-1.3b")
    params = XL.mlstm_init(jax.random.key(0), cfg, jnp.float32)
    B, S = 2, 24
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((B, S, cfg.d_model)), jnp.float32)
    y_train = XL.mlstm_train(params, x, cfg, chunk=8)
    state = XL.mlstm_state_init(cfg, B, jnp.float32)
    outs = []
    for t in range(S):
        y, state = XL.mlstm_decode(params, x[:, t : t + 1], state, cfg)
        outs.append(np.asarray(y[:, 0]))
    y_dec = np.stack(outs, axis=1)
    np.testing.assert_allclose(y_dec, np.asarray(y_train), rtol=3e-3, atol=3e-3)
