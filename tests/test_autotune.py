"""Price-driven autotuner: decision determinism, cache robustness, escape
hatches, bytes-aware pricing — plus the multi-device end-to-end checks
(MoE "auto" bit-exactness on 8 devices, the 64-device scale smoke) run as
subprocesses with forced host devices."""

import json
import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest

from repro.core import costmodel
from repro.runtime import autotune as at

ROOT = pathlib.Path(__file__).resolve().parent.parent


# ------------------------------------------------------------ satellite 3
def test_seconds_backward_compatible():
    """No bytes: the original hops·t_w + t_s formula, unchanged."""
    assert costmodel.seconds(10) == pytest.approx(10 * 1.0e-6)
    assert costmodel.seconds(10, 2e-6, 5e-6) == pytest.approx(25e-6)


def test_seconds_scales_with_bytes():
    base = costmodel.seconds(10)
    # 50 GB moved per hop at 50 GB/s adds exactly 1 s per hop
    big = costmodel.seconds(10, bytes_per_hop=50e9, bandwidth=50e9)
    assert big == pytest.approx(base + 10.0)
    # monotone in message size
    a = costmodel.seconds(7, bytes_per_hop=1024)
    b = costmodel.seconds(7, bytes_per_hop=4096)
    assert b > a > costmodel.seconds(7)


# ------------------------------------------------------------- key space
def test_bucket_bytes_powers_of_two():
    assert at.bucket_bytes(0) == 64
    assert at.bucket_bytes(64) == 64
    assert at.bucket_bytes(65) == 128
    assert at.bucket_bytes(4096) == 4096
    assert at.bucket_bytes(5000) == 8192


def test_candidates_per_site():
    assert at.candidates("alltoall", "host") == ("loop", "fused", "sendrecv")
    assert "xla" in at.candidates("alltoall", "shard")
    assert "xla" in at.candidates("alltoall", "global")
    assert "sendrecv" in at.candidates("alltoall", "global")
    # the trace interpreter is a host-style replay: never a shard candidate
    assert "sendrecv" not in at.candidates("alltoall", "shard")
    assert "xla" not in at.candidates("matmul", "global")   # no fused-op form
    # emulated programs exclude xla: the fused op would mix idle devices
    assert "xla" not in at.candidates("alltoall", "shard", emulated=True)
    with pytest.raises(ValueError):
        at.candidates("alltoall", "bogus")


def test_analytic_prices_scale_with_bytes():
    lay = at.layout_for(4)
    small = at.analytic_prices("alltoall", lay, 64, ("loop", "fused"))
    large = at.analytic_prices("alltoall", lay, 1 << 20, ("loop", "fused"))
    assert all(large[s] > small[s] for s in small)


# ------------------------------------------- compute-keyed pipeline tuning
def test_candidates_overlap_fused_alltoall_shard_only():
    assert "overlap_fused" in at.candidates("alltoall", "shard")
    # still offered when the program is emulated (xla is not)
    assert "overlap_fused" in at.candidates("alltoall", "shard", emulated=True)
    assert "overlap_fused" not in at.candidates("allreduce", "shard")
    assert "overlap_fused" not in at.candidates("alltoall", "global")
    assert "overlap_fused" not in at.candidates("alltoall", "host")


def test_tunekey_compute_us_suffix_backward_compatible():
    """compute_us == 0 must format exactly as the pre-pipeline key so the
    existing schema-1 cache entries keep resolving."""
    k0 = at.TuneKey("alltoall", 2, 2, 1024, "float32", "shard")
    assert str(k0) == "alltoall|K2M2|b1024|float32|shard"
    k1 = at.TuneKey("alltoall", 2, 2, 1024, "float32", "shard", 512)
    assert str(k1) == "alltoall|K2M2|b1024|float32|shard|c512"
    assert k0 != k1
    k2 = at.TuneKey("alltoall", 2, 2, 1024, "float32", "shard", 512, True)
    assert str(k2) == "alltoall|K2M2|b1024|float32|shard|c512|emu"


def test_emulated_site_never_reuses_native_xla_decision(tmp_path):
    """Regression: ``emulated`` is part of the TuneKey. A native decision
    (possibly xla) memoized/cached for the same shapes must not be replayed
    at an emulated site, where the fused op would mix idle devices."""
    lay = at.layout_for(4)
    t = at.Autotuner(cache_path=tmp_path / "c.json", mode="analytic")
    d_native = t.decide("alltoall", lay, 256, site="shard")
    d_emu = t.decide("alltoall", lay, 256, site="shard", emulated=True)
    assert d_native.key != d_emu.key
    assert d_emu.key.emulated and str(d_emu.key).endswith("|emu")
    assert d_emu.strategy != "xla"
    assert "xla" not in d_emu.analytic_us
    # same shapes again: each variant replays its own memoized decision
    assert t.decide("alltoall", lay, 256, site="shard") is d_native
    assert t.decide("alltoall", lay, 256, site="shard", emulated=True) is d_emu


def test_bucket_compute_us():
    assert at.bucket_compute_us(0) == 0
    assert at.bucket_compute_us(1) == 1
    assert at.bucket_compute_us(2) == 2
    assert at.bucket_compute_us(3) == 4
    assert at.bucket_compute_us(300) == 512
    assert at.bucket_compute_us(25165) == 32768


def test_analytic_overlap_fused_discount():
    """With a large compute term the sequential strategies pay
    2·wire + compute while overlap_fused pays ~max(wire, compute): the
    overlap discount must rank it strictly cheaper."""
    lay = at.layout_for(8)
    pr = at.analytic_prices("alltoall", lay, 65536,
                            at.candidates("alltoall", "shard"),
                            compute_us=10_000)
    assert pr["overlap_fused"] < pr["loop"]
    assert pr["overlap_fused"] < pr["xla"]
    # without compute there is no round trip to hide: prices stay in the
    # plain-dispatch regime (overlap_fused ~ pipelined wire + group costs)
    pr0 = at.analytic_prices("alltoall", lay, 65536,
                             at.candidates("alltoall", "shard"))
    assert pr0["overlap_fused"] < pr["overlap_fused"]


def test_moe_compute_us_scales_with_ffn_flops():
    base = at.moe_compute_us(2, 32, 16, 64, 128)
    assert base > 0
    assert at.moe_compute_us(2, 32, 16, 64, 256) == pytest.approx(2 * base, abs=2)
    assert at.moe_compute_us(4, 32, 16, 64, 128) == pytest.approx(2 * base, abs=2)


def test_chunk_bytes_site_dependent():
    """Regression for the shard-site byte-bucketing fix: a global buffer
    (n, n, chunk) must key on the per-destination capacity chunk, not the
    n-times larger per-device buffer."""
    from repro.runtime.backends.auto import _chunk_bytes

    x_shard = np.zeros((8, 16, 4), np.float32)
    x_glob = np.zeros((8, 8, 16, 4), np.float32)
    assert _chunk_bytes(x_shard, "alltoall") == 16 * 4 * 4
    assert _chunk_bytes(x_glob, "alltoall", "global") == 16 * 4 * 4
    # non-alltoall kinds key on the full per-device vector
    assert _chunk_bytes(x_shard, "allreduce") == x_shard.size * 4


# -------------------------------------------------- satellite 4: determinism
def test_warm_cache_same_key_same_decision(tmp_path):
    lay = at.layout_for(4)
    t1 = at.Autotuner(cache_path=tmp_path / "cache.json")
    d1 = t1.decide("alltoall", lay, 256, site="host")
    assert d1.source == "measured" and d1.measured_us
    # a fresh tuner over the same cache returns the recorded decision
    t2 = at.Autotuner(cache_path=tmp_path / "cache.json")
    d2 = t2.decide("alltoall", lay, 256, site="host")
    assert d2.source == "cache"
    assert d2.strategy == d1.strategy
    # and within one tuner, repeat calls memoize (no decision-log growth)
    n = len(t2.decisions)
    d3 = t2.decide("alltoall", lay, 256, site="host")
    assert d3 is d2 and len(t2.decisions) == n


def test_corrupt_cache_falls_back_to_analytic(tmp_path):
    p = tmp_path / "cache.json"
    p.write_text("{this is not json")
    t = at.Autotuner(cache_path=p, mode="analytic")
    d = t.decide("alltoall", at.layout_for(4), 256, site="host")
    assert d.source == "analytic" and d.strategy in ("loop", "fused")


def test_missing_cache_falls_back_to_analytic(tmp_path):
    t = at.Autotuner(cache_path=tmp_path / "never_written.json", mode="analytic")
    d = t.decide("allreduce", at.layout_for(4), 256, site="host")
    assert d.source == "analytic"


def test_schema_mismatch_ignored(tmp_path):
    p = tmp_path / "cache.json"
    p.write_text(json.dumps({"schema": 999, "entries": {"x": {"strategy": "loop"}}}))
    t = at.Autotuner(cache_path=p, mode="analytic")
    assert t._cache == {}


def test_stale_cache_entry_with_unavailable_strategy_rederived(tmp_path):
    lay = at.layout_for(4)
    key = at.TuneKey("alltoall", lay.topo.K, lay.topo.M, 256, "float32", "host")
    p = tmp_path / "cache.json"
    p.write_text(json.dumps({
        "schema": at.SCHEMA_VERSION,
        "entries": {str(key): {"strategy": "xla"}},  # not a host candidate
    }))
    t = at.Autotuner(cache_path=p, mode="analytic")
    d = t.decide("alltoall", lay, 256, site="host")
    assert d.source == "analytic" and d.strategy != "xla"


def test_analytic_mode_writes_nothing(tmp_path):
    p = tmp_path / "cache.json"
    t = at.Autotuner(cache_path=p, mode="analytic")
    t.decide("alltoall", at.layout_for(4), 256, site="host")
    assert not p.exists()


def test_measure_writes_schema_versioned_cache(tmp_path):
    p = tmp_path / "cache.json"
    t = at.Autotuner(cache_path=p)
    d = t.decide("allreduce", at.layout_for(4), 256, site="host")
    assert d.source == "measured"
    raw = json.loads(p.read_text())
    assert raw["schema"] == at.SCHEMA_VERSION
    assert str(d.key) in raw["entries"]
    assert raw["entries"][str(d.key)]["strategy"] == d.strategy


# ------------------------------------------------------------ escape hatches
def test_forced_strategy_honored(tmp_path):
    t = at.Autotuner(cache_path=tmp_path / "c.json", force="fused")
    d = t.decide("alltoall", at.layout_for(4), 256, site="host")
    assert d.strategy == "fused" and d.source == "forced"
    assert not (tmp_path / "c.json").exists()  # forcing never measures


def test_forced_strategy_unavailable_degrades_to_candidate(tmp_path):
    # pallas_fused is not a host-site candidate: fall to a legal strategy
    t = at.Autotuner(cache_path=tmp_path / "c.json", force="pallas_fused")
    d = t.decide("alltoall", at.layout_for(4), 256, site="host")
    assert d.strategy in at.candidates("alltoall", "host")


def test_mode_off_returns_pre_autotuner_defaults(tmp_path):
    t = at.Autotuner(cache_path=tmp_path / "c.json", mode="off")
    assert t.decide("alltoall", at.layout_for(4), 256, site="shard").strategy == "xla"
    assert t.decide("alltoall", at.layout_for(4), 256, site="host").strategy == "loop"


def test_env_escape_hatches(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE", "off")
    assert at.Autotuner(cache_path=tmp_path / "c.json").mode == "off"
    monkeypatch.setenv("REPRO_AUTOTUNE", "analytic")
    assert at.Autotuner(cache_path=tmp_path / "c.json").mode == "analytic"
    monkeypatch.setenv("REPRO_AUTOTUNE", "overlap")
    assert at.Autotuner(cache_path=tmp_path / "c.json").force == "overlap"
    monkeypatch.setenv("REPRO_AUTOTUNE", "bogus")
    with pytest.raises(ValueError):
        at.Autotuner(cache_path=tmp_path / "c.json")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "elsewhere.json"))
    monkeypatch.delenv("REPRO_AUTOTUNE")
    assert at.Autotuner().cache_path == tmp_path / "elsewhere.json"


# ------------------------------------------------------- the auto backend
def test_auto_backend_matches_reference(tmp_path):
    """Whatever the tuner picks, the auto backend's result is bit-identical
    to the reference replay (single-device process: the availability guard
    degrades mesh-backed strategies to the fused global replay)."""
    from repro.dist import collectives as coll
    from repro.runtime.backends.auto import AutoBackend
    from repro.runtime.backends.reference import NumpyReferenceBackend

    tuner = at.Autotuner(cache_path=tmp_path / "c.json", mode="analytic")
    be = AutoBackend(tuner=tuner)
    ref = NumpyReferenceBackend()
    lay = at.layout_for(4)
    rng = np.random.default_rng(0)

    prog = coll.alltoall_program(lay)
    x = rng.integers(-8, 9, (4, 4, 8)).astype(np.float32)
    np.testing.assert_array_equal(
        np.asarray(be.run_alltoall(x, prog)), ref.run_alltoall(x, prog))

    par = coll.allreduce_program(lay)
    v = rng.integers(-8, 9, (4, 16)).astype(np.float32)
    np.testing.assert_array_equal(
        np.asarray(be.run_allreduce(v, par)), ref.run_allreduce(v, par))

    pb = coll.broadcast_program(lay, 1)
    np.testing.assert_array_equal(
        np.asarray(be.run_broadcast(v, pb)), ref.run_broadcast(v, pb))


def test_moe_site_report_shapes(tmp_path):
    from repro.configs import get_smoke_config
    from repro.dist.sharding import ShardRules

    cfg = get_smoke_config("mixtral-8x7b")
    rules = ShardRules(model_axis_size=4, data_axis_size=2)
    tuner = at.Autotuner(cache_path=tmp_path / "c.json", mode="analytic")
    rep = at.moe_site_report(cfg, rules, n_tokens=128, tuner=tuner)
    assert rep["status"] == "ok"
    assert rep["strategy"] in ("xla", "loop", "overlap", "overlap_fused")
    assert rep["moe_collectives"] in (
        "xla", "dragonfly", "dragonfly_overlap", "dragonfly_overlap_fused")
    assert rep["rounds"] >= 1 and rep["priced_hops"] > 0


# ------------------------------------------------------ combined guest site
def _fleet_embeddings():
    from repro.core.emulation import disjoint_embeddings
    from repro.core.topology import D3

    return tuple(disjoint_embeddings(D3(4, 2), [(1, 2), (1, 2)]))


def test_decide_combined_key_and_measured_win(tmp_path):
    """The combined site class: keyed on the guest-set signature, measured
    via reference replays of BOTH arms, and on disjoint same-shape guests
    the merged program wins (max vs sum of rounds)."""
    embs = _fleet_embeddings()
    tuner = at.Autotuner(cache_path=tmp_path / "c.json")
    dec = tuner.decide_combined("alltoall", embs, nbytes=4096)
    assert str(dec.key).endswith("|combined|emu|g2xD3(1,2)")
    assert dec.source == "measured"
    assert set(dec.measured_us) == {"combined", "time_mux"}
    assert dec.strategy == "combined"
    assert dec.analytic_us["combined"] < dec.analytic_us["time_mux"]
    # memoized, and placement-independent: the reversed tenant order is the
    # same signature, hence the same decision object
    assert tuner.decide_combined("alltoall", embs[::-1], nbytes=4096) is dec
    # a second tuner on the same cache path replays from disk
    warm = at.Autotuner(cache_path=tmp_path / "c.json")
    dec2 = warm.decide_combined("alltoall", embs, nbytes=4096)
    assert dec2.source == "cache" and dec2.strategy == dec.strategy


def test_decide_combined_modes_and_candidates(tmp_path):
    embs = _fleet_embeddings()
    assert at.candidates("alltoall", "combined") == ("combined", "time_mux")
    ana = at.Autotuner(cache_path=tmp_path / "a.json", mode="analytic")
    d = ana.decide_combined("alltoall", embs, nbytes=1 << 20)
    assert d.source == "analytic" and d.strategy == "combined"
    off = at.Autotuner(cache_path=tmp_path / "b.json", mode="off")
    assert off.decide_combined("alltoall", embs).strategy == "time_mux"
    forced = at.Autotuner(cache_path=tmp_path / "d.json", force="time_mux")
    assert forced.decide_combined("alltoall", embs).source == "forced"
    with pytest.raises(ValueError, match="at least one"):
        ana.decide_combined("alltoall", ())
    # plain keys are unchanged by the guests field (old caches stay valid)
    assert "|g" not in str(at.TuneKey("alltoall", 4, 2, 4096, "float32",
                                      "shard"))


# ------------------------------------------- subprocess end-to-end checks
@pytest.mark.slow
def test_moe_auto_bit_exact_8dev():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(ROOT / "src")
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "moe_auto_check_script.py")],
        env=env, capture_output=True, text=True, timeout=1200,
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    assert "MOE AUTO CHECKS PASSED" in proc.stdout


@pytest.mark.slow
def test_scale_smoke_64dev():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=64"
    env["PYTHONPATH"] = str(ROOT / "src")
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "scale_check_script.py")],
        env=env, capture_output=True, text=True, timeout=1800,
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    assert "ALL SCALE CHECKS PASSED" in proc.stdout
