"""Elastic-training drill — run as a SUBPROCESS with
XLA_FLAGS=--xla_force_host_platform_device_count=16 (set before jax
import; the pytest wrapper in test_elastic.py and the CI job both do
this). The device-backed acceptance check for always-on training:

1. randomized drill — a SEEDED ``FaultInjector.sample`` schedule (seed
   pinned so the run shrinks three times: D3(2,2) -> (1,2) -> (2,1) ->
   (1,1), the middle shape reachable only through the mixed
   cabinet×position regime) with the §5 redistribution broadcast replayed
   through the REAL jax mesh (``JaxPpermuteBackend``), asserting ≥ 2
   rewound cascaded failovers, zero schedule derivations per failover,
   and loss continuity against an uninterrupted same-seed run;
2. deterministic cascade — explicit kills through ``launch/train.py
   --elastic`` flags parsing, exercising the launcher surface end to end.

Exits 0 on success."""

import os
import tempfile

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=16")

import jax

from repro.configs import get_smoke_config
from repro.core.topology import D3
from repro.runtime.backends.jax_ppermute import JaxPpermuteBackend
from repro.train.elastic import ElasticTrainer, FaultInjector, max_loss_divergence
from repro.train.optimizer import OptConfig
from repro.train.train_step import TrainSettings

# pinned: seed 2 samples kills {2: [4], 3: [0], 8: [3]} on D3(2,2) — three
# REWOUND failovers, cascade (1,2) -> (2,1) -> (1,1) with the (2,1) stage
# reachable only via the mixed cabinet×position survivor search
DRILL_SEED = 2
STEPS = 10
HOST = D3(2, 2)


def trainer(ckpt_dir, injector=None):
    cfg = get_smoke_config("tinyllama-1.1b")
    opt_cfg = OptConfig(lr=3e-3, warmup_steps=2, total_steps=STEPS)
    settings = TrainSettings(use_kernel=False, remat=False)
    return ElasticTrainer(
        cfg, opt_cfg, settings, ckpt_dir=ckpt_dir, host=HOST,
        injector=injector, backend=JaxPpermuteBackend(),
        batch=4, seq=16, seed=0, ckpt_every=2,
    )


def main():
    assert jax.device_count() >= 16, jax.device_count()

    # ---------------------------------------------------- randomized drill
    injector = FaultInjector.sample(
        HOST, steps=STEPS, failures=3, seed=DRILL_SEED)
    print(f"sampled fault schedule (seed {DRILL_SEED}): {injector.schedule}")

    with tempfile.TemporaryDirectory() as base_dir:
        baseline = trainer(base_dir).run(STEPS)
    with tempfile.TemporaryDirectory() as el_dir:
        el = trainer(el_dir, injector)
        losses = el.run(STEPS)

    rewound = [e for e in el.events if not e.absorbed]
    assert len(rewound) >= 2, f"need >= 2 cascaded failovers, got {el.events}"
    shapes = [e.shape for e in rewound]
    assert shapes == [(1, 2), (2, 1), (1, 1)], shapes
    for e in el.events:
        assert e.derivations == 0, e          # rewrite-only failover
        print(f"failover @step {e.step}: killed {list(e.failed)} -> "
              f"D3{e.shape} on {list(e.survivors)}, resumed from "
              f"{e.resumed_from}, {e.broadcast_rounds} bcast rounds, "
              f"{e.bytes_redistributed} B, {e.wall_s * 1e3:.0f} ms")
    dead_so_far = set()
    for e in el.events:   # no survivor set ever contains a dead device
        dead_so_far |= set(e.failed)
        assert not set(e.survivors) & dead_so_far, e
    for prev, nxt in zip(rewound, rewound[1:]):
        assert len(nxt.survivors) < len(prev.survivors), (prev, nxt)

    div = max_loss_divergence(baseline, losses)
    print(f"loss continuity: max |elastic - uninterrupted| = {div:.2e} "
          f"over {len(losses)} steps")
    assert div < 1e-4, div

    # ------------------------------------- launcher surface (explicit kills)
    from repro.launch import train as launch_train

    with tempfile.TemporaryDirectory() as ckpt_dir:
        final = launch_train.main([
            "--smoke", "--steps", "6", "--batch", "4", "--seq", "16",
            "--ckpt-dir", ckpt_dir, "--ckpt-every", "2",
            "--elastic", "--host", "2", "2", "--inject-failures", "2:1,4:4",
        ])
    assert final > 0  # the launcher ran its elastic loop to completion

    print("ELASTIC CHECKS PASSED")


if __name__ == "__main__":
    main()
