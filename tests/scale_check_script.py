"""Scale smoke — run as a SUBPROCESS with
XLA_FLAGS=--xla_force_host_platform_device_count=256 (set before jax
import, see test_autotune.py and the CI scale step). D3(4,4) doubly-
parallel all-to-all plus the Theorem-2 matmul on grid (2,4) — K²M² = 64
devices — and, when the process has 256 devices, the grid-(4,4) matmul
(D3(16,4), K²M² = 256 routers). All bit-exact against ground truth.
Exits 0 on success."""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=256")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.dist import collectives as coll
from repro.dist.mesh import dragonfly_layout
from repro.runtime.compat import shard_map


def get_mesh(n):
    return Mesh(np.array(jax.devices()[:n]), ("x",))


def check_all_to_all_64():
    n = 64
    layout = dragonfly_layout(n)
    assert (layout.topo.K, layout.topo.M) == (4, 4), layout.topo
    mesh = get_mesh(n)
    rng = np.random.default_rng(0)
    x = rng.standard_normal((n, n, 4)).astype(np.float32)

    f = jax.jit(
        shard_map(
            lambda s: coll.dragonfly_all_to_all(s[0], "x", layout)[None],
            mesh=mesh, in_specs=P("x"), out_specs=P("x"),
        )
    )
    got = np.asarray(f(x))
    np.testing.assert_allclose(got, x.transpose(1, 0, 2), rtol=1e-6)
    print("D3(4,4) all_to_all OK (64 devices)")


def check_matmul_64():
    # Theorem 2 grid (K, M) = (2, 4): the K×K array of M×M blocks needs
    # K²M² = 64 devices in router order.
    from repro.core.matmul import MatmulGrid, gather_blocks, scatter_blocks

    K, M = 2, 4
    grid = MatmulGrid(K, M)
    prog = coll.matmul_program(K, M)
    assert prog.n == 64, prog.n
    mesh = get_mesh(64)
    b = 4
    rng = np.random.default_rng(3)
    side = grid.n * b
    # integer-valued floats: the round-structured sum is bit-exact vs @
    Bmat = rng.integers(-4, 5, (side, side)).astype(np.float32)
    Amat = rng.integers(-4, 5, (side, side)).astype(np.float32)
    bb = jnp.asarray(scatter_blocks(grid, Bmat))
    aa = jnp.asarray(scatter_blocks(grid, Amat))

    f = jax.jit(
        shard_map(
            lambda p, q: coll.dragonfly_matmul(p[0], q[0], "x", (K, M))[None],
            mesh=mesh, in_specs=(P("x"), P("x")), out_specs=P("x"),
        )
    )
    got = gather_blocks(grid, np.asarray(f(bb, aa)))
    np.testing.assert_array_equal(got, Bmat @ Amat)
    print("Theorem-2 matmul grid (2,4) OK (64 devices, bit-exact)")


def check_matmul_256():
    # Theorem 2 grid (K, M) = (4, 4): K²M² = 256 devices — the largest
    # forced-host mesh the CI scale job exercises. b=2 keeps the compile
    # a few seconds while still blocking (32×32 matrix, 16 rounds).
    from repro.core.matmul import MatmulGrid, gather_blocks, scatter_blocks

    K, M = 4, 4
    grid = MatmulGrid(K, M)
    prog = coll.matmul_program(K, M)
    assert prog.n == 256, prog.n
    mesh = get_mesh(256)
    b = 2
    rng = np.random.default_rng(5)
    side = grid.n * b
    Bmat = rng.integers(-4, 5, (side, side)).astype(np.float32)
    Amat = rng.integers(-4, 5, (side, side)).astype(np.float32)
    bb = jnp.asarray(scatter_blocks(grid, Bmat))
    aa = jnp.asarray(scatter_blocks(grid, Amat))

    f = jax.jit(
        shard_map(
            lambda p, q: coll.dragonfly_matmul(p[0], q[0], "x", (K, M))[None],
            mesh=mesh, in_specs=(P("x"), P("x")), out_specs=P("x"),
        )
    )
    got = gather_blocks(grid, np.asarray(f(bb, aa)))
    np.testing.assert_array_equal(got, Bmat @ Amat)
    print("Theorem-2 matmul grid (4,4) OK (256 devices, bit-exact)")


if __name__ == "__main__":
    assert jax.device_count() >= 64, jax.device_count()
    check_all_to_all_64()
    check_matmul_64()
    if jax.device_count() >= 256:
        check_matmul_256()
    else:
        print("skipping grid (4,4): need 256 devices, have", jax.device_count())
    print("ALL SCALE CHECKS PASSED")
