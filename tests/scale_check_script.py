"""Scale smoke — run as a SUBPROCESS with
XLA_FLAGS=--xla_force_host_platform_device_count=256 (set before jax
import, see test_autotune.py and the CI scale step). D3(4,4) doubly-
parallel all-to-all plus the Theorem-2 matmul on grid (2,4) — K²M² = 64
devices — and, when the process has 256 devices, the grid-(4,4) matmul
(D3(16,4), K²M² = 256 routers). All bit-exact against ground truth.
Also exports the same shapes to send/recv device traces, re-validates
them, and replays them through the ``sendrecv`` interpreter against the
jax backend (``check_export_256``); set ``REPRO_EXPORT_TRACE_DIR`` to
keep the trace JSON (the CI artifact). Exits 0 on success."""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=256")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.dist import collectives as coll
from repro.dist.mesh import dragonfly_layout
from repro.runtime.compat import shard_map


def get_mesh(n):
    return Mesh(np.array(jax.devices()[:n]), ("x",))


def check_all_to_all_64():
    n = 64
    layout = dragonfly_layout(n)
    assert (layout.topo.K, layout.topo.M) == (4, 4), layout.topo
    mesh = get_mesh(n)
    rng = np.random.default_rng(0)
    x = rng.standard_normal((n, n, 4)).astype(np.float32)

    f = jax.jit(
        shard_map(
            lambda s: coll.dragonfly_all_to_all(s[0], "x", layout)[None],
            mesh=mesh, in_specs=P("x"), out_specs=P("x"),
        )
    )
    got = np.asarray(f(x))
    np.testing.assert_allclose(got, x.transpose(1, 0, 2), rtol=1e-6)
    print("D3(4,4) all_to_all OK (64 devices)")


def check_matmul_64():
    # Theorem 2 grid (K, M) = (2, 4): the K×K array of M×M blocks needs
    # K²M² = 64 devices in router order.
    from repro.core.matmul import MatmulGrid, gather_blocks, scatter_blocks

    K, M = 2, 4
    grid = MatmulGrid(K, M)
    prog = coll.matmul_program(K, M)
    assert prog.n == 64, prog.n
    mesh = get_mesh(64)
    b = 4
    rng = np.random.default_rng(3)
    side = grid.n * b
    # integer-valued floats: the round-structured sum is bit-exact vs @
    Bmat = rng.integers(-4, 5, (side, side)).astype(np.float32)
    Amat = rng.integers(-4, 5, (side, side)).astype(np.float32)
    bb = jnp.asarray(scatter_blocks(grid, Bmat))
    aa = jnp.asarray(scatter_blocks(grid, Amat))

    f = jax.jit(
        shard_map(
            lambda p, q: coll.dragonfly_matmul(p[0], q[0], "x", (K, M))[None],
            mesh=mesh, in_specs=(P("x"), P("x")), out_specs=P("x"),
        )
    )
    got = gather_blocks(grid, np.asarray(f(bb, aa)))
    np.testing.assert_array_equal(got, Bmat @ Amat)
    print("Theorem-2 matmul grid (2,4) OK (64 devices, bit-exact)")


def check_matmul_256():
    # Theorem 2 grid (K, M) = (4, 4): K²M² = 256 devices — the largest
    # forced-host mesh the CI scale job exercises. b=2 keeps the compile
    # a few seconds while still blocking (32×32 matrix, 16 rounds).
    from repro.core.matmul import MatmulGrid, gather_blocks, scatter_blocks

    K, M = 4, 4
    grid = MatmulGrid(K, M)
    prog = coll.matmul_program(K, M)
    assert prog.n == 256, prog.n
    mesh = get_mesh(256)
    b = 2
    rng = np.random.default_rng(5)
    side = grid.n * b
    Bmat = rng.integers(-4, 5, (side, side)).astype(np.float32)
    Amat = rng.integers(-4, 5, (side, side)).astype(np.float32)
    bb = jnp.asarray(scatter_blocks(grid, Bmat))
    aa = jnp.asarray(scatter_blocks(grid, Amat))

    f = jax.jit(
        shard_map(
            lambda p, q: coll.dragonfly_matmul(p[0], q[0], "x", (K, M))[None],
            mesh=mesh, in_specs=(P("x"), P("x")), out_specs=P("x"),
        )
    )
    got = gather_blocks(grid, np.asarray(f(bb, aa)))
    np.testing.assert_array_equal(got, Bmat @ Amat)
    print("Theorem-2 matmul grid (4,4) OK (256 devices, bit-exact)")
    return got


def check_export_256(jax_c256=None):
    """Differential export at scale: compile the D3(4,4) pipelined §3
    all-to-all and the grid-(4,4) Theorem-2 matmul (256 routers) to
    send/recv traces, re-validate the exported form, replay through the
    ``sendrecv`` interpreter against the jax backend's output, and — when
    ``REPRO_EXPORT_TRACE_DIR`` is set — write the trace JSON for the CI
    artifact + ``python -m repro.runtime.export`` check."""
    import pathlib

    from repro.runtime import export as rexport
    from repro.runtime.backends.sendrecv import SendRecvBackend

    sr = SendRecvBackend()
    written = []
    out_dir = os.environ.get("REPRO_EXPORT_TRACE_DIR")

    # D3(4,4) §3 all-to-all, Schedule-1 pipelined: overlap windows survive
    layout = dragonfly_layout(64)
    prog = coll.alltoall_program(layout, pipelined=1)
    trace = rexport.validate(rexport.export(prog))
    assert trace.waves()[-1][0] < rexport.export(
        coll.alltoall_program(layout)).waves()[-1][0], "no pipelined overlap"
    rng = np.random.default_rng(11)
    x = rng.integers(-4, 5, (64, 64, 4)).astype(np.float32)
    mesh = get_mesh(64)
    f = jax.jit(
        shard_map(
            lambda s: coll.dragonfly_all_to_all(s[0], "x", layout)[None],
            mesh=mesh, in_specs=P("x"), out_specs=P("x"),
        )
    )
    np.testing.assert_array_equal(sr.run_alltoall(x, prog), np.asarray(f(x)))
    print(f"export D3(4,4) all-to-all pipe1 OK (sendrecv == jax, "
          f"ops={trace.num_ops} waves={len(trace.waves())})")
    traces = {"alltoall_d3_4x4_pipe1": trace}

    # grid-(4,4) matmul: the 256-router trace exports/validates with no
    # devices at all; replay checks vs the jax output when we have one.
    from repro.core.matmul import MatmulGrid

    K, M = 4, 4
    prog = coll.matmul_program(K, M)
    trace = rexport.validate(rexport.export(prog))
    grid = MatmulGrid(K, M)
    rng = np.random.default_rng(5)
    side = grid.n * 2
    Bmat = rng.integers(-4, 5, (side, side)).astype(np.float32)
    Amat = rng.integers(-4, 5, (side, side)).astype(np.float32)
    got = sr.run_matmul(Bmat, Amat, prog)
    np.testing.assert_array_equal(got, Bmat @ Amat)
    if jax_c256 is not None:
        np.testing.assert_array_equal(got, jax_c256)
    print(f"export grid (4,4) matmul OK (sendrecv"
          f"{' == jax' if jax_c256 is not None else ''}, 256 routers, "
          f"ops={trace.num_ops})")
    traces["matmul_grid_4x4"] = trace

    if out_dir:
        d = pathlib.Path(out_dir)
        d.mkdir(parents=True, exist_ok=True)
        for name, t in traces.items():
            p = d / f"{name}.json"
            p.write_text(t.to_json())
            written.append(str(p))
        print("wrote traces:", " ".join(written))


if __name__ == "__main__":
    assert jax.device_count() >= 64, jax.device_count()
    check_all_to_all_64()
    check_matmul_64()
    c256 = None
    if jax.device_count() >= 256:
        c256 = check_matmul_256()
    else:
        print("skipping grid (4,4): need 256 devices, have", jax.device_count())
    check_export_256(c256)
    print("ALL SCALE CHECKS PASSED")
