"""Serving engine: continuous batching, per-slot positions, greedy decode
consistency with the pure decode_step."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.serve.engine import Engine, Request


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("tinyllama-1.1b")
    params = M.init_params(jax.random.key(0), cfg)
    return cfg, params


def greedy_reference(cfg, params, prompt, n_new):
    """Single-request greedy decode via the pure API."""
    cache = M.init_cache(cfg, 1, 64, dtype=jnp.float32)
    step = jax.jit(lambda p, c, b, pos: M.decode_step(p, c, b, pos, cfg))
    logits = None
    pos = 0
    for t in prompt:
        logits, cache = step(params, cache, {"token": jnp.asarray([t], jnp.int32)}, pos)
        pos += 1
    out = []
    for _ in range(n_new):
        nxt = int(np.argmax(np.asarray(logits[0])))
        out.append(nxt)
        logits, cache = step(params, cache, {"token": jnp.asarray([nxt], jnp.int32)}, pos)
        pos += 1
    return out


def test_engine_matches_reference_single(setup):
    cfg, params = setup
    prompt = np.asarray([5, 9, 42], np.int32)
    want = greedy_reference(cfg, params, prompt, 6)
    eng = Engine(cfg, params, batch_slots=1, max_seq=64)
    req = Request(rid=0, prompt=prompt, max_new_tokens=6)
    eng.admit(req)
    eng.run_to_completion()
    assert req.done
    assert req.out == want


def test_engine_batched_isolation(setup):
    """Two concurrent requests produce the same outputs as when served
    alone (slots don't leak into each other)."""
    cfg, params = setup
    p1 = np.asarray([3, 7], np.int32)
    p2 = np.asarray([11, 2, 19, 4], np.int32)
    solo1 = greedy_reference(cfg, params, p1, 5)
    solo2 = greedy_reference(cfg, params, p2, 5)
    eng = Engine(cfg, params, batch_slots=2, max_seq=64)
    r1 = Request(rid=1, prompt=p1, max_new_tokens=5)
    r2 = Request(rid=2, prompt=p2, max_new_tokens=5)
    eng.admit(r1)
    eng.admit(r2)
    eng.run_to_completion()
    assert r1.out == solo1
    assert r2.out == solo2


def test_engine_continuous_admission(setup):
    """A late request joins after earlier ones started decoding."""
    cfg, params = setup
    eng = Engine(cfg, params, batch_slots=2, max_seq=64)
    a = Request(rid=0, prompt=np.asarray([1, 2], np.int32), max_new_tokens=4)
    eng.admit(a)
    eng.step()
    eng.step()
    b = Request(rid=1, prompt=np.asarray([9, 9, 9], np.int32), max_new_tokens=3)
    eng.admit(b)
    eng.run_to_completion()
    assert a.done and b.done
    assert b.out == greedy_reference(cfg, params, b.prompt, 3)


def test_slot_reuse(setup):
    cfg, params = setup
    eng = Engine(cfg, params, batch_slots=1, max_seq=64)
    r1 = Request(rid=0, prompt=np.asarray([4], np.int32), max_new_tokens=2)
    eng.admit(r1)
    eng.run_to_completion()
    assert r1.done and eng.free_slots == [0]
    # NOTE: reusing a slot inherits stale cache beyond the new request's
    # positions; positions reset on admit, and attention masks by position,
    # so stale entries past the new prompt are masked out.
    r2 = Request(rid=1, prompt=np.asarray([4], np.int32), max_new_tokens=2)
    eng.admit(r2)
    eng.run_to_completion()
    assert r2.done
    assert r2.out == r1.out  # same prompt, same params -> same greedy output


def test_admit_coadvance_semantics(setup):
    """The documented co-advance contract of ``Engine.admit``: while a new
    prompt prefills, every other active slot keeps DECODING — those tokens
    are real output, identical to solo greedy, they count against the
    decoding request's budget (it can finish mid-prefill), and the
    admitted request itself is charged nothing until its first decode."""
    cfg, params = setup
    a_prompt = np.asarray([3, 7], np.int32)
    solo = greedy_reference(cfg, params, a_prompt, 3)
    eng = Engine(cfg, params, batch_slots=2, max_seq=64)
    a = Request(rid=0, prompt=a_prompt, max_new_tokens=3)
    eng.admit(a)
    eng.step()
    assert len(a.out) == 1
    # 6-token prompt = 5 co-advance steps: a's remaining budget (2) is
    # consumed mid-prefill and its slot frees before admit returns
    b = Request(rid=1, prompt=np.asarray([9, 8, 7, 6, 5, 4], np.int32),
                max_new_tokens=2)
    eng.admit(b)
    assert a.done and a.out == solo      # finished DURING b's prefill
    assert b.out == []                   # prefill charged nothing to b
    eng.run_to_completion()
    assert b.done and len(b.out) == 2
    assert b.out == greedy_reference(cfg, params, b.prompt, 2)


def test_admit_into_slot_freed_same_step(setup):
    """A slot retired inside ``step`` is admittable immediately — no dead
    step between retirement and the next request — and the re-admitted
    request's output matches solo greedy despite the stale cache beyond
    its positions."""
    cfg, params = setup
    eng = Engine(cfg, params, batch_slots=1, max_seq=64)
    r1 = Request(rid=0, prompt=np.asarray([4, 13], np.int32), max_new_tokens=1)
    eng.admit(r1)
    eng.step()  # r1 finishes and leaves its slot during THIS step
    assert r1.done and eng.free_slots == [0]
    r2 = Request(rid=1, prompt=np.asarray([7, 7, 7], np.int32), max_new_tokens=3)
    assert eng.admit(r2)
    eng.run_to_completion()
    assert r2.done
    assert r2.out == greedy_reference(cfg, params, r2.prompt, 3)


def test_max_seq_truncation(setup):
    """A request whose budget exceeds the cache truncates at max_seq-1
    instead of writing past the cache (and still reports done)."""
    cfg, params = setup
    eng = Engine(cfg, params, batch_slots=1, max_seq=12)
    req = Request(rid=0, prompt=np.asarray([5, 9, 42], np.int32),
                  max_new_tokens=100)
    eng.admit(req)
    eng.run_to_completion()
    assert req.done
    assert 0 < len(req.out) < 100
    # truncated exactly at the cache bound, bit-exact up to the cut
    want = greedy_reference(cfg, params, req.prompt, len(req.out))
    assert req.out == want
    assert eng.tokens_out == len(req.out)
