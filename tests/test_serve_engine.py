"""Serving engine: continuous batching, per-slot positions, greedy decode
consistency with the pure decode_step."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.serve.engine import Engine, Request


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("tinyllama-1.1b")
    params = M.init_params(jax.random.key(0), cfg)
    return cfg, params


def greedy_reference(cfg, params, prompt, n_new):
    """Single-request greedy decode via the pure API."""
    cache = M.init_cache(cfg, 1, 64, dtype=jnp.float32)
    step = jax.jit(lambda p, c, b, pos: M.decode_step(p, c, b, pos, cfg))
    logits = None
    pos = 0
    for t in prompt:
        logits, cache = step(params, cache, {"token": jnp.asarray([t], jnp.int32)}, pos)
        pos += 1
    out = []
    for _ in range(n_new):
        nxt = int(np.argmax(np.asarray(logits[0])))
        out.append(nxt)
        logits, cache = step(params, cache, {"token": jnp.asarray([nxt], jnp.int32)}, pos)
        pos += 1
    return out


def test_engine_matches_reference_single(setup):
    cfg, params = setup
    prompt = np.asarray([5, 9, 42], np.int32)
    want = greedy_reference(cfg, params, prompt, 6)
    eng = Engine(cfg, params, batch_slots=1, max_seq=64)
    req = Request(rid=0, prompt=prompt, max_new_tokens=6)
    eng.admit(req)
    eng.run_to_completion()
    assert req.done
    assert req.out == want


def test_engine_batched_isolation(setup):
    """Two concurrent requests produce the same outputs as when served
    alone (slots don't leak into each other)."""
    cfg, params = setup
    p1 = np.asarray([3, 7], np.int32)
    p2 = np.asarray([11, 2, 19, 4], np.int32)
    solo1 = greedy_reference(cfg, params, p1, 5)
    solo2 = greedy_reference(cfg, params, p2, 5)
    eng = Engine(cfg, params, batch_slots=2, max_seq=64)
    r1 = Request(rid=1, prompt=p1, max_new_tokens=5)
    r2 = Request(rid=2, prompt=p2, max_new_tokens=5)
    eng.admit(r1)
    eng.admit(r2)
    eng.run_to_completion()
    assert r1.out == solo1
    assert r2.out == solo2


def test_engine_continuous_admission(setup):
    """A late request joins after earlier ones started decoding."""
    cfg, params = setup
    eng = Engine(cfg, params, batch_slots=2, max_seq=64)
    a = Request(rid=0, prompt=np.asarray([1, 2], np.int32), max_new_tokens=4)
    eng.admit(a)
    eng.step()
    eng.step()
    b = Request(rid=1, prompt=np.asarray([9, 9, 9], np.int32), max_new_tokens=3)
    eng.admit(b)
    eng.run_to_completion()
    assert a.done and b.done
    assert b.out == greedy_reference(cfg, params, b.prompt, 3)


def test_slot_reuse(setup):
    cfg, params = setup
    eng = Engine(cfg, params, batch_slots=1, max_seq=64)
    r1 = Request(rid=0, prompt=np.asarray([4], np.int32), max_new_tokens=2)
    eng.admit(r1)
    eng.run_to_completion()
    assert r1.done and eng.free_slots == [0]
    # NOTE: reusing a slot inherits stale cache beyond the new request's
    # positions; positions reset on admit, and attention masks by position,
    # so stale entries past the new prompt are masked out.
    r2 = Request(rid=1, prompt=np.asarray([4], np.int32), max_new_tokens=2)
    eng.admit(r2)
    eng.run_to_completion()
    assert r2.done
    assert r2.out == r1.out  # same prompt, same params -> same greedy output
