"""Per-shard pipelined dispatch differentials — run as a SUBPROCESS with
XLA_FLAGS=--xla_force_host_platform_device_count=16 (set before jax
import, see test_pipelined_replay.py). On real device meshes (8-device
D3(2,2) and 16-device D3(4,2)) the ``overlap_fused`` shard path — wave-
ordered dispatch and the fused dispatch+compute+combine round trip — must
be BIT-EXACT against the per-stage loop backend and the NumPy reference,
for Schedule offsets 1..3 and for an emulated guest-on-host program.
Exits 0 on success."""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=16")

import jax
import numpy as np

from repro.core import alltoall as a2a
from repro.dist.mesh import dragonfly_layout
from repro.runtime import lowering
from repro.runtime import optimize as ropt
from repro.runtime.backends.jax_ppermute import JaxPpermuteBackend
from repro.runtime.backends.reference import NumpyReferenceBackend

ref = NumpyReferenceBackend()
be_loop = JaxPpermuteBackend()
be_of = JaxPpermuteBackend(overlap_fused=True)


def check_dispatch(n):
    """overlap_fused vs loop vs reference, offsets 1..3 + barrier."""
    layout = dragonfly_layout(n)
    p, topo = layout.da_params, layout.topo
    rng = np.random.default_rng(n)
    x = rng.standard_normal((n, n, 4)).astype(np.float32)
    programs = [lowering.lower(a2a.pipelined_schedule(p, off, topo))
                for off in (1, 2, 3)]
    programs.append(lowering.lower(a2a.schedule(p, topo)))
    for prog in programs:
        want = ref.run_alltoall(x.copy(), prog)
        np.testing.assert_array_equal(
            np.asarray(be_loop.run_alltoall(x, prog)), want)
        np.testing.assert_array_equal(
            np.asarray(be_of.run_alltoall(x, prog)), want)
        # OptimizedProgram route: the wave-table scan replay
        np.testing.assert_array_equal(
            np.asarray(be_of.run_alltoall(x, ropt.optimize(prog))), want)
    print(f"dispatch OK (n={n}, offsets 1-3 + barrier)")


def check_fused_compute(n):
    """Round trip out[j] = compute_j(x[j]) with per-device weights.
    Multiply-only compute: eager and jit agree bitwise (no FMA fusion)."""
    layout = dragonfly_layout(n)
    prog = lowering.lower(
        a2a.pipelined_schedule(layout.da_params, 1, layout.topo))
    rng = np.random.default_rng(n + 1)
    x = rng.standard_normal((n, n, 4)).astype(np.float32)
    W = (np.arange(n, dtype=np.float32) + 2.0).reshape(n, 1)

    def comp_local(chunks, w):
        return chunks * w[0]

    got = np.asarray(be_of.run_alltoall_compute(x, prog, comp_local, weights=(W,)))
    want = ref.run_alltoall_compute(x.copy(), prog, lambda d, c: c * W[d, 0])
    np.testing.assert_array_equal(got, want)
    # identity compute is the identity map (round trip, NOT the transpose)
    np.testing.assert_array_equal(
        np.asarray(be_of.run_alltoall_compute(x, prog)), x)
    print(f"fused compute OK (n={n})")


def check_emulated_guest():
    """Guest D3(2,2) pipelined program on the 16-device D3(4,2) host:
    dispatch and fused round trip bit-exact, idle devices untouched."""
    from repro.core.emulation import embed
    from repro.core.topology import D3
    from repro.dist.mesh import DeviceLayout
    from repro.runtime.rewrite import emulate

    guest = DeviceLayout(D3(2, 2))
    emb = embed(D3(4, 2), 2, 2, c_set=(1, 3), p_set=(0, 1))
    gprog = lowering.lower(
        a2a.pipelined_schedule(guest.da_params, 1, guest.topo))
    hprog = emulate(gprog, emb)
    n = hprog.n
    act = np.asarray(hprog.active_devices)
    rng = np.random.default_rng(7)
    x = np.zeros((n, n, 3), np.float32)
    x[np.ix_(act, act)] = rng.standard_normal(
        (len(act), len(act), 3)).astype(np.float32)

    want = ref.run_alltoall(x.copy(), hprog)
    np.testing.assert_array_equal(np.asarray(be_of.run_alltoall(x, hprog)), want)
    np.testing.assert_array_equal(np.asarray(be_loop.run_alltoall(x, hprog)), want)

    W = (np.arange(n, dtype=np.float32) + 2.0).reshape(n, 1)
    got = np.asarray(be_of.run_alltoall_compute(
        x, hprog, lambda chunks, w: chunks * w[0], weights=(W,)))
    want = ref.run_alltoall_compute(x.copy(), hprog, lambda d, c: c * W[d, 0])
    np.testing.assert_array_equal(got, want)
    idle = np.setdiff1d(np.arange(n), act)
    assert not got[idle].any() and not got[:, idle].any()
    print("emulated guest OK (D3(2,2) on 16 hosts)")


if __name__ == "__main__":
    assert jax.device_count() >= 16, jax.device_count()
    for n in (8, 16):
        check_dispatch(n)
        check_fused_compute(n)
    check_emulated_guest()
    print("ALL PIPELINE CHECKS PASSED")
