"""Concurrent guests: the ``runtime.combine`` program combinator.

Acceptance (ISSUE 5): two disjoint D3(2,2) guests combined onto a D3(4,4)
host replay bit-exact vs their solo runs on both the reference and
jax_ppermute backends, the combined program passes the Schedule-IR
conflict check, and the combined makespan beats the time-multiplexed sum
(rounds asserted here; wall time in ``benchmarks.run
bench_concurrent_guests``). The mesh-backed (32 forced devices) replay of
a combined program lives in ``program_check_script.py``.
"""

import numpy as np
import pytest

from repro.core import alltoall as a2a
from repro.core import broadcast as bc
from repro.core import hypercube as hc
from repro.core import matmul as mm
from repro.core.emulation import disjoint_embeddings, embed
from repro.core.simulator import verify
from repro.core.topology import D3
from repro.dist.mesh import DeviceLayout
from repro.runtime import lowering
from repro.runtime.backends.reference import NumpyReferenceBackend
from repro.runtime.combine import (
    GuestConflictError,
    check_step_conflicts,
    combine,
    combine_schedules,
    extract_guest,
    gather_guests,
    scatter_guests,
)
from repro.runtime.optimize import optimize
from repro.runtime.program import CollectiveProgram, Perm
from repro.runtime.rewrite import emulate, emulate_schedule, scatter_guest

REF = NumpyReferenceBackend()
HOST = D3(4, 4)
GUEST = DeviceLayout(D3(2, 2))
EMBS = disjoint_embeddings(HOST, [(2, 2), (2, 2)])


def _a2a_prog():
    return lowering.lower(a2a.schedule(GUEST.da_params, GUEST.topo))


def _combined_alltoall():
    prog = _a2a_prog()
    return prog, [emulate(prog, e) for e in EMBS]


# ------------------------------------------------------------- acceptance
def test_two_guests_bit_exact_vs_solo_on_reference_and_jax():
    """The headline: one combined replay == two solo replays, per guest,
    on the reference backend (per-stage AND fused) and on the jax_ppermute
    backend (fused global replay — the meshless OptimizedProgram path)."""
    from repro.runtime.backends.jax_ppermute import JaxPpermuteBackend

    prog, solos = _combined_alltoall()
    comb = combine(solos)
    rng = np.random.default_rng(0)
    xs = [rng.standard_normal((prog.n, prog.n, 3)).astype(np.float32)
          for _ in EMBS]
    wants = [REF.run_alltoall(x, prog) for x in xs]

    xh = scatter_guests(xs, EMBS, axes=(0, 1))
    out_ref = REF.run_alltoall(xh, comb)
    out_opt = REF.run_alltoall(xh, optimize(comb))
    out_jax = np.asarray(
        JaxPpermuteBackend().run_alltoall(xh, optimize(comb)))
    np.testing.assert_array_equal(out_opt, out_ref)
    np.testing.assert_array_equal(out_jax, out_ref)
    for e, want in zip(EMBS, wants):
        np.testing.assert_array_equal(
            extract_guest(out_ref, e, axes=(0, 1)), want)
    # idle rows/cols of the 64-device host stay zero
    idle = ~comb.active_mask_np
    assert not out_ref[idle].any() and not out_ref[:, idle].any()


def test_combined_program_passes_schedule_ir_conflict_check():
    """The merged host-graph Schedule replays conflict-free through
    ``core.simulator.verify`` — the same checker every algorithm's tests
    use — for all three comm kinds."""
    scheds = {
        "alltoall": a2a.schedule(GUEST.da_params, GUEST.topo),
        "allreduce": hc.allreduce_schedule(GUEST.sbh),
        "broadcast": bc.depth3_schedule(GUEST.topo, (0, 1, 0)),
    }
    for kind, sched in scheds.items():
        merged = combine_schedules([emulate_schedule(sched, e) for e in EMBS])
        assert merged.topo == HOST
        merged.validate()  # every hop is a physical host link
        verify(HOST, merged).raise_on_conflict(f"combined {kind}")
        # payloads are namespaced by guest, so coverage is attributable
        assert all(p[0] in (0, 1) for r in merged.rounds
                   for p in r.payloads())


def test_combine_schedules_preserves_pipelined_stamps_across_shapes():
    """Mixed-SHAPE pipelined guests disagree on per-round start_steps; the
    merged schedule keeps each guest's own launch offsets, so pipelined
    verify stays conflict-free instead of spuriously colliding at 0."""
    embs = disjoint_embeddings(HOST, [(2, 2), (2, 4)])
    scheds = []
    for e in embs:
        lay = DeviceLayout(e.guest)
        s = a2a.pipelined_schedule(lay.da_params, offset=1, topo=lay.topo)
        verify(lay.topo, s, pipelined=True).raise_on_conflict("solo")
        scheds.append(emulate_schedule(s, e))
    merged = combine_schedules(scheds)
    verify(HOST, merged).raise_on_conflict("combined barrier")
    verify(HOST, merged, pipelined=True).raise_on_conflict("combined pipelined")
    want = sorted({r.meta["start_step"] for s in scheds for r in s.rounds
                   if "start_step" in r.meta})
    got = sorted({r.meta["start_step"] for r in merged.rounds
                  if "start_step" in r.meta})
    assert got == want  # every guest's launch offset survived the merge


def test_combined_makespan_is_max_not_sum():
    _, solos = _combined_alltoall()
    comb = combine(solos)
    assert comb.num_rounds == max(p.num_rounds for p in solos)
    assert comb.num_rounds < sum(p.num_rounds for p in solos)
    # and the packing is perfect for same-shape guests: same stage count
    # as ONE guest — every merged Perm carries both guests' pairs
    assert len(comb.stages) == len(solos[0].stages)
    for merged, s0, s1 in zip(comb.stages, solos[0].stages, solos[1].stages):
        assert isinstance(merged, Perm) and merged.is_partial
        assert set(merged.pairs) == set(s0.pairs) | set(s1.pairs)
        assert (merged.round_index, merged.step, merged.start_step) == \
            (s0.round_index, s0.step, s0.start_step)


# ----------------------------------------------------- other kinds
def test_combined_allreduce_and_broadcast_bit_exact():
    rng = np.random.default_rng(1)
    ar = lowering.lower(hc.allreduce_schedule(GUEST.sbh))
    comb = combine([emulate(ar, e) for e in EMBS])
    ys = [rng.standard_normal((ar.n, 4)) for _ in EMBS]
    yh = scatter_guests(ys, EMBS, fill=9.25)  # idle garbage must pass through
    out = REF.run_allreduce(yh, comb)
    np.testing.assert_array_equal(REF.run_allreduce(yh, optimize(comb)), out)
    for e, y in zip(EMBS, ys):
        np.testing.assert_array_equal(extract_guest(out, e),
                                      REF.run_allreduce(y, ar))
    np.testing.assert_array_equal(out[~comb.active_mask_np], 9.25)

    # two broadcasts with DIFFERENT per-guest roots in one replay
    b1 = lowering.lower(bc.depth3_schedule(GUEST.topo, (0, 1, 0)))
    b2 = lowering.lower(bc.depth3_schedule(GUEST.topo, (1, 0, 1)))
    comb = combine([emulate(b1, EMBS[0]), emulate(b2, EMBS[1])])
    assert comb.root is None  # per-guest roots live on the solo programs
    zs = [rng.standard_normal((b1.n, 2)), rng.standard_normal((b2.n, 2))]
    zh = scatter_guests(zs, EMBS, fill=-3.0)
    out = REF.run_broadcast(zh, comb)
    np.testing.assert_array_equal(REF.run_broadcast(zh, optimize(comb)), out)
    np.testing.assert_array_equal(extract_guest(out, EMBS[0]),
                                  REF.run_broadcast(zs[0], b1))
    np.testing.assert_array_equal(extract_guest(out, EMBS[1]),
                                  REF.run_broadcast(zs[1], b2))


def test_combined_matmul_blocks_bit_exact_and_skeleton_guard():
    """Two grid-(1,2) guests multiplex one host at the blocks level; a
    shape-mismatched matmul guest is rejected (local-contract stages act
    on every device, so skeletons must agree)."""
    g = mm.MatmulGrid(1, 2)
    prog = lowering.lower(mm.schedule(g))
    embs = disjoint_embeddings(HOST, [(1, 2), (1, 2)])
    solos = [emulate(prog, e) for e in embs]
    comb = combine(solos)
    assert comb.grid == (1, 2)
    rng = np.random.default_rng(2)
    X = 3
    from repro.core.matmul import scatter_blocks

    Bs = [rng.integers(-4, 5, (g.n * X, g.n * X)).astype(np.float64)
          for _ in embs]
    As = [rng.integers(-4, 5, (g.n * X, g.n * X)).astype(np.float64)
          for _ in embs]
    bh = scatter_guests([scatter_blocks(g, B) for B in Bs], embs)
    ah = scatter_guests([scatter_blocks(g, A) for A in As], embs)
    c = REF.matmul_blocks(bh, ah, comb)
    np.testing.assert_array_equal(REF.matmul_blocks(bh, ah, optimize(comb)), c)
    for e, B, A, solo in zip(embs, Bs, As, solos):
        want = REF.matmul_blocks(
            scatter_guest(scatter_blocks(g, B), solo),
            scatter_guest(scatter_blocks(g, A), solo), solo)
        np.testing.assert_array_equal(extract_guest(c, e),
                                      extract_guest(want, e))

    other = lowering.lower(mm.schedule(mm.MatmulGrid(2, 2)))
    with pytest.raises(GuestConflictError, match="skeleton"):
        combine([solos[0],
                 emulate(other, embed(HOST, 4, 2, p_set=(2, 3)))])


def test_run_matmul_guests_whole_matrix_wrapper():
    """``run_matmul_guests``: N whole (N·X, N·X) products through one
    combined blocks-level replay — each guest's result equals its plain
    ``B @ A``, and the guardrails (count mismatch, wrong kind, backend
    without ``matmul_blocks``) raise informatively."""
    from repro.runtime.combine import run_matmul_guests

    g = mm.MatmulGrid(1, 2)
    embs = disjoint_embeddings(HOST, [(1, 2), (1, 2)])
    comb = combine([emulate(lowering.lower(mm.schedule(g)), e) for e in embs])
    rng = np.random.default_rng(3)
    side = g.n * 3
    Bs = [rng.integers(-4, 5, (side, side)).astype(np.float64) for _ in embs]
    As = [rng.integers(-4, 5, (side, side)).astype(np.float64) for _ in embs]
    Cs = run_matmul_guests(REF, Bs, As, comb, embs)
    for B, A, C in zip(Bs, As, Cs):
        np.testing.assert_array_equal(C, B @ A)

    with pytest.raises(ValueError, match="guests"):
        run_matmul_guests(REF, Bs[:1], As, comb, embs)
    comb_a2a = combine(_combined_alltoall()[1])
    with pytest.raises(ValueError, match="matmul"):
        run_matmul_guests(REF, Bs, As, comb_a2a, embs)

    class NoBlocks:
        name = "noblocks"

    with pytest.raises(ValueError, match="matmul_blocks"):
        run_matmul_guests(NoBlocks(), Bs, As, comb, embs)


# ------------------------------------------------------------ validation
def test_overlapping_images_raise_structured_error():
    prog, solos = _combined_alltoall()
    clash = emulate(prog, embed(HOST, 2, 2, c_set=(1, 2), p_set=(0, 1)))
    with pytest.raises(GuestConflictError) as ei:
        combine([solos[0], clash])
    assert ei.value.guests == (0, 1)
    assert ei.value.device in solos[0].active_devices
    assert ei.value.device in clash.active_devices


def test_step_conflict_check_reports_step_and_link():
    """Defense in depth: disjoint images but hand-built stages that reach
    outside them are caught by the cross-guest step re-check."""
    a = CollectiveProgram(
        "alltoall", 4, 1, (Perm(((0, 2), (2, 0)), n=4),),
        active_devices=(0, 1))
    b = CollectiveProgram(
        "alltoall", 4, 1, (Perm(((0, 2), (2, 0)), n=4),),
        active_devices=(2, 3))
    with pytest.raises(GuestConflictError) as ei:
        check_step_conflicts([a, b])
    assert ei.value.step == (0, 0)
    assert ei.value.link == (0, 2)
    assert ei.value.guests == (0, 1)
    with pytest.raises(GuestConflictError, match="overlap|link|write"):
        combine([a, b])


def test_cross_guest_reduce_combine_write_is_rejected():
    """A guest's ReduceCombine folding into ANOTHER guest's device is a
    conflict (intra-guest repeated RC destinations stay legal) — whatever
    the start_step stamps, the structured error fires before any merge
    could corrupt the victim's bits."""
    from repro.runtime.program import ReduceCombine

    a = CollectiveProgram(
        "allreduce", 4, 1, (ReduceCombine(4, ((0, 2),)),),
        active_devices=(0, 2))
    for start in (0, 1):
        b = CollectiveProgram(
            "allreduce", 4, 1,
            (ReduceCombine(4, ((1, 2),), start_step=start),),
            active_devices=(1, 3))
        with pytest.raises(GuestConflictError) as ei:
            combine([a, b])
        assert ei.value.guests == (0, 1) and ei.value.step == (0, 0)
        assert ei.value.device == 2  # the doubly-written accumulator
    # identity (self) RC pairs use no link but DO write: a foreign Perm
    # landing on that accumulator in the same step is a conflict too
    p = CollectiveProgram(
        "allreduce", 4, 1, (ReduceCombine(4, ((1, 3),)),),
        active_devices=(1, 2))
    q = CollectiveProgram(
        "allreduce", 4, 1, (ReduceCombine(4, ((3, 3),)),),
        active_devices=(0, 3))
    with pytest.raises(GuestConflictError, match="write device 3") as ei:
        combine([p, q])
    assert ei.value.device == 3 and ei.value.link is None  # no link used


def test_combine_rejects_mixed_kinds_sizes_and_native_programs():
    prog, solos = _combined_alltoall()
    with pytest.raises(ValueError, match="kinds"):
        combine([solos[0],
                 emulate(lowering.lower(hc.allreduce_schedule(GUEST.sbh)),
                         EMBS[1])])
    native_host = CollectiveProgram(
        "alltoall", HOST.num_routers, 1,
        (Perm(tuple((i, i) for i in range(HOST.num_routers))),))
    with pytest.raises(ValueError, match="native"):
        combine([solos[0], native_host])
    small = emulate(prog, embed(D3(2, 4), 2, 2, p_set=(0, 2)))
    with pytest.raises(ValueError, match="host-sized"):
        combine([solos[0], small])
    with pytest.raises(ValueError, match="at least one"):
        combine([])
    assert combine([solos[0]]) is solos[0]  # single guest passes through
    with pytest.raises(ValueError, match="native"):
        combine([native_host])  # ... but only after validation


def test_combine_is_cached():
    _, solos = _combined_alltoall()
    assert combine(solos) is combine(tuple(solos))


# -------------------------------------------------- enumerator + movement
def test_disjoint_embeddings_regimes():
    # cabinet regime: ΣJ ≤ K, every guest keeps its full position prefix
    embs = disjoint_embeddings(D3(4, 4), [(2, 2), (2, 2)])
    assert [e.c_set for e in embs] == [(0, 1), (2, 3)]
    # position regime: ΣJ > K but ΣL ≤ M
    embs = disjoint_embeddings(D3(2, 4), [(2, 2), (2, 2)])
    assert [e.p_set for e in embs] == [(0, 1), (2, 3)]
    images = [set(map(int, e.device_map)) for e in embs]
    assert not images[0] & images[1]
    # three tenants of mixed shape on the cabinet axis
    embs = disjoint_embeddings(D3(4, 4), [(1, 2), (2, 4), (1, 3)])
    assert [e.c_set for e in embs] == [(0,), (1, 2), (3,)]
    with pytest.raises(ValueError, match="pack"):
        disjoint_embeddings(D3(2, 2), [(2, 2), (1, 1)])
    with pytest.raises(ValueError, match="fit"):
        disjoint_embeddings(D3(2, 2), [(3, 1)])


def test_scatter_gather_guests_roundtrip_and_host_to_guest_extraction():
    prog, solos = _combined_alltoall()
    comb = combine(solos)
    rng = np.random.default_rng(3)
    xs = [rng.standard_normal((prog.n, prog.n, 2)) for _ in EMBS]
    xh = scatter_guests(xs, EMBS, axes=(0, 1), fill=5.0)
    assert xh.shape == (comb.n, comb.n, 2)
    outs = gather_guests(xh, EMBS, axes=(0, 1))
    for x, o in zip(xs, outs):
        np.testing.assert_array_equal(o, x)
    # extraction via a solo program (active_devices) == via the embedding
    # (host_to_guest) — the two guest views coincide
    np.testing.assert_array_equal(
        extract_guest(xh, solos[0], axes=(0, 1)),
        extract_guest(xh, EMBS[0], axes=(0, 1)))
    idle = ~comb.active_mask_np
    np.testing.assert_array_equal(xh[idle], 5.0)
    # the fill participates in the output dtype: integer guests with a
    # fractional sentinel widen instead of silently truncating the fill
    ints = [np.arange(prog.n, dtype=np.int32) for _ in EMBS]
    ih = scatter_guests(ints, EMBS, fill=9.25)
    assert ih.dtype == np.float64
    np.testing.assert_array_equal(ih[idle], 9.25)
    with pytest.raises(ValueError, match="slots"):
        scatter_guests([xs[0][:3]], [EMBS[0]])
    with pytest.raises(ValueError, match="guests"):
        scatter_guests(xs, [EMBS[0]])


# ------------------------------------------------- dist getters + failover
def test_concurrent_program_getters_cached_and_optimized():
    from repro.dist import collectives as coll

    prog = coll.concurrent_program("alltoall", EMBS)
    assert prog is coll.concurrent_program("alltoall", EMBS)
    assert prog.guest_n == 2 * GUEST.n and prog.n == HOST.num_routers
    opt = coll.concurrent_program("alltoall", EMBS, optimized=True)
    assert opt.program is prog
    suite = coll.concurrent_programs(EMBS, roots=(0, 3))
    assert set(suite) == {"alltoall", "allreduce", "broadcast"}
    # matmul-incapable shapes skip the kind instead of failing the suite
    assert "matmul" not in coll.concurrent_programs(
        EMBS, kinds=("alltoall", "matmul"))
    with pytest.raises(ValueError, match="roots"):
        coll.concurrent_program("broadcast", EMBS, roots=(0,))
    with pytest.raises(ValueError, match="roots"):  # not a silent {} suite
        coll.concurrent_programs(EMBS, roots=(0,))
    # malformed tenant sets raise instead of thinning the suite: these two
    # embeddings target DIFFERENT hosts
    mixed = (EMBS[0], embed(D3(2, 4), 2, 2, p_set=(0, 2)))
    with pytest.raises(ValueError, match="host-sized"):
        coll.concurrent_programs(mixed)
    # degenerate single-router tenants: no hypercube to reduce over — the
    # kind is skipped, not crashed on
    ones = disjoint_embeddings(HOST, [(1, 1), (1, 1)])
    assert set(coll.concurrent_programs(ones)) == {"alltoall", "broadcast"}
    # individually matmul-capable but differently-shaped tenants: matmul
    # is skipped (no shared skeleton) without losing the rest of the suite
    mixed_grids = disjoint_embeddings(HOST, [(1, 2), (4, 2)])
    suite = coll.concurrent_programs(mixed_grids, kinds=("alltoall", "matmul"))
    assert set(suite) == {"alltoall"}


def test_prepare_shape_refuses_mixed_roots():
    """The (J, L) shape library is root-stamped: a cache hit under a
    different broadcast root raises instead of serving wrong-root bits."""
    from repro.train.fault_tolerance import ClusterState

    cs = ClusterState(DeviceLayout(HOST))
    suite = cs.prepare_shape(2, 2, root=3)
    assert suite.root == 3
    assert cs.prepare_shape(2, 2, root=3) is suite  # idempotent per root
    with pytest.raises(ValueError, match="broadcast root"):
        cs.prepare_shape(2, 2)  # default root=0 on a root-3 cache entry


def test_multitenant_eviction_recombines_without_rederiving(monkeypatch):
    """A failure inside one tenant's image evicts ONLY that tenant; the
    survivor keeps its (cached) rewritten programs and the re-combination
    never calls a core derivation or the lowering."""
    from repro.train.fault_tolerance import MultiTenantCluster

    mt = MultiTenantCluster(DeviceLayout(HOST))
    for e in EMBS:
        mt.admit(e)
    with pytest.raises(ValueError, match="overlaps"):
        mt.admit(embed(HOST, 2, 2, c_set=(1, 2), p_set=(0, 1)))

    healthy = mt.plan_eviction()
    assert healthy.surviving == (0, 1) and healthy.evicted == ()
    assert set(healthy.programs) == {"alltoall", "allreduce", "broadcast"}
    assert healthy.programs["alltoall"].guest_n == 2 * GUEST.n
    # explicit kinds intersect with what the survivors support
    assert set(mt.plan_eviction(kinds=["alltoall", "matmul"]).programs) == \
        {"alltoall"}

    def _boom(*a, **k):
        raise AssertionError("eviction path called into a derivation")

    monkeypatch.setattr(a2a, "schedule", _boom)
    monkeypatch.setattr(bc, "depth3_schedule", _boom)
    monkeypatch.setattr(hc, "allreduce_schedule", _boom)
    monkeypatch.setattr(lowering, "lower", _boom)

    mt.fail(int(EMBS[1].device_map[2]))
    plan = mt.plan_eviction()
    assert plan.surviving == (0,) and plan.evicted == (1,)
    # the evictee was UNSEATED: a replacement of a prepared shape avoiding
    # the dead chip can take over the freed cabinets (same derive-once
    # library entry, so even with derivations boomed admit succeeds)
    assert mt.tenants == [EMBS[0]]
    replacement = embed(HOST, 2, 2, c_set=(2, 3), p_set=(2, 3))
    assert mt.admit(replacement) == 1
    assert set(mt.plan_eviction().surviving) == {0, 1}
    mt.tenants = [EMBS[0]]  # back to one survivor for the drain check below
    # a newcomer cannot be seated on chips already marked failed
    fresh = MultiTenantCluster(DeviceLayout(HOST))
    fresh.fail(int(EMBS[1].device_map[2]))
    with pytest.raises(ValueError, match="failed host devices"):
        fresh.admit(EMBS[1])
    assert plan.embeddings == (EMBS[0],)
    # the survivor's combined program IS its cached solo rewrite
    solo = emulate(mt.library[(2, 2)].programs["alltoall"], EMBS[0])
    assert plan.programs["alltoall"] is solo
    assert plan.index_maps[0] == {g: int(h)
                                  for g, h in enumerate(EMBS[0].device_map)}
    with pytest.raises(RuntimeError, match="no tenant"):
        for e in EMBS:
            for h in e.device_map:
                mt.dead.add(HOST.id_router(int(h)))
        mt.plan_eviction()
