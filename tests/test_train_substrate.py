"""Training substrate: optimizer, train loop convergence, checkpointing,
gradient compression, fault tolerance, data pipeline determinism."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.train.optimizer import OptConfig, init_state, apply_updates, lr_at, global_norm
from repro.train.train_step import TrainSettings, make_train_step, init_train_state
from repro.train.data import DataState, SyntheticLM
from repro.train import checkpoint as ckpt
from repro.train import compression as C
from repro.train.fault_tolerance import ClusterState, StragglerPolicy, renormalized_scale
from repro.dist.mesh import DeviceLayout
from repro.core.topology import D3


# ------------------------------------------------------------- optimizer
def test_adamw_reduces_quadratic():
    cfg = OptConfig(lr=0.1, warmup_steps=0, total_steps=100, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0, 1.0])}
    state = init_state(params, cfg)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}
        params, state, _ = apply_updates(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_factored_matches_adam_direction():
    cfg_full = OptConfig(lr=0.01, warmup_steps=0, factored=False)
    cfg_fact = OptConfig(lr=0.01, warmup_steps=0, factored=True)
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.standard_normal((128, 256)), jnp.float32)}
    g = {"w": jnp.asarray(rng.standard_normal((128, 256)), jnp.float32)}
    s_full = init_state(params, cfg_full)
    s_fact = init_state(params, cfg_fact)
    assert "vr" in s_fact["mu"]["w"] and "v" in s_full["mu"]["w"]
    p1, _, _ = apply_updates(params, g, s_full, cfg_full)
    p2, _, _ = apply_updates(params, g, s_fact, cfg_fact)
    d1 = np.asarray(p1["w"] - params["w"]).ravel()
    d2 = np.asarray(p2["w"] - params["w"]).ravel()
    cos = d1 @ d2 / (np.linalg.norm(d1) * np.linalg.norm(d2))
    # rank-1 second-moment approximation of an unstructured random gradient
    # is the worst case — direction still strongly aligned, equal magnitude
    assert cos > 0.7
    assert np.linalg.norm(d2) == pytest.approx(np.linalg.norm(d1), rel=0.05)


def test_lr_schedule_shape():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(lr_at(cfg, 0)) < 0.11
    assert float(lr_at(cfg, 10)) == pytest.approx(1.0, rel=0.01)
    assert float(lr_at(cfg, 100)) < float(lr_at(cfg, 50))


def test_grad_clip():
    cfg = OptConfig(lr=1e-3, grad_clip=1.0, warmup_steps=0)
    params = {"w": jnp.zeros(4)}
    state = init_state(params, cfg)
    big = {"w": jnp.full(4, 1e6)}
    _, _, metrics = apply_updates(params, big, state, cfg)
    assert float(metrics["grad_norm"]) > 1e5  # reported pre-clip


# ------------------------------------------------------------ train loop
def test_loss_decreases_tinyllama_smoke():
    cfg = get_smoke_config("tinyllama-1.1b")
    opt = OptConfig(lr=3e-3, warmup_steps=5, total_steps=40)
    settings = TrainSettings(use_kernel=False, remat=True)
    params, opt_state = init_train_state(jax.random.key(0), cfg, opt, settings)
    step = jax.jit(make_train_step(cfg, opt, settings), donate_argnums=(0, 1))
    data = SyntheticLM(DataState(seed=0, batch=8, seq=32, vocab=cfg.vocab))
    losses = []
    for _ in range(30):
        batch = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])


def test_microbatch_equivalence():
    """mb=1 vs mb=4 gradients agree (same total batch)."""
    cfg = get_smoke_config("olmo-1b")
    opt = OptConfig(lr=1e-3, warmup_steps=0)
    data = SyntheticLM(DataState(seed=3, batch=8, seq=16, vocab=cfg.vocab))
    batch = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
    outs = []
    for mb in (1, 4):
        settings = TrainSettings(microbatches=mb, use_kernel=False, remat=False)
        params, opt_state = init_train_state(jax.random.key(1), cfg, opt, settings)
        step = jax.jit(make_train_step(cfg, opt, settings))
        p2, _, m = step(params, opt_state, batch)
        outs.append((float(m["loss"]), p2))
    assert outs[0][0] == pytest.approx(outs[1][0], rel=1e-4)
    l1 = jax.tree.leaves(outs[0][1])
    l2 = jax.tree.leaves(outs[1][1])
    for a, b in zip(l1, l2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-5)


# ------------------------------------------------------------ checkpoint
def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "params": {"w": np.arange(6, dtype=np.float32).reshape(2, 3)},
        "opt": ({"m": np.ones(3)},),
        "data": {"seed": 1, "step": 7},
    }
    path = ckpt.save(tmp_path, 5, tree)
    step, back = ckpt.restore(tmp_path)
    assert step == 5
    np.testing.assert_array_equal(back["params"]["w"], tree["params"]["w"])
    np.testing.assert_array_equal(back["opt"][0]["m"], tree["opt"][0]["m"])
    assert int(back["data"]["step"]) == 7


def test_checkpoint_retention_and_latest(tmp_path):
    for s in (1, 2, 3, 4, 5):
        ckpt.save(tmp_path, s, {"x": np.zeros(1)}, keep=3)
    assert ckpt.latest_step(tmp_path) == 5
    import pathlib
    kept = sorted(p.name for p in pathlib.Path(tmp_path).glob("step_*"))
    assert len(kept) == 3


def test_checkpoint_corruption_detected(tmp_path):
    ckpt.save(tmp_path, 1, {"x": np.arange(10.0)})
    import pathlib
    f = next(pathlib.Path(tmp_path).glob("step_*/arrays.npz"))
    data = bytearray(f.read_bytes())
    data[len(data) // 2] ^= 0xFF
    f.write_bytes(bytes(data))
    with pytest.raises(IOError):
        ckpt.restore(tmp_path)


# ----------------------------------------------------------- compression
def test_int8_error_feedback_converges():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)}
    err = C.init_error(g)
    # accumulated dequantized grads + final error == accumulated true grads
    total_true = np.zeros((64, 64))
    total_deq = np.zeros((64, 64))
    for _ in range(10):
        codes, err = C.compress_tree(g, err)
        deq = C.decompress_tree(codes, g)
        total_true += np.asarray(g["w"])
        total_deq += np.asarray(deq["w"])
    resid = np.abs(total_true - (total_deq + np.asarray(err["w"]))).max()
    assert resid < 1e-3  # error feedback preserves the running sum
    rel = np.abs(total_true - total_deq).max() / np.abs(total_true).max()
    assert rel < 0.2


def test_quantize_roundtrip_scale():
    x = jnp.asarray(np.linspace(-3, 3, 512), jnp.float32)
    q, s = C.quantize(x)
    back = C.dequantize(q, s, x.shape, x.size)
    assert float(jnp.abs(back - x).max()) < 3 / 127 + 1e-6


# ------------------------------------------------------- fault tolerance
def test_cluster_recovery_plan():
    cluster = ClusterState(DeviceLayout(D3(4, 4)))
    cluster.prepare_fallbacks()  # derive-once; recovery itself is rewrite-only
    cluster.fail(5)
    plan = cluster.plan_recovery()
    assert plan.layout.n < 64
    dead_router = DeviceLayout(D3(4, 4)).topo.id_router(5)
    assert dead_router not in {
        DeviceLayout(D3(4, 4)).topo.id_router(v) for v in plan.index_map.values()
    }
    # the plan ships rewritten, host-sized programs with the guest image
    for prog in plan.programs.values():
        assert prog.n == 64
        assert prog.active_devices == tuple(plan.embedding.device_map)


def test_straggler_policy():
    pol = StragglerPolicy(deadline_factor=2.0)
    keep = pol.judge([1.0, 1.1, 0.9, 5.0])
    assert keep == [True, True, True, False]
    # systemic stall: too many "stragglers" -> keep everyone
    keep = pol.judge([1.0, 10.0, 11.0, 12.0])
    assert all(keep)
    assert renormalized_scale(3, 4) == pytest.approx(4 / 3)


# ------------------------------------------------------------------ data
def test_data_deterministic_restart():
    s1 = SyntheticLM(DataState(seed=7, batch=4, seq=16, vocab=100))
    b1 = [s1.next_batch()["tokens"] for _ in range(3)]
    # restart from step 1
    s2 = SyntheticLM(DataState(seed=7, batch=4, seq=16, vocab=100, step=1))
    b2 = s2.next_batch()["tokens"]
    np.testing.assert_array_equal(b1[1], b2)
    # different shards differ
    s3 = SyntheticLM(DataState(seed=7, batch=4, seq=16, vocab=100, shard=1))
    assert not np.array_equal(b1[0], s3.next_batch()["tokens"])
