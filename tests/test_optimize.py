"""Optimizer pass: fused table replay must be bit-exact vs the per-stage
loop on all four algorithm kinds AND on emulated guest programs, on both
the reference backend and the JAX table replay (which runs on the global
array — a single CPU device suffices, no forced mesh).

Structure invariants (what fused where), cache identity, and the new
pipelined §3 schedule ride along. Device-mesh differentials of optimized
programs live in ``program_check_script.py`` (32 forced devices).
"""

import numpy as np
import pytest

from repro.core import alltoall as a2a
from repro.core import broadcast as bc
from repro.core import hypercube as hc
from repro.core import matmul as mm
from repro.core.emulation import embed
from repro.core.topology import D3
from repro.dist.mesh import DeviceLayout
from repro.runtime import lowering
from repro.runtime import optimize as opt
from repro.runtime.backends import get_backend
from repro.runtime.backends.reference import NumpyReferenceBackend
from repro.runtime.rewrite import emulate, scatter_guest

REF = NumpyReferenceBackend()
JAX = get_backend("jax_ppermute")
LAYOUT = DeviceLayout(D3(4, 2))
EMB = embed(D3(4, 4), 2, 2, c_set=(1, 3), p_set=(0, 2))
GUEST = DeviceLayout(D3(2, 2))


def _programs():
    return {
        "alltoall": lowering.lower(a2a.schedule(LAYOUT.da_params, LAYOUT.topo)),
        "allreduce": lowering.lower(hc.allreduce_schedule(LAYOUT.sbh)),
        "broadcast": lowering.lower(bc.depth3_schedule(LAYOUT.topo, (0, 1, 0))),
        "matmul": lowering.lower(mm.schedule(mm.MatmulGrid(2, 2))),
    }


# --------------------------------------------------------------- structure
def test_fusion_structure():
    progs = _programs()
    o = opt.optimize(progs["alltoall"])
    # the whole §3 exchange fuses to ONE batched scatter table
    assert o.num_fused_ops == 1
    (ex,) = o.ops
    assert isinstance(ex, opt.FusedExchange)
    assert len(ex.src) == progs["alltoall"].num_permutes * o.n

    o = opt.optimize(progs["allreduce"])
    assert o.num_fused_ops == progs["allreduce"].num_rounds
    assert all(isinstance(op, opt.FusedCombine) for op in o.ops)

    o = opt.optimize(progs["broadcast"])
    assert o.num_fused_ops == sum(1 for _ in progs["broadcast"].step_groups())
    assert all(isinstance(op, opt.FusedSelect) for op in o.ops)

    o = opt.optimize(progs["matmul"])
    assert o.uniform_rounds  # the §2 lowering emits identical round recipes
    assert o.num_fused_ops % progs["matmul"].num_rounds == 0


def test_optimize_is_cached_and_idempotent():
    prog = _programs()["alltoall"]
    first = opt.optimize(prog)
    assert opt.optimize(prog) is first
    assert opt.optimize(first) is first
    # lru keying is by program EQUALITY — equal programs share one rewrite
    assert opt.as_program(first) == prog
    assert first.kind == "alltoall" and first.n == prog.n


def test_lower_optimized_kwarg():
    sched = bc.depth3_schedule(LAYOUT.topo, (0, 0, 1))
    o = lowering.lower(sched, optimized=True)
    assert isinstance(o, opt.OptimizedProgram)
    assert o.program == lowering.lower(sched)


# ----------------------------------------------- bit-exact replay, 4 kinds
def test_optimized_alltoall_bit_exact():
    prog = _programs()["alltoall"]
    o = opt.optimize(prog)
    n = prog.n
    x = np.random.default_rng(0).standard_normal((n, n, 3)).astype(np.float32)
    want = REF.run_alltoall(x, prog)
    np.testing.assert_array_equal(REF.run_alltoall(x, o), want)
    np.testing.assert_array_equal(np.asarray(JAX.run_alltoall(x, o)), want)


def test_optimized_allreduce_bit_exact():
    prog = _programs()["allreduce"]
    o = opt.optimize(prog)
    x = np.random.default_rng(1).standard_normal((prog.n, 4)).astype(np.float32)
    want = REF.run_allreduce(x, prog)
    np.testing.assert_array_equal(REF.run_allreduce(x, o), want)
    np.testing.assert_array_equal(np.asarray(JAX.run_allreduce(x, o)), want)


def test_optimized_broadcast_bit_exact():
    prog = _programs()["broadcast"]
    o = opt.optimize(prog)
    x = np.random.default_rng(2).standard_normal((prog.n, 4)).astype(np.float32)
    want = REF.run_broadcast(x, prog)
    np.testing.assert_array_equal(REF.run_broadcast(x, o), want)
    np.testing.assert_array_equal(np.asarray(JAX.run_broadcast(x, o)), want)


def test_optimized_pipelined_broadcast_waves():
    """Multi-round wave programs: fused replay == barrier == pipelined."""
    prog = lowering.lower(
        bc.pipelined_m_broadcast_schedule(LAYOUT.topo, (0, 0, 1), waves=4)
    )
    o = opt.optimize(prog)
    x = np.random.default_rng(3).standard_normal(
        (prog.num_rounds, prog.n, 3)).astype(np.float32)
    want = REF.run_broadcast(x, prog)
    np.testing.assert_array_equal(REF.run_broadcast(x, prog, pipelined=True), want)
    np.testing.assert_array_equal(REF.run_broadcast(x, o), want)
    np.testing.assert_array_equal(REF.run_broadcast(x, o, pipelined=True), want)
    np.testing.assert_array_equal(np.asarray(JAX.run_broadcast(x, o)), want)


@pytest.mark.parametrize("grid,X", [((2, 2), 1), ((2, 2), 3), ((1, 4), 2)], ids=str)
def test_optimized_matmul_bit_exact(grid, X):
    prog = lowering.lower(mm.schedule(mm.MatmulGrid(*grid)))
    o = opt.optimize(prog)
    rng = np.random.default_rng(4)
    N = mm.MatmulGrid(*grid).n * X
    B = rng.integers(-4, 5, (N, N)).astype(np.float32)
    A = rng.integers(-4, 5, (N, N)).astype(np.float32)
    want = REF.run_matmul(B, A, prog)
    np.testing.assert_array_equal(want, B @ A)
    np.testing.assert_array_equal(REF.run_matmul(B, A, o), want)
    np.testing.assert_array_equal(np.asarray(JAX.run_matmul(B, A, o)), want)


# ------------------------------------------------------- emulated programs
def test_optimized_emulated_programs_bit_exact():
    """Guest D3(2,2) programs rewritten onto a D3(4,4) host: the optimizer
    fuses partial tables and idle devices still pass through (the reference
    backend asserts it on the optimized replay too)."""
    ng = GUEST.n
    rng = np.random.default_rng(5)

    hp = emulate(lowering.lower(a2a.schedule(GUEST.da_params, GUEST.topo)), EMB)
    o = opt.optimize(hp)
    x = scatter_guest(
        rng.standard_normal((ng, ng, 2)).astype(np.float32), hp, axes=(0, 1))
    want = REF.run_alltoall(x, hp)
    np.testing.assert_array_equal(REF.run_alltoall(x, o), want)
    np.testing.assert_array_equal(np.asarray(JAX.run_alltoall(x, o)), want)

    hp = emulate(lowering.lower(hc.allreduce_schedule(GUEST.sbh)), EMB)
    o = opt.optimize(hp)
    xr = scatter_guest(
        rng.standard_normal((ng, 4)).astype(np.float32), hp, fill=7.0)
    want = REF.run_allreduce(xr, hp)
    np.testing.assert_array_equal(REF.run_allreduce(xr, o), want)
    np.testing.assert_array_equal(np.asarray(JAX.run_allreduce(xr, o)), want)
    assert np.all(np.asarray(JAX.run_allreduce(xr, o))[~hp.active_mask_np] == 7.0)

    g = mm.MatmulGrid(1, 2)
    hp = emulate(lowering.lower(mm.schedule(g)),
                 embed(D3(4, 4), g.topo.K, g.topo.M, p_set=(0, 2)))
    o = opt.optimize(hp)
    N = g.n * 2
    B = rng.integers(-4, 5, (N, N)).astype(np.float32)
    A = rng.integers(-4, 5, (N, N)).astype(np.float32)
    want = REF.run_matmul(B, A, hp)
    np.testing.assert_array_equal(want, B @ A)
    np.testing.assert_array_equal(REF.run_matmul(B, A, o), want)
    np.testing.assert_array_equal(np.asarray(JAX.run_matmul(B, A, o)), want)


# ------------------------------------------------- device-side scatter/gather
def test_jax_block_scatter_gather_round_trip():
    g = (2, 2)
    N = 4 * 3
    B = np.random.default_rng(6).standard_normal((N, N)).astype(np.float32)
    blocks = opt.jax_scatter_blocks(B, g)
    np.testing.assert_array_equal(
        np.asarray(blocks), mm.scatter_blocks(mm.MatmulGrid(*g), B))
    np.testing.assert_array_equal(np.asarray(opt.jax_gather_blocks(blocks, g)), B)


# ------------------------------------------------- pipelined §3 (overlap)
def test_pipelined_alltoall_schedule_stamps_and_replay():
    """`pipelined_schedule` stamps Schedule-1 launch offsets (with the
    measured minimal delays of ``round_starts``) onto the rounds; lowering
    keeps them; replay in any stage order is bit-exact (all-to-all stages
    read only the immutable input)."""
    p = LAYOUT.da_params
    sched = a2a.pipelined_schedule(p, offset=1)
    starts, delays, makespan = a2a.round_starts(p, 1)
    rep = a2a.pipeline(p, 1)
    assert (rep.delays, rep.total_steps) == (delays, makespan)
    assert [r.meta["start_step"] for r in sched.rounds] == starts

    prog = lowering.lower(sched)
    assert sorted({s.start_step for s in prog.stages}) == sorted(set(starts))
    # pipelined launch order is a genuine compaction vs barrier replay
    assert prog.max_start_step + 1 < sum(r.num_steps for r in sched.rounds)

    n = prog.n
    x = np.random.default_rng(7).standard_normal((n, n, 2)).astype(np.float32)
    want = x.transpose(1, 0, 2)
    np.testing.assert_array_equal(REF.run_alltoall(x, prog), want)
    np.testing.assert_array_equal(REF.run_alltoall(x, opt.optimize(prog)), want)
