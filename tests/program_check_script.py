"""Backend equivalence checks — run as a SUBPROCESS with
XLA_FLAGS=--xla_force_host_platform_device_count=32 (set before jax import;
see test_runtime_program.py). Exits 0 on success.

The acceptance bar:

  * the NumPy reference backend and the JAX ppermute backend agree
    bit-for-bit on all four algorithms' programs at (K,M) ∈ {(4,2), (2,4)};
  * ``dragonfly_matmul`` executes the §2 rounds via the program executor —
    bit-exact vs ``jnp.einsum`` on a CPU device mesh, and its HLO contains
    collective-permutes but NO all-gather;
  * pipelined (start_step-ordered) execution of the §5 wave schedule on
    devices is bit-identical to barrier replay;
  * guest D3(2,2) programs rewritten onto a D3(2,4) host
    (``runtime.rewrite.emulate``) replay on the 32-device mesh
    bit-identically to the natively-lowered guest, idle devices passing
    through.

(n = K²M² routers means no §2 grid has exactly 8 devices — the smallest
non-degenerate grid (2,2) is the 16-device mesh checked here; grid (2,1)
runs on 4 of 8 devices in runtime_check_script.py.)
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=32")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import alltoall as a2a
from repro.core import broadcast as bc
from repro.core import hypercube as hc
from repro.core import matmul as mm
from repro.core.emulation import embed
from repro.core.topology import D3
from repro.dist.mesh import DeviceLayout
from repro.runtime import compat, lowering
from repro.runtime import optimize as ropt
from repro.runtime.backends.jax_ppermute import JaxPpermuteBackend
from repro.runtime.backends.reference import NumpyReferenceBackend
from repro.runtime.rewrite import emulate, gather_guest, scatter_guest

JAXBE = JaxPpermuteBackend()
OVER = JaxPpermuteBackend(overlap=True)
REF = NumpyReferenceBackend()


def mesh_of(n):
    return Mesh(np.array(jax.devices()[:n]), ("df",))


def check_differential(K, M):
    """Reference and JAX backends agree bit-for-bit on the §3/§4/§5
    programs of D3(K, M) (broadcast from router id 0 — the falsy root)."""
    layout = DeviceLayout(D3(K, M))
    n = layout.n
    mesh = mesh_of(n)
    rng = np.random.default_rng(0)

    prog = lowering.lower(a2a.schedule(layout.da_params, layout.topo))
    x = rng.standard_normal((n, n, 3)).astype(np.float32)
    np.testing.assert_array_equal(
        np.asarray(JAXBE.run_alltoall(x, prog, mesh=mesh)),
        REF.run_alltoall(x, prog),
    )

    prog = lowering.lower(hc.allreduce_schedule(layout.sbh))
    xr = rng.standard_normal((n, 4)).astype(np.float32)
    np.testing.assert_array_equal(
        np.asarray(JAXBE.run_allreduce(xr, prog, mesh=mesh)),
        REF.run_allreduce(xr, prog),
    )

    prog = lowering.lower(bc.depth3_schedule(layout.topo, layout.topo.id_router(0)))
    assert prog.root == 0
    np.testing.assert_array_equal(
        np.asarray(JAXBE.run_broadcast(xr, prog, mesh=mesh)),
        REF.run_broadcast(xr, prog),
    )
    print(f"differential D3({K},{M}) OK (alltoall/allreduce/broadcast, n={n})")


def check_matmul_differential(K, M, X):
    """§2 on the program executor: JAX == reference == jnp.einsum,
    bit-exact (integer-valued float32)."""
    g = mm.MatmulGrid(K, M)
    prog = lowering.lower(mm.schedule(g))
    rng = np.random.default_rng(1)
    N = g.n * X
    B = rng.integers(-4, 5, (N, N)).astype(np.float32)
    A = rng.integers(-4, 5, (N, N)).astype(np.float32)
    got = JAXBE.run_matmul(B, A, prog, mesh=mesh_of(prog.n))
    want = np.asarray(jnp.einsum("ij,jk->ik", jnp.asarray(B), jnp.asarray(A)))
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(got, REF.run_matmul(B, A, prog))
    print(f"matmul grid ({K},{M}) X={X} OK (n={prog.n}, bit-exact vs einsum)")


def check_matmul_hlo_no_gather():
    """The §2 round structure is on the wire: the dragonfly_matmul HLO has
    one collective-permute per program stage and NO all-gather."""
    from repro.dist import collectives as coll

    prog = coll.matmul_program(2, 2)
    mesh = mesh_of(prog.n)
    b = jnp.zeros((prog.n, 2, 2), jnp.float32)
    f = jax.jit(
        compat.shard_map(
            lambda bb, aa: coll.dragonfly_matmul(bb[0], aa[0], "df", (2, 2))[None],
            mesh=mesh, in_specs=(P("df"), P("df")), out_specs=P("df"),
        )
    )
    txt = f.lower(b, b).as_text()
    n_perm = txt.count("collective_permute") + txt.count("collective-permute")
    n_gather = txt.count("all_gather") + txt.count("all-gather")
    assert n_perm >= prog.num_permutes, (n_perm, prog.num_permutes)
    assert n_gather == 0, f"matmul program must not lower to all-gather ({n_gather})"
    print(f"matmul HLO OK ({n_perm} collective-permutes, 0 all-gathers)")


def check_pipelined_broadcast_on_device():
    """start_step replay on the mesh == barrier replay == reference."""
    topo = D3(4, 2)
    prog = lowering.lower(bc.pipelined_m_broadcast_schedule(topo, (0, 0, 1), waves=4))
    mesh = mesh_of(prog.n)
    rng = np.random.default_rng(2)
    x = rng.standard_normal((prog.num_rounds, prog.n, 3)).astype(np.float32)
    bar = np.asarray(JAXBE.run_broadcast(x, prog, mesh=mesh))
    pip = np.asarray(JAXBE.run_broadcast(x, prog, mesh=mesh, pipelined=True))
    np.testing.assert_array_equal(bar, pip)
    np.testing.assert_array_equal(bar, REF.run_broadcast(x, prog, pipelined=True))
    np.testing.assert_array_equal(
        bar, np.broadcast_to(x[:, prog.root][:, None], x.shape)
    )
    print(f"pipelined broadcast OK (waves={prog.num_rounds}, "
          f"makespan {prog.max_start_step + 1} vs barrier "
          f"{sum(6 for _ in range(prog.num_rounds))})")


def check_emulation_rewrite():
    """Guest D3(2,2) programs rewritten onto a D3(2,4) host (32 devices,
    non-contiguous survivor subset) replay on the JAX mesh bit-identically
    to the natively-lowered guest on the reference backend — idle host
    devices pass through. The §2 matmul runs guest grid (1,2) = D3(1,2)
    on the same 32-device host."""
    host = D3(2, 4)
    guest = DeviceLayout(D3(2, 2))
    emb = embed(host, 2, 2, p_set=(1, 3))
    mesh = mesh_of(host.num_routers)
    rng = np.random.default_rng(3)
    ng = guest.n

    prog = lowering.lower(a2a.schedule(guest.da_params, guest.topo))
    hprog = emulate(prog, emb)
    x = rng.standard_normal((ng, ng, 3)).astype(np.float32)
    xh = scatter_guest(x, hprog, axes=(0, 1))
    got = np.asarray(JAXBE.run_alltoall(xh, hprog, mesh=mesh))
    np.testing.assert_array_equal(got, REF.run_alltoall(xh, hprog))
    np.testing.assert_array_equal(
        gather_guest(got, hprog, axes=(0, 1)), REF.run_alltoall(x, prog)
    )
    idle = ~hprog.active_mask_np
    assert not got[idle].any() and not got[:, idle].any()

    prog = lowering.lower(hc.allreduce_schedule(guest.sbh))
    hprog = emulate(prog, emb)
    xr = rng.standard_normal((ng, 4)).astype(np.float32)
    xrh = scatter_guest(xr, hprog, fill=7.0)  # idle slots must pass through
    got = np.asarray(JAXBE.run_allreduce(xrh, hprog, mesh=mesh))
    np.testing.assert_array_equal(got, REF.run_allreduce(xrh, hprog))
    np.testing.assert_array_equal(gather_guest(got, hprog), REF.run_allreduce(xr, prog))
    np.testing.assert_array_equal(got[~hprog.active_mask_np], 7.0)

    prog = lowering.lower(bc.depth3_schedule(guest.topo, (0, 1, 0)))
    hprog = emulate(prog, emb)
    xbh = scatter_guest(xr, hprog, fill=-2.0)
    got = np.asarray(JAXBE.run_broadcast(xbh, hprog, mesh=mesh))
    np.testing.assert_array_equal(got, REF.run_broadcast(xbh, hprog))
    np.testing.assert_array_equal(gather_guest(got, hprog), REF.run_broadcast(xr, prog))

    g = mm.MatmulGrid(1, 2)
    prog = lowering.lower(mm.schedule(g))
    hprog = emulate(prog, embed(host, g.topo.K, g.topo.M, p_set=(0, 2)))
    X = 2
    N = g.n * X
    B = rng.integers(-4, 5, (N, N)).astype(np.float32)
    A = rng.integers(-4, 5, (N, N)).astype(np.float32)
    got = JAXBE.run_matmul(B, A, hprog, mesh=mesh)
    np.testing.assert_array_equal(got, B @ A)
    np.testing.assert_array_equal(got, REF.run_matmul(B, A, hprog))
    print(f"emulation rewrite OK (guest D3(2,2) on D3(2,4) host, "
          f"{host.num_routers}-device mesh, idle pass-through)")


def check_overlap_differential():
    """Satellite: ``overlap=True`` (start_step-ordered) replay of PIPELINED
    schedules differentially vs the reference backend — the §3 Schedule-1
    all-to-all (``pipelined_schedule``, measured delays stamped) and the §5
    wave broadcast, end-to-end on the device mesh. Barrier replay only used
    to be covered; this pins the overlapped order too."""
    layout = DeviceLayout(D3(4, 2))
    n = layout.n
    mesh = mesh_of(n)
    rng = np.random.default_rng(4)

    prog = lowering.lower(a2a.pipelined_schedule(layout.da_params, offset=1,
                                                 topo=layout.topo))
    assert prog.max_start_step + 1 < 3 * prog.num_rounds  # genuinely pipelined
    x = rng.standard_normal((n, n, 3)).astype(np.float32)
    want = REF.run_alltoall(x, prog)
    np.testing.assert_array_equal(
        np.asarray(OVER.run_alltoall(x, prog, mesh=mesh)), want)

    bprog = lowering.lower(
        bc.pipelined_m_broadcast_schedule(layout.topo, (0, 0, 1), waves=4))
    xw = rng.standard_normal((bprog.num_rounds, n, 3)).astype(np.float32)
    bwant = REF.run_broadcast(xw, bprog, pipelined=True)
    np.testing.assert_array_equal(
        np.asarray(OVER.run_broadcast(xw, bprog, mesh=mesh)), bwant)
    print(f"overlap differential OK (pipelined alltoall makespan "
          f"{prog.max_start_step + 1} vs barrier {3 * prog.num_rounds}; "
          f"wave broadcast)")


def check_optimized_on_device():
    """optimize(program) replays bit-identically to the per-stage ppermute
    loop for every kind on real device buffers (the fused table path the
    run_* wrappers take for OptimizedProgram)."""
    layout = DeviceLayout(D3(4, 2))
    n = layout.n
    mesh = mesh_of(n)
    rng = np.random.default_rng(5)

    prog = lowering.lower(a2a.schedule(layout.da_params, layout.topo))
    x = rng.standard_normal((n, n, 3)).astype(np.float32)
    np.testing.assert_array_equal(
        np.asarray(JAXBE.run_alltoall(x, ropt.optimize(prog))),
        np.asarray(JAXBE.run_alltoall(x, prog, mesh=mesh)))

    prog = lowering.lower(hc.allreduce_schedule(layout.sbh))
    xr = rng.standard_normal((n, 4)).astype(np.float32)
    np.testing.assert_array_equal(
        np.asarray(JAXBE.run_allreduce(xr, ropt.optimize(prog))),
        np.asarray(JAXBE.run_allreduce(xr, prog, mesh=mesh)))

    prog = lowering.lower(bc.depth3_schedule(layout.topo, (0, 1, 0)))
    np.testing.assert_array_equal(
        np.asarray(JAXBE.run_broadcast(xr, ropt.optimize(prog))),
        np.asarray(JAXBE.run_broadcast(xr, prog, mesh=mesh)))

    g = mm.MatmulGrid(2, 2)
    prog = lowering.lower(mm.schedule(g))
    N = g.n * 2
    B = rng.integers(-4, 5, (N, N)).astype(np.float32)
    A = rng.integers(-4, 5, (N, N)).astype(np.float32)
    np.testing.assert_array_equal(
        np.asarray(JAXBE.run_matmul(B, A, ropt.optimize(prog))),
        np.asarray(JAXBE.run_matmul(B, A, prog, mesh=mesh_of(prog.n))))
    print("optimized-vs-loop on-device OK (all four kinds)")


def check_concurrent_guests():
    """Two disjoint D3(2,2) guests COMBINED (``runtime.combine``) onto the
    32-device D3(2,4) host: one mesh replay of the combined program agrees
    bit-for-bit, per guest, with the guests' solo rewritten replays — on
    the per-stage ppermute path AND the fused optimized path."""
    from repro.core.emulation import disjoint_embeddings
    from repro.runtime import combine as cmb

    host = D3(2, 4)
    guest = DeviceLayout(D3(2, 2))
    embs = disjoint_embeddings(host, [(2, 2), (2, 2)])  # position regime
    mesh = mesh_of(host.num_routers)
    rng = np.random.default_rng(6)

    prog = lowering.lower(a2a.schedule(guest.da_params, guest.topo))
    solos = [emulate(prog, e) for e in embs]
    comb = cmb.combine(solos)
    xs = [rng.standard_normal((guest.n, guest.n, 3)).astype(np.float32)
          for _ in embs]
    xh = cmb.scatter_guests(xs, embs, axes=(0, 1))
    got = np.asarray(JAXBE.run_alltoall(xh, comb, mesh=mesh))
    np.testing.assert_array_equal(got, REF.run_alltoall(xh, comb))
    np.testing.assert_array_equal(
        got, np.asarray(JAXBE.run_alltoall(xh, ropt.optimize(comb))))
    for e, x, solo in zip(embs, xs, solos):
        want = gather_guest(
            np.asarray(JAXBE.run_alltoall(
                scatter_guest(x, solo, axes=(0, 1)), solo, mesh=mesh)),
            solo, axes=(0, 1))
        np.testing.assert_array_equal(
            cmb.extract_guest(got, e, axes=(0, 1)), want)
    idle = ~comb.active_mask_np
    assert not got[idle].any() and not got[:, idle].any()

    ar = lowering.lower(hc.allreduce_schedule(guest.sbh))
    comb_ar = cmb.combine([emulate(ar, e) for e in embs])
    ys = [rng.standard_normal((guest.n, 4)).astype(np.float32) for _ in embs]
    yh = cmb.scatter_guests(ys, embs, fill=3.5)
    got = np.asarray(JAXBE.run_allreduce(yh, comb_ar, mesh=mesh))
    np.testing.assert_array_equal(got, REF.run_allreduce(yh, comb_ar))
    for e, y in zip(embs, ys):
        np.testing.assert_array_equal(
            cmb.extract_guest(got, e), REF.run_allreduce(y, ar))
    np.testing.assert_array_equal(got[~comb_ar.active_mask_np], 3.5)
    print(f"concurrent guests OK (2×D3(2,2) combined on D3(2,4) mesh, "
          f"{comb.num_rounds} rounds vs {2 * prog.num_rounds} time-muxed)")


if __name__ == "__main__":
    assert jax.device_count() >= 32, jax.device_count()
    check_differential(4, 2)
    check_differential(2, 4)
    check_overlap_differential()
    check_optimized_on_device()
    check_emulation_rewrite()
    check_concurrent_guests()
    # §2 grids: D3(4,2) is grid (2,2); no grid has K²M² = 2·16 (K must be a
    # perfect square), so (1,4) is the second matmul case.
    check_matmul_differential(2, 2, X=2)
    check_matmul_differential(1, 4, X=1)
    check_matmul_hlo_no_gather()
    check_pipelined_broadcast_on_device()
    print("ALL PROGRAM CHECKS PASSED")
