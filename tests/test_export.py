"""Round-trip and validator property tests for the send/recv export.

The contract under test (``runtime/export.py``): ``export -> to_json ->
from_json`` is lossless for random D3(K,M) shapes across all kinds and
program forms, the DESERIALIZED trace replays bit-exactly against the
reference backend (the JSON alone carries the whole program), and the
static validator rejects hand-corrupted traces — dropped recv, double-
booked link, stale schema version, op on an idle device — with the typed
error naming that violation class.
"""

import dataclasses
import json

import numpy as np
import pytest

try:  # hypothesis is optional — deterministic fallback sampler otherwise
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.emulation import embed
from repro.core.matmul import MatmulGrid
from repro.core.topology import D3
from repro.dist import collectives as coll
from repro.dist.mesh import DeviceLayout
from repro.runtime import export as rexport
from repro.runtime import optimize as opt
from repro.runtime.backends import sendrecv as sr
from repro.runtime.backends.reference import NumpyReferenceBackend

REF = NumpyReferenceBackend()


def _groups(trace):
    """Bucket a (possibly deserialized) trace's ops by replay group,
    device-major — the interpreter's input form."""
    gs = [[] for _ in range(trace.num_groups)]
    for dev, ops in enumerate(trace.devices):
        for op in ops:
            gs[op.group].append((dev, op))
    return tuple(tuple(g) for g in gs)


def _replay_from_json(program, x):
    """Run the trace interpreter on the JSON-round-tripped trace only —
    never on the program — so the test proves the serialized form alone
    reproduces the collective."""
    prog = opt.as_program(program)
    trace = rexport.DeviceTrace.from_json(rexport.export(prog).to_json())
    assert trace == rexport.export(prog)  # lossless
    rexport.validate(trace)
    groups = _groups(trace)
    if prog.kind == "alltoall":
        out = np.zeros_like(x)
        sr._replay(trace, groups, {"x": x, "out": out})
        return out
    val = x.copy()  # allreduce / broadcast
    sr._replay(trace, groups, {"val": val})
    return val


def _ints(rng, shape):
    return rng.integers(-4, 5, shape).astype(np.float32)


# ------------------------------------------------------------ round trips
@given(st.sampled_from([(1, 2), (2, 2), (1, 3), (3, 2), (2, 3)]),
       st.sampled_from(["alltoall", "alltoall1", "allreduce", "broadcast"]),
       st.integers(0, 1), st.data())
@settings(max_examples=25, deadline=None)
def test_roundtrip_replay_random_shapes(km, kind, optimized, data):
    """export -> to_json -> from_json -> replay: lossless and bit-exact
    vs the reference backend for random D3(K,M) and every kind."""
    layout = DeviceLayout(D3(*km))
    if kind == "allreduce" and (layout.sbh is None or layout.sbh.dims == 0):
        kind = "alltoall"  # shape has no hypercube — exercise §3 instead
    if kind == "alltoall1":
        prog = coll.alltoall_program(layout, optimized=bool(optimized),
                                     pipelined=1)
    elif kind == "alltoall":
        prog = coll.alltoall_program(layout, optimized=bool(optimized))
    elif kind == "allreduce":
        prog = coll.allreduce_program(layout, optimized=bool(optimized))
    else:
        root = data.draw(st.integers(0, layout.topo.num_routers - 1))
        prog = coll.broadcast_program(layout, root, optimized=bool(optimized))
    p = opt.as_program(prog)
    rng = np.random.default_rng(p.n * 7 + optimized)
    if p.kind == "alltoall":
        x = _ints(rng, (p.n, p.n, 2))
        want = REF.run_alltoall(x, prog)
    elif p.kind == "allreduce":
        x = _ints(rng, (p.n, 3))
        want = REF.run_allreduce(x, prog)
    else:
        x = _ints(rng, (p.n, 3))
        want = REF.run_broadcast(x, prog)
    np.testing.assert_array_equal(_replay_from_json(prog, x), want)


def test_roundtrip_replay_matmul():
    """§2 trace JSON round trip, replayed on the block buffers."""
    prog = coll.matmul_program(1, 2)
    p = opt.as_program(prog)
    g = MatmulGrid(*p.grid)
    rng = np.random.default_rng(3)
    from repro.core.matmul import gather_blocks, scatter_blocks

    B, A = _ints(rng, (g.n * 2, g.n * 2)), _ints(rng, (g.n * 2, g.n * 2))
    b, a = scatter_blocks(g, B), scatter_blocks(g, A)
    trace = rexport.DeviceTrace.from_json(rexport.export(p).to_json())
    assert trace == rexport.export(p)
    rexport.validate(trace)
    dtype = np.result_type(b, a)
    val = np.zeros(b.shape, dtype)
    c = np.zeros_like(val)
    sr._replay(trace, _groups(trace),
               {"b": b, "a": a, "val": val, "acc": np.zeros_like(val), "c": c},
               dtype=dtype)
    np.testing.assert_array_equal(gather_blocks(g, c), B @ A)


def test_roundtrip_emulated_idle_lists_empty():
    """Emulated programs export with structurally-empty idle op lists, and
    the JSON keeps ``active_devices`` so a consumer can prove it too."""
    emb = embed(D3(2, 2), 1, 2)
    prog = coll.alltoall_program(DeviceLayout(D3(1, 2)), emb)
    trace = rexport.DeviceTrace.from_json(rexport.export(prog).to_json())
    assert trace.active_devices == prog.active_devices
    idle = set(range(trace.n)) - set(trace.active_devices)
    assert idle and all(trace.devices[d] == () for d in idle)
    rng = np.random.default_rng(1)
    x = _ints(rng, (prog.n, prog.n, 2))
    np.testing.assert_array_equal(_replay_from_json(prog, x),
                                  REF.run_alltoall(x, prog))


def test_optimized_form_exports_identically():
    """The fused-table form is the same program — same trace object."""
    layout = DeviceLayout(D3(2, 2))
    plain = coll.alltoall_program(layout)
    fused = coll.alltoall_program(layout, optimized=True)
    assert rexport.export(plain) == rexport.export(fused)


def test_pipelined_waves_are_real_overlap_windows():
    """Schedule-1 pipelining: the same rounds (same per-window send
    counts) launch earlier in the exported trace — each round's window
    opens before the previous round's steps have drained, which is the
    overlap the ``overlap``/``overlap_fused`` executors exploit."""
    layout = DeviceLayout(D3(2, 2))
    barrier = rexport.export(coll.alltoall_program(layout))
    piped = rexport.export(coll.alltoall_program(layout, pipelined=1))
    assert barrier.num_sends == piped.num_sends
    assert ([c for _, c in barrier.waves()] == [c for _, c in piped.waves()])
    assert piped.waves()[-1][0] < barrier.waves()[-1][0]
    assert all(pw <= bw for (pw, _), (bw, _)
               in zip(piped.waves(), barrier.waves()))


# ------------------------------------------------------- corrupted traces
def _edit_devices(trace, fn):
    devs = [list(ops) for ops in trace.devices]
    fn(devs)
    return dataclasses.replace(
        trace, devices=tuple(tuple(ops) for ops in devs))


def _find(trace, op_name):
    for dev, ops in enumerate(trace.devices):
        for i, op in enumerate(ops):
            if op.op == op_name:
                return dev, i, op
    raise AssertionError(f"no {op_name} in trace")


@pytest.fixture(scope="module")
def trace():
    return rexport.export(coll.alltoall_program(DeviceLayout(D3(2, 2))))


def test_validator_accepts_the_export(trace):
    assert rexport.validate(trace) is trace


def test_validator_rejects_stale_schema(trace):
    with pytest.raises(rexport.TraceSchemaError, match="schema 999"):
        rexport.validate(dataclasses.replace(trace, schema=999))


def test_validator_rejects_dropped_recv(trace):
    dev, i, _ = _find(trace, "recv")
    bad = _edit_devices(trace, lambda devs: devs[dev].pop(i))
    with pytest.raises(rexport.TracePairingError, match="matching"):
        rexport.validate(bad)


def test_validator_rejects_double_booked_link(trace):
    dev, i, op = _find(trace, "send")
    bad = _edit_devices(trace, lambda devs: devs[dev].insert(i, op))
    with pytest.raises(rexport.TraceLinkConflictError, match="double-booked"):
        rexport.validate(bad)


def test_validator_rejects_op_on_idle_device(trace):
    emb = embed(D3(2, 2), 1, 2)
    t = rexport.export(coll.alltoall_program(DeviceLayout(D3(1, 2)), emb))
    idle = next(d for d in range(t.n) if d not in t.active_devices)
    _, _, op = _find(t, "copy")
    bad = _edit_devices(t, lambda devs: devs[idle].append(op))
    with pytest.raises(rexport.TraceSchemaError, match="idle device"):
        rexport.validate(bad)


def test_from_json_rejects_garbage():
    with pytest.raises(rexport.TraceSchemaError):
        rexport.DeviceTrace.from_json("not json at all {")
    with pytest.raises(rexport.TraceSchemaError):
        rexport.DeviceTrace.from_json(json.dumps({"kind": "alltoall"}))


# ----------------------------------------------------------- CLI + wiring
def test_cli_validates_files(tmp_path, trace):
    good = tmp_path / "good.json"
    good.write_text(trace.to_json())
    bad = tmp_path / "bad.json"
    bad.write_text(dataclasses.replace(trace, schema=999).to_json())
    assert rexport.main([str(good)]) == 0
    assert rexport.main([str(good), str(bad)]) == 1
    assert rexport.main([]) == 2


def test_dist_device_trace_getter(trace):
    """``dist.collectives.device_trace``: validated, memoized, and the
    fused form maps to the same trace."""
    layout = DeviceLayout(D3(2, 2))
    t1 = coll.device_trace(coll.alltoall_program(layout))
    t2 = coll.device_trace(coll.alltoall_program(layout, optimized=True))
    assert t1 is t2 and t1 == trace
