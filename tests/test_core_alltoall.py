"""Theorem 3 — doubly-parallel all-to-all on D3(ks, ms)."""

import numpy as np
import pytest
try:  # hypothesis is optional — deterministic fallback sampler otherwise
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.topology import D3
from repro.core.routing import vector_dest
from repro.core.simulator import check_vector_round
from repro.core import alltoall as a2a


CASES = [a2a.DAParams(2, 4, 2), a2a.DAParams(4, 6, 2), a2a.DAParams(3, 3, 3), a2a.DAParams(6, 9, 3)]


@pytest.mark.parametrize("p", CASES, ids=lambda p: f"K{p.K}M{p.M}s{p.s}")
def test_round_count_theorem3(p):
    rs = list(a2a.rounds(p))
    assert len(rs) == p.total_rounds == p.K * p.M * p.M // p.s
    assert all(len(vs) == p.s for _, vs in rs)


@pytest.mark.parametrize("p", CASES, ids=lambda p: f"K{p.K}M{p.M}s{p.s}")
def test_vector_coverage(p):
    """Every (γ,π,δ) used exactly once => all-to-all completeness."""
    a2a.verify_vector_coverage(p)


@pytest.mark.parametrize("p", CASES[:3], ids=lambda p: f"K{p.K}M{p.M}s{p.s}")
def test_rounds_conflict_free(p):
    """Each round: every router sends all s vectors simultaneously; the
    generalized Property 3 guarantees zero link conflicts."""
    topo = D3(p.K, p.M)
    routers = list(topo.routers())
    for key, vecs in a2a.rounds(p):
        # within-round disagreement (the DA property)
        gs = [v[0] for v in vecs]
        ps = [v[1] for v in vecs]
        ds = [v[2] for v in vecs]
        assert len(set(gs)) == p.s and len(set(ps)) == p.s and len(set(ds)) == p.s, key
        sends = [(r, v) for v in vecs for r in routers]
        conflicts, _ = check_vector_round(topo, sends)
        assert conflicts == [], (key, conflicts[:2])


def test_delivery_completeness_small():
    """Actually move data: after all rounds every router holds exactly one
    chunk from every source."""
    p = a2a.DAParams(2, 4, 2)
    topo = D3(p.K, p.M)
    n = topo.num_routers
    received = {r: set() for r in topo.routers()}
    for _, vecs in a2a.rounds(p):
        for v in vecs:
            for src in topo.routers():
                received[vector_dest(topo, src, v)].add(src)
    for r, srcs in received.items():
        assert len(srcs) == n, r


@pytest.mark.parametrize("p", CASES, ids=lambda p: f"K{p.K}M{p.M}s{p.s}")
def test_pipeline_schedules(p):
    """Measured pipeline costs: schedule 3 conflict-free with zero delays,
    schedule 1 delays ~= paper's KM count, makespans track the formulas."""
    r3 = a2a.pipeline(p, offset=3)
    assert r3.delays == 0
    assert r3.total_steps == 3 * p.total_rounds  # 3KM²/s exactly

    if p.s > p.M // 2:
        return  # paper: Schedule 1 requires s <= M/2 (2s local offsets/step)
    r1 = a2a.pipeline(p, offset=1)
    # paper: KM delays; our minimal-delay scheduler may consolidate a few
    # (successive delays merge) so allow a small band around KM.
    assert r1.delays <= a2a.schedule1_predicted_delays(p) * 2
    assert r1.total_steps <= p.total_rounds + r1.delays + 3
    # schedule-1 makespan ~ KM²/s + delays, far below schedule 3
    assert r1.total_steps < r3.total_steps / 2


def test_schedule1_sM2_constraint():
    """Schedule 1 valid only if s <= M/2 (2s local offsets per step)."""
    p = a2a.DAParams(2, 4, 2)  # s = M/2 boundary OK
    r1 = a2a.pipeline(p, offset=1)
    assert r1.total_steps > 0


@given(st.sampled_from(CASES), st.integers(1, 3))
@settings(max_examples=8, deadline=None)
def test_cost_scaling_property(p, x):
    """n = x·KM² items -> x² · (KM²/s) rounds (Theorem 3 general form)."""
    P = p.K * p.M * p.M
    assert a2a.alltoall_cost_rounds(p, x * P) == x * x * p.total_rounds


def test_beats_relatively_prime_example():
    """Paper's K=7, M=16 example: running on embedded D3(5,15) with s=5
    costs 225·(1.59)² ≈ 569 << 1792."""
    emb = a2a.DAParams(5, 15, 5)
    assert emb.total_rounds == 5 * 15 * 15 // 5  # 225
    full_items = 7 * 16 * 16  # 1792 items on the big machine
    ratio = full_items / (5 * 15 * 15)
    cost = emb.total_rounds * ratio**2
    assert cost < 1792
    assert 550 < cost < 590
