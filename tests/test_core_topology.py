"""Topology + source-vector routing properties (paper §1, P1-P3)."""

import itertools

import pytest
try:  # hypothesis is optional — deterministic fallback sampler otherwise
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.topology import D3
from repro.core.routing import vector_for, vector_dest, vector_path, path_links
from repro.core.simulator import check_vector_round


small_km = st.tuples(st.integers(2, 5), st.integers(2, 5))


def test_counts():
    t = D3(3, 4)
    assert t.num_routers == 3 * 16
    assert t.num_local_links == 3 * 4 * (4 * 3 // 2)
    ids = sorted(t.router_id(r) for r in t.routers())
    assert ids == list(range(t.num_routers))


@given(small_km)
@settings(max_examples=20, deadline=None)
def test_id_roundtrip(km):
    K, M = km
    t = D3(K, M)
    for i in range(t.num_routers):
        assert t.router_id(t.id_router(i)) == i


@given(small_km, st.integers(0, 10_000))
@settings(max_examples=50, deadline=None)
def test_vector_bijection(km, seed):
    """The unique vector src->dst routes there (paper §1)."""
    K, M = km
    t = D3(K, M)
    n = t.num_routers
    src = t.id_router(seed % n)
    dst = t.id_router((seed * 7 + 3) % n)
    vec = vector_for(t, src, dst)
    assert vector_dest(t, src, vec) == dst
    path = vector_path(t, src, vec)
    assert path[0] == src and path[-1] == dst
    for a, b in path_links(path):
        assert t.is_link(a, b), (a, b)


@given(small_km, st.data())
@settings(max_examples=40, deadline=None)
def test_property1_permutation_conflict_free(km, data):
    """P1: every router sends the same vector simultaneously — a
    permutation, zero link conflicts."""
    K, M = km
    t = D3(K, M)
    vec = (
        data.draw(st.integers(0, K - 1)),
        data.draw(st.integers(0, M - 1)),
        data.draw(st.integers(0, M - 1)),
    )
    sends = [(r, vec) for r in t.routers()]
    conflicts, arrivals = check_vector_round(t, sends)
    assert conflicts == []
    assert len(arrivals) == t.num_routers  # bijective
    assert all(len(v) == 1 for v in arrivals.values())


@given(small_km, st.data())
@settings(max_examples=40, deadline=None)
def test_property3_disagreeable_pair(km, data):
    """P3: two vectors disagreeing in every coordinate are conflict-free
    when sent by every router simultaneously."""
    K, M = km
    t = D3(K, M)
    g1 = data.draw(st.integers(0, K - 1))
    g2 = data.draw(st.integers(0, K - 1).filter(lambda x: x != g1))
    p1 = data.draw(st.integers(0, M - 1))
    p2 = data.draw(st.integers(0, M - 1).filter(lambda x: x != p1))
    d1 = data.draw(st.integers(0, M - 1))
    d2 = data.draw(st.integers(0, M - 1).filter(lambda x: x != d1))
    sends = [(r, (g1, p1, d1)) for r in t.routers()]
    sends += [(r, (g2, p2, d2)) for r in t.routers()]
    conflicts, _ = check_vector_round(t, sends)
    assert conflicts == []


def test_property3_violation_detected():
    """Sanity for the verifier itself: two vectors sharing γ (and hence
    global links) DO conflict — the simulator must see it."""
    t = D3(3, 3)
    # same gamma, different pi/delta: global phase uses same directed links?
    sends = [(r, (1, 0, 1)) for r in t.routers()] + [(r, (1, 1, 2)) for r in t.routers()]
    conflicts, _ = check_vector_round(t, sends)
    # identical gamma with differing delta means two packets traverse
    # distinct global links... conflicts arise when delta equal or paths
    # collide; construct a guaranteed collision instead: same vector twice.
    sends2 = [(r, (1, 1, 1)) for r in t.routers()] + [(r, (1, 1, 1)) for r in t.routers()]
    conflicts2, _ = check_vector_round(t, sends2)
    assert conflicts2, "duplicate sends must conflict"


def test_diameter_small():
    # D3 diameter is small (<= 5ish for tiny nets); spot-check reachability.
    t = D3(2, 3)
    routers = list(t.routers())
    for a, b in itertools.product(routers[:4], routers[-4:]):
        assert t.shortest_path_len(a, b) <= 5
