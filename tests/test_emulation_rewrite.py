"""Emulation rewrite layer: guest D3(J,L) programs lowered onto their
D3(K,M) host (``runtime.rewrite``) and the rewrite-only failover path
(``train.fault_tolerance``).

Host-side (reference backend) coverage; the forced-32-device JAX-mesh
differential lives in ``program_check_script.py`` (spawned by
``test_runtime_program.py::test_program_backends_32dev``).
"""

import numpy as np
import pytest

from repro.core import alltoall as a2a
from repro.core import broadcast as bc
from repro.core import hypercube as hc
from repro.core import matmul as mm
from repro.core.emulation import embed
from repro.core.simulator import verify
from repro.core.topology import D3
from repro.dist.mesh import DeviceLayout
from repro.runtime import lowering
from repro.runtime.backends.reference import NumpyReferenceBackend
from repro.runtime.program import LocalContract, Match, Perm, ReduceCombine
from repro.runtime.rewrite import (
    emulate,
    emulate_schedule,
    gather_guest,
    scatter_guest,
)
from repro.train.fault_tolerance import ClusterState, UnpreparedShapeError

REF = NumpyReferenceBackend()
HOST = D3(4, 4)
GUEST = DeviceLayout(D3(2, 2))

#: a deliberately non-contiguous survivor set — the regime failover produces
EMB = embed(HOST, 2, 2, c_set=(1, 3), p_set=(0, 2))


def _guest_programs():
    return {
        "alltoall": lowering.lower(a2a.schedule(GUEST.da_params, GUEST.topo)),
        "allreduce": lowering.lower(hc.allreduce_schedule(GUEST.sbh)),
        "broadcast": lowering.lower(bc.depth3_schedule(GUEST.topo, (0, 1, 0))),
    }


# ------------------------------------------------------------ structure
def test_rewrite_preserves_stamps_and_kind():
    for kind, prog in _guest_programs().items():
        host_prog = emulate(prog, EMB)
        assert host_prog.kind == kind == prog.kind
        assert host_prog.n == HOST.num_routers
        assert host_prog.guest_n == prog.n == GUEST.n
        assert host_prog.num_rounds == prog.num_rounds
        assert host_prog.active_devices == tuple(EMB.device_map)
        assert len(host_prog.stages) == len(prog.stages)
        for g, h in zip(prog.stages, host_prog.stages):
            assert type(g) is type(h)
            assert (g.round_index, g.step, g.start_step) == \
                (h.round_index, h.step, h.start_step)


def test_rewrite_maps_every_pair_through_device_map():
    dm = EMB.device_map
    prog = _guest_programs()["alltoall"]
    host_prog = emulate(prog, EMB)
    for g, h in zip(prog.comm_stages, host_prog.comm_stages):
        assert isinstance(h, Perm) and h.is_partial and h.size == HOST.num_routers
        assert h.pairs == tuple((int(dm[s]), int(dm[d])) for s, d in g.pairs)
    root_prog = _guest_programs()["broadcast"]
    assert emulate(root_prog, EMB).root == int(dm[root_prog.root])


def test_rewrite_is_cached_per_program_and_embedding():
    """Satellite: repeated failover re-lowers hit the lru cache, so host
    index arrays are shared rather than rebuilt inside jit traces."""
    prog = _guest_programs()["alltoall"]
    first = emulate(prog, EMB)
    assert emulate(prog, EMB) is first
    assert first.stages[0].sigma_np is first.stages[0].sigma_np
    other = embed(HOST, 2, 2)  # different survivor set -> different entry
    assert emulate(prog, other) is not first
    assert emulate(prog, other) is emulate(prog, other)


def test_rewrite_rejects_mismatched_guest_and_double_rewrite():
    prog = _guest_programs()["alltoall"]
    with pytest.raises(ValueError, match="guest"):
        emulate(prog, embed(HOST, 2, 3))
    host_prog = emulate(prog, EMB)
    with pytest.raises(ValueError, match="already an emulation rewrite"):
        emulate(host_prog, embed(D3(4, 8), 4, 4))


# ----------------------------------------------- differential: 4 kinds
def test_alltoall_rewrite_bit_exact_vs_native_guest():
    prog = _guest_programs()["alltoall"]
    host_prog = emulate(prog, EMB)
    rng = np.random.default_rng(0)
    x = rng.standard_normal((prog.n, prog.n, 3))
    want = REF.run_alltoall(x, prog)
    xh = scatter_guest(x, host_prog, axes=(0, 1))
    out = REF.run_alltoall(xh, host_prog)
    np.testing.assert_array_equal(gather_guest(out, host_prog, axes=(0, 1)), want)
    idle = ~host_prog.active_mask_np
    assert not out[idle].any() and not out[:, idle].any()


def test_allreduce_rewrite_bit_exact_and_idle_passthrough():
    prog = _guest_programs()["allreduce"]
    host_prog = emulate(prog, EMB)
    rng = np.random.default_rng(1)
    x = rng.standard_normal((prog.n, 4))
    # idle slots carry garbage that must neither leak in nor change
    xh = scatter_guest(x, host_prog, fill=123.25)
    out = REF.run_allreduce(xh, host_prog)
    np.testing.assert_array_equal(gather_guest(out, host_prog), REF.run_allreduce(x, prog))
    np.testing.assert_array_equal(out[~host_prog.active_mask_np], 123.25)


def test_broadcast_rewrite_bit_exact_vs_native_guest():
    prog = _guest_programs()["broadcast"]
    host_prog = emulate(prog, EMB)
    rng = np.random.default_rng(2)
    x = rng.standard_normal((prog.n, 2))
    xh = scatter_guest(x, host_prog, fill=-1.5)
    out = REF.run_broadcast(xh, host_prog)
    np.testing.assert_array_equal(gather_guest(out, host_prog), REF.run_broadcast(x, prog))
    np.testing.assert_array_equal(out[~host_prog.active_mask_np], -1.5)


@pytest.mark.parametrize("grid,X", [((1, 2), 3), ((2, 2), 2)], ids=str)
def test_matmul_rewrite_bit_exact(grid, X):
    """§2 guest grids on a larger host: grid (1,2) = D3(1,2) and grid
    (2,2) = D3(4,2), both rewritten onto D3(4,4)."""
    g = mm.MatmulGrid(*grid)
    prog = lowering.lower(mm.schedule(g))
    emb = embed(HOST, g.topo.K, g.topo.M, p_set=(1, 3))
    host_prog = emulate(prog, emb)
    rng = np.random.default_rng(3)
    N = g.n * X
    B = rng.integers(-4, 5, (N, N)).astype(np.float64)
    A = rng.integers(-4, 5, (N, N)).astype(np.float64)
    np.testing.assert_array_equal(REF.run_matmul(B, A, host_prog), B @ A)
    np.testing.assert_array_equal(REF.run_matmul(B, A, host_prog),
                                  REF.run_matmul(B, A, prog))


# ------------------------------------------- conflict-freedom on host
@pytest.mark.parametrize("c_set,p_set", [(None, None), ((1, 3), (0, 2))],
                         ids=["contiguous", "scattered"])
def test_rewritten_schedules_conflict_free_on_host_graph(c_set, p_set):
    """Dilation-1: every guest hop maps to one host link, so the unified
    simulator must find ZERO conflicts replaying the rewritten schedule on
    the literal host graph — the programmatic form of the demo's old
    hand-rolled ``verify_schedule_on_host`` loop."""
    emb = embed(HOST, 2, 2, c_set=c_set, p_set=p_set)
    scheds = {
        "alltoall": a2a.schedule(GUEST.da_params, GUEST.topo),
        "allreduce": hc.allreduce_schedule(GUEST.sbh),
        "broadcast": bc.depth3_schedule(GUEST.topo, (0, 1, 0)),
    }
    for kind, sched in scheds.items():
        hsched = emulate_schedule(sched, emb)
        assert hsched.topo == HOST
        hsched.validate()  # every mapped hop is a physical host link
        verify(HOST, hsched).raise_on_conflict(f"rewritten {kind}")


def test_rewritten_pipelined_schedule_conflict_free_on_host_graph():
    """start_step stamps survive the schedule rewrite: the §5 pipelined
    wave schedule stays conflict-free under overlapped replay on the
    host graph."""
    sched = bc.pipelined_m_broadcast_schedule(GUEST.topo, (0, 0, 1), waves=3)
    hsched = emulate_schedule(sched, EMB)
    assert [r.meta.get("start_step") for r in hsched.rounds] == \
        [r.meta.get("start_step") for r in sched.rounds]
    verify(HOST, hsched, pipelined=True).raise_on_conflict("pipelined waves")


def test_emulate_schedule_is_verify_only():
    """Lowering metadata is moved under guest_* so the host view cannot be
    mistaken for a lowerable schedule."""
    sched = a2a.schedule(GUEST.da_params, GUEST.topo)
    hsched = emulate_schedule(sched, EMB)
    assert all("vectors" not in r.meta and "guest_vectors" in r.meta
               for r in hsched.rounds)
    with pytest.raises(ValueError, match="on D3"):
        emulate_schedule(sched, embed(HOST, 2, 3))


# ----------------------------------------------- rewrite-only failover
def _boom(*a, **k):
    raise AssertionError("recovery path called into a core derivation")


def test_plan_recovery_is_rewrite_only(monkeypatch):
    """Acceptance: zero calls into core.{matmul,alltoall,broadcast,
    hypercube} derivations (and zero re-lowering) inside plan_recovery."""
    cluster = ClusterState(DeviceLayout(D3(4, 4)))
    cluster.prepare_fallbacks()
    cluster.fail(5)
    monkeypatch.setattr(a2a, "schedule", _boom)
    monkeypatch.setattr(mm, "schedule", _boom)
    monkeypatch.setattr(bc, "depth3_schedule", _boom)
    monkeypatch.setattr(hc, "allreduce_schedule", _boom)
    monkeypatch.setattr(lowering, "lower", _boom)
    plan = cluster.plan_recovery()
    assert set(plan.programs) >= {"alltoall", "broadcast"}
    guest = plan.layout.topo
    dead = DeviceLayout(D3(4, 4)).topo.id_router(5)
    assert dead not in {HOST.id_router(h) for h in plan.index_map.values()}
    # the rewritten programs are host-sized and bit-exact vs the library's
    # natively-lowered guest program
    native = cluster.library[(guest.K, guest.M)].programs["alltoall"]
    rewritten = plan.programs["alltoall"]
    assert rewritten.n == 64 and rewritten.guest_n == native.n
    rng = np.random.default_rng(4)
    x = rng.standard_normal((native.n, native.n, 2))
    np.testing.assert_array_equal(
        gather_guest(
            REF.run_alltoall(scatter_guest(x, rewritten, axes=(0, 1)), rewritten),
            rewritten, axes=(0, 1)),
        REF.run_alltoall(x, native),
    )
    # and the host-graph schedules verify conflict-free without re-deriving
    for kind, sched in plan.schedules.items():
        verify(D3(4, 4), sched).raise_on_conflict(f"recovery {kind}")


def test_plan_recovery_requires_preparation():
    cluster = ClusterState(DeviceLayout(D3(4, 4)))
    cluster.fail(5)
    with pytest.raises(UnpreparedShapeError, match="prepare_fallbacks"):
        cluster.plan_recovery()


def test_recovery_plan_covers_both_drop_regimes():
    # striped failures: same (d, p) slot across every cabinet -> the old
    # cabinet-drop-only search would keep nothing; position-drop keeps 4/9
    cluster = ClusterState(DeviceLayout(D3(3, 3)))
    cluster.prepare_fallbacks()
    for c in range(3):
        cluster.fail(DeviceLayout(D3(3, 3)).topo.router_id((c, 0, 0)))
    plan = cluster.plan_recovery()
    assert (plan.layout.topo.K, plan.layout.topo.M) == (3, 2)
    assert plan.embedding.c_set == (0, 1, 2) and plan.embedding.p_set == (1, 2)
    survivors = {D3(3, 3).id_router(h) for h in plan.index_map.values()}
    assert survivors.isdisjoint(cluster.dead)


# ---------------------------------------------------- stage-level guards
def test_partial_perm_validation():
    Perm(((3, 5), (5, 3)), n=8)  # partial over 8 devices: ok
    with pytest.raises(ValueError, match="exceed"):
        Perm(((3, 9), (9, 3)), n=8)
    with pytest.raises(ValueError, match="cover"):
        Perm(((3, 5), (5, 3)))  # no n: must cover 0..len-1
    p = Perm(((1, 2), (2, 1)), n=4)
    assert p.is_partial and p.sigma == (0, 2, 1, 3) and p.inverse == (0, 2, 1, 3)
    assert list(p.src_np) == [1, 2] and list(p.dst_np) == [2, 1]


def test_active_devices_validation():
    from repro.runtime.program import CollectiveProgram

    with pytest.raises(ValueError, match="distinct"):
        CollectiveProgram("alltoall", 4, 1, (), active_devices=(1, 1))
    with pytest.raises(ValueError, match="exceed"):
        CollectiveProgram("alltoall", 4, 1, (), active_devices=(0, 7))
    prog = CollectiveProgram("alltoall", 4, 1, (), active_devices=(2, 0))
    assert prog.guest_n == 2
    assert list(prog.active_np) == [2, 0]  # guest order, NOT sorted
    assert list(prog.active_mask_np) == [True, False, True, False]


def test_scatter_gather_guest_roundtrip():
    prog = emulate(_guest_programs()["alltoall"], EMB)
    rng = np.random.default_rng(5)
    x = rng.standard_normal((prog.guest_n, prog.guest_n, 2))
    xh = scatter_guest(x, prog, axes=(0, 1), fill=9.0)
    assert xh.shape == (prog.n, prog.n, 2)
    np.testing.assert_array_equal(gather_guest(xh, prog, axes=(0, 1)), x)
    idle = ~prog.active_mask_np
    np.testing.assert_array_equal(xh[idle], 9.0)
    with pytest.raises(ValueError, match="slots"):
        scatter_guest(np.zeros((3,)), prog)
