"""Executable backend contract: every registered backend × every program form.

The conformance suite the backend registry docstring promises
(``runtime/backends/__init__.py``): each entry of ``available_backends()``
replays the four algorithms' lowered programs — plain, optimized
(fused-table), emulated (guest-on-host ``active_devices``), and combined
(two-tenant ``runtime.combine``) — bit-for-bit against the pure-NumPy
``reference`` backend, and honours idle-device pass-through on emulated
forms. A new backend added to ``_REGISTRY`` is picked up here with zero
test changes; a backend that drifts by one element fails with the exact
program form that exposed it.

Mesh-backed whole-array replay (``jax_ppermute``) needs ``program.n`` real
devices; those cases skip in the single-device tier-1 process (the same
programs run devices-for-real in ``tests/dist_check_script.py``). The
``auto`` backend is pinned to an analytic tuner so the suite never touches
the on-disk measurement cache.
"""

import functools

import numpy as np
import pytest

from repro.core.emulation import disjoint_embeddings, embed
from repro.core.matmul import MatmulGrid
from repro.core.topology import D3
from repro.dist import collectives as coll
from repro.dist.mesh import DeviceLayout
from repro.runtime import optimize as opt
from repro.runtime.backends import available_backends, get_backend

HOST = DeviceLayout(D3(2, 2))                        # n = 8, has an SBH
GUEST = DeviceLayout(D3(1, 2))                       # n = 4 guest
EMB = embed(D3(2, 2), 1, 2)                          # D3(1,2) on D3(2,2)
EMBS = disjoint_embeddings(D3(2, 2), [(1, 2), (1, 2)])  # two tenants

BACKENDS = available_backends()


def _program_matrix():
    """(label, program) for every kind × {plain, optimized} × {native,
    emulated, combined} the n=8 host supports."""
    out = []
    for optimized in (False, True):
        tag = "opt" if optimized else "plain"
        out += [
            (f"alltoall-{tag}",
             coll.alltoall_program(HOST, optimized=optimized)),
            (f"alltoall-pipe1-{tag}",
             coll.alltoall_program(HOST, optimized=optimized, pipelined=1)),
            (f"allreduce-{tag}",
             coll.allreduce_program(HOST, optimized=optimized)),
            (f"broadcast-{tag}",
             coll.broadcast_program(HOST, 0, optimized=optimized)),
            (f"matmul-{tag}",
             coll.matmul_program(1, 2, optimized=optimized)),
            (f"alltoall-emu-{tag}",
             coll.alltoall_program(GUEST, EMB, optimized=optimized)),
            (f"allreduce-emu-{tag}",
             coll.allreduce_program(GUEST, EMB, optimized=optimized)),
            (f"broadcast-emu-{tag}",
             coll.broadcast_program(GUEST, 0, EMB, optimized=optimized)),
            (f"matmul-emu-{tag}",
             coll.matmul_program(1, 2, EMB, optimized=optimized)),
            (f"alltoall-comb-{tag}",
             coll.concurrent_program("alltoall", EMBS, optimized=optimized)),
            (f"allreduce-comb-{tag}",
             coll.concurrent_program("allreduce", EMBS, optimized=optimized)),
            (f"broadcast-comb-{tag}",
             coll.concurrent_program("broadcast", EMBS, optimized=optimized)),
        ]
    return out


PROGRAMS = _program_matrix()
_BY_LABEL = dict(PROGRAMS)


def _make_backend(name):
    if name == "auto":
        from repro.runtime.autotune import Autotuner

        return get_backend("auto", tuner=Autotuner(mode="analytic"))
    return get_backend(name)


def _inputs(label):
    """Deterministic integer-valued float inputs (sums/products stay exact
    in float32, so bit-equality across backends is meaningful)."""
    prog = opt.as_program(_BY_LABEL[label])
    rng = np.random.default_rng(abs(hash(label)) % (2**32))
    if prog.kind == "alltoall":
        return (rng.integers(-4, 5, (prog.n, prog.n, 3)).astype(np.float32),)
    if prog.kind in ("allreduce", "broadcast"):
        return (rng.integers(-4, 5, (prog.n, 5)).astype(np.float32),)
    side = MatmulGrid(*prog.grid).n * 2
    return (rng.integers(-4, 5, (side, side)).astype(np.float32),
            rng.integers(-4, 5, (side, side)).astype(np.float32))


def _run(backend, label):
    program = _BY_LABEL[label]
    prog = opt.as_program(program)
    args = _inputs(label)
    if prog.kind == "matmul":
        return np.asarray(backend.run_matmul(args[0], args[1], program))
    return np.asarray(getattr(backend, f"run_{prog.kind}")(args[0], program))


@functools.lru_cache(maxsize=None)
def _reference_output(label):
    return _run(_make_backend("reference"), label)


def _skip_if_meshless(name, label):
    if name != "jax_ppermute":
        return
    import jax

    if jax.device_count() < opt.as_program(_BY_LABEL[label]).n:
        pytest.skip("jax_ppermute whole-array replay needs a full mesh")


def test_registry_covers_the_suite():
    """The suite really is over every registered backend (a backend added
    to ``_REGISTRY`` without a loader typo shows up here)."""
    assert "reference" in BACKENDS and "sendrecv" in BACKENDS
    assert len(BACKENDS) == len(set(BACKENDS))


@pytest.mark.parametrize("label", [lbl for lbl, _ in PROGRAMS])
@pytest.mark.parametrize("name", BACKENDS)
def test_backend_matches_reference(name, label):
    """Bit-exact agreement with ``reference`` on this program form."""
    _skip_if_meshless(name, label)
    got = _run(_make_backend(name), label)
    np.testing.assert_array_equal(got, _reference_output(label),
                                  err_msg=f"{name} diverged on {label}")


@pytest.mark.parametrize("name", BACKENDS)
def test_idle_passthrough_emulated(name):
    """Idle host devices of emulated programs: inputs flow through
    untouched (allreduce/broadcast) or stay zero (alltoall outputs)."""
    for label in ("alltoall-emu-plain", "allreduce-emu-plain",
                  "broadcast-emu-plain"):
        _skip_if_meshless(name, label)
        prog = opt.as_program(_BY_LABEL[label])
        idle = ~prog.active_mask_np
        assert idle.any(), "emulated program should leave hosts idle"
        args = _inputs(label)
        out = _run(_make_backend(name), label)
        if prog.kind == "alltoall":
            assert not out[idle].any(), f"{name}: idle rows written on {label}"
            assert not out[:, idle].any(), f"{name}: idle slots written on {label}"
        else:
            np.testing.assert_array_equal(
                out[idle], args[0][idle],
                err_msg=f"{name}: idle rows changed on {label}")


@pytest.mark.parametrize("name", BACKENDS)
def test_combined_covers_both_tenants(name):
    """Combined two-tenant programs: the union of guest images is active,
    the rest idle — and the whole thing still matches reference (covered
    above); here the structure that makes that meaningful is asserted."""
    prog = opt.as_program(_BY_LABEL["alltoall-comb-plain"])
    assert prog.active_devices is not None
    assert prog.guest_n == sum(e.guest.num_routers for e in EMBS)
    _skip_if_meshless(name, "alltoall-comb-plain")
    out = _run(_make_backend(name), "alltoall-comb-plain")
    idle = ~prog.active_mask_np
    if idle.any():
        assert not out[idle].any()
