"""Per-architecture smoke tests: instantiate the REDUCED config of each
family, run one forward/train step and one decode step on CPU, assert
output shapes and finiteness."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_smoke_config, get_config
from repro.models import model as M


def make_batch(cfg, B=2, S=16, seed=0):
    rng = np.random.default_rng(seed)
    batch = {}
    if cfg.embeds_input:
        batch["embeds"] = jnp.asarray(
            rng.standard_normal((B, S, cfg.d_model)), jnp.dtype(cfg.compute_dtype)
        )
    else:
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    if cfg.rope == "mrope":
        pos = np.broadcast_to(np.arange(S, dtype=np.int32), (3, B, S))
        batch["mrope_positions"] = jnp.asarray(pos)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_loss(arch):
    cfg = get_smoke_config(arch)
    params = M.init_params(jax.random.key(0), cfg)
    batch = make_batch(cfg)
    loss, metrics = jax.jit(
        lambda p, b: M.loss_fn(p, b, cfg, use_kernel=False, remat=False)
    )(params, batch)
    assert np.isfinite(float(loss)), (arch, metrics)
    logits, aux, h = M.forward_train(params, batch, cfg, use_kernel=False, remat=False)
    B, S = batch["labels"].shape
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_grad_step(arch):
    cfg = get_smoke_config(arch)
    params = M.init_params(jax.random.key(1), cfg)
    batch = make_batch(cfg, seed=1)

    @jax.jit
    def step(p, b):
        (loss, _), grads = jax.value_and_grad(
            lambda q: M.loss_fn(q, b, cfg, use_kernel=False, remat=True), has_aux=True
        )(p)
        p2 = jax.tree.map(lambda w, g: w - 1e-3 * g.astype(w.dtype), p, grads)
        return loss, p2

    l0, params = step(params, batch)
    l1, params = step(params, batch)
    assert np.isfinite(float(l0)) and np.isfinite(float(l1)), arch
    # one SGD step on the same batch should not explode
    assert float(l1) < float(l0) * 1.5 + 1.0, (arch, float(l0), float(l1))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = get_smoke_config(arch)
    params = M.init_params(jax.random.key(2), cfg)
    B, max_seq = 2, 32
    cache = M.init_cache(cfg, B, max_seq)
    rng = np.random.default_rng(2)
    if cfg.embeds_input:
        batch = {"embed": jnp.asarray(rng.standard_normal((B, cfg.d_model)),
                                      jnp.dtype(cfg.compute_dtype))}
    else:
        batch = {"token": jnp.asarray(rng.integers(0, cfg.vocab, (B,)), jnp.int32)}
    if cfg.rope == "mrope":
        batch["mrope_positions"] = jnp.zeros((3, B, 1), jnp.int32)

    step = jax.jit(lambda p, c, b, pos: M.decode_step(p, c, b, pos, cfg))
    logits, cache = step(params, cache, batch, 0)
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch
    logits2, cache = step(params, cache, batch, 1)
    assert np.isfinite(np.asarray(logits2, np.float32)).all(), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    """Prefill-vs-decode consistency: running tokens one-by-one through the
    cache reproduces the teacher-forced forward logits."""
    cfg = get_smoke_config(arch)
    if cfg.embeds_input:
        pytest.skip("stub-frontend archs exercise decode elsewhere")
    params = M.init_params(jax.random.key(3), cfg)
    B, S = 1, 8
    rng = np.random.default_rng(3)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    logits_full, _, _ = M.forward_train(params, batch, cfg, use_kernel=False, remat=False)

    cache = M.init_cache(cfg, B, S, dtype=jnp.float32)
    step = jax.jit(lambda p, c, b, pos: M.decode_step(p, c, b, pos, cfg))
    outs = []
    for t in range(S):
        lg, cache = step(params, cache, {"token": tokens[:, t]}, t)
        outs.append(np.asarray(lg, np.float32))
    dec = np.stack(outs, axis=1)
    np.testing.assert_allclose(
        dec, np.asarray(logits_full, np.float32), rtol=2e-2, atol=2e-2
    )


def test_full_config_param_counts():
    """The FULL configs' parameter counts land near the advertised sizes."""
    expect = {
        "mixtral-8x7b": (40e9, 52e9),       # 8x7B total ~46.7B
        "deepseek-v3-671b": (600e9, 720e9),
        "llama3-405b": (380e9, 430e9),
        "tinyllama-1.1b": (0.9e9, 1.3e9),
        "phi3-mini-3.8b": (3.3e9, 4.3e9),
        "olmo-1b": (0.9e9, 1.4e9),
        "jamba-1.5-large-398b": (330e9, 430e9),
        "qwen2-vl-7b": (6e9, 9e9),
        "musicgen-large": (2.6e9, 3.9e9),
        # our mLSTM block (block-diag qkv, pf=2, untied embeds) lands ~2B;
        # the published 1.3B uses additional factorizations — [unverified]
        "xlstm-1.3b": (1.0e9, 2.4e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, f"{n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]")


def test_moe_active_params():
    cfg = get_config("mixtral-8x7b")
    active = cfg.active_param_count()
    # mixtral active ~12.9B (2 of 8 experts)
    assert 10e9 < active < 16e9, active / 1e9
