"""The backend-neutral program layer: one ``lower()`` for all four
algorithms, stage invariants, the NumPy reference backend vs analytic
oracles, and pipelined (start_step) replay — all host-side, no devices.

The reference-vs-JAX differential and on-device matmul checks run in a
subprocess with forced host devices (``program_check_script.py``).
"""

import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest

from repro.core import alltoall as a2a
from repro.core import broadcast as bc
from repro.core import hypercube as hc
from repro.core import matmul as mm
from repro.core.schedule import Schedule, hop_round
from repro.core.topology import D3
from repro.dist.mesh import DeviceLayout
from repro.runtime import lowering
from repro.runtime.backends import get_backend
from repro.runtime.backends.reference import NumpyReferenceBackend
from repro.runtime.program import (
    CollectiveProgram,
    LocalContract,
    Match,
    Perm,
    ReduceCombine,
)

ROOT = pathlib.Path(__file__).resolve().parent.parent
REF = NumpyReferenceBackend()


def _programs_for(K, M):
    layout = DeviceLayout(D3(K, M))
    return layout, {
        "alltoall": lowering.lower(a2a.schedule(layout.da_params, layout.topo)),
        "allreduce": lowering.lower(hc.allreduce_schedule(layout.sbh)),
        "broadcast": lowering.lower(bc.depth3_schedule(layout.topo, (0, 1, 0))),
    }


# --------------------------------------------------------- one entry point
@pytest.mark.parametrize("KM", [(4, 2), (2, 4)], ids=str)
def test_lower_dispatches_all_four_families(KM):
    layout, progs = _programs_for(*KM)
    progs["matmul"] = lowering.lower(mm.schedule(mm.MatmulGrid(2, 2)))
    for kind, prog in progs.items():
        assert isinstance(prog, CollectiveProgram)
        assert prog.kind == kind
    assert progs["alltoall"].n == layout.n
    assert all(isinstance(s, Perm) for s in progs["alltoall"].stages)
    assert all(isinstance(s, ReduceCombine) for s in progs["allreduce"].stages)
    assert all(isinstance(s, Match) for s in progs["broadcast"].stages)


def test_lower_rejects_mixed_families():
    topo = D3(2, 2)
    r_vec = next(iter(a2a.iter_round_irs(DeviceLayout(topo).da_params, topo)))
    r_tree = bc.depth3_schedule(topo, (0, 0, 0)).rounds[0]
    with pytest.raises(ValueError, match="mixes round families"):
        lowering.lower(Schedule("mixed", topo, [r_vec, r_tree]))
    with pytest.raises(ValueError, match="empty"):
        lowering.lower(Schedule("empty", topo, []))


def test_named_wrappers_enforce_kind():
    topo = D3(2, 2)
    sched = bc.depth3_schedule(topo, (0, 0, 0))
    with pytest.raises(ValueError, match="expected 'alltoall'"):
        lowering.lower_alltoall(sched)
    assert lowering.lower_broadcast(sched).kind == "broadcast"


# ------------------------------------------------------------ stage checks
def test_stage_validation():
    with pytest.raises(ValueError):
        Perm(((0, 1), (1, 1)))
    with pytest.raises(ValueError):
        Match(3, ((0, 1), (0, 2)))
    with pytest.raises(ValueError):
        Match(3, ((0, 0),))  # identity pairs must be elided
    ReduceCombine(3, ((0, 0), (1, 2)))  # identity = local contribution: ok
    with pytest.raises(ValueError):
        ReduceCombine(3, ((0, 1),), combine="max")
    with pytest.raises(ValueError):
        LocalContract("unknown_fn")
    with pytest.raises(ValueError):
        CollectiveProgram("nonsense", 4, 1, ())


def test_perm_index_arrays_are_cached_across_accesses():
    """Satellite: σ/σ⁻¹ host arrays are built once per stage (cached
    property), not rebuilt inside every jit trace."""
    layout, progs = _programs_for(4, 2)
    op = progs["alltoall"].stages[0]
    assert op.sigma_np is op.sigma_np
    assert op.inverse_np is op.inverse_np
    assert op.sigma_np.dtype == np.int32
    assert sorted(op.sigma) == list(range(layout.n))
    assert all(op.inverse[op.sigma[i]] == i for i in range(layout.n))


# ------------------------------------------------- falsy-root regression
def test_broadcast_root_zero_not_dropped():
    """Regression: ``meta.get("root") or meta.get("source")`` dropped a
    legitimate root of 0. Root router id 0 must lower and execute."""
    topo = D3(4, 2)
    n = topo.num_routers
    # int device id 0 in meta (the falsy case the old `or` chain dropped)
    tree = bc.depth3_tree(topo, (0, 0, 0))
    sched = Schedule(
        "bcast_root0", topo,
        [hop_round([(s, a, b, 0) for s, a, b in tree])],
        meta={"root": 0},
    )
    prog = lowering.lower(sched)
    assert prog.root == 0
    x = np.random.default_rng(0).standard_normal((n, 3))
    out = REF.run_broadcast(x, prog)
    np.testing.assert_array_equal(out, np.broadcast_to(x[0], x.shape))
    # router-tuple root (0, 0, 0) — falsy-looking but must resolve to id 0
    prog2 = lowering.lower(bc.depth3_schedule(topo, (0, 0, 0)))
    assert prog2.root == 0
    # a schedule with neither key still errors
    with pytest.raises(ValueError, match="root"):
        lowering.lower(Schedule("no_root", topo, [hop_round([(0, (0, 0, 0), (0, 0, 1), 0)])]))


# ------------------------------------------- reference backend vs oracles
@pytest.mark.parametrize("KM", [(4, 2), (2, 4)], ids=str)
def test_reference_backend_matches_analytic_results(KM):
    layout, progs = _programs_for(*KM)
    n = layout.n
    rng = np.random.default_rng(1)
    x = rng.standard_normal((n, n, 3))
    np.testing.assert_array_equal(
        REF.run_alltoall(x, progs["alltoall"]), x.transpose(1, 0, 2)
    )
    xr = rng.standard_normal((n, 4))
    np.testing.assert_allclose(
        REF.run_allreduce(xr, progs["allreduce"]),
        np.broadcast_to(xr.sum(0), xr.shape), rtol=1e-12,
    )
    root = progs["broadcast"].root
    np.testing.assert_array_equal(
        REF.run_broadcast(xr, progs["broadcast"]),
        np.broadcast_to(xr[root], xr.shape),
    )


@pytest.mark.parametrize("grid,X", [((2, 2), 1), ((2, 2), 3), ((1, 4), 2), ((3, 2), 1)], ids=str)
def test_reference_matmul_bit_exact(grid, X):
    """§2 via program replay == B @ A, bit-exact on integer-valued floats,
    and identical to the literal per-round data-movement simulation."""
    g = mm.MatmulGrid(*grid)
    prog = lowering.lower(mm.schedule(g))
    rng = np.random.default_rng(2)
    N = g.n * X
    B = rng.integers(-4, 5, (N, N)).astype(np.float64)
    A = rng.integers(-4, 5, (N, N)).astype(np.float64)
    C = REF.run_matmul(B, A, prog)
    np.testing.assert_array_equal(C, B @ A)
    if X == 1:
        np.testing.assert_array_equal(C, mm.simulate_matmul(g, B, A))


def test_matmul_program_structure():
    """Theorem 1 projected onto the program: KM rounds, each K+M-1
    broadcast matchings + K+M accumulation combines + the Z-fix hop, with
    identity combine pairs carrying the local (off-and-on) adds."""
    g = mm.MatmulGrid(2, 2)
    prog = lowering.lower(mm.schedule(g))
    assert prog.kind == "matmul" and prog.grid == (2, 2)
    assert prog.num_rounds == g.K * g.M  # = √n rounds on n = (KM)² routers
    for i in range(prog.num_rounds):
        sts = prog.stages_of_round(i)
        matches = [s for s in sts if isinstance(s, Match)]
        combines = [s for s in sts if isinstance(s, ReduceCombine)]
        locals_ = [s for s in sts if isinstance(s, LocalContract)]
        assert len(matches) == g.K + (g.M - 1) + 1  # bcast g, bcast l, zfix
        assert len(combines) == g.K + g.M
        assert [l.fn for l in locals_] == ["load_b", "mul_a", "promote", "promote", "store_c"]
        assert any(s == d for c in combines for (s, d) in c.pairs)
        store = locals_[-1]
        assert store.mask is not None and len(store.mask) == g.K * g.M


# --------------------------------------------------- pipelined replay
def test_pipelined_broadcast_matches_barrier_replay():
    """§5 pipelined waves: start_step-ordered replay interleaves rounds yet
    is bit-identical to barrier replay (the IR verified it conflict-free
    under ``verify(pipelined=True)``)."""
    topo = D3(4, 2)
    sched = bc.pipelined_m_broadcast_schedule(topo, (0, 0, 1), waves=4)
    prog = lowering.lower(sched)
    assert prog.num_rounds == 4
    # stamps survive lowering: wave w launches at (w//2)*6 + (w%2)
    starts = sorted({s.start_step - s.step for s in prog.stages_of_round(3)})
    assert starts == [sched.rounds[3].meta["start_step"]]
    # the pipelined order genuinely interleaves rounds...
    order = [s.round_index for s in prog.pipelined_stages()]
    assert order != sorted(order)
    # ...and the makespan contracts vs barrier replay
    barrier_span = sum(r.num_steps for r in sched.rounds)
    assert prog.max_start_step + 1 < barrier_span
    rng = np.random.default_rng(3)
    x = rng.standard_normal((prog.num_rounds, topo.num_routers, 3))
    bar = REF.run_broadcast(x, prog)
    pip = REF.run_broadcast(x, prog, pipelined=True)
    np.testing.assert_array_equal(bar, pip)
    np.testing.assert_array_equal(
        bar, np.broadcast_to(x[:, prog.root][:, None], x.shape)
    )


def test_backend_registry():
    assert isinstance(get_backend("reference"), NumpyReferenceBackend)
    with pytest.raises(ValueError):
        get_backend("nccl")  # not built in (yet) — see runtime/backends


# --------------------------------------------------------- device check
@pytest.mark.slow
def test_program_backends_32dev():
    """Differential reference-vs-JAX on all four programs at (K,M) ∈
    {(4,2), (2,4)}, §2 matmul bit-exact vs jnp.einsum on a device mesh,
    pipelined broadcast vs barrier replay, and the emulation rewrite
    (guest D3(2,2) on a D3(2,4) host mesh) — in a subprocess with 32
    forced host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=32"
    env["PYTHONPATH"] = str(ROOT / "src")
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "program_check_script.py")],
        env=env,
        capture_output=True,
        text=True,
        timeout=1200,
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    assert "ALL PROGRAM CHECKS PASSED" in proc.stdout
