"""Documentation cannot rot: every ``>>>`` snippet in README.md and
docs/*.md runs through ``python -m doctest`` — doctest treats a text file
as one big docstring, so the fenced sessions in the markdown are executed
verbatim. Each file runs in a SUBPROCESS with the environment the docs
themselves document (8 forced host devices, ``src`` on the path), so the
quickstart's device-backed example really executes the §3 all-to-all on
an 8-device CPU mesh.

The CI ``docs`` job runs exactly this module; it is also tier-1, so a doc
edit that breaks a snippet fails the ordinary test run too.
"""

import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
DOCS = sorted([ROOT / "README.md", *(ROOT / "docs").glob("*.md")])


def test_docs_are_discovered():
    """The extractor must see the README and both architecture docs — a
    renamed/deleted doc should fail here, not silently skip."""
    names = {d.name for d in DOCS}
    assert {"README.md", "architecture.md", "paper_map.md"} <= names


@pytest.mark.parametrize("doc", DOCS, ids=lambda p: p.name)
def test_doc_snippets_execute(doc):
    text = doc.read_text()
    assert ">>> " in text, (
        f"{doc.name} contains no runnable ``>>>`` snippets — docs must "
        "carry at least one executed example so they can't silently rot"
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = (
        str(ROOT / "src") + os.pathsep + env.get("PYTHONPATH", "")
    )
    # the quickstart documents this exact invocation: devices must exist
    # before jax initializes, hence a fresh subprocess per file
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    proc = subprocess.run(
        [sys.executable, "-m", "doctest", str(doc)],
        capture_output=True, text=True, cwd=ROOT, timeout=600, env=env,
    )
    assert proc.returncode == 0, (
        f"doctest failed for {doc.name}\n"
        f"--- stdout ---\n{proc.stdout}\n--- stderr ---\n{proc.stderr}"
    )
