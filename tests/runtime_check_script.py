"""Runtime executor equivalence checks — run as a SUBPROCESS with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (set before jax import;
see test_runtime_executor.py). Exits 0 on success.

The acceptance bar: the §3 all-to-all Schedule, lowered mechanically from
the IR into a ``CollectiveProgram`` and replayed on an 8-device CPU mesh
(one ppermute per source vector), is BIT-EXACT against jax.lax.all_to_all;
the §4/§5 programs reproduce their analytic results; and the §2 matmul
program (grid (2,1) — no K²M² grid has exactly 8 routers) is bit-exact
against jnp.einsum. Heavier device checks live in program_check_script.py.
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import alltoall as a2a
from repro.core import broadcast as bc
from repro.core import hypercube as hc
from repro.core import matmul as mm
from repro.dist.mesh import dragonfly_layout
from repro.runtime import lowering
from repro.runtime.backends.jax_ppermute import JaxPpermuteBackend
from repro.runtime.compat import shard_map

N = 8
BACKEND = JaxPpermuteBackend()


def get_mesh(n=N):
    return Mesh(np.array(jax.devices()[:n]), ("df",))


def check_alltoall_bit_exact():
    layout = dragonfly_layout(N)
    assert (layout.topo.K, layout.topo.M) == (2, 2), layout
    prog = lowering.lower(a2a.schedule(layout.da_params, layout.topo))
    # n/s rounds of s permutes each: K·M² ppermutes total
    assert prog.num_permutes == N
    assert prog.num_rounds == layout.da_params.total_rounds
    mesh = get_mesh()
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((N, N, 5)), jnp.float32)

    got = np.asarray(BACKEND.run_alltoall(x, prog, mesh=mesh))

    @jax.jit
    def run_ref(x):
        f = shard_map(
            lambda s: jax.lax.all_to_all(s[0], "df", split_axis=0, concat_axis=0)[None],
            mesh=mesh, in_specs=P("df"), out_specs=P("df"),
        )
        return f(x)

    want = np.asarray(run_ref(x))
    np.testing.assert_array_equal(want, np.asarray(x).transpose(1, 0, 2))
    np.testing.assert_array_equal(got, want)  # bit-exact, zero tolerance
    print("alltoall bit-exact OK")


def check_alltoall_hlo_round_structure():
    """The lowered program is visible in the HLO: one collective-permute
    per source vector."""
    layout = dragonfly_layout(N)
    prog = lowering.lower(a2a.schedule(layout.da_params, layout.topo))
    mesh = get_mesh()
    x = jnp.zeros((N, N, 5), jnp.float32)
    f = jax.jit(
        shard_map(
            lambda s: BACKEND.alltoall(s[0], "df", prog)[None],
            mesh=mesh, in_specs=P("df"), out_specs=P("df"),
        )
    )
    txt = f.lower(x).as_text()
    n_perm = txt.count("collective_permute") + txt.count("collective-permute")
    assert n_perm >= prog.num_permutes, (n_perm, prog.num_permutes)
    print(f"round structure OK ({n_perm} collective-permutes >= {prog.num_permutes})")


def check_allreduce():
    layout = dragonfly_layout(N)  # D3(2,2) = SBH(1,1)
    sbh = layout.sbh
    assert sbh is not None and (sbh.k, sbh.m) == (1, 1)
    prog = lowering.lower(hc.allreduce_schedule(sbh))
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((N, 4)), jnp.float32)
    got = np.asarray(BACKEND.run_allreduce(x, prog, mesh=get_mesh()))
    want = np.broadcast_to(np.asarray(x).sum(0), (N, 4))
    np.testing.assert_allclose(got, want, rtol=1e-5)
    print("allreduce OK")


def check_broadcast():
    layout = dragonfly_layout(N)
    root = 5
    prog = lowering.lower(
        bc.depth3_schedule(layout.topo, layout.topo.id_router(root))
    )
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((N, 4)), jnp.float32)
    got = np.asarray(BACKEND.run_broadcast(x, prog, mesh=get_mesh()))
    np.testing.assert_array_equal(got, np.broadcast_to(np.asarray(x)[root], (N, 4)))
    print("broadcast OK")


def check_matmul_program():
    """§2 matmul through the program executor on the devices this
    environment has: grid (2,1) -> 4-router mesh, bit-exact vs einsum."""
    g = mm.MatmulGrid(2, 1)
    prog = lowering.lower(mm.schedule(g))
    rng = np.random.default_rng(3)
    X = 4
    side = g.n * X
    B = rng.integers(-4, 5, (side, side)).astype(np.float32)
    A = rng.integers(-4, 5, (side, side)).astype(np.float32)
    got = BACKEND.run_matmul(B, A, prog, mesh=get_mesh(prog.n))
    want = np.asarray(jnp.einsum("ij,jk->ik", jnp.asarray(B), jnp.asarray(A)))
    np.testing.assert_array_equal(got, want)
    print(f"matmul program OK (grid (2,1), n={prog.n}, bit-exact vs einsum)")


if __name__ == "__main__":
    assert jax.device_count() >= N, jax.device_count()
    check_alltoall_bit_exact()
    check_alltoall_hlo_round_structure()
    check_allreduce()
    check_broadcast()
    check_matmul_program()
    print("ALL RUNTIME CHECKS PASSED")
