"""Theorems 1 & 2 — matrix product on D3(K², M).

Includes the documented erratum fix: accumulation uses the mirror
reduction trees (g-then-l) so the sums converge over the row index pair
(t, v); the literal reverse of path 2.2 would sum over (t', v'). The
claimed structure (4 hops, 2 accumulations, conflict-free, KM rounds) is
preserved and machine-verified here.
"""

import numpy as np
import pytest
try:  # hypothesis is optional — deterministic fallback sampler otherwise
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.matmul import (
    MatmulGrid,
    vector_matmul_phases,
    check_round_conflicts,
    simulate_vector_matmul,
    simulate_matmul,
    rounds_for,
    network_time,
)


GRIDS = [MatmulGrid(2, 2), MatmulGrid(2, 3), MatmulGrid(3, 2)]


@pytest.mark.parametrize("g", GRIDS, ids=lambda g: f"K{g.K}M{g.M}")
def test_four_hops_two_phases(g):
    phases = vector_matmul_phases(g, 0, 0)
    assert len(phases) == 4  # Theorem 1: 4 network hops per round
    # phase fan-out sanity: broadcast covers the whole of row-block set
    assert len(phases[1]) > 0 and len(phases[3]) > 0


@pytest.mark.parametrize("g", GRIDS, ids=lambda g: f"K{g.K}M{g.M}")
def test_round_conflict_free(g):
    for s in range(g.K):
        for u in range(g.M):
            assert check_round_conflicts(g, s, u) == []


@pytest.mark.parametrize("g", GRIDS, ids=lambda g: f"K{g.K}M{g.M}")
def test_vector_matmul_correct(g):
    rng = np.random.default_rng(0)
    n = g.n
    V = rng.standard_normal(n)
    A = rng.standard_normal((n, n))
    out = simulate_vector_matmul(g, V, A, s=0, u=0)
    np.testing.assert_allclose(out, V @ A, rtol=1e-12)


@pytest.mark.parametrize("g", GRIDS[:2], ids=lambda g: f"K{g.K}M{g.M}")
def test_full_matmul_theorem1(g):
    rng = np.random.default_rng(1)
    n = g.n
    B = rng.standard_normal((n, n))
    A = rng.standard_normal((n, n))
    np.testing.assert_allclose(simulate_matmul(g, B, A), B @ A, rtol=1e-11)


def test_out_of_place_root():
    g = MatmulGrid(2, 2)
    rng = np.random.default_rng(2)
    V = rng.standard_normal(g.n)
    A = rng.standard_normal((g.n, g.n))
    # S != s: out-of-place variant lands on a different cabinet block
    out = simulate_vector_matmul(g, V, A, s=0, u=1, S=1)
    np.testing.assert_allclose(out, V @ A, rtol=1e-12)
    for s in range(g.K):
        for u in range(g.M):
            assert check_round_conflicts(g, s, u) == []


@given(st.sampled_from(GRIDS), st.integers(1, 4))
@settings(max_examples=10, deadline=None)
def test_theorem2_round_scaling(g, x):
    n = x * g.n
    assert rounds_for(g, n) == n * n // g.n  # n²/KM
    assert network_time(g, n, t_w=1.0, t_s=0.5) == rounds_for(g, n) * 5.0


def test_paper_table_consistency():
    """§2 table: D3 cost 4 t_w n²/√P with P = (KM)² routers in D3(K²,M)."""
    g = MatmulGrid(3, 2)
    P = g.topo.num_routers  # K² M² = (KM)²... K²M² = 9*4 = 36 = (KM)²
    assert P == g.n * g.n
    n = 4 * g.n
    hops = rounds_for(g, n) * 4
    assert hops == pytest.approx(4 * n * n / np.sqrt(P))
