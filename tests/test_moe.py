"""MoE: sparse capacity-bounded dispatch vs dense-dispatch oracle, router
properties, load-balance loss."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
try:  # hypothesis is optional — deterministic fallback sampler otherwise
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, strategies as st

from repro.configs import get_smoke_config
from repro.models import moe as MOE


def _setup(seed=0, B=2, S=16):
    cfg = get_smoke_config("mixtral-8x7b")
    params = MOE.moe_init(jax.random.key(seed), cfg, jnp.float32)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((B, S, cfg.d_model)) * 0.1, jnp.float32)
    return cfg, params, x


def test_sparse_matches_dense_with_ample_capacity():
    """With capacity >= T·k no tokens drop: sparse == dense exactly."""
    cfg, params, x = _setup()
    y_dense, aux_d = MOE.moe_apply(params, x, cfg)
    y_sparse, aux_s = MOE.moe_apply_sparse(params, x, cfg, capacity_factor=8.0)
    np.testing.assert_allclose(np.asarray(y_sparse), np.asarray(y_dense), rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(float(aux_s), float(aux_d), rtol=1e-6)


def test_sparse_capacity_drops_bounded():
    """With tight capacity outputs differ only by dropped tokens (bounded
    deviation, never NaN)."""
    cfg, params, x = _setup(seed=1)
    y, _ = MOE.moe_apply_sparse(params, x, cfg, capacity_factor=0.5)
    assert np.isfinite(np.asarray(y)).all()


@given(st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_router_topk_properties(seed):
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.standard_normal((8, 6)), jnp.float32)
    w, idx = MOE.router_topk(logits, k=2, norm_probs=True)
    w = np.asarray(w)
    idx = np.asarray(idx)
    assert np.allclose(w.sum(-1), 1.0, atol=1e-5)       # renormalized
    assert (w >= 0).all()
    assert (idx[:, 0] != idx[:, 1]).all()               # distinct experts
    # top-1 really is the argmax
    probs = np.asarray(jax.nn.softmax(logits, -1))
    assert (idx[:, 0] == probs.argmax(-1)).all()


def test_load_balance_loss_uniform_is_one():
    """Perfectly uniform router -> aux loss == E · Σ (1/E)(1/E) · E = 1."""
    T, E, k = 1024, 8, 2
    logits = jnp.zeros((T, E))
    rng = np.random.default_rng(0)
    idx = jnp.asarray(
        np.stack([rng.permutation(E)[:k] for _ in range(T)]), jnp.int32
    )
    loss = MOE.load_balance_loss(logits, idx, E, k)
    # f_e ~ uniform 1/E, p_e = 1/E exactly -> E * E * (1/E * 1/E) = 1
    assert 0.9 < float(loss) < 1.1


def test_shared_expert_always_active():
    cfg = get_smoke_config("deepseek-v3-671b")
    params = MOE.moe_init(jax.random.key(0), cfg, jnp.float32)
    assert "shared" in params
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((2, 8, cfg.d_model)) * 0.1, jnp.float32)
    y, _ = MOE.moe_apply_sparse(params, x, cfg)
    # zeroing the shared expert changes every token's output
    p2 = dict(params, shared=jax.tree.map(jnp.zeros_like, params["shared"]))
    y2, _ = MOE.moe_apply_sparse(p2, x, cfg)
    assert not np.allclose(np.asarray(y), np.asarray(y2))
