"""Property 2 — D3(J,L) ⊂ D3(K,M) dilation-1 emulation + elastic failover."""

import pytest
try:  # hypothesis is optional — deterministic fallback sampler otherwise
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.topology import D3
from repro.core.emulation import embed, largest_embeddable


@given(
    st.integers(2, 5), st.integers(2, 5), st.integers(1, 4), st.integers(2, 4)
)
@settings(max_examples=25, deadline=None)
def test_embed_dilation1(K, M, J, L):
    J, L = min(J, K), min(L, M)
    emb = embed(D3(K, M), J, L)  # verify() runs inside
    # image routers distinct
    imgs = {emb.map_router(r) for r in emb.guest.routers()}
    assert len(imgs) == emb.guest.num_routers


def test_embed_noncontiguous_subsets():
    emb = embed(D3(5, 6), 3, 4, c_set=(0, 2, 4), p_set=(1, 2, 4, 5))
    emb.verify()


def test_ports_map_to_legal_ports():
    host = D3(5, 6)
    emb = embed(host, 3, 4, c_set=(0, 2, 4), p_set=(1, 2, 4, 5))
    for r in emb.guest.routers():
        for delta in range(1, emb.guest.M):
            port = emb.map_local_port(r, delta)
            assert 1 <= port < host.M
        for gamma in range(1, emb.guest.K):
            port = emb.map_global_port(r, gamma)
            assert 0 <= port < host.K


def test_largest_embeddable_failover():
    host = D3(4, 4)
    dead = {(1, 2, 3)}
    J, L, c_set, p_set = largest_embeddable(host, dead)
    assert 1 not in c_set
    assert J == 3
    emb = embed(host, J, L, c_set=c_set, p_set=p_set)
    for r in emb.guest.routers():
        assert emb.map_router(r) not in dead


def test_failover_multiple_failures():
    host = D3(4, 4)
    dead = {(0, 0, 0), (2, 3, 1)}
    J, L, c_set, p_set = largest_embeddable(host, dead)
    emb = embed(host, J, L, c_set=c_set, p_set=p_set)
    for r in emb.guest.routers():
        assert emb.map_router(r) not in dead
