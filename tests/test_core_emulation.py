"""Property 2 — D3(J,L) ⊂ D3(K,M) dilation-1 emulation + elastic failover."""

import numpy as np
import pytest
try:  # hypothesis is optional — deterministic fallback sampler otherwise
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.topology import D3
from repro.core.emulation import embed, largest_embeddable


@given(
    st.integers(2, 5), st.integers(2, 5), st.integers(1, 4), st.integers(2, 4)
)
@settings(max_examples=25, deadline=None)
def test_embed_dilation1(K, M, J, L):
    J, L = min(J, K), min(L, M)
    emb = embed(D3(K, M), J, L)  # verify() runs inside
    # image routers distinct
    imgs = {emb.map_router(r) for r in emb.guest.routers()}
    assert len(imgs) == emb.guest.num_routers


def test_embed_noncontiguous_subsets():
    emb = embed(D3(5, 6), 3, 4, c_set=(0, 2, 4), p_set=(1, 2, 4, 5))
    emb.verify()


def test_ports_map_to_legal_ports():
    host = D3(5, 6)
    emb = embed(host, 3, 4, c_set=(0, 2, 4), p_set=(1, 2, 4, 5))
    for r in emb.guest.routers():
        for delta in range(1, emb.guest.M):
            port = emb.map_local_port(r, delta)
            assert 1 <= port < host.M
        for gamma in range(1, emb.guest.K):
            port = emb.map_global_port(r, gamma)
            assert 0 <= port < host.K


def test_largest_embeddable_failover():
    host = D3(4, 4)
    dead = {(1, 2, 3)}
    J, L, c_set, p_set = largest_embeddable(host, dead)
    assert 1 not in c_set
    assert J == 3
    emb = embed(host, J, L, c_set=c_set, p_set=p_set)
    for r in emb.guest.routers():
        assert emb.map_router(r) not in dead


def test_failover_multiple_failures():
    host = D3(4, 4)
    dead = {(0, 0, 0), (2, 3, 1)}
    J, L, c_set, p_set = largest_embeddable(host, dead)
    emb = embed(host, J, L, c_set=c_set, p_set=p_set)
    for r in emb.guest.routers():
        assert emb.map_router(r) not in dead


# ------------------------------------------------ the two drop regimes
def test_largest_embeddable_cabinet_drop_regime():
    """Clustered failures: dropping the one poisoned cabinet beats
    dropping the poisoned positions (3·16 = 48 > 4·4 = 16)."""
    host = D3(4, 4)
    dead = {(1, 0, 1), (1, 2, 3)}
    J, L, c_set, p_set = largest_embeddable(host, dead)
    assert (J, L) == (3, 4)
    assert c_set == (0, 2, 3) and p_set == (0, 1, 2, 3)


def test_largest_embeddable_position_drop_regime():
    """Regression for the always-empty ``bad_p`` bug: failures striped at
    one (d, p) slot across EVERY cabinet used to leave no survivors at
    all; the position-drop regime keeps D3(K, M-1). Here it also beats
    cabinet-drop when only most cabinets are hit (4·9 = 36 > 1·16)."""
    host = D3(4, 4)
    striped = {(c, 0, 0) for c in range(4)}
    J, L, c_set, p_set = largest_embeddable(host, striped)
    assert (J, L) == (4, 3)
    assert c_set == (0, 1, 2, 3) and p_set == (1, 2, 3)
    emb = embed(host, J, L, c_set=c_set, p_set=p_set)
    assert not {emb.map_router(r) for r in emb.guest.routers()} & striped

    partial_stripe = {(0, 0, 0), (1, 0, 0), (2, 0, 0)}
    J, L, c_set, p_set = largest_embeddable(host, partial_stripe)
    assert (J, L) == (4, 3)  # 36 chips > cabinet-drop's 1·16


def test_largest_embeddable_regime_tie_prefers_cabinets():
    # D3(2,2), one dead chip: cabinet-drop 1·4 == position-drop 2·1... no:
    # (0,0,1) poisons positions {0,1} entirely -> only cabinet-drop lives.
    J, L, c_set, p_set = largest_embeddable(D3(2, 2), {(0, 0, 1)})
    assert (J, L) == (1, 2) and c_set == (1,)
    # on D3(1,2) the pure regimes find nothing, but the mixed search
    # still recovers the healthy singleton (0,1,1) as a D3(1,1) guest
    assert largest_embeddable(D3(1, 2), {(0, 0, 1)}) == (1, 1, (0,), (1,))
    with pytest.raises(RuntimeError, match="survives"):
        largest_embeddable(D3(1, 1), {(0, 0, 0)})  # nothing left at all


def test_largest_embeddable_mixed_regime_dominates():
    """Failures striped across SOME cabinets at SOME positions: one
    poisoned position is worth dropping (it clears cabinets 1-3), the
    other is worth keeping a cabinet-drop for — the mixed survivor
    D3(3,3) = 27 strictly beats cabinet-drop (nothing: every cabinet is
    hit) and position-drop (4·4 = 16), and is dilation-1 verified."""
    host = D3(4, 4)
    dead = {(0, 1, 1), (1, 0, 0), (2, 0, 0), (3, 0, 0)}
    J, L, c_set, p_set = largest_embeddable(host, dead)
    assert (J, L) == (3, 3)
    assert c_set == (1, 2, 3) and p_set == (1, 2, 3)
    emb = embed(host, J, L, c_set=c_set, p_set=p_set)
    emb.verify()
    assert not {emb.map_router(r) for r in emb.guest.routers()} & dead


def test_largest_embeddable_mixed_when_both_pure_regimes_die():
    """Diagonal kills poison every cabinet AND every position — both pure
    regimes return nothing, but dropping one position un-poisons the
    cabinets whose dead router sat there."""
    host = D3(2, 2)
    dead = {(0, 0, 1), (1, 0, 0)}
    J, L, c_set, p_set = largest_embeddable(host, dead)
    assert (J, L) == (2, 1)
    assert c_set == (0, 1) and p_set == (1,)
    embed(host, J, L, c_set=c_set, p_set=p_set).verify()


def test_largest_embeddable_mixed_never_beats_equal_pure():
    """Tie-break order is cabinet > position > mixed: the mixed regime is
    returned only when it STRICTLY dominates both pure regimes, so the
    pure-regime answers of the existing tests are unchanged."""
    host = D3(4, 4)
    # one poisoned cabinet, one poisoned position: cabinet-drop keeps 48
    dead = {(1, 0, 1), (1, 2, 3)}
    assert largest_embeddable(host, dead)[:2] == (3, 4)
    # full stripe at (0,0): dropping the single poisoned position IS the
    # pure position regime — the mixed search enumerates only PROPER
    # subsets of the poisoned positions, so position-drop answers alone
    striped = {(c, 0, 0) for c in range(4)}
    assert largest_embeddable(host, striped)[:2] == (4, 3)


def test_fallback_shapes_cover_mixed_ladder():
    """Every shape the mixed search can produce is pre-lowered: the
    fallback ladder is the full (j, l) grid, largest survivors first."""
    from repro.dist.mesh import DeviceLayout
    from repro.train.fault_tolerance import ClusterState

    cs = ClusterState(DeviceLayout(D3(3, 3)))
    shapes = cs.fallback_shapes()
    assert set(shapes) == {(j, l) for j in (1, 2, 3) for l in (1, 2, 3)}
    sizes = [j * l * l for j, l in shapes]
    assert sizes == sorted(sizes, reverse=True)
    assert shapes[0] == (3, 3)


def test_largest_embeddable_dead_position_pair_excluded():
    """Every dead router must be excluded from the survivor image under
    BOTH regimes (its cabinet leaves C, or its d AND p leave P)."""
    host = D3(3, 5)
    dead = {(0, 1, 2), (2, 4, 4)}
    J, L, c_set, p_set = largest_embeddable(host, dead)
    emb = embed(host, J, L, c_set=c_set, p_set=p_set)
    assert not {emb.map_router(r) for r in emb.guest.routers()} & dead


# ------------------------------------------------ vectorized device maps
def test_device_map_matches_map_router():
    host = D3(5, 6)
    emb = embed(host, 3, 4, c_set=(0, 2, 4), p_set=(1, 2, 4, 5))
    dm = emb.device_map
    assert dm.dtype == np.int32 and len(dm) == emb.guest.num_routers
    for r in emb.guest.routers():
        assert dm[emb.guest.router_id(r)] == host.router_id(emb.map_router(r))
    # inverse: host -> guest, -1 off the image
    inv = emb.host_to_guest
    assert (inv[dm] == np.arange(len(dm))).all()
    assert (inv == -1).sum() == host.num_routers - emb.guest.num_routers


def test_device_map_is_cached_and_readonly():
    emb = embed(D3(4, 4), 2, 2)
    assert emb.device_map is emb.device_map
    assert emb.host_to_guest is emb.host_to_guest
    with pytest.raises(ValueError):
        emb.device_map[0] = 7
    # the cache must not break hashing/eq of the frozen dataclass
    assert emb == embed(D3(4, 4), 2, 2) and hash(emb) == hash(embed(D3(4, 4), 2, 2))


def test_embedding_rejects_out_of_range_subsets():
    with pytest.raises(ValueError, match="out of range"):
        embed(D3(4, 4), 2, 2, c_set=(0, 5))
    with pytest.raises(ValueError, match="out of range"):
        embed(D3(4, 4), 2, 2, p_set=(0, 4))
