"""Unified Schedule IR: golden equivalence with the pre-IR emitters, the
single verify() entry point, round-count formulas, and price() cross-checks
against the analytic cost tables."""

import math

import pytest

from repro.core.topology import D3
from repro.core.routing import vector_dest
from repro.core import alltoall as a2a
from repro.core import broadcast as bc
from repro.core import costmodel as cm
from repro.core import hypercube as hc
from repro.core import matmul as mm
from repro.core.schedule import Schedule, Hop, vector_round
from repro.core.simulator import verify


# The acceptance grid: all four algorithms conflict-free on these fabrics.
TOPOS = [(4, 4), (4, 8), (9, 3)]


def _da_params(K, M):
    return a2a.DAParams(K, M, math.gcd(K, M))


# ---------------------------------------------------------------- golden
@pytest.mark.parametrize("KM", [(4, 4), (4, 8)], ids=str)
def test_golden_alltoall_rounds_match_legacy(KM):
    """Each emitted IR round carries exactly the legacy rounds() vectors,
    and its hops are the l-g-l expansion check_vector_round replayed."""
    p = _da_params(*KM)
    topo = D3(p.K, p.M)
    routers = list(topo.routers())
    legacy = list(a2a.rounds(p))
    irs = a2a.iter_round_irs(p, topo)
    for (key, vecs), rnd in zip(legacy, irs):
        assert rnd.meta["key"] == key
        assert rnd.meta["vectors"] == tuple(vecs)
        expected = []
        for v in vecs:
            gamma, pi, delta = v
            for r in routers:
                tag = (v, topo.router_id(r))
                r1 = topo.local_hop(r, delta)
                r2 = topo.global_hop(r1, gamma)
                r3 = topo.local_hop(r2, pi)
                if r1 != r:
                    expected.append(Hop(0, r, r1, tag))
                if r2 != r1:
                    expected.append(Hop(1, r1, r2, tag))
                if r3 != r2:
                    expected.append(Hop(2, r2, r3, tag))
        assert rnd.hops == tuple(expected)


@pytest.mark.parametrize("KM", [(4, 4), (4, 8)], ids=str)
def test_golden_broadcast_trees_match_legacy(KM):
    topo = D3(*KM)
    root = (1, 0, 1)
    sch = bc.depth3_schedule(topo, root)
    assert [(h.step, h.src, h.dst) for h in sch.rounds[0].hops] == bc.depth3_tree(topo, root)
    src = (0, 1, 0)
    schm = bc.m_broadcast_schedule(topo, src)
    assert [(h.step, h.src, h.dst) for h in schm.rounds[0].hops] == bc.m_broadcast(topo, src)
    # payloads are the tree colors 0..M-1
    assert schm.rounds[0].payloads() == set(range(topo.M))


@pytest.mark.parametrize("KM", [(4, 4), (4, 8)], ids=str)
def test_golden_matmul_round_matches_phases(KM):
    K, M = KM
    g = mm.MatmulGrid(K // 2, M)  # D3((K/2)², M)... grid K'=K/2 -> topo D3(K'²,M)
    rnd = mm.round_ir(g, 0, 1)
    phases = mm.vector_matmul_phases(g, 0, 1)
    expected = [
        (phase, a, b) for phase, hops in enumerate(phases) for (a, b) in hops
    ]
    assert [(h.step, h.src, h.dst) for h in rnd.hops] == expected
    assert rnd.meta["startups"] == 2


@pytest.mark.parametrize("km", [(2, 2), (2, 3)], ids=str)
def test_golden_hypercube_rounds_match_emulation_paths(km):
    sbh = hc.SBH(*km)
    sch = hc.allreduce_schedule(sbh)
    assert sch.num_rounds == sbh.dims
    for dim, rnd in enumerate(sch.rounds):
        expected = []
        pairs = []
        for x in range(sbh.num_nodes):
            path = sbh.emulation_path(sbh.node(x), dim)
            pairs.append((x, sbh.index(path[-1])))
            for i in range(len(path) - 1):
                if path[i] != path[i + 1]:
                    expected.append(Hop(i, path[i], path[i + 1], x))
        assert rnd.hops == tuple(expected)
        assert rnd.meta["pairs"] == tuple(pairs)


# ------------------------------------------------------- verify() property
@pytest.mark.parametrize("KM", TOPOS, ids=str)
def test_verify_alltoall_zero_conflicts_and_round_count(KM):
    """Theorem 3 on the IR: n/s rounds (n = K·M² unit items), zero
    conflicts, every vector's chunk delivered."""
    p = _da_params(*KM)
    topo = D3(p.K, p.M)
    n_rounds = 0
    for rnd in a2a.iter_round_irs(p, topo):
        rep = verify(topo, Schedule("a2a_round", topo, [rnd]))
        assert rep.ok, rep.conflicts[:2]
        n_rounds += 1
    assert n_rounds == p.total_rounds == p.K * p.M * p.M // p.s


@pytest.mark.parametrize("KM", TOPOS, ids=str)
def test_verify_broadcast_zero_conflicts_and_coverage(KM):
    topo = D3(*KM)
    src = (0, 0, 1)
    rep = verify(topo, bc.m_broadcast_schedule(topo, src))
    assert rep.ok
    assert rep.total_steps == 5  # delegation + depth-4 tree
    for p in range(topo.M):  # every color reaches the whole machine
        assert rep.covered(p) | {src} == set(topo.routers())
    # pipelined pairs: 3X/M makespan, still conflict-free
    waves = 4
    pipe = bc.pipelined_m_broadcast_schedule(topo, src, waves)
    prep = verify(topo, pipe, pipelined=True)
    assert prep.ok
    X = waves * topo.M
    assert prep.total_steps == 3 * X // topo.M  # 2 waves of M per 6 hops


@pytest.mark.parametrize("KM", TOPOS, ids=str)
def test_verify_matmul_zero_conflicts_and_sqrt_rounds(KM):
    K, M = KM
    gk = {4: 2, 9: 3}[K]
    g = mm.MatmulGrid(gk, M)
    assert (g.topo.K, g.topo.M) == (K, M)
    sch = mm.schedule(g)
    rep = verify(g.topo, sch)
    assert rep.ok, rep.conflicts[:2]
    # Theorem 1: KM = √(K²M²) rounds of 4 hops on the D3(K², M) machine
    assert rep.num_rounds == g.n == math.isqrt(g.topo.num_routers)
    assert rep.total_steps == 4 * rep.num_rounds


@pytest.mark.parametrize("km", [(2, 2), (2, 3)], ids=str)
def test_verify_hypercube_zero_conflicts_factor2(km):
    """2·log₂ n steps: the emulation's barrier makespan is exactly twice
    the native (k+2m)-cube ascend."""
    sbh = hc.SBH(*km)
    rep = verify(sbh.topo, hc.allreduce_schedule(sbh))
    assert rep.ok, rep.conflicts[:2]
    assert rep.num_rounds == sbh.dims == int(math.log2(sbh.num_nodes))
    assert rep.total_steps == 2 * sbh.dims


# ----------------------------------------------------------- price() x-check
def test_price_matches_analytic_tables():
    p = _da_params(4, 4)
    sch = a2a.schedule(p)
    assert cm.price(sch, t_w=1.0, t_s=0.0) == cm.alltoall_schedule3(4, 4, p.s)
    g = mm.MatmulGrid(2, 4)
    msch = mm.schedule(g)
    assert cm.price(msch, t_w=1.0, t_s=0.5) == mm.network_time(g, g.n, 1.0, 0.5)
    topo = D3(4, 4)
    pipe = bc.pipelined_m_broadcast_schedule(topo, (0, 0, 0), waves=8)
    X = pipe.meta["X"]
    assert cm.price_pipelined(pipe, t_w=1.0, t_s=0.0) == cm.broadcast_m_tree(X, topo.M)


def test_verify_reports_conflicts_with_location():
    """Two packets forced onto one directed link — the report localizes it."""
    topo = D3(2, 2)
    rnd = vector_round(topo, [((0, 0, 0), (1, 1, 1)), ((0, 0, 0), (1, 1, 1))])
    rep = verify(topo, Schedule("bad", topo, [rnd]))
    assert not rep.ok
    c = rep.conflicts[0]
    assert len(c.packets) == 2 and topo.is_link(*c.link)
