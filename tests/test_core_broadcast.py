"""§5 — Broadcast Swapped Dragonfly: depth-3/-4 trees, edge-disjointness,
M-broadcast, pipelining, synchronized-header automaton."""

import pytest

from repro.core.topology import D3
from repro.core.routing import SyncHeader, STAR, header_trace
from repro.core import broadcast as bc


TOPOS = [D3(2, 3), D3(3, 3), D3(2, 4)]


@pytest.mark.parametrize("t", TOPOS, ids=lambda t: f"K{t.K}M{t.M}")
def test_depth3_tree_spans(t):
    root = (0, 1, 2 % t.M)
    hops = bc.depth3_tree(t, root)
    assert bc.tree_covers(t, root, hops)
    assert max(s for s, _, _ in hops) == 2  # 3 levels: steps 0,1,2
    for _, a, b in hops:
        assert t.is_link(a, b)


@pytest.mark.parametrize("t", TOPOS, ids=lambda t: f"K{t.K}M{t.M}")
def test_depth4_tree_spans(t):
    for p in range(t.M):
        root = (0, 0, p)
        hops = bc.depth4_tree(t, root)
        assert bc.tree_covers(t, root, hops)
        assert max(s for s, _, _ in hops) == 3
        for _, a, b in hops:
            assert t.is_link(a, b)


@pytest.mark.parametrize("t", TOPOS, ids=lambda t: f"K{t.K}M{t.M}")
def test_m_trees_edge_disjoint_levelwise(t):
    """Edge-disjointness of the M depth-4 trees, verified precisely.

    Level-wise (same-depth) the trees are fully directed-edge-disjoint —
    which is what makes each synchronized step of the M-broadcast
    conflict-free. Across levels there is exactly one overlap family
    (documented erratum to the paper's flat claim): tree_{p=d}'s level-3
    local broadcast sources (x, p', d) coincide with tree_{p'}'s level-1
    sources, so those local edges are shared ACROSS DIFFERENT STEPS. The
    paper's own chaining diagram exhibits this same conflict when
    pipelining at offset 1 (hence pair-chaining); operationally the
    5-step schedule never collides (test_m_broadcast below).
    """
    d = 0
    trees = [bc.depth4_tree(t, (0, d, p)) for p in range(t.M)]
    # (1) same-level edges are disjoint across trees
    for level in range(4):
        seen = {}
        for p, tree in enumerate(trees):
            for s, a, b in tree:
                if s != level:
                    continue
                assert (a, b) not in seen, (level, p, seen[(a, b)], a, b)
                seen[(a, b)] = p
    # (2) cross-level overlaps exist only between tree_d level 3 and
    #     tree_{p'} level 1
    edges = {
        (p, s, a, b) for p, tree in enumerate(trees) for s, a, b in tree
    }
    by_edge = {}
    overlaps = []
    for p, s, a, b in edges:
        if (a, b) in by_edge:
            overlaps.append((by_edge[(a, b)], (p, s)))
        else:
            by_edge[(a, b)] = (p, s)
    for (p1, s1), (p2, s2) in overlaps:
        levels = {s1, s2}
        colors = {p1, p2}
        # two static-overlap families, both involving tree_d and both at
        # DIFFERENT levels (hence conflict-free in the synchronized
        # schedule): (a) tree_p level-0 global-port-0 hop == tree_d
        # level-2 Z edge; (b) tree_d level-3 local == tree_p level-1 local.
        assert levels in ({1, 3}, {0, 2}), (p1, s1, p2, s2)
        assert d in colors, (p1, s1, p2, s2)
    # (3) trees with color p != d are pairwise fully edge-disjoint
    non_d = [tree for p, tree in enumerate(trees) if p != d]
    assert bc.directed_edge_disjoint(non_d)


@pytest.mark.parametrize("t", TOPOS, ids=lambda t: f"K{t.K}M{t.M}")
def test_m_broadcast_conflict_free_5_steps(t):
    source = (0, 0, 0)
    conflicts = bc.check_m_broadcast(t, source)
    assert conflicts == []
    hops = bc.m_broadcast(t, source)
    assert max(s for s, _, _ in hops) == 4  # 5 router hops: steps 0..4


@pytest.mark.parametrize("t", TOPOS, ids=lambda t: f"K{t.K}M{t.M}")
def test_depth3_pipeline_cost_X(t):
    root = (0, 1, 0)  # p != d required for conflict-free chaining
    rep = bc.pipeline_depth3(t, root, X=12)
    assert rep.conflicts == 0
    assert rep.total_steps == 12 + 2  # X hops + drain
    assert rep.steps_per_broadcast < 1.5


@pytest.mark.parametrize("t", TOPOS, ids=lambda t: f"K{t.K}M{t.M}")
def test_depth4_pair_pipeline_3X_over_M(t):
    rep = bc.pipeline_depth4_pairs(t, (0, 0, 0), waves=8)
    assert rep.conflicts == 0
    # 2 waves (2M broadcasts) per 6 steps -> 3X/M (+ drain)
    assert rep.total_steps <= 3 * rep.num_broadcasts / t.M + 6
    # and the M-tree schedule beats the depth-3 pipeline (X hops) by M/3:
    assert rep.steps_per_broadcast <= 3.0 / t.M + 0.25


def test_header_automaton_traces():
    """§5 evolutions: [3;*,*,*] -> L,G,L and [4;*,*,*] -> G,L,Z(G),L."""
    t3 = header_trace(SyncHeader(3, STAR, STAR, STAR))
    assert [k for k, _ in t3] == ["local", "global", "local"]
    t4 = header_trace(SyncHeader(4, STAR, STAR, STAR))
    assert [k for k, _ in t4] == ["global", "local", "global", "local"]
    # [2;0,0,*] compels point-to-point over global port 0:
    assert t4[2] == ("global", 0)
    # [1;0,0,*] compels a local broadcast:
    assert t4[3] == ("local", STAR)


@pytest.mark.parametrize("t", TOPOS, ids=lambda t: f"K{t.K}M{t.M}")
def test_header_driven_flood_matches_trees(t):
    """Position-independent router program: flooding with [3;*] / [4;*]
    covers the machine in exactly 3 / 4 steps."""
    root = (0, 1, 1 % t.M)
    cov3, steps3 = bc.run_header_broadcast(t, root, SyncHeader(3, STAR, STAR, STAR))
    assert len(cov3) == t.num_routers and steps3 == 3
    cov4, steps4 = bc.run_header_broadcast(t, root, SyncHeader(4, STAR, STAR, STAR))
    assert len(cov4) == t.num_routers and steps4 == 4


def test_point_to_point_header():
    """A [3; γ, π, δ] header follows the l-g-l source-vector path."""
    t = D3(3, 4)
    h = SyncHeader(3, 2, 1, 3)
    trace = header_trace(h)
    assert trace == [("local", 3), ("global", 2), ("local", 1)]
