"""moe_collectives="auto" end-to-end — run as a SUBPROCESS with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (set before jax import,
see test_autotune.py). The acceptance check for the autotuner wiring:
whatever strategy the tuner picks for the MoE EP dispatch/combine site
must be BIT-EXACT against both fixed paths. Exits 0 on success."""

import os
import tempfile

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
# keep the tuner's cache out of the repo tree for this run
os.environ["REPRO_AUTOTUNE_CACHE"] = os.path.join(
    tempfile.mkdtemp(prefix="autotune_"), "cache.json"
)

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.configs import get_smoke_config
from repro.dist import sharding as SH
from repro.models import moe as MOE


def main():
    assert jax.device_count() >= 8, jax.device_count()
    cfg = get_smoke_config("mixtral-8x7b")
    E = cfg.moe.num_experts
    n_model, n_data = 4, 2
    assert E % n_model == 0, (E, n_model)
    mesh = Mesh(
        np.array(jax.devices()[: n_data * n_model]).reshape(n_data, n_model),
        ("data", "model"),
    )
    base = SH.ShardRules(model_axis_size=n_model, data_axis_size=n_data)
    params = MOE.moe_init(jax.random.key(0), cfg, jnp.float32)
    rng = np.random.default_rng(0)
    B, S = 2, 16  # T=32 tokens, 8 shards -> T_loc=4
    x = jnp.asarray(rng.standard_normal((B, S, cfg.d_model)) * 0.1, jnp.float32)

    outs = {}
    for mode in ("xla", "dragonfly", "dragonfly_overlap",
                 "dragonfly_overlap_fused", "auto"):
        rules = dataclasses.replace(base, moe_collectives=mode)
        SH.set_active(rules, mesh)
        y, aux = MOE.moe_apply_ep(params, x, cfg)
        outs[mode] = (np.asarray(y), float(aux))
        print(f"{mode}: aux={outs[mode][1]:.6f}")

    # the tuner may pick ANY of the four strategies — all must agree, so
    # "auto" is bit-exact against every fixed path (zero tolerance)
    for mode in ("xla", "dragonfly", "dragonfly_overlap",
                 "dragonfly_overlap_fused"):
        np.testing.assert_array_equal(outs["auto"][0], outs[mode][0])
        assert outs["auto"][1] == outs[mode][1], (mode, outs)

    from repro.runtime.autotune import get_autotuner

    rows = get_autotuner().report()
    assert rows, "auto path never consulted the tuner"
    print("auto decision:", rows[0]["strategy"], f"({rows[0]['source']})")
    print("MOE AUTO CHECKS PASSED")


if __name__ == "__main__":
    main()
