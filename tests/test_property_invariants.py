"""Hypothesis property tests on system-wide invariants: schedule algebra,
routing bijectivity, collective payload conservation, checkpoint codecs."""

import numpy as np
import jax
import jax.numpy as jnp
try:  # hypothesis is optional — deterministic fallback sampler otherwise
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.topology import D3
from repro.core.routing import vector_for, vector_dest
from repro.core.alltoall import DAParams, rounds, round_vectors
from repro.core.hypercube import SBH
from repro.core.emulation import embed
from repro.train import checkpoint as ckpt
from repro.train import compression as C


# --------------------------------------------------------- routing algebra
@given(st.integers(2, 6), st.integers(2, 6), st.data())
@settings(max_examples=40, deadline=None)
def test_vector_composition_is_translation(K, M, data):
    """The same vector from two sources produces destinations whose
    coordinate differences mirror the sources' (after the d/p swap) —
    i.e. vectors act equivariantly (underlies Property 1)."""
    t = D3(K, M)
    vec = (
        data.draw(st.integers(0, K - 1)),
        data.draw(st.integers(0, M - 1)),
        data.draw(st.integers(0, M - 1)),
    )
    s1 = t.id_router(data.draw(st.integers(0, t.num_routers - 1)))
    s2 = t.id_router(data.draw(st.integers(0, t.num_routers - 1)))
    d1 = vector_dest(t, s1, vec)
    d2 = vector_dest(t, s2, vec)
    # difference of destinations == swapped difference of sources
    assert (d1[0] - d2[0]) % K == (s1[0] - s2[0]) % K
    assert (d1[1] - d2[1]) % M == (s1[2] - s2[2]) % M
    assert (d1[2] - d2[2]) % M == (s1[1] - s2[1]) % M


@given(st.sampled_from([(2, 4, 2), (4, 6, 2), (4, 8, 4), (6, 9, 3)]), st.data())
@settings(max_examples=30, deadline=None)
def test_da_round_disagreement(parms, data):
    """Any round of the doubly-parallel schedule has pairwise-distinct
    γ, π AND δ (the disagreeable-array property that Property 3 needs)."""
    K, M, s = parms
    p = DAParams(K, M, s)
    mu = data.draw(st.integers(0, s - 1))
    nu = data.draw(st.integers(0, s - 1))
    a = data.draw(st.integers(0, p.m - 1))
    b = data.draw(st.integers(0, p.m - 1))
    c = data.draw(st.integers(0, p.k - 1))
    vecs = round_vectors(p, mu, nu, a, b, c)
    gs, ps, ds = zip(*vecs)
    assert len(set(gs)) == s and len(set(ps)) == s and len(set(ds)) == s


@given(st.sampled_from([(1, 1), (2, 1), (1, 2), (2, 2)]), st.data())
@settings(max_examples=30, deadline=None)
def test_sbh_emulation_is_involution(km, data):
    """Flipping the same cube dimension twice returns to the start."""
    s = SBH(*km)
    x = data.draw(st.integers(0, s.num_nodes - 1))
    dim = data.draw(st.integers(0, s.dims - 1))
    once = s.emulation_path(s.node(x), dim)[-1]
    back = s.emulation_path(once, dim)[-1]
    assert back == s.node(x)


@given(st.integers(2, 5), st.integers(2, 5), st.integers(1, 4), st.integers(1, 4), st.data())
@settings(max_examples=25, deadline=None)
def test_embedding_preserves_vector_semantics(K, M, J, L, data):
    """Routing a vector in the guest and mapping == mapping then routing
    the translated ports in the host (dilation-1 emulation exactness)."""
    J, L = min(J, K), min(L, M)
    emb = embed(D3(K, M), J, L)
    g = emb.guest
    src = g.id_router(data.draw(st.integers(0, g.num_routers - 1)))
    dst = g.id_router(data.draw(st.integers(0, g.num_routers - 1)))
    vec = vector_for(g, src, dst)
    assert vector_dest(g, src, vec) == dst
    assert emb.map_router(dst) == emb.map_router(vector_dest(g, src, vec))


# ------------------------------------------------------ codecs round-trip
@given(st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_quantize_bounded_error(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(300) * rng.uniform(0.01, 100), jnp.float32)
    q, s = C.quantize(x)
    back = C.dequantize(q, s, x.shape, x.size)
    blockmax = np.abs(np.asarray(x)).max()
    assert float(jnp.abs(back - x).max()) <= blockmax / 127 + 1e-6


@given(st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_checkpoint_flatten_roundtrip(seed):
    rng = np.random.default_rng(seed)
    tree = {
        "a": {"b": rng.standard_normal(3), "c": (rng.standard_normal(2), rng.standard_normal(1))},
        "d": rng.integers(0, 10, 4),
    }
    flat = ckpt._flatten(tree)
    back = ckpt._unflatten(flat)
    assert set(flat) == set(ckpt._flatten(back))
    np.testing.assert_array_equal(back["a"]["c"][1], tree["a"]["c"][1])
    np.testing.assert_array_equal(back["d"], tree["d"])


# ----------------------------------------------- dry-run artifact sanity
def test_dryrun_artifacts_consistent():
    """If the sweep has run, every ok cell's roofline terms are finite and
    positive, and no supported cell failed."""
    import glob, json, pathlib

    files = glob.glob(str(pathlib.Path(__file__).parents[1] / "experiments" / "dryrun" / "*.json"))
    if not files:
        import pytest
        pytest.skip("dry-run sweep not executed in this checkout")
    bad = []
    for f in files:
        d = json.load(open(f))
        if d["status"] == "FAILED":
            bad.append(f)
        if d["status"] == "ok" and "roofline" in d:
            r = d["roofline"]
            assert r["compute_s"] >= 0 and np.isfinite(r["compute_s"]), f
            assert r["step_time_bound_s"] > 0, f
    assert not bad, bad
