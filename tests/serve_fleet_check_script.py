"""Multi-tenant serving smoke — run as a SUBPROCESS with
XLA_FLAGS=--xla_force_host_platform_device_count=16 (set before jax
import; the pytest wrapper in test_serve_fleet.py and the CI job both
do this). The device-backed acceptance check for the fleet: two guests on
a forced 16-device D3(4,2) mesh, admit -> serve -> evict -> re-admit, every
tenant's tokens bit-exact against a solo fleet through the SAME jax
replay path. Exits 0 on success."""

import os
import tempfile

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=16")
# keep the tuner's cache out of the repo tree for this run
os.environ["REPRO_AUTOTUNE_CACHE"] = os.path.join(
    tempfile.mkdtemp(prefix="autotune_"), "cache.json"
)

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.serve.fleet import TenantFleet

HOST = (4, 2)
PROMPTS = [[5, 6, 7], [9, 10], [3, 4]]


def solo_tokens(cfg, params, prompt, n_new):
    fleet = TenantFleet(HOST, backend="jax", max_seq=32)
    tid = fleet.admit_model(cfg, params, guest=(1, 2), slots=2)
    req = fleet.submit(tid, prompt, n_new)
    fleet.run_to_completion()
    assert req.done
    return req.out


def main():
    assert jax.device_count() >= 16, jax.device_count()
    cfg = get_smoke_config("mixtral-8x7b")
    params = [M.init_params(jax.random.key(i), cfg) for i in range(3)]

    # admit two tenants, serve through the combined program
    fleet = TenantFleet(HOST, backend="jax", max_seq=32)
    t0 = fleet.admit_model(cfg, params[0], guest=(1, 2), slots=2)
    t1 = fleet.admit_model(cfg, params[1], guest=(1, 2), slots=2)
    r0 = fleet.submit(t0, PROMPTS[0], 6)
    r1 = fleet.submit(t1, PROMPTS[1], 4)
    for _ in range(2):
        fleet.step()

    # evict tenant 1 mid-traffic, re-admit a third onto the freed cabinets
    plan = fleet.evict(t1)
    assert plan.surviving == (0,), plan
    t2 = fleet.admit_model(cfg, params[2], guest=(1, 2), slots=2)
    r2 = fleet.submit(t2, PROMPTS[2], 4)
    fleet.run_to_completion()
    assert r0.done and r2.done and not r1.done

    # bit-exact per tenant vs served alone (same jax replay path)
    assert r0.out == solo_tokens(cfg, params[0], PROMPTS[0], 6), r0.out
    assert r2.out == solo_tokens(cfg, params[2], PROMPTS[2], 4), r2.out
    print("survivor + re-admitted tenant bit-exact across churn")

    # round evidence: the combined program beats the time-muxed sum
    rep = fleet.collective_report()
    assert rep["status"] == "ok", rep
    print(f"combined-site decision: {rep['key']} -> {rep['strategy']} "
          f"({rep['source']})")
    fleet.admit_model(cfg, params[1], guest=(1, 2), slots=2)
    rep2 = fleet.collective_report()
    assert rep2["combined_rounds"] < rep2["time_mux_rounds"], rep2
    print(f"rounds: combined={rep2['combined_rounds']} < "
          f"time_mux={rep2['time_mux_rounds']}")

    print("SERVE FLEET CHECKS PASSED")


if __name__ == "__main__":
    main()
