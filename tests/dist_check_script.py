"""Multi-device collective equivalence checks — run as a SUBPROCESS with
XLA_FLAGS=--xla_force_host_platform_device_count=16 (set before jax import,
see test_dist_collectives.py). Exits 0 on success."""

import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=16")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.dist.mesh import dragonfly_layout
from repro.dist import collectives as coll
from repro.runtime.compat import shard_map


def get_mesh(n):
    return Mesh(np.array(jax.devices()[:n]), ("x",))


def check_all_to_all():
    n = 16
    layout = dragonfly_layout(n)
    assert layout.n == n, layout
    mesh = get_mesh(n)
    rng = np.random.default_rng(0)
    # global input: (n, n, 4) — x[i, j] is the chunk device i sends to j
    x = rng.standard_normal((n, n, 4)).astype(np.float32)

    @jax.jit
    def run_df(x):
        f = shard_map(
            lambda s: coll.dragonfly_all_to_all(s[0], "x", layout)[None],
            mesh=mesh, in_specs=P("x"), out_specs=P("x"),
        )
        return f(x)

    @jax.jit
    def run_ref(x):
        f = shard_map(
            lambda s: coll.xla_all_to_all(s[0], "x")[None],
            mesh=mesh, in_specs=P("x"), out_specs=P("x"),
        )
        return f(x)

    got = np.asarray(run_df(x))
    want = np.asarray(run_ref(x))
    # ground truth: out[i, j] = x[j, i]
    np.testing.assert_allclose(want, x.transpose(1, 0, 2), rtol=0, atol=0)
    np.testing.assert_allclose(got, want, rtol=1e-6)
    print("all_to_all OK")


def check_all_reduce():
    n = 16
    layout = dragonfly_layout(n)  # D3(4,2): K=4 M=2 -> SBH(2,1)
    assert layout.sbh is not None
    mesh = get_mesh(n)
    rng = np.random.default_rng(1)
    x = rng.standard_normal((n, 8)).astype(np.float32)

    @jax.jit
    def run_df(x):
        f = shard_map(
            lambda s: coll.dragonfly_all_reduce(s[0], "x", layout)[None],
            mesh=mesh, in_specs=P("x"), out_specs=P("x"),
        )
        return f(x)

    got = np.asarray(run_df(x))
    want = np.broadcast_to(x.sum(0), (n, 8))
    np.testing.assert_allclose(got, want, rtol=1e-5)
    print("all_reduce OK")


def check_broadcast():
    n = 16
    layout = dragonfly_layout(n)
    mesh = get_mesh(n)
    rng = np.random.default_rng(2)
    x = rng.standard_normal((n, 8)).astype(np.float32)
    root = 3

    @jax.jit
    def run_df(x):
        f = shard_map(
            lambda s: coll.dragonfly_broadcast(s[0], "x", layout, root=root)[None],
            mesh=mesh, in_specs=P("x"), out_specs=P("x"),
        )
        return f(x)

    got = np.asarray(run_df(x))
    want = np.broadcast_to(x[root], (n, 8))
    np.testing.assert_allclose(got, want, rtol=1e-5)
    print("broadcast OK")


def check_matmul():
    # D3(K²,M) with K=2, M=2: 16 routers = 16 devices in router order.
    # The §2 rounds run on the program executor — ppermutes, no gather.
    from repro.core.matmul import MatmulGrid, gather_blocks, scatter_blocks

    K, M = 2, 2
    grid = MatmulGrid(K, M)
    prog = coll.matmul_program(K, M)
    assert prog.n == 16
    mesh = get_mesh(16)
    b = 8  # block size: Theorem 2's X blocks
    rng = np.random.default_rng(3)
    side = grid.n * b
    # integer-valued floats: the round-structured sum is bit-exact vs einsum
    Bmat = rng.integers(-4, 5, (side, side)).astype(np.float32)
    Amat = rng.integers(-4, 5, (side, side)).astype(np.float32)
    bb = jnp.asarray(scatter_blocks(grid, Bmat))
    aa = jnp.asarray(scatter_blocks(grid, Amat))

    f = jax.jit(
        shard_map(
            lambda x, y: coll.dragonfly_matmul(x[0], y[0], "x", (K, M))[None],
            mesh=mesh, in_specs=(P("x"), P("x")), out_specs=P("x"),
        )
    )
    got = gather_blocks(grid, np.asarray(f(bb, aa)))
    want = np.asarray(jnp.einsum("ij,jk->ik", jnp.asarray(Bmat), jnp.asarray(Amat)))
    np.testing.assert_array_equal(got, want)  # bit-exact, zero tolerance
    txt = f.lower(bb, aa).as_text()
    n_gather = txt.count("all_gather") + txt.count("all-gather")
    assert n_gather == 0, f"dragonfly_matmul must not lower to all-gather ({n_gather})"
    print("matmul OK (program executor, bit-exact, no all-gather)")


def check_ppermute_round_count():
    """HLO of the dragonfly all-to-all shows exactly K·M² collective
    permutes minus the identity vector (the schedule is visible)."""
    n = 16
    layout = dragonfly_layout(n)
    mesh = get_mesh(n)
    x = jnp.zeros((n, n, 4), jnp.float32)
    f = jax.jit(
        shard_map(
            lambda s: coll.dragonfly_all_to_all(s[0], "x", layout)[None],
            mesh=mesh, in_specs=P("x"), out_specs=P("x"),
        )
    )
    txt = f.lower(x).as_text()
    # StableHLO spells it collective_permute; compiled HLO collective-permute
    n_perm = txt.count("collective_permute") + txt.count("collective-permute")
    K, Mm = layout.topo.K, layout.topo.M
    expected = K * Mm * Mm - 1  # identity vector elided
    assert n_perm >= expected, (n_perm, expected)
    print(f"round structure OK ({n_perm} collective-permutes ~ {expected})")


def check_embedded_collectives():
    """Guest-sized collectives on the host mesh via the optional embedding:
    dragonfly_all_to_all and dragonfly_matmul of a D3(2,2)/grid(1,2) guest
    run on the 16-device D3(4,2) host axis, bit-exact vs the guest run
    host-side, idle devices passing through."""
    from repro.core.matmul import MatmulGrid, gather_blocks, scatter_blocks
    from repro.dist.mesh import DeviceLayout
    from repro.core.topology import D3
    from repro.runtime.backends.reference import NumpyReferenceBackend
    from repro.runtime.rewrite import gather_guest, scatter_guest

    ref = NumpyReferenceBackend()
    host = dragonfly_layout(16)          # D3(4,2)
    guest = DeviceLayout(D3(2, 2))
    emb = guest.embed_onto(host, c_set=(1, 3))
    prog = coll.alltoall_program(guest, emb)
    assert prog.n == 16 and prog.guest_n == guest.n
    mesh = get_mesh(16)
    rng = np.random.default_rng(4)
    xg = rng.standard_normal((guest.n, guest.n, 4)).astype(np.float32)
    xh = jnp.asarray(scatter_guest(xg, prog, axes=(0, 1)))

    f = jax.jit(
        shard_map(
            lambda s: coll.dragonfly_all_to_all(s[0], "x", guest, embedding=emb)[None],
            mesh=mesh, in_specs=P("x"), out_specs=P("x"),
        )
    )
    got = gather_guest(np.asarray(f(xh)), prog, axes=(0, 1))
    np.testing.assert_array_equal(got, xg.transpose(1, 0, 2))

    g = MatmulGrid(1, 2)                 # guest D3(1,2): 4 of 16 devices
    membb = DeviceLayout(g.topo).embed_onto(host)
    mprog = coll.matmul_program(1, 2, membb)
    side = g.n * 4
    Bmat = rng.integers(-4, 5, (side, side)).astype(np.float32)
    Amat = rng.integers(-4, 5, (side, side)).astype(np.float32)
    bb = jnp.asarray(scatter_guest(scatter_blocks(g, Bmat), mprog))
    aa = jnp.asarray(scatter_guest(scatter_blocks(g, Amat), mprog))
    fm = jax.jit(
        shard_map(
            lambda x, y: coll.dragonfly_matmul(x[0], y[0], "x", (1, 2), embedding=membb)[None],
            mesh=mesh, in_specs=(P("x"), P("x")), out_specs=P("x"),
        )
    )
    out = gather_blocks(g, gather_guest(np.asarray(fm(bb, aa)), mprog))
    np.testing.assert_array_equal(out, Bmat @ Amat)
    np.testing.assert_array_equal(out, ref.run_matmul(Bmat, Amat, mprog))
    print("embedded collectives OK (guest D3(2,2) + grid(1,2) on D3(4,2) mesh)")


if __name__ == "__main__":
    assert jax.device_count() >= 16, jax.device_count()
    check_all_to_all()
    check_all_reduce()
    check_broadcast()
    check_matmul()
    check_ppermute_round_count()
    check_embedded_collectives()
    print("ALL DIST CHECKS PASSED")
