"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret mode)."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels.block_matmul.block_matmul import block_matmul
from repro.kernels.block_matmul.ref import block_matmul_ref
from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.flash_attention.ops import gqa_attention


# ---------------------------------------------------------------- matmul
@pytest.mark.parametrize("m,n,k", [(128, 128, 128), (256, 128, 512), (128, 384, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_block_matmul_shapes(m, n, k, dtype):
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((m, k)), dtype)
    b = jnp.asarray(rng.standard_normal((k, n)), dtype)
    got = block_matmul(a, b, bm=128, bn=128, bk=128, interpret=True)
    want = block_matmul_ref(a, b)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


@pytest.mark.parametrize("tiles", [(64, 64, 64), (128, 64, 256)])
def test_block_matmul_tile_sweep(tiles):
    bm, bn, bk = tiles
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.standard_normal((256, 256)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((256, 256)), jnp.float32)
    got = block_matmul(a, b, bm=bm, bn=bn, bk=bk, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(a @ b), rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------- attention
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("sq,sk", [(128, 128), (128, 256)])
def test_flash_vs_ref(causal, sq, sk):
    rng = np.random.default_rng(2)
    BH, D = 4, 64
    q = jnp.asarray(rng.standard_normal((BH, sq, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((BH, sk, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((BH, sk, D)), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, bq=64, bk=64, interpret=True)
    want = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_flash_sliding_window():
    rng = np.random.default_rng(3)
    BH, S, D, W = 2, 256, 64, 64
    q = jnp.asarray(rng.standard_normal((BH, S, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((BH, S, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((BH, S, D)), jnp.float32)
    got = flash_attention(q, k, v, causal=True, window=W, bq=64, bk=64, interpret=True)
    want = attention_ref(q, k, v, causal=True, window=W)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_dtypes(dtype):
    rng = np.random.default_rng(4)
    BH, S, D = 2, 128, 64
    q = jnp.asarray(rng.standard_normal((BH, S, D)), dtype)
    k = jnp.asarray(rng.standard_normal((BH, S, D)), dtype)
    v = jnp.asarray(rng.standard_normal((BH, S, D)), dtype)
    got = flash_attention(q, k, v, bq=64, bk=64, interpret=True)
    want = attention_ref(q, k, v)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


@pytest.mark.parametrize("hq,hkv", [(8, 8), (8, 2), (4, 1)])
def test_gqa_grouping(hq, hkv):
    rng = np.random.default_rng(5)
    B, S, D = 2, 128, 32
    q = jnp.asarray(rng.standard_normal((B, S, hq, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, hkv, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, hkv, D)), jnp.float32)
    got = gqa_attention(q, k, v, use_kernel=True, interpret=True)
    want = gqa_attention(q, k, v, use_kernel=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_flash_matches_big_kv_tiling():
    """Property: result independent of kv tile size (online softmax)."""
    rng = np.random.default_rng(6)
    BH, S, D = 2, 256, 64
    q = jnp.asarray(rng.standard_normal((BH, S, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((BH, S, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((BH, S, D)), jnp.float32)
    a = flash_attention(q, k, v, bq=64, bk=64, interpret=True)
    b = flash_attention(q, k, v, bq=64, bk=256, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)
