"""Dragonfly collectives ≡ XLA reference — executed in a subprocess with 16
forced host devices (the main pytest process must keep 1 device; see the
dry-run instructions in launch/dryrun.py)."""

import os
import subprocess
import sys
import pathlib

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent


@pytest.mark.slow
def test_dist_collectives_16dev():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    env["PYTHONPATH"] = str(ROOT / "src")
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "dist_check_script.py")],
        env=env,
        capture_output=True,
        text=True,
        timeout=1200,
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    assert "ALL DIST CHECKS PASSED" in proc.stdout


def test_layout_factorizations():
    from repro.dist.mesh import dragonfly_layout

    l256 = dragonfly_layout(256)
    assert (l256.topo.K, l256.topo.M) == (4, 8)
    assert l256.da_params.s == 4
    assert l256.sbh is not None and (l256.sbh.k, l256.sbh.m) == (2, 3)

    l512 = dragonfly_layout(512)
    assert (l512.topo.K, l512.topo.M) == (8, 8)
    assert l512.da_params.s == 8

    l16 = dragonfly_layout(16)
    assert (l16.topo.K, l16.topo.M) == (4, 2)

    l64 = dragonfly_layout(64)
    assert (l64.topo.K, l64.topo.M) == (4, 4)
