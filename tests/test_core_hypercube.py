"""§4 — SBH(k,m) hypercube emulation in D3(2^k, 2^m)."""

import numpy as np
import pytest

from repro.core.hypercube import (
    SBH,
    allreduce_rounds,
    check_allreduce_conflicts,
    simulate_allreduce,
    hypercube_cost,
)


CASES = [SBH(1, 1), SBH(2, 1), SBH(1, 2), SBH(2, 2)]


@pytest.mark.parametrize("s", CASES, ids=lambda s: f"k{s.k}m{s.m}")
def test_dilation_bounds(s):
    """Max dilation 3, average < 2 (strictly, thanks to d == p cases)."""
    worst, avg = s.dilation_stats()
    assert worst <= 3
    assert avg < 2.0


@pytest.mark.parametrize("s", CASES, ids=lambda s: f"k{s.k}m{s.m}")
def test_emulation_paths_flip_one_bit(s):
    for x in range(s.num_nodes):
        r = s.node(x)
        for dim in range(s.dims):
            end = s.emulation_path(r, dim)[-1]
            assert s.index(end) == x ^ (1 << dim), (x, dim)


@pytest.mark.parametrize("s", CASES, ids=lambda s: f"k{s.k}m{s.m}")
def test_paths_use_real_links(s):
    topo = s.topo
    for x in range(s.num_nodes):
        r = s.node(x)
        for dim in range(s.dims):
            path = s.emulation_path(r, dim)
            for a, b in zip(path, path[1:]):
                assert topo.is_link(a, b), (a, b, dim)


@pytest.mark.parametrize("s", CASES[:3], ids=lambda s: f"k{s.k}m{s.m}")
def test_ascend_conflict_free(s):
    conflicts, steps = check_allreduce_conflicts(s)
    assert conflicts == []
    # factor-2 claim: total steps <= 2 * dims + slack from dilation-3 dims
    assert steps <= 3 * s.dims
    emulated, native = hypercube_cost(s)
    assert emulated <= 2 * native + s.m  # avg dilation 2; worst-case padding


@pytest.mark.parametrize("s", CASES, ids=lambda s: f"k{s.k}m{s.m}")
def test_allreduce_correct(s):
    rng = np.random.default_rng(0)
    vals = rng.standard_normal(s.num_nodes)
    out = simulate_allreduce(s, vals)
    np.testing.assert_allclose(out, np.full(s.num_nodes, vals.sum()), rtol=1e-9)


@pytest.mark.parametrize("s", CASES, ids=lambda s: f"k{s.k}m{s.m}")
def test_sync_header_uniform_dilation4(s):
    """§5: [4; ...] headers give uniform 4-step paths that land on the
    correct cube neighbor."""
    for x in range(s.num_nodes):
        r = s.node(x)
        for dim in range(s.dims):
            path = s.sync_path(r, dim)
            assert len(path) == 5  # 4 steps, uniform
            assert s.index(path[-1]) == x ^ (1 << dim)


def test_dp_alltoall_beats_jh_on_sbh():
    """§4 closing claim: max(2^m, 2^{k+m+1}) < 2^{k+2m} for k,m >= 1... the
    paper compares against (2^{k+2m}/3); verify the strict form they use."""
    from repro.core import costmodel as cm

    for k in range(1, 5):
        for m in range(2, 5):
            dp = cm.alltoall_dp_on_d3_2k2m(k, m)
            jh = (1 << (k + 2 * m)) / 3
            assert dp < (1 << (k + 2 * m)), (k, m)
            if m >= 2 and k >= 1 and (k + m + 1) < (k + 2 * m):
                assert dp <= 2 * jh  # within the paper's claimed regime
