"""Runtime lowering + executor.

Pure-python lowering invariants run in-process; the device executor runs in
a subprocess with 8 forced host devices (the main pytest process must keep
1 device), asserting the lowered §3 all-to-all is bit-exact against
jax.lax.all_to_all — the IR is not just verifiable, it is the thing that
executes. Program-layer semantics and backend differentials live in
test_runtime_program.py.
"""

import os
import pathlib
import subprocess
import sys

import pytest

from repro.core import alltoall as a2a
from repro.core import broadcast as bc
from repro.core import hypercube as hc
from repro.core.topology import D3
from repro.dist.mesh import DeviceLayout, dragonfly_layout
from repro.runtime import lowering

ROOT = pathlib.Path(__file__).resolve().parent.parent


# ------------------------------------------------------------ pure lowering
@pytest.mark.parametrize("KM", [(2, 2), (4, 2), (4, 4)], ids=str)
def test_lower_alltoall_permutation_structure(KM):
    layout = DeviceLayout(D3(*KM))
    p = layout.da_params
    prog = lowering.lower(a2a.schedule(p, layout.topo))
    assert prog.kind == "alltoall"
    assert prog.n == layout.n
    # K·M²/s rounds of s full permutations = K·M² ppermutes
    assert prog.num_rounds == p.total_rounds
    assert prog.num_permutes == p.K * p.M * p.M
    for rnd in prog.perm_rounds:
        assert len(rnd) == p.s
        for op in rnd:
            sigma = op.sigma
            assert sorted(sigma) == list(range(prog.n))  # bijection
            inv = op.inverse
            assert all(inv[sigma[i]] == i for i in range(prog.n))


def test_lower_exchange_involutions():
    sbh = hc.SBH(2, 2)
    prog = lowering.lower(hc.allreduce_schedule(sbh))
    assert prog.kind == "allreduce"
    assert prog.num_rounds == sbh.dims
    assert len(prog.comm_stages) == sbh.dims
    for op in prog.comm_stages:
        assert op.is_full_permutation
        pairs = dict(op.pairs)
        assert all(pairs[pairs[s]] == s and pairs[s] != s for s in pairs)


def test_lower_broadcast_matchings_cover_all_devices():
    topo = D3(4, 4)
    root = (0, 0, 1)
    prog = lowering.lower(bc.depth3_schedule(topo, root))
    assert prog.kind == "broadcast"
    reached = {prog.root}
    for stage in prog.stages:
        srcs = [s for s, _ in stage.pairs]
        dsts = [d for _, d in stage.pairs]
        assert len(set(srcs)) == len(srcs) and len(set(dsts)) == len(dsts)
        for s, d in stage.pairs:
            assert s in reached  # parents always send before children
            reached.add(d)
    assert reached == set(range(topo.num_routers))


def test_barrier_start_steps_accumulate():
    """Non-pipelined schedules get barrier-base start_steps, so pipelined
    (start_step-ordered) replay degenerates to program order."""
    layout = DeviceLayout(D3(2, 2))
    prog = lowering.lower(a2a.schedule(layout.da_params, layout.topo))
    starts = [s.start_step for s in prog.stages]
    assert starts == sorted(starts)
    assert prog.pipelined_stages() == prog.stages


def test_dragonfly_layout_8_devices():
    layout = dragonfly_layout(8)
    assert (layout.topo.K, layout.topo.M) == (2, 2)
    assert layout.da_params.s == 2
    assert layout.sbh is not None


# ------------------------------------------------------------- device check
@pytest.mark.slow
def test_runtime_executor_8dev():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(ROOT / "src")
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "runtime_check_script.py")],
        env=env,
        capture_output=True,
        text=True,
        timeout=1200,
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    assert "ALL RUNTIME CHECKS PASSED" in proc.stdout
