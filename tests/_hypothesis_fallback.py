"""Deterministic stand-in for the tiny hypothesis subset these tests use.

When ``hypothesis`` is installed the real library is used (see the
try/except at each import site); otherwise ``@given`` degrades to a seeded
loop over ``max_examples`` random samples — the property tests still
exercise a spread of inputs, just without shrinking or example databases.
"""

from __future__ import annotations

import random


class _Strategy:
    def __init__(self, sampler):
        self._sampler = sampler

    def sample(self, rng: random.Random):
        return self._sampler(rng)

    def filter(self, pred) -> "_Strategy":
        def sampler(rng, _tries=1000):
            for _ in range(_tries):
                v = self._sampler(rng)
                if pred(v):
                    return v
            raise ValueError("filter predicate never satisfied")

        return _Strategy(sampler)

    def map(self, fn) -> "_Strategy":
        return _Strategy(lambda rng: fn(self._sampler(rng)))


class _DataObject:
    """Mimics hypothesis' interactive data object: draw(strategy)."""

    def __init__(self, rng: random.Random):
        self._rng = rng

    def draw(self, strategy: _Strategy, label: str | None = None):
        return strategy.sample(self._rng)


class strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def sampled_from(elements) -> _Strategy:
        pool = list(elements)
        return _Strategy(lambda rng: pool[rng.randrange(len(pool))])

    @staticmethod
    def tuples(*strats: "_Strategy") -> _Strategy:
        return _Strategy(lambda rng: tuple(s.sample(rng) for s in strats))

    @staticmethod
    def data() -> _Strategy:
        return _Strategy(lambda rng: _DataObject(rng))


def settings(max_examples: int = 20, deadline=None, **_ignored):
    def deco(f):
        f._fallback_max_examples = max_examples
        return f

    return deco


def given(*strats: _Strategy):
    def deco(f):
        # NOTE: no functools.wraps — copying __wrapped__/signature would
        # make pytest treat the sampled parameters as fixtures.
        def wrapper():
            n = getattr(
                wrapper, "_fallback_max_examples",
                getattr(f, "_fallback_max_examples", 20),
            )
            rng = random.Random(0xD3)  # deterministic across runs
            for _ in range(n):
                f(*(s.sample(rng) for s in strats))

        wrapper.__name__ = f.__name__
        wrapper.__doc__ = f.__doc__
        wrapper.__module__ = f.__module__
        return wrapper

    return deco
