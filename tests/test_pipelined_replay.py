"""Pipelined shard-path dispatch — §3 Schedules 1–3 as wave-ordered fused
tables (``core.alltoall.wave_rounds`` / ``runtime.optimize.exchange_waves``)
and the overlapped global replay ``jax_alltoall_overlapped``, differential
against the sequential fused replay and the NumPy reference.

These run in the main pytest process (global-array replay needs no device
mesh). The mesh-backed per-shard differentials — ``overlap_fused``
dispatch and the fused dispatch+compute+combine round trip on 8- and
16-device meshes, incl. an emulated guest — live in
``pipeline_check_script.py`` and run as a slow-marked subprocess below
(XLA device count must be forced before jax imports)."""

import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest

from repro.core import alltoall as a2a
from repro.dist.mesh import dragonfly_layout
from repro.runtime import lowering
from repro.runtime import optimize as ropt

ROOT = pathlib.Path(__file__).resolve().parent.parent


# ------------------------------------------------------- wave structure
@pytest.mark.parametrize("offset", [1, 2, 3])
def test_wave_rounds_partition_matches_round_starts(offset):
    p = dragonfly_layout(8).da_params
    starts, _, _ = a2a.round_starts(p, offset)
    waves = a2a.wave_rounds(p, offset)
    # a partition of all rounds, grouped by identical start, in launch order
    flat = [r for w in waves for r in w]
    assert sorted(flat) == list(range(p.total_rounds))
    wave_starts = [starts[w[0]] for w in waves]
    assert wave_starts == sorted(wave_starts)
    assert len(set(wave_starts)) == len(waves)
    for w in waves:
        assert len({starts[r] for r in w}) == 1


@pytest.mark.parametrize("offset", [1, 2, 3])
def test_exchange_waves_cover_fused_tables(offset):
    layout = dragonfly_layout(8)
    p = layout.da_params
    opt = ropt.optimize(
        lowering.lower(a2a.pipelined_schedule(p, offset, layout.topo)))
    waves = ropt.exchange_waves(opt)
    wr = a2a.wave_rounds(p, offset)
    assert len(waves) == len(wr)
    # each round is s permutations of n pairs: the (src, dst) tables of a
    # wave hold exactly len(rounds)*s*n entries, and starts are increasing
    for (start, src, dst), rids in zip(waves, wr):
        assert len(src) == len(dst) == len(rids) * p.s * opt.n
    assert [w[0] for w in waves] == sorted({w[0] for w in waves})


# ------------------------------------------- overlapped global replay
@pytest.mark.parametrize("offset", [1, 2, 3])
def test_overlapped_replay_bit_exact(offset):
    layout = dragonfly_layout(8)
    opt = ropt.optimize(lowering.lower(
        a2a.pipelined_schedule(layout.da_params, offset, layout.topo)))
    rng = np.random.default_rng(0)
    x = rng.standard_normal((8, 8, 3)).astype(np.float32)
    want = ropt.np_alltoall(x.copy(), opt)
    got = np.asarray(ropt.jax_alltoall_overlapped(opt)(x))
    np.testing.assert_array_equal(got, want)
    # and identical to the sequential fused replay (the backend contract)
    np.testing.assert_array_equal(got, np.asarray(ropt.jax_alltoall(opt, False)(x)))


def test_overlapped_replay_barrier_program():
    """A program without start_step stamps degenerates to one wave and must
    still replay bit-exactly."""
    layout = dragonfly_layout(8)
    opt = ropt.optimize(lowering.lower(
        a2a.schedule(layout.da_params, layout.topo)))
    rng = np.random.default_rng(1)
    x = rng.standard_normal((8, 8, 2)).astype(np.float32)
    np.testing.assert_array_equal(
        np.asarray(ropt.jax_alltoall_overlapped(opt)(x)),
        ropt.np_alltoall(x.copy(), opt))


@pytest.mark.parametrize("offset", [1, 3])
def test_overlapped_replay_with_compute_round_trip(offset):
    """compute keyed by destination: out[s, d] = compute_d(x[s, d]).
    Multiply-only compute so eager/jit agree bitwise (no FMA fusion)."""
    import jax.numpy as jnp

    layout = dragonfly_layout(8)
    opt = ropt.optimize(lowering.lower(
        a2a.pipelined_schedule(layout.da_params, offset, layout.topo)))
    rng = np.random.default_rng(2)
    x = rng.standard_normal((8, 8, 3)).astype(np.float32)
    scale = jnp.arange(8, dtype=jnp.float32) + 1.0

    def comp(chunks, dst_ids):
        return chunks * scale[dst_ids][:, None]

    got = np.asarray(ropt.jax_alltoall_overlapped(opt, comp)(x))
    want = x * (np.arange(8, dtype=np.float32) + 1.0)[None, :, None]
    np.testing.assert_array_equal(got, want)


def test_overlapped_replay_emulated_guest():
    """Guest D3(2,2) pipelined program embedded on a D3(4,2) host: idle
    devices stay untouched, the guest block matches the reference."""
    from repro.core.emulation import embed
    from repro.core.topology import D3
    from repro.dist.mesh import DeviceLayout
    from repro.runtime.backends.reference import NumpyReferenceBackend
    from repro.runtime.rewrite import emulate

    guest = DeviceLayout(D3(2, 2))
    emb = embed(D3(4, 2), 2, 2, c_set=(1, 3), p_set=(0, 1))
    gprog = lowering.lower(
        a2a.pipelined_schedule(guest.da_params, 1, guest.topo))
    hprog = emulate(gprog, emb)
    assert hprog.active_devices is not None
    n = hprog.n
    act = np.asarray(hprog.active_devices)
    rng = np.random.default_rng(7)
    x = np.zeros((n, n, 3), np.float32)
    x[np.ix_(act, act)] = rng.standard_normal(
        (len(act), len(act), 3)).astype(np.float32)

    opt = ropt.optimize(hprog)
    got = np.asarray(ropt.jax_alltoall_overlapped(opt)(x))
    want = NumpyReferenceBackend().run_alltoall(x.copy(), hprog)
    np.testing.assert_array_equal(got, want)
    idle = np.setdiff1d(np.arange(n), act)
    assert not got[idle].any() and not got[:, idle].any()


# ------------------------------------------- subprocess mesh differentials
@pytest.mark.slow
def test_pipeline_shard_differentials_16dev():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    env["PYTHONPATH"] = str(ROOT / "src")
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "pipeline_check_script.py")],
        env=env, capture_output=True, text=True, timeout=1800,
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    assert "ALL PIPELINE CHECKS PASSED" in proc.stdout
