"""Elastic training: fault injection, rewrite-only failover, §5-broadcast
shard redistribution, loss-curve continuity — plus the recovery-path
satellites (checkpoint hygiene, typed data-state restore, straggler
renormalization)."""

import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core.topology import D3
from repro.dist.mesh import DeviceLayout
from repro.train import checkpoint as ckpt
from repro.train.data import DataState, SyntheticLM
from repro.train.elastic import (
    ElasticTrainer,
    FaultInjector,
    max_loss_divergence,
)
from repro.train.fault_tolerance import (
    ClusterState,
    StragglerPolicy,
    derivation_count,
    renormalized_scale,
)
from repro.train.optimizer import OptConfig
from repro.train.train_step import (
    TrainSettings,
    init_train_state,
    make_apply_step,
    make_microbatch_grads,
    make_train_step,
    split_microbatches,
)


# ------------------------------------------------------ checkpoint hygiene
def test_latest_step_ignores_stray_files(tmp_path):
    """Regression: a stray FILE matching step_* (a step_tmp leftover, an
    editor backup) used to crash latest_step — only step_<int> directories
    count now, unparseable directory names are skipped too."""
    ckpt.save(tmp_path, 3, {"x": np.zeros(1)})
    ckpt.save(tmp_path, 7, {"x": np.ones(1)})
    (tmp_path / "step_tmp").write_text("leftover")          # stray file
    (tmp_path / "step_00000099").write_text("not a dir")    # file, big step
    (tmp_path / "step_bogus").mkdir()                        # unparseable dir
    assert ckpt.latest_step(tmp_path) == 7
    step, tree = ckpt.restore(tmp_path)
    assert step == 7 and float(tree["x"][0]) == 1.0


def test_restore_verify_raises_on_truncated_npz(tmp_path):
    """A truncated arrays.npz must raise on the digest check BEFORE any
    parameter loads (verify=True is the failover default)."""
    ckpt.save(tmp_path, 2, {"w": np.arange(64, dtype=np.float32)})
    arrays = tmp_path / "step_00000002" / "arrays.npz"
    blob = arrays.read_bytes()
    arrays.write_bytes(blob[: len(blob) // 2])
    with pytest.raises(IOError, match="digest mismatch"):
        ckpt.restore(tmp_path, verify=True)


def test_data_state_restore_roundtrip(tmp_path):
    """Checkpoint -> restore of the data-iterator state: from_dict coerces
    the numpy scalars npz hands back into real ints, and the restored
    stream continues exactly where the original left off."""
    data = SyntheticLM(DataState(seed=5, batch=2, seq=8, vocab=32))
    for _ in range(3):
        data.next_batch()
    ckpt.save(tmp_path, 3, {"data": data.state.to_dict()})
    expected = data.next_batch()

    _, tree = ckpt.restore(tmp_path)
    state = DataState.from_dict(tree["data"])
    for f, v in state.__dict__.items():
        assert type(v) is int, (f, type(v))
    resumed = SyntheticLM(state).next_batch()
    np.testing.assert_array_equal(resumed["tokens"], expected["tokens"])


# ----------------------------------------------------------- fault injector
def test_fault_injector_consume_once():
    inj = FaultInjector({4: [1, 2], 9: [5]})
    assert inj.take(3) == ()
    assert inj.take(4) == (1, 2)
    assert inj.take(4) == ()    # fired: a post-failover rewind passing the
    assert inj.take(9) == (5,)  # same step must not re-kill
    assert inj.take(9) == ()


def test_fault_injector_sample_deterministic():
    host = D3(2, 2)
    a = FaultInjector.sample(host, steps=12, failures=3, seed=7)
    b = FaultInjector.sample(host, steps=12, failures=3, seed=7)
    assert a.schedule == b.schedule
    devices = [d for devs in a.schedule.values() for d in devs]
    assert len(devices) == 3 and len(set(devices)) == 3
    assert all(0 <= d < host.num_routers for d in devices)
    assert all(1 <= s < 12 for s in a.schedule)
    with pytest.raises(ValueError):
        FaultInjector.sample(host, steps=3, failures=9, seed=0)


# ------------------------------------------------- elastic trainer (drill)
def _tiny():
    cfg = get_smoke_config("tinyllama-1.1b")
    opt_cfg = OptConfig(lr=3e-3, warmup_steps=2, total_steps=10)
    settings = TrainSettings(use_kernel=False, remat=False)
    return cfg, opt_cfg, settings


@pytest.fixture(scope="module")
def cascade_runs(tmp_path_factory):
    """One uninterrupted run and one twice-shrinking elastic run of the
    same seed/data — shared by the continuity and cascade assertions."""
    cfg, opt_cfg, settings = _tiny()
    kw = dict(host=D3(2, 2), batch=4, seq=16, seed=0, ckpt_every=2)
    base = ElasticTrainer(
        cfg, opt_cfg, settings,
        ckpt_dir=tmp_path_factory.mktemp("base"), **kw)
    base_losses = base.run(10)
    el = ElasticTrainer(
        cfg, opt_cfg, settings,
        ckpt_dir=tmp_path_factory.mktemp("elastic"),
        injector=FaultInjector({3: [1], 7: [4]}), **kw)
    el_losses = el.run(10)
    return base, base_losses, el, el_losses


def test_cascade_survives_and_shrinks_twice(cascade_runs):
    _, _, el, el_losses = cascade_runs
    assert len(el_losses) == 10
    assert [e.shape for e in el.events] == [(1, 2), (2, 1)]
    assert [e.absorbed for e in el.events] == [False, False]
    # the survivor pool shrinks monotonically and never includes a dead
    # device (the second image may re-admit healthy devices the first
    # image left idle — Property 2 searches the whole host, not the
    # previous image)
    assert len(el.events[1].survivors) < len(el.events[0].survivors)
    dead_so_far: set = set()
    for e in el.events:
        dead_so_far |= set(e.failed)
        assert not set(e.survivors) & dead_so_far
        assert e.derivations == 0          # rewrite-only, asserted per event
        assert e.broadcast_rounds >= 1     # shards moved via the §5 program
        assert e.bytes_redistributed > 0
        assert e.resumed_from <= e.step


def test_cascade_loss_continuity(cascade_runs):
    """Post-failover losses match the uninterrupted run at equal
    data-state: recovery restores the exact (params, opt, data) triple, so
    the two curves coincide everywhere, failovers included."""
    _, base_losses, _, el_losses = cascade_runs
    assert set(base_losses) == set(el_losses)
    assert max_loss_divergence(base_losses, el_losses) < 1e-4


def test_cascade_reuses_memoized_library(cascade_runs):
    """A second plan for the same dead set is a pure cache hit: same suite
    objects from the shape library, identical rewritten programs from the
    memoized emulate — and zero derivations."""
    _, _, el, _ = cascade_runs
    d0 = derivation_count()
    p1 = el.cluster.plan_recovery()
    p2 = el.cluster.plan_recovery()
    assert derivation_count() == d0
    assert set(el.cluster.library) >= {(2, 2), (1, 2), (2, 1), (1, 1)}
    for kind in p1.programs:
        assert p1.programs[kind] is p2.programs[kind]


def test_absorbed_failure_outside_image_keeps_stepping(tmp_path):
    """After shrinking to cabinet 1 (devices 4-7), killing device 0 —
    outside the active image — must not rewind: the sitting plan stays
    valid and training continues from the detection step."""
    cfg, opt_cfg, settings = _tiny()
    el = ElasticTrainer(
        cfg, opt_cfg, settings, ckpt_dir=tmp_path, host=D3(2, 2),
        injector=FaultInjector({2: [1], 5: [0]}),
        batch=4, seq=16, seed=0, ckpt_every=3)
    losses = el.run(8)
    assert len(losses) == 8
    first, second = el.events
    assert not first.absorbed and first.shape == (1, 2)
    assert second.absorbed
    assert second.resumed_from == second.step == 5
    assert second.broadcast_rounds == 0 and second.bytes_redistributed == 0
    assert second.derivations == 0


def test_unprepared_shape_is_refused(tmp_path):
    """plan_recovery never derives: an empty library raises rather than
    silently re-deriving inside the failover window."""
    from repro.train.fault_tolerance import UnpreparedShapeError
    cs = ClusterState(DeviceLayout(D3(2, 2)))
    cs.fail(1)
    with pytest.raises(UnpreparedShapeError):
        cs.plan_recovery()


# ------------------------------------------------ straggler renormalization
def test_straggler_drop_renormalized_matches_kept_batch():
    """The split step (per-microbatch grads + renormalized accumulation +
    apply) with microbatch i dropped equals the FUSED step run on a batch
    containing only the kept microbatches — the dropped contribution is
    gone, not smeared."""
    cfg = get_smoke_config("olmo-1b")
    opt_cfg = OptConfig(lr=1e-3, warmup_steps=0)
    data = SyntheticLM(DataState(seed=3, batch=8, seq=16, vocab=cfg.vocab))
    batch = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
    total = 4
    settings = TrainSettings(microbatches=total, use_kernel=False, remat=False)
    params, opt_state = init_train_state(jax.random.key(1), cfg, opt_cfg, settings)

    mb_grads = jax.jit(make_microbatch_grads(cfg, settings))
    apply_fn = jax.jit(make_apply_step(cfg, opt_cfg, settings))
    mbs = split_microbatches(batch, total)
    keep = [True, True, False, True]           # microbatch 2 straggles
    results = [mb_grads(params, mb) for mb in mbs]
    kept = [r for r, k in zip(results, keep) if k]
    scale = renormalized_scale(len(kept), total) / total   # == 1 / kept
    g_sum = jax.tree.map(lambda *gs: sum(gs), *(g for _, _, g in kept))
    grads = jax.tree.map(lambda g: g * scale, g_sum)
    loss = sum(l for l, _, _ in kept) * scale
    p_drop, _, m_drop = apply_fn(params, opt_state, grads, loss, kept[-1][1])

    # reference: the fused step over ONLY the kept microbatches
    kept_batch = {
        k: jnp.concatenate([mb[k] for mb, kp in zip(mbs, keep) if kp])
        for k in batch
    }
    ref_settings = TrainSettings(microbatches=len(kept), use_kernel=False, remat=False)
    ref_step = jax.jit(make_train_step(cfg, opt_cfg, ref_settings))
    p_ref, _, m_ref = ref_step(params, opt_state, kept_batch)

    assert float(m_drop["loss"]) == pytest.approx(float(m_ref["loss"]), rel=1e-5)
    for a, b in zip(jax.tree.leaves(p_drop), jax.tree.leaves(p_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-6)


def test_straggler_policy_and_scale():
    policy = StragglerPolicy()
    keep = policy.judge([1.0, 1.1, 0.9, 25.0])
    assert keep == [True, True, True, False]
    assert renormalized_scale(sum(keep), len(keep)) == pytest.approx(4 / 3)


# ------------------------------------------- subprocess end-to-end drill
@pytest.mark.slow
def test_elastic_drill_16dev():
    """Device-backed randomized fault-injection drill on a forced
    16-device mesh (the CI smoke): seeded kills, jax-backend §5
    redistribution, loss continuity vs. the uninterrupted run."""
    root = pathlib.Path(__file__).resolve().parent.parent
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    env["PYTHONPATH"] = str(root / "src")
    proc = subprocess.run(
        [sys.executable, str(root / "tests" / "elastic_check_script.py")],
        env=env, capture_output=True, text=True, timeout=1200,
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    assert "ELASTIC CHECKS PASSED" in proc.stdout
