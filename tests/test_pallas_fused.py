"""Pallas-fused backend smoke tests — CPU, ``interpret=True``.

Tier-1 exercises the fused path without a TPU: the ReduceCombine table
kernel (the interpret-mode face of the remote-DMA ring), the vmapped
``block_matmul`` Pallas kernel on the §2 ``mul_a`` contraction, and the
optimizer-table delegation for the data-movement collectives — all
bit-exact against the reference backend. Shapes stay tiny: the Pallas
interpreter executes kernel bodies op-by-op.
"""

import numpy as np
import pytest

from repro.core import alltoall as a2a
from repro.core import broadcast as bc
from repro.core import hypercube as hc
from repro.core import matmul as mm
from repro.core.emulation import embed
from repro.core.topology import D3
from repro.dist.mesh import DeviceLayout
from repro.runtime import lowering
from repro.runtime import optimize as opt
from repro.runtime.backends import get_backend
from repro.runtime.backends.pallas_fused import PallasFusedBackend
from repro.runtime.backends.reference import NumpyReferenceBackend
from repro.runtime.rewrite import emulate, scatter_guest

REF = NumpyReferenceBackend()
PAL = PallasFusedBackend(interpret=True)
LAYOUT = DeviceLayout(D3(2, 2))


def test_registry_and_auto_interpret():
    be = get_backend("pallas_fused")
    assert isinstance(be, PallasFusedBackend)
    assert be.name == "pallas_fused"
    # on a CPU host the auto mode must select the interpreter
    import jax

    if jax.default_backend() != "tpu":
        assert be._interp()
    assert get_backend("pallas", interpret=True)._interp()


def test_ring_kernel_allreduce_smoke():
    """Satellite: the Pallas ReduceCombine kernel (interpret) replays the
    §4 hypercube rounds bit-exactly — on the program AND its optimized
    form."""
    prog = lowering.lower(hc.allreduce_schedule(LAYOUT.sbh))
    x = np.random.default_rng(0).standard_normal((prog.n, 4)).astype(np.float32)
    want = REF.run_allreduce(x, prog)
    np.testing.assert_array_equal(np.asarray(PAL.run_allreduce(x, prog)), want)
    np.testing.assert_array_equal(
        np.asarray(PAL.run_allreduce(x, opt.optimize(prog))), want)
    np.testing.assert_allclose(want, np.broadcast_to(x.sum(0), x.shape),
                               rtol=1e-5, atol=1e-6)


def test_ring_kernel_allreduce_emulated():
    """Emulated guest rounds drive the same kernel through partial tables:
    idle host devices pass through (fill value survives)."""
    emb = embed(D3(2, 4), 2, 2, p_set=(1, 3))
    hp = emulate(lowering.lower(hc.allreduce_schedule(LAYOUT.sbh)), emb)
    xg = np.random.default_rng(1).standard_normal((LAYOUT.n, 3)).astype(np.float32)
    xh = scatter_guest(xg, hp, fill=7.0)
    got = np.asarray(PAL.run_allreduce(xh, hp))
    np.testing.assert_array_equal(got, REF.run_allreduce(xh, hp))
    assert np.all(got[~hp.active_mask_np] == 7.0)


@pytest.mark.parametrize("grid,X", [((2, 2), 2), ((1, 2), 4)], ids=str)
def test_matmul_through_pallas_kernels(grid, X):
    """§2 replay with mul_a on the block_matmul Pallas kernel and the
    combine groups on the table kernel — bit-exact vs B @ A and the
    reference replay (integer-valued float32)."""
    g = mm.MatmulGrid(*grid)
    prog = lowering.lower(mm.schedule(g))
    rng = np.random.default_rng(2)
    N = g.n * X
    B = rng.integers(-4, 5, (N, N)).astype(np.float32)
    A = rng.integers(-4, 5, (N, N)).astype(np.float32)
    got = np.asarray(PAL.run_matmul(B, A, prog))
    np.testing.assert_array_equal(got, B @ A)
    np.testing.assert_array_equal(got, REF.run_matmul(B, A, prog))


def test_data_movement_delegates_to_fused_tables():
    """alltoall/broadcast have no compute to fuse: the backend replays the
    optimizer tables and must match the reference bit-for-bit."""
    rng = np.random.default_rng(3)
    n = LAYOUT.n
    prog = lowering.lower(a2a.schedule(LAYOUT.da_params, LAYOUT.topo))
    x = rng.standard_normal((n, n, 2)).astype(np.float32)
    np.testing.assert_array_equal(
        np.asarray(PAL.run_alltoall(x, prog)), REF.run_alltoall(x, prog))

    prog = lowering.lower(bc.depth3_schedule(LAYOUT.topo, (0, 1, 0)))
    xb = rng.standard_normal((n, 3)).astype(np.float32)
    np.testing.assert_array_equal(
        np.asarray(PAL.run_broadcast(xb, prog)), REF.run_broadcast(xb, prog))
    # pipelined flag is accepted and bit-identical (fused replay is
    # order-free by conflict-freedom)
    np.testing.assert_array_equal(
        np.asarray(PAL.run_broadcast(xb, prog, pipelined=True)),
        REF.run_broadcast(xb, prog, pipelined=True))


def test_batched_block_matmul_kernel():
    """The vmapped Pallas kernel entry used for mul_a (interpret mode)."""
    from repro.kernels.block_matmul.ops import batched_matmul

    rng = np.random.default_rng(4)
    a = rng.integers(-3, 4, (5, 4, 4)).astype(np.float32)
    b = rng.integers(-3, 4, (5, 4, 4)).astype(np.float32)
    got = np.asarray(batched_matmul(a, b, interpret=True))
    np.testing.assert_array_equal(got, np.einsum("nab,nbc->nac", a, b))


def test_shard_ring_path_guarded_off_tpu():
    """The remote-DMA ring per-shard path refuses to run without TPU
    interconnect (the interpreter cannot simulate cross-chip DMA)."""
    import jax

    if jax.default_backend() == "tpu":  # pragma: no cover - CPU CI
        pytest.skip("TPU host: ring path is live")
    prog = lowering.lower(hc.allreduce_schedule(LAYOUT.sbh))
    with pytest.raises(RuntimeError, match="remote DMA"):
        PAL.allreduce_shard(np.zeros((4,)), "df", prog)
