"""§2 — matrix product on D3(K², M): Theorem 1/2 round counts, the paper's
network-cost comparison table (D3 vs Cannon vs HJE vs DNS vs GS), and
simulator-verified conflict-freedom."""

from __future__ import annotations

import numpy as np

from repro.core.matmul import MatmulGrid, simulate_matmul, check_round_conflicts, rounds_for
from repro.core import costmodel as cm


def table_theorem1(log=print):
    """Round/hop counts + correctness on concrete grids."""
    rows = []
    for K, M in [(2, 2), (2, 3), (3, 2), (3, 3)]:
        g = MatmulGrid(K, M)
        n = g.n
        rng = np.random.default_rng(0)
        B = rng.standard_normal((n, n))
        A = rng.standard_normal((n, n))
        ok = np.allclose(simulate_matmul(g, B, A), B @ A, rtol=1e-9, atol=1e-9)
        conf = sum(len(check_round_conflicts(g, s, u)) for s in range(K) for u in range(M))
        rows.append((f"D3({K * K},{M})", n, rounds_for(g, n), 4, conf, ok))
        log(f"matmul_thm1,K2={K*K},M={M},n={n},rounds={rounds_for(g, n)},hops_per_round=4,conflicts={conf},correct={ok}")
    return rows


def table_section2(log=print, n=4096, P=4096):
    """The paper's §2 cost table: network time (t_w units) for an n×n
    product on P processors."""
    rows = []
    for name, fn in cm.MATMUL_TABLE.items():
        t = fn(n, P)
        rows.append((name, t))
        log(f"matmul_table,algo={name},n={n},P={P},network_time={t:.4g}")
    # the paper's qualitative ordering: D3 = 2x Cannon; both beat HJE/GS logs
    d3 = dict(rows)["D3(K^2,M)"]
    cannon = dict(rows)["Cannon"]
    assert abs(d3 / cannon - 2.0) < 1e-9
    return rows


def run(log=print):
    table_theorem1(log)
    table_section2(log)


if __name__ == "__main__":
    run()
