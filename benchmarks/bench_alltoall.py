"""§3 — doubly-parallel all-to-all: Theorem 3 round counts, schedule 1/2/3
measured pipeline costs (with delay insertion), the K=7/M=16 embedded-
subnetwork example, and the §4 comparison vs Johnsson-Ho."""

from __future__ import annotations

from repro.core import alltoall as a2a
from repro.core import costmodel as cm


def table_theorem3(log=print):
    for K, M, s in [(2, 4, 2), (4, 6, 2), (6, 9, 3), (4, 8, 4), (8, 8, 8)]:
        p = a2a.DAParams(K, M, s)
        a2a.verify_vector_coverage(p)
        log(
            f"a2a_thm3,K={K},M={M},s={s},rounds={p.total_rounds},"
            f"paper_formula={K * M * M // s},packets={K * M * M}"
        )


def table_schedules(log=print):
    for K, M, s in [(2, 4, 2), (4, 6, 2), (4, 8, 4)]:
        p = a2a.DAParams(K, M, s)
        r3 = a2a.pipeline(p, offset=3)
        r2 = a2a.pipeline(p, offset=2)
        r1 = a2a.pipeline(p, offset=1) if s <= M // 2 else None
        log(
            f"a2a_schedules,K={K},M={M},s={s},"
            f"sched3_steps={r3.total_steps},sched3_paper={3 * p.total_rounds},"
            f"sched2_steps={r2.total_steps},sched2_paper={2 * p.total_rounds},"
            + (
                f"sched1_steps={r1.total_steps},sched1_delays={r1.delays},"
                f"sched1_paper_delays={a2a.schedule1_predicted_delays(p)}"
                if r1
                else "sched1=invalid(s>M/2)"
            )
        )


def table_embedded_example(log=print):
    """Paper's K=7, M=16 example: D3(5,15) s=5 inside beats native."""
    p = a2a.DAParams(5, 15, 5)
    items = 7 * 16 * 16
    ratio = items / (5 * 15 * 15)
    cost = p.total_rounds * ratio * ratio
    log(
        f"a2a_embedded,host=D3(7,16),guest=D3(5,15),s=5,native_rounds=1792,"
        f"embedded_rounds={cost:.0f},paper_value=569"
    )
    assert cost < 1792


def table_vs_johnsson_ho(log=print):
    """§4: doubly-parallel on D3(2^k,2^m) vs JH on the emulated SBH."""
    for k, m in [(2, 3), (3, 3), (2, 4), (4, 4)]:
        P = 1 << (k + 2 * m)
        dp = cm.alltoall_dp_on_d3_2k2m(k, m)
        jh_native = cm.alltoall_johnsson_ho(P)
        jh_sbh = cm.alltoall_jh_on_sbh(k, m)
        log(
            f"a2a_vs_jh,k={k},m={m},P={P},doubly_parallel={dp:.0f},"
            f"jh_on_hypercube={jh_native:.0f},jh_on_sbh={jh_sbh:.0f},"
            f"dp_wins={dp < jh_sbh}"
        )


def run(log=print):
    table_theorem3(log)
    table_schedules(log)
    table_embedded_example(log)
    table_vs_johnsson_ho(log)


if __name__ == "__main__":
    run()
