"""§5 — Broadcast Swapped Dragonfly: depth-3 vs M-tree pipelines, the
3X/M claim, per-step conflict freedom, header-automaton coverage."""

from __future__ import annotations

from repro.core.topology import D3
from repro.core import broadcast as bc
from repro.core.routing import SyncHeader, STAR
from repro.core import costmodel as cm


def table_single_broadcasts(log=print):
    for K, M in [(2, 3), (3, 4), (4, 8)]:
        t = D3(K, M)
        conflicts = bc.check_m_broadcast(t, (0, 0, 0))
        cov3, s3 = bc.run_header_broadcast(t, (0, 1 % M, 0), SyncHeader(3, STAR, STAR, STAR))
        cov4, s4 = bc.run_header_broadcast(t, (0, 1 % M, 0), SyncHeader(4, STAR, STAR, STAR))
        log(
            f"bcast_trees,K={K},M={M},m_broadcast_conflicts={len(conflicts)},"
            f"hdr3_cover={len(cov3)}/{t.num_routers},hdr3_steps={s3},"
            f"hdr4_cover={len(cov4)}/{t.num_routers},hdr4_steps={s4}"
        )


def table_pipelines(log=print):
    for K, M, waves in [(2, 3, 8), (3, 4, 8), (4, 8, 6)]:
        t = D3(K, M)
        rep4 = bc.pipeline_depth4_pairs(t, (0, 0, 0), waves=waves)
        X = rep4.num_broadcasts
        rep3 = bc.pipeline_depth3(t, (0, 1, 0), X=X)
        log(
            f"bcast_pipeline,K={K},M={M},X={X},"
            f"depth3_steps={rep3.total_steps},depth3_paper={cm.broadcast_depth3(X):.0f},"
            f"mtree_steps={rep4.total_steps},mtree_paper={cm.broadcast_m_tree(X, M):.0f},"
            f"mtree_conflicts={rep4.conflicts},speedup={rep3.total_steps / rep4.total_steps:.2f}"
        )


def run(log=print):
    table_single_broadcasts(log)
    table_pipelines(log)


if __name__ == "__main__":
    run()
