"""§4 — SBH(k,m) hypercube emulation: dilation statistics, ascend-descend
(all-reduce) cost factor vs native hypercube, uniform dilation-4 headers."""

from __future__ import annotations

import numpy as np

from repro.core.hypercube import (
    SBH, check_allreduce_conflicts, simulate_allreduce, hypercube_cost,
)


def table_dilation(log=print):
    for k, m in [(1, 1), (2, 1), (1, 2), (2, 2), (3, 2)]:
        s = SBH(k, m)
        worst, avg = s.dilation_stats()
        log(
            f"sbh_dilation,k={k},m={m},nodes={s.num_nodes},dims={s.dims},"
            f"max_dilation={worst},avg_dilation={avg:.3f},paper_max=3,paper_avg<2"
        )
        assert worst <= 3 and avg < 2.0


def table_ascend_descend(log=print):
    for k, m in [(2, 1), (1, 2), (2, 2)]:
        s = SBH(k, m)
        conflicts, steps = check_allreduce_conflicts(s)
        emulated, native = hypercube_cost(s)
        vals = np.random.default_rng(0).standard_normal(s.num_nodes)
        out = simulate_allreduce(s, vals)
        ok = np.allclose(out, vals.sum(), rtol=1e-9)
        log(
            f"sbh_allreduce,k={k},m={m},conflicts={len(conflicts)},steps={steps},"
            f"emulated_hops={emulated},native_hops={native},"
            f"factor={emulated / native:.2f},paper_factor~2,correct={ok}"
        )


def table_sync_dilation4(log=print):
    for k, m in [(2, 1), (2, 2)]:
        s = SBH(k, m)
        lens = {
            len(s.sync_path(s.node(x), dim)) - 1
            for x in range(s.num_nodes)
            for dim in range(s.dims)
        }
        log(f"sbh_sync_header,k={k},m={m},path_lengths={sorted(lens)},paper=uniform 4")
        assert lens == {4}


def run(log=print):
    table_dilation(log)
    table_ascend_descend(log)
    table_sync_dilation4(log)


if __name__ == "__main__":
    run()
