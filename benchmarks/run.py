"""Benchmark harness — one module per paper table/figure + timed micro-
benchmarks of the runtime layers. Prints ``name,...`` CSV-ish lines;
``--json BENCH_<date>.json`` additionally writes machine-readable records
({name, params, us_per_call?, rounds?}) so the perf trajectory is tracked
across PRs.

    PYTHONPATH=src python -m benchmarks.run [--json BENCH_2026-07-30.json]

``--compare OLD.json NEW.json`` diffs two such trajectories instead of
benchmarking: shared records whose us_per_call grew beyond ``--tolerance``
(default 0.5 = +50%, CPU CI timings are noisy) print as REGRESSION lines.
Warn-only by default; ``--strict`` exits 1 when regressions exist, and
``--strict-families autotuner,optimizer`` promotes just those record-name
prefixes to CI-failing while the rest stay warn-only (what the CI bench
job runs against the committed baseline).
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np


def _timed(fn, *args, warmup=1, iters=3, **kw):
    for _ in range(warmup):
        fn(*args, **kw)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / iters
    return out, dt * 1e6


def bench_schedule_lowering(log=print):
    """IR -> mesh lowering throughput: emit the §3 Schedule and lower it to
    device permutations (the control-plane cost the executor pays once per
    layout, then caches)."""
    from repro.core.alltoall import schedule
    from repro.dist.mesh import dragonfly_layout
    from repro.runtime.lowering import lower_alltoall

    for n in (16, 64):
        layout = dragonfly_layout(n)
        p = layout.da_params
        low, us = _timed(lambda: lower_alltoall(schedule(p, layout.topo)))
        log(
            f"schedule_lowering,n={n},K={p.K},M={p.M},s={p.s},"
            f"rounds={p.total_rounds},permutes={low.num_permutes},us_per_call={us:.0f}"
        )


def bench_backends(log=print):
    """Backend comparison on the SAME lowered programs: the §3 all-to-all
    replayed by the dragonfly jax_ppermute backend vs the fused XLA op vs
    the pure-NumPy reference backend, and the §2 ``matmul_program`` vs its
    oracles. Device-backed rows appear when the process has ≥16 host
    devices (CI forces XLA_FLAGS=--xla_force_host_platform_device_count=16);
    otherwise they are recorded as skipped so the JSON trajectory stays
    comparable across environments."""
    import jax
    import jax.numpy as jnp

    from repro.core import alltoall as a2a
    from repro.core import matmul as mm
    from repro.core.matmul import gather_blocks, scatter_blocks
    from repro.dist.mesh import dragonfly_layout
    from repro.runtime import compat, lowering
    from repro.runtime.backends.jax_ppermute import JaxPpermuteBackend
    from repro.runtime.backends.reference import NumpyReferenceBackend

    n = 16
    ref = NumpyReferenceBackend()
    layout = dragonfly_layout(n)
    prog = lowering.lower(a2a.schedule(layout.da_params, layout.topo))
    rng = np.random.default_rng(0)
    x = rng.standard_normal((n, n, 64)).astype(np.float32)
    _, us = _timed(lambda: ref.run_alltoall(x, prog))
    log(f"backend_alltoall,backend=reference,n={n},rounds={prog.num_rounds},us_per_call={us:.0f}")

    g = mm.MatmulGrid(2, 2)
    mprog = lowering.lower(mm.schedule(g))
    X = 16
    side = g.n * X
    B = rng.integers(-4, 5, (side, side)).astype(np.float32)
    A = rng.integers(-4, 5, (side, side)).astype(np.float32)
    _, us = _timed(lambda: ref.run_matmul(B, A, mprog))
    log(f"matmul_program,backend=reference,grid=2x2,X={X},rounds={mprog.num_rounds},us_per_call={us:.0f}")
    _, us = _timed(lambda: B @ A)
    log(f"matmul_program,backend=numpy_oracle,grid=2x2,X={X},us_per_call={us:.0f}")

    # pallas_fused backend: global fused replay + interpret-mode kernels on
    # CPU hosts (compiled kernels + RDMA ring on TPU) — no mesh needed
    from repro.runtime.backends.pallas_fused import PallasFusedBackend

    pal = PallasFusedBackend()
    _, us = _timed(lambda: np.asarray(pal.run_alltoall(x, prog)))
    log(f"backend_alltoall,backend=pallas_fused,n={n},rounds={prog.num_rounds},us_per_call={us:.0f}")
    from repro.core import hypercube as hc

    sbh_prog = lowering.lower(hc.allreduce_schedule(layout.sbh))
    xr = rng.standard_normal((n, 64)).astype(np.float32)
    _, us = _timed(lambda: np.asarray(pal.run_allreduce(xr, sbh_prog)))
    log(f"backend_allreduce,backend=pallas_fused,n={n},rounds={sbh_prog.num_rounds},us_per_call={us:.0f}")
    out, us = _timed(lambda: np.asarray(pal.run_matmul(B, A, mprog)))
    np.testing.assert_array_equal(out, B @ A)
    log(f"matmul_program,backend=pallas_fused,grid=2x2,X={X},rounds={mprog.num_rounds},us_per_call={us:.0f}")

    if jax.device_count() < n:
        log(f"backend_alltoall,backend=dragonfly,n={n},skipped=need_{n}_devices")
        log(f"matmul_program,backend=dragonfly,grid=2x2,skipped=need_{n}_devices")
        return
    from jax.sharding import Mesh, PartitionSpec as P

    jaxbe = JaxPpermuteBackend()
    mesh = Mesh(np.array(jax.devices()[:n]), ("df",))
    xj = jnp.asarray(x)
    run_df = jax.jit(compat.shard_map(
        lambda s: jaxbe.alltoall(s[0], "df", prog)[None],
        mesh=mesh, in_specs=P("df"), out_specs=P("df")))
    run_xla = jax.jit(compat.shard_map(
        lambda s: jax.lax.all_to_all(s[0], "df", split_axis=0, concat_axis=0)[None],
        mesh=mesh, in_specs=P("df"), out_specs=P("df")))
    _, us = _timed(lambda: run_df(xj).block_until_ready())
    log(f"backend_alltoall,backend=dragonfly,n={n},rounds={prog.num_rounds},us_per_call={us:.0f}")
    _, us = _timed(lambda: run_xla(xj).block_until_ready())
    log(f"backend_alltoall,backend=fused_xla,n={n},us_per_call={us:.0f}")

    bb = jnp.asarray(scatter_blocks(g, B))
    aa = jnp.asarray(scatter_blocks(g, A))
    run_mm = jax.jit(compat.shard_map(
        lambda p, q: jaxbe.matmul(p[0], q[0], "df", mprog)[None],
        mesh=mesh, in_specs=(P("df"), P("df")), out_specs=P("df")))
    out, us = _timed(lambda: run_mm(bb, aa).block_until_ready())
    np.testing.assert_array_equal(gather_blocks(g, np.asarray(out)), B @ A)
    log(f"matmul_program,backend=dragonfly,grid=2x2,X={X},rounds={mprog.num_rounds},us_per_call={us:.0f}")


def bench_optimizer(log=print):
    """The optimizer pass vs the per-stage replay loop on the SAME lowered
    programs (§3 all-to-all n=16 and the §2 grid-(2,2) matmul):

      * ``ref_loop`` / ``ref_fused``   — host (reference backend) replay:
        per-stage advanced indexing vs one batched table op per group;
      * ``trace_compile_loop`` / ``trace_compile_fused`` — cold jit
        ``lower().compile()`` wall time of the device replay: the per-stage
        loop unrolls one collective chain per stage into the HLO, the fused
        path is one batched scatter / one lax.scan body regardless of
        program length (this is the cost bench_emulation_rewrite showed
        dominating);
      * ``replay_loop`` / ``replay_fused`` — steady-state device replay.

    Loop rows need a 16-device mesh (CI forces it); fused rows replay the
    global array and run anywhere.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import alltoall as a2a
    from repro.core import matmul as mm
    from repro.dist.mesh import dragonfly_layout
    from repro.runtime import lowering
    from repro.runtime import optimize as ropt
    from repro.runtime.backends.jax_ppermute import (
        JaxPpermuteBackend,
        _compiled_collective,
        _compiled_matmul,
    )
    from repro.runtime.backends.reference import NumpyReferenceBackend

    n = 16
    ref = NumpyReferenceBackend()
    jaxbe = JaxPpermuteBackend()
    layout = dragonfly_layout(n)
    prog = lowering.lower(a2a.schedule(layout.da_params, layout.topo))
    o = ropt.optimize(prog)
    rng = np.random.default_rng(0)
    x = rng.standard_normal((n, n, 64)).astype(np.float32)

    _, us = _timed(lambda: ref.run_alltoall(x, prog))
    log(f"optimizer,path=ref_loop,kind=alltoall,n={n},stages={prog.num_permutes},us_per_call={us:.0f}")
    _, us = _timed(lambda: ref.run_alltoall(x, o))
    log(f"optimizer,path=ref_fused,kind=alltoall,n={n},fused_ops={o.num_fused_ops},us_per_call={us:.0f}")

    g = mm.MatmulGrid(2, 2)
    mprog = lowering.lower(mm.schedule(g))
    mo = ropt.optimize(mprog)
    X = 16
    side = g.n * X
    B = rng.integers(-4, 5, (side, side)).astype(np.float32)
    A = rng.integers(-4, 5, (side, side)).astype(np.float32)
    _, us = _timed(lambda: ref.run_matmul(B, A, mprog))
    log(f"optimizer,path=ref_loop,kind=matmul,grid=2x2,X={X},us_per_call={us:.0f}")
    _, us = _timed(lambda: ref.run_matmul(B, A, mo))
    log(f"optimizer,path=ref_fused,kind=matmul,grid=2x2,X={X},us_per_call={us:.0f}")

    # cold trace+compile: __wrapped__ bypasses the closure caches so every
    # call re-traces and re-compiles from scratch
    xj = jnp.asarray(x)
    _, us = _timed(
        lambda: ropt.jax_alltoall.__wrapped__(o).lower(xj).compile(),
        warmup=0, iters=2)
    log(f"optimizer,path=trace_compile_fused,kind=alltoall,n={n},us_per_call={us:.0f}")
    _, us = _timed(
        lambda: jax.jit(ropt.build_jax_matmul(mo)).lower(
            jnp.zeros((mprog.n, X, X), jnp.float32),
            jnp.zeros((mprog.n, X, X), jnp.float32)).compile(),
        warmup=0, iters=2)
    log(f"optimizer,path=trace_compile_fused,kind=matmul,grid=2x2,us_per_call={us:.0f}")
    _, us = _timed(lambda: ropt.jax_alltoall(o)(xj).block_until_ready())
    log(f"optimizer,path=replay_fused,kind=alltoall,n={n},us_per_call={us:.0f}")

    if jax.device_count() < n:
        log(f"optimizer,path=trace_compile_loop,kind=alltoall,n={n},skipped=need_{n}_devices")
        log(f"optimizer,path=trace_compile_loop,kind=matmul,grid=2x2,skipped=need_{n}_devices")
        return
    _, us = _timed(
        lambda: _compiled_collective.__wrapped__(
            jaxbe, prog, "alltoall", "df", None, False).lower(xj).compile(),
        warmup=0, iters=2)
    log(f"optimizer,path=trace_compile_loop,kind=alltoall,n={n},us_per_call={us:.0f}")
    _, us = _timed(
        lambda: _compiled_matmul.__wrapped__(jaxbe, mprog, "df", None).lower(B, A).compile(),
        warmup=0, iters=2)
    log(f"optimizer,path=trace_compile_loop,kind=matmul,grid=2x2,us_per_call={us:.0f}")
    _, us = _timed(lambda: jaxbe.run_alltoall(xj, prog).block_until_ready())
    log(f"optimizer,path=replay_loop,kind=alltoall,n={n},us_per_call={us:.0f}")


def bench_emulation_rewrite(log=print):
    """Guest-on-host rewrite overhead (the elastic-failover hot path):

      * ``native_lowering``  — derive + lower the guest schedule from
        scratch (what recovery used to do);
      * ``rewrite_cold``     — relabel the already-lowered guest program
        through the embedding (what recovery does now), cache cleared;
      * ``rewrite_cached``   — the same call hitting the lru cache (what
        repeated failovers onto one survivor set pay);
      * ``replay_overhead``  — reference-backend replay of the rewritten
        host-sized program vs the native guest program (idle devices cost).
    """
    from repro.core import alltoall as a2a
    from repro.core.topology import D3
    from repro.dist.mesh import DeviceLayout
    from repro.runtime import lowering, rewrite
    from repro.runtime.backends.reference import NumpyReferenceBackend

    ref = NumpyReferenceBackend()
    for (J, L), (K, M) in (((2, 2), (4, 4)), ((4, 4), (4, 8))):
        guest = DeviceLayout(D3(J, L))
        emb = guest.embed_onto(DeviceLayout(D3(K, M)))
        tag = f"guest={J}x{L},host={K}x{M}"

        _, us = _timed(lambda: lowering.lower(a2a.schedule(guest.da_params, guest.topo)))
        log(f"emulation_rewrite,path=native_lowering,{tag},us_per_call={us:.0f}")

        prog = lowering.lower(a2a.schedule(guest.da_params, guest.topo))

        def cold():
            rewrite.emulate.cache_clear()
            return rewrite.emulate(prog, emb)

        hprog, us = _timed(cold)
        log(f"emulation_rewrite,path=rewrite_cold,{tag},"
            f"stages={hprog.num_permutes},us_per_call={us:.0f}")
        _, us = _timed(lambda: rewrite.emulate(prog, emb))
        log(f"emulation_rewrite,path=rewrite_cached,{tag},us_per_call={us:.0f}")

        rng = np.random.default_rng(0)
        xg = rng.standard_normal((prog.n, prog.n, 8)).astype(np.float32)
        xh = rewrite.scatter_guest(xg, hprog, axes=(0, 1))
        _, us = _timed(lambda: ref.run_alltoall(xg, prog))
        log(f"emulation_rewrite,path=replay_native,{tag},us_per_call={us:.0f}")
        _, us = _timed(lambda: ref.run_alltoall(xh, hprog))
        log(f"emulation_rewrite,path=replay_rewritten,{tag},us_per_call={us:.0f}")


def bench_concurrent_guests(log=print):
    """Multi-tenant makespan: two disjoint D3(2,2) guests on one D3(4,4)
    host (``runtime.combine``) vs time-multiplexing them.

      * ``solo_sum`` — the host without a combinator: replay each guest's
        rewritten program in turn (Σ T_i rounds, two replays);
      * ``combined`` — ONE replay of the combined program (max T_i rounds;
        same-stamp perms packed into single partial permutations);
      * ``combined_fused`` — the combined program through ``optimize()``
        (the stacked-σ table now spans both guests).

    Bit-exactness of combined vs solo per guest is asserted in-line, so a
    regression shows up here as a failure rather than a fast wrong row.
    """
    from repro.core.emulation import disjoint_embeddings
    from repro.core.topology import D3
    from repro.dist import collectives as coll
    from repro.dist.mesh import DeviceLayout
    from repro.runtime import combine as cmb
    from repro.runtime.backends.reference import NumpyReferenceBackend

    ref = NumpyReferenceBackend()
    host = D3(4, 4)
    embs = disjoint_embeddings(host, [(2, 2), (2, 2)])
    guest = DeviceLayout(D3(2, 2))
    solos = [coll.alltoall_program(guest, e) for e in embs]
    comb = coll.concurrent_program("alltoall", tuple(embs))
    tag = "guests=2,guest=2x2,host=4x4"

    rng = np.random.default_rng(0)
    xs = [rng.standard_normal((guest.n, guest.n, 16)).astype(np.float32)
          for _ in embs]
    hosts_solo = [cmb.scatter_guests([x], [e], axes=(0, 1))
                  for x, e in zip(xs, embs)]
    xh = cmb.scatter_guests(xs, embs, axes=(0, 1))

    def solo_sum():
        return [ref.run_alltoall(h, p) for h, p in zip(hosts_solo, solos)]

    outs, us = _timed(solo_sum)
    rounds_sum = sum(p.num_rounds for p in solos)
    log(f"concurrent_guests,path=solo_sum,{tag},rounds={rounds_sum},us_per_call={us:.0f}")

    out, us = _timed(lambda: ref.run_alltoall(xh, comb))
    log(f"concurrent_guests,path=combined,{tag},rounds={comb.num_rounds},us_per_call={us:.0f}")
    assert comb.num_rounds < rounds_sum  # the makespan win, in rounds
    for gi, (e, solo_out) in enumerate(zip(embs, outs)):
        np.testing.assert_array_equal(
            cmb.extract_guest(out, e, axes=(0, 1)),
            cmb.extract_guest(solo_out, e, axes=(0, 1)),
        )

    from repro.runtime.optimize import optimize

    opt = optimize(comb)
    fused, us = _timed(lambda: ref.run_alltoall(xh, opt))
    np.testing.assert_array_equal(fused, out)
    log(f"concurrent_guests,path=combined_fused,{tag},rounds={comb.num_rounds},"
        f"fused_ops={opt.num_fused_ops},us_per_call={us:.0f}")


def bench_core_micro(log=print):
    """Schedule-generation throughput (rounds/s) — the control-plane cost
    of the paper's algorithms at pod scale (D3(4,8) = 256 chips)."""
    from repro.core.alltoall import DAParams, rounds
    from repro.core.broadcast import m_broadcast
    from repro.core.topology import D3

    p = DAParams(4, 8, 4)
    _, us = _timed(lambda: sum(1 for _ in rounds(p)))
    log(f"micro_a2a_schedule,K=4,M=8,s=4,rounds={p.total_rounds},us_per_call={us:.0f}")

    t = D3(4, 8)
    _, us = _timed(lambda: m_broadcast(t, (0, 0, 0)))
    log(f"micro_m_broadcast_schedule,K=4,M=8,us_per_call={us:.0f}")


def bench_kernels(log=print):
    """Pallas kernels (interpret) + the XLA flash path, vs oracles."""
    import jax.numpy as jnp
    from repro.kernels.block_matmul.block_matmul import block_matmul
    from repro.kernels.flash_attention.xla_flash import flash_attention_xla

    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((256, 256)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((256, 256)), jnp.float32)
    out, us = _timed(
        lambda: block_matmul(a, b, bm=128, bn=128, bk=128, interpret=True).block_until_ready()
    )
    log(f"kernel_block_matmul_interp,shape=256x256x256,us_per_call={us:.0f}")

    q = jnp.asarray(rng.standard_normal((2, 4, 512, 64)), jnp.float32)
    out, us = _timed(
        lambda: flash_attention_xla(q, q, q, causal=True).block_until_ready()
    )
    log(f"kernel_flash_xla,shape=(2,4,512,64),us_per_call={us:.0f}")


def bench_train_smoke(log=print):
    """End-to-end train-step latency on the CPU-scale config (the
    framework's hot loop: loss+grads+AdamW, jitted)."""
    import jax
    from repro.configs import get_smoke_config
    from repro.train.optimizer import OptConfig
    from repro.train.train_step import TrainSettings, make_train_step, init_train_state
    from repro.train.data import DataState, SyntheticLM

    cfg = get_smoke_config("tinyllama-1.1b")
    opt = OptConfig(total_steps=100)
    settings = TrainSettings(use_kernel=False, remat=False)
    params, opt_state = init_train_state(jax.random.key(0), cfg, opt, settings)
    step = jax.jit(make_train_step(cfg, opt, settings))
    data = SyntheticLM(DataState(seed=0, batch=4, seq=32, vocab=cfg.vocab))
    batch = {k: jax.numpy.asarray(v) for k, v in data.next_batch().items()}
    params, opt_state, metrics = step(params, opt_state, batch)  # compile

    def one():
        p, o, m = step(params, opt_state, batch)
        jax.block_until_ready(m["loss"])
        return m

    m, us = _timed(one)
    log(f"train_step_smoke,arch=tinyllama-smoke,B=4,S=32,us_per_call={us:.0f},loss={float(m['loss']):.3f}")


def bench_autotuner(log=print):
    """Price-driven autotuner (runtime/autotune.py): the decision table the
    tuner produces for a spread of call-site keys, plus fresh per-strategy
    timings with their measured-vs-analytic error.

    Rows:
      * ``autotuner_decision`` — one per key: chosen strategy, decision
        source (measured / cache / analytic), the schedule's priced rounds
        and hops, predicted µs;
      * ``autotuner_strategy`` — one per runnable candidate: fresh measured
        µs, the analytic seed price, and err_ratio = measured / analytic
        (how well the seed model ranks without calibration).

    The acceptance bound is asserted in-line: the chosen strategy's fresh
    timing is never slower than the worst fixed candidate (with 10% timer
    slack), so a mis-ranking tuner fails the bench instead of logging a
    plausible-looking row. Decisions use the default on-disk cache
    (benchmarks/autotune_cache.json) — the CI artifact next to the BENCH
    trajectory."""
    from repro.runtime import autotune as at

    tuner = at.Autotuner()
    sites = [
        ("alltoall", 16, 256, "host", None),
        ("alltoall", 16, 256, "global", None),
        ("allreduce", 16, 256, "global", None),
        ("broadcast", 16, 256, "global", None),
        ("alltoall", 16, 256, "shard", None),
        ("alltoall", 16, 1 << 16, "global", None),  # large messages rerank
        ("matmul", 16, 16 * 16 * 4, "global", (2, 2)),
    ]
    for kind, n, nbytes, site, grid in sites:
        layout = at.layout_for(n)
        dec = tuner.decide(kind, layout, nbytes, site=site, grid=grid)
        log(
            f"autotuner_decision,kind={kind},site={site},n={n},b={dec.key.nbytes},"
            f"strategy={dec.strategy},source={dec.source},rounds={dec.rounds},"
            f"hops={dec.hops:.0f},us_per_call={dec.predicted_us:.0f}"
        )
        times: dict[str, float] = {}
        for s in at.candidates(kind, site):
            try:
                fn = at._measure_closure(kind, site, s, layout, grid,
                                         dec.key.nbytes, dec.key.dtype)
            except Exception:
                fn = None
            if fn is None:
                log(f"autotuner_strategy,kind={kind},site={site},n={n},"
                    f"b={dec.key.nbytes},strategy={s},skipped=unrunnable_here")
                continue
            us = at._time_us(fn)
            times[s] = us
            err = us / max(dec.analytic_us.get(s, us), 1e-9)
            log(
                f"autotuner_strategy,kind={kind},site={site},n={n},"
                f"b={dec.key.nbytes},strategy={s},chosen={int(s == dec.strategy)},"
                f"analytic_us={dec.analytic_us.get(s, 0):.0f},err_ratio={err:.2f},"
                f"us_per_call={us:.0f}"
            )
        if dec.strategy in times and len(times) > 1:
            worst = max(times.values())
            assert times[dec.strategy] <= worst * 1.10, (
                f"tuner picked {dec.strategy} ({times[dec.strategy]:.0f}us) but the "
                f"worst fixed strategy costs {worst:.0f}us — ranking inverted: {times}"
            )
    tuner.save()


def bench_export(log=print):
    """Collective compiler export (runtime/export.py): compile the §2–§5
    programs at n=16 into versioned per-device send/recv traces, re-prove
    them (structure, link conflict-freedom, send/recv pairing), JSON
    round-trip them, and replay the traces through the ``sendrecv``
    interpreter — asserted bit-identical to the reference backend in-line,
    so a drifting exporter fails the bench instead of logging a row.

    Rows (family ``export``):
      * ``export_compile``   — cold export (lru cache cleared inside the
        timed closure) with the trace's group/op/send/wave counts;
      * ``export_validate``  — the static validator on the exported form;
      * ``export_roundtrip`` — ``to_json`` + ``from_json`` (lossless),
        with the serialized byte size;
      * ``export_replay``    — the NumPy trace interpreter executing the
        trace (the ``sendrecv`` backend's hot path).
    """
    from repro.core.topology import D3
    from repro.dist import collectives as coll
    from repro.dist.mesh import DeviceLayout
    from repro.runtime import export as rexport
    from repro.runtime.backends.reference import NumpyReferenceBackend
    from repro.runtime.backends.sendrecv import SendRecvBackend

    layout = DeviceLayout(D3(4, 2))  # n=16, power-of-two SBH
    progs = [
        ("alltoall", coll.alltoall_program(layout)),
        ("alltoall_pipe1", coll.alltoall_program(layout, pipelined=1)),
        ("allreduce", coll.allreduce_program(layout)),
        ("broadcast", coll.broadcast_program(layout, 0)),
        ("matmul", coll.matmul_program(2, 2)),
    ]
    rng = np.random.default_rng(0)
    sr, ref = SendRecvBackend(), NumpyReferenceBackend()
    for name, prog in progs:
        def cold_export():
            rexport._export.cache_clear()
            return rexport.export(prog)

        trace, us = _timed(cold_export)
        log(
            f"export_compile,kind={name},n={prog.n},groups={trace.num_groups},"
            f"ops={trace.num_ops},sends={trace.num_sends},"
            f"waves={len(trace.waves())},us_per_call={us:.0f}"
        )
        _, us = _timed(lambda: rexport.validate(trace))
        log(f"export_validate,kind={name},n={prog.n},ops={trace.num_ops},"
            f"us_per_call={us:.0f}")
        text = trace.to_json()
        back, us = _timed(lambda: rexport.DeviceTrace.from_json(trace.to_json()))
        assert back == trace, f"{name}: JSON round-trip not lossless"
        log(f"export_roundtrip,kind={name},n={prog.n},bytes={len(text)},"
            f"us_per_call={us:.0f}")
        if prog.kind == "alltoall":
            x = rng.integers(-4, 5, (prog.n, prog.n, 4)).astype(np.float32)
            out, us = _timed(sr.run_alltoall, x, prog)
            ok = np.array_equal(out, ref.run_alltoall(x, prog))
        elif prog.kind == "allreduce":
            x = rng.integers(-4, 5, (prog.n, 8)).astype(np.float32)
            out, us = _timed(sr.run_allreduce, x, prog)
            ok = np.array_equal(out, ref.run_allreduce(x, prog))
        elif prog.kind == "broadcast":
            x = rng.integers(-4, 5, (prog.n, 8)).astype(np.float32)
            out, us = _timed(sr.run_broadcast, x, prog)
            ok = np.array_equal(out, ref.run_broadcast(x, prog))
        else:  # matmul: N=4 grid of 2x2 blocks -> 8x8 operands
            side = 4 * 2
            B = rng.integers(-4, 5, (side, side)).astype(np.float32)
            A = rng.integers(-4, 5, (side, side)).astype(np.float32)
            out, us = _timed(sr.run_matmul, B, A, prog)
            ok = np.array_equal(out, ref.run_matmul(B, A, prog))
        assert ok, f"{name}: sendrecv replay diverged from reference"
        log(f"export_replay,kind={name},n={prog.n},backend=sendrecv,"
            f"us_per_call={us:.0f}")


def bench_moe_pipeline(log=print):
    """Pipelined shard-path dispatch (§3 Schedules 1–3 overlapped with
    expert compute): the MoE-shaped dispatch+FFN+combine round trip on the
    16-device D3(4,2) mesh, per execution path —

      * ``reference``     — host NumPy ground truth (untimed oracle);
      * ``loop``          — per-stage ppermute dispatch, one batched FFN
        over all arrivals, per-stage combine (the sequential baseline);
      * ``xla``           — ``lax.all_to_all`` dispatch/combine around the
        same batched FFN;
      * ``overlap_fused`` — ``alltoall_compute`` on the pipelined program:
        each wave's ppermutes issue while the previous wave's arrivals
        drain through the FFN and return over the inverse pairs.

    Shapes mirror the EP hot path (E_loc=2, C_loc=32, d=64, f=128 silu-
    gated FFN). Bit-exactness vs the reference is asserted in-line for
    every path, as is the tentpole's acceptance bound: overlap_fused
    strictly beats the sequential loop. ``moe_pipeline_decision`` rows
    record what the autotuner picks for the matching compute-keyed shard
    sites (native 16-device, small 8-device, and an emulated site where
    the fused-XLA candidate is excluded) — at least one must select
    overlap_fused, also asserted in-line."""
    import jax
    import jax.numpy as jnp

    from repro.runtime import autotune as at

    n, E_loc, C_loc, d, f = 16, 2, 32, 64, 128
    tag = f"n={n},E_loc={E_loc},C_loc={C_loc},d={d},f={f}"
    if jax.device_count() < n:
        for path in ("loop", "xla", "overlap_fused"):
            log(f"moe_pipeline,path={path},{tag},skipped=need_{n}_devices")
        return
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.dist.collectives import alltoall_program
    from repro.dist.mesh import dragonfly_layout
    from repro.runtime import compat
    from repro.runtime.backends.jax_ppermute import JaxPpermuteBackend
    from repro.runtime.backends.reference import NumpyReferenceBackend

    layout = dragonfly_layout(n)
    pipe = alltoall_program(layout, pipelined=1)
    barrier = alltoall_program(layout)
    rng = np.random.default_rng(0)
    x = rng.standard_normal((n, n, E_loc, C_loc, d)).astype(np.float32)
    WG = jnp.asarray(rng.standard_normal((d, f)).astype(np.float32) * 0.05)
    WI = jnp.asarray(rng.standard_normal((d, f)).astype(np.float32) * 0.05)
    WO = jnp.asarray(rng.standard_normal((f, d)).astype(np.float32) * 0.05)

    def ffn(chunks):
        g = jax.nn.silu(chunks @ WG) * (chunks @ WI)
        return g @ WO

    ref = NumpyReferenceBackend()
    want = ref.run_alltoall_compute(
        x.copy(), pipe, lambda j, c: np.asarray(ffn(jnp.asarray(c))))
    log(f"moe_pipeline,path=reference,{tag},oracle=1")

    mesh = Mesh(np.array(jax.devices()[:n]), ("df",))
    sm = lambda body: jax.jit(compat.shard_map(
        body, mesh=mesh, in_specs=P("df"), out_specs=P("df")))
    be_loop = JaxPpermuteBackend()
    be_of = JaxPpermuteBackend(overlap_fused=True)
    runners = {
        "loop": sm(lambda s: be_loop.alltoall(
            ffn(be_loop.alltoall(s[0], "df", barrier)), "df", barrier)[None]),
        "xla": sm(lambda s: jax.lax.all_to_all(
            ffn(jax.lax.all_to_all(s[0], "df", split_axis=0, concat_axis=0)),
            "df", split_axis=0, concat_axis=0)[None]),
        "overlap_fused": sm(
            lambda s: be_of.alltoall_compute(s[0], "df", pipe, ffn)[None]),
    }
    times: dict[str, float] = {}
    for path, fn in runners.items():
        out, us = _timed(lambda: jax.block_until_ready(fn(x)), iters=5)
        np.testing.assert_array_equal(np.asarray(out), want)
        times[path] = us
        log(f"moe_pipeline,path={path},{tag},waves={pipe.num_rounds},"
            f"us_per_call={us:.0f}")
    assert times["overlap_fused"] < times["loop"], (
        f"pipelining lost to the sequential loop: {times}")

    # what the tuner records for the matching compute-keyed shard sites
    # (default on-disk cache, the CI artifact next to the BENCH trajectory)
    tuner = at.Autotuner()
    chunk = E_loc * C_loc * d * 4
    sites = [
        (layout, chunk, at.moe_compute_us(E_loc, C_loc, n, d, f), False),
        (at.layout_for(8), chunk, 2000, False),
        (layout, chunk, at.moe_compute_us(E_loc, C_loc, n, d, f), True),
    ]
    chosen = []
    for lay, nbytes, cus, emulated in sites:
        dec = tuner.decide("alltoall", lay, nbytes, site="shard",
                           emulated=emulated, compute_us=cus)
        chosen.append(dec.strategy)
        log(f"moe_pipeline_decision,site=shard,K={lay.topo.K},M={lay.topo.M},"
            f"b={dec.key.nbytes},c={dec.key.compute_us},emulated={int(emulated)},"
            f"strategy={dec.strategy},source={dec.source},"
            f"us_per_call={dec.predicted_us:.0f}")
    assert "overlap_fused" in chosen, (
        f"no compute-keyed shard site selected overlap_fused: {chosen}")
    tuner.save()


def bench_multitenant_serving(log=print):
    """Multi-tenant serving: two mixtral-smoke tenants decode through ONE
    combined host program per MoE boundary round vs the time-multiplexed
    control (same tenants, one solo pipelined replay each). Runs on the
    jax ppermute backend (8 of the forced host devices) where replayed
    rounds cost real wall-clock, so the deterministic round-count win
    (combined rounds = max over guests, muxed = sum) shows up directly as
    serving throughput.

    Asserted in-line: every tenant's tokens are bit-exact against a
    single-tenant fleet through the same replay path (both arms), and the
    combined fleet's per-token latency strictly beats time-muxed (min over
    3 fresh-fleet episodes). ``multitenant_serving_decision`` records the
    autotuner's combined-site pick for this guest set, keyed on the
    guest-set signature."""
    import time as _time

    import jax

    from repro.configs import get_smoke_config
    from repro.models import model as M
    from repro.runtime.autotune import Autotuner
    from repro.serve.fleet import TenantFleet

    tag = "tenants=2,host=2x2,guest=1x2,arch=mixtral-smoke"
    if jax.device_count() < 8:
        for path in ("combined", "time_mux"):
            log(f"multitenant_serving,path={path},{tag},skipped=need_8_devices")
        return

    cfg = get_smoke_config("mixtral-8x7b")
    params = [M.init_params(jax.random.key(i), cfg) for i in range(2)]
    prompts = [[5, 6, 7], [9, 10]]
    n_new = 6

    def episode(combined, idxs=(0, 1)):
        fleet = TenantFleet((2, 2), backend="jax", max_seq=32,
                            combined=combined)
        reqs = [
            fleet.submit(
                fleet.admit_model(cfg, params[i], guest=(1, 2), slots=2),
                prompts[i], n_new)
            for i in idxs
        ]
        t0 = _time.perf_counter()
        fleet.run_to_completion()
        dt = _time.perf_counter() - t0
        assert all(r.done for r in reqs)
        return fleet, [r.out for r in reqs], dt

    solo = [episode(True, idxs=(i,))[1][0] for i in range(2)]
    best: dict[str, tuple] = {}
    for path, combined in (("combined", True), ("time_mux", False)):
        episode(combined)  # warm the lru-cached program combine/lowering
        fleet, dt = None, float("inf")
        for _ in range(3):
            f, outs, d = episode(combined)
            assert outs == solo, (
                f"{path} fleet not bit-exact vs solo: {outs} != {solo}")
            if d < dt:
                fleet, dt = f, d
        us_tok = dt * 1e6 / fleet.tokens_out
        best[path] = (fleet, us_tok)
        log(f"multitenant_serving,path={path},{tag},replays={fleet.replays},"
            f"rounds={fleet.rounds_replayed},tokens={fleet.tokens_out},"
            f"us_per_call={us_tok:.0f}")
    comb, mux = best["combined"], best["time_mux"]
    assert comb[0].rounds_replayed < mux[0].rounds_replayed, (
        comb[0].rounds_replayed, mux[0].rounds_replayed)
    assert comb[1] < mux[1], (
        f"combined fleet lost to time-mux: {comb[1]:.0f}us/token "
        f"vs {mux[1]:.0f}us/token")
    print(f"# combined serves {1e6 / comb[1]:.0f} tok/s vs "
          f"{1e6 / mux[1]:.0f} tok/s time-muxed "
          f"({mux[1] / comb[1]:.2f}x)")

    # the combined-site decision for this guest set (analytic mode keeps
    # the recorded strategy deterministic across hosts)
    rep = comb[0].collective_report(tuner=Autotuner(mode="analytic"))
    assert rep["status"] == "ok", rep
    assert rep["combined_rounds"] < rep["time_mux_rounds"], rep
    log(f"multitenant_serving_decision,{tag},"
        f"combined_rounds={rep['combined_rounds']},"
        f"time_mux_rounds={rep['time_mux_rounds']},"
        f"strategy={rep['strategy']},source={rep['source']},"
        f"us_per_call={rep['analytic_us'][rep['strategy']]:.0f}")


def bench_elastic_failover(log=print):
    """Elastic training failover: the detection -> resume wall time and
    the §5 redistribution broadcast's round count for every stage of a
    twice-cascading failure on a D3(2,2) training run (shrinks (1,2) ->
    (2,1), the second stage reachable only through the mixed
    cabinet×position survivor search), plus the one-off prepare cost of
    lowering the full fallback-shape library.

    Asserted in-line: every failover is rewrite-only (zero schedule
    derivations) and the elastic loss curve is continuous — it matches an
    uninterrupted same-seed run at equal data-state."""
    import tempfile

    from repro.configs import get_smoke_config
    from repro.core.topology import D3
    from repro.dist.mesh import DeviceLayout
    from repro.train.elastic import (
        ElasticTrainer, FaultInjector, max_loss_divergence)
    from repro.train.fault_tolerance import ClusterState
    from repro.train.optimizer import OptConfig
    from repro.train.train_step import TrainSettings

    tag = "host=2x2,arch=tinyllama-smoke"
    steps = 10

    t0 = time.perf_counter()
    cs = ClusterState(DeviceLayout(D3(2, 2)))
    cs.prepare_fallbacks()
    prep_us = (time.perf_counter() - t0) * 1e6
    log(f"elastic_failover,phase=prepare,{tag},shapes={len(cs.library)},"
        f"us_per_call={prep_us:.0f}")

    cfg = get_smoke_config("tinyllama-1.1b")
    opt_cfg = OptConfig(lr=3e-3, warmup_steps=2, total_steps=steps)
    settings = TrainSettings(use_kernel=False, remat=False)
    kw = dict(host=D3(2, 2), batch=4, seq=16, seed=0, ckpt_every=2)

    with tempfile.TemporaryDirectory() as d:
        baseline = ElasticTrainer(
            cfg, opt_cfg, settings, ckpt_dir=d, **kw).run(steps)
    with tempfile.TemporaryDirectory() as d:
        el = ElasticTrainer(
            cfg, opt_cfg, settings, ckpt_dir=d,
            injector=FaultInjector({3: [1], 7: [4]}), **kw)
        losses = el.run(steps)

    div = max_loss_divergence(baseline, losses)
    assert div < 1e-4, f"post-failover loss curve diverged: {div}"
    assert [e.absorbed for e in el.events] == [False, False], el.events
    for i, ev in enumerate(el.events):
        assert ev.derivations == 0, ev    # rewrite-only failover
        log(f"elastic_failover,phase=failover,stage={i},"
            f"shape={ev.shape[0]}x{ev.shape[1]},{tag},"
            f"survivors={len(ev.survivors)},rounds={ev.broadcast_rounds},"
            f"bytes={ev.bytes_redistributed},"
            f"us_per_call={ev.wall_s * 1e6:.0f}")
    print(f"# elastic: {len(el.events)} cascaded failovers survived, "
          f"loss divergence {div:.1e}")


# ------------------------------------------------------- trajectory compare
#: param keys excluded from record identity when diffing trajectories —
#: they vary run to run (timing noise, cache state) without the record
#: meaning a different measurement
_VOLATILE_PARAMS = {"err_ratio", "loss", "source", "chosen", "analytic_us",
                    "skipped", "hops"}


def _record_key(rec: dict) -> str:
    items = sorted(
        (k, v) for k, v in rec.get("params", {}).items()
        if k not in _VOLATILE_PARAMS
    )
    return rec["name"] + "|" + ",".join(f"{k}={v}" for k, v in items)


def compare(old_path: str, new_path: str, tolerance: float = 0.5,
            log=print, strict_families: tuple[str, ...] = ()) -> tuple[int, int]:
    """Diff two ``--json`` trajectories; returns (regressions, strict).

    A shared record regresses when its us_per_call grew beyond
    ``1 + tolerance``; symmetric improvements and added/removed records are
    reported informationally. Records without timings (skipped rows,
    structural records) are ignored. ``strict_families`` are record-name
    prefixes (e.g. ``("autotuner", "optimizer")``) whose regressions count
    toward the second, CI-failing total even in warn-only mode — the
    families whose timings have soaked enough to be load-bearing."""
    with open(old_path) as f:
        old = {_record_key(r): r for r in json.load(f)}
    with open(new_path) as f:
        new = {_record_key(r): r for r in json.load(f)}
    shared = sorted(set(old) & set(new))
    regressions = strict = 0
    for key in shared:
        o, nrec = old[key], new[key]
        if "us_per_call" not in o or "us_per_call" not in nrec:
            continue
        ou, nu = float(o["us_per_call"]), float(nrec["us_per_call"])
        if ou <= 0:
            continue
        ratio = nu / ou
        if ratio > 1 + tolerance:
            regressions += 1
            in_family = any(nrec["name"].startswith(f) for f in strict_families)
            strict += in_family
            sev = "REGRESSION(strict)" if in_family else "REGRESSION"
            log(f"{sev} {key}: {ou:.0f}us -> {nu:.0f}us "
                f"({ratio:.2f}x > {1 + tolerance:.2f}x tolerance)")
        elif ratio < 1 / (1 + tolerance):
            log(f"improved   {key}: {ou:.0f}us -> {nu:.0f}us ({ratio:.2f}x)")
    for key in sorted(set(new) - set(old)):
        log(f"added      {key}")
    for key in sorted(set(old) - set(new)):
        log(f"removed    {key}")
    log(f"# compared {len(shared)} shared records; "
        f"{regressions} regression(s) beyond +{tolerance:.0%}"
        + (f", {strict} in strict families" if strict_families else ""))
    return regressions, strict


def _parse_record(line: str) -> dict | None:
    """``name,k=v,...`` -> {name, params, us_per_call?, rounds?}."""
    parts = line.strip().split(",")
    if not parts or not parts[0] or "=" in parts[0]:
        return None
    rec: dict = {"name": parts[0], "params": {}}
    for kv in parts[1:]:
        if "=" not in kv:
            continue
        k, v = kv.split("=", 1)
        try:
            val: object = int(v)
        except ValueError:
            try:
                val = float(v)
            except ValueError:
                val = v
        if k in ("us_per_call", "rounds"):
            rec[k] = val
        else:
            rec["params"][k] = val
    return rec


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write machine-readable records to PATH")
    ap.add_argument("--compare", nargs=2, metavar=("OLD", "NEW"), default=None,
                    help="diff two --json trajectories instead of benchmarking")
    ap.add_argument("--tolerance", type=float, default=0.5,
                    help="relative us_per_call growth before a shared record "
                         "counts as a regression (default 0.5 = +50%%)")
    ap.add_argument("--strict", action="store_true",
                    help="with --compare: exit 1 when regressions exist "
                         "(default is warn-only)")
    ap.add_argument("--strict-families", metavar="PREFIXES", default="",
                    help="with --compare: comma-separated record-name "
                         "prefixes (e.g. autotuner,optimizer) whose "
                         "regressions exit 1 even without --strict")
    args = ap.parse_args(argv)

    if args.compare:
        fams = tuple(f for f in args.strict_families.split(",") if f)
        n_reg, n_strict = compare(*args.compare, tolerance=args.tolerance,
                                  strict_families=fams)
        if (args.strict and n_reg) or n_strict:
            raise SystemExit(1)
        return

    if args.json:  # fail fast before minutes of benchmarking
        with open(args.json, "a"):
            pass

    records: list[dict] = []

    def log(line):
        print(line)
        rec = _parse_record(str(line))
        if rec is not None:
            records.append(rec)

    from benchmarks import bench_matmul, bench_alltoall, bench_hypercube, bench_broadcast

    print("# ---- paper §2: matrix product on D3(K²,M)")
    bench_matmul.run(log)
    print("# ---- paper §3: doubly-parallel all-to-all")
    bench_alltoall.run(log)
    print("# ---- paper §4: SBH hypercube emulation")
    bench_hypercube.run(log)
    print("# ---- paper §5: broadcast spanning trees")
    bench_broadcast.run(log)
    print("# ---- runtime micro-benchmarks")
    bench_schedule_lowering(log)
    print("# ---- runtime backends (dragonfly vs fused XLA vs reference vs pallas)")
    bench_backends(log)
    print("# ---- optimizer pass (fused table replay vs per-stage loop)")
    bench_optimizer(log)
    print("# ---- emulation rewrite (guest-on-host vs native lowering)")
    bench_emulation_rewrite(log)
    print("# ---- concurrent guests (combined multiplex vs time-multiplex)")
    bench_concurrent_guests(log)
    print("# ---- price-driven autotuner (decision table + strategy timings)")
    bench_autotuner(log)
    print("# ---- collective compiler export (send/recv traces + trace replay)")
    bench_export(log)
    print("# ---- pipelined shard-path dispatch (waves overlapped with expert FFN)")
    bench_moe_pipeline(log)
    print("# ---- multi-tenant serving (combined fleet vs time-multiplexed)")
    bench_multitenant_serving(log)
    print("# ---- elastic failover (rewrite-only recovery + §5 re-shard)")
    bench_elastic_failover(log)
    bench_core_micro(log)
    bench_kernels(log)
    bench_train_smoke(log)

    if args.json:
        with open(args.json, "w") as f:
            json.dump(records, f, indent=1)
        print(f"# wrote {len(records)} records to {args.json}")


if __name__ == "__main__":
    main()
