"""Tiled MXU matmul Pallas kernel — the "off-and-on" local product of the
D3(K², M) distributed matmul (§2, Theorem 2's X×X block product).

TPU adaptation: the paper's per-router block product maps to an MXU-tiled
kernel. BlockSpecs stage (bm, bk) × (bk, bn) operand tiles HBM→VMEM; the
grid is (M/bm, N/bn, K/bk) with the contraction dimension innermost
(ARBITRARY semantics) accumulating into a VMEM scratch tile in fp32,
flushed to the output tile on the last k-step. Tile sides are multiples
of the MXU's 128-lane systolic shape.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# pallas renamed TPUCompilerParams -> CompilerParams across jax releases
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams



def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    """One (i, j, k) grid step: acc += A[i,k] @ B[k,j]; flush at k == n_k-1."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret", "out_dtype")
)
def block_matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int = 256,
    bn: int = 256,
    bk: int = 512,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """C = A @ B with explicit VMEM tiling.

    Default tiles: (256, 512) A-tile + (512, 256) B-tile + (256, 256) fp32
    acc = 256·512·2·2 + 256·256·4 ≈ 0.8 MB in VMEM (bf16 operands) — well
    inside the ~16 MB/core budget with double buffering, and every matmul
    dim is a multiple of the 128-wide MXU.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, ((m, n, k), (bm, bn, bk))
    if out_dtype is None:
        out_dtype = a.dtype
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=(pltpu.PARALLEL, pltpu.PARALLEL, pltpu.ARBITRARY),
        ),
        interpret=interpret,
    )(a, b)
