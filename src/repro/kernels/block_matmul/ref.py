"""Pure-jnp oracle for the block matmul kernel."""

import jax
import jax.numpy as jnp


@jax.jit
def block_matmul_ref(a: jax.Array, b: jax.Array, out_dtype=None) -> jax.Array:
    if out_dtype is None:
        out_dtype = a.dtype
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(out_dtype)
