"""Public entry point: picks the Pallas kernel on TPU, interpret mode on
CPU (tests), with the pure-jnp oracle available for fallback/validation."""

import jax

from repro.kernels.block_matmul.block_matmul import block_matmul
from repro.kernels.block_matmul.ref import block_matmul_ref


def matmul(a: jax.Array, b: jax.Array, **kw) -> jax.Array:
    """Dispatch: real kernel on TPU; interpret=True elsewhere (correctness
    path — the kernel body runs in Python on CPU)."""
    on_tpu = jax.default_backend() == "tpu"
    return block_matmul(a, b, interpret=not on_tpu, **kw)


__all__ = ["matmul", "block_matmul", "block_matmul_ref"]
