"""Public entry point: picks the Pallas kernel on TPU, interpret mode on
CPU (tests), with the pure-jnp oracle available for fallback/validation."""

import jax

from repro.kernels.block_matmul.block_matmul import block_matmul
from repro.kernels.block_matmul.ref import block_matmul_ref


def matmul(a: jax.Array, b: jax.Array, **kw) -> jax.Array:
    """Dispatch: real kernel on TPU; interpret=True elsewhere (correctness
    path — the kernel body runs in Python on CPU)."""
    on_tpu = jax.default_backend() == "tpu"
    return block_matmul(a, b, interpret=not on_tpu, **kw)


def batched_matmul(a: jax.Array, b: jax.Array, *, interpret: bool | None = None,
                   **kw) -> jax.Array:
    """Batched block product ``(n, X, X) @ (n, X, X) -> (n, X, X)`` through
    the Pallas kernel, vmapped over the leading (router-block) axis — the
    §2 off-network ``mul_a`` contraction of the program executor.
    ``interpret=None`` auto-selects like ``matmul``."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return jax.vmap(
        lambda p, q: block_matmul(p, q, interpret=interpret, **kw)
    )(a, b)


__all__ = ["matmul", "batched_matmul", "block_matmul", "block_matmul_ref"]
