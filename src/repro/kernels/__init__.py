"""Pallas TPU kernels for the compute hot-spots: the §2 local block product
(block_matmul) and tiled attention (flash_attention). Each kernel ships a
pure-jnp oracle (ref.py) and is validated in interpret mode on CPU."""

from repro.kernels.block_matmul.ops import matmul as block_matmul_op
from repro.kernels.flash_attention.ops import gqa_attention

__all__ = ["block_matmul_op", "gqa_attention"]
