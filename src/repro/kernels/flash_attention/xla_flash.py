"""Chunked online-softmax attention in pure XLA (nested lax.scan).

Same recurrence as the Pallas kernel but expressed as loops XLA compiles
on any backend — the fallback used when the Mosaic kernel is unavailable
(CPU dry-run) and the memory-bounded path for giant sequence lengths:
peak score tile is (B, H, bq, bk) instead of (B, H, Sq, Sk).

Operates on the 4-D (B, H, S, D) layout so batch/head shardings propagate
through the loop (flattening B·H forces an SPMD resharding — see
EXPERIMENTS.md §Perf iteration 0).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


NEG_INF = -1e30


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "scale", "bq", "bk")
)
def flash_attention_xla(
    q: jax.Array,  # (B, H, Sq, D)
    k: jax.Array,  # (B, H, Sk, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    bq: int = 512,
    bk: int = 1024,
) -> jax.Array:
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, ((Sq, Sk), (bq, bk))
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    nq, nk = Sq // bq, Sk // bk

    kc = jnp.moveaxis(k.reshape(B, H, nk, bk, D), 2, 0)  # (nk, B, H, bk, D)
    vc = jnp.moveaxis(v.reshape(B, H, nk, bk, D), 2, 0)

    def q_block(qi, q_tile):
        # q_tile: (B, H, bq, D)
        q_pos = qi * bq + jnp.arange(bq)

        def kv_step(carry, inp):
            m, l, acc = carry
            ki, k_tile, v_tile = inp
            s = jnp.einsum(
                "bhqd,bhkd->bhqk",
                q_tile.astype(jnp.float32),
                k_tile.astype(jnp.float32),
            ) * scale
            k_pos = ki * bk + jnp.arange(bk)
            mask = jnp.ones((bq, bk), bool)
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if window is not None:
                mask &= (q_pos[:, None] - k_pos[None, :]) < window
            s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p, v_tile.astype(jnp.float32)
            )
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((B, H, bq), NEG_INF, jnp.float32),
            jnp.zeros((B, H, bq), jnp.float32),
            jnp.zeros((B, H, bq, D), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(kv_step, init, (jnp.arange(nk), kc, vc))
        l = jnp.where(l == 0.0, 1.0, l)
        return (acc / l[..., None]).astype(q.dtype)

    qc = jnp.moveaxis(q.reshape(B, H, nq, bq, D), 2, 0)  # (nq, B, H, bq, D)
    out = jax.lax.map(lambda args: q_block(*args), (jnp.arange(nq), qc))
    return jnp.moveaxis(out, 0, 2).reshape(B, H, Sq, D)
