"""Flash attention Pallas kernel (TPU): online-softmax tiled attention.

Used by the prefill/train paths (the dominant compute of the 32k-prefill
shapes). Supports causal masking, sliding-window attention (mixtral) and
GQA via q-head grouping done by the wrapper (ops.py) — the kernel itself
sees one KV head per q-block.

Layout: q (B*H, Sq, D), k/v (B*H, Sk, D). Grid (B*H, Sq/bq); the kernel
loop walks kv tiles of size bk with running max/denominator (the
standard flash recurrence), skipping fully-masked tiles (causal upper
triangle / outside the sliding window) via the grid mask, all in VMEM:
q tile (bq, D) + k/v tiles (bk, D) + acc (bq, D) — a few hundred KB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# pallas renamed TPUCompilerParams -> CompilerParams across jax releases
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams



NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, bq: int, bk: int, n_k: int, causal: bool, window: int | None, scale: float,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]  # (bq, D)
    k = k_ref[0]  # (bk, D)
    v = v_ref[0]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (bq, bk)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]  # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)  # (bq, bk)
    alpha = jnp.exp(m_prev - m_new)  # (bq, 1)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_scr[...] = m_new

    @pl.when(ki == n_k - 1)
    def _flush():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows -> zeros
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "bq", "bk", "interpret", "scale"),
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    bq: int = 256,
    bk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """q: (BH, Sq, D), k/v: (BH, Sk, D) -> (BH, Sq, D)."""
    BH, Sq, D = q.shape
    _, Sk, _ = k.shape
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, ((Sq, Sk), (bq, bk))
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    n_k = Sk // bk
    grid = (BH, Sq // bq, n_k)
    return pl.pallas_call(
        functools.partial(
            _flash_kernel, bq=bq, bk=bk, n_k=n_k, causal=causal,
            window=window, scale=scale,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=(pltpu.PARALLEL, pltpu.PARALLEL, pltpu.ARBITRARY),
        ),
        interpret=interpret,
    )(q, k, v)
