"""Public GQA attention entry: handles (B, S, H, D) layouts, KV-head
grouping, and implementation dispatch:

    impl="pallas" — the Mosaic TPU kernel (interpret=True on CPU tests)
    impl="xla"    — chunked online-softmax scans (any backend; dry-run)
    impl="naive"  — materialized-score oracle (small shapes / unrolled
                    cost-analysis compiles, where loop bodies would be
                    counted once — see launch/dryrun.py)

All paths keep the 4-D (B, H, S, D) layout (no B·H flattening) so batch-
and head-shardings propagate cleanly through SPMD.
"""

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.xla_flash import flash_attention_xla
from repro.kernels.flash_attention.ref import attention_ref


def default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def _naive_4d(q, k, v, causal, window, scale):
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    s = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "impl", "interpret")
)
def gqa_attention_impl(
    q: jax.Array,  # (B, Sq, Hq, D)
    k: jax.Array,  # (B, Sk, Hkv, D)
    v: jax.Array,  # (B, Sk, Hkv, D)
    *,
    causal: bool = True,
    window: int | None = None,
    impl: str = "xla",
    interpret: bool = True,
) -> jax.Array:
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    G = Hq // Hkv
    scale = 1.0 / (D ** 0.5)
    q4 = q.transpose(0, 2, 1, 3)  # (B, Hq, Sq, D)
    k4 = jnp.repeat(k, G, axis=2).transpose(0, 2, 1, 3)
    v4 = jnp.repeat(v, G, axis=2).transpose(0, 2, 1, 3)
    if impl == "pallas":
        of = flash_attention(
            q4.reshape(B * Hq, Sq, D),
            k4.reshape(B * Hq, Sk, D),
            v4.reshape(B * Hq, Sk, D),
            causal=causal, window=window, interpret=interpret,
        ).reshape(B, Hq, Sq, D)
    elif impl == "xla":
        of = flash_attention_xla(q4, k4, v4, causal=causal, window=window)
    else:
        of = _naive_4d(q4, k4, v4, causal, window, scale)
    return of.transpose(0, 2, 1, 3)


def gqa_attention(q, k, v, *, causal=True, window=None, use_kernel=True, interpret=None):
    """Boolean entry: use_kernel=True picks the best fused path for the
    backend; use_kernel=False uses the materializing oracle."""
    impl = default_impl() if use_kernel else "naive"
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return gqa_attention_impl(
        q, k, v, causal=causal, window=window, impl=impl, interpret=interpret
    )


__all__ = [
    "gqa_attention",
    "gqa_attention_impl",
    "flash_attention",
    "flash_attention_xla",
    "attention_ref",
]
