"""Pure-jnp oracle for flash attention (materializes the score matrix)."""

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("causal", "window", "scale"))
def attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
) -> jax.Array:
    """q: (BH, Sq, D), k/v: (BH, Sk, D)."""
    BH, Sq, D = q.shape
    Sk = k.shape[1]
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    # rows that are fully masked produce uniform softmax over -1e30; zero them
    any_valid = mask.any(axis=1)[None, :, None]
    out = jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))
    out = jnp.where(any_valid, out, 0.0)
    return out.astype(q.dtype)
