"""Concurrent guests — multiplex disjoint D3(J,L) workloads on one host mesh.

Paper Property 2 gives D3(K,M) a dilation-1 copy of every smaller D3(J,L);
``runtime.rewrite.emulate`` makes ONE such guest executable per host. This
module makes N of them executable AT ONCE: ``combine(programs)`` merges N
already-rewritten guest programs whose ``active_devices`` images are
pairwise disjoint into a single host-sized ``CollectiveProgram`` that any
conforming backend replays unchanged.

Why this is sound: a Property-2 image C × P × P is *closed* — every link a
guest hop traverses connects two routers of the image — so disjoint router
images use disjoint sets of directed physical links. Interleaving the
guests' stages therefore cannot create a link conflict, and because a
stage only ever reads/writes devices of its own guest, ANY replay order
that preserves each guest's own stage order is bit-exact per guest. The
combined makespan is max(T_1..T_N) synchronous rounds instead of the
ΣT_i a time-multiplexed host would pay (the ``concurrent_guests`` bench
row measures exactly this).

The merge packs aggressively: stages from different guests that share one
``(round_index, step, start_step)`` stamp and one type fuse into a single
partial stage (disjoint ``Perm``s become one partial permutation, ``Match``
/ ``ReduceCombine`` pair sets union), so the combined program has the SAME
stage count per step group as the widest guest — one ``ppermute`` moves
both guests' chunks. Stages whose stamps differ simply coexist; barrier
replay still groups them by ``(round_index, step)``.

Conflicts are re-checked, not assumed: ``combine`` walks every synchronous
step group across guests with the paper's conflict model (a directed link
serves one packet per step; only ``ReduceCombine`` destinations may repeat
within a group) and raises a structured ``GuestConflictError`` carrying
the offending ``(step, link)`` and guest indices — overlapping images are
reported the same way before any merge happens. ``combine_schedules`` is
the Schedule-IR companion: it merges the guests' host-graph Schedule views
(``rewrite.emulate_schedule`` output) into one Schedule that
``core.simulator.verify`` — the same conflict checker every algorithm's
tests use — replays on the literal host links.

Matmul programs carry non-communication ``LocalContract`` stages that
backends apply to EVERY device (idle devices just hold zero blocks), so
matmul guests must share one local-contract skeleton — same grid shape,
same round structure; ``combine`` verifies this and merges the skeletons
positionally (``store_c`` masks union). Combined matmul programs replay at
the blocks level (``matmul_blocks`` / the per-shard ``matmul`` method):
each guest's blocks are scattered to its own slots with its solo program,
and results extracted per guest (below).

Per-guest data movement: ``scatter_guests`` packs N guest-sized arrays
into one host-sized array (each guest at its own ``active_devices``
slots); ``gather_guests`` / ``extract_guest`` pull each guest's result
back out through ``Embedding.host_to_guest`` (or a rewritten program's
``active_devices``). Pure Python + NumPy over hashable data — ``combine``
is memoized, so elastic failover can re-combine a surviving tenant set as
cheaply as it re-emulates a single guest.
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np

from repro.core.emulation import Embedding
from repro.core.schedule import Round, Schedule
from repro.runtime.program import (
    CollectiveProgram,
    LocalContract,
    Match,
    Perm,
    ReduceCombine,
    Stage,
)
from repro.runtime.rewrite import gather_guest


class GuestConflictError(ValueError):
    """Two guests collide — overlapping device images or a step conflict.

    ``guests`` holds the offending guest indices (positions in the
    ``combine`` argument). For image overlaps ``device`` is the shared host
    device id. For step conflicts ``step`` is the ``(round_index, step)``
    stamp, plus ``link`` — the contested directed ``(src, dst)`` pair —
    for link conflicts, or ``device`` — the doubly-written id — for write
    conflicts (``link`` is then the writing pair only if one traverses a
    link).
    """

    def __init__(self, message: str, *, guests=None, device=None,
                 step=None, link=None):
        super().__init__(message)
        self.guests = guests
        self.device = device
        self.step = step
        self.link = link


# ---------------------------------------------------------------------------
# Validation: disjoint images + cross-guest step-conflict re-check.
# ---------------------------------------------------------------------------

def _check_images_disjoint(programs) -> None:
    seen: dict[int, int] = {}
    for gi, prog in enumerate(programs):
        for dev in prog.active_devices:
            gj = seen.setdefault(dev, gi)
            if gj != gi:
                raise GuestConflictError(
                    f"guests {gj} and {gi} overlap on host device {dev}",
                    guests=(gj, gi), device=dev,
                )


def _stage_events(st: Stage):
    """(src, dst, uses_link) triples for a communication stage: identity
    ``ReduceCombine`` pairs WRITE their own accumulator but use no link."""
    if isinstance(st, (Perm, Match)):
        return [(s, d, True) for s, d in st.pairs]
    if isinstance(st, ReduceCombine):
        return [(s, d, s != d) for s, d in st.pairs]
    return []


def check_step_conflicts(programs) -> None:
    """Re-check the paper's conflict model across guests, step by step.

    Within one synchronous ``(round_index, step)`` group, a directed device
    link may serve ONE packet, and no device may be written by two GUESTS
    — repeated writes are legal only intra-guest (``ReduceCombine`` folds,
    per the backend contract), never across guests, since disjoint closed
    images put every destination inside exactly one guest. The check
    catches callers who merge programs that were not independently
    rewritten (and is cheap: one dict pass over the pair sets).
    """
    links: dict[tuple, int] = {}   # (round, step, src, dst) -> guest
    writes: dict[tuple, int] = {}  # (round, step, dst) -> guest
    for gi, prog in enumerate(programs):
        for st in prog.stages:
            key = (st.round_index, st.step)
            for s, d, uses_link in _stage_events(st):
                if uses_link:
                    prev = links.setdefault(key + (s, d), gi)
                    if prev != gi:
                        raise GuestConflictError(
                            f"guests {prev} and {gi} both use link {s}->{d} "
                            f"at step {key}",
                            guests=(prev, gi), step=key, link=(s, d),
                        )
                owner = writes.setdefault(key + (d,), gi)
                if owner != gi:
                    raise GuestConflictError(
                        f"guests {owner} and {gi} both write device {d} "
                        f"at step {key}",
                        guests=(owner, gi), step=key, device=d,
                        link=(s, d) if uses_link else None,
                    )


# ---------------------------------------------------------------------------
# Stage merging.
# ---------------------------------------------------------------------------

def _stamps(st: Stage) -> dict:
    return dict(round_index=st.round_index, step=st.step,
                start_step=st.start_step)


def _merge_comm(stages: list[Stage], n: int) -> Stage:
    """Union same-type stages with identical stamps into one partial stage
    over the host's n devices (the packing step: disjoint guests' perms
    become ONE partial permutation — one ppermute on the wire)."""
    st = stages[0]
    pairs = tuple(p for s in stages for p in s.pairs)
    if isinstance(st, Perm):
        return Perm(pairs, n=n, **_stamps(st))
    if isinstance(st, Match):
        return Match(n, pairs, **_stamps(st))
    assert isinstance(st, ReduceCombine)
    return ReduceCombine(n, pairs, combine=st.combine, **_stamps(st))


def _merge_homogeneous(programs, n: int) -> tuple[Stage, ...]:
    """Merge comm-only programs (alltoall / allreduce / broadcast).

    Stages bucket by ``(round_index, step, start_step, type)``; within a
    bucket each guest contributes an ordered run (broadcast fan-out emits
    several matchings per step) and the runs merge positionally, so every
    guest keeps its own stage order — the property replay correctness
    rides on. Buckets come out sorted by stamp, which coincides with each
    guest's own (round-major, step-minor) barrier order.
    """
    buckets: dict[tuple, list[list[Stage]]] = {}
    for prog in programs:
        mine: dict[tuple, list[Stage]] = {}
        for st in prog.stages:
            key = (st.round_index, st.step, st.start_step, type(st).__name__)
            mine.setdefault(key, []).append(st)
        for key, run in mine.items():
            buckets.setdefault(key, []).append(run)
    out: list[Stage] = []
    for key in sorted(buckets):
        runs = buckets[key]
        for i in range(max(len(r) for r in runs)):
            out.append(_merge_comm([r[i] for r in runs if i < len(r)], n))
    return tuple(out)


def _skeleton(prog: CollectiveProgram) -> tuple:
    return tuple(
        (type(st).__name__, getattr(st, "fn", None),
         st.round_index, st.step, st.start_step)
        for st in prog.stages
    )


def _merge_matmul(programs, n: int) -> tuple[Stage, ...]:
    """Positional merge of matmul programs sharing one local-contract
    skeleton (``load_b``/``mul_a``/``promote`` act on every device, so the
    guests' round structures must agree stage for stage)."""
    skel = _skeleton(programs[0])
    for gi, prog in enumerate(programs[1:], start=1):
        if _skeleton(prog) != skel:
            raise GuestConflictError(
                f"matmul guests 0 and {gi} have different local-contract "
                "skeletons (grids/round structures differ); combine only "
                "multiplexes matmul guests of one shape",
                guests=(0, gi),
            )
    out: list[Stage] = []
    for column in zip(*(p.stages for p in programs)):
        st = column[0]
        if isinstance(st, LocalContract):
            if st.mask is None:
                out.append(LocalContract(st.fn, n=n, **_stamps(st)))
            else:
                mask = tuple(i for s in column for i in s.mask)
                out.append(LocalContract(st.fn, mask=mask, n=n, **_stamps(st)))
        else:
            out.append(_merge_comm(list(column), n))
    return tuple(out)


# ---------------------------------------------------------------------------
# The combinator.
# ---------------------------------------------------------------------------

def combine(programs, name: str = "") -> CollectiveProgram:
    """Merge N rewritten guest programs into one concurrent host program.

    Every input must be an emulation rewrite (``active_devices`` set) of
    the SAME kind on the SAME host size, with pairwise-disjoint device
    images — violations raise ``GuestConflictError``. The result's
    ``active_devices`` is the guests' images concatenated in argument
    order (guest g's devices at offset ``sum(guest_n of guests < g)``),
    its round count is ``max`` over guests, and its stages are the packed
    merge described in the module docstring. A single program passes
    through unchanged (after validation — it must still be a rewrite).
    Memoized per (programs, name) — programs are frozen/hashable, so
    failover re-combines are cache hits.
    """
    return _combine(tuple(programs), name)


@functools.lru_cache(maxsize=None)
def _combine(programs: tuple[CollectiveProgram, ...],
             name: str) -> CollectiveProgram:
    if not programs:
        raise ValueError("combine() needs at least one program")
    first = programs[0]
    for gi, prog in enumerate(programs):
        if prog.kind != first.kind:
            raise ValueError(
                f"cannot combine kinds {first.kind!r} and {prog.kind!r} "
                f"(guest {gi}): backends replay one kind per program"
            )
        if prog.n != first.n:
            raise ValueError(
                f"guest {gi} is host-sized {prog.n}, expected {first.n}"
            )
        if prog.active_devices is None:
            raise ValueError(
                f"guest {gi} is a native (full-mesh) program; combine takes "
                "emulation rewrites — pass it through rewrite.emulate first"
            )
    if len(programs) == 1:  # validated pass-through: already a rewrite
        return first
    _check_images_disjoint(programs)
    check_step_conflicts(programs)
    if first.kind == "matmul":
        stages = _merge_matmul(programs, first.n)
    else:
        stages = _merge_homogeneous(programs, first.n)
    grids = {p.grid for p in programs}
    return CollectiveProgram(
        kind=first.kind,
        n=first.n,
        num_rounds=max(p.num_rounds for p in programs),
        stages=stages,
        root=None,  # per-guest roots live on the solo programs
        grid=grids.pop() if len(grids) == 1 else None,
        name=name or "+".join(p.name or p.kind for p in programs),
        active_devices=tuple(d for p in programs for d in p.active_devices),
    )


def combine_schedules(schedules, name: str = "") -> Schedule:
    """Merge host-graph Schedule views (``rewrite.emulate_schedule`` output)
    for the Schedule-IR conflict checker.

    Round i of every guest lands in round-index-i position of the merged
    schedule (the barrier window ``combine`` merges programs by), SPLIT
    per distinct ``start_step`` stamp so pipelined replay launches every
    guest's rounds at its own offsets — mixed-shape pipelined guests whose
    stamps disagree keep them instead of defaulting to 0. Payloads are
    namespaced ``(guest_index, payload)`` so the verifier attributes
    conflicts to guests. ``core.simulator.verify`` on the result — zero
    conflicts, barrier and pipelined — is the IR-level proof that the
    combined program's step groups fit the host links concurrently.
    """
    schedules = list(schedules)
    if not schedules:
        raise ValueError("combine_schedules() needs at least one schedule")
    topo = schedules[0].topo
    for sched in schedules[1:]:
        if sched.topo != topo:
            raise ValueError(
                f"host topologies differ: D3({topo.K},{topo.M}) vs "
                f"D3({sched.topo.K},{sched.topo.M})"
            )
    num_rounds = max(s.num_rounds for s in schedules)
    rounds: list[Round] = []
    for i in range(num_rounds):
        by_start: dict = {}  # start_step stamp (or None) -> merged hops
        for gi, sched in enumerate(schedules):
            if i >= sched.num_rounds:
                continue
            rnd = sched.rounds[i]
            by_start.setdefault(rnd.meta.get("start_step"), []).extend(
                dataclasses.replace(h, payload=(gi, h.payload))
                for h in rnd.hops
            )
        for start in sorted(by_start, key=lambda s: (s is not None, s or 0)):
            meta = {} if start is None else {"start_step": start}
            rounds.append(Round(tuple(by_start[start]), meta))
    return Schedule(
        name or "+".join(s.name for s in schedules), topo, rounds,
        {"guests": len(schedules)},
    )


# ---------------------------------------------------------------------------
# Per-guest data movement around a combined replay.
# ---------------------------------------------------------------------------

def _guest_index(guest) -> np.ndarray:
    """Guest-ordered host device ids of an ``Embedding`` (its cached
    ``device_map``, i.e. the ``host_to_guest`` inverse) or of a rewritten
    program (``active_devices``)."""
    if isinstance(guest, Embedding):
        return guest.device_map
    prog = guest.program if hasattr(guest, "program") else guest
    if prog.active_devices is None:
        raise ValueError("native program has no guest view to extract")
    return prog.active_np


def extract_guest(x: np.ndarray, guest, *, axes=(0,)) -> np.ndarray:
    """Pull ONE guest's slice out of a host-sized combined replay result.

    ``guest`` is the guest's ``Embedding`` (mapped through its
    ``host_to_guest`` inverse) or its solo rewritten program (delegated to
    ``rewrite.gather_guest``). Each listed host axis shrinks to the
    guest's device count, in guest id order.
    """
    if not isinstance(guest, Embedding):
        prog = guest.program if hasattr(guest, "program") else guest
        if prog.active_devices is None:
            raise ValueError("native program has no guest view to extract")
        return gather_guest(np.asarray(x), prog, axes=axes)
    host_n = guest.host.num_routers
    idx = _guest_index(guest)
    out = np.asarray(x)
    for ax in axes:
        if out.shape[ax] != host_n:
            raise ValueError(
                f"axis {ax} has {out.shape[ax]} slots, host has {host_n}"
            )
        sel = [slice(None)] * out.ndim
        sel[ax] = idx
        out = out[tuple(sel)]
    return out


def gather_guests(x: np.ndarray, guests, *, axes=(0,)) -> list[np.ndarray]:
    """``extract_guest`` for every guest of a combined replay, in order."""
    return [extract_guest(x, g, axes=axes) for g in guests]


def scatter_guests(xs, guests, host_shape=None, *, axes=(0,), fill=0) -> np.ndarray:
    """Pack per-guest arrays into ONE host-sized array for a combined
    replay: guest g's slice lands at its own device slots, every other slot
    holds ``fill``. ``host_shape`` defaults to the first array's shape with
    each listed axis widened to the host device count (taken from the first
    guest's embedding host / program n)."""
    xs = [np.asarray(x) for x in xs]
    guests = list(guests)
    if len(xs) != len(guests):
        raise ValueError(f"{len(xs)} arrays for {len(guests)} guests")
    g0 = guests[0]
    host_n = (g0.host.num_routers if isinstance(g0, Embedding)
              else (g0.program if hasattr(g0, "program") else g0).n)
    if host_shape is None:
        host_shape = list(xs[0].shape)
        for ax in axes:
            host_shape[ax] = host_n
    out = np.full(tuple(host_shape), fill,
                  np.result_type(fill, *(x.dtype for x in xs)))
    for x, guest in zip(xs, guests):
        idx = _guest_index(guest)
        for ax in axes:
            if x.shape[ax] != len(idx):
                raise ValueError(
                    f"axis {ax} has {x.shape[ax]} slots, guest has {len(idx)}"
                )
        # np.ix_-style cross-product index over the listed axes, slices
        # elsewhere: one advanced-index assignment per guest
        index: list = [slice(None)] * out.ndim
        for k, ax in enumerate(axes):
            shape = [1] * len(axes)
            shape[k] = len(idx)
            index[ax] = idx.reshape(shape)
        out[tuple(index)] = x
    return out


def run_matmul_guests(backend, Bs, As, program: CollectiveProgram, guests
                      ) -> list[np.ndarray]:
    """N whole-matrix §2 products through ONE combined replay.

    The whole-matrix twin of a combined ``matmul_blocks`` call: each
    guest's (N·X, N·X) factor matrices are cut into §2 blocks
    (``core.matmul.scatter_blocks``, grid = the shared guest grid), every
    guest's blocks land at its own host slots (``scatter_guests``), the
    backend replays the combined program ONCE at the blocks level, and each
    product matrix is reassembled from its guest's slots. Returns
    ``[B_g @ A_g for g in guests]`` in guest order.

    ``program`` must come from ``combine`` (or
    ``dist.collectives.concurrent_program('matmul', ...)``) over guests of
    ONE grid shape — that is the only combinable matmul case, and it is
    what makes ``program.grid`` the per-guest grid. ``backend`` needs the
    blocks-level entry point (``matmul_blocks``); the per-shard
    ``run_matmul`` wrappers can't express N disjoint whole matrices.
    """
    from repro.core.matmul import MatmulGrid, gather_blocks, scatter_blocks

    if len(Bs) != len(As) or len(Bs) != len(guests):
        raise ValueError(
            f"{len(Bs)} B / {len(As)} A matrices for {len(guests)} guests"
        )
    if program.kind != "matmul":
        raise ValueError(f"expected a matmul program, got {program.kind!r}")
    if program.grid is None:
        raise ValueError(
            "combined program lacks grid metadata — matmul guests of mixed "
            "grid shapes cannot share one whole-matrix replay"
        )
    if not hasattr(backend, "matmul_blocks"):
        raise ValueError(
            f"backend {getattr(backend, 'name', type(backend).__name__)!r} "
            "has no blocks-level matmul entry point (matmul_blocks); the "
            "combined whole-matrix wrapper needs it"
        )
    g = MatmulGrid(*program.grid)
    bs = [scatter_blocks(g, np.asarray(B)) for B in Bs]
    as_ = [scatter_blocks(g, np.asarray(A)) for A in As]
    host_shape = (program.n, *bs[0].shape[1:])
    bh = scatter_guests(bs, guests, host_shape)
    ah = scatter_guests(as_, guests, host_shape)
    ch = backend.matmul_blocks(bh, ah, program)
    return [gather_blocks(g, cg) for cg in gather_guests(ch, guests)]
