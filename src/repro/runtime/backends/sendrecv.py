"""Send/recv trace backend: replay exported ``DeviceTrace``s in pure NumPy.

The consuming half of the collective compiler (``runtime.export``): every
``run_*`` call compiles its program to a per-device send/recv op trace —
memoized and statically re-validated once per program — and then executes
THE TRACE, never the program stages. What the NCCL-style runtime of a
non-XLA substrate would do with the exported JSON, this backend does on
host arrays, which makes the export format itself differential-testable:
``sendrecv`` must be bit-identical to ``reference`` on every program
(native, optimized, emulated, combined — the conformance suite in
``tests/test_backend_contract.py`` asserts exactly that).

Replay semantics follow the trace contract: groups execute sequentially;
within a group every ``send`` payload is read (and copied) from the
pre-group buffers, then recv/reduce/copy/contract ops apply in per-device
op order. ``contract`` ops batch into one ``einsum`` over the contracting
devices so the §2 block product is bit-identical to the reference replay.
Idle devices of emulated/combined programs have no ops at all, so idle
pass-through (inputs unchanged for allreduce/broadcast, outputs zero for
alltoall/matmul) holds structurally.

No jax, no devices. ``OptimizedProgram``s are accepted anywhere a program
is (the trace of the fused form is the trace of its source program).
"""

from __future__ import annotations

import functools

import numpy as np

from repro.runtime import export as _export
from repro.runtime import optimize as _opt
from repro.runtime.program import CollectiveProgram, check_kind as _check_kind


@functools.lru_cache(maxsize=None)
def _compiled(prog: CollectiveProgram):
    """(validated trace, replay groups) for one program. Groups are the
    trace ops bucketed by group id, device-major — per-device op order is
    preserved, which is the only order the replay contract requires."""
    trace = _export.validate(_export.export(prog))
    groups: list[list[tuple[int, _export.TraceOp]]] = [
        [] for _ in range(trace.num_groups)
    ]
    for dev, ops in enumerate(trace.devices):
        for op in ops:
            groups[op.group].append((dev, op))
    return trace, tuple(tuple(g) for g in groups)


def _replay(trace, groups, bufs: dict[str, np.ndarray], dtype=None) -> None:
    """Execute the trace in place on the named buffers."""
    waves = trace.kind == "broadcast" and trace.num_rounds > 1
    a_cast = None  # lazily-cast A blocks for contract ops
    for gops in groups:
        payloads: dict[tuple[int, int], np.ndarray] = {}
        pre_val = None
        contract_devs: list[int] = []
        # pass 1: read every send payload from the pre-group buffers
        # (copies — a later write must not alias an in-flight packet),
        # snapshot ``val`` if an off-and-on reduce needs the pre value,
        # and collect the group's contracting devices.
        for dev, op in gops:
            if op.op == "send":
                if trace.kind == "alltoall":
                    payloads[dev, op.peer] = bufs["x"][dev, op.slot].copy()
                elif waves:
                    payloads[dev, op.peer] = bufs["val"][op.slot, dev].copy()
                else:
                    payloads[dev, op.peer] = bufs[op.buf][dev].copy()
            elif op.op == "reduce" and op.src == "val" and pre_val is None:
                pre_val = bufs["val"].copy()
            elif op.op == "contract":
                contract_devs.append(dev)
        if contract_devs:
            if a_cast is None:
                a_cast = bufs["a"].astype(dtype)
            idx = np.asarray(contract_devs)
            bufs["val"][idx] = np.einsum(
                "nab,nbc->nac", bufs["val"][idx], a_cast[idx])
        # pass 2: land the writes in per-device op order
        tmp: dict[int, np.ndarray] = {}
        for dev, op in gops:
            if op.op == "recv":
                v = payloads[op.peer, dev]
                if op.buf == "tmp":
                    tmp[dev] = v
                elif trace.kind == "alltoall":
                    bufs["out"][dev, op.slot] = v
                elif waves:
                    bufs["val"][op.slot, dev] = v
                else:
                    bufs[op.buf][dev] = v
            elif op.op == "reduce":
                src = tmp[dev] if op.src == "tmp" else pre_val[dev]
                tgt = bufs[op.buf]
                tgt[dev] = tgt[dev] + src
            elif op.op == "copy":
                if op.src == "x":       # alltoall self chunk
                    bufs["out"][dev, op.slot] = bufs["x"][dev, op.slot]
                elif op.src == "zero":  # accumulator reset
                    bufs[op.buf][dev] = 0
                else:
                    bufs[op.buf][dev] = bufs[op.src][dev]


class SendRecvBackend:
    """Replay exported send/recv traces on host arrays (global view)."""

    name = "sendrecv"

    @staticmethod
    def trace(program) -> "_export.DeviceTrace":
        """The validated :class:`~repro.runtime.export.DeviceTrace` this
        backend replays for ``program`` (exposed for inspection/export)."""
        return _compiled(_opt.as_program(program))[0]

    # ------------------------------------------------------------ alltoall
    def run_alltoall(self, x, program) -> np.ndarray:
        prog = _opt.as_program(program)
        _check_kind(prog, "alltoall")
        x = np.asarray(x)
        n = prog.n
        if x.shape[0] != n or x.shape[1] != n:
            raise ValueError(f"expected leading dims ({n}, {n}), got {x.shape}")
        trace, groups = _compiled(prog)
        out = np.zeros_like(x)
        _replay(trace, groups, {"x": x, "out": out})
        return out

    # ----------------------------------------------------------- allreduce
    def run_allreduce(self, x, program) -> np.ndarray:
        prog = _opt.as_program(program)
        _check_kind(prog, "allreduce")
        trace, groups = _compiled(prog)
        val = np.asarray(x).copy()
        _replay(trace, groups, {"val": val})
        return val

    # ----------------------------------------------------------- broadcast
    def run_broadcast(self, x, program, *, pipelined: bool = False) -> np.ndarray:
        """``pipelined`` is accepted for contract parity: the trace replays
        its barrier groups either way, bit-identical to start_step order by
        the IR's pipelined conflict-freedom (the same coincidence the fused
        replay relies on)."""
        prog = _opt.as_program(program)
        _check_kind(prog, "broadcast")
        trace, groups = _compiled(prog)
        x = np.asarray(x)
        if trace.num_rounds > 1 and x.shape[0] != trace.num_rounds:
            raise ValueError(
                f"expected leading wave dim {trace.num_rounds}, got {x.shape}")
        val = x.copy()
        _replay(trace, groups, {"val": val})
        return val

    # -------------------------------------------------------------- matmul
    def run_matmul(self, B, A, program) -> np.ndarray:
        from repro.core.matmul import MatmulGrid, gather_blocks, scatter_blocks
        from repro.runtime.rewrite import gather_guest, scatter_guest

        prog = _opt.as_program(program)
        _check_kind(prog, "matmul")
        if prog.grid is None:
            raise ValueError("matmul program lacks grid metadata")
        g = MatmulGrid(*prog.grid)
        b = scatter_guest(scatter_blocks(g, np.asarray(B)), prog)
        a = scatter_guest(scatter_blocks(g, np.asarray(A)), prog)
        c = self.matmul_blocks(b, a, program)
        return gather_blocks(g, gather_guest(c, prog))

    def matmul_blocks(self, b, a, program) -> np.ndarray:
        prog = _opt.as_program(program)
        _check_kind(prog, "matmul")
        b, a = np.asarray(b), np.asarray(a)
        n = prog.n
        if b.shape != a.shape or b.shape[0] != n:
            raise ValueError(f"expected blocks (n={n}, X, X), got {b.shape} {a.shape}")
        trace, groups = _compiled(prog)
        dtype = np.result_type(b, a)
        val = np.zeros(b.shape, dtype)
        _replay(trace, groups,
                {"b": b, "a": a, "val": val, "acc": np.zeros_like(val),
                 "c": (c := np.zeros_like(val))}, dtype=dtype)
        return c
