"""Pure-NumPy reference backend: host-side replay of a CollectiveProgram.

No jax, no devices — the ground truth the JAX backend is differential-
tested against, and a host-side validator for schedules lowered for
hardware this process doesn't have. Arrays carry the GLOBAL view: index 0
is the device (= router id) axis.

Semantics mirror ``runtime.program``'s synchronous-step contract: all
stages of one step group read the pre-group values, then their writes land
together.

Emulated (guest-on-host) programs — ``program.active_devices`` set — are
replayed on host-sized arrays. This backend is the enforcement point of the
idle-isolation guarantee: after replay it ASSERTS that slots belonging to
idle host devices were never touched (inputs pass through for allreduce/
broadcast; outputs stay zero for alltoall/matmul). A violated assertion
means the rewrite or a backend broke the contract, not user error.

Every ``run_*`` entry point also accepts an ``optimize.OptimizedProgram``:
the replay then applies the fused group tables (one advanced-indexing
operation per conflict-free step group — the §3 all-to-all collapses to a
single scatter) instead of the per-stage loop, with identical results and
the same idle assertions.
"""

from __future__ import annotations

import numpy as np

from repro.runtime import optimize as _opt
from repro.runtime.program import (
    CollectiveProgram,
    LocalContract,
    Match,
    Perm,
    ReduceCombine,
    check_kind as _check_kind,
)


def _assert_idle_untouched(program: CollectiveProgram, got: np.ndarray,
                           want: np.ndarray, axes=(0,)) -> None:
    """Emulated programs: idle host devices' slots must be bit-identical to
    ``want`` (the pre-replay values, or zeros for freshly-built outputs)."""
    if program.active_devices is None:
        return
    idle = ~program.active_mask_np
    for ax in axes:
        sel = [slice(None)] * got.ndim
        sel[ax] = idle
        if not np.array_equal(got[tuple(sel)], want[tuple(sel)]):
            raise AssertionError(
                f"idle device slots were touched on axis {ax} of a "
                f"{program.kind!r} emulation replay ({program.name})"
            )


class NumpyReferenceBackend:
    """Replay programs on host arrays (global view, device axis first)."""

    name = "reference"

    # ------------------------------------------------------------ alltoall
    def run_alltoall(self, x: np.ndarray, program: CollectiveProgram) -> np.ndarray:
        """x: (n, n, ...) with x[i, j] the chunk device i sends to device j;
        returns out[i, j] = chunk received by i FROM j (= x[j, i]).

        Emulated programs: only active (i, j) slots are filled; rows and
        columns of idle devices stay zero (asserted)."""
        opt = program if isinstance(program, _opt.OptimizedProgram) else None
        program = _opt.as_program(program)
        _check_kind(program, "alltoall")
        n = program.n
        if x.shape[0] != n or x.shape[1] != n:
            raise ValueError(f"expected leading dims ({n}, {n}), got {x.shape}")
        if opt is not None:
            out = _opt.np_alltoall(x, opt)
        else:
            out = np.zeros_like(x)
            for op in program.comm_stages:
                assert isinstance(op, Perm)
                # sender s ships chunk x[s, d] to d, who files it under index
                # s — pairs-based so partial (emulated) perms never touch
                # idle slots.
                out[op.dst_np, op.src_np] = x[op.src_np, op.dst_np]
        _assert_idle_untouched(program, out, np.zeros_like(out), axes=(0, 1))
        return out

    def run_alltoall_compute(
        self, x: np.ndarray, program: CollectiveProgram, compute=None
    ) -> np.ndarray:
        """Fused dispatch+compute round trip, ground truth for the JAX
        backend's ``alltoall_compute``: every chunk x[i, j] is processed AT
        its destination j and returned to sender i, so
        out[i, j] = compute_j(x[i, j]) — NOT the all-to-all transpose.
        ``compute(d, chunks)`` maps destination id d and the (k, ...) stack
        of chunks arriving there to the processed (k, ...) stack;
        ``compute=None`` is the identity round trip.

        Emulated programs: only active (i, j) slots are processed; rows and
        columns of idle devices stay zero (asserted)."""
        program = _opt.as_program(program)
        _check_kind(program, "alltoall")
        n = program.n
        if x.shape[0] != n or x.shape[1] != n:
            raise ValueError(f"expected leading dims ({n}, {n}), got {x.shape}")
        act = (np.flatnonzero(program.active_mask_np)
               if program.active_devices is not None else np.arange(n))
        out = np.zeros_like(x)
        for j in act:
            chunks = x[act, j]
            out[act, j] = chunks if compute is None else compute(int(j), chunks)
        _assert_idle_untouched(program, out, np.zeros_like(out), axes=(0, 1))
        return out

    # ----------------------------------------------------------- allreduce
    def run_allreduce(self, x: np.ndarray, program: CollectiveProgram) -> np.ndarray:
        """x: (n, ...) -> (n, ...) with every active row the sum over active
        rows; idle rows pass through unchanged (asserted)."""
        opt = program if isinstance(program, _opt.OptimizedProgram) else None
        program = _opt.as_program(program)
        _check_kind(program, "allreduce")
        x = np.asarray(x)
        if opt is not None:
            val = _opt.np_allreduce(x, opt)
        else:
            val = x.copy()
            for st in program.comm_stages:
                assert isinstance(st, ReduceCombine)
                recv = np.zeros_like(val)
                for s, d in st.link_pairs:
                    recv[d] = val[s]
                recv[st.self_mask_np] += val[st.self_mask_np]
                val = val + recv
        _assert_idle_untouched(program, val, x)
        return val

    # ----------------------------------------------------------- broadcast
    def run_broadcast(
        self, x: np.ndarray, program: CollectiveProgram, *, pipelined: bool = False
    ) -> np.ndarray:
        """Single-round programs: x (n, ...) -> root's row everywhere.
        Multi-round (pipelined wave) programs: x (R, n, ...), wave w's tree
        moves slice x[w]. ``pipelined=True`` replays in start_step order —
        results must be identical to barrier order (the IR's pipelined
        conflict-freedom, projected onto data). Optimized programs replay
        their fused barrier-order groups regardless of ``pipelined`` (the
        results coincide by the same conflict-freedom)."""
        opt = program if isinstance(program, _opt.OptimizedProgram) else None
        program = _opt.as_program(program)
        _check_kind(program, "broadcast")
        waves = program.num_rounds > 1
        x = np.asarray(x)
        if waves and x.shape[0] != program.num_rounds:
            raise ValueError(
                f"expected leading wave dim {program.num_rounds}, got {x.shape}"
            )
        if opt is not None:
            val = _opt.np_broadcast(x, opt)
        else:
            val = x.copy()
            for group in program.step_groups(pipelined=pipelined):
                pre = val.copy()
                for st in group:
                    assert isinstance(st, Match)
                    if waves:
                        val[st.round_index][st.dst_np] = pre[st.round_index][st.src_np]
                    else:
                        val[st.dst_np] = pre[st.src_np]
        _assert_idle_untouched(program, val, x, axes=(1,) if waves else (0,))
        return val

    # -------------------------------------------------------------- matmul
    def run_matmul(
        self, B: np.ndarray, A: np.ndarray, program: CollectiveProgram
    ) -> np.ndarray:
        """§2 block product via program replay: B, A are (N·X, N·X)
        matrices; returns B @ A computed by the paper's rounds. Emulated
        programs scatter the guest's blocks to their host devices (grid
        metadata is the GUEST grid), replay host-sized, and gather back."""
        from repro.core.matmul import MatmulGrid, gather_blocks, scatter_blocks
        from repro.runtime.rewrite import gather_guest, scatter_guest

        prog = _opt.as_program(program)
        _check_kind(prog, "matmul")
        if prog.grid is None:
            raise ValueError("matmul program lacks grid metadata")
        g = MatmulGrid(*prog.grid)
        b = scatter_guest(scatter_blocks(g, np.asarray(B)), prog)
        a = scatter_guest(scatter_blocks(g, np.asarray(A)), prog)
        c = self.matmul_blocks(b, a, program)
        return gather_blocks(g, gather_guest(c, prog))

    def matmul_blocks(
        self, b: np.ndarray, a: np.ndarray, program: CollectiveProgram
    ) -> np.ndarray:
        """Per-router block replay: b, a (n, X, X) in router-id order ->
        c (n, X, X). The per-device state is (val, acc) driven by the
        LocalContract stages; see runtime.program.LOCAL_FNS."""
        opt = program if isinstance(program, _opt.OptimizedProgram) else None
        program = _opt.as_program(program)
        _check_kind(program, "matmul")
        n = program.n
        if b.shape != a.shape or b.shape[0] != n:
            raise ValueError(f"expected blocks (n={n}, X, X), got {b.shape} {a.shape}")
        if opt is not None:
            c = _opt.np_matmul_blocks(b, a, opt)
            _assert_idle_untouched(program, c, np.zeros_like(c))
            return c
        dtype = np.result_type(b, a)
        val = np.zeros_like(b, dtype=dtype)
        acc = np.zeros_like(val)
        c = np.zeros_like(val)
        for group in program.step_groups():
            if isinstance(group[0], LocalContract):
                (st,) = group
                if st.fn == "load_b":
                    val = b.astype(dtype).copy()
                    acc = np.zeros_like(val)
                elif st.fn == "mul_a":
                    val = np.einsum("nab,nbc->nac", val, a.astype(dtype))
                    acc = np.zeros_like(val)
                elif st.fn == "promote":
                    val = acc
                    acc = np.zeros_like(val)
                elif st.fn == "store_c":
                    mask = st.mask_np
                    c[mask] = val[mask]
                continue
            pre = val.copy()
            for st in group:
                if isinstance(st, Match):
                    src = [s for s, _ in st.pairs]
                    dst = [d for _, d in st.pairs]
                    val[dst] = pre[src]
                elif isinstance(st, ReduceCombine):
                    for s, d in st.pairs:
                        acc[d] = acc[d] + pre[s]
                else:  # pragma: no cover - lowering never emits Perm here
                    raise TypeError(f"unexpected stage {st!r} in matmul program")
        _assert_idle_untouched(program, c, np.zeros_like(c))
        return c
