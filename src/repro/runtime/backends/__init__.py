"""Pluggable execution backends for ``CollectiveProgram``s.

The backend contract (see also the ``repro.runtime`` package docstring):
every backend exposes the four whole-array entry points

    run_alltoall(x, program)           (n, n, ...) -> (n, n, ...)
    run_allreduce(x, program)          (n, ...)    -> (n, ...)
    run_broadcast(x, program, *,       (n, ...)    -> (n, ...)   single round
                  pipelined=False)     (R, n, ...) -> (R, n, ...) R waves
    run_matmul(B, A, program)          two (N·X, N·X) matrices -> their product

replaying the SAME lowered program, so backends are differential-testable
against each other bit-for-bit. The JAX backend additionally exposes
per-shard methods (``alltoall``/``allreduce``/``broadcast``/``matmul``)
for use inside a caller's ``shard_map`` (the MoE dispatch path).

Built-in backends:

  * ``jax_ppermute`` — issues one ``jax.lax.ppermute`` per communication
    stage on a 1-D device mesh in router order; ``overlap=True`` launches
    stages in ``start_step`` order so pipelined rounds interleave on the
    wire (cross-round overlap when the schedule's ``start_step`` permits).
  * ``reference`` — a pure-NumPy host-side replay: no devices, no jax.
    The ground truth for differential testing and host validation. Also the
    enforcement point for emulated programs: it asserts idle-device slots
    stay untouched.
  * ``pallas_fused`` — replays the OPTIMIZED program form
    (``runtime.optimize``) with Pallas kernels on the hot spots: the
    allreduce / matmul ``ReduceCombine`` permute+accumulate rounds run as
    table-driven kernels (remote-DMA ring exchange on TPU meshes) and the
    §2 ``mul_a`` contraction goes through ``kernels/block_matmul``.
    ``interpret=True`` (automatic off-TPU) runs the same kernels in the
    Pallas interpreter so CPU CI exercises the fused path bit-for-bit.
  * ``sendrecv`` — the NCCL-style serialization backend: compiles every
    program through ``runtime.export`` into a per-device send/recv op
    trace (versioned, JSON-serializable, statically re-validated for
    link-conflict-freedom and send/recv pairing) and replays THE TRACE in
    pure NumPy — the executable proof that the exported form alone, with
    no Schedule IR and no program stages, reproduces every backend's bits
    on native, optimized, emulated, and combined programs.
  * ``auto`` — no executor of its own: each call asks the price-driven
    autotuner (``runtime.autotune``) for the cheapest strategy at this
    call site — per-stage loop, overlapped, fused-table, Pallas, the
    send/recv trace replay, or the plain XLA collective — and delegates
    to it. Same bits either way; the tuner only moves latency.

Every backend's ``run_*`` also accepts an ``optimize.OptimizedProgram``
(the fused table form) and must produce the same bits for it as for the
program it was built from.

Emulated (guest-on-host) programs are NOT a separate backend: the
``runtime.rewrite.emulate`` pass produces an ordinary ``CollectiveProgram``
with ``active_devices`` set, and every backend replays it under the
idle-pass-through rules of the package contract (``runtime/__init__.py``).
The same holds for COMBINED multi-guest programs (``runtime.combine``):
their ``active_devices`` is the concatenation of the guests' images, and
a conforming backend replays them unchanged.

New backends plug in as additional modules here: add a loader to
``_REGISTRY`` and it shows up in ``available_backends()`` /
``get_backend`` — and in the executable conformance suite
(``tests/test_backend_contract.py``), which replays every registered
backend against ``reference`` bit-for-bit across all four algorithms and
all four program forms (plain, optimized, emulated, combined).
"""

from __future__ import annotations


def _load_jax_ppermute():
    from repro.runtime.backends.jax_ppermute import JaxPpermuteBackend

    return JaxPpermuteBackend


def _load_reference():
    from repro.runtime.backends.reference import NumpyReferenceBackend

    return NumpyReferenceBackend


def _load_pallas_fused():
    from repro.runtime.backends.pallas_fused import PallasFusedBackend

    return PallasFusedBackend


def _load_sendrecv():
    from repro.runtime.backends.sendrecv import SendRecvBackend

    return SendRecvBackend


def _load_auto():
    from repro.runtime.backends.auto import AutoBackend

    return AutoBackend


#: canonical name -> lazy class loader (lazy so the reference backend never
#: pulls in jax); aliases below map user-facing shorthands onto it.
_REGISTRY = {
    "jax_ppermute": _load_jax_ppermute,
    "reference": _load_reference,
    "pallas_fused": _load_pallas_fused,
    "sendrecv": _load_sendrecv,
    "auto": _load_auto,
}

_ALIASES = {"jax": "jax_ppermute", "numpy": "reference", "pallas": "pallas_fused",
            "trace": "sendrecv"}


def available_backends() -> tuple[str, ...]:
    """Canonical names of every registered backend, registration order."""
    return tuple(_REGISTRY)


def get_backend(name: str = "jax_ppermute", **kwargs):
    """Instantiate a backend by canonical name or alias."""
    loader = _REGISTRY.get(_ALIASES.get(name, name))
    if loader is None:
        raise ValueError(
            f"unknown backend {name!r}; available: {', '.join(_REGISTRY)}"
        )
    return loader()(**kwargs)
