"""Pluggable execution backends for ``CollectiveProgram``s.

The backend contract (see also the ``repro.runtime`` package docstring):
every backend exposes the four whole-array entry points

    run_alltoall(x, program)           (n, n, ...) -> (n, n, ...)
    run_allreduce(x, program)          (n, ...)    -> (n, ...)
    run_broadcast(x, program, *,       (n, ...)    -> (n, ...)   single round
                  pipelined=False)     (R, n, ...) -> (R, n, ...) R waves
    run_matmul(B, A, program)          two (N·X, N·X) matrices -> their product

replaying the SAME lowered program, so backends are differential-testable
against each other bit-for-bit. The JAX backend additionally exposes
per-shard methods (``alltoall``/``allreduce``/``broadcast``/``matmul``)
for use inside a caller's ``shard_map`` (the MoE dispatch path).

Built-in backends:

  * ``jax_ppermute`` — issues one ``jax.lax.ppermute`` per communication
    stage on a 1-D device mesh in router order; ``overlap=True`` launches
    stages in ``start_step`` order so pipelined rounds interleave on the
    wire (cross-round overlap when the schedule's ``start_step`` permits).
  * ``reference`` — a pure-NumPy host-side replay: no devices, no jax.
    The ground truth for differential testing and host validation. Also the
    enforcement point for emulated programs: it asserts idle-device slots
    stay untouched.
  * ``pallas_fused`` — replays the OPTIMIZED program form
    (``runtime.optimize``) with Pallas kernels on the hot spots: the
    allreduce / matmul ``ReduceCombine`` permute+accumulate rounds run as
    table-driven kernels (remote-DMA ring exchange on TPU meshes) and the
    §2 ``mul_a`` contraction goes through ``kernels/block_matmul``.
    ``interpret=True`` (automatic off-TPU) runs the same kernels in the
    Pallas interpreter so CPU CI exercises the fused path bit-for-bit.

Every backend's ``run_*`` also accepts an ``optimize.OptimizedProgram``
(the fused table form) and must produce the same bits for it as for the
program it was built from.

Emulated (guest-on-host) programs are NOT a separate backend: the
``runtime.rewrite.emulate`` pass produces an ordinary ``CollectiveProgram``
with ``active_devices`` set, and every backend replays it under the
idle-pass-through rules of the package contract (``runtime/__init__.py``).

Future backends (NCCL-style send/recv lists) plug in as additional modules
here.
"""

from __future__ import annotations


def get_backend(name: str = "jax_ppermute", **kwargs):
    """Instantiate a backend by name (imports lazily so the reference
    backend never pulls in jax)."""
    if name in ("jax", "jax_ppermute"):
        from repro.runtime.backends.jax_ppermute import JaxPpermuteBackend

        return JaxPpermuteBackend(**kwargs)
    if name in ("reference", "numpy"):
        from repro.runtime.backends.reference import NumpyReferenceBackend

        return NumpyReferenceBackend(**kwargs)
    if name in ("pallas", "pallas_fused"):
        from repro.runtime.backends.pallas_fused import PallasFusedBackend

        return PallasFusedBackend(**kwargs)
    raise ValueError(f"unknown backend {name!r}")
