"""Auto backend: route every call through the price-driven autotuner.

``AutoBackend`` satisfies the backend contract by DELEGATING: each call
asks ``runtime.autotune`` for the cheapest strategy at this call site's
``TuneKey`` (kind, D3 topology, message bytes, dtype, site) and dispatches
to the strategy's executor —

  * ``loop``          per-stage replay on the ``jax_ppermute`` backend
  * ``overlap``       the same program in ``start_step`` order
  * ``fused``         the ``optimize()`` table replay
  * ``pallas_fused``  the Pallas-kernel backend
  * ``xla``           the fused XLA collective (``lax.all_to_all``/``psum``)
  * ``overlap_fused`` the wave-ordered fused-table pipeline (all-to-all:
    single gather/scatter dispatch, and the fused dispatch+compute+combine
    round trip of ``alltoall_compute``)
  * ``sendrecv``      the exported per-device send/recv trace replayed by
    the NumPy interpreter (``runtime.export`` — device-free, never needs
    a mesh quorum, so it is exempt from the too-few-devices degrade)

Whole-array ``run_*`` calls tune at ``site="global"``; the per-shard
methods (valid inside a caller's shard_map, e.g. MoE dispatch) tune at
``site="shard"`` where the structural candidates are xla/loop/overlap
(+ overlap_fused for all-to-all).
Results are bit-identical across strategies (the backend contract), so
the tuner is free to switch on speed alone. Decisions are made in Python
at trace time — a jitted caller retraces only when the decision (a cache
lookup after the first call) changes.

Emulated (``active_devices``) programs never dispatch to ``xla``: the
fused op would mix idle devices into the result. ``get_backend("auto")``
instantiates this class.
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np

from repro.runtime import autotune as _at
from repro.runtime import optimize as _opt
from repro.runtime.program import CollectiveProgram, check_kind as _check_kind


def _chunk_bytes(x, kind: str, site: str = "shard") -> int:
    """Message bytes at this site: per-destination capacity chunk for
    all-to-all, the full per-device vector otherwise.

    The all-to-all chunk is ``site``-dependent because the buffers differ
    by a device axis: a shard-site ``x`` is (n, chunk...) so one leading
    dim strips to the chunk, while a global ``x`` is (n, n, chunk...) —
    dividing by ``x.shape[0]`` alone would key the tuner on the n-times
    larger full per-device buffer, a different bucket than the
    per-destination bytes ``_measure_closure`` times and ``models.moe``
    keys for the same exchange."""
    itemsize = np.dtype(x.dtype).itemsize
    if kind == "alltoall":
        div = x.shape[0] * (x.shape[1] if site == "global" else 1)
        return max(1, int(x.size) // max(1, div)) * itemsize
    return int(x.size) * itemsize


@functools.lru_cache(maxsize=None)
def _xla_collective(kind: str, n: int, axis_name: str, root: int = 0):
    """Jitted whole-array shard_map closure of the fused XLA op, cached per
    (kind, n, axis) — the ``xla`` strategy's executor at global sites."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.runtime import compat
    from repro.runtime.backends.jax_ppermute import _axis_mesh

    mesh = _axis_mesh(n, axis_name)
    if kind == "alltoall":
        body = lambda s: jax.lax.all_to_all(
            s[0], axis_name, split_axis=0, concat_axis=0)[None]
    elif kind == "allreduce":
        body = lambda s: jax.lax.psum(s, axis_name)
    else:  # broadcast from root: one masked psum
        body = lambda s: jax.lax.psum(jnp.where(
            jax.lax.axis_index(axis_name) == root, s, jnp.zeros_like(s)),
            axis_name)
    return jax.jit(compat.shard_map(
        body, mesh=mesh, in_specs=P(axis_name), out_specs=P(axis_name)))


@dataclasses.dataclass(frozen=True)
class AutoBackend:
    """Strategy-per-call-site dispatcher (see module docstring).

    ``tuner=None`` uses the process-wide ``autotune.get_autotuner()`` —
    pass an explicit ``Autotuner`` to pin mode/cache (tests, launchers)."""

    tuner: object | None = None
    name: str = "auto"

    def _tuner(self) -> _at.Autotuner:
        return self.tuner if self.tuner is not None else _at.get_autotuner()

    def _decide(self, kind: str, program: CollectiveProgram, nbytes: int,
                dtype, site: str, compute_us: int = 0) -> _at.Decision:
        emulated = program.active_devices is not None
        grid = program.grid if kind == "matmul" else None
        layout = _at.layout_for(program.n)
        return self._tuner().decide(
            kind, layout, nbytes, dtype=str(dtype), site=site, grid=grid,
            emulated=emulated, compute_us=compute_us)

    def _delegate(self, strategy: str, program):
        """(backend instance, program form) for a non-xla strategy."""
        from repro.runtime.backends.jax_ppermute import JaxPpermuteBackend

        prog = _opt.as_program(program)
        if strategy == "pallas_fused":
            from repro.runtime.backends.pallas_fused import PallasFusedBackend

            return PallasFusedBackend(), prog
        if strategy == "sendrecv":
            from repro.runtime.backends.sendrecv import SendRecvBackend

            return SendRecvBackend(), prog
        if strategy == "overlap_fused":
            return JaxPpermuteBackend(overlap_fused=True), prog
        be = JaxPpermuteBackend(overlap=(strategy == "overlap"))
        return be, (_opt.optimize(prog) if strategy == "fused" else prog)

    @staticmethod
    def _global_strategy(dec: _at.Decision, n: int) -> str:
        """Analytic decisions can name a mesh-backed strategy the process
        cannot run (too few devices) — degrade to the fused global replay,
        which runs anywhere."""
        if dec.strategy in ("loop", "overlap", "xla", "overlap_fused"):
            import jax

            if jax.device_count() < n:
                return "fused"
        return dec.strategy

    # ------------------------------------------------- whole-array wrappers
    def _run(self, kind: str, x, program, *run_args, **run_kw):
        prog = _opt.as_program(program)
        _check_kind(prog, kind)
        dec = self._decide(kind, prog, _chunk_bytes(x, kind, "global"),
                           x.dtype, "global")
        strategy = self._global_strategy(dec, prog.n)
        if strategy == "xla":
            return _xla_collective(kind, prog.n, "df", prog.root or 0)(x)
        be, p = self._delegate(strategy, prog)
        return getattr(be, f"run_{kind}")(x, p, *run_args, **run_kw)

    def run_alltoall(self, x, program):
        return self._run("alltoall", x, program)

    def run_allreduce(self, x, program):
        return self._run("allreduce", x, program)

    def run_broadcast(self, x, program, *, pipelined: bool = False):
        prog = _opt.as_program(program)
        _check_kind(prog, "broadcast")
        dec = self._decide("broadcast", prog, _chunk_bytes(x, "broadcast"),
                           x.dtype, "global")
        # no global xla candidate for broadcast
        be, p = self._delegate(self._global_strategy(dec, prog.n), prog)
        return be.run_broadcast(x, p, pipelined=pipelined)

    def run_matmul(self, B, A, program):
        prog = _opt.as_program(program)
        _check_kind(prog, "matmul")
        nbytes = 0
        if prog.grid is not None:
            from repro.core.matmul import MatmulGrid

            X = B.shape[0] // MatmulGrid(*prog.grid).n
            nbytes = X * X * np.dtype(B.dtype).itemsize
        dec = self._decide("matmul", prog, nbytes, B.dtype, "global")
        be, p = self._delegate(self._global_strategy(dec, prog.n), prog)
        return be.run_matmul(B, A, p)

    # ---------------------------------------------------------- per-shard
    def alltoall(self, x, axis_name: str, program: CollectiveProgram):
        import jax

        prog = _opt.as_program(program)
        _check_kind(prog, "alltoall")
        dec = self._decide("alltoall", prog, _chunk_bytes(x, "alltoall"),
                           x.dtype, "shard")
        if dec.strategy == "xla":
            return jax.lax.all_to_all(x, axis_name, split_axis=0, concat_axis=0)
        be, p = self._delegate(dec.strategy, prog)
        return be.alltoall(x, axis_name, p)

    def alltoall_compute(self, x, axis_name: str, program: CollectiveProgram,
                         compute=None, compute_us: int = 0):
        """Fused round trip out[j] = compute_j(x[j]) (see the ppermute
        backend's ``alltoall_compute``), tuned as a full pipeline when the
        caller passes its ``compute_us`` estimate. Strategies other than
        ``overlap_fused`` fall back to the bit-identical sequential form:
        dispatch all-to-all, one batched ``compute`` over all n arrivals,
        combine all-to-all."""
        import jax

        prog = _opt.as_program(program)
        _check_kind(prog, "alltoall")
        dec = self._decide("alltoall", prog, _chunk_bytes(x, "alltoall"),
                           x.dtype, "shard", compute_us)
        if dec.strategy == "overlap_fused":
            be, p = self._delegate(dec.strategy, prog)
            return be.alltoall_compute(x, axis_name, p, compute)
        if dec.strategy == "xla":
            a2a = lambda v: jax.lax.all_to_all(
                v, axis_name, split_axis=0, concat_axis=0)
        else:
            be, p = self._delegate(dec.strategy, prog)
            a2a = lambda v: be.alltoall(v, axis_name, p)
        recv = a2a(x)
        return a2a(recv if compute is None else compute(recv))

    def allreduce(self, x, axis_name: str, program: CollectiveProgram):
        import jax

        prog = _opt.as_program(program)
        _check_kind(prog, "allreduce")
        dec = self._decide("allreduce", prog, _chunk_bytes(x, "allreduce"),
                           x.dtype, "shard")
        if dec.strategy == "xla":
            return jax.lax.psum(x, axis_name)
        be, p = self._delegate(dec.strategy, prog)
        return be.allreduce(x, axis_name, p)

    def broadcast(self, x, axis_name: str, program: CollectiveProgram,
                  *, pipelined: bool = False):
        import jax
        import jax.numpy as jnp

        prog = _opt.as_program(program)
        _check_kind(prog, "broadcast")
        dec = self._decide("broadcast", prog, _chunk_bytes(x, "broadcast"),
                           x.dtype, "shard")
        if dec.strategy == "xla" and prog.num_rounds == 1:
            return jax.lax.psum(jnp.where(
                jax.lax.axis_index(axis_name) == (prog.root or 0),
                x, jnp.zeros_like(x)), axis_name)
        be, p = self._delegate(dec.strategy if dec.strategy != "xla" else "loop",
                               prog)
        return be.broadcast(x, axis_name, p, pipelined=pipelined)

    def matmul(self, b, a, axis_name: str, program: CollectiveProgram):
        prog = _opt.as_program(program)
        _check_kind(prog, "matmul")
        nbytes = int(b.size) * np.dtype(b.dtype).itemsize
        dec = self._decide("matmul", prog, nbytes, b.dtype, "shard")
        be, p = self._delegate(dec.strategy, prog)
        return be.matmul(b, a, axis_name, p)
