"""JAX backend: replay a CollectiveProgram as ppermute collectives.

The per-shard methods (``alltoall``/``allreduce``/``broadcast``/``matmul``)
run INSIDE ``shard_map`` over a 1-D mesh axis of ``program.n`` devices
(device i = router ``topo.id_router(i)``). Each communication stage becomes
one ``jax.lax.ppermute``; the conflict-freedom ``core.simulator.verify``
proved for the schedule is the statement that a step's stages occupy
disjoint directed links on the physical D3 network, so issuing them
per-step preserves the paper's round structure (visible in the HLO as one
collective-permute per stage).

``overlap=True`` launches stages in ``start_step`` order instead of round
order: rounds of a pipelined schedule (``meta["start_step"]``) interleave,
letting XLA overlap independent ppermutes across rounds. For barrier
schedules the two orders coincide, so overlap is always safe to enable.

Emulated (guest-on-host) programs — ``runtime.rewrite.emulate`` output,
``program.active_devices`` set — replay on the full K·M·M host mesh with no
special casing: their stages are partial permutations/matchings over the
embedded device subset, ``ppermute`` hands idle (non-destination) devices
zeros, and the replay logic only folds an arrival into a device's state
when that device is a listed destination, so idle devices pass through.
A guest J·L·L-device program therefore runs on the host mesh unchanged,
stamps and pipelining included.

The ``run_*`` wrappers build the shard_map plumbing for whole-array callers
(the backend contract shared with the NumPy reference backend) and are the
executable form of the paper: MoE token dispatch calls the per-shard
``alltoall`` instead of the generic fused ``lax.all_to_all`` when
``--collectives dragonfly`` is on.

Hot-path behavior of the wrappers:

  * meshes and jitted shard_map closures are CACHED per (backend, program,
    axis, mesh, flags) — repeated collective calls (MoE dispatch per layer)
    reuse one compiled executable instead of rebuilding the mesh and
    retracing every call;
  * ``run_matmul`` scatters/gathers operand blocks (and emulated guest
    slots) entirely in jnp inside one jitted closure — no ``np.asarray``
    host sync until the caller materializes the result;
  * every ``run_*`` accepts an ``optimize.OptimizedProgram`` and routes it
    to the fused table replay (``lax.scan`` over stacked index tensors on
    the global array) instead of the per-stage ppermute loop — same bits,
    constant-size HLO;
  * ``donate=True`` on the backend donates the wrapper inputs to XLA
    (buffer reuse for callers that hand over ownership — do NOT enable it
    when the same arrays are passed again, e.g. benchmark loops).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.runtime import compat
from repro.runtime import optimize as _opt
from repro.runtime.program import (
    CollectiveProgram,
    LocalContract,
    Match,
    Perm,
    ReduceCombine,
    check_kind as _check_kind,
)


@dataclasses.dataclass(frozen=True)
class JaxPpermuteBackend:
    """One ppermute per communication stage on a 1-D router-order axis.

    ``overlap_fused=True`` replays all-to-alls through the wave-ordered
    fused-table dispatch: ONE gather of every outgoing chunk up front
    (stacked-σ table in ``start_step`` order), the per-stage ppermutes
    issued wave by wave, and ONE scatter of every arrival at the end — no
    per-stage dynamic-update-slice chain, which is what the sequential
    ``loop`` replay pays 16× over on a host mesh. The same wave order
    drives ``alltoall_compute``, the §3 Schedules 1–3 pipeline where the
    expert compute for wave w-1's arrivals trails one wave behind wave w's
    dispatch.

    ``donate=True`` donates the whole-array wrapper inputs to XLA (callers
    must not reuse the passed buffers afterwards)."""

    overlap: bool = False
    donate: bool = False
    overlap_fused: bool = False
    name: str = "jax_ppermute"

    # ---------------------------------------------------------- per-shard
    def alltoall(self, x: jax.Array, axis_name: str, program: CollectiveProgram) -> jax.Array:
        """All-to-all of per-destination chunks.

        ``x``: (n, ...) local buffer where x[j] is this device's chunk for
        device j. Returns (n, ...) where out[j] is the chunk received FROM
        device j — the ``lax.all_to_all(split_axis=0, concat_axis=0)``
        layout.

        One ppermute per source vector: for vector permutation σ, device i
        contributes x[σ(i)] and the receiver σ(i) stores the arrival at
        index σ⁻¹(σ(i)) = i, its sender. The σ/σ⁻¹ gather indices are
        precomputed on the program (cached per stage), so retraces reuse
        them instead of rebuilding host arrays.
        """
        program = _opt.as_program(program)  # per-shard path replays stages
        _check_kind(program, "alltoall")
        if x.shape[0] != program.n:
            raise ValueError(f"leading dim {x.shape[0]} != mesh axis {program.n}")
        idx = jax.lax.axis_index(axis_name)
        if self.overlap_fused:
            order = [st for w in _wave_stages(program) for st in w]
            sig = jnp.asarray(np.stack([st.sigma_np for st in order]))
            inv = jnp.asarray(np.stack([st.inverse_np for st in order]))
            all_sel = x[sig[:, idx]]  # ONE gather of every outgoing chunk
            recvs = [
                jax.lax.ppermute(all_sel[k], axis_name, st.pairs)
                for k, st in enumerate(order)
            ]
            # ONE scatter: arrivals of idle emulated devices are the zeros
            # ppermute hands non-destinations, written at their own row.
            return jnp.zeros_like(x).at[inv[:, idx]].set(jnp.stack(recvs))
        out = jnp.zeros_like(x)
        for op in self._ordered(program):
            assert isinstance(op, Perm)
            sigma = jnp.asarray(op.sigma_np)
            inv = jnp.asarray(op.inverse_np)
            sel = x[sigma[idx]]
            recv = jax.lax.ppermute(sel, axis_name, op.pairs)
            out = out.at[inv[idx]].set(recv)
        return out

    def alltoall_compute(
        self,
        x: jax.Array,
        axis_name: str,
        program: CollectiveProgram,
        compute=None,
    ) -> jax.Array:
        """Fused round trip: ship chunk x[j] to device j, apply device j's
        ``compute`` there, return the processed chunk to its sender.

        out[j] = compute_j(x[j]) — NOT the all-to-all transpose; with
        ``compute=None`` this is the identity round trip. ``compute`` is
        THIS device's batched chunk transform: called as compute(chunks)
        with chunks (V, ...), the stacked arrivals of one launch wave.

        Waves follow the program's ``start_step`` stamps (§3 Schedules 1-3
        pipelining): wave w's ppermutes are issued BEFORE wave w-1's
        arrivals go through ``compute`` and return over the inverse pairs,
        so the contraction for already-arrived chunks overlaps the next
        wave's network time. ONE gather feeds every dispatch and ONE
        scatter commits every return; the ``pending`` double buffer holds
        exactly one wave of arrivals between issue and drain. Barrier
        (unstamped) programs degenerate to a single wave — all compute
        after all dispatch — so pass a pipelined lowering to overlap."""
        program = _opt.as_program(program)
        _check_kind(program, "alltoall")
        if x.shape[0] != program.n:
            raise ValueError(f"leading dim {x.shape[0]} != mesh axis {program.n}")
        waves = _wave_stages(program)
        order = [st for w in waves for st in w]
        idx = jax.lax.axis_index(axis_name)
        sig = jnp.asarray(np.stack([st.sigma_np for st in order]))
        dests = sig[:, idx]  # stage k ships this device's chunk for σ_k(idx)
        all_sel = x[dests]
        backs: list = [None] * len(order)

        def drain(pending):
            if not pending:
                return
            stacked = jnp.stack([r for _, r in pending])
            ys = stacked if compute is None else compute(stacked)
            for j, (k, _) in enumerate(pending):
                inv_pairs = tuple((d, s) for s, d in order[k].pairs)
                backs[k] = jax.lax.ppermute(ys[j], axis_name, inv_pairs)

        pending: list = []
        k = 0
        for wave in waves:
            newly = []
            for st in wave:
                newly.append((k, jax.lax.ppermute(all_sel[k], axis_name, st.pairs)))
                k += 1
            drain(pending)
            pending = newly
        drain(pending)
        # Idle emulated devices: dests == idx, backs are ppermute zeros —
        # their row is written with zeros and every other row stays zero.
        return jnp.zeros_like(x).at[dests].set(jnp.stack(backs))

    def allreduce(self, x: jax.Array, axis_name: str, program: CollectiveProgram) -> jax.Array:
        """Recursive-doubling all-reduce (sum): one pairwise exchange per
        cube dimension — the §4 ascend algorithm on the emulated
        hypercube."""
        program = _opt.as_program(program)
        _check_kind(program, "allreduce")
        idx = jax.lax.axis_index(axis_name)
        for st in self._ordered(program):
            assert isinstance(st, ReduceCombine)
            recv = jax.lax.ppermute(x, axis_name, st.link_pairs)
            if st.self_mask_np.any():  # local contributions (identity pairs)
                recv = recv + jnp.where(jnp.asarray(st.self_mask_np)[idx], x, 0)
            x = x + recv
        return x

    def broadcast(
        self,
        x: jax.Array,
        axis_name: str,
        program: CollectiveProgram,
        *,
        pipelined: bool = False,
    ) -> jax.Array:
        """Spanning-tree broadcast from ``program.root``: each stage is a
        masked partial ppermute; non-receivers keep their value, so after
        the last stage every device holds the root's value.

        Multi-round (pipelined wave) programs take ``x`` with a leading
        wave dim (num_rounds, ...); wave w's tree moves slice x[w].
        ``pipelined=True`` (or ``overlap`` on the backend) replays in
        start_step order — cross-round overlap where start_step permits."""
        program = _opt.as_program(program)
        _check_kind(program, "broadcast")
        idx = jax.lax.axis_index(axis_name)
        waves = program.num_rounds > 1
        val = x
        for group in program.step_groups(pipelined=pipelined or self.overlap):
            pre = val
            for st in group:
                assert isinstance(st, Match)
                sent = pre[st.round_index] if waves else pre
                recv = jax.lax.ppermute(sent, axis_name, st.pairs)
                mask = jnp.asarray(st.dst_mask_np)[idx]
                if waves:
                    val = val.at[st.round_index].set(
                        jnp.where(mask, recv, val[st.round_index])
                    )
                else:
                    val = jnp.where(mask, recv, val)
        return val

    def matmul(
        self, b: jax.Array, a: jax.Array, axis_name: str, program: CollectiveProgram
    ) -> jax.Array:
        """§2 block product: ``b``/``a`` are this device's (X, X) blocks of
        B and A in the paper's storage map; returns the device's (X, X)
        block of B @ A. Per-device state is (val, acc) driven by the
        program's LocalContract stages; every hop is a ppermute — no
        ``all_gather``, the HLO shows Theorem 1's round structure."""
        program = _opt.as_program(program)
        _check_kind(program, "matmul")
        idx = jax.lax.axis_index(axis_name)
        dtype = jnp.result_type(b, a)
        val = jnp.zeros(b.shape, dtype)
        acc = jnp.zeros(b.shape, dtype)
        c = jnp.zeros(b.shape, dtype)
        for group in program.step_groups(pipelined=self.overlap):
            if isinstance(group[0], LocalContract):
                (st,) = group
                if st.fn == "load_b":
                    val = b.astype(dtype)
                    acc = jnp.zeros_like(acc)
                elif st.fn == "mul_a":
                    val = val @ a.astype(dtype)  # the off-network block product
                    acc = jnp.zeros_like(acc)
                elif st.fn == "promote":
                    val, acc = acc, jnp.zeros_like(acc)
                elif st.fn == "store_c":
                    c = jnp.where(jnp.asarray(st.mask_np)[idx], val, c)
                continue
            pre = val
            for st in group:
                if isinstance(st, Match):
                    recv = jax.lax.ppermute(pre, axis_name, st.pairs)
                    val = jnp.where(jnp.asarray(st.dst_mask_np)[idx], recv, val)
                elif isinstance(st, ReduceCombine):
                    recv = jax.lax.ppermute(pre, axis_name, st.link_pairs)
                    if st.self_mask_np.any():
                        recv = recv + jnp.where(
                            jnp.asarray(st.self_mask_np)[idx], pre, 0
                        )
                    acc = acc + recv
                else:  # pragma: no cover - lowering never emits Perm here
                    raise TypeError(f"unexpected stage {st!r} in matmul program")
        return c

    def _ordered(self, program: CollectiveProgram):
        return program.pipelined_stages() if self.overlap else program.stages

    # ------------------------------------------------- whole-array wrappers
    def run_alltoall(
        self, x_global, program, axis_name: str = "df", mesh: Mesh | None = None
    ):
        """x_global: (n, n, ...) where x_global[i, j] is the chunk device i
        sends to device j; returns (n, n, ...) with out[i, j] =
        x_global[j, i, ...] moved by the paper's round schedule.

        ``OptimizedProgram`` inputs take the fused table replay on the
        GLOBAL array — there is no shard_map, so ``axis_name``/``mesh``
        do not apply on that path (``donate`` still does)."""
        if isinstance(program, _opt.OptimizedProgram):
            _check_kind(program.program, "alltoall")
            if self.overlap_fused:
                return _opt.jax_alltoall_overlapped(
                    program, donate=self.donate)(x_global)
            return _opt.jax_alltoall(program, self.donate)(x_global)
        return _compiled_collective(self, program, "alltoall", axis_name, mesh,
                                    False)(x_global)

    def run_alltoall_compute(
        self,
        x_global,
        program,
        compute=None,
        weights=(),
        axis_name: str = "df",
        mesh: Mesh | None = None,
    ):
        """x_global: (n, n, ...) with x_global[i, j] the chunk device i sends
        to device j; returns out[i, j] = compute_j(x_global[i, j]) — every
        chunk processed AT its destination j and returned to its sender
        (round trip), NOT the all-to-all transpose.

        ``compute(chunks, *wl)`` runs per shard: chunks is one wave's (V,
        ...) stacked arrivals and ``wl`` holds the device's row of every
        array in ``weights`` (each (n, ...), sharded over the axis). The
        jitted shard_map closure is cached per (backend, program, compute,
        arity) — pass a stable ``compute`` callable, not a per-call lambda,
        to reuse the compiled executable."""
        prog = _opt.as_program(program)
        _check_kind(prog, "alltoall")
        return _compiled_alltoall_compute(
            self, prog, compute, len(weights), axis_name, mesh
        )(x_global, *weights)

    def run_allreduce(
        self, x_global, program, axis_name: str = "df", mesh: Mesh | None = None
    ):
        if isinstance(program, _opt.OptimizedProgram):
            _check_kind(program.program, "allreduce")
            return _opt.jax_allreduce(program, self.donate)(x_global)
        return _compiled_collective(self, program, "allreduce", axis_name,
                                    mesh, False)(x_global)

    def run_broadcast(
        self,
        x_global,
        program,
        axis_name: str = "df",
        mesh: Mesh | None = None,
        *,
        pipelined: bool = False,
    ):
        """Single round: x (n, ...). Pipelined waves: x (R, n, ...) with the
        device axis second. Optimized programs replay their fused tables on
        the global array (``axis_name``/``mesh`` do not apply) — barrier
        order, bit-identical to the pipelined result."""
        if isinstance(program, _opt.OptimizedProgram):
            _check_kind(program.program, "broadcast")
            return _opt.jax_broadcast(program, self.donate)(x_global)
        return _compiled_collective(self, program, "broadcast", axis_name,
                                    mesh, pipelined)(x_global)

    def run_matmul(
        self, B, A, program, axis_name: str = "df", mesh: Mesh | None = None
    ):
        """B, A: (N·X, N·X) matrices -> B @ A via the §2 rounds on a mesh of
        ``program.n`` devices in router order. Emulated programs scatter the
        guest's blocks to their ``active_devices`` slots of the host mesh
        (grid metadata is the GUEST grid) and gather them back. The whole
        scatter -> replay -> gather pipeline is one cached jit — blocks
        never round-trip through the host; the caller materializes the
        returned device array when it actually needs the bytes."""
        prog = _opt.as_program(program)
        _check_kind(prog, "matmul")
        if prog.grid is None:
            raise ValueError("matmul program lacks grid metadata")
        return _compiled_matmul(self, program, axis_name, mesh)(B, A)


@functools.lru_cache(maxsize=None)
def _wave_stages(program: CollectiveProgram) -> tuple[tuple[Perm, ...], ...]:
    """Stages grouped by launch wave — one tuple per distinct ``start_step``
    value, waves in launch order, stage order preserved inside a wave.
    Barrier (unstamped) programs collapse to a single wave. Mirrors
    ``core.alltoall.wave_rounds`` at the lowered-program level."""
    waves: dict[int, list[Perm]] = {}
    for st in program.pipelined_stages():
        assert isinstance(st, Perm)
        waves.setdefault(st.start_step, []).append(st)
    return tuple(tuple(waves[s]) for s in sorted(waves))


@functools.lru_cache(maxsize=None)
def _compiled_alltoall_compute(backend: JaxPpermuteBackend,
                               program: CollectiveProgram, compute,
                               n_weights: int, axis_name: str,
                               mesh: Mesh | None):
    """Jitted shard_map closure for the fused dispatch+compute round trip,
    cached per (backend, program, compute, weight arity, axis, mesh)."""
    _check_kind(program, "alltoall")
    mesh = mesh or _axis_mesh(program.n, axis_name)

    def local(s, *ws):
        wl = [w[0] for w in ws]
        fn = None if compute is None else (lambda chunks: compute(chunks, *wl))
        return backend.alltoall_compute(s[0], axis_name, program, fn)[None]

    f = compat.shard_map(
        local, mesh=mesh,
        in_specs=(P(axis_name),) * (1 + n_weights),
        out_specs=P(axis_name),
    )
    donate = (0,) if backend.donate else ()
    return jax.jit(f, donate_argnums=donate)


@functools.lru_cache(maxsize=None)
def _axis_mesh(n: int, axis_name: str) -> Mesh:
    """1-D device mesh in router order, cached per (n, axis) — the device
    list is fixed for the process lifetime."""
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(f"need {n} devices for the lowered program, have {len(devs)}")
    return Mesh(np.array(devs[:n]), (axis_name,))


@functools.lru_cache(maxsize=None)
def _compiled_collective(backend: JaxPpermuteBackend, program: CollectiveProgram,
                         kind: str, axis_name: str, mesh: Mesh | None,
                         pipelined: bool):
    """Jitted shard_map closure for a whole-array replay, cached per
    (backend, program, axis, mesh, flags) so repeated collective calls
    don't rebuild the mesh or retrace (programs and Mesh are hashable)."""
    _check_kind(program, kind)
    mesh = mesh or _axis_mesh(program.n, axis_name)
    donate = (0,) if backend.donate else ()
    if kind == "broadcast":
        waves = program.num_rounds > 1
        spec = P(None, axis_name) if waves else P(axis_name)

        def local(s):
            s = s[:, 0] if waves else s[0]
            out = backend.broadcast(s, axis_name, program, pipelined=pipelined)
            return out[:, None] if waves else out[None]

        f = compat.shard_map(local, mesh=mesh, in_specs=spec, out_specs=spec)
        return jax.jit(f, donate_argnums=donate)

    method = backend.alltoall if kind == "alltoall" else backend.allreduce
    f = compat.shard_map(
        lambda s: method(s[0], axis_name, program)[None],
        mesh=mesh, in_specs=P(axis_name), out_specs=P(axis_name),
    )
    return jax.jit(f, donate_argnums=donate)


@functools.lru_cache(maxsize=None)
def _compiled_matmul(backend: JaxPpermuteBackend, program, axis_name: str,
                     mesh: Mesh | None):
    """One jitted closure per (backend, program): jnp block scatter (+ guest
    scatter for emulated programs) -> per-shard replay (or the fused table
    scan for ``OptimizedProgram``) -> jnp gather. No host syncs inside."""
    prog = _opt.as_program(program)
    grid = prog.grid
    if isinstance(program, _opt.OptimizedProgram):
        replay = _opt.build_jax_matmul(program)
    else:
        m = mesh or _axis_mesh(prog.n, axis_name)
        replay = compat.shard_map(
            lambda bb, aa: backend.matmul(bb[0], aa[0], axis_name, program)[None],
            mesh=m, in_specs=(P(axis_name), P(axis_name)),
            out_specs=P(axis_name),
        )

    def f(B, A):
        b = _opt.jax_scatter_guest(_opt.jax_scatter_blocks(B, grid), prog)
        a = _opt.jax_scatter_guest(_opt.jax_scatter_blocks(A, grid), prog)
        c = replay(b, a)
        return _opt.jax_gather_blocks(_opt.jax_gather_guest(c, prog), grid)

    donate = (0, 1) if backend.donate else ()
    return jax.jit(f, donate_argnums=donate)
