"""Pallas-fused backend: fused table replay with Pallas kernels on the
reduce and contraction hot spots.

Third implementation of the backend contract (``runtime/__init__.py``).
Where ``jax_ppermute`` issues one collective per stage to keep the paper's
round structure visible in the HLO, this backend replays the OPTIMIZED form
of the program (``runtime.optimize``) and pushes the two compute-bound
pieces into Pallas kernels:

  * the per-round permute+accumulate of the allreduce / matmul
    ``ReduceCombine`` stages runs as ONE kernel per program (allreduce) or
    per fused group (matmul): the stacked (gather, mask) tables drive a
    ``fori_loop`` inside the kernel, so every round's gather lands in VMEM
    and the accumulation never leaves the core — the kernel-side analog of
    a remote-DMA ring step (see ``_rdma_exchange_kernel`` for the actual
    inter-chip pattern);
  * the §2 ``mul_a`` local contraction routes through the existing MXU-tiled
    ``kernels/block_matmul`` Pallas kernel (vmapped over the router-block
    axis) instead of a bare ``@``.

Interpret-mode caveats
----------------------
CPU CI runs every kernel with ``interpret=True`` (the Pallas interpreter
executes kernel bodies op-by-op): numerically identical to the compiled
kernel, but *slow* — the smoke tests keep shapes tiny, and the benchmark
rows labeled ``pallas_fused`` on a CPU host measure the interpreter, not
the hardware. On a TPU host (``jax.default_backend() == "tpu"``) the same
entry points compile the kernels for real, and ``run_allreduce`` routes the
inter-device exchange through ``_rdma_exchange_kernel`` — a
``make_async_remote_copy`` ring step per round (remote-DMA pattern per the
Pallas guide) inside the caller's mesh. That path needs physical chips and
is exercised only on TPU pods, never by the interpret-mode CI.

``run_alltoall`` / ``run_broadcast`` are pure data movement with no
compute to fuse — they delegate to the optimizer's table replay (one
batched scatter / one ``lax.scan`` over masked gathers), which is already
the fastest XLA-expressible form.

All four entry points are bit-exact against the reference backend on the
same programs, native and emulated — differential-tested by
``tests/test_pallas_fused.py`` without any device requirement.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime import optimize as _opt
from repro.runtime.program import CollectiveProgram, check_kind as _check_kind


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# Kernels.
# ---------------------------------------------------------------------------

def _reduce_rounds_kernel(g_ref, m_ref, x_ref, o_ref):
    """Replay R permute+accumulate rounds over the whole (n, F) buffer:
    round r adds ``where(mask[r, k], val[gather[r, k]], 0)`` rows (stage
    order) into every device's slot. Tables ride in as int32 tensors; the
    gather stays in VMEM across all rounds."""
    rounds, k_rows = g_ref.shape[0], g_ref.shape[1]

    def round_body(r, val):
        recv = jnp.zeros_like(val)
        for k in range(k_rows):  # static row count — unrolled, stage order
            rows = jnp.take(val, g_ref[r, k], axis=0)
            recv = recv + jnp.where((m_ref[r, k] != 0)[:, None], rows, 0)
        return val + recv

    o_ref[...] = jax.lax.fori_loop(0, rounds, round_body, x_ref[...])


def _combine_group_kernel(g_ref, m_ref, v_ref, o_ref):
    """One fused ReduceCombine group: out = Σ_k where(mask[k], val[gather[k]], 0)
    with rows folded in stage order (bit-exact accumulation)."""
    val = v_ref[...]
    acc = jnp.zeros_like(val)
    for k in range(g_ref.shape[0]):
        acc = acc + jnp.where((m_ref[k] != 0)[:, None],
                              jnp.take(val, g_ref[k], axis=0), 0)
    o_ref[...] = acc


def _rdma_exchange_kernel(partner_ref, x_ref, o_ref, send_sem, recv_sem):
    """TPU-only ring step: ship this device's buffer to ``partner`` over the
    interconnect (remote-DMA pattern per the Pallas guide). Runs inside
    shard_map; ``partner_ref`` is scalar-prefetched per device."""
    from jax.experimental.pallas import tpu as pltpu

    rdma = pltpu.make_async_remote_copy(
        src_ref=x_ref,
        dst_ref=o_ref,
        send_sem=send_sem,
        recv_sem=recv_sem,
        device_id=(partner_ref[0],),
        device_id_type=pltpu.DeviceIdType.LOGICAL,
    )
    rdma.start()
    rdma.wait()


def _tpu_ring_exchange(x, partner, axis_name):  # pragma: no cover - TPU only
    """Per-shard remote-DMA permute: send local ``x`` to ``partner``."""
    from jax.experimental import pallas as pl
    from jax.experimental.pallas import tpu as pltpu

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(1,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.VMEM)],
        out_specs=pl.BlockSpec(memory_space=pltpu.VMEM),
        scratch_shapes=[pltpu.SemaphoreType.DMA(()), pltpu.SemaphoreType.DMA(())],
    )
    return pl.pallas_call(
        _rdma_exchange_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        compiler_params=pltpu.CompilerParams(has_side_effects=True,
                                             collective_id=0),
    )(partner.reshape(1), x)


@functools.lru_cache(maxsize=None)
def _allreduce_executor(opt: _opt.OptimizedProgram, interpret: bool):
    from jax.experimental import pallas as pl

    gat, msk = _opt.stacked_combine_tables(opt)
    msk = msk.astype(np.int32)  # kernel tables: bool -> int32 lanes
    n = opt.n

    @jax.jit
    def run(x):
        flat = x.reshape(n, -1)
        out = pl.pallas_call(
            _reduce_rounds_kernel,
            out_shape=jax.ShapeDtypeStruct(flat.shape, flat.dtype),
            interpret=interpret,
        )(gat, msk, flat)
        return out.reshape(x.shape)

    return run


@functools.lru_cache(maxsize=None)
def _matmul_executor(opt: _opt.OptimizedProgram, interpret: bool):
    from jax.experimental import pallas as pl

    from repro.kernels.block_matmul.ops import batched_matmul

    n = opt.n

    def combine_fn(acc, val, gather, mask):
        flat = val.reshape(n, -1)
        out = pl.pallas_call(
            _combine_group_kernel,
            out_shape=jax.ShapeDtypeStruct(flat.shape, flat.dtype),
            interpret=interpret,
        )(gather.astype(jnp.int32), mask.astype(jnp.int32), flat)
        return acc + out.reshape(val.shape)

    def mul_fn(val, a):
        return batched_matmul(val, a, interpret=interpret)

    return jax.jit(_opt.build_jax_matmul(opt, mul_fn=mul_fn,
                                         combine_fn=combine_fn))


# ---------------------------------------------------------------------------
# The backend.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PallasFusedBackend:
    """Fused table replay + Pallas kernels on the reduce/contract hot path.

    ``interpret=None`` auto-selects: compiled kernels on TPU, interpreter
    everywhere else (the CPU CI path).
    """

    interpret: bool | None = None
    name: str = "pallas_fused"

    def _interp(self) -> bool:
        return (not _on_tpu()) if self.interpret is None else self.interpret

    def _optimized(self, program, kind: str) -> _opt.OptimizedProgram:
        prog = _opt.as_program(program)
        _check_kind(prog, kind)
        return program if isinstance(program, _opt.OptimizedProgram) \
            else _opt.optimize(program)

    # ------------------------------------------------------------- contract
    def run_alltoall(self, x, program):
        opt = self._optimized(program, "alltoall")
        return _opt.jax_alltoall(opt)(x)

    def run_allreduce(self, x, program):
        opt = self._optimized(program, "allreduce")
        return _allreduce_executor(opt, self._interp())(x)

    def run_broadcast(self, x, program, *, pipelined: bool = False):
        # fused replay is order-free: barrier == pipelined bit-for-bit
        opt = self._optimized(program, "broadcast")
        return _opt.jax_broadcast(opt)(x)

    def run_matmul(self, B, A, program):
        opt = self._optimized(program, "matmul")
        prog = opt.program
        if prog.grid is None:
            raise ValueError("matmul program lacks grid metadata")
        replay = _matmul_executor(opt, self._interp())
        b = _opt.jax_scatter_guest(_opt.jax_scatter_blocks(B, prog.grid), prog)
        a = _opt.jax_scatter_guest(_opt.jax_scatter_blocks(A, prog.grid), prog)
        return _opt.jax_gather_blocks(_opt.jax_gather_guest(replay(b, a), prog),
                                      prog.grid)

    # ------------------------------------------------- per-shard (TPU ring)
    def allreduce_shard(self, x, axis_name: str,
                        program: CollectiveProgram):  # pragma: no cover - TPU
        """Per-shard §4 all-reduce with the remote-DMA ring kernel: one
        RDMA exchange + local accumulate per round. TPU meshes only — the
        interpreter cannot simulate cross-chip DMA, which is why CPU CI
        exercises ``run_allreduce``'s table kernel instead."""
        prog = _opt.as_program(program)
        _check_kind(prog, "allreduce")
        if not _on_tpu():
            raise RuntimeError(
                "allreduce_shard needs TPU remote DMA; use run_allreduce "
                "(interpret-mode table kernel) on CPU hosts"
            )
        idx = jax.lax.axis_index(axis_name)
        for st in prog.comm_stages:
            if not st.is_full_permutation:
                raise ValueError(
                    "RDMA ring path handles native (full-involution) "
                    "programs; replay emulated programs via run_allreduce"
                )
            partner = jnp.asarray(st.inverse_np)[idx]
            recv = _tpu_ring_exchange(x, partner.astype(jnp.int32), axis_name)
            x = x + recv
        return x
