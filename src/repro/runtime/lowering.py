"""Mechanical lowering: Schedule IR -> backend-neutral ``CollectiveProgram``.

One entry point, ``lower(schedule)``, dispatches on per-round metadata
instead of per-algorithm functions — all four of the paper's algorithms
arrive here as the same IR and leave as the same program type:

  * *vector rounds* (``meta["vectors"]``) — one full device ``Perm`` per
    source vector (Property 1 makes every vector a bijection of the router
    set): the §3 doubly-parallel all-to-all;
  * *exchange rounds* (``meta["pairs"]``) — one full-permutation
    ``ReduceCombine`` per round, the endpoint involution of the §4
    hypercube dimension exchanges (combine = sum for all-reduce);
  * *matmul rounds* (``meta["matmul"]``) — the §2 4-phase round becomes
    ``LocalContract('load_b')``, the juxtaposition ``Match`` matchings, a
    ``LocalContract('mul_a')`` block product, the mirrored-accumulation
    ``ReduceCombine`` matchings (identity pairs = local adds), accumulator
    promotions, the Z-fix ``Match`` and a masked ``LocalContract('store_c')``;
  * *tree rounds* (stepped spanning-tree hops, anything else) — per-step
    maximal matchings (``Match``), the §5 broadcasts.

Device index = ``topo.router_id`` (the linear c·M²+d·M+p order), so a 1-D
mesh axis of K·M² devices is the D3 network and the conflict-freedom the
simulator proved for the IR is exactly the claim that each lowered step's
stages can fly concurrently on the physical links.

Every stage is stamped with the IR ``(round_index, step)`` it came from and
a ``start_step``: the round's ``meta["start_step"]`` launch offset when
present (pipelined schedules), else the barrier-replay base — so a stable
sort by ``start_step`` IS the pipelined replay and barrier programs are
unchanged by it.

Lowering is pure Python on hashable IR — no jax imports — so it can be
cached per (topology, schedule) and reused across traces.
"""

from __future__ import annotations

from repro.core.schedule import Round, Schedule, permutation_of_vector
from repro.core.topology import D3
from repro.runtime.program import (
    CollectiveProgram,
    LocalContract,
    Match,
    Perm,
    ReduceCombine,
    Stage,
)


def lower(schedule: Schedule, *, optimized: bool = False):
    """Lower any Schedule to a ``CollectiveProgram`` by round metadata.

    ``optimized=True`` additionally runs the fusion pass and returns the
    ``runtime.optimize.OptimizedProgram`` (batched table ops; replayable by
    every backend) — the one-call path from IR to the fast replay form.
    """
    if not schedule.rounds:
        raise ValueError(f"empty schedule {schedule.name!r}")
    family = _round_family(schedule.rounds[0])
    for rnd in schedule.rounds[1:]:
        if _round_family(rnd) != family:
            raise ValueError(
                f"schedule {schedule.name!r} mixes round families; "
                f"got {family} then {_round_family(rnd)}"
            )
    program = _LOWERERS[family](schedule)
    if optimized:
        from repro.runtime.optimize import optimize

        return optimize(program)
    return program


def _round_family(rnd: Round) -> str:
    if "vectors" in rnd.meta:
        return "vector"
    if "pairs" in rnd.meta:
        return "exchange"
    if "matmul" in rnd.meta:
        return "matmul"
    return "tree"


def _round_start(rnd: Round, barrier_base: int) -> int:
    """Launch step of a round: its pipelined offset if stamped, else the
    barrier base — so ``start_step`` ordering replays pipelined schedules
    and leaves barrier schedules untouched."""
    start = rnd.meta.get("start_step")
    return barrier_base if start is None else start


# --------------------------------------------------------------- all-to-all
def _lower_vector(schedule: Schedule) -> CollectiveProgram:
    """Each round's s vectors -> s device permutations (one ppermute each).
    K·M²/s rounds × s vectors = K·M² permutes for the full exchange."""
    topo = schedule.topo
    stages: list[Stage] = []
    base = 0
    for i, rnd in enumerate(schedule.rounds):
        start = _round_start(rnd, base)
        for v in rnd.meta["vectors"]:
            stages.append(
                Perm(tuple(permutation_of_vector(topo, v)),
                     round_index=i, step=0, start_step=start)
            )
        base += rnd.num_steps
    return CollectiveProgram(
        "alltoall", topo.num_routers, schedule.num_rounds, tuple(stages),
        name=schedule.name,
    )


# ---------------------------------------------------------------- exchange
def _lower_exchange(schedule: Schedule) -> CollectiveProgram:
    """One full-permutation combine per round from meta['pairs'] (hypercube
    dimension exchanges: involutions over the node set)."""
    n = schedule.topo.num_routers
    stages: list[Stage] = []
    base = 0
    for i, rnd in enumerate(schedule.rounds):
        stages.append(
            ReduceCombine(n, tuple(rnd.meta["pairs"]),
                          round_index=i, step=0,
                          start_step=_round_start(rnd, base))
        )
        base += rnd.num_steps
    return CollectiveProgram(
        "allreduce", n, schedule.num_rounds, tuple(stages), name=schedule.name,
    )


# --------------------------------------------------------------- broadcast
def hops_to_matchings(topo: D3, rnd: Round) -> list[tuple[int, tuple]]:
    """Decompose a tree round's hops, step by step, into (step, pairs)
    matchings. Within a step a source may fan out to several children
    (packet duplication); each fan-out degree becomes one matching. Step
    order is preserved so data dependencies (parent before child) hold."""
    out: list[tuple[int, tuple]] = []
    for step in range(rnd.num_steps):
        remaining = [(topo.router_id(h.src), topo.router_id(h.dst)) for h in rnd.hops_at(step)]
        while remaining:
            used_src: set[int] = set()
            used_dst: set[int] = set()
            matching: list[tuple[int, int]] = []
            rest: list[tuple[int, int]] = []
            for s, d in remaining:
                if s not in used_src and d not in used_dst:
                    used_src.add(s)
                    used_dst.add(d)
                    matching.append((s, d))
                else:
                    rest.append((s, d))
            out.append((step, tuple(matching)))
            remaining = rest
    return out


def _broadcast_root(schedule: Schedule) -> int:
    """Resolve the root device id. Explicit ``is None`` checks: router id 0
    and router (0, 0, 0) are legitimate falsy-looking roots."""
    root = schedule.meta.get("root")
    if root is None:
        root = schedule.meta.get("source")
    if root is None:
        raise ValueError(
            f"broadcast schedule {schedule.name!r} lacks meta['root']/['source']"
        )
    if isinstance(root, int):
        return root
    return schedule.topo.router_id(root)


def _lower_tree(schedule: Schedule) -> CollectiveProgram:
    """Spanning-tree rounds -> ordered masked matchings. Multi-round
    schedules are pipelined broadcast waves: round w's stages act on wave
    slice w and carry its ``start_step`` launch offset."""
    topo = schedule.topo
    n = topo.num_routers
    stages: list[Stage] = []
    base = 0
    for i, rnd in enumerate(schedule.rounds):
        start = _round_start(rnd, base)
        for step, pairs in hops_to_matchings(topo, rnd):
            stages.append(Match(n, pairs, round_index=i, step=step,
                                start_step=start + step))
        base += rnd.num_steps
    return CollectiveProgram(
        "broadcast", n, schedule.num_rounds, tuple(stages),
        root=_broadcast_root(schedule), name=schedule.name,
    )


# ------------------------------------------------------------------ matmul
def _lower_matmul(schedule: Schedule) -> CollectiveProgram:
    """§2 rounds -> the program the paper's Theorem 1 executes per row:

        load_b; K+M-1 bcast matchings; mul_a; K+M reduce-combines;
        promote; zfix match; store_c(mask)

    with a ``promote`` between the global and nothing else — the two
    accumulator promotions realize the paper's two off-and-ons."""
    topo = schedule.topo
    n = topo.num_routers
    grid = None
    stages: list[Stage] = []
    base = 0
    for i, rnd in enumerate(schedule.rounds):
        mm = rnd.meta["matmul"]
        grid = rnd.meta.get("grid", grid)
        start = _round_start(rnd, base)
        stages.append(LocalContract("load_b", round_index=i, step=0,
                                    start_step=start))
        for step, pairs in mm["bcast"]:
            stages.append(Match(n, pairs, round_index=i, step=step,
                                start_step=start + step))
        stages.append(LocalContract("mul_a", round_index=i, step=2,
                                    start_step=start + 2))
        glob = [sp for sp in mm["reduce"] if sp[0] == 2]
        loc = [sp for sp in mm["reduce"] if sp[0] != 2]
        for step, pairs in glob:
            stages.append(ReduceCombine(n, pairs, round_index=i, step=step,
                                        start_step=start + step))
        stages.append(LocalContract("promote", round_index=i, step=3,
                                    start_step=start + 3))
        for step, pairs in loc:
            stages.append(ReduceCombine(n, pairs, round_index=i, step=step,
                                        start_step=start + step))
        stages.append(LocalContract("promote", round_index=i, step=4,
                                    start_step=start + 4))
        zstep, zpairs = mm["zfix"]
        if zpairs:
            stages.append(Match(n, zpairs, round_index=i, step=zstep,
                                start_step=start + zstep))
        stages.append(LocalContract("store_c", mask=mm["store_mask"], n=n,
                                    round_index=i, step=zstep + 1,
                                    start_step=start + zstep + 1))
        base += rnd.num_steps + 1  # + the zfix storage hop
    return CollectiveProgram(
        "matmul", n, schedule.num_rounds, tuple(stages), grid=grid,
        name=schedule.name,
    )


_LOWERERS = {
    "vector": _lower_vector,
    "exchange": _lower_exchange,
    "tree": _lower_tree,
    "matmul": _lower_matmul,
}


# ---------------------------------------------------------------------------
# Named entry points retained as thin wrappers over ``lower`` — they assert
# the caller got the program family it expected.
# ---------------------------------------------------------------------------

def _expect(schedule: Schedule, kind: str) -> CollectiveProgram:
    prog = lower(schedule)
    if prog.kind != kind:
        raise ValueError(
            f"schedule {schedule.name!r} lowered to {prog.kind!r}, expected {kind!r}"
        )
    return prog


def lower_alltoall(schedule: Schedule) -> CollectiveProgram:
    return _expect(schedule, "alltoall")


def lower_exchange(schedule: Schedule) -> CollectiveProgram:
    return _expect(schedule, "allreduce")


def lower_broadcast(schedule: Schedule) -> CollectiveProgram:
    return _expect(schedule, "broadcast")


def lower_matmul(schedule: Schedule) -> CollectiveProgram:
    return _expect(schedule, "matmul")
