"""Mechanical lowering: Schedule IR -> device-mesh collective programs.

A ``Schedule``'s rounds become sequences of primitive mesh operations:

  * a *vector round* (``meta["vectors"]``) lowers to one full device
    permutation per vector — Property 1 makes every source vector a
    bijection of the router set, so each vector is exactly one ``ppermute``;
  * an *exchange round* (``meta["pairs"]``) lowers to one permutation, the
    endpoint map of its emulation paths (hypercube dimension rounds);
  * a *tree round* (spanning-tree hops) lowers per step into *matchings* —
    maximal hop subsets where every device sends at most once and receives
    at most once — each a masked partial ``ppermute``.

Device index = ``topo.router_id`` (the linear c·M²+d·M+p order), so a 1-D
mesh axis of K·M² devices is the D3 network and the conflict-freedom the
simulator proved for the IR is exactly the claim that each lowered round's
permutations can fly concurrently on the physical links.

Lowering is pure Python on hashable IR — no jax imports — so it can be
cached per (topology, schedule) and reused across traces.
"""

from __future__ import annotations

import dataclasses

from repro.core.schedule import Round, Schedule, permutation_of_vector
from repro.core.topology import D3


@dataclasses.dataclass(frozen=True)
class PermOp:
    """One full permutation over device ids: device i sends to sigma[i]."""

    pairs: tuple[tuple[int, int], ...]

    @property
    def sigma(self) -> tuple[int, ...]:
        out = [0] * len(self.pairs)
        for s, d in self.pairs:
            out[s] = d
        return tuple(out)

    @property
    def inverse(self) -> tuple[int, ...]:
        out = [0] * len(self.pairs)
        for s, d in self.pairs:
            out[d] = s
        return tuple(out)

    def __post_init__(self) -> None:
        srcs = {s for s, _ in self.pairs}
        dsts = {d for _, d in self.pairs}
        if len(srcs) != len(self.pairs) or dsts != srcs:
            raise ValueError("PermOp pairs must form a permutation")


@dataclasses.dataclass(frozen=True)
class MatchOp:
    """One matching (partial permutation): receivers are masked in."""

    pairs: tuple[tuple[int, int], ...]

    @property
    def dsts(self) -> tuple[int, ...]:
        return tuple(d for _, d in self.pairs)

    def __post_init__(self) -> None:
        if len({s for s, _ in self.pairs}) != len(self.pairs):
            raise ValueError("MatchOp sources must be distinct")
        if len({d for _, d in self.pairs}) != len(self.pairs):
            raise ValueError("MatchOp destinations must be distinct")


@dataclasses.dataclass(frozen=True)
class LoweredAllToAll:
    n: int
    rounds: tuple[tuple[PermOp, ...], ...]

    @property
    def num_permutes(self) -> int:
        return sum(len(r) for r in self.rounds)


@dataclasses.dataclass(frozen=True)
class LoweredExchange:
    n: int
    rounds: tuple[PermOp, ...]


@dataclasses.dataclass(frozen=True)
class LoweredBroadcast:
    n: int
    root: int
    stages: tuple[MatchOp, ...]


# --------------------------------------------------------------------------

def lower_alltoall(schedule: Schedule) -> LoweredAllToAll:
    """Each round's s vectors -> s device permutations (one ppermute each).
    K·M²/s rounds × s vectors = K·M² permutes for the full exchange."""
    topo = schedule.topo
    rounds = []
    for rnd in schedule.rounds:
        vecs = rnd.meta.get("vectors")
        if vecs is None:
            raise ValueError(f"round lacks meta['vectors']; not a vector round: {rnd.meta}")
        rounds.append(
            tuple(PermOp(tuple(permutation_of_vector(topo, v))) for v in vecs)
        )
    return LoweredAllToAll(topo.num_routers, tuple(rounds))


def lower_exchange(schedule: Schedule) -> LoweredExchange:
    """One permutation per round from meta['pairs'] (hypercube dimension
    exchanges: involutions over the node set)."""
    n = schedule.topo.num_routers
    rounds = []
    for rnd in schedule.rounds:
        pairs = rnd.meta.get("pairs")
        if pairs is None:
            raise ValueError(f"round lacks meta['pairs']: {rnd.meta}")
        rounds.append(PermOp(tuple(pairs)))
    return LoweredExchange(n, tuple(rounds))


def hops_to_matchings(topo: D3, rnd: Round) -> list[MatchOp]:
    """Decompose a tree round's hops, step by step, into matchings. Within
    a step a source may fan out to several children (packet duplication);
    each fan-out degree becomes one matching. Step order is preserved so
    data dependencies (parent before child) hold."""
    stages: list[MatchOp] = []
    for step in range(rnd.num_steps):
        remaining = [(topo.router_id(h.src), topo.router_id(h.dst)) for h in rnd.hops_at(step)]
        while remaining:
            used_src: set[int] = set()
            used_dst: set[int] = set()
            matching: list[tuple[int, int]] = []
            rest: list[tuple[int, int]] = []
            for s, d in remaining:
                if s not in used_src and d not in used_dst:
                    used_src.add(s)
                    used_dst.add(d)
                    matching.append((s, d))
                else:
                    rest.append((s, d))
            stages.append(MatchOp(tuple(matching)))
            remaining = rest
    return stages


def lower_broadcast(schedule: Schedule) -> LoweredBroadcast:
    """A (single-round) spanning-tree schedule -> ordered masked matchings."""
    topo = schedule.topo
    if schedule.num_rounds != 1:
        raise ValueError("lower_broadcast expects a single-round tree schedule")
    root = schedule.meta.get("root") or schedule.meta.get("source")
    if root is None:
        raise ValueError("broadcast schedule lacks meta['root']/['source']")
    stages = hops_to_matchings(topo, schedule.rounds[0])
    return LoweredBroadcast(topo.num_routers, topo.router_id(root), tuple(stages))
