"""Emulation rewrite — lower any D3(J,L) program onto its D3(K,M) host.

Paper Property 2 (embeddings formalized in Draper, *The Swapped Dragonfly*,
arXiv:2202.01843): D3(K,M) contains a dilation-1 copy of every D3(J,L)
with J ≤ K, L ≤ M. ``emulate(program, embedding)`` is that property as a
program-to-program pass: every ``Perm``/``Match``/``ReduceCombine`` pair
set of an already-lowered guest ``CollectiveProgram`` is relabeled through
the embedding's vectorized device-id map (guest router id → host router id,
``Embedding.device_map``), ``LocalContract`` store masks are relabeled the
same way, and the result is a host-sized program whose ``active_devices``
tuple records (in guest order) which host devices participate. Because the
embedding is dilation-1, every rewritten pair is still a single physical
link of the host graph, so the guest schedule's conflict-freedom transfers
verbatim — no re-derivation, no re-verification, no re-lowering.

What the pass guarantees (the contract tests and ``train.fault_tolerance``
rely on):

  * **stamps survive** — ``(round_index, step, start_step)`` are copied
    unchanged, so pipelined (start_step-ordered) replay of the rewritten
    program interleaves exactly like the guest's;
  * **bit-exactness** — replaying the rewritten program on host arrays that
    carry the guest data at ``active_devices`` slots produces, at those
    slots, bit-for-bit the guest program's result on any conforming
    backend (differential-tested reference vs JAX);
  * **idle isolation** — host devices outside ``active_devices`` neither
    contribute to nor receive guest data: their slots pass through
    untouched (asserted by the reference backend);
  * **caching** — ``emulate`` is memoized on the hashable
    ``(program, embedding)`` key, i.e. on (host, guest, c_set, p_set,
    program), the same way per-stage σ/σ⁻¹ arrays are cached — repeated
    failover re-lowers reuse the built host index arrays instead of
    rebuilding them inside jit traces.

``emulate_schedule`` is the companion *verification* view: it maps a guest
Schedule IR's hops router-by-router onto the host graph so
``core.simulator.verify`` can replay them on the literal host links
(dilation-1 ⇒ zero conflicts). Its output is for verify()/price() only —
lowering metadata (``vectors``/``pairs``/``matmul``) is moved under
``guest_*`` keys so the result cannot be accidentally re-lowered; use
``emulate`` for the executable program.

Pure Python + NumPy over hashable data — no jax imports, safe to call from
the reference backend and from host-side recovery planning.
"""

from __future__ import annotations

import functools

import numpy as np

from repro.core.emulation import Embedding
from repro.core.schedule import Hop, Round, Schedule
from repro.runtime.program import (
    CollectiveProgram,
    LocalContract,
    Match,
    Perm,
    ReduceCombine,
    Stage,
)

#: round meta keys that drive ``runtime.lowering`` dispatch — moved under
#: ``guest_*`` by ``emulate_schedule`` so its output is verify-only.
_LOWERING_META = ("vectors", "pairs", "matmul")


def _check_embedding(program: CollectiveProgram, embedding: Embedding) -> None:
    if embedding.guest.num_routers != program.n:
        raise ValueError(
            f"program acts on {program.n} devices but the embedding's guest "
            f"D3({embedding.guest.K},{embedding.guest.M}) has "
            f"{embedding.guest.num_routers}"
        )
    if program.active_devices is not None:
        raise ValueError(
            "program is already an emulation rewrite; compose embeddings "
            "instead of stacking rewrites"
        )


@functools.lru_cache(maxsize=None)
def emulate(program: CollectiveProgram, embedding: Embedding) -> CollectiveProgram:
    """Rewrite a guest ``CollectiveProgram`` onto the embedding's host.

    Returns a program with ``n = host.num_routers`` whose communication
    stages carry host device ids, whose (round_index, step, start_step)
    stamps are the guest's, and whose ``active_devices`` is the guest-
    ordered host image (``Embedding.device_map``). Memoized per
    (program, embedding) — both are frozen/hashable.
    """
    _check_embedding(program, embedding)
    dm = embedding.device_map
    host_n = embedding.host.num_routers

    def mapped(pairs):
        return tuple((int(dm[s]), int(dm[d])) for s, d in pairs)

    stages: list[Stage] = []
    for st in program.stages:
        stamps = dict(round_index=st.round_index, step=st.step,
                      start_step=st.start_step)
        if isinstance(st, Perm):
            stages.append(Perm(mapped(st.pairs), n=host_n, **stamps))
        elif isinstance(st, Match):
            stages.append(Match(host_n, mapped(st.pairs), **stamps))
        elif isinstance(st, ReduceCombine):
            stages.append(ReduceCombine(host_n, mapped(st.pairs),
                                        combine=st.combine, **stamps))
        elif isinstance(st, LocalContract):
            mask = None if st.mask is None else tuple(int(dm[i]) for i in st.mask)
            stages.append(LocalContract(st.fn, mask=mask, n=host_n, **stamps))
        else:  # pragma: no cover - Stage union is closed
            raise TypeError(f"unknown stage type {type(st).__name__}")
    return CollectiveProgram(
        kind=program.kind,
        n=host_n,
        num_rounds=program.num_rounds,
        stages=tuple(stages),
        root=None if program.root is None else int(dm[program.root]),
        grid=program.grid,
        name=f"{program.name or program.kind}@D3({embedding.host.K},{embedding.host.M})",
        active_devices=tuple(int(h) for h in dm),
    )


def emulate_schedule(schedule: Schedule, embedding: Embedding) -> Schedule:
    """Map a guest Schedule IR hop-by-hop onto the host graph — the
    verification companion of ``emulate``.

    Every hop's endpoints go through ``Embedding.map_router``; steps,
    payloads, ``start_step``/``startups`` metadata are preserved, so
    ``core.simulator.verify(host_topo, emulate_schedule(s, emb))`` replays
    the guest schedule on the literal host links (and must report zero
    conflicts — dilation 1). Lowering-dispatch metadata is stashed under
    ``guest_*`` keys: the result is for verify()/price(), not for
    ``runtime.lowering.lower``.
    """
    if schedule.topo != embedding.guest:
        raise ValueError(
            f"schedule is on D3({schedule.topo.K},{schedule.topo.M}) but the "
            f"embedding's guest is D3({embedding.guest.K},{embedding.guest.M})"
        )
    mr = embedding.map_router
    rounds = []
    for rnd in schedule.rounds:
        hops = tuple(Hop(h.step, mr(h.src), mr(h.dst), h.payload) for h in rnd.hops)
        meta = dict(rnd.meta)
        for key in _LOWERING_META:
            if key in meta:
                meta[f"guest_{key}"] = meta.pop(key)
        rounds.append(Round(hops, meta))
    meta = dict(schedule.meta)
    for key in ("root", "source"):
        if meta.get(key) is not None:
            root = meta[key]
            meta[key] = (
                int(embedding.device_map[root]) if isinstance(root, int)
                else mr(root)
            )
    return Schedule(
        f"{schedule.name}@D3({embedding.host.K},{embedding.host.M})",
        embedding.host, rounds, meta,
    )


# ---------------------------------------------------------------------------
# Guest-view scatter/gather: move guest-sized arrays in and out of the
# host-sized device axis of a rewritten program.
# ---------------------------------------------------------------------------

def scatter_guest(x: np.ndarray, program: CollectiveProgram, *, axes=(0,),
                  fill=0) -> np.ndarray:
    """Embed guest-sized array ``x`` into the rewritten program's host axis.

    Each listed axis of length ``guest_n`` becomes a host axis of length
    ``n`` with guest slice g landing at host index ``active_devices[g]``
    and idle slots holding ``fill``. Identity for native programs.
    """
    if program.active_devices is None:
        return np.asarray(x)
    out = np.asarray(x)
    idx = program.active_np
    for ax in axes:
        if out.shape[ax] != program.guest_n:
            raise ValueError(
                f"axis {ax} has {out.shape[ax]} slots, guest has {program.guest_n}"
            )
        shape = list(out.shape)
        shape[ax] = program.n
        host = np.full(shape, fill, out.dtype)
        sel = [slice(None)] * out.ndim
        sel[ax] = idx
        host[tuple(sel)] = out
        out = host
    return out


def gather_guest(x: np.ndarray, program: CollectiveProgram, *, axes=(0,)) -> np.ndarray:
    """Project the rewritten program's host axis back to the guest view —
    the inverse of ``scatter_guest`` (idle slots are dropped)."""
    if program.active_devices is None:
        return np.asarray(x)
    out = np.asarray(x)
    idx = program.active_np
    for ax in axes:
        if out.shape[ax] != program.n:
            raise ValueError(
                f"axis {ax} has {out.shape[ax]} slots, host has {program.n}"
            )
        sel = [slice(None)] * out.ndim
        sel[ax] = idx
        out = out[tuple(sel)]
    return out
