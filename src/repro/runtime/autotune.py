"""Price-driven collective autotuner — pick the cheapest strategy per call
site, seeded by the paper's analytic prices and calibrated by measurement.

The paper prices every algorithm in rounds (Theorems 1–4, Schedules 1–3)
and ``core.costmodel`` encodes those tables; PR 4 added three coexisting
execution strategies for every lowered program (per-stage replay, fused
``optimize()`` tables, Pallas kernels) plus the plain XLA collective the
runtime replaces. Nothing *dispatched* on price until now: every call site
hardcoded one strategy. The ``Autotuner`` closes that gap:

  * a call site is keyed on ``(kind, K·M topology, message bytes, dtype,
    site)`` — ``TuneKey``; message bytes are bucketed to the next power of
    two so nearby shapes share one decision;
  * the candidate strategies per site class are

      - ``site="host"``   (NumPy whole-array callers):   loop | fused |
        sendrecv
      - ``site="global"`` (device whole-array ``run_*``): loop | fused |
        pallas_fused | sendrecv | xla
      - ``site="shard"``  (inside a caller's shard_map, e.g. MoE
        dispatch): xla | loop | overlap | overlap_fused (all-to-all
        only — the fused wave pipeline that overlaps dispatch with the
        per-destination compute; priced with the max-of-overlap discount
        when the key carries a ``compute_us`` term)
      - ``site="combined"`` (N disjoint guests on one host — the
        multi-tenant fleet's boundary replays): combined | time_mux.
        ``combined`` is ONE merged-program replay at makespan
        max(T_1..T_N); ``time_mux`` is N sequential solo replays at
        ΣT_i. Keyed on the guest-set signature (``decide_combined``),
        since the tenant mix — not just the host shape — decides the
        merge's worth.

    where ``loop`` is the per-stage D3 schedule replay, ``overlap`` the
    same program in ``start_step`` order, ``fused`` the ``optimize()``
    table replay, ``pallas_fused`` the Pallas-kernel backend, ``sendrecv``
    the exported per-device trace replayed by the NumPy interpreter
    (``runtime.export`` + ``backends/sendrecv`` — device-free, like the
    host-site strategies), and ``xla`` the fused XLA collective
    (``lax.all_to_all`` / ``psum``). Inside a
    shard_map the fused-table form of an all-to-all IS the single fused
    op, so ``xla`` is how "fused" manifests at shard sites;
  * decisions are SEEDED by analytic prices — ``costmodel.price`` of the
    emitted schedule turned into wall-clock by the bytes-aware
    ``costmodel.seconds`` plus per-strategy software-overhead terms — and
    then CALIBRATED by one-shot measured timings, memoized in an on-disk
    JSON cache (``benchmarks/autotune_cache.json``, schema-versioned,
    corrupt-tolerant: an unreadable cache falls back to analytic seeding
    and is rewritten on the next measurement);
  * escape hatches: ``REPRO_AUTOTUNE=analytic`` forces analytic-only
    ranking (no measurement, no disk), ``REPRO_AUTOTUNE=off`` disables
    tuning (every site gets its pre-autotuner default), and
    ``REPRO_AUTOTUNE=<strategy>`` forces one strategy everywhere it is
    structurally available. ``REPRO_AUTOTUNE_CACHE`` moves the cache file.
    The ``Autotuner`` constructor takes the same knobs (``mode``,
    ``force``, ``cache_path``) for programmatic control.

Wired call sites: ``dist.collectives.dragonfly_*`` accept
``backend="auto"``, ``runtime.backends.get_backend("auto")`` returns the
:class:`AutoBackend` whole-array dispatcher, ``models.moe`` routes EP
dispatch/combine through the tuner when ``moe_collectives="auto"``, and
``serve.engine`` / ``launch.dryrun`` report the chosen strategy + priced
rounds per config via :func:`moe_site_report`.

Determinism: a warm cache always returns the recorded decision (no
re-measurement), analytic ranking is pure arithmetic over the schedule,
and measurement happens at most once per key per cache lifetime.
"""

from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import tempfile
import time

import numpy as np

from repro.core import costmodel

SCHEMA_VERSION = 1
DEFAULT_CACHE = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / "autotune_cache.json"

KINDS = ("alltoall", "allreduce", "broadcast", "matmul")
SITES = ("host", "global", "shard", "combined")
STRATEGIES = ("loop", "overlap", "fused", "pallas_fused", "xla",
              "overlap_fused", "sendrecv", "combined", "time_mux")

#: analytic seed constants (calibration overrides these — they only need to
#: produce a sane ranking before the first measurement lands in the cache)
T_W = 1.0e-6          # per-hop router latency, the paper's t_w
BANDWIDTH = 50e9      # per-link wire bandwidth (TPU v5e ICI)
T_DISPATCH = 5.0e-6   # software overhead per replayed stage (loop paths)
T_GROUP = 2.0e-6      # software overhead per fused table group
T_KERNEL = 10.0e-6    # extra per-group cost of a Pallas kernel launch
T_XLA = 20.0e-6       # fixed overhead of one fused XLA collective
T_TRACE_OP = 2.0e-6   # per-op overhead of the sendrecv trace interpreter
COMPUTE_RATE = 2e9    # proxy flops/s for sizing synthetic pipeline compute


# ---------------------------------------------------------------------------
# Keys and decisions
# ---------------------------------------------------------------------------

def bucket_bytes(nbytes: int) -> int:
    """Round message bytes up to the next power of two (min 64) so nearby
    shapes share one cache entry and the key space stays bounded."""
    n = max(64, int(nbytes))
    return 1 << (n - 1).bit_length()


def bucket_compute_us(compute_us: int) -> int:
    """Bucket the per-device fused-compute term: 0 (pure collective) stays
    0, anything else rounds up to the next power of two µs."""
    n = int(compute_us)
    return 0 if n <= 0 else 1 << (n - 1).bit_length()


@dataclasses.dataclass(frozen=True)
class TuneKey:
    """One call site: what is being moved, over which topology, how big.

    ``compute_us`` is the bucketed per-device cost of the compute fused
    into the collective's round trip (MoE expert FFN at dispatch sites);
    0 means a pure data-movement site. ``emulated`` marks guest-on-host
    ``active_devices`` sites, whose candidate set excludes ``xla`` — it
    must be part of the key or a native decision (possibly ``xla``) would
    be replayed from the memo/cache at an emulated site. Pure native
    sites keep the pre-compute key string (no ``|c``/``|emu`` suffix), so
    caches recorded before these fields existed stay valid."""

    kind: str      # alltoall | allreduce | broadcast | matmul
    K: int         # D3(K, M) of the mesh axis (matmul: the grid's topo)
    M: int
    nbytes: int    # bucketed message bytes (per chunk / vector / block)
    dtype: str
    site: str      # host | global | shard | combined
    compute_us: int = 0  # bucketed fused-compute µs per device (0 = none)
    emulated: bool = False  # guest-on-host program (xla excluded)
    guests: str = ""  # combined sites: the guest-set signature ("2xD3(1,2)")

    def __str__(self) -> str:
        tail = f"|c{self.compute_us}" if self.compute_us else ""
        tail += "|emu" if self.emulated else ""
        tail += f"|g{self.guests}" if self.guests else ""
        return (f"{self.kind}|K{self.K}M{self.M}|b{self.nbytes}"
                f"|{self.dtype}|{self.site}{tail}")


@dataclasses.dataclass(frozen=True)
class Decision:
    """The tuner's answer for one key, with its full evidence trail."""

    key: TuneKey
    strategy: str
    source: str                     # forced | off | cache | measured | analytic
    rounds: int                     # priced schedule rounds (xla: 1)
    hops: float                     # costmodel.price of the schedule, t_w units
    analytic_us: dict[str, float]   # strategy -> analytic seed price
    measured_us: dict[str, float]   # strategy -> measured (empty if analytic)

    @property
    def predicted_us(self) -> float:
        got = self.measured_us.get(self.strategy)
        return got if got is not None else self.analytic_us.get(self.strategy, 0.0)

    def as_row(self) -> dict:
        return {
            "key": str(self.key), "strategy": self.strategy,
            "source": self.source, "rounds": self.rounds, "hops": self.hops,
            "predicted_us": round(self.predicted_us, 1),
            "analytic_us": {k: round(v, 1) for k, v in self.analytic_us.items()},
            "measured_us": {k: round(v, 1) for k, v in self.measured_us.items()},
        }


def _default_strategy(kind: str, site: str) -> str:
    """What each call site did BEFORE the autotuner existed (mode='off')."""
    if site == "combined":
        return "time_mux"  # pre-fleet behavior: every tenant served alone
    return "xla" if site == "shard" else "loop"


def candidates(kind: str, site: str, *, emulated: bool = False) -> tuple[str, ...]:
    """Structurally available strategies for a (kind, site) class.

    ``emulated`` (guest-on-host ``active_devices`` programs) drops ``xla``:
    the fused op would mix idle devices into the result."""
    if kind not in KINDS:
        raise ValueError(f"unknown kind {kind!r}")
    if site == "combined":
        return ("combined", "time_mux")
    if site == "host":
        out: tuple[str, ...] = ("loop", "fused", "sendrecv")
    elif site == "global":
        out = ("loop", "fused", "pallas_fused", "sendrecv")
        if kind in ("alltoall", "allreduce"):
            out += ("xla",)
    elif site == "shard":
        out = ("loop", "overlap")
        if kind != "matmul":
            out = ("xla",) + out
        if kind == "alltoall":
            out += ("overlap_fused",)
    else:
        raise ValueError(f"unknown site {site!r}; expected one of {SITES}")
    if emulated:
        out = tuple(s for s in out if s != "xla")
    return out


# ---------------------------------------------------------------------------
# Schedules / programs per kind (lazy dist imports — dist layers on runtime)
# ---------------------------------------------------------------------------

def _schedule(kind: str, layout, grid=None):
    from repro.core import alltoall as a2a
    from repro.core import broadcast as bc
    from repro.core import hypercube as hc
    from repro.core import matmul as mm

    if kind == "alltoall":
        return a2a.schedule(layout.da_params, layout.topo)
    if kind == "allreduce":
        if layout.sbh is None:
            raise ValueError(f"D3({layout.topo.K},{layout.topo.M}) has no SBH")
        return hc.allreduce_schedule(layout.sbh)
    if kind == "broadcast":
        return bc.depth3_schedule(layout.topo, layout.topo.id_router(0))
    if kind == "matmul":
        return mm.schedule(mm.MatmulGrid(*grid))
    raise ValueError(f"unknown kind {kind!r}")


def _program(kind: str, layout, grid=None):
    from repro.dist import collectives as coll

    if kind == "alltoall":
        return coll.alltoall_program(layout)
    if kind == "allreduce":
        return coll.allreduce_program(layout)
    if kind == "broadcast":
        return coll.broadcast_program(layout, 0)
    return coll.matmul_program(*grid)


def layout_for(n: int):
    from repro.dist.mesh import dragonfly_layout

    return dragonfly_layout(n)


def _guest_layout(embedding):
    from repro.dist.mesh import DeviceLayout

    return DeviceLayout(embedding.guest)


# ---------------------------------------------------------------------------
# Analytic seeding
# ---------------------------------------------------------------------------

def analytic_prices(kind: str, layout, nbytes: int, strategies, grid=None,
                    compute_us: int = 0) -> dict[str, float]:
    """Per-strategy analytic seed prices in µs: the schedule's priced hops
    through the bytes-aware ``costmodel.seconds`` plus software-overhead
    terms per replayed stage / fused group / kernel launch.

    ``compute_us`` prices a compute term fused into the site's round trip
    (MoE expert FFN). Sequential strategies pay dispatch + compute + combine
    as a SUM; ``overlap_fused`` issues waves while already-arrived chunks
    are contracted, so it pays max(pipelined wire time, compute) — the
    Schedules 1–3 overlap discount — plus its per-stage table overhead."""
    from repro.runtime import lowering, optimize as ropt

    sched = _schedule(kind, layout, grid)
    hops = costmodel.price(sched, t_w=1.0, t_s=0.0)
    hops_pipe = costmodel.price_pipelined(sched, 1.0, 0.0)
    prog = lowering.lower(sched)
    n_stages = len(prog.stages)
    n_groups = ropt.optimize(prog).num_fused_ops
    n = prog.n
    compute_s = max(0, int(compute_us)) * 1e-6

    out: dict[str, float] = {}
    for s in strategies:
        if s == "loop":
            sec = costmodel.seconds(hops, T_W, n_stages * T_DISPATCH,
                                    bytes_per_hop=nbytes, bandwidth=BANDWIDTH)
        elif s == "overlap":
            sec = costmodel.seconds(hops_pipe, T_W, n_stages * T_DISPATCH,
                                    bytes_per_hop=nbytes, bandwidth=BANDWIDTH)
        elif s == "fused":
            sec = costmodel.seconds(hops, T_W, n_groups * T_GROUP,
                                    bytes_per_hop=nbytes, bandwidth=BANDWIDTH)
        elif s == "pallas_fused":
            sec = costmodel.seconds(hops, T_W, n_groups * (T_GROUP + T_KERNEL),
                                    bytes_per_hop=nbytes, bandwidth=BANDWIDTH)
        elif s == "sendrecv":
            # the exported-trace interpreter walks every per-device op —
            # honest seeding keeps it priced above the fused table replay
            from repro.runtime import export as rexport

            n_ops = rexport.export(prog).num_ops
            sec = costmodel.seconds(hops, T_W, n_ops * T_TRACE_OP,
                                    bytes_per_hop=nbytes, bandwidth=BANDWIDTH)
        elif s == "xla":
            # one fused op: latency-optimal collective, e.g. n-1 exchange
            # steps for all-to-all, 2·log2(n) for a psum ring/tree
            xla_hops = (n - 1) if kind == "alltoall" else 2 * max(1, n).bit_length()
            sec = costmodel.seconds(xla_hops, T_W, T_XLA,
                                    bytes_per_hop=nbytes, bandwidth=BANDWIDTH)
        elif s == "overlap_fused":
            wire = costmodel.seconds(hops_pipe, T_W, 0.0,
                                     bytes_per_hop=nbytes, bandwidth=BANDWIDTH)
            if compute_s and kind == "alltoall":
                # overlap discount: the expert compute hides behind the
                # pipelined dispatch+return rounds (and vice versa) — only
                # the table bookkeeping is serial
                sec = max(2.0 * wire, compute_s) + n_stages * T_GROUP
            else:
                sec = wire + n_stages * T_GROUP
            out[s] = sec * 1e6
            continue
        else:  # pragma: no cover - candidates() guards the universe
            raise ValueError(f"unknown strategy {s!r}")
        if compute_s and kind == "alltoall":
            # sequential round trip: dispatch + compute + combine
            sec = 2.0 * sec + compute_s
        out[s] = sec * 1e6
    return out


def priced_rounds(kind: str, layout, grid=None) -> tuple[int, float]:
    """(rounds, priced hops in t_w units) of the kind's schedule — the
    paper-table numbers the reports attach to each decision."""
    sched = _schedule(kind, layout, grid)
    return len(sched.rounds), costmodel.price(sched, t_w=1.0, t_s=0.0)


def guest_signature(embeddings) -> str:
    """Canonical guest-set signature for combined-site keys: shape counts
    in sorted order, e.g. ``"2xD3(1,2)"`` or ``"1xD3(1,2)+1xD3(2,2)"`` —
    placement-independent, so re-admitting the same mix after churn hits
    the same cache entry."""
    counts: dict[str, int] = {}
    for e in embeddings:
        s = f"D3({e.guest.K},{e.guest.M})"
        counts[s] = counts.get(s, 0) + 1
    return "+".join(f"{n}x{s}" for s, n in sorted(counts.items()))


def analytic_combined_prices(kind: str, embeddings, nbytes: int
                             ) -> dict[str, float]:
    """Seed prices (µs) for one combined site: ``combined`` pays the
    makespan — max of the guests' priced hops — plus the MERGED program's
    per-stage overhead (same-stamp stages packed into one partial stage);
    ``time_mux`` pays the sum of hops plus every solo program's stage
    overhead. The wire term dominates at scale, the software term at toy
    sizes — both favor combining, by Property 2's disjoint-links argument."""
    from repro.dist import collectives as coll
    from repro.dist.mesh import DeviceLayout
    from repro.runtime import lowering

    hops, stages = [], []
    for emb in embeddings:
        sched = _schedule(kind, DeviceLayout(emb.guest))
        hops.append(costmodel.price(sched, t_w=1.0, t_s=0.0))
        stages.append(len(lowering.lower(sched).stages))
    comb = coll.concurrent_program(kind, tuple(embeddings))
    combined = costmodel.seconds(max(hops), T_W, len(comb.stages) * T_DISPATCH,
                                 bytes_per_hop=nbytes, bandwidth=BANDWIDTH)
    mux = costmodel.seconds(sum(hops), T_W, sum(stages) * T_DISPATCH,
                            bytes_per_hop=nbytes, bandwidth=BANDWIDTH)
    return {"combined": combined * 1e6, "time_mux": mux * 1e6}


# ---------------------------------------------------------------------------
# Measurement
# ---------------------------------------------------------------------------

def _elems(nbytes: int, dtype: str) -> int:
    return max(1, int(nbytes) // max(1, np.dtype(dtype).itemsize))


def _time_us(fn, warmup: int = 1, iters: int = 3) -> float:
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _measure_closure(kind: str, site: str, strategy: str, layout, grid,
                     nbytes: int, dtype: str, compute_us: int = 0):
    """A zero-arg runnable of (kind, strategy) at the keyed message size,
    or None when the strategy cannot run here (e.g. too few devices).

    ``compute_us > 0`` all-to-all keys measure the FULL round-trip
    pipeline — dispatch, a synthetic per-chunk contraction sized to
    ``compute_us`` per device (via ``COMPUTE_RATE``), combine — so the
    overlap discount of ``overlap_fused`` shows up in the timing instead
    of being assumed."""
    from repro.runtime import optimize as ropt

    prog = _program(kind, layout, grid)
    e = _elems(nbytes, dtype)
    rng = np.random.default_rng(0)

    if kind == "matmul":
        from repro.core.matmul import MatmulGrid

        g = MatmulGrid(*grid)
        X = max(1, int(np.sqrt(e)))
        side = g.n * X
        B = rng.integers(-4, 5, (side, side)).astype(dtype)
        A = rng.integers(-4, 5, (side, side)).astype(dtype)
    elif kind == "alltoall":
        x = rng.standard_normal((prog.n, prog.n, e)).astype(dtype)
    else:
        x = rng.standard_normal((prog.n, e)).astype(dtype)

    if site == "host":
        if strategy == "sendrecv":
            from repro.runtime.backends.sendrecv import SendRecvBackend

            ref = SendRecvBackend()
        else:
            from repro.runtime.backends.reference import NumpyReferenceBackend

            ref = NumpyReferenceBackend()
        p = ropt.optimize(prog) if strategy == "fused" else prog
        if kind == "alltoall":
            return lambda: ref.run_alltoall(x, p)
        if kind == "allreduce":
            return lambda: ref.run_allreduce(x, p)
        if kind == "broadcast":
            return lambda: ref.run_broadcast(x, p)
        return lambda: ref.run_matmul(B, A, p)

    if strategy == "sendrecv":
        # device-free at every site class it is a candidate for: the trace
        # interpreter needs no mesh quorum, so measure it before touching jax
        from repro.runtime.backends.sendrecv import SendRecvBackend

        be = SendRecvBackend()
        if kind == "matmul":
            return lambda: be.run_matmul(B, A, prog)
        run = {"alltoall": be.run_alltoall, "allreduce": be.run_allreduce,
               "broadcast": be.run_broadcast}[kind]
        return lambda: run(x, prog)

    # device-backed sites
    import jax
    import jax.numpy as jnp

    if (strategy in ("loop", "overlap", "xla", "overlap_fused")
            and jax.device_count() < prog.n):
        return None

    if kind == "alltoall" and compute_us > 0:
        # full dispatch+compute+combine pipeline: sequential strategies do
        # a2a -> batched contraction -> a2a; overlap_fused runs the fused
        # wave pipeline over the Schedule-1 stamped program
        from jax.sharding import Mesh, PartitionSpec as P

        from repro.dist.collectives import alltoall_program
        from repro.runtime import compat
        from repro.runtime.backends.jax_ppermute import JaxPpermuteBackend

        n = prog.n
        # the proxy is a silu-gated FFN (6·tokens·d_in·f flops per device)
        # with each chunk factored into (tokens, d_in) rows: both the
        # matmul geometry and the gate's elementwise traffic match what a
        # real MoE closure does — a flat (V, e) matmul would be
        # pathologically skinny per wave and elementwise-free, penalizing
        # the wave-sliced strategies for a shape no caller uses
        f_dim = max(1, int(compute_us * 1e-6 * COMPUTE_RATE / (6.0 * n * e)))
        d_in = next((w for w in (64, 32, 16, 8, 4, 2, 1) if e % w == 0))
        # ~1/sqrt(fan-in) weight scale keeps activations O(1) through the
        # gate: unscaled normals push silu into saturated/denormal ranges
        # no trained FFN visits, distorting the timing
        WG = jnp.asarray((rng.standard_normal((d_in, f_dim))
                          / np.sqrt(d_in)).astype(dtype))
        WI = jnp.asarray((rng.standard_normal((d_in, f_dim))
                          / np.sqrt(d_in)).astype(dtype))
        WO = jnp.asarray((rng.standard_normal((f_dim, d_in))
                          / np.sqrt(f_dim)).astype(dtype))
        mesh = Mesh(np.array(jax.devices()[:n]), ("df",))

        def comp(chunks):
            lead = chunks.shape[:-1]
            h = chunks.reshape(-1, d_in)
            g = jax.nn.silu(h @ WG) * (h @ WI)
            return (g @ WO).reshape(*lead, e)

        if strategy == "overlap_fused":
            be = JaxPpermuteBackend(overlap_fused=True)
            pipe = alltoall_program(layout, pipelined=1)
            local = lambda s: be.alltoall_compute(s[0], "df", pipe, comp)[None]
        else:
            if strategy == "xla":
                a2a = lambda v: jax.lax.all_to_all(
                    v, "df", split_axis=0, concat_axis=0)
            else:
                be = JaxPpermuteBackend(overlap=(strategy == "overlap"))
                a2a = lambda v: be.alltoall(v, "df", prog)
            local = lambda s: a2a(comp(a2a(s[0])))[None]
        f = jax.jit(compat.shard_map(
            local, mesh=mesh, in_specs=P("df"), out_specs=P("df")))
        xj = jnp.asarray(x)
        return lambda: jax.block_until_ready(f(xj))

    if strategy == "xla":
        from jax.sharding import Mesh, PartitionSpec as P

        from repro.runtime import compat

        mesh = Mesh(np.array(jax.devices()[: prog.n]), ("df",))
        if kind == "alltoall":
            f = jax.jit(compat.shard_map(
                lambda s: jax.lax.all_to_all(
                    s[0], "df", split_axis=0, concat_axis=0)[None],
                mesh=mesh, in_specs=P("df"), out_specs=P("df")))
        elif kind == "allreduce":
            f = jax.jit(compat.shard_map(
                lambda s: jax.lax.psum(s, "df"),
                mesh=mesh, in_specs=P("df"), out_specs=P("df")))
        else:  # broadcast root 0: one masked psum
            f = jax.jit(compat.shard_map(
                lambda s: jax.lax.psum(jnp.where(
                    jax.lax.axis_index("df") == 0, s, jnp.zeros_like(s)), "df"),
                mesh=mesh, in_specs=P("df"), out_specs=P("df")))
        xj = jnp.asarray(x)
        return lambda: jax.block_until_ready(f(xj))

    from repro.runtime.backends.jax_ppermute import JaxPpermuteBackend

    if strategy == "pallas_fused":
        from repro.runtime.backends.pallas_fused import PallasFusedBackend

        be = PallasFusedBackend()
        p = prog
    elif strategy == "overlap_fused":
        from repro.dist.collectives import alltoall_program

        be = JaxPpermuteBackend(overlap_fused=True)
        p = alltoall_program(layout, pipelined=1)
    else:
        be = JaxPpermuteBackend(overlap=(strategy == "overlap"))
        p = ropt.optimize(prog) if strategy == "fused" else prog
    if kind == "matmul":
        Bj, Aj = jnp.asarray(B), jnp.asarray(A)
        return lambda: jax.block_until_ready(be.run_matmul(Bj, Aj, p))
    xj = jnp.asarray(x)
    run = {"alltoall": be.run_alltoall, "allreduce": be.run_allreduce,
           "broadcast": be.run_broadcast}[kind]
    return lambda: jax.block_until_ready(run(xj, p))


def _measure_combined_closure(kind: str, strategy: str, embeddings,
                              nbytes: int, dtype: str):
    """A zero-arg runnable of one combined-site strategy, or None when the
    kind has no device-free replay to time. Both arms replay on the NumPy
    reference backend (host-site style: deterministic, no device quorum):
    ``combined`` is ONE merged-program replay, ``time_mux`` is every
    guest's solo emulated replay back to back — the exact pair of
    executions the multi-tenant fleet chooses between."""
    if kind not in ("alltoall", "allreduce"):
        return None
    from repro.dist import collectives as coll
    from repro.dist.mesh import DeviceLayout
    from repro.runtime.backends.reference import NumpyReferenceBackend
    from repro.runtime.combine import scatter_guests

    ref = NumpyReferenceBackend()
    e = _elems(nbytes, dtype)
    rng = np.random.default_rng(0)
    axes = (0, 1) if kind == "alltoall" else (0,)
    solos, xs = [], []
    for emb in embeddings:
        layout = DeviceLayout(emb.guest)
        if kind == "alltoall":
            solos.append(coll.alltoall_program(layout, emb))
            xs.append(rng.standard_normal(
                (layout.topo.num_routers, layout.topo.num_routers, e)
            ).astype(dtype))
        else:
            solos.append(coll.allreduce_program(layout, emb))
            xs.append(rng.standard_normal(
                (layout.topo.num_routers, e)).astype(dtype))
    run = ref.run_alltoall if kind == "alltoall" else ref.run_allreduce
    if strategy == "combined":
        comb = coll.concurrent_program(kind, tuple(embeddings))
        xh = scatter_guests(xs, embeddings, axes=axes)
        return lambda: run(xh, comb)
    hs = [scatter_guests([x], [emb], axes=axes)
          for x, emb in zip(xs, embeddings)]

    def mux():
        for prog, xh in zip(solos, hs):
            run(xh, prog)

    return mux


# ---------------------------------------------------------------------------
# The tuner
# ---------------------------------------------------------------------------

class Autotuner:
    """Per-call-site strategy dispatcher with an on-disk measurement cache.

    ``mode``: ``"measure"`` (default — measure once, cache to disk),
    ``"analytic"`` (rank by seed prices only, touch nothing on disk), or
    ``"off"`` (return each site's pre-autotuner default). ``force`` pins
    one strategy wherever it is structurally available. Both default to
    the ``REPRO_AUTOTUNE`` env var; ``cache_path`` to
    ``REPRO_AUTOTUNE_CACHE`` / ``benchmarks/autotune_cache.json``.
    """

    def __init__(self, cache_path: str | os.PathLike | None = None,
                 mode: str | None = None, force: str | None = None):
        env = os.environ.get("REPRO_AUTOTUNE", "").strip()
        if mode is None and force is None and env:
            if env in ("analytic", "off", "measure"):
                mode = env
            elif env in STRATEGIES:
                force = env
            else:
                raise ValueError(
                    f"REPRO_AUTOTUNE={env!r}: expected 'analytic', 'off', "
                    f"'measure' or a strategy in {STRATEGIES}")
        if force is not None and force not in STRATEGIES:
            raise ValueError(f"unknown forced strategy {force!r}; known: {STRATEGIES}")
        self.mode = mode or "measure"
        if self.mode not in ("measure", "analytic", "off"):
            raise ValueError(f"unknown mode {self.mode!r}")
        self.force = force
        self.cache_path = pathlib.Path(
            cache_path or os.environ.get("REPRO_AUTOTUNE_CACHE", DEFAULT_CACHE))
        self.decisions: list[Decision] = []   # the decision log, for reports
        self._memo: dict[TuneKey, Decision] = {}
        self._cache: dict[str, dict] = self._load_cache()
        self._dirty = False

    # ------------------------------------------------------------- cache
    def _load_cache(self) -> dict[str, dict]:
        """Schema-checked, corrupt-tolerant load: anything unreadable or
        version-mismatched degrades to an empty cache (analytic seeding
        still works; the next measurement rewrites the file)."""
        try:
            raw = json.loads(self.cache_path.read_text())
            if raw.get("schema") != SCHEMA_VERSION:
                return {}
            entries = raw.get("entries")
            return dict(entries) if isinstance(entries, dict) else {}
        except (OSError, ValueError):
            return {}

    def save(self) -> None:
        if not self._dirty:
            return
        payload = {"schema": SCHEMA_VERSION, "entries": self._cache}
        self.cache_path.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=self.cache_path.parent, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=1, sort_keys=True)
            os.replace(tmp, self.cache_path)
        except OSError:
            try:
                os.unlink(tmp)
            except OSError:
                pass
        self._dirty = False

    # ------------------------------------------------------------ decide
    def decide(self, kind: str, layout=None, nbytes: int = 0,
               dtype: str = "float32", site: str = "global", grid=None,
               emulated: bool = False, compute_us: int = 0) -> Decision:
        """The cheapest strategy for one call site key. Deterministic for a
        warm cache: same key -> same decision, no re-measurement.

        ``compute_us`` (per-device µs of compute fused into the site's
        round trip, e.g. the MoE expert FFN) keys and prices the decision
        as a full dispatch+compute+combine pipeline: sequential strategies
        pay the sum, ``overlap_fused`` the overlapped max."""
        if kind == "matmul":
            if grid is None:
                raise ValueError("matmul decisions need grid=(K, M)")
            from repro.core.matmul import MatmulGrid

            topo = MatmulGrid(*grid).topo
            if layout is None:
                layout = layout_for(topo.num_routers)
        else:
            if layout is None:
                raise ValueError(f"{kind} decisions need a DeviceLayout")
            topo = layout.topo
        key = TuneKey(kind, topo.K, topo.M, bucket_bytes(nbytes),
                      str(np.dtype(dtype)), site,
                      bucket_compute_us(compute_us), emulated)
        if key in self._memo:
            return self._memo[key]

        cands = candidates(kind, site, emulated=emulated)
        analytic = analytic_prices(kind, layout, key.nbytes, cands, grid,
                                   key.compute_us)
        rounds, hops = priced_rounds(kind, layout, grid)

        if self.force is not None:
            strategy = self.force if self.force in cands else cands[0]
            dec = Decision(key, strategy, "forced", rounds, hops, analytic, {})
        elif self.mode == "off":
            dec = Decision(key, _default_strategy(kind, site), "off",
                           rounds, hops, analytic, {})
        else:
            # analytic mode ignores the cache too: its contract is pure
            # deterministic arithmetic over the schedule, independent of
            # whatever a previous measuring run left on disk
            dec = (self._cached_decision(key, cands, rounds, hops, analytic)
                   if self.mode == "measure" else None)
            if dec is None:
                dec = self._fresh_decision(key, cands, layout, grid,
                                           rounds, hops, analytic)
        self._memo[key] = dec
        self.decisions.append(dec)
        return dec

    def _cached_decision(self, key, cands, rounds, hops, analytic):
        ent = self._cache.get(str(key))
        if not isinstance(ent, dict):
            return None
        strategy = ent.get("strategy")
        if strategy not in cands:   # stale/foreign entry: ignore, re-derive
            return None
        measured = ent.get("measured_us")
        measured = dict(measured) if isinstance(measured, dict) else {}
        return Decision(key, strategy, "cache", rounds, hops, analytic, measured)

    def _fresh_decision(self, key, cands, layout, grid, rounds, hops, analytic):
        measured: dict[str, float] = {}
        if self.mode == "measure":
            for s in cands:
                try:
                    fn = _measure_closure(key.kind, key.site, s, layout, grid,
                                          key.nbytes, key.dtype,
                                          key.compute_us)
                except Exception:
                    fn = None
                if fn is not None:
                    measured[s] = _time_us(fn)
        return self._conclude(key, rounds, hops, analytic, measured)

    def _conclude(self, key, rounds, hops, analytic, measured):
        """Rank + record: cheapest measured strategy (persisted to the disk
        cache) or, with nothing measurable, cheapest analytic seed."""
        if measured:
            strategy = min(measured, key=measured.__getitem__)
            dec = Decision(key, strategy, "measured", rounds, hops, analytic, measured)
            self._cache[str(key)] = {
                "strategy": strategy, "source": "measured", "rounds": rounds,
                "measured_us": {k: round(v, 2) for k, v in measured.items()},
                "analytic_us": {k: round(v, 2) for k, v in analytic.items()},
            }
            self._dirty = True
            self.save()
        else:
            strategy = min(analytic, key=analytic.__getitem__)
            dec = Decision(key, strategy, "analytic", rounds, hops, analytic, {})
        return dec

    # -------------------------------------------------- combined guest sites
    def decide_combined(self, kind: str, embeddings, nbytes: int = 0,
                        dtype: str = "float32") -> Decision:
        """Combined-vs-time-muxed for one tenant SET: should N disjoint
        guests' ``kind`` collectives replay as one merged host program
        (makespan max(T_i)) or one by one (ΣT_i)?

        The key is the ``combined`` site class keyed on the guest-set
        signature — same host, same bytes, but a different tenant mix is a
        different decision. Measurement replays both arms on the reference
        backend (device-free, like ``site="host"``); kinds without a
        reference replay rank analytically. Memoized and disk-cached like
        ``decide``."""
        embeddings = tuple(embeddings)
        if not embeddings:
            raise ValueError("decide_combined needs at least one embedding")
        host = embeddings[0].host
        key = TuneKey(kind, host.K, host.M, bucket_bytes(nbytes),
                      str(np.dtype(dtype)), "combined", 0, True,
                      guest_signature(embeddings))
        if key in self._memo:
            return self._memo[key]

        cands = candidates(kind, "combined")
        analytic = analytic_combined_prices(kind, embeddings, key.nbytes)
        from repro.dist import collectives as coll

        comb = coll.concurrent_program(kind, embeddings)
        rounds = comb.num_rounds
        hops = max(
            costmodel.price(_schedule(kind, _guest_layout(e)), t_w=1.0, t_s=0.0)
            for e in embeddings
        )

        if self.force is not None:
            strategy = self.force if self.force in cands else cands[0]
            dec = Decision(key, strategy, "forced", rounds, hops, analytic, {})
        elif self.mode == "off":
            dec = Decision(key, _default_strategy(kind, "combined"), "off",
                           rounds, hops, analytic, {})
        else:
            dec = (self._cached_decision(key, cands, rounds, hops, analytic)
                   if self.mode == "measure" else None)
            if dec is None:
                measured: dict[str, float] = {}
                if self.mode == "measure":
                    for s in cands:
                        try:
                            fn = _measure_combined_closure(
                                kind, s, embeddings, key.nbytes, key.dtype)
                        except Exception:
                            fn = None
                        if fn is not None:
                            measured[s] = _time_us(fn)
                dec = self._conclude(key, rounds, hops, analytic, measured)
        self._memo[key] = dec
        self.decisions.append(dec)
        return dec

    # ------------------------------------------------------------ report
    def report(self) -> list[dict]:
        """The decision table accumulated this process, one row per call."""
        return [d.as_row() for d in self.decisions]


# ---------------------------------------------------------------------------
# Process-wide default tuner (the `backend="auto"` entry points use this)
# ---------------------------------------------------------------------------

_DEFAULT: Autotuner | None = None


def get_autotuner() -> Autotuner:
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = Autotuner()
    return _DEFAULT


def set_autotuner(tuner: Autotuner | None) -> None:
    """Install (or with None, reset) the process-wide tuner — tests and
    launchers use this to control cache location and mode."""
    global _DEFAULT
    _DEFAULT = tuner


# ---------------------------------------------------------------------------
# Config-level reports (serve.engine / launch.dryrun)
# ---------------------------------------------------------------------------

def moe_compute_us(E_loc: int, c_loc: int, n_model: int, d_model: int,
                   d_ff: int) -> int:
    """Estimated per-device µs of the MoE expert FFN fused into a dispatch
    round trip: each device contracts n_model arriving (E_loc, c_loc,
    d_model) capacity chunks through the silu-gated FFN — three einsums,
    ~6·tokens·d·f flops — at the proxy ``COMPUTE_RATE``. Shared by
    ``models.moe.moe_apply_ep`` and ``moe_site_report`` so both key the
    same tuner decision."""
    flops = 6.0 * E_loc * c_loc * n_model * d_model * d_ff
    return int(flops / COMPUTE_RATE * 1e6)


def moe_site_report(cfg, rules, n_tokens: int, dtype: str = "float32",
                    tuner: Autotuner | None = None) -> dict:
    """Chosen strategy + priced rounds for a config's MoE EP dispatch site.

    Mirrors the key ``models.moe.moe_apply_ep`` uses for its dispatch and
    combine all-to-alls: D3 view of the model axis, per-destination buffer
    bytes from the capacity bound at ``n_tokens`` routed tokens. Returns a
    JSON-ready dict; configs without an EP-capable MoE report why."""
    if getattr(cfg, "moe", None) is None:
        return {"status": "n/a", "reason": "config has no MoE"}
    m = cfg.moe
    E = m.num_experts
    n_model = rules.model_axis_size
    if E % n_model:
        return {"status": "n/a",
                "reason": f"E={E} not divisible by model axis {n_model} (TP path)"}
    tuner = tuner or get_autotuner()
    layout = layout_for(n_model)
    shards = max(1, rules.data_axis_size * n_model)
    t_loc = max(1, n_tokens // shards)
    c_loc = max(8, int(m.capacity_factor * t_loc * m.top_k / E))
    c_loc = -(-c_loc // 8) * 8
    chunk = (E // n_model) * c_loc * cfg.d_model * np.dtype(dtype).itemsize
    dec = tuner.decide(
        "alltoall", layout, chunk, dtype=dtype, site="shard",
        compute_us=moe_compute_us(E // n_model, c_loc, n_model, cfg.d_model,
                                  m.d_ff_expert))
    return {
        "status": "ok",
        "kind": "alltoall",
        "topology": f"D3({layout.topo.K},{layout.topo.M})",
        "key": str(dec.key),
        "strategy": dec.strategy,
        "source": dec.source,
        "rounds": dec.rounds,
        "priced_hops": dec.hops,
        "predicted_us": round(dec.predicted_us, 1),
        "analytic_us": {k: round(v, 1) for k, v in dec.analytic_us.items()},
        "measured_us": {k: round(v, 1) for k, v in dec.measured_us.items()},
        "moe_collectives": {
            "xla": "xla", "loop": "dragonfly",
            "overlap": "dragonfly_overlap",
            "overlap_fused": "dragonfly_overlap_fused"}[dec.strategy],
    }
