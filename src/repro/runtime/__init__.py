"""Runtime: Schedule IR -> one backend-neutral program -> pluggable backends.

``lowering.lower(schedule)`` turns ANY ``core.schedule.Schedule`` — all four
of the paper's algorithms — into a single ``program.CollectiveProgram``:
an ordered tuple of primitive stages (``Perm`` / ``Match`` /
``ReduceCombine`` / ``LocalContract``), each stamped with the IR
``(round_index, step)`` it came from and a ``start_step`` launch offset so
pipelined schedules survive lowering. ``compat`` papers over jax API drift
(shard_map moved out of jax.experimental after 0.4.x).

Backend interface contract
--------------------------
A backend executes programs; it never sees the IR. It must provide

    run_alltoall(x, program)                 # (n, n, ...) -> (n, n, ...)
    run_allreduce(x, program)                # (n, ...)    -> (n, ...)
    run_broadcast(x, program, pipelined=..)  # (n, ...) or (R, n, ...) waves
    run_matmul(B, A, program)                # (N·X, N·X) pair -> product

with identical results across backends (differential-testable bit-for-bit
on integer-valued floats). Obligations:

  * replay communication stages grouped by synchronous step — every stage
    of one ``(round_index, step)`` group reads the PRE-group values; the
    lowering guarantees distinct write targets within a group;
  * ``Perm``: full permutation of the per-device value; ``Match``: listed
    destinations replace their value; ``ReduceCombine``: destinations sum
    the arrival into an accumulator, identity pairs meaning a local (no
    link) contribution; ``LocalContract``: the named local compute steps
    of the matmul state machine (``load_b``/``mul_a``/``promote``/
    ``store_c``) over per-device state (val, acc, c);
  * honor ``pipelined``/``overlap`` by replaying in stable ``start_step``
    order — bit-identical to barrier order for any program whose schedule
    verified conflict-free under ``verify(pipelined=True)``;
  * use each stage's cached host index arrays (``sigma_np`` etc.) rather
    than rebuilding them per trace.

``backends.get_backend("jax_ppermute" | "reference")`` instantiates the
built-ins: ppermutes on a JAX mesh (optionally overlapped), and a pure-
NumPy host replay used for differential testing and device-free
validation.
"""

from repro.runtime import backends, compat, lowering, program  # noqa: F401
