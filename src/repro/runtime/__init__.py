"""Runtime: Schedule IR -> one backend-neutral program -> pluggable backends.

``lowering.lower(schedule)`` turns ANY ``core.schedule.Schedule`` — all four
of the paper's algorithms — into a single ``program.CollectiveProgram``:
an ordered tuple of primitive stages (``Perm`` / ``Match`` /
``ReduceCombine`` / ``LocalContract``), each stamped with the IR
``(round_index, step)`` it came from and a ``start_step`` launch offset so
pipelined schedules survive lowering. ``compat`` papers over jax API drift
(shard_map moved out of jax.experimental after 0.4.x).

Backend interface contract
--------------------------
A backend executes programs; it never sees the IR. It must provide

    run_alltoall(x, program)                 # (n, n, ...) -> (n, n, ...)
    run_allreduce(x, program)                # (n, ...)    -> (n, ...)
    run_broadcast(x, program, pipelined=..)  # (n, ...) or (R, n, ...) waves
    run_matmul(B, A, program)                # (N·X, N·X) pair -> product

with identical results across backends (differential-testable bit-for-bit
on integer-valued floats). Obligations:

  * replay communication stages grouped by synchronous step — every stage
    of one ``(round_index, step)`` group reads the PRE-group values; the
    lowering guarantees distinct write targets within each stage, and
    across the stages of one group only ``ReduceCombine`` destinations may
    repeat (each arrival folds into the accumulator with a commutative
    combine, so replay order within a group cannot change results);
  * ``Perm``: full permutation of the per-device value; ``Match``: listed
    destinations replace their value; ``ReduceCombine``: destinations sum
    the arrival into an accumulator, identity pairs meaning a local (no
    link) contribution; ``LocalContract``: the named local compute steps
    of the matmul state machine (``load_b``/``mul_a``/``promote``/
    ``store_c``) over per-device state (val, acc, c);
  * honor ``pipelined``/``overlap`` by replaying in stable ``start_step``
    order — bit-identical to barrier order for any program whose schedule
    verified conflict-free under ``verify(pipelined=True)``;
  * use each stage's cached host index arrays (``sigma_np`` etc.) rather
    than rebuilding them per trace;
  * honor ``program.active_devices`` (emulated guest-on-host programs,
    below): devices outside it are IDLE — they must not contribute data to
    any active device's result, and their own slots pass through (inputs
    unchanged for allreduce/broadcast; outputs zero for alltoall/matmul).
    Stages of such programs are partial permutations/matchings that never
    name an idle device, so a conforming backend usually gets this for
    free; the reference backend additionally ASSERTS idle slots were
    untouched after every replay.

Emulation rewrite guarantees (``rewrite.emulate(program, embedding)``)
----------------------------------------------------------------------
Paper Property 2 as a program-to-program pass: a lowered guest D3(J,L)
program becomes a host D3(K,M)-sized program with every device id mapped
through ``Embedding.device_map`` and ``active_devices`` recording the
guest-ordered host image. The pass guarantees:

  * ``(round_index, step, start_step)`` stamps are preserved, so pipelined
    replay of the rewrite interleaves exactly like the guest's;
  * dilation-1: every rewritten pair is one physical host link — the guest
    schedule's conflict-freedom transfers without re-verification (and can
    be re-checked via ``rewrite.emulate_schedule`` + ``core.simulator``);
  * bit-exactness: replaying the rewrite on host arrays carrying the guest
    data at ``active_devices`` slots (``rewrite.scatter_guest``) yields, at
    those slots, exactly the guest program's result on any conforming
    backend;
  * rewrites are memoized per (program, embedding) — i.e. per (host,
    guest, c_set, p_set, program) — so repeated failover re-lowers reuse
    the built host index arrays instead of rebuilding them in jit traces.

``backends.get_backend("jax_ppermute" | "reference")`` instantiates the
built-ins: ppermutes on a JAX mesh (optionally overlapped), and a pure-
NumPy host replay used for differential testing and device-free
validation.
"""

from repro.runtime import backends, compat, lowering, program, rewrite  # noqa: F401
