"""Runtime: lowering of core Schedule IR onto real JAX device meshes.

``lowering`` turns a ``core.schedule.Schedule`` into per-round device
permutations / tree matchings; ``executor`` replays them as ``ppermute``
collectives inside ``shard_map``. ``compat`` papers over jax API drift
(shard_map moved out of jax.experimental after 0.4.x).
"""

from repro.runtime import compat, executor, lowering  # noqa: F401
