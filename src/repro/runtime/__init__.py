"""Runtime: Schedule IR -> one backend-neutral program -> pluggable backends.

``lowering.lower(schedule)`` turns ANY ``core.schedule.Schedule`` — all four
of the paper's algorithms — into a single ``program.CollectiveProgram``:
an ordered tuple of primitive stages (``Perm`` / ``Match`` /
``ReduceCombine`` / ``LocalContract``), each stamped with the IR
``(round_index, step)`` it came from and a ``start_step`` launch offset so
pipelined schedules survive lowering. ``compat`` papers over jax API drift
(shard_map moved out of jax.experimental after 0.4.x).

Backend interface contract
--------------------------
A backend executes programs; it never sees the IR. It must provide

    run_alltoall(x, program)                 # (n, n, ...) -> (n, n, ...)
    run_allreduce(x, program)                # (n, ...)    -> (n, ...)
    run_broadcast(x, program, pipelined=..)  # (n, ...) or (R, n, ...) waves
    run_matmul(B, A, program)                # (N·X, N·X) pair -> product

with identical results across backends (differential-testable bit-for-bit
on integer-valued floats). Obligations:

  * replay communication stages grouped by synchronous step — every stage
    of one ``(round_index, step)`` group reads the PRE-group values; the
    lowering guarantees distinct write targets within each stage, and
    across the stages of one group only ``ReduceCombine`` destinations may
    repeat (each arrival folds into the accumulator with a commutative
    combine, so replay order within a group cannot change results);
  * ``Perm``: full permutation of the per-device value; ``Match``: listed
    destinations replace their value; ``ReduceCombine``: destinations sum
    the arrival into an accumulator, identity pairs meaning a local (no
    link) contribution; ``LocalContract``: the named local compute steps
    of the matmul state machine (``load_b``/``mul_a``/``promote``/
    ``store_c``) over per-device state (val, acc, c);
  * honor ``pipelined``/``overlap`` by replaying in stable ``start_step``
    order — bit-identical to barrier order for any program whose schedule
    verified conflict-free under ``verify(pipelined=True)``;
  * use each stage's cached host index arrays (``sigma_np`` etc.) rather
    than rebuilding them per trace;
  * honor ``program.active_devices`` (emulated guest-on-host programs,
    below): devices outside it are IDLE — they must not contribute data to
    any active device's result, and their own slots pass through (inputs
    unchanged for allreduce/broadcast; outputs zero for alltoall/matmul).
    Stages of such programs are partial permutations/matchings that never
    name an idle device, so a conforming backend usually gets this for
    free; the reference backend additionally ASSERTS idle slots were
    untouched after every replay.

Emulation rewrite guarantees (``rewrite.emulate(program, embedding)``)
----------------------------------------------------------------------
Paper Property 2 as a program-to-program pass: a lowered guest D3(J,L)
program becomes a host D3(K,M)-sized program with every device id mapped
through ``Embedding.device_map`` and ``active_devices`` recording the
guest-ordered host image. The pass guarantees:

  * ``(round_index, step, start_step)`` stamps are preserved, so pipelined
    replay of the rewrite interleaves exactly like the guest's;
  * dilation-1: every rewritten pair is one physical host link — the guest
    schedule's conflict-freedom transfers without re-verification (and can
    be re-checked via ``rewrite.emulate_schedule`` + ``core.simulator``);
  * bit-exactness: replaying the rewrite on host arrays carrying the guest
    data at ``active_devices`` slots (``rewrite.scatter_guest``) yields, at
    those slots, exactly the guest program's result on any conforming
    backend;
  * rewrites are memoized per (program, embedding) — i.e. per (host,
    guest, c_set, p_set, program) — so repeated failover re-lowers reuse
    the built host index arrays instead of rebuilding them in jit traces.

Concurrent-guest guarantees (``combine.combine(programs)``)
-----------------------------------------------------------
N rewritten guest programs with pairwise-disjoint ``active_devices``
images merge into ONE host program (multi-tenant serving of disjoint
D3(J,L) workloads on one mesh). What ``combine`` adds to the contract:

  * the combined program is an ordinary emulated program —
    ``active_devices`` is the guests' images concatenated in argument
    order — so every conforming backend replays it with NO new code: the
    idle-pass-through rules above already cover it;
  * stages from different guests sharing one ``(round_index, step,
    start_step)`` stamp are PACKED into a single partial stage (disjoint
    ``Perm``s become one partial permutation — one ppermute moves every
    guest's chunk), so the combined makespan is max(T_i) rounds, not Σ T_i;
  * per-guest isolation: a guest's stages only name its own devices, so
    each guest's slots carry bit-for-bit its solo (un-combined) result —
    any replay order preserving each guest's own stage order is exact;
  * conflicts are re-checked, not assumed: ``combine`` re-walks every
    synchronous step across guests (one packet per directed link; only
    ``ReduceCombine`` destinations repeat) and raises a structured
    ``GuestConflictError`` with the offending (step, link) — and
    ``combine.combine_schedules`` merges the guests' host-graph Schedule
    views so ``core.simulator.verify`` re-proves conflict-freedom on the
    literal host links;
  * matmul guests must share one local-contract skeleton (same grid
    shape/rounds) because ``load_b``/``mul_a``/``promote`` act on every
    device; combined matmul programs replay at the blocks level;
  * ``optimize`` fuses combined programs like any other: the stacked-σ
    exchange table spans all guests, so the fused replay is still one
    batched op per step group.

Optimizer pass guarantees (``optimize.optimize(program)``)
----------------------------------------------------------
The performance layer between lowering and execution: ``optimize`` fuses
every conflict-free step group of a program into one batched table op
(stacked-σ scatter for ``Perm`` groups, masked-gather tables for ``Match``
groups, stage-ordered (gather, mask) row stacks for ``ReduceCombine``
groups) and precomputes all per-stage host arrays into device-ready index
tensors, so replay is a single batched op or a ``lax.scan`` over tables
instead of a per-stage Python loop. The pass preserves:

  * **stamps** — fusion follows barrier ``(round_index, step)`` groups;
    because the schedule verified conflict-free under pipelined replay,
    the fused barrier-order result equals the ``start_step``-ordered one,
    so ``pipelined``/``overlap`` callers may substitute an optimized
    program freely;
  * **``active_devices``** — emulated programs fuse to partial tables
    (identity gathers + zero masks outside the embedded subset); idle
    pass-through holds exactly as for the unfused program, and the
    reference backend still asserts it;
  * **conflict-freedom** — only stages the lowering proved concurrent are
    merged; no fusion crosses a synchronous step;
  * **bit-exactness** — ``FusedCombine`` rows fold in stage order, group
    reads see pre-group values: every backend must produce bit-identical
    results for ``optimize(p)`` and ``p`` (differential-tested in
    ``tests/test_optimize.py``).

Every backend ``run_*`` accepts either representation. The optimized form
is the hot path: constant-size HLO regardless of program length (compile
time), one upload of stacked index tensors (trace time), one advanced-
indexing pass per group (host replay).

``backends.get_backend("jax_ppermute" | "reference" | "pallas_fused" |
"sendrecv" | "auto")`` instantiates the built-ins: ppermutes on a JAX
mesh (optionally overlapped), a pure-NumPy host replay used for
differential testing and device-free validation, the Pallas-fused
backend — optimized-table replay with Pallas kernels on the
ReduceCombine rounds and the §2 ``mul_a`` block contraction — and the
send/recv trace interpreter (below). The Pallas kernels run compiled on
TPU (where ``run_allreduce``'s exchange uses the remote-DMA ring
pattern) and under ``interpret=True`` everywhere else, so CPU CI
exercises the fused path bit-for-bit; interpret mode is a correctness
vehicle, not a performance one — see ``backends/pallas_fused.py`` for
the caveats. Conformance is executable: every registered backend is
swept against ``reference`` across all four algorithms and all program
forms by ``tests/test_backend_contract.py``.

Send/recv export guarantees (``export.export(program)``)
--------------------------------------------------------
The portable half of the collective compiler: any program — lowered,
optimized, emulated, combined — compiles to a versioned,
JSON-serializable :class:`~repro.runtime.export.DeviceTrace`, an ordered
op list PER DEVICE over five primitives (``send`` / ``recv`` /
``reduce`` / ``copy`` / ``contract``). What the export preserves:

  * **stamps** — every op keeps its ``(round_index, step)`` group and
    ``start_step`` launch window, so pipelined §3/§5 schedules export
    with their real overlap waves (``DeviceTrace.waves()``);
  * **static safety, re-proved** — ``export.validate`` checks the
    EXPORTED form (not the IR it came from) for link-conflict-freedom
    per synchronous step AND per overlap window, exact send/recv pairing
    per group, and structurally-empty op lists on idle devices; failures
    raise typed ``TraceValidationError`` subclasses;
  * **executability** — the ``sendrecv`` backend replays the trace alone
    (never the program stages) bit-identically to every other backend;
    ``to_json``/``from_json`` round-trip losslessly, so the JSON file is
    the whole program (``python -m repro.runtime.export`` validates
    saved traces from the CLI — the CI artifact check).

Autotuner guarantees (``autotune.Autotuner`` / the ``auto`` backend)
---------------------------------------------------------------------
The dispatcher that turns the three coexisting execution strategies into
one fast default path. Per call site — keyed on ``(kind, D3 topology,
bucketed message bytes, dtype, site)`` — it picks the cheapest of the
strategies structurally available there (per-stage ``loop`` replay,
``start_step``-ordered ``overlap``, fused ``optimize()`` tables, the
``pallas_fused`` backend, the device-free ``sendrecv`` trace replay, or
the plain ``xla`` collective), seeded by
``core.costmodel`` analytic prices and calibrated by one-shot measured
timings memoized in a schema-versioned on-disk cache. What it preserves:

  * **bit-exactness is free** — every candidate strategy satisfies the
    backend contract above, so switching strategies can never change a
    result, only its latency; emulated (``active_devices``) programs
    additionally exclude ``xla`` (the fused op would mix idle devices);
  * **determinism** — a warm cache returns the recorded decision without
    re-measurement; a corrupt or missing cache degrades to analytic
    seeding without error;
  * **escape hatches** — ``REPRO_AUTOTUNE=analytic`` (rank without
    measuring), ``REPRO_AUTOTUNE=off`` (pre-autotuner defaults), or
    ``REPRO_AUTOTUNE=<strategy>`` (pin one strategy) — the same knobs the
    ``Autotuner`` constructor takes programmatically.
"""

from repro.runtime import (  # noqa: F401
    autotune,
    backends,
    combine,
    compat,
    export,
    lowering,
    optimize,
    program,
    rewrite,
)
