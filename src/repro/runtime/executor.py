"""Executor: replay lowered schedules as ppermute collectives.

The ``*_on_axis`` functions run INSIDE ``shard_map`` over a 1-D mesh axis of
``lowered.n`` devices (device i = router ``topo.id_router(i)``). Each IR
round becomes its permutations issued back-to-back; the conflict-freedom
``core.simulator.verify`` proved for the schedule is the statement that a
round's permutations occupy disjoint directed links on the physical D3
network, so issuing them per-round preserves the paper's round structure
(visible in the HLO as one collective-permute per source vector).

``run_alltoall`` wraps the shard_map plumbing for whole-array callers and
is the executable form of §3: MoE token dispatch calls this instead of the
generic fused ``lax.all_to_all`` when ``--collectives dragonfly`` is on.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.runtime import compat
from repro.runtime.lowering import (
    LoweredAllToAll,
    LoweredBroadcast,
    LoweredExchange,
)


def alltoall_on_axis(x: jax.Array, axis_name: str, lowered: LoweredAllToAll) -> jax.Array:
    """All-to-all of per-destination chunks.

    ``x``: (n, ...) local buffer where x[j] is this device's chunk for
    device j. Returns (n, ...) where out[j] is the chunk received FROM
    device j — the ``lax.all_to_all(split_axis=0, concat_axis=0)`` layout.

    One ppermute per source vector: for vector permutation σ, device i
    contributes x[σ(i)] and the receiver σ(i) stores the arrival at index
    σ⁻¹(σ(i)) = i, its sender.
    """
    if x.shape[0] != lowered.n:
        raise ValueError(f"leading dim {x.shape[0]} != mesh axis {lowered.n}")
    idx = jax.lax.axis_index(axis_name)
    out = jnp.zeros_like(x)
    for rnd in lowered.rounds:
        for op in rnd:
            sigma = jnp.asarray(np.array(op.sigma, np.int32))
            inv = jnp.asarray(np.array(op.inverse, np.int32))
            sel = x[sigma[idx]]
            recv = jax.lax.ppermute(sel, axis_name, op.pairs)
            out = out.at[inv[idx]].set(recv)
    return out


def allreduce_on_axis(x: jax.Array, axis_name: str, lowered: LoweredExchange) -> jax.Array:
    """Recursive-doubling all-reduce (sum): one pairwise exchange per cube
    dimension — the §4 ascend algorithm on the emulated hypercube."""
    for op in lowered.rounds:
        recv = jax.lax.ppermute(x, axis_name, op.pairs)
        x = x + recv
    return x


def broadcast_on_axis(x: jax.Array, axis_name: str, lowered: LoweredBroadcast) -> jax.Array:
    """Spanning-tree broadcast from ``lowered.root``: each stage is a masked
    partial ppermute; non-receivers keep their value, so after the last
    stage every device holds the root's value."""
    idx = jax.lax.axis_index(axis_name)
    val = x
    for stage in lowered.stages:
        if not stage.pairs:
            continue
        is_dst = np.zeros(lowered.n, bool)
        for _, d in stage.pairs:
            is_dst[d] = True
        recv = jax.lax.ppermute(val, axis_name, stage.pairs)
        val = jnp.where(jnp.asarray(is_dst)[idx], recv, val)
    return val


# --------------------------------------------------------------------------
# Whole-array wrappers (build the shard_map for you).
# --------------------------------------------------------------------------

def _axis_mesh(n: int, axis_name: str) -> Mesh:
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(f"need {n} devices for the lowered schedule, have {len(devs)}")
    return Mesh(np.array(devs[:n]), (axis_name,))


def run_alltoall(x_global, lowered: LoweredAllToAll, axis_name: str = "df", mesh: Mesh | None = None):
    """x_global: (n, n, ...) where x_global[i, j] is the chunk device i
    sends to device j; returns (n, n, ...) with out[i, j] = x_global[j, i, ...]
    moved by the paper's round schedule."""
    mesh = mesh or _axis_mesh(lowered.n, axis_name)
    f = compat.shard_map(
        lambda s: alltoall_on_axis(s[0], axis_name, lowered)[None],
        mesh=mesh, in_specs=P(axis_name), out_specs=P(axis_name),
    )
    return jax.jit(f)(x_global)


def run_allreduce(x_global, lowered: LoweredExchange, axis_name: str = "df", mesh: Mesh | None = None):
    mesh = mesh or _axis_mesh(lowered.n, axis_name)
    f = compat.shard_map(
        lambda s: allreduce_on_axis(s[0], axis_name, lowered)[None],
        mesh=mesh, in_specs=P(axis_name), out_specs=P(axis_name),
    )
    return jax.jit(f)(x_global)


def run_broadcast(x_global, lowered: LoweredBroadcast, axis_name: str = "df", mesh: Mesh | None = None):
    mesh = mesh or _axis_mesh(lowered.n, axis_name)
    f = compat.shard_map(
        lambda s: broadcast_on_axis(s[0], axis_name, lowered)[None],
        mesh=mesh, in_specs=P(axis_name), out_specs=P(axis_name),
    )
    return jax.jit(f)(x_global)
