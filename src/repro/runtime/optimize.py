"""Program optimizer — fuse a ``CollectiveProgram`` into batched table ops.

``optimize(program)`` is the performance layer between lowering and
execution. The per-stage replay loop (one ppermute / one masked select per
stage) is faithful to the paper's round structure but pays a per-stage cost
three times over: Python dispatch while tracing, one HLO op chain per stage
while compiling, and per-stage host-array uploads while running. The
optimizer removes all three without changing a single output bit:

  * **step-group fusion** — every conflict-free step group (the maximal
    stage runs ``CollectiveProgram.step_groups`` yields) collapses into ONE
    batched op: consecutive ``Perm``s become a single stacked-σ scatter
    table (``FusedExchange``), a ``Match`` group becomes one masked-gather
    table (``FusedSelect``), a ``ReduceCombine`` group becomes stacked
    (gather, mask) rows applied in stage order (``FusedCombine``), and
    ``LocalContract`` stages keep their vocabulary (``FusedLocal``);
  * **table stacking** — per-group host arrays are precomputed into
    device-ready index tensors stacked along a leading group (or round)
    axis, so the JAX replay is a ``lax.scan`` over tables — the traced
    graph is one scan body regardless of program length — instead of a
    Python loop that unrolls every stage into the HLO;
  * **group-level vectorization on the host** — the NumPy replay of an
    optimized program applies each fused group as one advanced-indexing
    operation (the §3 all-to-all collapses to a single scatter), which is
    what the ``replay_*`` rows of ``bench_emulation_rewrite`` pay.

What ``optimize()`` preserves (the contract ``runtime/__init__.py``
documents and ``tests/test_optimize.py`` enforces):

  * **bit-exactness** — fused replay applies every group against the
    pre-group values with writes landing together, and ``FusedCombine``
    folds rows in stage order, so results are bit-identical to the
    per-stage replay on every backend, for native AND emulated programs;
  * **stamps** — the fused ops are built from barrier order
    ``(round_index, step)`` groups; because the schedule verified
    conflict-free under pipelined replay too, the barrier-order fused
    result equals the ``start_step``-ordered replay (so ``pipelined=True``
    / ``overlap=True`` callers may use an optimized program unchanged);
  * **``active_devices``** — emulated (guest-on-host) programs fuse to
    partial tables: idle devices get identity gathers and zero masks, so
    they pass through exactly as the backend contract requires;
  * **conflict-freedom** — fusion only merges stages the lowering already
    proved concurrent; no group ever merges across a synchronous step.

``optimize`` is memoized per program (programs are frozen/hashable); the
jitted JAX replay closures are memoized per optimized program, so repeated
collective calls (MoE dispatch per layer) reuse one compiled executable.

Pure NumPy table construction — jax is imported lazily inside the JAX
replay builders so the reference backend can replay optimized programs
without pulling in jax.
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np

from repro.runtime.program import (
    CollectiveProgram,
    LocalContract,
    Match,
    Perm,
    ReduceCombine,
)


# ---------------------------------------------------------------------------
# Fused ops: one per conflict-free step group.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class FusedExchange:
    """All ``Perm`` stages of an all-to-all program as one scatter table:
    ``out[dst[t], src[t]] = x[src[t], dst[t]]`` for every pair t. Valid
    because every stage reads the immutable input and the full exchange
    delivers each ordered (src, dst) chunk exactly once — so the whole
    program is one batched permute, independent of replay order.

    ``starts[t]`` is the pair's pipelined launch stamp (the owning stage's
    ``start_step``, itself the Schedule-1..3 launch from
    ``core.alltoall.round_starts``). Slicing the table by distinct starts
    (``exchange_waves``) recovers the wave-by-wave issue order the
    ``overlap_fused`` replay dispatches — all zeros for barrier schedules,
    where the whole exchange is one wave."""

    src: np.ndarray  # (T,) int32 senders, concatenated over stages
    dst: np.ndarray  # (T,) int32 receivers
    starts: np.ndarray | None = None  # (T,) int32 pipelined launch stamps


@dataclasses.dataclass(frozen=True, eq=False)
class FusedSelect:
    """One ``Match`` step group: ``val = where(mask, val[gather], val)``.
    ``gather`` is identity outside the group's destinations, so idle
    (emulated) devices read themselves and the mask keeps their value."""

    gather: np.ndarray  # (n,) int32
    mask: np.ndarray    # (n,) bool
    wave: int = 0       # broadcast wave (round) the group acts on


@dataclasses.dataclass(frozen=True, eq=False)
class FusedCombine:
    """One ``ReduceCombine`` step group as stacked (gather, mask) rows.
    Row k contributes ``where(mask[k], val[gather[k]], 0)`` and rows fold
    into the accumulator IN ORDER (k-sequential adds), reproducing the
    per-stage accumulation bit-for-bit. Identity (self) pairs become rows
    with identity gathers."""

    gather: np.ndarray  # (k, n) int32
    mask: np.ndarray    # (k, n) bool


@dataclasses.dataclass(frozen=True, eq=False)
class FusedLocal:
    """A ``LocalContract`` stage (matmul state machine step)."""

    fn: str
    mask: np.ndarray | None = None  # (n,) bool for store_c


FusedOp = FusedExchange | FusedSelect | FusedCombine | FusedLocal


@dataclasses.dataclass(frozen=True, eq=False)
class OptimizedProgram:
    """A ``CollectiveProgram`` compiled to fused table ops.

    Carries the source program for metadata (``kind``, ``n``, ``grid``,
    ``root``, ``active_devices``) — backends accept an ``OptimizedProgram``
    anywhere they accept a program and route it to the fused replay.
    ``uniform_rounds`` marks matmul programs whose per-round op recipes are
    identical (always true for the §2 lowering), enabling the round-scan
    replay; non-uniform programs fall back to an unrolled-but-fused loop.
    """

    program: CollectiveProgram
    ops: tuple[FusedOp, ...]
    uniform_rounds: bool = False

    @property
    def kind(self) -> str:
        return self.program.kind

    @property
    def n(self) -> int:
        return self.program.n

    @property
    def num_fused_ops(self) -> int:
        return len(self.ops)


def as_program(program) -> CollectiveProgram:
    """The underlying ``CollectiveProgram`` of either representation."""
    return program.program if isinstance(program, OptimizedProgram) else program


# ---------------------------------------------------------------------------
# Table builders.
# ---------------------------------------------------------------------------

def _select_of(group, n: int, wave: int = 0) -> FusedSelect:
    gather = np.arange(n, dtype=np.int32)
    mask = np.zeros(n, bool)
    for st in group:
        for s, d in st.pairs:
            if mask[d]:  # the lowering guarantees distinct Match dests
                raise ValueError("Match group has a repeated destination")
            gather[d] = s
            mask[d] = True
    return FusedSelect(gather, mask, wave)


def _combine_of(group, n: int) -> FusedCombine:
    gathers: list[np.ndarray] = []
    masks: list[np.ndarray] = []
    for st in group:
        if st.link_pairs:
            g = np.arange(n, dtype=np.int32)
            m = np.zeros(n, bool)
            for s, d in st.link_pairs:
                g[d] = s
                m[d] = True
            gathers.append(g)
            masks.append(m)
        if st.self_mask_np.any():
            gathers.append(np.arange(n, dtype=np.int32))
            masks.append(st.self_mask_np.copy())
    return FusedCombine(np.stack(gathers), np.stack(masks))


def _build_alltoall(program: CollectiveProgram) -> tuple[FusedOp, ...]:
    assert all(isinstance(st, Perm) for st in program.comm_stages)
    src = np.concatenate([st.src_np for st in program.comm_stages])
    dst = np.concatenate([st.dst_np for st in program.comm_stages])
    starts = np.concatenate([
        np.full(len(st.src_np), st.start_step, np.int32)
        for st in program.comm_stages
    ])
    return (FusedExchange(src.astype(np.int32), dst.astype(np.int32), starts),)


def _build_allreduce(program: CollectiveProgram) -> tuple[FusedOp, ...]:
    return tuple(
        _combine_of(group, program.n) for group in program.step_groups()
    )


def _build_broadcast(program: CollectiveProgram) -> tuple[FusedOp, ...]:
    waves = program.num_rounds > 1
    return tuple(
        _select_of(group, program.n,
                   wave=group[0].round_index if waves else 0)
        for group in program.step_groups()
    )


def _build_matmul(program: CollectiveProgram) -> tuple[FusedOp, ...]:
    ops: list[FusedOp] = []
    for group in program.step_groups():
        st = group[0]
        if isinstance(st, LocalContract):
            mask = st.mask_np.copy() if st.fn == "store_c" else None
            ops.append(FusedLocal(st.fn, mask))
        elif isinstance(st, Match):
            ops.append(_select_of(group, program.n))
        elif isinstance(st, ReduceCombine):
            ops.append(_combine_of(group, program.n))
        else:  # pragma: no cover - Perm never appears in matmul programs
            raise TypeError(f"unexpected stage {st!r} in matmul program")
    return tuple(ops)


def _op_signature(op: FusedOp):
    if isinstance(op, FusedLocal):
        return ("local", op.fn)
    if isinstance(op, FusedSelect):
        return ("select",)
    if isinstance(op, FusedCombine):
        return ("combine", op.gather.shape[0])
    return ("exchange",)


def _matmul_round_template(program: CollectiveProgram,
                           ops: tuple[FusedOp, ...]) -> bool:
    """True iff every round fuses to the same op recipe (same op kinds and
    combine widths) — the condition for the round-scan replay."""
    rounds = program.num_rounds
    if rounds == 0 or len(ops) % rounds:
        return False
    period = len(ops) // rounds
    sig = [_op_signature(op) for op in ops]
    return all(sig[i] == sig[i % period] for i in range(len(sig)))


_BUILDERS = {
    "alltoall": _build_alltoall,
    "allreduce": _build_allreduce,
    "broadcast": _build_broadcast,
    "matmul": _build_matmul,
}


@functools.lru_cache(maxsize=None)
def optimize(program: CollectiveProgram) -> OptimizedProgram:
    """Fuse ``program`` into batched table ops (memoized per program)."""
    if isinstance(program, OptimizedProgram):
        return program
    ops = _BUILDERS[program.kind](program)
    uniform = (
        program.kind == "matmul" and _matmul_round_template(program, ops)
    )
    return OptimizedProgram(program, ops, uniform_rounds=uniform)


# ---------------------------------------------------------------------------
# NumPy replay (the reference backend's fused path).
# ---------------------------------------------------------------------------

def _expand(mask: np.ndarray, ndim: int):
    """Broadcast a (n,) mask over an array's trailing feature dims."""
    return mask.reshape(mask.shape + (1,) * (ndim - mask.ndim))


def np_alltoall(x: np.ndarray, opt: OptimizedProgram) -> np.ndarray:
    (op,) = opt.ops
    out = np.zeros_like(x)
    out[op.dst, op.src] = x[op.src, op.dst]
    return out


def np_allreduce(x: np.ndarray, opt: OptimizedProgram) -> np.ndarray:
    val = np.asarray(x).copy()
    for op in opt.ops:
        recv = np.zeros_like(val)
        for g, m in zip(op.gather, op.mask):
            recv[m] += val[g[m]]  # stage-order fold, masked rows only
        val = val + recv
    return val


def np_broadcast(x: np.ndarray, opt: OptimizedProgram) -> np.ndarray:
    waves = opt.program.num_rounds > 1
    val = np.asarray(x).copy()
    for op in opt.ops:
        sl = val[op.wave] if waves else val
        sel = np.where(_expand(op.mask, sl.ndim), sl[op.gather], sl)
        if waves:
            val[op.wave] = sel
        else:
            val = sel
    return val


def np_matmul_blocks(b: np.ndarray, a: np.ndarray,
                     opt: OptimizedProgram) -> np.ndarray:
    dtype = np.result_type(b, a)
    a = a.astype(dtype)
    val = np.zeros_like(b, dtype=dtype)
    acc = np.zeros_like(val)
    c = np.zeros_like(val)
    for op in opt.ops:
        if isinstance(op, FusedLocal):
            if op.fn == "load_b":
                val = b.astype(dtype).copy()
                acc = np.zeros_like(val)
            elif op.fn == "mul_a":
                val = np.einsum("nab,nbc->nac", val, a)
                acc = np.zeros_like(val)
            elif op.fn == "promote":
                val, acc = acc, np.zeros_like(acc)
            elif op.fn == "store_c":
                m = _expand(op.mask, c.ndim)
                c = np.where(m, val, c)
        elif isinstance(op, FusedSelect):
            val = np.where(_expand(op.mask, val.ndim), val[op.gather], val)
        else:
            for g, m in zip(op.gather, op.mask):
                acc[m] = acc[m] + val[g[m]]  # stage-order fold, masked rows
    return c


# ---------------------------------------------------------------------------
# JAX replay: jitted lax.scan over stacked tables, memoized per program.
# jax imported lazily — the reference path above must stay jax-free.
# ---------------------------------------------------------------------------

def _combine_fold(acc, val, gather, mask, where):
    """Fold combine rows into ``acc`` in stage order (bit-exactness)."""
    for k in range(gather.shape[0]):
        acc = acc + where(mask[k], val[gather[k]])
    return acc


def stacked_combine_tables(opt: OptimizedProgram) -> tuple[np.ndarray, np.ndarray]:
    """(R, k, n) gather/mask tensors over an allreduce program's combine
    groups, narrow groups padded with identity-gather / zero-mask rows so
    every scan step (or kernel round) sees one table shape — a zero-masked
    row adds exact zeros, preserving bit-exactness. Shared by the scan
    replay below and the pallas_fused reduce kernels."""
    k = max(op.gather.shape[0] for op in opt.ops)
    n = opt.n
    ident = np.arange(n, dtype=np.int32)
    gat = np.stack([
        np.concatenate([op.gather,
                        np.broadcast_to(ident, (k - op.gather.shape[0], n))])
        for op in opt.ops
    ]).astype(np.int32)
    msk = np.stack([
        np.concatenate([op.mask,
                        np.zeros((k - op.mask.shape[0], n), bool)])
        for op in opt.ops
    ])
    return gat, msk


def _donate(donate: bool):
    return (0,) if donate else ()


@functools.lru_cache(maxsize=None)
def jax_alltoall(opt: OptimizedProgram, donate: bool = False):
    import jax
    import jax.numpy as jnp

    (op,) = opt.ops
    src, dst = jnp.asarray(op.src), jnp.asarray(op.dst)

    def replay(x):
        return jnp.zeros_like(x).at[dst, src].set(x[src, dst])

    return jax.jit(replay, donate_argnums=_donate(donate))


@functools.lru_cache(maxsize=None)
def exchange_waves(opt: OptimizedProgram) -> tuple[tuple[int, np.ndarray, np.ndarray], ...]:
    """The fused §3 exchange table sliced per launch wave: one
    ``(start_step, src, dst)`` triple per distinct ``FusedExchange.starts``
    value, in launch order. Barrier programs yield a single wave (the whole
    table); a ``pipelined_schedule`` program yields one slice per
    Schedule-1..3 launch stamp (``core.alltoall.round_starts``) — the issue
    order of the ``overlap_fused`` replays below and in the jax_ppermute
    backend. Wave slices never split a stage: stamps are per stage, so a
    stage's pairs always land in one wave."""
    (op,) = opt.ops
    starts = (op.starts if op.starts is not None
              else np.zeros(len(op.src), np.int32))
    out = []
    for s in np.unique(starts):
        sel = starts == s
        out.append((int(s), op.src[sel].copy(), op.dst[sel].copy()))
    return tuple(out)


def _wave_tables(opt: OptimizedProgram) -> tuple[np.ndarray, np.ndarray]:
    """(W, V) src/dst scan tables, one row per wave, narrow waves padded by
    REPEATING their first pair — a repeated (src, dst) scatters the same
    value to the same slot, so padding cannot perturb results (no masked
    adds that would rewrite -0.0)."""
    waves = exchange_waves(opt)
    v = max(len(s) for _, s, _ in waves)
    src = np.stack([np.resize(s, v) for _, s, _ in waves]).astype(np.int32)
    dst = np.stack([np.resize(d, v) for _, _, d in waves]).astype(np.int32)
    return src, dst


@functools.lru_cache(maxsize=None)
def jax_alltoall_overlapped(opt: OptimizedProgram, compute=None,
                            donate: bool = False):
    """Wave-by-wave replay of the fused exchange as a ``lax.scan`` with a
    DOUBLE-BUFFERED carry: wave w's table rows ride the carry as the
    *pending* buffer while the scan body commits wave w-1's already-arrived
    chunks — the §3 Schedules 1–3 launch overlap, projected onto the global
    array. The final pending wave drains after the scan.

    Without ``compute`` this is the one-way exchange, bit-identical to
    ``jax_alltoall``: ``out[dst, src] = x[src, dst]``. With a ``compute``
    the replay is the full dispatch→process→combine ROUND TRIP:
    ``out[src, dst] = compute(x[src, dst], dst)`` — the chunk travels to
    ``dst``, is processed by the destination's function, and returns to its
    sender (the MoE expert pipeline in one fused collective).
    ``compute(chunks, dst_ids)`` takes the wave's stacked (V, ...) chunks
    and their (V,) destination device ids (to select per-destination
    parameters) and returns the processed (V, ...) stack."""
    import jax
    import jax.numpy as jnp

    src_t, dst_t = _wave_tables(opt)
    src_j, dst_j = jnp.asarray(src_t), jnp.asarray(dst_t)

    def commit(out, x, psrc, pdst):
        if compute is None:
            return out.at[pdst, psrc].set(x[psrc, pdst])
        return out.at[psrc, pdst].set(compute(x[psrc, pdst], pdst))

    def replay(x):
        out = jnp.zeros_like(x)
        # pending wave: the previous iteration's (src, dst) rows. Seeded
        # with wave 0's own rows and has_pending=False so the first body
        # commits nothing.
        def body(carry, tables):
            out, psrc, pdst, has_pending = carry
            # wave w "dispatches" by riding the carry; its commit is
            # deferred one iteration (the double buffer)
            out = jnp.where(has_pending, commit(out, x, psrc, pdst), out)
            return (out, tables[0], tables[1], jnp.bool_(True)), None

        carry0 = (out, src_j[0], dst_j[0], jnp.bool_(False))
        (out, psrc, pdst, _), _ = jax.lax.scan(body, carry0, (src_j, dst_j))
        return commit(out, x, psrc, pdst)  # drain the last pending wave

    return jax.jit(replay, donate_argnums=_donate(donate))


@functools.lru_cache(maxsize=None)
def jax_allreduce(opt: OptimizedProgram, donate: bool = False):
    import jax
    import jax.numpy as jnp

    gat, msk = stacked_combine_tables(opt)
    gat_j, msk_j = jnp.asarray(gat), jnp.asarray(msk)

    def replay(x):
        def where(m, v):
            return jnp.where(m.reshape(m.shape + (1,) * (x.ndim - 1)), v, 0)

        def body(val, tables):
            g, m = tables
            return val + _combine_fold(jnp.zeros_like(val), val, g, m, where), None

        val, _ = jax.lax.scan(body, x, (gat_j, msk_j))
        return val

    return jax.jit(replay, donate_argnums=_donate(donate))


@functools.lru_cache(maxsize=None)
def jax_broadcast(opt: OptimizedProgram, donate: bool = False):
    import jax
    import jax.numpy as jnp

    waves = opt.program.num_rounds > 1
    gat = jnp.asarray(np.stack([op.gather for op in opt.ops]))
    msk = jnp.asarray(np.stack([op.mask for op in opt.ops]))
    wav = jnp.asarray(np.asarray([op.wave for op in opt.ops], np.int32))

    def replay(x):
        val = x if waves else x[None]

        def body(v, tables):
            g, m, w = tables
            sl = v[w]
            sel = jnp.where(m.reshape(m.shape + (1,) * (sl.ndim - 1)),
                            sl[g], sl)
            return v.at[w].set(sel), None

        val, _ = jax.lax.scan(body, val, (gat, msk, wav))
        return val if waves else val[0]

    return jax.jit(replay, donate_argnums=_donate(donate))


def _matmul_round_ops(opt: OptimizedProgram):
    """ops regrouped per round (requires ``uniform_rounds``)."""
    period = len(opt.ops) // opt.program.num_rounds
    return [opt.ops[i:i + period] for i in range(0, len(opt.ops), period)], period


def build_jax_matmul(opt: OptimizedProgram, *, mul_fn=None, combine_fn=None):
    """The fused §2 replay on (n, X, X) blocks: a ``lax.scan`` over rounds
    when the per-round recipes are uniform, an unrolled fused loop
    otherwise. ``mul_fn(val, a)`` / ``combine_fn(acc, val, gather, mask)``
    hooks let the pallas_fused backend route ``mul_a`` through the Pallas
    block kernel and the combine groups through the table kernel."""
    import jax
    import jax.numpy as jnp

    def where(m, v):
        return jnp.where(m.reshape(m.shape + (1,) * (v.ndim - 1)), v, 0)

    mul = mul_fn or (lambda val, a: val @ a)
    comb = combine_fn or (
        lambda acc, val, g, m: _combine_fold(acc, val, g, m, where)
    )

    def apply_op(op, tables, b, a, val, acc, c):
        if isinstance(op, FusedLocal):
            if op.fn == "load_b":
                val, acc = b, jnp.zeros_like(acc)
            elif op.fn == "mul_a":
                val, acc = mul(val, a), jnp.zeros_like(acc)
            elif op.fn == "promote":
                val, acc = acc, jnp.zeros_like(acc)
            elif op.fn == "store_c":
                c = jnp.where(tables["mask"].reshape(op.mask.shape + (1, 1)),
                              val, c)
            return val, acc, c
        if isinstance(op, FusedSelect):
            val = jnp.where(tables["mask"].reshape(op.mask.shape + (1, 1)),
                            val[tables["gather"]], val)
            return val, acc, c
        acc = comb(acc, val, tables["gather"], tables["mask"])
        return val, acc, c

    def tables_of(op):
        if isinstance(op, FusedLocal):
            return ({"mask": np.asarray(op.mask)} if op.fn == "store_c" else {})
        return {"gather": op.gather, "mask": op.mask}

    if opt.uniform_rounds:
        rounds, period = _matmul_round_ops(opt)
        template = rounds[0]
        # stack each op position's tables across rounds -> scan xs
        xs = []
        for pos in range(period):
            stacked = {
                key: jnp.asarray(np.stack([tables_of(r[pos])[key] for r in rounds]))
                for key in tables_of(template[pos])
            }
            xs.append(stacked)

        def replay(b, a):
            dtype = jnp.result_type(b, a)
            b, a = b.astype(dtype), a.astype(dtype)
            zero = jnp.zeros_like(b)

            def body(c, slices):
                val = acc = zero
                for pos, op in enumerate(template):
                    val, acc, c = apply_op(op, slices[pos], b, a, val, acc, c)
                return c, None

            c, _ = jax.lax.scan(body, zero, tuple(xs))
            return c

        return replay

    consts = [
        {key: jnp.asarray(v) for key, v in tables_of(op).items()}
        for op in opt.ops
    ]

    def replay(b, a):
        dtype = jnp.result_type(b, a)
        b, a = b.astype(dtype), a.astype(dtype)
        val = acc = c = jnp.zeros_like(b)
        for op, tabs in zip(opt.ops, consts):
            val, acc, c = apply_op(op, tabs, b, a, val, acc, c)
        return c

    return replay


@functools.lru_cache(maxsize=None)
def jax_matmul_blocks(opt: OptimizedProgram):
    import jax

    return jax.jit(build_jax_matmul(opt))


# ---------------------------------------------------------------------------
# Whole-matrix matmul wrapper shared by the JAX-side backends: scatter the
# (N·X, N·X) operands to router blocks (and guest blocks to their host
# slots) entirely in jnp — no host round-trip until the caller's boundary.
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _block_index(grid: tuple[int, int]) -> tuple[np.ndarray, np.ndarray]:
    """Router-id-ordered (block-row, block-col) index arrays of the §2
    storage map (host-built once per grid, device-uploaded per trace)."""
    from repro.core.matmul import MatmulGrid, block_of_router

    g = MatmulGrid(*grid)
    bi = np.empty(g.topo.num_routers, np.int32)
    bj = np.empty(g.topo.num_routers, np.int32)
    for r in g.topo.routers():
        i, j = block_of_router(g, r)
        rid = g.topo.router_id(r)
        bi[rid], bj[rid] = i, j
    return bi, bj


def jax_scatter_blocks(mat, grid: tuple[int, int]):
    """(N·X, N·X) -> (n_routers, X, X) on device (jnp twin of
    ``core.matmul.scatter_blocks``)."""
    import jax.numpy as jnp

    bi, bj = _block_index(grid)
    N = grid[0] * grid[1]
    mat = jnp.asarray(mat)
    X = mat.shape[0] // N
    blocks = mat.reshape(N, X, N, X).transpose(0, 2, 1, 3)
    return blocks[bi, bj]


def jax_gather_blocks(blocks, grid: tuple[int, int]):
    """(n_routers, X, X) -> (N·X, N·X) on device."""
    import jax.numpy as jnp

    bi, bj = _block_index(grid)
    N = grid[0] * grid[1]
    X = blocks.shape[1]
    out = jnp.zeros((N, N, X, X), blocks.dtype).at[bi, bj].set(blocks)
    return out.transpose(0, 2, 1, 3).reshape(N * X, N * X)


def jax_scatter_guest(x, program: CollectiveProgram, *, axes=(0,)):
    """jnp twin of ``rewrite.scatter_guest`` (identity for native)."""
    import jax.numpy as jnp

    if program.active_devices is None:
        return jnp.asarray(x)
    idx = program.active_np
    out = jnp.asarray(x)
    for ax in axes:
        shape = list(out.shape)
        shape[ax] = program.n
        sel = [slice(None)] * out.ndim
        sel[ax] = idx
        out = jnp.zeros(shape, out.dtype).at[tuple(sel)].set(out)
    return out


def jax_gather_guest(x, program: CollectiveProgram, *, axes=(0,)):
    import jax.numpy as jnp

    if program.active_devices is None:
        return jnp.asarray(x)
    idx = program.active_np
    out = jnp.asarray(x)
    for ax in axes:
        sel = [slice(None)] * out.ndim
        sel[ax] = idx
        out = out[tuple(sel)]
    return out
