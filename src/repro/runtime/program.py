"""Backend-neutral ``CollectiveProgram`` — the one lowered representation.

``runtime.lowering.lower`` turns any ``core.schedule.Schedule`` into a
``CollectiveProgram``: an ordered tuple of primitive *stages*, each stamped
with the IR round and hop step it came from plus a ``start_step`` (the
global launch step under pipelined replay) so pipelined schedules survive
lowering. Backends (``runtime.backends``) replay the same program on
different substrates — ppermutes on a JAX mesh, a pure-NumPy host replay —
without knowing which of the paper's four algorithms produced it.

Stage primitives
----------------
``Perm``           full device permutation: device i sends its value to
                   ``sigma[i]`` (one ``ppermute`` on the JAX backend).
``Match``          partial permutation (a matching): listed destinations
                   replace their value with the sender's; everyone else
                   keeps theirs. Identity pairs are elided at build time.
``ReduceCombine``  matching whose destinations *combine* the incoming value
                   into an accumulator (``acc[d] ⊕= val[s]``). Identity
                   pairs (s == d) are legal and mean a local contribution —
                   no link is used, the paper's "off-and-on" compute event.
``LocalContract``  no communication: a named local compute step the backend
                   applies between hops (block product, accumulator
                   promotion, masked output store, ...).

Synchronous-step semantics: stages sharing one ``(round_index, step)`` group
read the *pre-step* values and their writes land together — the paper's
model where all of a hop-step's packets are in flight simultaneously. The
lowering guarantees write targets are distinct within each stage (the
link-conflict-freedom ``core.simulator.verify`` proved, projected onto
devices); across the stages of one group only ``ReduceCombine``
destinations may repeat, and their commutative combine is why group replay
order still cannot change results.

Programs are host-retargetable: ``runtime.rewrite.emulate`` relabels a
guest D3(J,L) program through a Property-2 embedding into a D3(K,M)-sized
program whose ``active_devices`` names the participating host devices (in
guest order); every other device is idle and passes through. Backends honor
the mask per the contract in ``runtime/__init__.py``.

Everything here is pure Python over hashable data — programs can be cached
per (topology, schedule) key and shared across jit traces. Per-stage NumPy
index arrays are materialized once via ``cached_property`` so re-traces
reuse them instead of rebuilding host arrays inside every trace.
"""

from __future__ import annotations

import dataclasses
from functools import cached_property

import numpy as np

Pairs = tuple[tuple[int, int], ...]

#: program kinds — what the stages collectively compute
KINDS = ("alltoall", "allreduce", "broadcast", "matmul")

#: LocalContract vocabulary (the backend contract; see runtime/__init__.py)
LOCAL_FNS = ("load_b", "mul_a", "promote", "store_c")


@dataclasses.dataclass(frozen=True)
class Perm:
    """Permutation over device ids: device i sends to ``sigma[i]``.

    ``n`` (default 0 = ``len(pairs)``) is the device count the permutation
    acts over. With ``n > len(pairs)`` the stage is a *partial* permutation
    — a bijection on the subset of devices named in ``pairs`` with every
    other device an implicit fixed point that neither sends nor receives.
    The emulation rewrite (``runtime.rewrite``) produces these: a guest
    program's full permutations become host-sized partial permutations over
    the embedded device subset.
    """

    pairs: Pairs
    round_index: int = 0
    step: int = 0
    start_step: int = 0
    n: int = 0

    def __post_init__(self) -> None:
        srcs = {s for s, _ in self.pairs}
        dsts = {d for _, d in self.pairs}
        if len(srcs) != len(self.pairs) or dsts != srcs:
            raise ValueError("Perm pairs must form a permutation")
        if self.n and srcs and (min(srcs) < 0 or max(srcs) >= self.n):
            raise ValueError(f"Perm pairs exceed n={self.n}")
        if not self.n and srcs != set(range(len(self.pairs))):
            raise ValueError("full Perm must cover device ids 0..len(pairs)-1")

    @cached_property
    def size(self) -> int:
        """Device count the permutation acts over (= program n)."""
        return self.n or len(self.pairs)

    @cached_property
    def is_partial(self) -> bool:
        return len(self.pairs) < self.size

    @cached_property
    def sigma(self) -> tuple[int, ...]:
        out = list(range(self.size))  # implicit fixed points stay in place
        for s, d in self.pairs:
            out[s] = d
        return tuple(out)

    @cached_property
    def inverse(self) -> tuple[int, ...]:
        out = list(range(self.size))
        for s, d in self.pairs:
            out[d] = s
        return tuple(out)

    @cached_property
    def sigma_np(self) -> np.ndarray:
        return np.asarray(self.sigma, np.int32)

    @cached_property
    def inverse_np(self) -> np.ndarray:
        return np.asarray(self.inverse, np.int32)

    @cached_property
    def src_np(self) -> np.ndarray:
        """Explicit senders only (the pairs), for partial-perm replay."""
        return np.asarray([s for s, _ in self.pairs], np.int32)

    @cached_property
    def dst_np(self) -> np.ndarray:
        return np.asarray([d for _, d in self.pairs], np.int32)


@dataclasses.dataclass(frozen=True)
class Match:
    """Matching (partial permutation): destinations are masked in, everyone
    else keeps their value. Identity pairs must be elided by the builder."""

    n: int
    pairs: Pairs
    round_index: int = 0
    step: int = 0
    start_step: int = 0

    def __post_init__(self) -> None:
        if len({s for s, _ in self.pairs}) != len(self.pairs):
            raise ValueError("Match sources must be distinct")
        if len({d for _, d in self.pairs}) != len(self.pairs):
            raise ValueError("Match destinations must be distinct")
        if any(s == d for s, d in self.pairs):
            raise ValueError("Match pairs must not be identities (elide them)")

    @cached_property
    def dsts(self) -> tuple[int, ...]:
        return tuple(d for _, d in self.pairs)

    @cached_property
    def dst_mask_np(self) -> np.ndarray:
        mask = np.zeros(self.n, bool)
        mask[list(self.dsts)] = True
        return mask

    @cached_property
    def src_np(self) -> np.ndarray:
        return np.asarray([s for s, _ in self.pairs], np.int32)

    @cached_property
    def dst_np(self) -> np.ndarray:
        return np.asarray(self.dsts, np.int32)


@dataclasses.dataclass(frozen=True)
class ReduceCombine:
    """Matching whose receivers combine the arrival into an accumulator:
    ``acc[d] ⊕= val[s]``. Identity pairs (s == d) are local contributions —
    the sender's own value joins its accumulator without touching a link."""

    n: int
    pairs: Pairs
    combine: str = "add"
    round_index: int = 0
    step: int = 0
    start_step: int = 0

    def __post_init__(self) -> None:
        if self.combine != "add":
            raise ValueError(f"unsupported combine {self.combine!r}")
        if len({s for s, _ in self.pairs}) != len(self.pairs):
            raise ValueError("ReduceCombine sources must be distinct")
        if len({d for _, d in self.pairs}) != len(self.pairs):
            raise ValueError("ReduceCombine destinations must be distinct")

    @cached_property
    def link_pairs(self) -> Pairs:
        """The pairs that actually traverse links (s != d)."""
        return tuple((s, d) for s, d in self.pairs if s != d)

    @cached_property
    def self_mask_np(self) -> np.ndarray:
        mask = np.zeros(self.n, bool)
        mask[[s for s, d in self.pairs if s == d]] = True
        return mask

    @cached_property
    def dst_mask_np(self) -> np.ndarray:
        mask = np.zeros(self.n, bool)
        mask[[d for _, d in self.link_pairs]] = True
        return mask

    @cached_property
    def is_full_permutation(self) -> bool:
        srcs = {s for s, _ in self.pairs}
        return len(self.pairs) == self.n and srcs == {d for _, d in self.pairs}

    @cached_property
    def inverse_np(self) -> np.ndarray:
        """inverse[d] = s for full-permutation exchanges (allreduce rounds)."""
        if not self.is_full_permutation:
            raise ValueError("inverse only defined for full permutations")
        out = np.zeros(self.n, np.int32)
        for s, d in self.pairs:
            out[d] = s
        return out


@dataclasses.dataclass(frozen=True)
class LocalContract:
    """Named local compute stage (no communication). ``fn`` is one of
    ``LOCAL_FNS``; ``mask`` (device ids, over ``n`` devices) scopes
    ``store_c`` writes."""

    fn: str
    mask: tuple[int, ...] | None = None
    n: int = 0
    round_index: int = 0
    step: int = 0
    start_step: int = 0

    def __post_init__(self) -> None:
        if self.fn not in LOCAL_FNS:
            raise ValueError(f"unknown LocalContract fn {self.fn!r}")
        if self.mask is not None and not self.n:
            raise ValueError("masked LocalContract requires n")

    @cached_property
    def mask_np(self) -> np.ndarray:
        mask = np.zeros(self.n, bool)
        if self.mask is not None:
            mask[list(self.mask)] = True
        return mask


Stage = Perm | Match | ReduceCombine | LocalContract
COMM_STAGES = (Perm, Match, ReduceCombine)


def check_kind(program: "CollectiveProgram", kind: str) -> None:
    """Backend guard: the program must be of the expected ``kind``."""
    if program.kind != kind:
        raise ValueError(f"program is {program.kind!r}, expected {kind!r}")


@dataclasses.dataclass(frozen=True)
class CollectiveProgram:
    """One backend-retargetable lowered schedule.

    ``stages`` are in barrier replay order (round-major, step-minor);
    ``start_step`` stamps give the pipelined launch order — a stable sort by
    ``start_step`` is the overlapped replay, identical to program order for
    non-pipelined schedules.
    """

    kind: str
    n: int
    num_rounds: int
    stages: tuple[Stage, ...]
    root: int | None = None  # broadcast programs: root device id
    grid: tuple[int, int] | None = None  # matmul programs: (K, M) of the grid
    name: str = ""
    #: Emulated (guest-on-host) programs: the host device ids that
    #: participate, in GUEST id order — ``active_devices[g]`` is the host
    #: device emulating guest device g (``Embedding.device_map``). ``None``
    #: means every device participates (native programs). Devices outside
    #: the tuple are idle: backends must pass them through untouched, and
    #: the reference backend asserts they stay untouched.
    active_devices: tuple[int, ...] | None = None

    def __post_init__(self) -> None:
        if self.kind not in KINDS:
            raise ValueError(f"unknown program kind {self.kind!r}")
        if self.active_devices is not None:
            ids = self.active_devices
            if len(set(ids)) != len(ids) or len(ids) > self.n:
                raise ValueError("active_devices must be distinct device ids")
            if ids and (min(ids) < 0 or max(ids) >= self.n):
                raise ValueError(f"active_devices exceed n={self.n}")

    @property
    def guest_n(self) -> int:
        """Logical (guest) device count: ``n`` for native programs, the
        embedded subnetwork size for rewritten ones."""
        return self.n if self.active_devices is None else len(self.active_devices)

    @cached_property
    def active_np(self) -> np.ndarray:
        """Guest-ordered host ids (identity for native programs)."""
        if self.active_devices is None:
            return np.arange(self.n, dtype=np.int32)
        return np.asarray(self.active_devices, np.int32)

    @cached_property
    def active_mask_np(self) -> np.ndarray:
        """Boolean mask over the n devices: True = participates."""
        mask = np.zeros(self.n, bool)
        mask[self.active_np] = True
        return mask

    # ------------------------------------------------------------ structure
    @property
    def comm_stages(self) -> tuple[Stage, ...]:
        return tuple(s for s in self.stages if isinstance(s, COMM_STAGES))

    @property
    def num_permutes(self) -> int:
        """Communication stages = ppermutes the JAX backend issues."""
        return len(self.comm_stages)

    def stages_of_round(self, i: int) -> tuple[Stage, ...]:
        return tuple(s for s in self.stages if s.round_index == i)

    @property
    def perm_rounds(self) -> tuple[tuple[Perm, ...], ...]:
        """Per-round Perm groups (the §3 all-to-all round structure)."""
        out: list[list[Perm]] = [[] for _ in range(self.num_rounds)]
        for s in self.stages:
            if isinstance(s, Perm):
                out[s.round_index].append(s)
        return tuple(tuple(r) for r in out)

    @property
    def max_start_step(self) -> int:
        return max((s.start_step for s in self.stages), default=0)

    def pipelined_stages(self) -> tuple[Stage, ...]:
        """Stages in overlapped (start_step) order — the launch order of
        pipelined replay. Stable, so barrier programs are unchanged."""
        return tuple(sorted(self.stages, key=lambda s: s.start_step))

    def step_groups(self, pipelined: bool = False):
        """Yield maximal runs of communication stages sharing one synchronous
        step (and the LocalContract singletons between them, in order).

        Barrier order groups by ``(round_index, step)``; pipelined order
        groups by ``start_step`` so overlapping rounds' stages launch
        together. Backends apply each group's sends against the pre-group
        values (see module docstring).
        """
        stages = self.pipelined_stages() if pipelined else self.stages
        key = (lambda s: s.start_step) if pipelined else (lambda s: (s.round_index, s.step))
        group: list[Stage] = []
        for st in stages:
            if isinstance(st, LocalContract):
                if group:
                    yield tuple(group)
                    group = []
                yield (st,)
            elif group and key(group[-1]) == key(st):
                group.append(st)
            else:
                if group:
                    yield tuple(group)
                group = [st]
        if group:
            yield tuple(group)
