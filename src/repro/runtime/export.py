"""Send/recv export: compile any ``CollectiveProgram`` to a per-device trace.

``export(program)`` serializes a lowered (or optimized, emulated, combined)
program into a :class:`DeviceTrace` — a versioned, JSON-serializable list
of primitive ops PER DEVICE, in the NCCL style (Basu et al. 2023): what
each rank sends, receives, reduces, copies, and contracts, in replay
order. This is the portable half of the collective compiler: a non-XLA
runtime (or the pure-NumPy :class:`~repro.runtime.backends.sendrecv.
SendRecvBackend`) can execute the paper's four algorithms from the trace
alone, without the Schedule IR or the program stages.

Op vocabulary (:data:`OPS`)
---------------------------
``send(peer, buf, slot, nbytes)``   ship this device's ``buf`` value (chunk
                                    ``slot`` for all-to-all, wave ``slot``
                                    for pipelined broadcast) to ``peer``.
``recv(peer, buf, slot)``           file the arrival from ``peer`` into
                                    ``buf``: replacing (``val``/``out``) or
                                    into the scratch ``tmp`` a following
                                    ``reduce`` consumes.
``reduce(buf, src)``                fold into the target: ``buf[dev] +=
                                    src`` where ``src`` is ``tmp`` (the
                                    just-received value) or ``val`` (the
                                    pre-group own value — the paper's
                                    off-and-on local contribution).
``copy(buf, src, slot)``            local move between named buffers
                                    (``val <- b``, ``acc <- zero``,
                                    ``c <- val``, and the all-to-all
                                    self-chunk ``out[slot] <- x[slot]``).
``contract(fn)``                    the §2 ``mul_a`` block product
                                    ``val <- val @ a`` on this device.

Replay contract
---------------
Ops carry a ``group`` id; groups replay sequentially and correspond to the
program's synchronous step groups (every ``ReduceCombine`` stage of an
allreduce is its own group — the hypercube exchanges are data-dependent
round to round). Within a group all ``send`` payloads read the PRE-group
buffer values; writes land in per-device op order. Each op also keeps the
IR ``(round_index, step)`` stamp and the ``start_step`` launch stamp, so
pipelined §3/§5 schedules export with their real overlap windows —
:meth:`DeviceTrace.waves` lists them — while replay stays barrier-ordered
(bit-identical by the IR's pipelined conflict-freedom).

``validate(trace)`` re-proves the two structural safety properties on the
EXPORTED form (not the IR it came from): link-conflict-freedom — at most
one send per directed link per synchronous ``(round_index, step)`` AND per
``start_step`` overlap window — and exact 1:1 send/recv pairing per group.
Idle devices of emulated/combined programs must have EMPTY op lists: the
trace itself is the idle-pass-through guarantee. Violations raise typed
errors (:class:`TraceSchemaError` / :class:`TraceLinkConflictError` /
:class:`TracePairingError`, all :class:`TraceValidationError`).

``to_json``/``from_json`` round-trip losslessly (property-tested in
``tests/test_export.py``); ``python -m repro.runtime.export TRACE.json...``
validates trace files from the command line (the CI artifact check).
"""

from __future__ import annotations

import dataclasses
import functools
import json
import sys

from repro.runtime import optimize as _opt
from repro.runtime.program import (
    KINDS,
    CollectiveProgram,
    LocalContract,
    Match,
    Perm,
    ReduceCombine,
)

#: trace format version — bump on any incompatible layout change
SCHEMA_VERSION = 1

#: the full op vocabulary
OPS = ("send", "recv", "reduce", "copy", "contract")

#: named buffers ops may address, per kind:
#:   alltoall   x (read-only input), out
#:   allreduce  val
#:   broadcast  val  (leading wave axis when num_rounds > 1)
#:   matmul     b, a (read-only inputs), val, acc, c
#: plus the per-device scratch ``tmp`` (recv-then-reduce) and the pseudo
#: source ``zero`` (accumulator reset).
BUFS = ("x", "out", "val", "acc", "c", "b", "a", "tmp", "zero")


class TraceValidationError(ValueError):
    """Base: the trace is not a safe executable device program."""


class TraceSchemaError(TraceValidationError):
    """Wrong schema version or structurally malformed trace."""


class TracePairingError(TraceValidationError):
    """A send without its recv (or an orphan recv) within a group."""


class TraceLinkConflictError(TraceValidationError):
    """A directed link double-booked within one synchronous step/window."""


@dataclasses.dataclass(frozen=True)
class TraceOp:
    """One primitive on one device. Unused fields hold their defaults so
    ops stay uniform (and compress well in JSON — defaults are omitted)."""

    op: str
    group: int
    round_index: int
    step: int
    start_step: int
    peer: int = -1     # send/recv: the other endpoint's device id
    buf: str = ""      # the buffer written (recv/reduce/copy) or read (send)
    src: str = ""      # reduce/copy: source buffer name
    slot: int = -1     # alltoall chunk id / pipelined-broadcast wave id
    fn: str = ""       # contract: the LocalContract fn name
    nbytes: int = 0    # send: payload size stamp (0 = unstamped)


@dataclasses.dataclass(frozen=True)
class DeviceTrace:
    """The exported program: ``devices[i]`` is device i's ordered op list.

    Idle devices of emulated (``active_devices``) programs have empty
    lists — the trace carries the idle-pass-through guarantee structurally.
    Equality is structural, so ``from_json(to_json()) == trace``.
    """

    schema: int
    kind: str
    n: int
    num_rounds: int
    num_groups: int
    devices: tuple[tuple[TraceOp, ...], ...]
    root: int | None = None
    grid: tuple[int, int] | None = None
    name: str = ""
    active_devices: tuple[int, ...] | None = None

    # ------------------------------------------------------------- metrics
    @property
    def guest_n(self) -> int:
        return self.n if self.active_devices is None else len(self.active_devices)

    @property
    def num_ops(self) -> int:
        return sum(len(ops) for ops in self.devices)

    @property
    def num_sends(self) -> int:
        return sum(op.op == "send" for ops in self.devices for op in ops)

    def waves(self) -> tuple[tuple[int, int], ...]:
        """Overlap windows: sorted ``(start_step, sends launched there)``.
        Pipelined schedules show several rounds' sends sharing one window;
        barrier schedules degenerate to one window per step."""
        counts: dict[int, int] = {}
        for ops in self.devices:
            for op in ops:
                if op.op == "send":
                    counts[op.start_step] = counts.get(op.start_step, 0) + 1
        return tuple(sorted(counts.items()))

    # ---------------------------------------------------------------- JSON
    def to_json(self) -> str:
        devs = []
        for ops in self.devices:
            rows = []
            for op in ops:
                row: dict = {"op": op.op, "g": op.group, "r": op.round_index,
                             "t": op.step, "ss": op.start_step}
                for k, short in _OPTIONAL:
                    v = getattr(op, k)
                    if v != TraceOp.__dataclass_fields__[k].default:
                        row[short] = v
                rows.append(row)
            devs.append(rows)
        payload = {
            "schema": self.schema, "kind": self.kind, "n": self.n,
            "num_rounds": self.num_rounds, "num_groups": self.num_groups,
            "root": self.root,
            "grid": list(self.grid) if self.grid is not None else None,
            "name": self.name,
            "active_devices": (list(self.active_devices)
                               if self.active_devices is not None else None),
            "devices": devs,
        }
        return json.dumps(payload, separators=(",", ":"))

    @staticmethod
    def from_json(text: str) -> "DeviceTrace":
        try:
            raw = json.loads(text)
        except ValueError as e:
            raise TraceSchemaError(f"not a JSON trace: {e}") from None
        if not isinstance(raw, dict) or "devices" not in raw:
            raise TraceSchemaError("not a DeviceTrace JSON object")
        devices = []
        for rows in raw["devices"]:
            ops = []
            for row in rows:
                kw = {k: row[short] for k, short in _OPTIONAL if short in row}
                ops.append(TraceOp(row["op"], row["g"], row["r"], row["t"],
                                   row["ss"], **kw))
            devices.append(tuple(ops))
        grid = raw.get("grid")
        active = raw.get("active_devices")
        return DeviceTrace(
            schema=raw.get("schema", -1), kind=raw.get("kind", ""),
            n=raw.get("n", len(devices)),
            num_rounds=raw.get("num_rounds", 1),
            num_groups=raw.get("num_groups", 0),
            devices=tuple(devices), root=raw.get("root"),
            grid=tuple(grid) if grid is not None else None,
            name=raw.get("name", ""),
            active_devices=tuple(active) if active is not None else None,
        )


#: (TraceOp field, JSON short key) for default-omitted fields
_OPTIONAL = (("peer", "p"), ("buf", "b"), ("src", "s"), ("slot", "k"),
             ("fn", "f"), ("nbytes", "nb"))


# ---------------------------------------------------------------------------
# Export
# ---------------------------------------------------------------------------

def _iter_groups(prog: CollectiveProgram):
    """Replay groups: the program's synchronous step groups, except every
    allreduce ``ReduceCombine`` stage stands alone — hypercube exchange
    rounds are data-dependent (each reads the previous round's sums), so
    same-stamp stages must still replay sequentially."""
    if prog.kind == "allreduce":
        for st in prog.comm_stages:
            yield (st,)
    else:
        yield from prog.step_groups()


def _emit_local(devices: list, gid: int, st: LocalContract,
                prog: CollectiveProgram) -> None:
    s_ = dict(group=gid, round_index=st.round_index, step=st.step,
              start_step=st.start_step)
    if st.fn == "store_c":
        for d in (st.mask or ()):
            devices[d].append(TraceOp("copy", buf="c", src="val", **s_))
        return
    for d in prog.active_np.tolist():
        if st.fn == "load_b":
            devices[d].append(TraceOp("copy", buf="val", src="b", **s_))
        elif st.fn == "mul_a":
            devices[d].append(TraceOp("contract", fn="mul_a", **s_))
        else:  # promote
            devices[d].append(TraceOp("copy", buf="val", src="acc", **s_))
        devices[d].append(TraceOp("copy", buf="acc", src="zero", **s_))


def export(program, *, nbytes: int = 0) -> DeviceTrace:
    """Compile a program (or its ``OptimizedProgram`` form — the trace is
    the same, per the optimizer's bit-exactness guarantee) to a
    :class:`DeviceTrace`. ``nbytes`` stamps every ``send`` with its payload
    size when the caller knows it (pure metadata; replay ignores it).
    Memoized per (program, nbytes) — programs are frozen and hashable."""
    return _export(_opt.as_program(program), nbytes)


@functools.lru_cache(maxsize=None)
def _export(prog: CollectiveProgram, nbytes: int) -> DeviceTrace:
    waves = prog.kind == "broadcast" and prog.num_rounds > 1
    devices: list[list[TraceOp]] = [[] for _ in range(prog.n)]
    gid = -1
    for gid, group in enumerate(_iter_groups(prog)):
        if isinstance(group[0], LocalContract):
            _emit_local(devices, gid, group[0], prog)
            continue
        for st in group:
            s_ = dict(group=gid, round_index=st.round_index, step=st.step,
                      start_step=st.start_step)
            if isinstance(st, Perm):
                for s, d in st.pairs:
                    if s == d:  # the self chunk moves without a link
                        devices[s].append(
                            TraceOp("copy", buf="out", src="x", slot=s, **s_))
                    else:
                        devices[s].append(TraceOp("send", peer=d, buf="x",
                                                  slot=d, nbytes=nbytes, **s_))
                        devices[d].append(TraceOp("recv", peer=s, buf="out",
                                                  slot=s, **s_))
            elif isinstance(st, Match):
                slot = st.round_index if waves else -1
                for s, d in st.pairs:
                    devices[s].append(TraceOp("send", peer=d, buf="val",
                                              slot=slot, nbytes=nbytes, **s_))
                    devices[d].append(TraceOp("recv", peer=s, buf="val",
                                              slot=slot, **s_))
            elif isinstance(st, ReduceCombine):
                target = "val" if prog.kind == "allreduce" else "acc"
                for s, d in st.pairs:
                    if s == d:  # off-and-on: own pre-group value joins acc
                        devices[d].append(
                            TraceOp("reduce", buf=target, src="val", **s_))
                    else:
                        devices[s].append(TraceOp("send", peer=d, buf="val",
                                                  nbytes=nbytes, **s_))
                        devices[d].append(TraceOp("recv", peer=s, buf="tmp", **s_))
                        devices[d].append(
                            TraceOp("reduce", buf=target, src="tmp", **s_))
            else:  # pragma: no cover - Stage union is closed
                raise TypeError(f"unexpected stage {st!r}")
    return DeviceTrace(
        schema=SCHEMA_VERSION, kind=prog.kind, n=prog.n,
        num_rounds=prog.num_rounds, num_groups=gid + 1,
        devices=tuple(tuple(ops) for ops in devices),
        root=prog.root, grid=prog.grid, name=prog.name,
        active_devices=prog.active_devices,
    )


# ---------------------------------------------------------------------------
# Static validation
# ---------------------------------------------------------------------------

def _check_structure(trace: DeviceTrace) -> None:
    if trace.schema != SCHEMA_VERSION:
        raise TraceSchemaError(
            f"trace schema {trace.schema} != supported {SCHEMA_VERSION}")
    if trace.kind not in KINDS:
        raise TraceSchemaError(f"unknown trace kind {trace.kind!r}")
    if len(trace.devices) != trace.n:
        raise TraceSchemaError(
            f"trace has {len(trace.devices)} device lists for n={trace.n}")
    active = (set(range(trace.n)) if trace.active_devices is None
              else set(trace.active_devices))
    if not active <= set(range(trace.n)):
        raise TraceSchemaError(f"active_devices exceed n={trace.n}")
    for dev, ops in enumerate(trace.devices):
        if dev not in active and ops:
            raise TraceSchemaError(
                f"idle device {dev} has {len(ops)} ops — the trace must "
                f"carry the idle-pass-through guarantee structurally")
        for op in ops:
            if op.op not in OPS:
                raise TraceSchemaError(f"device {dev}: unknown op {op.op!r}")
            if not 0 <= op.group < trace.num_groups:
                raise TraceSchemaError(
                    f"device {dev}: op group {op.group} out of range "
                    f"[0, {trace.num_groups})")
            if op.op in ("send", "recv"):
                if not 0 <= op.peer < trace.n:
                    raise TraceSchemaError(
                        f"device {dev}: {op.op} peer {op.peer} out of range")
                if op.peer not in active:
                    raise TraceSchemaError(
                        f"device {dev}: {op.op} names idle peer {op.peer}")
                if not op.buf:
                    raise TraceSchemaError(f"device {dev}: {op.op} without buf")
            if op.op in ("reduce", "copy") and not (op.buf and op.src):
                raise TraceSchemaError(
                    f"device {dev}: {op.op} needs buf and src")


def _check_links(trace: DeviceTrace) -> None:
    """One send per directed link per synchronous step — checked per
    ``(round_index, step)`` stamp AND per ``start_step`` overlap window,
    so pipelined exports prove the stronger concurrent claim."""
    seen: set[tuple] = set()
    for dev, ops in enumerate(trace.devices):
        for op in ops:
            if op.op != "send":
                continue
            for key in (("rs", op.round_index, op.step, dev, op.peer),
                        ("ss", op.start_step, dev, op.peer)):
                if key in seen:
                    when = (f"step ({op.round_index}, {op.step})"
                            if key[0] == "rs"
                            else f"start_step window {op.start_step}")
                    raise TraceLinkConflictError(
                        f"link {dev}->{op.peer} double-booked at {when}")
                seen.add(key)


def _check_pairing(trace: DeviceTrace) -> None:
    sends: dict[tuple, int] = {}
    recvs: dict[tuple, int] = {}
    for dev, ops in enumerate(trace.devices):
        for op in ops:
            if op.op == "send":
                k = (op.group, dev, op.peer)
                sends[k] = sends.get(k, 0) + 1
            elif op.op == "recv":
                k = (op.group, op.peer, dev)
                recvs[k] = recvs.get(k, 0) + 1
    for k, c in sends.items():
        if recvs.get(k, 0) != c:
            g, s, d = k
            raise TracePairingError(
                f"group {g}: send {s}->{d} has {recvs.get(k, 0)} matching "
                f"recv(s), expected {c}")
    for k, c in recvs.items():
        if sends.get(k, 0) != c:
            g, s, d = k
            raise TracePairingError(
                f"group {g}: recv on {d} from {s} has no matching send")


def validate(trace: DeviceTrace) -> DeviceTrace:
    """Re-prove the exported form safe: schema/structure, link-conflict-
    freedom (per step and per overlap window), send/recv pairing. Returns
    the trace for chaining; raises a :class:`TraceValidationError`
    subclass naming the first violation."""
    _check_structure(trace)
    _check_links(trace)
    _check_pairing(trace)
    return trace


# ---------------------------------------------------------------------------
# CLI: validate trace files (the CI artifact check)
# ---------------------------------------------------------------------------

def main(argv: list[str] | None = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if not argv:
        print("usage: python -m repro.runtime.export TRACE.json [...]")
        return 2
    bad = 0
    for path in argv:
        try:
            with open(path) as f:
                trace = DeviceTrace.from_json(f.read())
            validate(trace)
        except (OSError, TraceValidationError) as e:
            print(f"FAIL {path}: {e}")
            bad += 1
            continue
        print(f"ok   {path}: kind={trace.kind} n={trace.n} "
              f"guest_n={trace.guest_n} groups={trace.num_groups} "
              f"ops={trace.num_ops} sends={trace.num_sends} "
              f"waves={len(trace.waves())}")
    return 1 if bad else 0


if __name__ == "__main__":  # pragma: no cover - exercised via subprocess
    raise SystemExit(main())
