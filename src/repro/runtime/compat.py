"""jax API drift shims.

The repo targets both the 0.4.x line (shard_map in jax.experimental, with
``check_rep``) and newer jax (``jax.shard_map`` with ``check_vma``). All
runtime / dist / model code routes shard_map through here.
"""

from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool | None = None):
    """jax.shard_map when available, else the jax.experimental fallback.
    ``check_vma`` maps onto the older ``check_rep`` flag."""
    if hasattr(jax, "shard_map"):
        kw = {} if check_vma is None else {"check_vma": check_vma}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _sm

    kw = {} if check_vma is None else {"check_rep": check_vma}
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


def make_mesh(axis_shapes, axis_names):
    """jax.make_mesh (>= 0.4.35) without the newer axis_types kwarg;
    falls back to mesh_utils + Mesh on older releases."""
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))
    from jax.experimental import mesh_utils

    devices = mesh_utils.create_device_mesh(tuple(axis_shapes))
    return jax.sharding.Mesh(devices, tuple(axis_names))
