"""Swapped Boolean Hypercube SBH(k, m) ⊂ D3(2^k, 2^m) — paper §4.

D3(2^k, 2^m) built over ⊕(Z mod 2) groups (XOR arithmetic). SBH(k,m) has
2^(k+2m) nodes (c, d, p); its links are the D3 links actually used by the
hypercube emulation:

  * π_i : (c,d,p) <-> (c,d,p^e_i)       local, flip bit i of p
  * γ_i : (c,d,p) <-> (c^e_i, p, d)     global, flip bit i of c (+swap)
  * Z   : (c,d,p) <-> (c,p,d)           global port 0 (absent when d == p)

Emulated (k+2m)-cube dimension exchange paths (dilation ≤ 3, avg < 2):

  c-bit i:  γ_i, Z                    (dilation 2; 1 when d == p)
  d-bit i:  Z, π_i, Z                 (dilation 3; Z∘π_i = 2 when d == p)
  p-bit i:  π_i                       (dilation 1)

With the synchronized header (§5) all three become uniform 4-step paths:
  c = [4; γ, 0, 0],  d = [4; 0, 0, δ],  p = [4; 0, π, 0].

Ascend–descend algorithms (all-reduce, FFT, bitonic steps) traverse the
k+2m dimensions in order; the emulation costs Σ dilations = 2(k+2m) hops,
i.e. 2× the hypercube — the paper's headline factor-2 claim.

Contract owed to the paper — §4, Theorem 4. Round count:
``allreduce_schedule(sbh)`` emits k+2m dimension-exchange rounds whose
emulated hop total is at most 2(k+2m) (``hypercube_cost``, dilation ≤ 3
per dimension, ≤ 2 on average). Conflict-freedom invariant: within each
dimension round every node pair exchanges along its emulation path with
zero directed-link conflicts — ``core.simulator.verify`` must agree
(asserted in tests/test_core_hypercube.py and test_schedule_ir.py).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.topology import D3, Router
from repro.core.simulator import Simulator, Conflict
from repro.core.routing import SyncHeader, header_trace
from repro.core.schedule import Schedule, path_round


@dataclasses.dataclass(frozen=True)
class SBH:
    k: int
    m: int

    @property
    def topo(self) -> D3:
        return D3(1 << self.k, 1 << self.m)

    @property
    def dims(self) -> int:
        return self.k + 2 * self.m

    @property
    def num_nodes(self) -> int:
        return 1 << self.dims

    # -------------------------------------------------- node <-> bit string
    def node(self, x: int) -> Router:
        """x is a (k+2m)-bit integer: c = high k bits, d = middle m, p = low m."""
        mask_m = (1 << self.m) - 1
        p = x & mask_m
        d = (x >> self.m) & mask_m
        c = x >> (2 * self.m)
        return (c, d, p)

    def index(self, r: Router) -> int:
        c, d, p = r
        return (c << (2 * self.m)) | (d << self.m) | p

    # --------------------------------------------------------- XOR-hop ops
    def local_xor(self, r: Router, bits: int) -> Router:
        c, d, p = r
        return (c, d, p ^ bits)

    def global_xor(self, r: Router, bits: int) -> Router:
        """Global port 'bits' under XOR arithmetic; bits == 0 is Z."""
        c, d, p = r
        return (c ^ bits, p, d)

    def field_of(self, dim: int) -> str:
        """Which coordinate field cube-dimension ``dim`` lives in."""
        if dim < self.m:
            return "p"
        if dim < 2 * self.m:
            return "d"
        return "c"

    def emulation_path(self, r: Router, dim: int) -> list[Router]:
        """Routers visited flipping cube-dimension ``dim`` from node r
        (the dilation-≤3 paths of §4, including the d == p special cases)."""
        c, d, p = r
        f = self.field_of(dim)
        if f == "p":
            return [r, self.local_xor(r, 1 << dim)]
        if f == "d":
            bit = 1 << (dim - self.m)
            if d == p:  # Z at the source is a self-loop: π_i then Z
                a = self.local_xor(r, bit)  # (c, d, d^bit)
                return [r, a, self.global_xor(a, 0)]  # (c, d^bit, d)
            a = self.global_xor(r, 0)  # (c, p, d)
            b = self.local_xor(a, bit)  # (c, p, d^bit)
            z = self.global_xor(b, 0)
            # if p == d^bit the trailing Z is a self-loop (b is already the
            # destination (c, d^bit, p) with swapped-equal coords): elide.
            return [r, a, b] if z == b else [r, a, b, z]
        bit = 1 << (dim - 2 * self.m)
        a = self.global_xor(r, bit)  # (c^bit, p, d)
        if d == p:
            return [r, a]  # swap is identity
        return [r, a, self.global_xor(a, 0)]

    def dilation(self, r: Router, dim: int) -> int:
        return len(self.emulation_path(r, dim)) - 1

    def dilation_stats(self) -> tuple[int, float]:
        """(max, average) dilation over all (node, dim) pairs."""
        worst = 0
        total = 0
        count = 0
        for x in range(self.num_nodes):
            r = self.node(x)
            for dim in range(self.dims):
                dil = self.dilation(r, dim)
                worst = max(worst, dil)
                total += dil
                count += 1
        return worst, total / count

    # ------------------------------------------- uniform dilation-4 headers
    def sync_header(self, dim: int) -> SyncHeader:
        """§5: c = [4; γ,0,0], d = [4; 0,0,δ], p = [4; 0,π,0]."""
        f = self.field_of(dim)
        if f == "c":
            return SyncHeader(4, 1 << (dim - 2 * self.m), 0, 0)
        if f == "d":
            return SyncHeader(4, 0, 0, 1 << (dim - self.m))
        return SyncHeader(4, 0, 1 << dim, 0)

    def sync_path(self, r: Router, dim: int) -> list[Router]:
        """Replay the header automaton from r under XOR arithmetic (D3 over
        ⊕Z_2 groups); returns visited routers. Degenerate steps (port 0)
        stay in place but still consume a synchronized step — that is the
        point of the uniform dilation-4 emulation."""
        path = [r]
        h = self.sync_header(dim)
        cur = r
        while not h.arrived:
            kind, port, h = h.step()
            assert isinstance(port, int)
            cur = self.local_xor(cur, port) if kind == "local" else self.global_xor(cur, port)
            path.append(cur)
        return path


# ---------------------------------------------------------------------------
# Ascend–descend: recursive-doubling all-reduce over the emulated cube.
# ---------------------------------------------------------------------------

def allreduce_rounds(sbh: SBH) -> list[list[tuple[Router, Router]]]:
    """One round per cube dimension; each round exchanges along that
    dimension via the emulation path (both directions simultaneously —
    links are full-duplex). Returns per-dimension lists of directed
    (src, dst) *endpoint* pairs; hop expansion happens in the simulator
    via emulation_path."""
    out = []
    for dim in range(sbh.dims):
        pairs = []
        for x in range(sbh.num_nodes):
            r = sbh.node(x)
            pairs.append((r, sbh.emulation_path(r, dim)[-1]))
        out.append(pairs)
    return out


def allreduce_schedule(sbh: SBH) -> Schedule:
    """Ascend–descend all-reduce as a unified ``Schedule``: one round per
    cube dimension, hops expanded from the dilation-≤3 emulation paths
    (payload = node index), ``meta["pairs"]`` holding the endpoint exchange
    permutation (an involution) the runtime lowers to one ppermute+add.
    Barrier makespan = Σ max-dilation = 2(k+2m) — the factor-2 claim."""
    topo = sbh.topo
    rounds = []
    for dim in range(sbh.dims):
        paths = []
        pairs = []
        for x in range(sbh.num_nodes):
            path = sbh.emulation_path(sbh.node(x), dim)
            paths.append((path, x))
            pairs.append((x, sbh.index(path[-1])))
        rounds.append(
            path_round(paths, meta={"dim": dim, "pairs": tuple(pairs),
                                    "field": sbh.field_of(dim)})
        )
    return Schedule(
        "sbh_allreduce", topo, rounds,
        meta={"k": sbh.k, "m": sbh.m, "dims": sbh.dims},
    )


def check_allreduce_conflicts(sbh: SBH) -> tuple[list[Conflict], int]:
    """Replay the full ascend all-reduce; every dimension-round expands to
    its (≤3)-hop emulation paths, packets advance one hop per step.
    Returns (conflicts, total_steps)."""
    total_steps = 0
    all_conflicts: list[Conflict] = []
    for dim in range(sbh.dims):
        sim = Simulator(sbh.topo)
        max_len = 0
        for pkt, x in enumerate(range(sbh.num_nodes)):
            path = sbh.emulation_path(sbh.node(x), dim)
            sim.add_path(0, path, pkt)
            max_len = max(max_len, len(path) - 1)
        all_conflicts.extend(sim.conflicts())
        total_steps += max_len
    return all_conflicts, total_steps


def simulate_allreduce(sbh: SBH, values: np.ndarray) -> np.ndarray:
    """values[x] per node; returns the all-reduced (sum) vector — verifies
    the ascend algorithm's data movement is a correct all-reduce."""
    vals = values.astype(np.float64).copy()
    for dim in range(sbh.dims):
        nxt = vals.copy()
        for x in range(sbh.num_nodes):
            partner = sbh.index(sbh.emulation_path(sbh.node(x), dim)[-1])
            nxt[x] = vals[x] + vals[partner]
        vals = nxt
    return vals


def hypercube_cost(sbh: SBH) -> tuple[int, int]:
    """(emulated cost in hops, native (k+2m)-cube cost) for one ascend."""
    emulated = sum(
        max(sbh.dilation(sbh.node(x), dim) for x in range(sbh.num_nodes))
        for dim in range(sbh.dims)
    )
    return emulated, sbh.dims
