"""Unified Schedule IR — the one conflict-checkable representation that all
four D3 algorithms emit, the simulator verifies, the cost model prices, and
the runtime lowers onto a JAX device mesh.

A ``Schedule`` is an ordered sequence of ``Round``s. A ``Round`` is a set of
directed ``Hop``s, each stamped with a *step* offset inside the round and a
hashable *payload* tag identifying the packet it carries. Rounds are barriers
by default (round i+1 starts after round i drains); a round may instead carry
``meta["start_step"]`` to describe pipelined schedules where rounds overlap
on the wire — ``core.simulator.verify`` honours it when ``pipelined=True``.

The paper's four algorithms map onto the IR as:

  * matmul (§2)      — KM rounds of 4 phases (steps 0..3), ``startups=2``;
  * all-to-all (§3)  — K·M²/s *vector rounds*: every router launches the
    round's s source vectors simultaneously (steps 0..2 = δ, γ, π phases);
    the vectors ride in ``meta["vectors"]`` so lowering can derive one
    device permutation per vector without re-parsing hop chains;
  * hypercube (§4)   — k+2m rounds, one per cube dimension, hops expanded
    from the dilation-≤3 emulation paths, ``meta["pairs"]`` holding the
    endpoint exchange permutation for the runtime;
  * broadcast (§5)   — spanning-tree rounds of stepped hops (payload = tree
    color), optionally pipelined via ``start_step``.

Everything downstream — ``simulator.verify``, ``costmodel.price``,
``runtime.lowering`` — consumes only this module's types.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Hashable, Iterable, Iterator

from repro.core.topology import D3, Router
from repro.core.routing import Vector, vector_dest


@dataclasses.dataclass(frozen=True)
class Hop:
    """One directed traversal of a physical link at ``step`` of its round."""

    step: int
    src: Router
    dst: Router
    payload: Hashable = 0

    def link(self) -> tuple[Router, Router]:
        return (self.src, self.dst)


@dataclasses.dataclass(frozen=True)
class Round:
    """One barrier-delimited group of hops.

    ``meta`` is free-form per-round metadata. Keys with IR-wide meaning:

      * ``vectors``    — tuple of source vectors (γ,π,δ) for vector rounds,
        used by the runtime to derive ppermute permutations;
      * ``pairs``      — tuple of (src_id, dst_id) endpoint exchanges for
        pairwise-exchange rounds (hypercube dimension rounds);
      * ``startups``   — number of software startups (t_s events) this
        round costs; ``costmodel.price`` defaults it to 1;
      * ``start_step`` — global launch step for pipelined replay.
    """

    hops: tuple[Hop, ...]
    meta: dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def num_steps(self) -> int:
        return 1 + max((h.step for h in self.hops), default=-1)

    def payloads(self) -> set[Hashable]:
        return {h.payload for h in self.hops}

    def hops_at(self, step: int) -> list[Hop]:
        return [h for h in self.hops if h.step == step]


@dataclasses.dataclass
class Schedule:
    """An ordered list of rounds on a concrete D3 topology."""

    name: str
    topo: D3
    rounds: list[Round]
    meta: dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def num_rounds(self) -> int:
        return len(self.rounds)

    @property
    def total_steps(self) -> int:
        """Sequential (barrier) makespan in hop steps."""
        return sum(r.num_steps for r in self.rounds)

    @property
    def num_hop_events(self) -> int:
        return sum(len(r.hops) for r in self.rounds)

    def all_hops(self) -> Iterator[tuple[int, Hop]]:
        for i, r in enumerate(self.rounds):
            for h in r.hops:
                yield i, h

    def validate(self) -> None:
        """Every hop must traverse a physical link of the topology."""
        for i, h in self.all_hops():
            if h.src == h.dst:
                raise ValueError(f"round {i}: degenerate hop {h} (elide, don't emit)")
            if not self.topo.is_link(h.src, h.dst):
                raise ValueError(
                    f"round {i}: {h.src} -> {h.dst} is not a link of "
                    f"D3({self.topo.K},{self.topo.M})"
                )


# --------------------------------------------------------------------------
# Builders
# --------------------------------------------------------------------------

def vector_round(
    topo: D3,
    sends: Iterable[tuple[Router, Vector]],
    payloads: Iterable[Hashable] | None = None,
    meta: dict[str, Any] | None = None,
) -> Round:
    """Build a round of simultaneous l-g-l source-vector sends.

    Hop phases are schedule positions, not path positions: the δ hop is
    always step 0, γ step 1, π step 2, and degenerate phases emit no hop —
    this keeps local/global phases aligned across packets, the synchronous
    round model Property 1/3 argue about. Payload defaults to the send's
    index within the round.
    """
    hops: list[Hop] = []
    sends = list(sends)
    tags = list(payloads) if payloads is not None else list(range(len(sends)))
    if len(tags) != len(sends):
        raise ValueError(f"{len(tags)} payloads for {len(sends)} sends")
    for tag, (src, vec) in zip(tags, sends):
        gamma, pi, delta = vec
        r0 = src
        r1 = topo.local_hop(r0, delta)
        r2 = topo.global_hop(r1, gamma)
        r3 = topo.local_hop(r2, pi)
        if r1 != r0:
            hops.append(Hop(0, r0, r1, tag))
        if r2 != r1:
            hops.append(Hop(1, r1, r2, tag))
        if r3 != r2:
            hops.append(Hop(2, r2, r3, tag))
    return Round(tuple(hops), dict(meta or {}))


def hop_round(
    hops: Iterable[tuple[int, Router, Router, Hashable]] | Iterable[Hop],
    meta: dict[str, Any] | None = None,
) -> Round:
    """Build a round from explicit (step, src, dst, payload) hops.
    Degenerate (src == dst) entries are elided — they use no link."""
    out: list[Hop] = []
    for h in hops:
        if not isinstance(h, Hop):
            h = Hop(*h)
        if h.src != h.dst:
            out.append(h)
    return Round(tuple(out), dict(meta or {}))


def path_round(
    paths: Iterable[tuple[list[Router], Hashable]],
    meta: dict[str, Any] | None = None,
    start_step: int = 0,
) -> Round:
    """Build a round from per-packet router paths; hop i of a path lands on
    step ``start_step + i``. Consecutive duplicates (degenerate waits) hold
    their step slot but emit no hop."""
    hops: list[Hop] = []
    for path, tag in paths:
        for i in range(len(path) - 1):
            if path[i] != path[i + 1]:
                hops.append(Hop(start_step + i, path[i], path[i + 1], tag))
    return Round(tuple(hops), dict(meta or {}))


def permutation_of_vector(topo: D3, vec: Vector) -> list[tuple[int, int]]:
    """The device permutation a single source vector induces when every
    router launches it simultaneously: src_id -> id(vector_dest(src, vec)).
    This is a bijection (Property 1) — the mechanical bridge from the IR to
    one ``ppermute`` per vector in the runtime lowering."""
    pairs = []
    for r in topo.routers():
        pairs.append((topo.router_id(r), topo.router_id(vector_dest(topo, r, vec))))
    return pairs
