"""Broadcast Swapped Dragonfly — paper §5.

Depth-3 spanning tree rooted at (c, d, p)  (header [3; *, *, *]):

    (c,d,p) --L--> (c,d,*) --G--> (*,*,d) --L--> (*,*,*)

Depth-4 spanning trees (header [4; *, *, *]) — M of them per drawer,
rooted at the M routers (c, d, p) of drawer (c, d):

    (c,d,p) --G--> (*,p,d) --L--> (*,p,*) --Z--> (*,*,p) --L--> (*,*,*)

(The paper prints the first hop's destination as (*,d,p); the global hop
swaps (d,p), so the reachable set is (*,p,d) — transcription fixed here,
the rest of §5 is consistent with this.) The M trees are edge-disjoint in
the DIRECTED sense (tree_p and tree_{p'} traverse the Z-link pair
{(x,p,p'),(x,p',p)} in opposite directions — full duplex, the standard
Dragonfly link model; all other stages use disjoint drawers/sources).

M simultaneous broadcasts from one source (c,d,q): delegate
(c,d,q) --L--> (c,d,p) ∀p, then each p runs tree_p: 5 hops total,
[t_s + 5 t_w] when routers duplicate packets.

Pipelining X >> M broadcasts: chaining depth-4 trees back-to-back at
offset 1 conflicts on the Z stage (paper's diagram), so trees chain in
PAIRS — 2 waves of M broadcasts every 6 hops — total cost 3X/M router
hops, vs X hops for the (single) depth-3 tree pipeline: the M-tree
schedule wins by M/3.

Contract owed to the paper — §5. Round count: one depth-3 tree spans all
n routers in 3 hop steps (an M-broadcast in 5, delegation included);
``pipelined_m_broadcast_schedule`` chains wave pairs so X broadcasts cost
3X/M rounds. Conflict-freedom invariant: the M depth-4 trees are
edge-disjoint in the DIRECTED sense (full-duplex Z links), so each wave's
hops — and, after pair-chaining, the overlapped waves — replay through
``core.simulator.verify`` with zero conflicts (asserted in
tests/test_core_broadcast.py).
"""

from __future__ import annotations

import dataclasses

from repro.core.topology import D3, Router
from repro.core.simulator import Simulator, Conflict
from repro.core.routing import SyncHeader, STAR, expand_broadcast
from repro.core.schedule import Schedule, hop_round


Hop = tuple[int, Router, Router]  # (step, src, dst)


def depth3_tree(topo: D3, root: Router) -> list[Hop]:
    """L, G, L — 3 steps."""
    c, d, p = root
    hops: list[Hop] = []
    lvl1 = [(c, d, q) for q in range(topo.M)]
    for r in lvl1:
        if r != root:
            hops.append((0, root, r))
    lvl2 = []
    for r in lvl1:
        for g in range(topo.K):
            dst = topo.global_hop(r, g)
            if dst != r:
                hops.append((1, r, dst))
            lvl2.append(dst)
    for r in set(lvl2):
        for q in range(topo.M):
            dst = (r[0], r[1], q)
            if dst != r:
                hops.append((2, r, dst))
    return hops


def depth4_tree(topo: D3, root: Router) -> list[Hop]:
    """G, L, Z, L — 4 steps; root (c,d,p) owns "color" p."""
    c, d, p = root
    hops: list[Hop] = []
    lvl1 = []
    for g in range(topo.K):
        dst = topo.global_hop(root, g)  # (c+g, p, d)
        if dst != root:
            hops.append((0, root, dst))
        lvl1.append(dst)
    lvl2 = []
    for r in set(lvl1):
        for q in range(topo.M):
            dst = (r[0], r[1], q)  # (x, p, *)
            if dst != r:
                hops.append((1, r, dst))
            lvl2.append(dst)
    lvl3 = []
    for r in set(lvl2):
        dst = topo.global_hop(r, 0)  # Z: (x, p, y) -> (x, y, p)
        if dst != r:
            hops.append((2, r, dst))
        lvl3.append(dst)
    for r in set(lvl3):
        for q in range(topo.M):
            dst = (r[0], r[1], q)
            if dst != r:
                hops.append((3, r, dst))
    return hops


def tree_covers(topo: D3, root: Router, hops: list[Hop]) -> bool:
    reached = {root} | {dst for _, _, dst in hops}
    return len(reached) == topo.num_routers


def m_broadcast(topo: D3, source: Router) -> list[Hop]:
    """Delegation + M depth-4 trees: M distinct broadcasts in 5 steps.
    Packet identity = tree color p (the delegate position)."""
    c, d, q = source
    hops: list[Hop] = []
    for p in range(topo.M):
        if (c, d, p) != source:
            hops.append((0, source, (c, d, p)))
        for step, a, b in depth4_tree(topo, (c, d, p)):
            hops.append((step + 1, a, b))
    return hops


def directed_edge_disjoint(trees: list[list[Hop]]) -> bool:
    seen: set[tuple[Router, Router]] = set()
    for t in trees:
        for _, a, b in t:
            if (a, b) in seen:
                return False
            seen.add((a, b))
    return True


def check_m_broadcast(topo: D3, source: Router) -> list[Conflict]:
    """Replay the delegation + M-tree schedule with per-tree packet ids."""
    sim = Simulator(topo)
    c, d, q = source
    for p in range(topo.M):
        if (c, d, p) != source:
            sim.add_hop(0, source, (c, d, p), packet=p)
        for step, a, b in depth4_tree(topo, (c, d, p)):
            sim.add_hop(step + 1, a, b, packet=p)
    return sim.conflicts()


# ---------------------------------------------------------------------------
# Schedule IR emitters — the §5 trees as unified, lowerable schedules.
# ---------------------------------------------------------------------------

def depth3_schedule(topo: D3, root: Router) -> Schedule:
    """One broadcast through the depth-3 tree as a single 3-step round.
    Payload = ("bcast", root) — one packet duplicated down the tree."""
    tag = ("bcast", topo.router_id(root))
    rnd = hop_round(
        [(step, a, b, tag) for step, a, b in depth3_tree(topo, root)],
        meta={"root": root, "tree": "depth3"},
    )
    return Schedule("broadcast_depth3", topo, [rnd], meta={"root": root})


def m_broadcast_schedule(topo: D3, source: Router) -> Schedule:
    """Delegation + M edge-disjoint depth-4 trees as one 5-step round;
    payload = tree color p, so the verifier sees M distinct packets."""
    c, d, q = source
    hops = []
    for p in range(topo.M):
        if (c, d, p) != source:
            hops.append((0, source, (c, d, p), p))
        for step, a, b in depth4_tree(topo, (c, d, p)):
            hops.append((step + 1, a, b, p))
    rnd = hop_round(hops, meta={"source": source, "tree": "m_depth4"})
    return Schedule("broadcast_m_tree", topo, [rnd], meta={"source": source})


def pipelined_m_broadcast_schedule(topo: D3, source: Router, waves: int) -> Schedule:
    """X = waves·M broadcasts, waves pair-chained every 6 steps (2 waves of
    M broadcasts per 6 hops => 3X/M makespan). One IR round per wave with
    ``meta["start_step"]`` carrying the launch offset; replay with
    ``verify(..., pipelined=True)``."""
    c, d, q = source
    rounds = []
    for w in range(waves):
        base = (w // 2) * 6 + (w % 2)  # pair members offset by 1
        hops = []
        for p in range(topo.M):
            pid = w * topo.M + p
            if (c, d, p) != source:
                hops.append((0, source, (c, d, p), pid))
            for step, a, b in depth4_tree(topo, (c, d, p)):
                hops.append((step + 1, a, b, pid))
        rounds.append(hop_round(hops, meta={"start_step": base, "wave": w}))
    return Schedule(
        "broadcast_m_tree_pipelined", topo, rounds,
        meta={"source": source, "waves": waves, "X": waves * topo.M},
    )


# ---------------------------------------------------------------------------
# Pipelined broadcast waves (X >> M).
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BroadcastPipelineReport:
    num_broadcasts: int
    total_steps: int
    conflicts: int

    @property
    def steps_per_broadcast(self) -> float:
        return self.total_steps / self.num_broadcasts


def pipeline_depth3(topo: D3, root: Router, X: int) -> BroadcastPipelineReport:
    """Depth-3 tree chained at offset 1 (conflict-free iff p != d)."""
    sim = Simulator(topo)
    tree = depth3_tree(topo, root)
    for w in range(X):
        for step, a, b in tree:
            sim.add_hop(step + w, a, b, packet=w)
    return BroadcastPipelineReport(X, sim.num_steps, len(sim.conflicts()))


def pipeline_depth4_pairs(topo: D3, source: Router, waves: int) -> BroadcastPipelineReport:
    """Pairs of M-broadcast waves chained every 6 steps (paper: 2 waves of
    M broadcasts / 6 hops => 3X/M). ``waves`` is the number of M-broadcast
    waves; X = waves * M broadcasts total."""
    sim = Simulator(topo)
    wave = m_broadcast(topo, source)
    for w in range(waves):
        base = (w // 2) * 6 + (w % 2) * 1  # pair members offset by 1
        for step, a, b in wave:
            sim.add_hop(base + step, a, b, packet=w * topo.M + (0 if a != source else 0))
    # packet ids must separate colors within a wave for conflict accounting
    sim2 = Simulator(topo)
    c, d, q = source
    for w in range(waves):
        base = (w // 2) * 6 + (w % 2) * 1
        for p in range(topo.M):
            pid = w * topo.M + p
            if (c, d, p) != source:
                sim2.add_hop(base, source, (c, d, p), packet=pid)
            for step, a, b in depth4_tree(topo, (c, d, p)):
                sim2.add_hop(base + step + 1, a, b, packet=pid)
    X = waves * topo.M
    return BroadcastPipelineReport(X, sim2.num_steps, len(sim2.conflicts()))


# ---------------------------------------------------------------------------
# Header-driven executor: verifies the router program [b; γ, π, δ] is
# position-independent — replaying ONLY the automaton reproduces the trees.
# ---------------------------------------------------------------------------

def run_header_broadcast(topo: D3, root: Router, header: SyncHeader) -> tuple[set[Router], int]:
    """Flood from root following the synchronized header; returns
    (covered routers, steps)."""
    frontier: list[tuple[Router, SyncHeader]] = [(root, header)]
    covered = {root}
    steps = 0
    while frontier:
        nxt: list[tuple[Router, SyncHeader]] = []
        advanced = False
        for r, h in frontier:
            if h.arrived:
                continue
            kind, port, h2 = h.step()
            targets = expand_broadcast(topo, r, kind, port)
            advanced = True
            if port == STAR or not targets:
                # broadcasting routers remain members of the next level
                # (the tree keeps a copy at the sender); degenerate
                # point-to-point hops stay put with the header advanced.
                nxt.append((r, h2))
            for t in targets:
                covered.add(t)
                nxt.append((t, h2))
        if advanced:
            steps += 1
        frontier = nxt
    return covered, steps
