"""Cycle-level link-conflict simulator for D3(K, M).

This is the verifier for every theorem in the paper: each algorithm module
(matmul / alltoall / hypercube / broadcast) emits *rounds*, where a round is
a list of packet sends; the simulator replays each round hop-by-hop on the
literal graph and asserts the paper's conflict model:

    within a single hop-step of a round, a DIRECTED link may be used by at
    most one packet (full-duplex links, standard Dragonfly assumption).

Two replay modes:

  * ``check_vector_round`` — all packets are 3-hop (l-g-l) source-vector
    packets launched simultaneously; hop t of every packet shares step t
    (the paper's Property-1/Property-3 setting).
  * ``Simulator`` — a general event-driven replay supporting multi-step
    pipelines (used by the broadcast spanning-tree schedules), where each
    packet is a list of (step, src, dst) directed-hop events.

Both return conflict diagnostics rather than just booleans so tests and
benchmarks can report *where* a schedule breaks.
"""

from __future__ import annotations

import collections
import dataclasses

from repro.core.topology import D3, Router
from repro.core.routing import Vector, vector_path, path_links


@dataclasses.dataclass
class Conflict:
    step: int
    link: tuple[Router, Router]
    packets: list[int]  # indices of offending packets

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"Conflict(step={self.step}, link={self.link[0]}->{self.link[1]}, packets={self.packets})"


def check_vector_round(
    topo: D3, sends: list[tuple[Router, Vector]]
) -> tuple[list[Conflict], dict[Router, list[int]]]:
    """Replay one round of simultaneous source-vector sends.

    Every packet advances one hop per step (hops are the non-degenerate
    links of its l-g-l path; packets whose l-g-l path elides a degenerate
    hop still advance on the *schedule position* so that local/global hop
    phases stay aligned across packets, matching the paper's synchronous
    round model).

    Returns (conflicts, arrivals) where arrivals maps destination router ->
    packet indices that arrived there.
    """
    # Build per-packet per-phase links. Phases: 0 = delta local hop,
    # 1 = gamma global hop, 2 = pi local hop. Degenerate phases use no link.
    conflicts: list[Conflict] = []
    arrivals: dict[Router, list[int]] = collections.defaultdict(list)
    phase_links: list[dict[tuple[Router, Router], list[int]]] = [
        collections.defaultdict(list) for _ in range(3)
    ]
    for idx, (src, vec) in enumerate(sends):
        gamma, pi, delta = vec
        r0 = src
        r1 = topo.local_hop(r0, delta)
        r2 = topo.global_hop(r1, gamma)
        r3 = topo.local_hop(r2, pi)
        if r1 != r0:
            phase_links[0][(r0, r1)].append(idx)
        if r2 != r1:
            phase_links[1][(r1, r2)].append(idx)
        if r3 != r2:
            phase_links[2][(r2, r3)].append(idx)
        arrivals[r3].append(idx)
    for phase, links in enumerate(phase_links):
        for link, users in links.items():
            if len(users) > 1:
                conflicts.append(Conflict(phase, link, users))
    return conflicts, dict(arrivals)


@dataclasses.dataclass
class HopEvent:
    step: int
    src: Router
    dst: Router
    packet: int


class Simulator:
    """General directed-hop replay with per-step link-conflict checking."""

    def __init__(self, topo: D3):
        self.topo = topo
        self.events: list[HopEvent] = []

    def add_hop(self, step: int, src: Router, dst: Router, packet: int) -> None:
        if src == dst:
            return  # degenerate, no link used
        if not self.topo.is_link(src, dst):
            raise ValueError(f"not a link in D3({self.topo.K},{self.topo.M}): {src} -> {dst}")
        self.events.append(HopEvent(step, src, dst, packet))

    def add_path(self, start_step: int, path: list[Router], packet: int) -> None:
        for i, link in enumerate(path_links(path)):
            self.add_hop(start_step + i, link[0], link[1], packet)

    def conflicts(self) -> list[Conflict]:
        by_step_link: dict[tuple[int, Router, Router], list[int]] = collections.defaultdict(list)
        for e in self.events:
            by_step_link[(e.step, e.src, e.dst)].append(e.packet)
        out = []
        for (step, src, dst), pkts in sorted(by_step_link.items()):
            if len(pkts) > 1:
                out.append(Conflict(step, (src, dst), pkts))
        return out

    @property
    def num_steps(self) -> int:
        return 1 + max((e.step for e in self.events), default=-1)

    def link_utilization(self) -> dict[int, int]:
        """links used per step — for pipelining/throughput analysis."""
        per_step: dict[int, int] = collections.defaultdict(int)
        for e in self.events:
            per_step[e.step] += 1
        return dict(per_step)


def assert_conflict_free(conflicts: list[Conflict], context: str = "") -> None:
    if conflicts:
        raise AssertionError(
            f"{context}: {len(conflicts)} link conflicts, first: {conflicts[0]}"
        )
