"""Unified conflict verifier for D3(K, M) schedules.

One entry point proves every theorem in the paper: each algorithm module
(matmul / alltoall / hypercube / broadcast) emits a ``core.schedule.Schedule``
and ``verify(topo, schedule)`` replays it hop-by-hop on the literal graph,
asserting the paper's conflict model:

    within a single hop-step, a DIRECTED link may be used by at most one
    packet (full-duplex links, standard Dragonfly assumption).

The report carries conflicts, round counts, makespan, payload coverage and
per-step link utilization, so tests and benchmarks report *where* a schedule
breaks rather than a bare boolean. Rounds replay as barriers by default;
``pipelined=True`` launches each round at ``meta["start_step"]`` instead, so
the §3/§5 pipelined schedules are measured by the same engine.

The two historical replay modes (``check_vector_round`` for synchronous
vector rounds, the event-driven ``Simulator`` for stepped spanning trees)
are retained as thin wrappers over the same engine.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Hashable

from repro.core.topology import D3, Router
from repro.core.routing import Vector, vector_dest, path_links
from repro.core.schedule import Hop, Round, Schedule, vector_round


@dataclasses.dataclass
class Conflict:
    step: int
    link: tuple[Router, Router]
    packets: list  # payload tags / indices of offending packets
    round_index: int = 0

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (
            f"Conflict(round={self.round_index}, step={self.step}, "
            f"link={self.link[0]}->{self.link[1]}, packets={self.packets})"
        )


@dataclasses.dataclass
class VerifyReport:
    """Unified diagnostics for one schedule replay."""

    schedule: str
    num_rounds: int
    total_steps: int  # makespan in hop steps (t_w units)
    conflicts: list[Conflict]
    num_hop_events: int
    reached: dict[Hashable, set[Router]]  # payload -> routers its hops touched
    link_utilization: dict[int, int]  # global step -> links in use

    @property
    def ok(self) -> bool:
        return not self.conflicts

    @property
    def steps_per_round(self) -> float:
        return self.total_steps / max(self.num_rounds, 1)

    def covered(self, payload: Hashable) -> set[Router]:
        return self.reached.get(payload, set())

    def raise_on_conflict(self, context: str = "") -> "VerifyReport":
        assert_conflict_free(self.conflicts, context or self.schedule)
        return self


def _replay_round(
    topo: D3,
    rnd: Round,
    base_step: int,
    round_index: int,
    by_step_link: dict,
    reached: dict,
    util: collections.Counter,
) -> None:
    for h in rnd.hops:
        if not topo.is_link(h.src, h.dst):
            raise ValueError(
                f"not a link in D3({topo.K},{topo.M}): {h.src} -> {h.dst}"
            )
        key = (base_step + h.step, h.src, h.dst)
        by_step_link[key].append((round_index, h.payload))
        reached[h.payload].add(h.dst)
        util[base_step + h.step] += 1


def verify(topo: D3, schedule: Schedule, *, pipelined: bool = False) -> VerifyReport:
    """Replay a Schedule on the literal D3 graph.

    Barrier replay (default): round i+1 starts the step after round i's last
    hop. Pipelined replay: each round starts at ``meta["start_step"]``
    (default 0), so overlapping rounds contend for links — exactly how the
    paper's Schedules 1–3 and the chained broadcast waves are costed.
    """
    by_step_link: dict = collections.defaultdict(list)
    reached: dict = collections.defaultdict(set)
    util: collections.Counter = collections.Counter()
    base = 0
    makespan = 0
    for i, rnd in enumerate(schedule.rounds):
        start = rnd.meta.get("start_step", 0) if pipelined else base
        _replay_round(topo, rnd, start, i, by_step_link, reached, util)
        makespan = max(makespan, start + rnd.num_steps)
        if not pipelined:
            base += rnd.num_steps
    conflicts = []
    for (step, src, dst), users in sorted(by_step_link.items()):
        if len(users) > 1:
            conflicts.append(
                Conflict(step, (src, dst), [p for _, p in users], users[0][0])
            )
    return VerifyReport(
        schedule=schedule.name,
        num_rounds=schedule.num_rounds,
        total_steps=makespan,
        conflicts=conflicts,
        num_hop_events=schedule.num_hop_events,
        reached=dict(reached),
        link_utilization=dict(util),
    )


# ---------------------------------------------------------------------------
# Thin wrappers preserving the historical entry points.
# ---------------------------------------------------------------------------

def check_vector_round(
    topo: D3, sends: list[tuple[Router, Vector]]
) -> tuple[list[Conflict], dict[Router, list[int]]]:
    """Replay one round of simultaneous source-vector sends (the
    Property-1/Property-3 setting). Packet index = position in ``sends``.

    Returns (conflicts, arrivals) where arrivals maps destination router ->
    packet indices that arrived there.
    """
    rnd = vector_round(topo, sends)
    rep = verify(topo, Schedule("vector_round", topo, [rnd]))
    arrivals: dict[Router, list[int]] = collections.defaultdict(list)
    for idx, (src, vec) in enumerate(sends):
        arrivals[vector_dest(topo, src, vec)].append(idx)
    return rep.conflicts, dict(arrivals)


class Simulator:
    """Event-driven directed-hop accumulator replayed by ``verify``."""

    def __init__(self, topo: D3):
        self.topo = topo
        self.hops: list[Hop] = []

    def add_hop(self, step: int, src: Router, dst: Router, packet) -> None:
        if src == dst:
            return  # degenerate, no link used
        if not self.topo.is_link(src, dst):
            raise ValueError(
                f"not a link in D3({self.topo.K},{self.topo.M}): {src} -> {dst}"
            )
        self.hops.append(Hop(step, src, dst, packet))

    def add_path(self, start_step: int, path: list[Router], packet) -> None:
        for i, link in enumerate(path_links(path)):
            self.add_hop(start_step + i, link[0], link[1], packet)

    def as_schedule(self, name: str = "simulator") -> Schedule:
        return Schedule(name, self.topo, [Round(tuple(self.hops))])

    def conflicts(self) -> list[Conflict]:
        return verify(self.topo, self.as_schedule()).conflicts

    @property
    def num_steps(self) -> int:
        return 1 + max((h.step for h in self.hops), default=-1)

    def link_utilization(self) -> dict[int, int]:
        """links used per step — for pipelining/throughput analysis."""
        return verify(self.topo, self.as_schedule()).link_utilization


def assert_conflict_free(conflicts: list[Conflict], context: str = "") -> None:
    if conflicts:
        raise AssertionError(
            f"{context}: {len(conflicts)} link conflicts, first: {conflicts[0]}"
        )
