"""Swapped Dragonfly topology D3(K, M).

The Swapped Dragonfly (Draper, arXiv:2202.01843) has K*M^2 routers with
coordinates (c mod K, d mod M, p mod M):

  * ``c`` — cabinet (group of drawers sharing a global-port color),
  * ``d`` — drawer within the cabinet,
  * ``p`` — position (router) within the drawer.

Connectivity::

    local :  (c, d, p) <->  (c, d, p')        for all p' != p
    global:  (c, d, p) <->  (c + g, p, d)     for all g  (note the d/p swap)

Local links form a complete graph K_M inside each drawer. The global link
with offset ``g`` (a *global port*) leaves cabinet ``c`` for cabinet
``c + g`` and lands on the router whose (d, p) are the *swap* of the
sender's. Global offset g = 0 is the "Z" link (c, d, p) <-> (c, p, d).

This module is the ground-truth graph: every schedule produced by the
algorithm modules (matmul / alltoall / hypercube / broadcast) is replayed
on this graph by ``core.simulator`` to prove the paper's conflict-freedom
and round-count claims.

Link identity
-------------
A *link* is an undirected physical resource; a *hop* is a directed
traversal. The paper's conflict model is: within one round, a directed
link (an ordered pair of adjacent routers) may be used by at most one
packet. Bidirectional links carry one packet each way simultaneously
(standard full-duplex assumption; the paper's Property 1 permutation
argument requires it). We therefore key conflicts on directed edges.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Iterator

Router = tuple[int, int, int]  # (c, d, p)
DirectedLink = tuple[Router, Router]


@dataclasses.dataclass(frozen=True)
class D3:
    """The Swapped Dragonfly D3(K, M)."""

    K: int
    M: int

    def __post_init__(self) -> None:
        if self.K < 1 or self.M < 1:
            raise ValueError(f"D3 requires K >= 1, M >= 1, got {self.K}, {self.M}")

    # ------------------------------------------------------------------ size
    @property
    def num_routers(self) -> int:
        return self.K * self.M * self.M

    @property
    def num_local_links(self) -> int:
        # K*M drawers, each a complete graph on M routers.
        return self.K * self.M * (self.M * (self.M - 1) // 2)

    @property
    def num_global_links(self) -> int:
        # Each router has K global ports (offsets 0..K-1); offset 0 with
        # d == p is a self-loop which we do not count. Undirected count:
        # pairs {(c,d,p), (c+g,p,d)}.
        total_directed = 0
        for g in range(self.K):
            for c, d, p in self.routers():
                dst = ((c + g) % self.K, p, d)
                if dst != (c, d, p):
                    total_directed += 1
        return total_directed // 2

    # --------------------------------------------------------------- routers
    def routers(self) -> Iterator[Router]:
        for c in range(self.K):
            for d in range(self.M):
                for p in range(self.M):
                    yield (c, d, p)

    def contains(self, r: Router) -> bool:
        c, d, p = r
        return 0 <= c < self.K and 0 <= d < self.M and 0 <= p < self.M

    # ---------------------------------------------------------- router <-> id
    def router_id(self, r: Router) -> int:
        """Linear id: c*M^2 + d*M + p — the device-mesh order used by dist/."""
        c, d, p = r
        assert self.contains(r), r
        return (c * self.M + d) * self.M + p

    def id_router(self, i: int) -> Router:
        p = i % self.M
        d = (i // self.M) % self.M
        c = i // (self.M * self.M)
        assert 0 <= c < self.K, i
        return (c, d, p)

    # ------------------------------------------------------------------ hops
    def local_hop(self, r: Router, delta: int) -> Router:
        """Use local port ``delta`` (offset within the drawer): p -> p+delta."""
        c, d, p = r
        return (c, d, (p + delta) % self.M)

    def global_hop(self, r: Router, gamma: int) -> Router:
        """Use global port ``gamma``: (c,d,p) -> (c+gamma, p, d). Swap d/p."""
        c, d, p = r
        return ((c + gamma) % self.K, p, d)

    def neighbors(self, r: Router) -> list[Router]:
        c, d, p = r
        out = [(c, d, q) for q in range(self.M) if q != p]
        for g in range(self.K):
            dst = self.global_hop(r, g)
            if dst != r:
                out.append(dst)
        return out

    def is_local_link(self, a: Router, b: Router) -> bool:
        return a[0] == b[0] and a[1] == b[1] and a[2] != b[2]

    def is_global_link(self, a: Router, b: Router) -> bool:
        # (c,d,p) -> (c', p, d) for some offset; the swap is the signature.
        return a[1] == b[2] and a[2] == b[1] and (a[0] != b[0] or a[1] != a[2])

    def is_link(self, a: Router, b: Router) -> bool:
        return self.contains(a) and self.contains(b) and (
            self.is_local_link(a, b) or self.is_global_link(a, b)
        )

    # -------------------------------------------------------------- distances
    def shortest_path_len(self, a: Router, b: Router) -> int:
        """BFS shortest-path length (used by tests on small instances)."""
        if a == b:
            return 0
        frontier = {a}
        seen = {a}
        dist = 0
        while frontier:
            dist += 1
            nxt = set()
            for r in frontier:
                for n in self.neighbors(r):
                    if n == b:
                        return dist
                    if n not in seen:
                        seen.add(n)
                        nxt.add(n)
            frontier = nxt
        raise AssertionError("disconnected — impossible for D3 with K,M >= 1")


def directed_link(a: Router, b: Router) -> DirectedLink:
    return (a, b)
