"""Property 2 — sub-network emulation: D3(J, L) ⊂ D3(K, M).

The routers of D3(K,M) with c in a J-subset C ⊆ Z_K and BOTH d and p in an
L-subset P ⊆ Z_M form a closed subnetwork isomorphic (dilation-1) to
D3(J, L), provided C and P are subgroups-like index sets closed under the
difference arithmetic the ports use. We use the canonical choice
C = {0..J-1} with port arithmetic relabeled through the subset index —
i.e. the embedded network's port g means "go to the g-th element of C",
realized on D3(K,M) by the port (C[(idx(c)+g) % J] - c) mod K, which is a
legal global port. Same for local ports within P.

This is the framework's *elastic scaling* mechanism: when chips die, the
runtime selects the largest (J, L) with J ≤ K, L ≤ M such that a healthy
C × P × P router set exists and REWRITES the already-lowered D3(J, L)
programs onto the survivors through ``Embedding.device_map`` (the
program-to-program pass in ``runtime.rewrite``) — recovery never re-derives
schedules. See train/fault_tolerance.py.

It is also the *multi-tenancy* mechanism: because a C × P × P image is
closed under every port the guest uses, two embeddings with disjoint
images occupy disjoint routers AND disjoint links, so their rewritten
programs can interleave on one host with zero conflicts
(``runtime.combine``). ``disjoint_embeddings`` packs a list of guest
shapes into such pairwise-disjoint images.

Contract owed to the paper: Property 2 (§1/§6) — D3(K,M) emulates every
D3(J,L) with J ≤ K, L ≤ M at dilation 1, so round counts and
conflict-freedom of all four algorithms transfer verbatim from guest to
host; ``Embedding.verify`` asserts the dilation-1 property link by link.
"""

from __future__ import annotations

import dataclasses
from functools import cached_property

import numpy as np

from repro.core.topology import D3, Router


@dataclasses.dataclass(frozen=True)
class Embedding:
    """Maps D3(J, L) routers onto a C × P × P subset of D3(K, M)."""

    host: D3
    guest: D3
    c_set: tuple[int, ...]
    p_set: tuple[int, ...]

    def __post_init__(self) -> None:
        if len(self.c_set) != self.guest.K or len(self.p_set) != self.guest.M:
            raise ValueError("subset sizes must match guest dimensions")
        if len(set(self.c_set)) != len(self.c_set) or len(set(self.p_set)) != len(self.p_set):
            raise ValueError("subsets must be duplicate-free")
        if not all(0 <= c < self.host.K for c in self.c_set):
            raise ValueError(f"c_set {self.c_set} out of range for K={self.host.K}")
        if not all(0 <= p < self.host.M for p in self.p_set):
            raise ValueError(f"p_set {self.p_set} out of range for M={self.host.M}")

    def map_router(self, r: Router) -> Router:
        c, d, p = r
        return (self.c_set[c], self.p_set[d], self.p_set[p])

    # ------------------------------------------------- vectorized device maps
    @cached_property
    def device_map(self) -> np.ndarray:
        """``device_map[g]`` = host router id of guest router id ``g`` —
        the whole embedding as one int32 gather, built once and cached
        (hash/eq of the frozen dataclass ignore the cache, so embeddings
        stay valid dict/lru keys)."""
        c = np.asarray(self.c_set, np.int32)[:, None, None]
        d = np.asarray(self.p_set, np.int32)[None, :, None]
        p = np.asarray(self.p_set, np.int32)[None, None, :]
        ids = (c * self.host.M + d) * self.host.M + p
        ids = ids.reshape(-1)  # guest router-id order: c-major, then d, then p
        ids.setflags(write=False)
        return ids

    @cached_property
    def host_to_guest(self) -> np.ndarray:
        """Inverse map: host router id -> guest router id, or -1 for host
        devices outside the embedded subnetwork (the idle devices)."""
        inv = np.full(self.host.num_routers, -1, np.int32)
        inv[self.device_map] = np.arange(self.guest.num_routers, dtype=np.int32)
        inv.setflags(write=False)
        return inv

    def map_local_port(self, r: Router, delta: int) -> int:
        """Guest local port delta at guest router r -> host local port."""
        c, d, p = r
        src = self.p_set[p]
        dst = self.p_set[(p + delta) % self.guest.M]
        return (dst - src) % self.host.M

    def map_global_port(self, r: Router, gamma: int) -> int:
        c, d, p = r
        src = self.c_set[c]
        dst = self.c_set[(c + gamma) % self.guest.K]
        return (dst - src) % self.host.K

    def verify(self) -> None:
        """Every guest link maps to a host link (dilation 1) and the global
        hop's d/p swap is preserved."""
        g, h = self.guest, self.host
        for r in g.routers():
            hr = self.map_router(r)
            for delta in range(1, g.M):
                dst = g.local_hop(r, delta)
                hdst = self.map_router(dst)
                if not h.is_local_link(hr, hdst):
                    raise AssertionError(f"local {r}->{dst} maps to non-link {hr}->{hdst}")
            for gamma in range(g.K):
                dst = g.global_hop(r, gamma)
                if dst == r:
                    continue
                hdst = self.map_router(dst)
                if not h.is_global_link(hr, hdst):
                    raise AssertionError(f"global {r}->{dst} maps to non-link {hr}->{hdst}")


def embed(host: D3, J: int, L: int, c_set=None, p_set=None) -> Embedding:
    if J > host.K or L > host.M:
        raise ValueError("guest must not exceed host")
    c_set = tuple(c_set) if c_set is not None else tuple(range(J))
    p_set = tuple(p_set) if p_set is not None else tuple(range(L))
    emb = Embedding(host, D3(J, L), c_set, p_set)
    emb.verify()
    return emb


def disjoint_embeddings(host: D3, guest_shapes) -> tuple[Embedding, ...]:
    """Pack guest shapes [(J, L), ...] into pairwise-DISJOINT Property-2
    embeddings of ``host`` — the enumerator behind concurrent guests
    (``runtime.combine``).

    Disjointness needs only ONE axis to be partitioned, because an image
    is the product set C × P × P: guests on disjoint cabinet sets never
    share a router (whatever their position sets), and likewise for
    disjoint position sets. We try the cabinet regime first (Σ J ≤ K —
    each guest keeps all M positions available, mirroring
    ``largest_embeddable``'s tie-break toward whole drawers), then the
    position regime (Σ L ≤ M), and raise when neither fits. Every
    returned embedding is dilation-1-verified.
    """
    shapes = [(int(J), int(L)) for J, L in guest_shapes]
    if not shapes:
        raise ValueError("disjoint_embeddings() needs at least one guest shape")
    for J, L in shapes:
        if J > host.K or L > host.M:
            raise ValueError(
                f"guest D3({J},{L}) does not fit host D3({host.K},{host.M})"
            )
    if sum(J for J, _ in shapes) <= host.K:
        out, c0 = [], 0
        for J, L in shapes:
            out.append(embed(host, J, L, c_set=range(c0, c0 + J)))
            c0 += J
        return tuple(out)
    if sum(L for _, L in shapes) <= host.M:
        out, p0 = [], 0
        for J, L in shapes:
            out.append(embed(host, J, L, p_set=range(p0, p0 + L)))
            p0 += L
        return tuple(out)
    raise ValueError(
        f"guest shapes {shapes} do not pack disjointly into "
        f"D3({host.K},{host.M}): need Σ J ≤ {host.K} or Σ L ≤ {host.M}"
    )


def largest_embeddable(host: D3, dead: set[Router]) -> tuple[int, int, tuple, tuple]:
    """Survivor-set search over the two drop regimes of Property 2; returns
    (J, L, c_set, p_set) with n = J·L² maximal between them.

    A dead router (c, d, p) is excluded from the C × P × P image iff its
    cabinet leaves C or one of its (d, p) indices leaves P, so two pure
    regimes always work:

      * *cabinet-drop*: remove every cabinet containing a dead router —
        survivors D3(K − |bad_c|, M), best for failures clustered in few
        cabinets;
      * *position-drop*: remove every position index a dead router poisons
        (both its d and its p) — survivors D3(K, M − |bad_p|), best for
        failures striped across many cabinets at few (d, p) indices.

    We price both and keep the larger network (ties to cabinet-drop, which
    keeps drawers whole). Mixed drops (some cabinets AND some positions)
    are a set-cover problem left to callers with exotic failure patterns.
    """
    bad_c = {r[0] for r in dead}
    bad_p = {r[1] for r in dead} | {r[2] for r in dead}
    cab_c = tuple(c for c in range(host.K) if c not in bad_c)
    pos_p = tuple(p for p in range(host.M) if p not in bad_p)
    candidates: list[tuple[int, int, tuple, tuple]] = []
    if cab_c:
        candidates.append((len(cab_c) * host.M * host.M, 0,
                           cab_c, tuple(range(host.M))))
    if pos_p:
        candidates.append((host.K * len(pos_p) * len(pos_p), 1,
                           tuple(range(host.K)), pos_p))
    if not candidates:
        raise RuntimeError("no embeddable subnetwork survives")
    _, _, c_set, p_set = max(candidates, key=lambda t: (t[0], -t[1]))
    return len(c_set), len(p_set), c_set, p_set
