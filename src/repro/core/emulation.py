"""Property 2 — sub-network emulation: D3(J, L) ⊂ D3(K, M).

The routers of D3(K,M) with c in a J-subset C ⊆ Z_K and BOTH d and p in an
L-subset P ⊆ Z_M form a closed subnetwork isomorphic (dilation-1) to
D3(J, L), provided C and P are subgroups-like index sets closed under the
difference arithmetic the ports use. We use the canonical choice
C = {0..J-1} with port arithmetic relabeled through the subset index —
i.e. the embedded network's port g means "go to the g-th element of C",
realized on D3(K,M) by the port (C[(idx(c)+g) % J] - c) mod K, which is a
legal global port. Same for local ports within P.

This is the framework's *elastic scaling* mechanism: when chips die, the
runtime selects the largest (J, L) with J ≤ K, L ≤ M such that a healthy
C × P × P router set exists and REWRITES the already-lowered D3(J, L)
programs onto the survivors through ``Embedding.device_map`` (the
program-to-program pass in ``runtime.rewrite``) — recovery never re-derives
schedules. See train/fault_tolerance.py.

It is also the *multi-tenancy* mechanism: because a C × P × P image is
closed under every port the guest uses, two embeddings with disjoint
images occupy disjoint routers AND disjoint links, so their rewritten
programs can interleave on one host with zero conflicts
(``runtime.combine``). ``disjoint_embeddings`` packs a list of guest
shapes into such pairwise-disjoint images.

Contract owed to the paper: Property 2 (§1/§6) — D3(K,M) emulates every
D3(J,L) with J ≤ K, L ≤ M at dilation 1, so round counts and
conflict-freedom of all four algorithms transfer verbatim from guest to
host; ``Embedding.verify`` asserts the dilation-1 property link by link.
"""

from __future__ import annotations

import dataclasses
from functools import cached_property

import numpy as np

from repro.core.topology import D3, Router


@dataclasses.dataclass(frozen=True)
class Embedding:
    """Maps D3(J, L) routers onto a C × P × P subset of D3(K, M)."""

    host: D3
    guest: D3
    c_set: tuple[int, ...]
    p_set: tuple[int, ...]

    def __post_init__(self) -> None:
        if len(self.c_set) != self.guest.K or len(self.p_set) != self.guest.M:
            raise ValueError("subset sizes must match guest dimensions")
        if len(set(self.c_set)) != len(self.c_set) or len(set(self.p_set)) != len(self.p_set):
            raise ValueError("subsets must be duplicate-free")
        if not all(0 <= c < self.host.K for c in self.c_set):
            raise ValueError(f"c_set {self.c_set} out of range for K={self.host.K}")
        if not all(0 <= p < self.host.M for p in self.p_set):
            raise ValueError(f"p_set {self.p_set} out of range for M={self.host.M}")

    def map_router(self, r: Router) -> Router:
        c, d, p = r
        return (self.c_set[c], self.p_set[d], self.p_set[p])

    # ------------------------------------------------- vectorized device maps
    @cached_property
    def device_map(self) -> np.ndarray:
        """``device_map[g]`` = host router id of guest router id ``g`` —
        the whole embedding as one int32 gather, built once and cached
        (hash/eq of the frozen dataclass ignore the cache, so embeddings
        stay valid dict/lru keys)."""
        c = np.asarray(self.c_set, np.int32)[:, None, None]
        d = np.asarray(self.p_set, np.int32)[None, :, None]
        p = np.asarray(self.p_set, np.int32)[None, None, :]
        ids = (c * self.host.M + d) * self.host.M + p
        ids = ids.reshape(-1)  # guest router-id order: c-major, then d, then p
        ids.setflags(write=False)
        return ids

    @cached_property
    def host_to_guest(self) -> np.ndarray:
        """Inverse map: host router id -> guest router id, or -1 for host
        devices outside the embedded subnetwork (the idle devices)."""
        inv = np.full(self.host.num_routers, -1, np.int32)
        inv[self.device_map] = np.arange(self.guest.num_routers, dtype=np.int32)
        inv.setflags(write=False)
        return inv

    def map_local_port(self, r: Router, delta: int) -> int:
        """Guest local port delta at guest router r -> host local port."""
        c, d, p = r
        src = self.p_set[p]
        dst = self.p_set[(p + delta) % self.guest.M]
        return (dst - src) % self.host.M

    def map_global_port(self, r: Router, gamma: int) -> int:
        c, d, p = r
        src = self.c_set[c]
        dst = self.c_set[(c + gamma) % self.guest.K]
        return (dst - src) % self.host.K

    def verify(self) -> None:
        """Every guest link maps to a host link (dilation 1) and the global
        hop's d/p swap is preserved."""
        g, h = self.guest, self.host
        for r in g.routers():
            hr = self.map_router(r)
            for delta in range(1, g.M):
                dst = g.local_hop(r, delta)
                hdst = self.map_router(dst)
                if not h.is_local_link(hr, hdst):
                    raise AssertionError(f"local {r}->{dst} maps to non-link {hr}->{hdst}")
            for gamma in range(g.K):
                dst = g.global_hop(r, gamma)
                if dst == r:
                    continue
                hdst = self.map_router(dst)
                if not h.is_global_link(hr, hdst):
                    raise AssertionError(f"global {r}->{dst} maps to non-link {hr}->{hdst}")


def embed(host: D3, J: int, L: int, c_set=None, p_set=None) -> Embedding:
    if J > host.K or L > host.M:
        raise ValueError("guest must not exceed host")
    c_set = tuple(c_set) if c_set is not None else tuple(range(J))
    p_set = tuple(p_set) if p_set is not None else tuple(range(L))
    emb = Embedding(host, D3(J, L), c_set, p_set)
    emb.verify()
    return emb


def disjoint_embeddings(host: D3, guest_shapes) -> tuple[Embedding, ...]:
    """Pack guest shapes [(J, L), ...] into pairwise-DISJOINT Property-2
    embeddings of ``host`` — the enumerator behind concurrent guests
    (``runtime.combine``).

    Disjointness needs only ONE axis to be partitioned, because an image
    is the product set C × P × P: guests on disjoint cabinet sets never
    share a router (whatever their position sets), and likewise for
    disjoint position sets. We try the cabinet regime first (Σ J ≤ K —
    each guest keeps all M positions available, mirroring
    ``largest_embeddable``'s tie-break toward whole drawers), then the
    position regime (Σ L ≤ M), and raise when neither fits. Every
    returned embedding is dilation-1-verified.
    """
    shapes = [(int(J), int(L)) for J, L in guest_shapes]
    if not shapes:
        raise ValueError("disjoint_embeddings() needs at least one guest shape")
    for J, L in shapes:
        if J > host.K or L > host.M:
            raise ValueError(
                f"guest D3({J},{L}) does not fit host D3({host.K},{host.M})"
            )
    if sum(J for J, _ in shapes) <= host.K:
        out, c0 = [], 0
        for J, L in shapes:
            out.append(embed(host, J, L, c_set=range(c0, c0 + J)))
            c0 += J
        return tuple(out)
    if sum(L for _, L in shapes) <= host.M:
        out, p0 = [], 0
        for J, L in shapes:
            out.append(embed(host, J, L, p_set=range(p0, p0 + L)))
            p0 += L
        return tuple(out)
    raise ValueError(
        f"guest shapes {shapes} do not pack disjointly into "
        f"D3({host.K},{host.M}): need Σ J ≤ {host.K} or Σ L ≤ {host.M}"
    )


#: above this many poisoned position indices the mixed search switches
#: from exact subset enumeration (2^|bad_p| candidates) to a greedy
#: peel — far beyond any failure pattern the drills inject.
_MIXED_EXACT_LIMIT = 16


def _mixed_candidates(host: D3, dead: set[Router], bad_p: set[int]):
    """The mixed cabinet×position regime: for every kept-position set P,
    the best cabinet set is forced — C must exclude exactly the cabinets
    that still hold a dead router with BOTH indices inside P (a dead
    (c, d, p) is excluded from C × P × P as soon as d or p leaves P).
    Only positions that appear in ``dead`` are worth dropping, so the
    search enumerates subsets of ``bad_p`` (smallest drops first, so
    equal-sized survivors resolve deterministically toward keeping more
    positions); past ``_MIXED_EXACT_LIMIT`` poisoned indices it degrades
    to a greedy peel of the most-poisoning position."""
    import itertools

    ordered = sorted(bad_p)

    def candidate(drop: tuple[int, ...]):
        p_set = tuple(p for p in range(host.M) if p not in drop)
        if not p_set:
            return None
        kept = set(p_set)
        poisoned = {c for c, d, p in dead if d in kept and p in kept}
        c_set = tuple(c for c in range(host.K) if c not in poisoned)
        if not c_set:
            return None
        return len(c_set) * len(p_set) * len(p_set), c_set, p_set

    if len(ordered) <= _MIXED_EXACT_LIMIT:
        for k in range(1, len(ordered)):  # proper mixed drops only: the
            # empty drop is the pure cabinet regime, the full drop the
            # pure position regime — both already priced by the caller
            for drop in itertools.combinations(ordered, k):
                cand = candidate(drop)
                if cand is not None:
                    yield cand
        return
    # greedy peel: repeatedly drop the position poisoning the most cabinets
    drop: list[int] = []
    remaining = set(ordered)
    while remaining:
        kept = {p for p in range(host.M) if p not in drop}

        def poisoners(q):
            k = kept - {q}
            return len({c for c, d, p in dead if d in k and p in k})

        worst = min(remaining, key=lambda q: (poisoners(q), q))
        drop.append(worst)
        remaining.discard(worst)
        if len(drop) < len(ordered):  # proper mixed drops only (see above)
            cand = candidate(tuple(drop))
            if cand is not None:
                yield cand


def largest_embeddable(host: D3, dead: set[Router]) -> tuple[int, int, tuple, tuple]:
    """Survivor-set search over the drop regimes of Property 2; returns
    (J, L, c_set, p_set) with n = J·L² maximal among them.

    A dead router (c, d, p) is excluded from the C × P × P image iff its
    cabinet leaves C or one of its (d, p) indices leaves P, so two pure
    regimes always work:

      * *cabinet-drop*: remove every cabinet containing a dead router —
        survivors D3(K − |bad_c|, M), best for failures clustered in few
        cabinets;
      * *position-drop*: remove every position index a dead router poisons
        (both its d and its p) — survivors D3(K, M − |bad_p|), best for
        failures striped across many cabinets at few (d, p) indices.

    Failures striped across SOME cabinets at SOME positions are a
    set-cover problem the *mixed* regime solves: drop a subset of the
    poisoned positions AND the cabinets the surviving position set still
    can't clear (``_mixed_candidates`` — exact for realistic failure
    counts, greedy beyond ``_MIXED_EXACT_LIMIT`` poisoned indices). All
    candidates are priced together; ties go cabinet-drop > position-drop
    > mixed, so the mixed survivor is returned exactly when it strictly
    dominates both pure regimes (keeping drawers whole otherwise).
    """
    bad_c = {r[0] for r in dead}
    bad_p = {r[1] for r in dead} | {r[2] for r in dead}
    cab_c = tuple(c for c in range(host.K) if c not in bad_c)
    pos_p = tuple(p for p in range(host.M) if p not in bad_p)
    candidates: list[tuple[int, int, tuple, tuple]] = []
    if cab_c:
        candidates.append((len(cab_c) * host.M * host.M, 0,
                           cab_c, tuple(range(host.M))))
    if pos_p:
        candidates.append((host.K * len(pos_p) * len(pos_p), 1,
                           tuple(range(host.K)), pos_p))
    if bad_c and bad_p:  # a mixed drop can only win when both axes hurt
        best_mixed = None
        for size, c_set, p_set in _mixed_candidates(host, dead, bad_p):
            if best_mixed is None or size > best_mixed[0]:
                best_mixed = (size, 2, c_set, p_set)
        if best_mixed is not None:
            candidates.append(best_mixed)
    if not candidates:
        raise RuntimeError("no embeddable subnetwork survives")
    _, _, c_set, p_set = max(candidates, key=lambda t: (t[0], -t[1]))
    return len(c_set), len(p_set), c_set, p_set
