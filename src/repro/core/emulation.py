"""Property 2 — sub-network emulation: D3(J, L) ⊂ D3(K, M).

The routers of D3(K,M) with c in a J-subset C ⊆ Z_K and BOTH d and p in an
L-subset P ⊆ Z_M form a closed subnetwork isomorphic (dilation-1) to
D3(J, L), provided C and P are subgroups-like index sets closed under the
difference arithmetic the ports use. We use the canonical choice
C = {0..J-1} with port arithmetic relabeled through the subset index —
i.e. the embedded network's port g means "go to the g-th element of C",
realized on D3(K,M) by the port (C[(idx(c)+g) % J] - c) mod K, which is a
legal global port. Same for local ports within P.

This is the framework's *elastic scaling* mechanism: when chips die, the
runtime selects the largest (J, L) with J ≤ K, L ≤ M such that a healthy
C × P × P router set exists, re-derives every schedule on D3(J, L), and
re-shards. See train/fault_tolerance.py.
"""

from __future__ import annotations

import dataclasses

from repro.core.topology import D3, Router


@dataclasses.dataclass(frozen=True)
class Embedding:
    """Maps D3(J, L) routers onto a C × P × P subset of D3(K, M)."""

    host: D3
    guest: D3
    c_set: tuple[int, ...]
    p_set: tuple[int, ...]

    def __post_init__(self) -> None:
        if len(self.c_set) != self.guest.K or len(self.p_set) != self.guest.M:
            raise ValueError("subset sizes must match guest dimensions")
        if len(set(self.c_set)) != len(self.c_set) or len(set(self.p_set)) != len(self.p_set):
            raise ValueError("subsets must be duplicate-free")

    def map_router(self, r: Router) -> Router:
        c, d, p = r
        return (self.c_set[c], self.p_set[d], self.p_set[p])

    def map_local_port(self, r: Router, delta: int) -> int:
        """Guest local port delta at guest router r -> host local port."""
        c, d, p = r
        src = self.p_set[p]
        dst = self.p_set[(p + delta) % self.guest.M]
        return (dst - src) % self.host.M

    def map_global_port(self, r: Router, gamma: int) -> int:
        c, d, p = r
        src = self.c_set[c]
        dst = self.c_set[(c + gamma) % self.guest.K]
        return (dst - src) % self.host.K

    def verify(self) -> None:
        """Every guest link maps to a host link (dilation 1) and the global
        hop's d/p swap is preserved."""
        g, h = self.guest, self.host
        for r in g.routers():
            hr = self.map_router(r)
            for delta in range(1, g.M):
                dst = g.local_hop(r, delta)
                hdst = self.map_router(dst)
                if not h.is_local_link(hr, hdst):
                    raise AssertionError(f"local {r}->{dst} maps to non-link {hr}->{hdst}")
            for gamma in range(g.K):
                dst = g.global_hop(r, gamma)
                if dst == r:
                    continue
                hdst = self.map_router(dst)
                if not h.is_global_link(hr, hdst):
                    raise AssertionError(f"global {r}->{dst} maps to non-link {hr}->{hdst}")


def embed(host: D3, J: int, L: int, c_set=None, p_set=None) -> Embedding:
    if J > host.K or L > host.M:
        raise ValueError("guest must not exceed host")
    c_set = tuple(c_set) if c_set is not None else tuple(range(J))
    p_set = tuple(p_set) if p_set is not None else tuple(range(L))
    emb = Embedding(host, D3(J, L), c_set, p_set)
    emb.verify()
    return emb


def largest_embeddable(host: D3, dead: set[Router]) -> tuple[int, int, tuple, tuple]:
    """Greedy survivor-set search: drop any cabinet c that contains a dead
    router, and any position index appearing in a dead router of surviving
    cabinets; returns (J, L, c_set, p_set). Conservative but fast — used
    by elastic failover (a failed chip poisons its (c) and (d,p) indices)."""
    bad_c = {r[0] for r in dead}
    c_set = tuple(c for c in range(host.K) if c not in bad_c)
    bad_p = {r[1] for r in dead if r[0] in c_set} | {r[2] for r in dead if r[0] in c_set}
    p_set = tuple(p for p in range(host.M) if p not in bad_p)
    if not c_set or not p_set:
        raise RuntimeError("no embeddable subnetwork survives")
    return len(c_set), len(p_set), c_set, p_set
