"""Matrix product on D3(K², M) — paper §2, Theorems 1 and 2.

Storage (paper §2): D3(K²,M) is viewed as a K×K array of M×M blocks with
index set (s, t, u, v), 0 ≤ s,t < K, 0 ≤ u,v < M, assigned to router
(c, d, p) = (s + t·K, u, v). For a KM×KM matrix, (s, u) is the ROW index
pair and (t, v) the COLUMN index pair:

    A[row=(s,u), col=(t,v)]  lives at router  (s + t·K, u, v).

A row vector V "at (s,u)" stores element (t, v) at (s + t·K, u, v).

Vector-matrix multiply, one round of four hops + two off-and-ons:

 Phase 1 (juxtaposition, paper path 2.1/2.2 — g then l):
    V_{t,v} at (s+tK, u, v)  --g-->  (t+t'K, v, u) ∀t'  --l-->  (t+t'K, v, v') ∀v'
 so V_{t,v} meets row (t,v) of A at every (t', v'); products
 P_{(t,v),(t',v')} = V_{t,v}·A[(t,v),(t',v')] form on (t+t'K, v, v').

 Phase 2 (accumulation). ERRATUM (documented in DESIGN.md/EXPERIMENTS.md):
 the paper's path 2.3 literally reverses 2.2, which converges the KM
 products sharing the SAME factor V_{t,v} (a row-sum), not the products
 contributing to one output element. We implement the mirror reduction
 that preserves the claimed structure (2 hops, 2 accumulations, zero
 conflicts): for output element (t', v'), contributors (t+t'K, v, v')
 over all (t, v) converge

    (t+t'K, v, v')  --g(γ = S - t)-->  (S+t'K, v', v)   [K values sum over t]
                    --l(v -> u)    -->  (S+t'K, v', u)   [M sums sum over v]

 landing output element (t',v') on router (S+t'K, v', u) — the Z-swap
 (d ↔ p) of the row-vector layout "at (S, u)". S = s gives the in-place
 variant (up to the Z-swap, fixable with one global-0 hop, or consumed
 directly by the next round's mirrored phase-1); S ≠ s gives the
 out-of-place variant the paper mentions ("modifying s and u").

A KM×KM matrix product is KM such rounds (one per row (s,u) of the left
matrix), each 4 network hops — Theorem 1. For n×n with X = n/KM, every
router holds X×X blocks and each round moves X-vectors; n²/KM rounds —
Theorem 2 (the X×X block product is the off-network compute, realized in
the JAX layer by the Pallas block_matmul kernel).

Contract owed to the paper — §2, Theorems 1 and 2. Round count:
``schedule(g)`` emits KM rounds (one per row (s, u) of the left matrix),
each 4 network hops + 2 off-and-ons; ``rounds_for(g, n)`` = n²/KM for
X-blocked operands. Conflict-freedom invariant: every round's
juxtaposition and mirrored-accumulation hops occupy pairwise-distinct
directed links of D3(K², M) — ``core.simulator.verify`` must report zero
conflicts (asserted in tests/test_core_matmul.py and, per Property 2,
preserved verbatim under every ``runtime.rewrite`` / ``runtime.combine``
relabeling).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.topology import D3, Router
from repro.core.simulator import Simulator, Conflict
from repro.core.schedule import Schedule, Round, hop_round


@dataclasses.dataclass(frozen=True)
class MatmulGrid:
    """D3(K², M) viewed as a K×K array of M×M blocks."""

    K: int
    M: int

    @property
    def topo(self) -> D3:
        return D3(self.K * self.K, self.M)

    @property
    def n(self) -> int:  # matrix side
        return self.K * self.M

    def router(self, s: int, t: int, u: int, v: int) -> Router:
        return ((s + t * self.K) % (self.K * self.K), u % self.M, v % self.M)

    def element_home(self, row: tuple[int, int], col: tuple[int, int]) -> Router:
        (s, u), (t, v) = row, col
        return self.router(s, t, u, v)

    def rc(self, i: int) -> tuple[int, int]:
        """Matrix index i in 0..KM-1 -> (block, offset) = (t, v)."""
        return divmod(i, self.M)


def vector_matmul_phases(
    g: MatmulGrid, s: int, u: int, S: int | None = None
) -> list[list[tuple[Router, Router]]]:
    """Directed hops of the 4 phases of one round (row (s,u), output root S).

    Returns [phase0, phase1, phase2, phase3] where each phase is a list of
    (src, dst) directed hops executed simultaneously.
    """
    if S is None:
        S = s
    K, M = g.K, g.M
    topo = g.topo
    ph0, ph1, ph2, ph3 = [], [], [], []
    for t in range(K):
        for v in range(M):
            src = g.router(s, t, u, v)
            for t2 in range(K):
                c1 = g.router(t, t2, v, u)
                if c1 != src:
                    ph0.append((src, c1))
                for v2 in range(M):
                    leaf = g.router(t, t2, v, v2)
                    if leaf != c1:
                        ph1.append((c1, leaf))
    # phase 2/3: mirror reduction. Contributor (t+t'K, v, v') -> (S+t'K, v', v)
    for t2 in range(K):
        for v2 in range(M):
            for t in range(K):
                for v in range(M):
                    leaf = g.router(t, t2, v, v2)
                    mid = g.router(S, t2, v2, v)
                    if mid != leaf:
                        ph2.append((leaf, mid))
            for v in range(M):
                mid = g.router(S, t2, v2, v)
                root = g.router(S, t2, v2, u)
                if root != mid:
                    ph3.append((mid, root))
    # sanity: every hop is a physical link of the right kind
    for a, b in ph0 + ph2:
        assert topo.is_global_link(a, b), (a, b)
    for a, b in ph1 + ph3:
        assert topo.is_local_link(a, b), (a, b)
    return [ph0, ph1, ph2, ph3]


def round_matchings(
    g: MatmulGrid, s: int, u: int, S: int | None = None
) -> dict[str, object]:
    """The executable partition of one round's 4 phases, in router-ID space —
    the accumulation-combine metadata the runtime lowering consumes.

    The paper's conflict model lets a router drive all its ports at once, so
    a phase is *several* simultaneous matchings on a ppermute backend:

      * ``bcast``  — phase 1/2 (juxtaposition): K global matchings (one per
        destination cabinet offset t') then M-1 local matchings (one per
        destination position v'). Receivers REPLACE their value; identity
        hops are elided (the value is already in place).
      * ``reduce`` — phase 3/4 (mirrored accumulation): K global matchings
        (one per contributor block-row t) then M local matchings (one per
        contributor position v). Receivers COMBINE (sum) arrivals into an
        accumulator; identity pairs are KEPT — they are the local
        contribution of a router to its own sum (an off-and-on, no link).
      * ``zfix``   — one global-0 matching undoing the Z-swap (d ↔ p) of the
        landing layout, the single extra hop the paper notes makes the
        in-place variant truly in place.
      * ``store_mask`` — router ids holding row (s,u) of the output after
        the zfix (the same routers that launched the row of B).

    Each entry is (step, pairs) with step the IR hop step (0..3; zfix = 4).
    """
    if S is None:
        S = s
    K, M = g.K, g.M
    rid = g.topo.router_id
    bcast: list[tuple[int, tuple]] = []
    for t2 in range(K):  # phase 0: global juxtaposition, one matching per t'
        pairs = []
        for t in range(K):
            for v in range(M):
                a, b = g.router(s, t, u, v), g.router(t, t2, v, u)
                if a != b:
                    pairs.append((rid(a), rid(b)))
        bcast.append((0, tuple(pairs)))
    for v2 in range(M):  # phase 1: local fan-out, one matching per v'
        if v2 == u:
            continue  # all-identity matching: the value is already there
        pairs = []
        for t in range(K):
            for t2 in range(K):
                for v in range(M):
                    a, b = g.router(t, t2, v, u), g.router(t, t2, v, v2)
                    pairs.append((rid(a), rid(b)))
        bcast.append((1, tuple(pairs)))
    reduce_: list[tuple[int, tuple]] = []
    for t in range(K):  # phase 2: global converge, one matching per t
        pairs = []
        for t2 in range(K):
            for v in range(M):
                for v2 in range(M):
                    a, b = g.router(t, t2, v, v2), g.router(S, t2, v2, v)
                    pairs.append((rid(a), rid(b)))  # identity = local add
        reduce_.append((2, tuple(pairs)))
    for v in range(M):  # phase 3: local converge, one matching per v
        pairs = []
        for t2 in range(K):
            for v2 in range(M):
                a, b = g.router(S, t2, v2, v), g.router(S, t2, v2, u)
                pairs.append((rid(a), rid(b)))
        reduce_.append((3, tuple(pairs)))
    zfix = []
    for t2 in range(K):  # global-0 hop: (S+t'K, v', u) -> (S+t'K, u, v')
        for v2 in range(M):
            a, b = g.router(S, t2, v2, u), g.router(S, t2, u, v2)
            if a != b:
                zfix.append((rid(a), rid(b)))
    store = tuple(
        sorted(rid(g.router(S, t, u, v)) for t in range(K) for v in range(M))
    )
    return {
        "bcast": tuple(bcast),
        "reduce": tuple(reduce_),
        "zfix": (4, tuple(zfix)),
        "store_mask": store,
    }


def round_ir(g: MatmulGrid, s: int, u: int, S: int | None = None) -> Round:
    """One vector-matmul round as an IR ``Round``: the 4 phases become steps
    0..3, payload = hop index within its phase (each phase's hops are
    pairwise link-distinct packets). ``startups=2`` records the two
    off-and-ons the paper charges per round (4 t_w + 2 t_s).
    ``meta["matmul"]`` carries the accumulation-combine partition
    (``round_matchings``) the runtime lowers to Match/ReduceCombine stages;
    the hop list itself stays the paper's 4-step round for verify/price."""
    hops = []
    for phase, phase_hops in enumerate(vector_matmul_phases(g, s, u, S)):
        for pkt, (a, b) in enumerate(phase_hops):
            hops.append((phase, a, b, pkt))
    return hop_round(hops, meta={"row": (s, u), "S": S if S is not None else s,
                                 "startups": 2, "grid": (g.K, g.M),
                                 "matmul": round_matchings(g, s, u, S)})


def schedule(g: MatmulGrid) -> Schedule:
    """Theorem 1: a KM×KM matrix product is KM rounds (one per row (s,u) of
    the left matrix), each 4 network hops — √n rounds on n = (KM)² routers
    is the paper's headline count for the square grid."""
    rounds = [round_ir(g, s, u) for s in range(g.K) for u in range(g.M)]
    return Schedule("matmul_d3", g.topo, rounds, meta={"grid": g, "n": g.n})


def check_round_conflicts(g: MatmulGrid, s: int, u: int) -> list[Conflict]:
    sim = Simulator(g.topo)
    for phase, hops in enumerate(vector_matmul_phases(g, s, u)):
        for pkt, (a, b) in enumerate(hops):
            sim.add_hop(phase, a, b, pkt)
    return sim.conflicts()


def simulate_vector_matmul(
    g: MatmulGrid, V: np.ndarray, A: np.ndarray, s: int, u: int, S: int | None = None
) -> np.ndarray:
    """Execute one round's data movement literally; return V @ A.

    V: (KM,) row vector (logically stored at row home (s,u));
    A: (KM, KM). Output row vector of length KM (gathered from the
    Z-swapped layout for verification).
    """
    if S is None:
        S = s
    K, M, n = g.K, g.M, g.n
    # phase 1: broadcast — value landing on each leaf router
    leaf_val: dict[Router, float] = {}
    for t in range(K):
        for v in range(M):
            val = V[t * M + v]
            for t2 in range(K):
                for v2 in range(M):
                    leaf_val[g.router(t, t2, v, v2)] = val
    # off-and-on #1: multiply by resident A element
    prod: dict[Router, float] = {}
    for t in range(K):
        for v in range(M):
            for t2 in range(K):
                for v2 in range(M):
                    r = g.router(t, t2, v, v2)
                    prod[r] = leaf_val[r] * A[t * M + v, t2 * M + v2]
    # phase 2: global converge, sum over t (off-and-on #2a)
    mid_sum: dict[Router, float] = {}
    for t2 in range(K):
        for v2 in range(M):
            for v in range(M):
                mid = g.router(S, t2, v2, v)
                mid_sum[mid] = sum(
                    prod[g.router(t, t2, v, v2)] for t in range(K)
                )
    # phase 3: local converge, sum over v (off-and-on #2b)
    out = np.zeros(n, dtype=np.result_type(V, A))
    for t2 in range(K):
        for v2 in range(M):
            root = g.router(S, t2, v2, u)
            out[t2 * M + v2] = sum(mid_sum[g.router(S, t2, v2, v)] for v in range(M))
            del root  # root identity checked in tests via layout map
    return out


def simulate_matmul(g: MatmulGrid, B: np.ndarray, A: np.ndarray) -> np.ndarray:
    """KM rounds (one per row (s,u) of B) -> B @ A. Theorem 1."""
    n = g.n
    out = np.zeros((n, n), dtype=np.result_type(B, A))
    for s in range(g.K):
        for u in range(g.M):
            out[s * g.M + u] = simulate_vector_matmul(g, B[s * g.M + u], A, s, u)
    return out


def rounds_for(g: MatmulGrid, n: int) -> int:
    """Theorem 2 round count for an n×n product, n a multiple of KM."""
    if n % g.n:
        raise ValueError("n must be a multiple of K*M")
    return n * n // g.n


def network_time(g: MatmulGrid, n: int, t_w: float = 1.0, t_s: float = 0.0) -> float:
    """Per paper: each round is 4 t_w + 2 t_s."""
    return rounds_for(g, n) * (4 * t_w + 2 * t_s)


# ---------------------------------------------------------------------------
# Block layout: matrix <-> per-router blocks (the storage map of §2).
# ---------------------------------------------------------------------------

def block_of_router(g: MatmulGrid, r: Router) -> tuple[int, int]:
    """Router (c, d, p) -> its (block-row, block-col) = (sM+u, tM+v) with
    s = c mod K, t = c div K, u = d, v = p."""
    c, d, p = r
    return (c % g.K) * g.M + d, (c // g.K) * g.M + p


def scatter_blocks(g: MatmulGrid, mat: np.ndarray) -> np.ndarray:
    """(N·X, N·X) matrix -> (n_routers, X, X) blocks in router-id order."""
    N = g.n
    if mat.shape[0] % N or mat.shape[1] % N or mat.shape[0] != mat.shape[1]:
        raise ValueError(f"matrix side must be a multiple of N={N}: {mat.shape}")
    X = mat.shape[0] // N
    out = np.empty((g.topo.num_routers, X, X), mat.dtype)
    for r in g.topo.routers():
        i, j = block_of_router(g, r)
        out[g.topo.router_id(r)] = mat[i * X:(i + 1) * X, j * X:(j + 1) * X]
    return out


def gather_blocks(g: MatmulGrid, blocks: np.ndarray) -> np.ndarray:
    """(n_routers, X, X) blocks in router-id order -> (N·X, N·X) matrix."""
    X = blocks.shape[1]
    N = g.n
    out = np.empty((N * X, N * X), blocks.dtype)
    for r in g.topo.routers():
        i, j = block_of_router(g, r)
        out[i * X:(i + 1) * X, j * X:(j + 1) * X] = blocks[g.topo.router_id(r)]
    return out
