"""Analytic network-cost models — the paper's comparison tables.

All costs in units of t_w (router latency) unless noted. P = number of
processors/routers. These formulas back benchmarks/ tables 1:1 with §2-§5.

``price(schedule, t_w, t_s)`` prices a concrete ``core.schedule.Schedule``
so analytic tables and replayed schedules are cross-checked from the SAME
object: e.g. ``price(alltoall.schedule(p))`` must equal
``alltoall_schedule3(K, M, s)`` with t_s = 0, and ``price(matmul.schedule(g),
t_w, t_s)`` must equal ``matmul.network_time(g, g.n, t_w, t_s)``.
"""

from __future__ import annotations

import math


def price(schedule, t_w: float = 1.0, t_s: float = 0.0) -> float:
    """Barrier-replay cost of a Schedule: each round pays its step count in
    t_w plus ``meta["startups"]`` (default 1) software startups in t_s."""
    total = 0.0
    for r in schedule.rounds:
        total += r.num_steps * t_w + r.meta.get("startups", 1) * t_s
    return total


def price_pipelined(schedule, t_w: float = 1.0, t_s: float = 1.0) -> float:
    """Pipelined makespan: rounds launch at meta["start_step"] and overlap;
    one startup for the whole pipeline."""
    end = 0
    for r in schedule.rounds:
        end = max(end, r.meta.get("start_step", 0) + r.num_steps)
    return end * t_w + t_s


# ------------------------------- §2 table: n×n matmul network costs -------
def matmul_d3(n: float, P: float) -> float:
    """D3(K²,M): 4 t_w n²/√P (P = K²M² routers, √P = KM)."""
    return 4.0 * n * n / math.sqrt(P)


def matmul_cannon(n: float, P: float) -> float:
    return 2.0 * n * n / math.sqrt(P)


def matmul_hje(n: float, P: float) -> float:
    return 2.0 * n * n / math.sqrt(P) * math.log2(P)


def matmul_dns_sqrt(n: float, P: float) -> float:
    return 2.0 * n * n / math.sqrt(P)


def matmul_gs(n: float, P: float) -> float:
    return 3.0 * n * n / P ** (2.0 / 3.0) * math.log2(P)


def matmul_dns_23(n: float, P: float) -> float:
    return 4.0 * n * n / P ** (2.0 / 3.0)


MATMUL_TABLE = {
    "D3(K^2,M)": matmul_d3,
    "Cannon": matmul_cannon,
    "HJE": matmul_hje,
    "DNS": matmul_dns_sqrt,
    "GS": matmul_gs,
    "DNS-P^2/3": matmul_dns_23,
}


# ------------------------------- §3 all-to-all -----------------------------
def alltoall_doubly_parallel(K: int, M: int, s: int, n: int | None = None) -> float:
    """KM²/s rounds; n ≥ KM² items -> n²/(KM²s)."""
    P = K * M * M
    if n is None:
        n = P
    return n * n / (P * s)


def alltoall_schedule1(K: int, M: int, s: int) -> float:
    return (K * M * M / s + K * M) / s


def alltoall_schedule2(K: int, M: int, s: int) -> float:
    return 2.0 * K * M * M / s


def alltoall_schedule3(K: int, M: int, s: int) -> float:
    return 3.0 * K * M * M / s


def alltoall_johnsson_ho(P: int, n: int | None = None) -> float:
    """Boolean hypercube: t_w·P/2; size n ≥ P -> n²/2P."""
    if n is None:
        n = P
    return n * n / (2.0 * P)


def alltoall_jh_on_sbh(k: int, m: int) -> float:
    """§4: Johnsson-Ho run through the SBH emulation: (2/3)... the paper
    uses avg dilation 2 => 2 · (2^{k+2m}/2) = 2^{k+2m}; it quotes
    (2/3)·(2^{k+2m}/2)·3 — we report dilation·P/2 with avg dilation 2."""
    P = 1 << (k + 2 * m)
    return 2.0 * P / 2.0


def alltoall_dp_on_d3_2k2m(k: int, m: int) -> float:
    """§4: s = min(2^k, 2^{m-1}) -> max(2^m, 2^{k+m+1})."""
    return float(max(1 << m, 1 << (k + m + 1)))


# ------------------------------- §5 broadcast ------------------------------
def broadcast_depth3(X: int) -> float:
    """Pipelined depth-3 tree: X hops for X broadcasts (+2 drain)."""
    return float(X)


def broadcast_m_tree(X: int, M: int) -> float:
    """Pair-chained M depth-4 trees: 3X/M."""
    return 3.0 * X / M


# ------------------------------- hardware-time helpers ---------------------
def seconds(
    hops: float,
    t_w: float = 1.0e-6,
    t_s: float = 0.0,
    *,
    bytes_per_hop: float = 0.0,
    bandwidth: float = 50e9,
) -> float:
    """Wall-clock estimate of ``hops`` network steps.

    The paper prices in t_w units (one router hop per step); real links
    also pay serialization time proportional to the message size, and the
    crossover between strategies moves with it — so the autotuner needs
    prices that SCALE with bytes. Each hop costs ``t_w`` (router latency)
    plus ``bytes_per_hop / bandwidth`` (wire time; default 50 GB/s, the
    TPU v5e ICI link), and the call pays ``t_s`` software startup once:

        seconds = hops · (t_w + bytes_per_hop / bandwidth) + t_s

    ``bytes_per_hop=0`` reproduces the original latency-only form.
    """
    per_hop = t_w + (bytes_per_hop / bandwidth if bytes_per_hop else 0.0)
    return hops * per_hop + t_s
