"""Source-vector routing and the synchronized broadcast header.

A source vector (γ, π, δ) at router (c, d, p) produces the 3-hop path

    (c,d,p) --δ(local)--> (c,d,p+δ) --γ(global)--> (c+γ, p+δ, d)
            --π(local)--> (c+γ, p+δ, d+π)

i.e. an l-g-l path. Degenerate ports (δ=0 local, π=0 local, γ=0 with d==p
after the swap would be a self-loop) consume no link.

The destination of (γ,π,δ) from (c,d,p) is (c+γ, p+δ, d+π): the unique
vector delivering from src=(c,d,p) to dst=(c',d',p') is

    γ = c' - c,   δ = d' - p,   π = p' - d      (mod K / M / M)

Synchronized header [b; γ, π, δ] (paper §5): a router program independent
of position in the spanning tree:

  * b odd  : use local port δ;  b -= 1;  δ <- π;  π <- 0
  * b even : use global port γ; b -= 1;  γ <- 0
  * b == 0 : arrived.

With broadcast semantics a '*' port means "all ports" (local broadcast over
the drawer / global broadcast over all K offsets).
"""

from __future__ import annotations

import dataclasses

from repro.core.topology import D3, Router

Vector = tuple[int, int, int]  # (gamma, pi, delta)

# Sentinel for "broadcast over all ports" in a synchronized header.
STAR = "*"


def vector_for(topo: D3, src: Router, dst: Router) -> Vector:
    """The unique source vector routing src -> dst (paper §1)."""
    c, d, p = src
    c2, d2, p2 = dst
    gamma = (c2 - c) % topo.K
    delta = (d2 - p) % topo.M
    pi = (p2 - d) % topo.M
    return (gamma, pi, delta)


def vector_dest(topo: D3, src: Router, vec: Vector) -> Router:
    gamma, pi, delta = vec
    c, d, p = src
    return ((c + gamma) % topo.K, (p + delta) % topo.M, (d + pi) % topo.M)


def vector_path(topo: D3, src: Router, vec: Vector) -> list[Router]:
    """Routers visited by the l-g-l path, including src. Degenerate hops
    (those that would stay on the same router) are elided — they use no
    link, matching the paper's hop accounting."""
    gamma, pi, delta = vec
    path = [src]
    r = topo.local_hop(src, delta)
    if r != path[-1]:
        path.append(r)
    r2 = topo.global_hop(path[-1], gamma)
    if r2 != path[-1]:
        path.append(r2)
    r3 = topo.local_hop(path[-1], pi)
    if r3 != path[-1]:
        path.append(r3)
    return path


def path_links(path: list[Router]) -> list[tuple[Router, Router]]:
    return [(path[i], path[i + 1]) for i in range(len(path) - 1)]


# --------------------------------------------------------------------------
# Synchronized header automaton (§5) — the "Broadcast Swapped Dragonfly".
# --------------------------------------------------------------------------

Port = int | str  # an int offset, or STAR


@dataclasses.dataclass(frozen=True)
class SyncHeader:
    """Header [b; γ, π, δ]. Interpreted identically by every router."""

    b: int
    gamma: Port
    pi: Port
    delta: Port

    def step(self) -> tuple[str, Port, "SyncHeader"]:
        """One router interpretation step.

        Returns (kind, port, next_header) where kind is 'local'|'global'.
        Raises if b == 0 (already arrived).
        """
        if self.b <= 0:
            raise ValueError("packet already arrived (b == 0)")
        if self.b % 2 == 1:  # odd -> local port delta; delta <- pi; pi <- 0
            return ("local", self.delta, SyncHeader(self.b - 1, self.gamma, 0, self.pi))
        # even -> global port gamma; gamma <- 0
        return ("global", self.gamma, SyncHeader(self.b - 1, 0, self.pi, self.delta))

    @property
    def arrived(self) -> bool:
        return self.b == 0


def header_trace(header: SyncHeader) -> list[tuple[str, Port]]:
    """Full evolution of a (non-broadcast) header to arrival."""
    out = []
    h = header
    while not h.arrived:
        kind, port, h = h.step()
        out.append((kind, port))
    return out


def expand_broadcast(topo: D3, r: Router, kind: str, port: Port) -> list[Router]:
    """Expand one header step at router r into next-hop routers.

    STAR on a local step = all M-1 drawer peers (plus staying is not a hop);
    STAR on a global step = all K global offsets (offset 0 kept unless it is
    a self-loop). An int port is a single hop; a degenerate hop (self-loop)
    yields [] (packet stays, no link used).
    """
    if kind == "local":
        if port == STAR:
            c, d, p = r
            return [(c, d, q) for q in range(topo.M) if q != p]
        nxt = topo.local_hop(r, port)  # type: ignore[arg-type]
        return [nxt] if nxt != r else []
    assert kind == "global"
    if port == STAR:
        out = []
        for g in range(topo.K):
            nxt = topo.global_hop(r, g)
            if nxt != r:
                out.append(nxt)
        return out
    nxt = topo.global_hop(r, port)  # type: ignore[arg-type]
    return [nxt] if nxt != r else []
