"""Core library: the paper's contribution — Swapped Dragonfly topology,
source-vector routing, and the four algorithms with their conflict-free
round schedules, plus the simulator that verifies every claim."""

from repro.core.topology import D3, Router
from repro.core.routing import (
    Vector,
    SyncHeader,
    STAR,
    vector_for,
    vector_dest,
    vector_path,
)
from repro.core.simulator import Simulator, check_vector_round, assert_conflict_free
from repro.core.alltoall import DAParams, rounds, round_vectors, pipeline
from repro.core.matmul import MatmulGrid, simulate_matmul, simulate_vector_matmul
from repro.core.hypercube import SBH
from repro.core.emulation import embed, largest_embeddable

__all__ = [
    "D3",
    "Router",
    "Vector",
    "SyncHeader",
    "STAR",
    "vector_for",
    "vector_dest",
    "vector_path",
    "Simulator",
    "check_vector_round",
    "assert_conflict_free",
    "DAParams",
    "rounds",
    "round_vectors",
    "pipeline",
    "MatmulGrid",
    "simulate_matmul",
    "simulate_vector_matmul",
    "SBH",
    "embed",
    "largest_embeddable",
]
