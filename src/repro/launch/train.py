"""End-to-end training launcher.

CPU-scale (runs for real):
    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b --smoke \\
        --steps 50 --batch 8 --seq 64

Pod-scale lowering is exercised via launch/dryrun.py; this driver owns the
real loop: data pipeline -> jitted train step -> checkpoint/restart ->
straggler accounting. `--restore` resumes from the latest checkpoint
(including the data-iterator state — no sample loss).
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.train.optimizer import OptConfig
from repro.train.train_step import TrainSettings, make_train_step, init_train_state
from repro.train.data import DataState, SyntheticLM
from repro.train import checkpoint as ckpt
from repro.train.fault_tolerance import StragglerPolicy


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--restore", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    opt_cfg = OptConfig(lr=args.lr, warmup_steps=10, total_steps=args.steps)
    settings = TrainSettings(
        microbatches=args.microbatches,
        use_kernel=False,
        remat=True,
        compress_grads=args.compress_grads,
    )
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, settings), donate_argnums=(0, 1))

    data_state = DataState(seed=args.seed, batch=args.batch, seq=args.seq, vocab=cfg.vocab)
    start_step = 0
    if args.restore and ckpt.latest_step(args.ckpt_dir) is not None:
        start_step, tree = ckpt.restore(args.ckpt_dir)
        params = jax.tree.map(jax.numpy.asarray, tree["params"])
        opt_state = jax.tree.map(jax.numpy.asarray, tree["opt"])
        data_state = DataState.from_dict(
            {k: int(v) if not isinstance(v, (int,)) else v for k, v in tree["data"].items()}
        )
        print(f"restored step={start_step}")
    else:
        params, opt_state = init_train_state(jax.random.key(args.seed), cfg, opt_cfg, settings)

    data = SyntheticLM(data_state)
    policy = StragglerPolicy()
    durations: list[float] = []

    for step in range(start_step, args.steps):
        if cfg.embeds_input:
            batch = data.next_embeds_batch(cfg.d_model)
        else:
            batch = data.next_batch()
        batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
        t0 = time.perf_counter()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        durations.append(dt)
        if len(durations) >= 8:
            keep = policy.judge(durations[-8:])
            if not all(keep):
                print(f"step {step}: straggler flags {keep}")
        print(f"step {step:4d} loss {loss:.4f} "
              f"gnorm {float(metrics['grad_norm']):.3f} {dt*1e3:.0f} ms")
        if (step + 1) % args.ckpt_every == 0 or step + 1 == args.steps:
            path = ckpt.save(
                args.ckpt_dir,
                step + 1,
                {
                    "params": jax.tree.map(np.asarray, params),
                    "opt": jax.tree.map(np.asarray, opt_state),
                    "data": data.state.to_dict(),
                },
            )
            print(f"checkpoint -> {path}")
    return float(metrics["loss"])


if __name__ == "__main__":
    main()
