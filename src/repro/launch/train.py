"""End-to-end training launcher.

CPU-scale (runs for real):
    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b --smoke \\
        --steps 50 --batch 8 --seq 64

Pod-scale lowering is exercised via launch/dryrun.py; this driver owns the
real loop: data pipeline -> jitted train step -> checkpoint/restart ->
straggler accounting. `--restore` resumes from the latest checkpoint
(including the data-iterator state — no sample loss).

Elastic mode (`--elastic`) hands the loop to ``train.elastic
.ElasticTrainer``: a seeded/explicit fault injector kills devices mid-run
and every failure is survived in-process — rewrite-only ``plan_recovery``,
§5-broadcast shard redistribution, resume from checkpoint:

    PYTHONPATH=src python -m repro.launch.train --smoke --steps 20 \\
        --elastic --host 2 2 --inject-failures "4:1,9:4"

`--straggler-drop` (with `--microbatches N`) times each microbatch on the
host, drops the ones ``StragglerPolicy`` flags, and renormalizes the
gradient over the kept contributions.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.train.optimizer import OptConfig
from repro.train.train_step import (
    TrainSettings,
    init_train_state,
    make_apply_step,
    make_microbatch_grads,
    make_train_step,
    split_microbatches,
)
from repro.train.data import DataState, SyntheticLM
from repro.train import checkpoint as ckpt
from repro.train.fault_tolerance import StragglerPolicy, renormalized_scale


def _parse_injections(spec: str) -> dict[int, list[int]]:
    """"step:dev,step:dev,..." -> {step: [dev, ...]} (a step may repeat)."""
    plan: dict[int, list[int]] = {}
    for item in filter(None, (s.strip() for s in spec.split(","))):
        step_s, dev_s = item.split(":")
        plan.setdefault(int(step_s), []).append(int(dev_s))
    return plan


def _run_elastic(args, cfg, opt_cfg, settings) -> float:
    from repro.core.topology import D3
    from repro.train.elastic import ElasticTrainer, FaultInjector

    host = D3(args.host[0], args.host[1])
    if args.inject_failures:
        injector = FaultInjector(_parse_injections(args.inject_failures))
    elif args.inject_random:
        injector = FaultInjector.sample(
            host, args.steps, args.inject_random, seed=args.seed)
    else:
        injector = FaultInjector()
    if injector.schedule:
        print(f"fault schedule: {injector.schedule}")
    trainer = ElasticTrainer(
        cfg, opt_cfg, settings,
        ckpt_dir=args.ckpt_dir, host=host, injector=injector,
        batch=args.batch, seq=args.seq, seed=args.seed,
        ckpt_every=args.ckpt_every,
    )
    losses = trainer.run(args.steps)
    for ev in trainer.events:
        kind = "absorbed" if ev.absorbed else "rewound"
        print(f"failover @step {ev.step}: killed {list(ev.failed)} -> "
              f"D3{ev.shape} on {list(ev.survivors)} ({kind}, resumed from "
              f"{ev.resumed_from}, {ev.broadcast_rounds} bcast rounds, "
              f"{ev.wall_s * 1e3:.0f} ms, {ev.derivations} derivations)")
    final = losses[max(losses)]
    print(f"elastic run done: {len(losses)} steps, "
          f"{len(trainer.events)} failovers, final loss {final:.4f}")
    return final


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--restore", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--straggler-drop", action="store_true",
                    help="time each microbatch, drop flagged stragglers and "
                         "renormalize the gradient (needs --microbatches > 1)")
    ap.add_argument("--elastic", action="store_true",
                    help="run under ElasticTrainer: survive injected chip "
                         "failures via rewrite-only failover")
    ap.add_argument("--host", type=int, nargs=2, default=(2, 2),
                    metavar=("K", "M"), help="elastic: host pod D3(K, M)")
    ap.add_argument("--inject-failures", default="",
                    help='elastic: explicit kills "step:dev,step:dev,..."')
    ap.add_argument("--inject-random", type=int, default=0,
                    help="elastic: sample N seeded (step, device) kills")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    opt_cfg = OptConfig(lr=args.lr, warmup_steps=10, total_steps=args.steps)
    settings = TrainSettings(
        microbatches=args.microbatches,
        use_kernel=False,
        remat=True,
        compress_grads=args.compress_grads,
    )
    if args.elastic:
        return _run_elastic(args, cfg, opt_cfg, settings)

    straggler_drop = args.straggler_drop and args.microbatches > 1
    if straggler_drop:
        # split step: per-microbatch grads are timed on the host so a
        # straggler can be dropped BEFORE it enters the accumulation
        # (the fused scan in make_train_step admits no such surgery)
        mb_grads_fn = jax.jit(make_microbatch_grads(cfg, settings))
        apply_fn = jax.jit(
            make_apply_step(cfg, opt_cfg, settings), donate_argnums=(0, 1))
        step_fn = None
    else:
        step_fn = jax.jit(
            make_train_step(cfg, opt_cfg, settings), donate_argnums=(0, 1))

    data_state = DataState(seed=args.seed, batch=args.batch, seq=args.seq, vocab=cfg.vocab)
    start_step = 0
    if args.restore and ckpt.latest_step(args.ckpt_dir) is not None:
        start_step, tree = ckpt.restore(args.ckpt_dir)
        params = jax.tree.map(jax.numpy.asarray, tree["params"])
        opt_state = jax.tree.map(jax.numpy.asarray, tree["opt"])
        data_state = DataState.from_dict(tree["data"])  # typed int coercion
        print(f"restored step={start_step}")
    else:
        params, opt_state = init_train_state(jax.random.key(args.seed), cfg, opt_cfg, settings)

    data = SyntheticLM(data_state)
    policy = StragglerPolicy()
    durations: list[float] = []

    for step in range(start_step, args.steps):
        if cfg.embeds_input:
            batch = data.next_embeds_batch(cfg.d_model)
        else:
            batch = data.next_batch()
        batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
        t0 = time.perf_counter()
        if straggler_drop:
            results, mb_durs = [], []
            for mb in split_microbatches(batch, args.microbatches):
                t_mb = time.perf_counter()
                loss_i, metrics_i, g_i = mb_grads_fn(params, mb)
                jax.block_until_ready(loss_i)
                mb_durs.append(time.perf_counter() - t_mb)
                results.append((loss_i, metrics_i, g_i))
            keep = policy.judge(mb_durs)
            kept = [r for r, k in zip(results, keep) if k]
            if not all(keep):
                print(f"step {step}: dropping microbatches "
                      f"{[i for i, k in enumerate(keep) if not k]} "
                      f"(renorm x{renormalized_scale(len(kept), len(keep)):.2f})")
            # mean over the KEPT microbatches only: Σ_kept g / total,
            # renormalized by total/kept
            scale = renormalized_scale(len(kept), len(keep)) / len(keep)
            g_sum = jax.tree.map(lambda *gs: sum(gs), *(g for _, _, g in kept))
            grads = jax.tree.map(lambda g: g * scale, g_sum)
            loss = sum(l for l, _, _ in kept) * scale
            params, opt_state, metrics = apply_fn(
                params, opt_state, grads, loss, kept[-1][1])
        else:
            params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        durations.append(dt)
        if not straggler_drop and len(durations) >= 8:
            keep = policy.judge(durations[-8:])
            if not all(keep):
                print(f"step {step}: straggler flags {keep}")
        print(f"step {step:4d} loss {loss:.4f} "
              f"gnorm {float(metrics['grad_norm']):.3f} {dt*1e3:.0f} ms")
        if (step + 1) % args.ckpt_every == 0 or step + 1 == args.steps:
            path = ckpt.save(
                args.ckpt_dir,
                step + 1,
                {
                    "params": jax.tree.map(np.asarray, params),
                    "opt": jax.tree.map(np.asarray, opt_state),
                    "data": data.state.to_dict(),
                },
            )
            print(f"checkpoint -> {path}")
    return float(metrics["loss"])


if __name__ == "__main__":
    main()
