"""Production meshes.

    single pod : (16, 16)      axes ("data", "model")       256 chips
    multi-pod  : (2, 16, 16)   axes ("pod", "data", "model") 512 chips

The model axis doubles as the Swapped Dragonfly: ``dragonfly_for_mesh``
views it as D3(K, M) (16 -> D3(4,2), so a pod's model axis runs the §3
all-to-all in K·M²/s ppermute rounds), and ``make_dragonfly_mesh`` builds a
flat 1-D mesh whose device order IS the router order — the executable form
of the core Schedule IR: ``runtime.lowering.lower`` emits one
``CollectiveProgram`` per schedule and ``dragonfly_runtime_backend``
returns the backend that replays it on the mesh.

Functions, not module constants — importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before any jax import)."""

from __future__ import annotations

import jax

from repro.dist.mesh import DeviceLayout, dragonfly_layout
from repro.dist.sharding import ShardRules
from repro.runtime import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_rules(*, multi_pod: bool = False, fsdp: bool = False) -> ShardRules:
    return ShardRules(
        tensor_axis="model",
        data_axis="data",
        pod_axis="pod" if multi_pod else None,
        fsdp=fsdp,
    )


def axis_sizes(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def dragonfly_for_mesh(mesh, axis: str = "model") -> DeviceLayout:
    """The D3 view of one mesh axis — what the dragonfly collectives
    (dist/collectives.py) replay their lowered schedules over."""
    return dragonfly_layout(axis_sizes(mesh)[axis])


def dragonfly_runtime_backend(name: str = "jax_ppermute", *, overlap: bool = False):
    """The runtime backend production launchers replay programs with.
    ``overlap=True`` orders stages by ``start_step`` so pipelined rounds
    interleave on the wire; ``name="reference"`` gives the device-free
    NumPy replay (host-side validation of a pod's schedules)."""
    from repro.runtime.backends import get_backend

    kwargs = {"overlap": overlap} if name in ("jax", "jax_ppermute") else {}
    return get_backend(name, **kwargs)


def make_dragonfly_mesh(n: int | None = None, axis_name: str = "df"):
    """A flat 1-D mesh over n devices in router order, plus its layout.

    Device i of the axis is router ``layout.topo.id_router(i)``; programs
    lowered from the IR (runtime/lowering.py) execute on it verbatim."""
    import numpy as np
    from jax.sharding import Mesh

    devs = jax.devices()
    n = n if n is not None else len(devs)
    if len(devs) < n:
        raise RuntimeError(f"need {n} devices, have {len(devs)}")
    return Mesh(np.array(devs[:n]), (axis_name,)), dragonfly_layout(n)
