"""Production meshes.

    single pod : (16, 16)      axes ("data", "model")       256 chips
    multi-pod  : (2, 16, 16)   axes ("pod", "data", "model") 512 chips

Functions, not module constants — importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before any jax import)."""

from __future__ import annotations

import jax

from repro.dist.sharding import ShardRules


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_rules(*, multi_pod: bool = False, fsdp: bool = False) -> ShardRules:
    return ShardRules(
        tensor_axis="model",
        data_axis="data",
        pod_axis="pod" if multi_pod else None,
        fsdp=fsdp,
    )


def axis_sizes(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
