"""Serving launcher: continuous-batching engine on a CPU-scale config.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \\
        --requests 6 --max-new 12

``--tenants N`` switches to the multi-tenant fleet: N independently-seeded
copies of the arch seated as disjoint D3(1,2) guests on one D3(K,M) host,
every model's MoE dispatch riding ONE combined program per boundary round
(``--time-mux`` serves the same tenants through sequential solo replays
instead, for comparison). Fleet mode needs an MoE arch, e.g.
``--arch mixtral-8x7b``.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import model as M
from repro.serve.engine import Engine, Request


def _random_prompts(rng, cfg, n, max_new):
    return [
        Request(
            rid=i,
            prompt=rng.integers(1, cfg.vocab, size=rng.integers(3, 9)).astype(np.int32),
            max_new_tokens=max_new,
        )
        for i in range(n)
    ]


def _serve_single(cfg, args):
    params = M.init_params(jax.random.key(args.seed), cfg)
    eng = Engine(cfg, params, batch_slots=args.slots, max_seq=args.max_seq)

    rng = np.random.default_rng(args.seed)
    pending = _random_prompts(rng, cfg, args.requests, args.max_new)
    submitted = list(pending)
    done: list[Request] = []
    t0 = time.perf_counter()
    while pending or eng.slot_req:
        while pending and eng.free_slots:
            req = pending.pop(0)
            eng.admit(req)
            print(f"admitted rid={req.rid} prompt_len={len(req.prompt)}")
        eng.step()
        # the engine retires finished requests out of slots itself; collect
        # them once each, in completion order
        done.extend(r for r in submitted if r.done and r not in done)
    dt = time.perf_counter() - t0
    assert len(done) == len(submitted), (
        f"{len(submitted) - len(done)} requests lost by the serve loop")
    print(f"completed {len(done)}/{len(submitted)} requests: "
          f"{[ (r.rid, len(r.out)) for r in done ]}")
    print(f"engine steps: {eng.steps_run}, wall: {dt:.2f}s, "
          f"tokens: {eng.tokens_out}, tokens/s: {eng.tokens_out / max(dt, 1e-9):.1f}")
    return eng.steps_run


def _serve_fleet(cfg, args):
    from repro.serve.fleet import TenantFleet

    if getattr(cfg, "moe", None) is None:
        raise SystemExit(
            f"--tenants needs an MoE arch (got {args.arch}): fleet tenants "
            "share the combined program at their expert-dispatch boundaries")
    fleet = TenantFleet((args.tenants, 2), max_seq=args.max_seq,
                        combined=not args.time_mux)
    rng = np.random.default_rng(args.seed)
    submitted = []
    for i in range(args.tenants):
        params = M.init_params(jax.random.key(args.seed + i), cfg)
        tid = fleet.admit_model(cfg, params, guest=(1, 2), slots=args.slots)
        for req in _random_prompts(rng, cfg, args.requests, args.max_new):
            submitted.append(fleet.submit(tid, req.prompt, req.max_new_tokens))
        print(f"admitted tenant {tid} with {args.requests} requests")
    t0 = time.perf_counter()
    fleet.run_to_completion()
    dt = time.perf_counter() - t0
    done = [r for r in submitted if r.done]
    assert len(done) == len(submitted), (
        f"{len(submitted) - len(done)} requests lost by the fleet loop")
    mode = "time_mux" if args.time_mux else "combined"
    print(f"completed {len(done)}/{len(submitted)} requests across "
          f"{args.tenants} tenants ({mode})")
    print(f"fleet steps: {fleet.steps_run}, replays: {fleet.replays}, "
          f"rounds: {fleet.rounds_replayed}, wall: {dt:.2f}s, "
          f"tokens: {fleet.tokens_out}, "
          f"tokens/s: {fleet.tokens_out / max(dt, 1e-9):.1f}")
    report = fleet.collective_report()
    print(f"combined-site decision: {report.get('key')} -> "
          f"{report.get('strategy')} ({report.get('source')}); "
          f"rounds combined={report.get('combined_rounds')} "
          f"vs time_mux={report.get('time_mux_rounds')}")
    return fleet.steps_run


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="tinyllama-1.1b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--tenants", type=int, default=0,
                    help="serve N copies of the arch as a multi-tenant fleet "
                         "on one D3(N,2) host (0 = single-engine mode)")
    ap.add_argument("--time-mux", action="store_true",
                    help="fleet mode: replay each tenant's solo program "
                         "sequentially instead of the combined program")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch)
    if cfg.embeds_input:
        raise SystemExit("stub-frontend archs serve via decode_step directly")
    if args.tenants:
        return _serve_fleet(cfg, args)
    return _serve_single(cfg, args)


if __name__ == "__main__":
    main()
