"""Serving launcher: continuous-batching engine on a CPU-scale config.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \\
        --requests 6 --max-new 12
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import model as M
from repro.serve.engine import Engine, Request


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="tinyllama-1.1b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch)
    if cfg.embeds_input:
        raise SystemExit("stub-frontend archs serve via decode_step directly")
    params = M.init_params(jax.random.key(args.seed), cfg)
    eng = Engine(cfg, params, batch_slots=args.slots, max_seq=args.max_seq)

    rng = np.random.default_rng(args.seed)
    pending = [
        Request(
            rid=i,
            prompt=rng.integers(1, cfg.vocab, size=rng.integers(3, 9)).astype(np.int32),
            max_new_tokens=args.max_new,
        )
        for i in range(args.requests)
    ]
    done: list[Request] = []
    t0 = time.perf_counter()
    while pending or eng.slot_req:
        while pending and eng.free_slots:
            req = pending.pop(0)
            eng.admit(req)
            print(f"admitted rid={req.rid} prompt_len={len(req.prompt)}")
        eng.step()
        for req in list(eng.slot_req.values()):
            pass
        done.extend([r for r in done if r.done])
        # collect finished (engine removes them from slots)
    dt = time.perf_counter() - t0
    print(f"engine steps: {eng.steps_run}, wall: {dt:.2f}s")
    return eng.steps_run


if __name__ == "__main__":
    main()
