import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape ×
mesh) cell, print memory_analysis() and cost_analysis(), extract the
roofline terms, and persist one JSON per cell.

    PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x7b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all            # every cell
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod

Loop-body FLOP counting: XLA's cost analysis counts a while-loop body
ONCE (verified empirically), so scanned layer stacks undercount. We
therefore compile depth-1 and depth-2 variants of each model and
extrapolate: total = c1 + (c2 - c1)·(n_groups - 1). The FULL-depth
program is still compiled — that compile IS the dry-run pass/fail and
the source of memory_analysis() and the collective schedule.
"""

import argparse
import dataclasses
import json
import pathlib
import re
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (
    ARCH_IDS, SHAPES, get_config, input_specs, cell_supported,
)
from repro.launch.mesh import make_production_mesh, make_rules
from repro.models import model as M
from repro.train import optimizer as O
from repro.train.train_step import TrainSettings, make_train_step, train_shardings

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

# TPU v5e constants (roofline denominators)
PEAK_FLOPS = 197e12       # bf16 per chip
HBM_BW = 819e9            # B/s per chip
LINK_BW = 50e9            # B/s per ICI link

# per-arch microbatch counts for train_4k (activation-memory control)
MICROBATCHES = {
    "llama3-405b": 16,
    "deepseek-v3-671b": 16,
    "jamba-1.5-large-398b": 8,
    "mixtral-8x7b": 4,
    "qwen2-vl-7b": 4,
    "phi3-mini-3.8b": 2,
    "musicgen-large": 2,
    "tinyllama-1.1b": 1,
    "olmo-1b": 1,
    "xlstm-1.3b": 2,
}
FACTORED_OPT = {"llama3-405b", "deepseek-v3-671b", "jamba-1.5-large-398b"}

COLLECTIVE_RE = re.compile(
    r"=\s+(\w+)\[([\d,]*)\][^ ]*\s+"
    r"(all-reduce-start|all-gather-start|reduce-scatter-start|all-to-all-start|"
    r"collective-permute-start|all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)\("
)

_DTYPE_BYTES = {
    "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1, "u8": 1,
    "pred": 1, "f64": 8, "s64": 8, "u64": 8, "s16": 2, "u16": 2,
}


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes per collective kind (each op counted once —
    loop-resident collectives are handled by the depth extrapolation)."""
    out: dict[str, float] = {}
    count: dict[str, int] = {}
    for m in COLLECTIVE_RE.finditer(hlo_text):
        dtype, dims, kind = m.groups()
        kind = kind.replace("-start", "")
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        b = n * _DTYPE_BYTES.get(dtype, 4)
        out[kind] = out.get(kind, 0.0) + b
        count[kind] = count.get(kind, 0) + 1
    out["_counts"] = count
    return out


def _depth_variant(cfg, groups: int):
    period = len(cfg.layer_kinds())
    prefix = min(cfg.first_dense_layers, 1)  # trip-1 prefix: no undercount
    return dataclasses.replace(
        cfg, n_layers=prefix + period * groups, first_dense_layers=prefix
    )


def _named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


# --------------------------------------------------------------- lowerings
def lower_train(cfg, shape, mesh, rules, microbatches, *, cost_mode=False,
                cost_attn="naive"):
    opt_cfg = O.OptConfig(factored=cfg.name in FACTORED_OPT)
    # cost_mode: unrolled groups + no microbatch scan, so cost_analysis
    # counts every layer (XLA counts loop bodies once; total flops are
    # microbatch-invariant). cost_attn picks the attention for the pair:
    #   naive — exact FLOP counting (materialized scores, no inner loops)
    #   flash — boundary-accurate BYTES (no fake S² HBM traffic)
    settings = TrainSettings(
        microbatches=1 if cost_mode else microbatches,
        use_kernel=(cost_attn == "flash") if cost_mode else True,
        remat=True,  # remat is per-group and unrolled in cost_mode: counted
        unroll=cost_mode,
    )
    step = make_train_step(cfg, opt_cfg, settings)
    pspecs, ospecs, bspecs, _ = train_shardings(cfg, rules, opt_cfg, settings)

    params_sds = jax.eval_shape(lambda: M.init_params(jax.random.key(0), cfg))
    opt_sds = jax.eval_shape(lambda: O.init_state(params_sds, opt_cfg))
    batch_sds = input_specs(cfg, shape)

    in_sh = (_named(mesh, pspecs), _named(mesh, ospecs), _named(mesh, bspecs))
    out_sh = (in_sh[0], in_sh[1], None)
    f = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh, donate_argnums=(0, 1))
    return f.lower(params_sds, opt_sds, batch_sds)


def lower_prefill(cfg, shape, mesh, rules, *, cost_mode=False, cost_attn="naive"):
    def prefill(params, batch):
        use_kernel = (cost_attn == "flash") if cost_mode else True
        logits, aux, h = M.forward_train(
            params, batch, cfg, use_kernel=use_kernel, remat=False,
            unroll=cost_mode,
        )
        return logits[:, -1]  # last-token logits (cache write bytes noted in report)

    pspecs = M.param_specs(cfg, rules)
    bspecs = {}
    if cfg.embeds_input:
        bspecs["embeds"] = rules.activations()
        bspecs["labels"] = rules.tokens()
    else:
        bspecs["tokens"] = rules.tokens()
        bspecs["labels"] = rules.tokens()
    if cfg.rope == "mrope":
        bspecs["mrope_positions"] = P(None, rules.batch_axes, None)
    params_sds = jax.eval_shape(lambda: M.init_params(jax.random.key(0), cfg))
    batch_sds = input_specs(cfg, shape)
    f = jax.jit(
        prefill,
        in_shardings=(_named(mesh, pspecs), _named(mesh, bspecs)),
        out_shardings=NamedSharding(mesh, P(rules.batch_axes, None)),
    )
    return f.lower(params_sds, batch_sds)


def lower_decode(cfg, shape, mesh, rules, *, cost_mode=False, cost_attn="naive"):
    long_ctx = shape.seq_len >= 262144

    def serve_step(params, cache, batch):
        pos = shape.seq_len - 1
        return M.decode_step(params, cache, batch, pos, cfg, unroll=cost_mode)

    pspecs = M.param_specs(cfg, rules)
    cspecs = M.cache_specs(cfg, rules, long_ctx)
    if shape.global_batch % 16:
        # batch too small to shard over the data axis (long_500k: B=1) —
        # replicate the batch dims, keep the sequence sharding.
        b = rules.batch_axes

        def debatch(spec):
            return P(*[None if ax == b else ax for ax in spec])

        cspecs = jax.tree.map(debatch, cspecs, is_leaf=lambda x: isinstance(x, P))
    params_sds = jax.eval_shape(lambda: M.init_params(jax.random.key(0), cfg))
    cache_sds = jax.eval_shape(
        lambda: M.init_cache(cfg, shape.global_batch, shape.seq_len, jnp.bfloat16)
    )
    batch_sds = input_specs(cfg, shape)
    batch_ax = None if shape.global_batch % 16 else rules.batch_axes
    bspecs = {}
    for k in batch_sds:
        if k == "mrope_positions":
            bspecs[k] = P(None, batch_ax, None)
        elif k == "embed":
            bspecs[k] = P(batch_ax, None)
        else:
            bspecs[k] = P(batch_ax)
    in_sh = (_named(mesh, pspecs), _named(mesh, cspecs), _named(mesh, bspecs))
    out_sh = (NamedSharding(mesh, P(batch_ax, None)), in_sh[1])
    f = jax.jit(serve_step, in_shardings=in_sh, out_shardings=out_sh, donate_argnums=(1,))
    return f.lower(params_sds, cache_sds, batch_sds)


def lower_cell(cfg, shape, mesh, rules, microbatches, *, cost_mode=False,
               cost_attn="naive"):
    if shape.kind == "train":
        return lower_train(cfg, shape, mesh, rules, microbatches,
                           cost_mode=cost_mode, cost_attn=cost_attn)
    if shape.kind == "prefill":
        return lower_prefill(cfg, shape, mesh, rules, cost_mode=cost_mode,
                             cost_attn=cost_attn)
    return lower_decode(cfg, shape, mesh, rules, cost_mode=cost_mode,
                        cost_attn=cost_attn)


# ----------------------------------------------------------------- analyze
def _cost(compiled):
    ca = compiled.cost_analysis()
    return float(ca.get("flops", 0.0)), float(ca.get("bytes accessed", 0.0))


def inner_loop_correction(cfg, shape, rules) -> float:
    """Analytic per-chip FLOP correction for sequence-inner loops that even
    the unrolled cost compiles count once (sLSTM's recurrent scan and
    mLSTM's chunk scan — xlstm only; Mamba uses associative_scan, which is
    log-depth combinators and fully counted).

    train: ×4 (fwd + recompute + 2·bwd under remat); prefill: ×1.
    """
    if not cfg.xlstm or shape.kind == "decode":
        return 0.0
    d = cfg.d_model
    H = cfg.n_heads
    dp = int(cfg.xlstm.proj_factor_mlstm * d)
    dh = dp // H
    tshard = rules.model_axis_size
    T_local = shape.global_batch * shape.seq_len / 16  # data-axis sharding
    pattern = cfg.layer_kinds()
    n_mlstm = sum(1 for m, _ in pattern if m == "mlstm") * cfg.n_groups
    n_slstm = sum(1 for m, _ in pattern if m == "slstm") * cfg.n_groups
    chunk = 256
    # mLSTM per token: intra-chunk scores+values 4·c·H·dh, inter/state 8·H·dh²
    mlstm_tok = 4 * chunk * H * dh + 8 * H * dh * dh
    # sLSTM per token: 2·(9·d²) matmul flops, model-sharded
    slstm_tok = 2 * 9 * d * d / tshard
    fwd = T_local * (n_mlstm * mlstm_tok + n_slstm * slstm_tok)
    mult = 4.0 if shape.kind == "train" else 1.0
    # subtract the once-counted single iteration (negligible, S >= 4096)
    return fwd * mult


def analyze_cell(arch: str, shape_name: str, multi_pod: bool, force=False,
                 variant: dict | None = None, tag: str = "") -> dict:
    shape = SHAPES[shape_name]
    mesh_tag = "pod2" if multi_pod else "pod1"
    suffix = f"__{tag}" if tag else ""
    out_path = RESULTS_DIR / f"{arch}__{shape_name}__{mesh_tag}{suffix}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    ok, reason = cell_supported(arch, shape_name)
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_tag,
        "time": time.time(),
    }
    if not ok:
        result["status"] = "skipped"
        result["reason"] = reason
        _save(out_path, result)
        return result

    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = make_rules(multi_pod=multi_pod)
    if variant:
        result["variant"] = variant
        mb_override = variant.pop("microbatches", None)
        rules = dataclasses.replace(rules, **variant)
        variant["microbatches"] = mb_override
    else:
        mb_override = None
    from repro.dist import sharding as SH
    SH.set_active(rules, mesh)  # model-internal sharding constraints (MoE)
    n_chips = int(np.prod(mesh.devices.shape))

    # what the price-driven autotuner would pick for this cell's MoE
    # dispatch site (analytic mode: deterministic, nothing measured or
    # written — the dry-run never times the 512 fake devices)
    from repro.runtime import autotune
    result["autotune"] = autotune.moe_site_report(
        cfg, rules, n_tokens=shape.global_batch * shape.seq_len,
        tuner=autotune.Autotuner(mode="analytic"),
    )
    mb = MICROBATCHES.get(arch, 1) if shape.kind == "train" else 1
    if mb_override:
        mb = mb_override

    t0 = time.time()
    try:
        lowered = lower_cell(cfg, shape, mesh, rules, mb)
        compiled = lowered.compile()
    except Exception as e:  # a dry-run failure is a bug in the system
        result["status"] = "FAILED"
        result["error"] = f"{type(e).__name__}: {e}"[:2000]
        _save(out_path, result)
        return result
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    print(f"[{arch} × {shape_name} × {mesh_tag}] memory_analysis():", mem, flush=True)
    flops_full, bytes_full = _cost(compiled)
    print(f"[{arch} × {shape_name} × {mesh_tag}] cost_analysis(): flops={flops_full:.3e} bytes={bytes_full:.3e}", flush=True)
    hlo = compiled.as_text()
    coll_full = collective_bytes(hlo)

    # depth-extrapolated true cost (loop bodies count once — see header)
    extrap = {}
    if not multi_pod:  # roofline table is single-pod only
        try:
            d1, d2 = _depth_variant(cfg, 1), _depth_variant(cfg, 2)
            # FLOPs pair: naive attention (every score tile counted)
            cn1 = lower_cell(d1, shape, mesh, rules, mb, cost_mode=True, cost_attn="naive").compile()
            cn2 = lower_cell(d2, shape, mesh, rules, mb, cost_mode=True, cost_attn="naive").compile()
            # bytes/collectives pair: flash attention (no fake S^2 traffic)
            cf1 = lower_cell(d1, shape, mesh, rules, mb, cost_mode=True, cost_attn="flash").compile()
            cf2 = lower_cell(d2, shape, mesh, rules, mb, cost_mode=True, cost_attn="flash").compile()
            f1, _ = _cost(cn1)
            f2, _ = _cost(cn2)
            _, b1 = _cost(cf1)
            _, b2 = _cost(cf2)
            k1 = collective_bytes(cf1.as_text())
            k2 = collective_bytes(cf2.as_text())
            g = cfg.n_groups
            extrap = {
                "flops": f1 + (f2 - f1) * (g - 1) + inner_loop_correction(cfg, shape, rules),
                "bytes": b1 + (b2 - b1) * (g - 1),
                "collective_bytes": {
                    kind: k1.get(kind, 0.0) + (k2.get(kind, 0.0) - k1.get(kind, 0.0)) * (g - 1)
                    for kind in set(k1) | set(k2) if kind != "_counts"
                },
            }
        except Exception as e:
            extrap = {"error": f"{type(e).__name__}: {e}"[:500]}

    result.update({
        "status": "ok",
        "compile_seconds": round(t_compile, 1),
        "n_chips": n_chips,
        "microbatches": mb,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        },
        "cost_full_compile": {"flops": flops_full, "bytes": bytes_full},
        "collectives_full_compile": coll_full,
        "cost_extrapolated": extrap,
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
    })
    if not multi_pod and "flops" in extrap:
        result["roofline"] = roofline_terms(result, cfg, shape)
    _save(out_path, result)
    return result


def roofline_terms(result: dict, cfg, shape) -> dict:
    """Three-term roofline from the extrapolated compiled cost.

    cost_analysis is whole-program (all partitions symmetric under SPMD:
    reported flops/bytes are per-partition already on the CPU backend?
    Empirically cost_analysis on a partitioned module reports the
    PER-PARTITION program; we treat it as per-chip).
    """
    n = result["n_chips"]
    ex = result["cost_extrapolated"]
    compute_s = ex["flops"] / PEAK_FLOPS
    memory_s = ex["bytes"] / HBM_BW
    cbytes = sum(v for v in ex["collective_bytes"].values())
    collective_s = cbytes / LINK_BW
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    if shape.kind == "train":
        model_flops = 6 * cfg.active_param_count() * tokens
    else:
        model_flops = 2 * cfg.active_param_count() * tokens
    dominant = max(
        ("compute", compute_s), ("memory", memory_s), ("collective", collective_s),
        key=lambda kv: kv[1],
    )[0]
    hlo_flops_global = ex["flops"] * n
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "model_flops": model_flops,
        "useful_flops_ratio": model_flops / max(hlo_flops_global, 1.0),
        "step_time_bound_s": max(compute_s, memory_s, collective_s),
        "roofline_fraction": compute_s / max(compute_s, memory_s, collective_s),
    }


def _save(path: pathlib.Path, result: dict):
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(result, indent=1, default=str))


# --------------------------------------------------------------------- CLI
def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    # hillclimb variant knobs (see EXPERIMENTS.md §Perf)
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--moe-collectives",
                    choices=["xla", "dragonfly", "dragonfly_overlap",
                             "dragonfly_overlap_fused", "auto"],
                    default=None)
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--tag", default="")
    args = ap.parse_args(argv)

    variant = {}
    if args.fsdp:
        variant["fsdp"] = True
    if args.zero1:
        variant["zero1"] = True
    if args.seq_parallel:
        variant["seq_parallel"] = True
    if args.moe_collectives:
        variant["moe_collectives"] = args.moe_collectives
    if args.microbatches:
        variant["microbatches"] = args.microbatches
    if variant and not args.tag:
        ap.error("--tag required when variant knobs are set")

    cells = []
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    failures = 0
    for a, s, mp in cells:
        r = analyze_cell(a, s, mp, force=args.force, variant=variant or None, tag=args.tag)
        tag = "pod2" if mp else "pod1"
        status = r["status"]
        extra = ""
        if status == "ok":
            extra = f"compile={r['compile_seconds']}s"
            if "roofline" in r:
                rf = r["roofline"]
                extra += (
                    f" dominant={rf['dominant']}"
                    f" terms(c/m/k)={rf['compute_s']:.3e}/{rf['memory_s']:.3e}/{rf['collective_s']:.3e}s"
                )
        elif status == "FAILED":
            failures += 1
            extra = r.get("error", "")[:160]
        else:
            extra = r.get("reason", "")
        print(f"{a:24s} {s:12s} {tag}  {status:8s} {extra}", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
