"""xLSTM 1.3B [arXiv:2405.04517; unverified] — 48 blocks d2048 4 heads,
xLSTM[7:1] (7 mLSTM : 1 sLSTM per group of 8); no separate FFN (d_ff=0)."""

from repro.configs.base import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    rope="none",
    norm="layernorm",
    xlstm=XLSTMConfig(proj_factor_mlstm=2.0, slstm_period=8),
)

SMOKE = ModelConfig(
    name="xlstm-smoke",
    family="ssm",
    n_layers=8,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=256,
    rope="none",
    norm="layernorm",
    xlstm=XLSTMConfig(proj_factor_mlstm=2.0, slstm_period=8),
    param_dtype="float32",
    compute_dtype="float32",
)
