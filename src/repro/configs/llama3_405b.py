"""Llama-3 405B [arXiv:2407.21783; unverified] — 126L d16384 128H (GQA
kv=8) d_ff=53248 vocab=128256."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    n_layers=126,
    d_model=16384,
    n_heads=128,
    n_kv_heads=8,
    d_ff=53248,
    vocab=128256,
    rope="rope",
    rope_theta=500000.0,
    norm="rmsnorm",
)

SMOKE = ModelConfig(
    name="llama3-smoke",
    family="dense",
    n_layers=3,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    d_ff=160,
    vocab=256,
    rope="rope",
    rope_theta=500000.0,
    norm="rmsnorm",
    param_dtype="float32",
    compute_dtype="float32",
)
