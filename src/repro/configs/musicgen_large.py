"""MusicGen-Large [arXiv:2306.05284; hf] — 48L d2048 32H d_ff=8192
vocab=2048 decoder-only over EnCodec tokens. The EnCodec frontend is a
STUB: input_specs() provides precomputed frame embeddings (positional
information included by the frontend, hence rope='none'); plain GELU MLP
(non-gated), LayerNorm — T5-style decoder."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=2048,
    rope="none",
    norm="layernorm",
    mlp_gated=False,
    embeds_input=True,
)

SMOKE = ModelConfig(
    name="musicgen-smoke",
    family="audio",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=64,
    rope="none",
    norm="layernorm",
    mlp_gated=False,
    embeds_input=True,
    param_dtype="float32",
    compute_dtype="float32",
)
