"""OLMo 1B [arXiv:2402.00838; hf] — 16L d2048 16H d_ff=8192 vocab=50304,
non-parametric LayerNorm, tied embeddings."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=50304,
    rope="rope",
    rope_theta=10000.0,
    norm="nonparametric",
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="olmo-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=256,
    rope="rope",
    norm="nonparametric",
    tie_embeddings=True,
    param_dtype="float32",
    compute_dtype="float32",
)
