"""Jamba 1.5 Large 398B [arXiv:2403.19887; hf] — 72L d8192 64H (GQA kv=8)
d_ff=24576 vocab=65536; Mamba+attention 1:7 interleave, MoE 16e top-2
every other layer."""

from repro.configs.base import ModelConfig, MoEConfig, MambaConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab=65536,
    rope="none",  # jamba uses no positional embeddings (Mamba carries order)
    norm="rmsnorm",
    attn_period=8,  # 1 attention : 7 mamba
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2, dt_rank=512),
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=24576, layer_period=2),
)

SMOKE = ModelConfig(
    name="jamba-smoke",
    family="hybrid",
    n_layers=8,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=256,
    rope="none",
    norm="rmsnorm",
    attn_period=8,
    mamba=MambaConfig(d_state=8, d_conv=4, expand=2, dt_rank=8),
    moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128, layer_period=2, capacity_factor=8.0),
    param_dtype="float32",
    compute_dtype="float32",
)
