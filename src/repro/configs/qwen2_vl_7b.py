"""Qwen2-VL 7B [arXiv:2409.12191; hf] — 28L d3584 28H (GQA kv=4)
d_ff=18944 vocab=152064; M-RoPE (temporal/height/width sections), dynamic
resolution. Vision frontend is a STUB: input_specs() provides precomputed
patch embeddings + 3-axis M-RoPE position ids."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab=152064,
    rope="mrope",
    rope_theta=1e6,
    mrope_sections=(16, 24, 24),  # head_dim 128 -> 64 freq pairs
    norm="rmsnorm",
    embeds_input=True,
)

SMOKE = ModelConfig(
    name="qwen2vl-smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=256,
    rope="mrope",
    mrope_sections=(4, 2, 2),  # head_dim 16 -> 8 freq pairs
    norm="rmsnorm",
    embeds_input=True,
    param_dtype="float32",
    compute_dtype="float32",
)
