"""Mixtral 8x7B [arXiv:2401.04088; hf] — 32L d4096 32H (GQA kv=8)
d_ff=14336 vocab=32000, MoE 8 experts top-2, sliding-window attention."""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=32000,
    sliding_window=4096,
    rope="rope",
    rope_theta=1e6,
    norm="rmsnorm",
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=14336, layer_period=1),
)

SMOKE = ModelConfig(
    name="mixtral-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=256,
    sliding_window=32,
    rope="rope",
    norm="rmsnorm",
    moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128, layer_period=1, capacity_factor=8.0),
    param_dtype="float32",
    compute_dtype="float32",
)
