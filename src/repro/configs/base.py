"""Config system: ModelConfig (architecture), ShapeConfig (workload),
arch registry, and input_specs() ShapeDtypeStruct builders for the dry-run.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    shared_experts: int = 0
    norm_topk_probs: bool = True
    layer_period: int = 1      # MoE every k-th layer
    aux_loss_weight: float = 0.01
    capacity_factor: float = 1.25  # sparse-dispatch buffer headroom


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 256  # ~ d_model/16


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    proj_factor_mlstm: float = 2.0
    slstm_period: int = 8  # 1 sLSTM per 8 blocks (xLSTM[7:1])


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    attention: str = "gqa"  # gqa | mla
    sliding_window: Optional[int] = None
    rope: str = "rope"  # rope | mrope | none
    rope_theta: float = 10000.0
    mrope_sections: tuple = (16, 24, 24)
    norm: str = "rmsnorm"  # rmsnorm | layernorm | nonparametric
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    mamba: Optional[MambaConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    attn_period: int = 1   # jamba: 1 attention per 8 layers
    first_dense_layers: int = 0  # deepseek: 3 dense layers before MoE
    mlp_gated: bool = True  # SwiGLU (False: plain GELU — musicgen)
    tie_embeddings: bool = False
    mtp_depth: int = 0     # deepseek multi-token prediction heads
    embeds_input: bool = False  # audio/vlm stub: precomputed frame/patch embeds
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # ----------------------------------------------------------- pattern
    def layer_kinds(self) -> list[tuple[str, str]]:
        """The repeating (mixer, ffn) pattern of the MAIN stack — one
        period. ``first_dense_layers`` (deepseek) form a separate dense
        prefix stack (see models/model.py)."""
        import math

        period = 1
        if self.xlstm:
            period = self.xlstm.slstm_period
        if self.attn_period > 1:
            period = max(period, self.attn_period)
        if self.moe:
            period = math.lcm(period, self.moe.layer_period)
        kinds = []
        for i in range(period):
            if self.xlstm:
                mixer = "slstm" if (i % self.xlstm.slstm_period) == self.xlstm.slstm_period - 1 else "mlstm"
                kinds.append((mixer, "none"))
                continue
            if self.mamba and self.attn_period > 1:
                mixer = "attn" if (i % self.attn_period) == 0 else "mamba"
            else:
                mixer = "attn"
            if self.moe and (i % self.moe.layer_period) == self.moe.layer_period - 1:
                ffn = "moe"
            else:
                ffn = "mlp"
            kinds.append((mixer, ffn))
        assert (self.n_layers - self.first_dense_layers) % len(kinds) == 0, (
            self.n_layers, self.first_dense_layers, len(kinds))
        return kinds

    @property
    def n_groups(self) -> int:
        return (self.n_layers - self.first_dense_layers) // len(self.layer_kinds())

    # -------------------------------------------------------- param count
    def param_count(self) -> int:
        """Total parameters N (used for MODEL_FLOPS = 6·N·D)."""
        import numpy as np
        d, hd = self.d_model, self.head_dim
        total = self.vocab * d * (1 if self.tie_embeddings else 2)
        all_layers = [("attn", "mlp")] * self.first_dense_layers + (
            self.layer_kinds() * self.n_groups
        )
        for mixer, ffn in all_layers:
            total += d  # norm1
            if mixer == "attn":
                if self.attention == "mla":
                    m = self.mla
                    qh = m.qk_nope_head_dim + m.qk_rope_head_dim
                    total += d * m.q_lora_rank + m.q_lora_rank * self.n_heads * qh
                    total += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    total += m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
                    total += self.n_heads * m.v_head_dim * d
                    total += m.q_lora_rank + m.kv_lora_rank
                else:
                    total += d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
                    total += self.n_heads * hd * d
            elif mixer == "mamba":
                mb = self.mamba
                di = mb.expand * d
                total += d * 2 * di + mb.d_conv * di + di
                total += di * (2 * mb.d_state + mb.dt_rank) + mb.dt_rank * di + di
                total += di * mb.d_state + di + di * d
            elif mixer == "mlstm":
                dp = int(self.xlstm.proj_factor_mlstm * d)
                dh = dp // self.n_heads
                # block-diagonal q/k/v: H·dh² each
                total += d * 2 * dp + 3 * self.n_heads * dh * dh
                total += dp * 2 * self.n_heads + dp + dp * d
            elif mixer == "slstm":
                total += 8 * d * d + 4 * d + d * d
            if ffn == "mlp":
                total += 3 * d * self.d_ff + d
            elif ffn == "moe":
                mo = self.moe
                total += d * mo.num_experts
                total += mo.num_experts * 3 * d * mo.d_ff_expert
                if mo.shared_experts:
                    total += 3 * d * mo.d_ff_expert * mo.shared_experts
                total += d
        total += d  # final norm
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k + shared experts)."""
        if not self.moe:
            return self.param_count()
        mo = self.moe
        inactive_per_moe_layer = (mo.num_experts - mo.top_k) * 3 * self.d_model * mo.d_ff_expert
        n_moe_layers = sum(1 for _, f in self.layer_kinds() if f == "moe") * self.n_groups
        return int(self.param_count() - n_moe_layers * inactive_per_moe_layer)


# ---------------------------------------------------------------- shapes
@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# archs allowed to run long_500k (sub-quadratic sequence mixing)
LONG_CONTEXT_OK = {"mixtral-8x7b", "jamba-1.5-large-398b", "xlstm-1.3b"}


def cell_supported(arch_name: str, shape_name: str) -> tuple[bool, str]:
    if shape_name == "long_500k" and arch_name not in LONG_CONTEXT_OK:
        return False, "full quadratic attention at 512k infeasible (DESIGN.md §4)"
    return True, ""


# -------------------------------------------------------------- registry
ARCH_IDS = [
    "mixtral-8x7b",
    "deepseek-v3-671b",
    "jamba-1.5-large-398b",
    "musicgen-large",
    "qwen2-vl-7b",
    "tinyllama-1.1b",
    "phi3-mini-3.8b",
    "olmo-1b",
    "llama3-405b",
    "xlstm-1.3b",
]

_MOD = {
    "mixtral-8x7b": "mixtral_8x7b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "musicgen-large": "musicgen_large",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "olmo-1b": "olmo_1b",
    "llama3-405b": "llama3_405b",
    "xlstm-1.3b": "xlstm_1_3b",
}


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MOD[arch]}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MOD[arch]}")
    return mod.SMOKE


# ------------------------------------------------------------ input specs
def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    B, S = shape.global_batch, shape.seq_len
    f = jax.ShapeDtypeStruct
    if shape.kind in ("train", "prefill"):
        specs = {}
        if cfg.embeds_input:
            # modality frontend stub: precomputed frame/patch embeddings
            specs["embeds"] = f((B, S, cfg.d_model), jnp.bfloat16)
            specs["labels"] = f((B, S), jnp.int32)
        else:
            specs["tokens"] = f((B, S), jnp.int32)
            specs["labels"] = f((B, S), jnp.int32)
        if cfg.rope == "mrope":
            specs["mrope_positions"] = f((3, B, S), jnp.int32)
        return specs
    # decode: one new token against a seq_len KV cache
    specs = {"token": f((B,), jnp.int32)}
    if cfg.embeds_input:
        specs = {"embed": f((B, cfg.d_model), jnp.bfloat16)}
    if cfg.rope == "mrope":
        specs["mrope_positions"] = f((3, B, 1), jnp.int32)
    return specs
