"""DeepSeek-V3 671B [arXiv:2412.19437; hf] — 61L d7168 128H MLA
d_ff(dense)=18432, MoE 1 shared + 256 routed top-8 (expert ff 2048), MTP."""

from repro.configs.base import ModelConfig, MoEConfig, MLAConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,          # dense-prefix FFN
    vocab=129280,
    attention="mla",
    head_dim=192,        # qk_nope 128 + qk_rope 64
    rope="rope",
    rope_theta=10000.0,
    norm="rmsnorm",
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        num_experts=256, top_k=8, d_ff_expert=2048, shared_experts=1, layer_period=1
    ),
    first_dense_layers=3,
    mtp_depth=1,
)

SMOKE = ModelConfig(
    name="deepseek-smoke",
    family="moe",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=256,
    attention="mla",
    head_dim=24,
    rope="rope",
    norm="rmsnorm",
    mla=MLAConfig(
        q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
        qk_rope_head_dim=8, v_head_dim=16,
    ),
    moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=32, shared_experts=1, layer_period=1, capacity_factor=8.0),
    first_dense_layers=1,
    mtp_depth=1,
    param_dtype="float32",
    compute_dtype="float32",
)
