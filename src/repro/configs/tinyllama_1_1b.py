"""TinyLlama 1.1B [arXiv:2401.02385; hf] — 22L d2048 32H (GQA kv=4)
d_ff=5632 vocab=32000, llama2-style."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b",
    family="dense",
    n_layers=22,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=5632,
    vocab=32000,
    rope="rope",
    rope_theta=10000.0,
    norm="rmsnorm",
)

SMOKE = ModelConfig(
    name="tinyllama-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=256,
    rope="rope",
    norm="rmsnorm",
    param_dtype="float32",
    compute_dtype="float32",
)
