"""Phi-3-mini 3.8B [arXiv:2404.14219; unverified] — 32L d3072 32H (kv=32)
d_ff=8192 vocab=32064, RoPE + SwiGLU."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32064,
    rope="rope",
    rope_theta=10000.0,
    norm="rmsnorm",
)

SMOKE = ModelConfig(
    name="phi3-smoke",
    family="dense",
    n_layers=2,
    d_model=96,
    n_heads=4,
    n_kv_heads=4,
    d_ff=192,
    vocab=256,
    rope="rope",
    norm="rmsnorm",
    param_dtype="float32",
    compute_dtype="float32",
)
