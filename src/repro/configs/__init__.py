"""Architecture registry — one module per assigned arch + the shape grid."""

from repro.configs.base import (
    ModelConfig,
    MoEConfig,
    MLAConfig,
    MambaConfig,
    XLSTMConfig,
    ShapeConfig,
    SHAPES,
    ARCH_IDS,
    LONG_CONTEXT_OK,
    get_config,
    get_smoke_config,
    input_specs,
    cell_supported,
)

__all__ = [
    "ModelConfig",
    "MoEConfig",
    "MLAConfig",
    "MambaConfig",
    "XLSTMConfig",
    "ShapeConfig",
    "SHAPES",
    "ARCH_IDS",
    "LONG_CONTEXT_OK",
    "get_config",
    "get_smoke_config",
    "input_specs",
    "cell_supported",
]
