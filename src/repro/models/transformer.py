"""Composable decoder: blocks = mixer (attn/mamba/mLSTM/sLSTM) + optional
FFN (dense MLP / MoE), pre-norm residual. Layers are stacked as repeating
GROUPS (the arch's block pattern period) and scanned with lax.scan +
jax.checkpoint — one trace per distinct member, n_layers/period iterations.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.models import attention as A
from repro.models import moe as MOE
from repro.models import mamba as MB
from repro.models import xlstm as XL


# ------------------------------------------------------------ one member
def member_init(key, cfg, mixer: str, ffn: str, dtype):
    k1, k2 = jax.random.split(key)
    p = {"norm1": L.make_norm(cfg.norm, cfg.d_model, dtype)[0]}
    if mixer == "attn":
        p["mixer"] = A.mla_init(k1, cfg, dtype) if cfg.attention == "mla" else A.gqa_init(k1, cfg, dtype)
    elif mixer == "mamba":
        p["mixer"] = MB.mamba_init(k1, cfg, dtype)
    elif mixer == "mlstm":
        p["mixer"] = XL.mlstm_init(k1, cfg, dtype)
    elif mixer == "slstm":
        p["mixer"] = XL.slstm_init(k1, cfg, dtype)
    else:
        raise ValueError(mixer)
    if ffn != "none":
        p["norm2"] = L.make_norm(cfg.norm, cfg.d_model, dtype)[0]
        p["ffn"] = MOE.moe_init(k2, cfg, dtype) if ffn == "moe" else L.mlp_init(
            k2, cfg.d_model, cfg.d_ff, dtype, gated=cfg.mlp_gated
        )
    return p


def member_specs(cfg, rules, mixer: str, ffn: str):
    s = {"norm1": L.norm_specs(cfg.norm)}
    if mixer == "attn":
        s["mixer"] = A.mla_specs(cfg, rules) if cfg.attention == "mla" else A.gqa_specs(cfg, rules)
    elif mixer == "mamba":
        s["mixer"] = MB.mamba_specs(cfg, rules)
    elif mixer == "mlstm":
        s["mixer"] = XL.mlstm_specs(cfg, rules)
    elif mixer == "slstm":
        s["mixer"] = XL.slstm_specs(cfg, rules)
    if ffn != "none":
        s["norm2"] = L.norm_specs(cfg.norm)
        s["ffn"] = MOE.moe_specs(cfg, rules) if ffn == "moe" else L.mlp_specs(
            rules, gated=cfg.mlp_gated
        )
    return s


def _norm(cfg):
    return L.rmsnorm if cfg.norm == "rmsnorm" else L.layernorm


def member_train(params, x, cfg, mixer, ffn, positions, mrope_positions, use_kernel):
    from repro.dist import sharding as SH

    act = SH.active()
    if act is not None and act[0].seq_parallel:
        # sequence parallelism: residual stream sharded over the tensor
        # axis between blocks — XLA turns the TP all-reduces into
        # reduce-scatter + all-gather pairs (half the collective bytes).
        x = SH.constrain(x, act[0].batch_axes, act[0].tensor_axis, None)
    norm = _norm(cfg)
    h = norm(params["norm1"], x)
    if mixer == "attn":
        if cfg.attention == "mla":
            mx = A.mla_train(params["mixer"], h, cfg, positions, use_kernel=use_kernel)
        else:
            mx = A.gqa_train(params["mixer"], h, cfg, positions, mrope_positions, use_kernel)
    elif mixer == "mamba":
        mx = MB.mamba_train(params["mixer"], h, cfg)
    elif mixer == "mlstm":
        mx = XL.mlstm_train(params["mixer"], h, cfg)
    else:
        mx = XL.slstm_train(params["mixer"], h, cfg)
    x = x + mx
    aux = jnp.zeros((), jnp.float32)
    if ffn != "none":
        h2 = norm(params["norm2"], x)
        if ffn == "moe":
            y, aux = MOE.moe_apply_auto(params["ffn"], h2, cfg)
        else:
            y = L.mlp_apply(
                params["ffn"], h2, act=jax.nn.silu if cfg.mlp_gated else jax.nn.gelu
            )
        x = x + y
    return x, aux


def member_decode_mixer(params, x, cache, cfg, mixer, position, mrope_positions):
    """The mixer half of one decode member: pre-norm mixer + residual.
    Returns (x, new_cache) — the FFN half (if any) applies on top."""
    norm = _norm(cfg)
    h = norm(params["norm1"], x)
    if mixer == "attn":
        if cfg.attention == "mla":
            mx, cache = A.mla_decode(params["mixer"], h, cache, cfg, position)
        else:
            mx, cache = A.gqa_decode(params["mixer"], h, cache, cfg, position, mrope_positions)
    elif mixer == "mamba":
        mx, cache = MB.mamba_decode(params["mixer"], h, cache, cfg)
    elif mixer == "mlstm":
        mx, cache = XL.mlstm_decode(params["mixer"], h, cache, cfg)
    else:
        mx, cache = XL.slstm_decode(params["mixer"], h, cache, cfg)
    return x + mx, cache


@functools.lru_cache(maxsize=None)
def mixer_decode_jit(cfg, mixer):
    """Jitted ``member_decode_mixer`` per (config, mixer kind) — the staged
    decode path (``stack_decode_staged``) runs the mixers compiled even
    though the generator itself is eager Python. mrope-free (token serving);
    callers with mrope positions fall back to the eager form."""

    def fn(params, x, cache, position):
        return member_decode_mixer(params, x, cache, cfg, mixer, position, None)

    return jax.jit(fn)


def member_decode(params, x, cache, cfg, mixer, ffn, position, mrope_positions):
    x, cache = member_decode_mixer(params, x, cache, cfg, mixer, position, mrope_positions)
    if ffn != "none":
        h2 = _norm(cfg)(params["norm2"], x)
        if ffn == "moe":
            y, _ = MOE.moe_apply_auto(params["ffn"], h2, cfg)
        else:
            y = L.mlp_apply(
                params["ffn"], h2, act=jax.nn.silu if cfg.mlp_gated else jax.nn.gelu
            )
        x = x + y
    return x, cache


def member_cache_init(cfg, mixer, batch, max_seq, dtype):
    if mixer == "attn":
        if cfg.attention == "mla":
            return A.mla_cache_init(cfg, batch, max_seq, dtype)
        return A.gqa_cache_init(cfg, batch, max_seq, dtype)
    if mixer == "mamba":
        return MB.mamba_state_init(cfg, batch, dtype)
    if mixer == "mlstm":
        return XL.mlstm_state_init(cfg, batch, dtype)
    return XL.slstm_state_init(cfg, batch, dtype)


# -------------------------------------------------------------- the stack
def stack_init(key, cfg, dtype):
    """Returns a tuple (one entry per group member) of param trees stacked
    over the n_groups axis (leading dim)."""
    pattern = cfg.layer_kinds()
    period = len(pattern)
    n_groups = cfg.n_groups  # excludes the dense prefix (deepseek)
    members = []
    for mi, (mixer, ffn) in enumerate(pattern):
        per_group = [
            member_init(jax.random.fold_in(key, g * period + mi), cfg, mixer, ffn, dtype)
            for g in range(n_groups)
        ]
        members.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_group))
    return tuple(members)


def stack_specs(cfg, rules):
    pattern = cfg.layer_kinds()

    def add_lead(spec):
        return P(None, *spec)

    return tuple(
        jax.tree.map(
            add_lead,
            member_specs(cfg, rules, mixer, ffn),
            is_leaf=lambda x: isinstance(x, P),
        )
        for mixer, ffn in pattern
    )


def stack_train(stack_params, x, cfg, positions, mrope_positions=None, use_kernel=True,
                remat: bool = True, unroll: bool = False):
    pattern = cfg.layer_kinds()

    def group_fn(x, group_params):
        aux_total = jnp.zeros((), jnp.float32)
        for mi, (mixer, ffn) in enumerate(pattern):
            x, aux = member_train(
                group_params[mi], x, cfg, mixer, ffn, positions, mrope_positions, use_kernel
            )
            aux_total += aux
        return x, aux_total

    if remat:
        group_fn = jax.checkpoint(group_fn)

    if unroll:
        # Python loop over groups — used by the dry-run's cost-analysis
        # compiles (XLA counts while-loop bodies once; unrolling makes
        # flops/bytes scale with depth so per-group deltas are exact).
        aux_total = jnp.zeros((), jnp.float32)
        for g in range(cfg.n_groups):
            group = jax.tree.map(lambda a: a[g], stack_params)
            x, aux = group_fn(x, group)
            aux_total += aux
        return x, aux_total

    x, auxs = jax.lax.scan(group_fn, x, stack_params)
    return x, auxs.sum()


def stack_decode(stack_params, x, caches, cfg, position, mrope_positions=None,
                 unroll: bool = False):
    pattern = cfg.layer_kinds()

    def group_fn(x, inputs):
        group_params, group_cache = inputs
        new_caches = []
        for mi, (mixer, ffn) in enumerate(pattern):
            x, nc = member_decode(
                group_params[mi], x, group_cache[mi], cfg, mixer, ffn, position, mrope_positions
            )
            new_caches.append(nc)
        return x, tuple(new_caches)

    if unroll:
        outs = []
        for g in range(cfg.n_groups):
            sel = lambda a: a[g]
            x, nc = group_fn(x, (jax.tree.map(sel, stack_params), jax.tree.map(sel, caches)))
            outs.append(nc)
        new_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        return x, new_caches

    x, new_caches = jax.lax.scan(group_fn, x, (stack_params, caches))
    return x, new_caches


def stack_decode_staged(stack_params, x, caches, cfg, position, mrope_positions=None):
    """Generator twin of ``stack_decode`` that SUSPENDS at every MoE member:
    instead of computing the expert FFN inline, it yields ``(ffn_params,
    h2)`` — the member's expert weights and its post-norm2 hidden — and
    expects the expert output ``y`` sent back (``gen.send(y)``), which it
    adds to the residual stream exactly where ``member_decode`` would.

    This is the seam multi-tenant serving cuts the forward at: the driver
    (``serve.fleet.TenantFleet``) collects the yields of N tenants' staged
    decodes and services them all with ONE combined host program replay per
    boundary round. Mixers run through the jitted ``mixer_decode_jit``
    (eager fallback when mrope positions are present); everything outside
    the MoE members is the same math as ``stack_decode(unroll=True)``.

    Returns (x, new_caches) via StopIteration.value, caches restacked over
    the group axis like the unroll path.
    """
    pattern = cfg.layer_kinds()
    norm = _norm(cfg)
    outs = []
    for g in range(cfg.n_groups):
        sel = lambda a: a[g]
        group_params = jax.tree.map(sel, stack_params)
        group_cache = jax.tree.map(sel, caches)
        new_caches = []
        for mi, (mixer, ffn) in enumerate(pattern):
            if mrope_positions is None:
                x, nc = mixer_decode_jit(cfg, mixer)(
                    group_params[mi], x, group_cache[mi], position
                )
            else:
                x, nc = member_decode_mixer(
                    group_params[mi], x, group_cache[mi], cfg, mixer,
                    position, mrope_positions,
                )
            new_caches.append(nc)
            if ffn == "moe":
                h2 = norm(group_params[mi]["norm2"], x)
                y = yield (group_params[mi]["ffn"], h2)
                x = x + jnp.asarray(y, x.dtype)
            elif ffn != "none":
                h2 = norm(group_params[mi]["norm2"], x)
                x = x + L.mlp_apply(
                    group_params[mi]["ffn"], h2,
                    act=jax.nn.silu if cfg.mlp_gated else jax.nn.gelu,
                )
        outs.append(tuple(new_caches))
    new_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    return x, new_caches


def stack_cache_init(cfg, batch, max_seq, dtype):
    pattern = cfg.layer_kinds()
    n_groups = cfg.n_groups
    caches = []
    for mixer, _ in pattern:
        one = member_cache_init(cfg, mixer, batch, max_seq, dtype)
        caches.append(jax.tree.map(lambda a: jnp.broadcast_to(a, (n_groups, *a.shape)), one))
    return tuple(caches)


def stack_cache_specs(cfg, rules, long_context: bool):
    """Decode caches are SEQUENCE-sharded over the tensor axis (kv-head
    counts like 8 don't divide a 16-wide model axis; seq always does).
    Recurrent states shard their inner/feature dims instead."""
    pattern = cfg.layer_kinds()
    b = rules.batch_axes
    t = rules.tensor_axis
    specs = []
    for mixer, _ in pattern:
        if mixer == "attn":
            if cfg.attention == "mla":
                specs.append({
                    "c_kv": P(None, b, t, None),     # (G, B, S, r)
                    "k_rope": P(None, b, t, None),
                })
            else:
                specs.append({
                    "k": P(None, b, None, t, None),  # (G, B, kvh, S, hd)
                    "v": P(None, b, None, t, None),
                })
        elif mixer == "mamba":
            specs.append({
                "conv": P(None, b, None, t),  # (G, B, d_conv-1, di)
                "ssm": P(None, b, t, None),   # (G, B, di, N)
            })
        elif mixer == "mlstm":
            specs.append({
                "C": P(None, b, None, t, None),  # (G, B, H, dh, dh)
                "n": P(None, b, None, t),
                "m": P(None, b, None),
            })
        else:  # slstm: (G, B, d)
            specs.append({
                "c": P(None, b, t),
                "n": P(None, b, t),
                "h": P(None, b, t),
                "m": P(None, b, t),
            })
    return tuple(specs)
