"""Language model wrapper: embedding -> (dense prefix) -> main stack ->
final norm -> logits, plus the DeepSeek-style MTP head, loss, and the
decode step. All entry points are pure functions of (params, batch).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.models import transformer as T
from repro.configs.base import ModelConfig


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# ------------------------------------------------------------------ init
def init_params(key, cfg: ModelConfig):
    dt = _dtype(cfg)
    ks = jax.random.split(key, 8)
    p = {
        "embed": L.embed_init(ks[0], cfg.vocab, cfg.d_model, dt),
        "final_norm": L.make_norm(cfg.norm, cfg.d_model, dt)[0],
        "stack": T.stack_init(ks[1], cfg, dt),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = {"w": L.truncated_normal(ks[2], (cfg.d_model, cfg.vocab), dt, cfg.d_model ** -0.5)}
    if cfg.first_dense_layers:
        members = [
            T.member_init(jax.random.fold_in(ks[3], i), cfg, "attn", "mlp", dt)
            for i in range(cfg.first_dense_layers)
        ]
        p["prefix"] = (jax.tree.map(lambda *xs: jnp.stack(xs), *members),)
    if cfg.mtp_depth:
        p["mtp"] = {
            "proj": L.truncated_normal(ks[4], (2 * cfg.d_model, cfg.d_model), dt, (2 * cfg.d_model) ** -0.5),
            "norm": L.make_norm(cfg.norm, cfg.d_model, dt)[0],
            "block": T.member_init(ks[5], cfg, "attn", "mlp", dt),
        }
    return p


def param_specs(cfg: ModelConfig, rules):
    s = {
        "embed": {"table": rules.embed((cfg.vocab, cfg.d_model))},
        "final_norm": L.norm_specs(cfg.norm),
        "stack": T.stack_specs(cfg, rules),
    }
    if not cfg.tie_embeddings:
        s["unembed"] = {"w": rules.attn_in((cfg.d_model, cfg.vocab))}
    if cfg.first_dense_layers:
        member = T.member_specs(cfg, rules, "attn", "mlp")
        s["prefix"] = (
            jax.tree.map(lambda sp: P(None, *sp), member, is_leaf=lambda x: isinstance(x, P)),
        )
    if cfg.mtp_depth:
        s["mtp"] = {
            "proj": P(None, None),
            "norm": L.norm_specs(cfg.norm),
            "block": T.member_specs(cfg, rules, "attn", "mlp"),
        }
    return s


# --------------------------------------------------------------- forward
def _embed_inputs(params, batch, cfg):
    if cfg.embeds_input and "embeds" in batch:
        x = batch["embeds"].astype(jnp.dtype(cfg.compute_dtype))
        B, S = x.shape[:2]
    else:
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = L.embed_apply(params["embed"], tokens).astype(jnp.dtype(cfg.compute_dtype))
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    mrope = batch.get("mrope_positions")
    return x, positions, mrope


def forward_train(params, batch, cfg: ModelConfig, use_kernel: bool = True, remat: bool = True,
                  unroll: bool = False):
    """-> (logits (B, S, vocab), aux_loss, hidden (B, S, d))."""
    x, positions, mrope = _embed_inputs(params, batch, cfg)
    aux = jnp.zeros((), jnp.float32)
    if cfg.first_dense_layers:
        def pre_fn(x, member):
            x, a = T.member_train(member, x, cfg, "attn", "mlp", positions, mrope, use_kernel)
            return x, a
        pf = jax.checkpoint(pre_fn) if remat else pre_fn
        if unroll:
            for i in range(cfg.first_dense_layers):
                x, a = pf(x, jax.tree.map(lambda v: v[i], params["prefix"][0]))
                aux += a
        else:
            x, auxs = jax.lax.scan(pf, x, params["prefix"][0])
            aux += auxs.sum()
    x, aux2 = T.stack_train(params["stack"], x, cfg, positions, mrope, use_kernel, remat, unroll)
    aux += aux2
    h = _norm_f(cfg)(params["final_norm"], x)
    logits = _unembed(params, h, cfg)
    return logits, aux, h


def _norm_f(cfg):
    return L.rmsnorm if cfg.norm == "rmsnorm" else L.layernorm


def _unembed(params, h, cfg):
    if cfg.tie_embeddings:
        return L.unembed_apply(params["embed"], h)
    return h @ params["unembed"]["w"]


def mtp_logits(params, h, batch, cfg, use_kernel=True):
    """DeepSeek MTP: predict token t+2 from [h_t ; emb(token_{t+1})]
    through one extra block sharing the embedding/unembedding."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    nxt = jnp.pad(tokens[:, 1:], ((0, 0), (0, 1)))
    e = L.embed_apply(params["embed"], nxt).astype(h.dtype)
    z = jnp.concatenate([_norm_f(cfg)(params["mtp"]["norm"], h), e], axis=-1)
    z = z @ params["mtp"]["proj"]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    z, _ = T.member_train(params["mtp"]["block"], z, cfg, "attn", "mlp", positions, None, use_kernel)
    return _unembed(params, z, cfg)


def softmax_xent(logits, labels, valid=None):
    lf = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = logz - ll
    if valid is None:
        return nll.mean()
    return (nll * valid).sum() / jnp.clip(valid.sum(), 1)


def loss_fn(params, batch, cfg: ModelConfig, use_kernel: bool = True, remat: bool = True,
            unroll: bool = False):
    logits, aux, h = forward_train(params, batch, cfg, use_kernel, remat, unroll)
    labels = batch["labels"]
    loss = softmax_xent(logits[:, :-1], labels[:, 1:])
    metrics = {"ce": loss}
    if cfg.moe is not None:
        loss = loss + cfg.moe.aux_loss_weight * aux
        metrics["moe_aux"] = aux
    if cfg.mtp_depth and "tokens" in batch:
        ml = mtp_logits(params, h, batch, cfg, use_kernel)
        mtp_loss = softmax_xent(ml[:, :-2], labels[:, 2:])
        loss = loss + 0.3 * mtp_loss
        metrics["mtp"] = mtp_loss
    metrics["loss"] = loss
    return loss, metrics


# ---------------------------------------------------------------- decode
def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=None):
    dt = dtype or jnp.dtype(cfg.compute_dtype)
    cache = {"stack": T.stack_cache_init(cfg, batch, max_seq, dt)}
    if cfg.first_dense_layers:
        one = T.member_cache_init(cfg, "attn", batch, max_seq, dt)
        cache["prefix"] = (
            jax.tree.map(lambda a: jnp.broadcast_to(a, (cfg.first_dense_layers, *a.shape)), one),
        )
    return cache


def cache_specs(cfg: ModelConfig, rules, long_context: bool):
    s = {"stack": T.stack_cache_specs(cfg, rules, long_context)}
    if cfg.first_dense_layers:
        s["prefix"] = (T.stack_cache_specs(cfg, rules, long_context)[0],)
    return s


def decode_step(params, cache, batch, position, cfg: ModelConfig, unroll: bool = False):
    """One token for the whole batch at ``position`` (scalar or (B,)).

    batch: {'token': (B,)} or {'embed': (B, d)} (+ mrope positions).
    Returns (logits (B, vocab), new_cache).
    """
    if cfg.embeds_input and "embed" in batch:
        x = batch["embed"][:, None].astype(jnp.dtype(cfg.compute_dtype))
    else:
        x = L.embed_apply(params["embed"], batch["token"][:, None]).astype(
            jnp.dtype(cfg.compute_dtype)
        )
    mrope = batch.get("mrope_positions")
    new_cache = dict(cache)
    if cfg.first_dense_layers:
        def pre_fn(x, inputs):
            member, c = inputs
            x, nc = T.member_decode(member, x, c, cfg, "attn", "mlp", position, mrope)
            return x, nc
        if unroll:
            outs = []
            for i in range(cfg.first_dense_layers):
                sel = lambda a: a[i]
                x, nc = pre_fn(x, (jax.tree.map(sel, params["prefix"][0]),
                                   jax.tree.map(sel, cache["prefix"][0])))
                outs.append(nc)
            npc = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        else:
            x, npc = jax.lax.scan(pre_fn, x, (params["prefix"][0], cache["prefix"][0]))
        new_cache["prefix"] = (npc,)
    x, nsc = T.stack_decode(params["stack"], x, cache["stack"], cfg, position, mrope, unroll)
    new_cache["stack"] = nsc
    h = _norm_f(cfg)(params["final_norm"], x)
    logits = _unembed(params, h, cfg)
    return logits[:, 0], new_cache


def decode_step_staged(params, cache, batch, position, cfg: ModelConfig):
    """Generator twin of ``decode_step`` that pauses at every MoE boundary.

    Same contract as ``decode_step`` — but instead of computing expert FFNs
    inline it delegates to ``transformer.stack_decode_staged``, yielding
    ``(ffn_params, h2)`` at each MoE member and expecting the expert output
    sent back. Drive it with ``next()`` / ``gen.send(y)``; the final
    ``StopIteration.value`` is ``(logits (B, vocab), new_cache)``.

    The dense prefix (deepseek ``first_dense_layers``) has no MoE members
    and runs eagerly up front; mixers inside the stack run jitted. This is
    the forward the multi-tenant ``serve.fleet`` engines use so N tenants'
    expert dispatches can share one combined host program per boundary.
    """
    if cfg.embeds_input and "embed" in batch:
        x = batch["embed"][:, None].astype(jnp.dtype(cfg.compute_dtype))
    else:
        x = L.embed_apply(params["embed"], batch["token"][:, None]).astype(
            jnp.dtype(cfg.compute_dtype)
        )
    mrope = batch.get("mrope_positions")
    new_cache = dict(cache)
    if cfg.first_dense_layers:
        outs = []
        for i in range(cfg.first_dense_layers):
            sel = lambda a: a[i]
            x, nc = T.member_decode(
                jax.tree.map(sel, params["prefix"][0]), x,
                jax.tree.map(sel, cache["prefix"][0]), cfg, "attn", "mlp",
                position, mrope,
            )
            outs.append(nc)
        new_cache["prefix"] = (jax.tree.map(lambda *xs: jnp.stack(xs), *outs),)
    x, nsc = yield from T.stack_decode_staged(
        params["stack"], x, cache["stack"], cfg, position, mrope
    )
    new_cache["stack"] = nsc
    h = _norm_f(cfg)(params["final_norm"], x)
    logits = _unembed(params, h, cfg)
    return logits[:, 0], new_cache
