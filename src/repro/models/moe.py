"""Mixture-of-Experts: top-k router, shared experts, dense-dispatch einsum
formulation (shardable over the expert axis by pjit), plus the shard_map
expert-parallel path that uses the paper's doubly-parallel all-to-all.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models import layers as L


def moe_init(key, cfg, dtype):
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    s_in = d ** -0.5
    s_out = m.d_ff_expert ** -0.5
    p = {
        "router": L.truncated_normal(ks[0], (d, m.num_experts), dtype, s_in),
        "w_in": L.truncated_normal(ks[1], (m.num_experts, d, m.d_ff_expert), dtype, s_in),
        "w_gate": L.truncated_normal(ks[2], (m.num_experts, d, m.d_ff_expert), dtype, s_in),
        "w_out": L.truncated_normal(ks[3], (m.num_experts, m.d_ff_expert, d), dtype, s_out),
    }
    if m.shared_experts:
        p["shared"] = L.mlp_init(
            jax.random.fold_in(key, 7), d, m.d_ff_expert * m.shared_experts, dtype
        )
    return p


def moe_specs(cfg, rules):
    E = cfg.moe.num_experts
    p = {
        "router": P(None, None),
        "w_in": rules.expert((E, 0, 0), ff_dim=2, n_experts=E),
        "w_gate": rules.expert((E, 0, 0), ff_dim=2, n_experts=E),
        "w_out": rules.expert((E, 0, 0), ff_dim=1, n_experts=E),
    }
    if cfg.moe.shared_experts:
        p["shared"] = L.mlp_specs(rules)
    return p


def router_topk(logits: jax.Array, k: int, norm_probs: bool):
    """logits: (..., E) -> (weights (..., k), indices (..., k))."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    w, idx = jax.lax.top_k(probs, k)
    if norm_probs:  # mixtral/deepseek renormalize the selected gates
        w = w / jnp.clip(w.sum(-1, keepdims=True), 1e-9)
    return w, idx


def moe_apply(params, x, cfg):
    """Dense-dispatch formulation: one-hot combine weights -> einsum over
    experts. The expert dim shards over the 'model' axis (EP); XLA turns
    the dispatch/combine contractions into all-to-alls on that axis —
    the §3 collective in fused form. O(T·E) routing memory, exact top-k
    (no capacity drops) — the reference semantics for the EP fast path.
    """
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    logits = xt @ params["router"]
    w, idx = router_topk(logits, m.top_k, m.norm_topk_probs)
    # combine[t, e] = sum_k w[t,k] * [idx[t,k] == e]
    combine = jnp.zeros((T, m.num_experts), jnp.float32)
    onehot = jax.nn.one_hot(idx, m.num_experts, dtype=jnp.float32)  # (T, k, E)
    combine = (onehot * w[..., None]).sum(axis=1)  # (T, E)
    # dispatch: every expert sees all tokens weighted by membership.
    # grouped einsum keeps peak memory at (E, T, ff) tiles XLA can shard.
    h_in = jnp.einsum("td,edf->etf", xt, params["w_in"])
    h_gate = jnp.einsum("td,edf->etf", xt, params["w_gate"])
    h = jax.nn.silu(h_gate) * h_in
    y_e = jnp.einsum("etf,efd->etd", h, params["w_out"])  # (E, T, d)
    y = jnp.einsum("etd,te->td", y_e.astype(jnp.float32), combine)
    y = y.astype(x.dtype)
    if "shared" in params:
        y = y + L.mlp_apply(params["shared"], xt)
    aux = load_balance_loss(logits, idx, m.num_experts, m.top_k)
    return y.reshape(B, S, d), aux


def moe_apply_sparse(params, x, cfg, capacity_factor: float | None = None):
    """Capacity-bounded sparse dispatch (production path): tokens gather
    into per-expert buffers of size C = cf·T·k/E; overflow drops (standard
    Switch/Mixtral-style). This is the formulation whose dispatch IS an
    all-to-all over the EP axis — bound to dragonfly_all_to_all in the
    shard_map training variant (train/step_dragonfly.py)."""
    from repro.dist import sharding as SH

    m = cfg.moe
    if capacity_factor is None:
        capacity_factor = m.capacity_factor
    B, S, d = x.shape
    T = B * S
    E = m.num_experts
    C = max(1, int(capacity_factor * T * m.top_k / E))
    C = -(-C // 16) * 16  # round up so the capacity dim shards evenly
    # expert-buffer sharding: EP puts experts on the tensor axis and
    # capacity on the batch axes; the TP fallback (E ∤ axis) shards the
    # hidden dims instead. Constraints are no-ops outside a launcher.
    act = SH.active()
    ep = act is not None and act[0].expert_parallel(E)
    t_ax = act[0].tensor_axis if act else None
    b_ax = act[0].batch_axes if act else None
    xt = x.reshape(T, d)
    logits = xt @ params["router"]
    w, idx = router_topk(logits, m.top_k, m.norm_topk_probs)  # (T,k)
    flat_e = idx.reshape(-1)  # (T*k,)
    # position of each (t, k) within its expert's buffer
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (T*k, E)
    pos_in_e = (jnp.cumsum(onehot, axis=0) - 1) * onehot  # (T*k, E)
    slot = pos_in_e.sum(-1)  # (T*k,)
    keep = slot < C
    buf = jnp.zeros((E, C, d), xt.dtype)
    src_tok = jnp.repeat(jnp.arange(T), m.top_k)
    buf = buf.at[flat_e, jnp.clip(slot, 0, C - 1)].add(
        jnp.where(keep[:, None], xt[src_tok], 0)
    )
    if act:  # the §3 all-to-all boundary: tokens -> expert-major buffers
        buf = SH.constrain(buf, t_ax if ep else None, b_ax, None)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])) * jnp.einsum(
        "ecd,edf->ecf", buf, params["w_in"]
    )
    if act:
        h = SH.constrain(h, t_ax if ep else None, b_ax, None if ep else t_ax)
    y_buf = jnp.einsum("ecf,efd->ecd", h, params["w_out"])  # (E, C, d)
    if act:  # combine all-to-all boundary
        y_buf = SH.constrain(y_buf, t_ax if ep else None, b_ax, None)
    y = jnp.zeros((T, d), jnp.float32)
    gathered = y_buf[flat_e, jnp.clip(slot, 0, C - 1)]
    y = y.at[src_tok].add(
        jnp.where(keep[:, None], gathered.astype(jnp.float32) * w.reshape(-1)[:, None], 0)
    )
    y = y.astype(x.dtype)
    if "shared" in params:
        y = y + L.mlp_apply(params["shared"], xt)
    aux = load_balance_loss(logits, idx, E, m.top_k)
    return y.reshape(B, S, d), aux


def moe_apply_ep(params, x, cfg):
    """Expert-parallel MoE via shard_map: the dispatch/combine are EXPLICIT
    all-to-alls over the tensor axis — the §3 collective boundary. Used
    when the active rules report E % model_axis == 0 (deepseek: 256/16,
    jamba: 16/16); each model shard owns E/n_model experts outright and
    token buffers travel (E, C_loc, d) -> (E_loc, n_model·C_loc, d).

    The ``--collectives dragonfly`` variant swaps lax.all_to_all for the
    doubly-parallel ppermute schedule: the §3 Schedule IR emitted by
    core/alltoall.py, lowered to a CollectiveProgram by
    runtime/lowering.py, replayed by the jax_ppermute backend (via
    dist/collectives.py) — same payload, K·M²/s visible rounds (see
    EXPERIMENTS.md §Perf). ``dragonfly_overlap`` replays the same program
    in start_step order so independent ppermutes overlap.
    ``dragonfly_overlap_fused`` goes further: dispatch, expert FFN and
    combine become ONE fused round trip (``dragonfly_all_to_all_compute``
    on the §3 pipelined schedule) where each wave's ppermutes issue while
    the previous wave's arrivals run through the experts. ``auto`` asks
    the price-driven autotuner (runtime/autotune.py) which of the four
    wins at this site's key — D3 view of the axis, per-destination buffer
    bytes, the expert FFN's ``moe_compute_us`` — and runs that; the
    decision happens here in Python, BEFORE shard_map, so the traced
    collective is whichever fixed path the tuner picked.
    """
    from repro.dist import sharding as SH
    from repro.runtime import compat
    from jax.sharding import PartitionSpec as PS

    rules, mesh = SH.active()
    m = cfg.moe
    E = m.num_experts
    t_ax = rules.tensor_axis
    b_ax = rules.batch_axes
    B, S, d = x.shape
    n_model = rules.model_axis_size
    E_loc = E // n_model
    # tokens shard over BOTH the batch axes and the tensor axis (sequence-
    # parallel dispatch): each chip routes its own T/(data·model) slice —
    # without this the model-axis replicas all dispatch identical buffers
    # and the expert compute is n_model-times redundant.
    b_axes = b_ax if isinstance(b_ax, tuple) else (b_ax,)
    tok_axes = (*b_axes, t_ax)

    moe_coll = rules.moe_collectives
    if moe_coll == "auto":
        # resolve the strategy OUTSIDE shard_map (tuner runs real closures;
        # it cannot measure inside a trace). Key: the dispatch/combine
        # all-to-all over the model axis' D3 view at this config's
        # per-destination buffer size, C_loc from the capacity bound.
        from repro.runtime import autotune

        t_loc = max(1, (B * S) // max(1, rules.data_axis_size * n_model))
        c_loc = max(8, int(m.capacity_factor * t_loc * m.top_k / E))
        c_loc = -(-c_loc // 8) * 8
        chunk = E_loc * c_loc * d * jnp.dtype(x.dtype).itemsize
        dec = autotune.get_autotuner().decide(
            "alltoall", autotune.layout_for(n_model), chunk,
            dtype=str(x.dtype), site="shard",
            compute_us=autotune.moe_compute_us(
                E_loc, c_loc, n_model, d, m.d_ff_expert))
        moe_coll = {"xla": "xla", "loop": "dragonfly",
                    "overlap": "dragonfly_overlap",
                    "overlap_fused": "dragonfly_overlap_fused"}[dec.strategy]

    def local_fn(xt, w_in, w_gate, w_out, router):
        T_loc = xt.shape[0]
        logits = xt @ router
        w, idx = router_topk(logits, m.top_k, m.norm_topk_probs)
        C_loc = max(8, int(m.capacity_factor * T_loc * m.top_k / E))
        C_loc = -(-C_loc // 8) * 8
        flat_e = idx.reshape(-1)
        onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
        slot = ((jnp.cumsum(onehot, 0) - 1) * onehot).sum(-1)
        keep = slot < C_loc
        src = jnp.repeat(jnp.arange(T_loc), m.top_k)
        buf = jnp.zeros((E, C_loc, d), xt.dtype)
        buf = buf.at[flat_e, jnp.clip(slot, 0, C_loc - 1)].add(
            jnp.where(keep[:, None], xt[src], 0)
        )
        # ---- dispatch all-to-all (paper §3 boundary). "dragonfly" uses
        # the doubly-parallel round schedule (K·M²/s conflict-free rounds
        # of ppermutes on the D3 view of the axis) via the program
        # executor; "dragonfly_overlap" the same program replayed in
        # start_step order (cross-round ppermute overlap, hiding round
        # latency behind per-round compute); "dragonfly_overlap_fused"
        # the whole dispatch -> expert FFN -> combine round trip as ONE
        # Schedules 1-3 pipeline (expert compute for arrived capacity
        # chunks overlaps the next wave's ppermutes); "xla" the fused op.
        buf = buf.reshape(n_model, E_loc, C_loc, d)
        if moe_coll == "dragonfly_overlap_fused":
            from repro.dist.collectives import dragonfly_all_to_all_compute
            from repro.dist.mesh import dragonfly_layout
            from repro.runtime.backends.jax_ppermute import JaxPpermuteBackend

            def expert_chunk(chunks):
                # one wave's arrivals, (V, E_loc, C_loc, d): the same
                # silu-gated FFN as the sequential path, batched over the
                # wave — bit-exact vs the big-batch contraction
                h = jax.nn.silu(
                    jnp.einsum("...ecd,edf->...ecf", chunks, w_gate)
                ) * jnp.einsum("...ecd,edf->...ecf", chunks, w_in)
                return jnp.einsum("...ecf,efd->...ecd", h, w_out)

            back = dragonfly_all_to_all_compute(
                buf, t_ax, dragonfly_layout(n_model), expert_chunk,
                backend=JaxPpermuteBackend(overlap_fused=True),
            ).reshape(E, C_loc, d)
        else:
            if moe_coll.startswith("dragonfly"):
                from repro.dist.collectives import dragonfly_all_to_all
                from repro.dist.mesh import dragonfly_layout
                from repro.runtime.backends.jax_ppermute import JaxPpermuteBackend

                layout = dragonfly_layout(n_model)
                a2a_backend = JaxPpermuteBackend(
                    overlap=moe_coll == "dragonfly_overlap"
                )
                recv = dragonfly_all_to_all(buf, t_ax, layout,
                                            backend=a2a_backend)
            else:
                recv = jax.lax.all_to_all(buf, t_ax, split_axis=0,
                                          concat_axis=0)
            recv = recv.transpose(1, 0, 2, 3).reshape(E_loc, n_model * C_loc, d)
            h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", recv, w_gate)) * jnp.einsum(
                "ecd,edf->ecf", recv, w_in
            )
            y = jnp.einsum("ecf,efd->ecd", h, w_out)
            # ---- combine all-to-all
            y = y.reshape(E_loc, n_model, C_loc, d).transpose(1, 0, 2, 3)
            if moe_coll.startswith("dragonfly"):
                back = dragonfly_all_to_all(y, t_ax, layout,
                                            backend=a2a_backend)
            else:
                back = jax.lax.all_to_all(y, t_ax, split_axis=0, concat_axis=0)
            back = back.reshape(E, C_loc, d)
        out = jnp.zeros((T_loc, d), xt.dtype)
        g = back[flat_e, jnp.clip(slot, 0, C_loc - 1)]
        out = out.at[src].add(
            jnp.where(keep[:, None], g * w.reshape(-1)[:, None].astype(g.dtype), 0)
        )
        aux = jax.lax.pmean(load_balance_loss(logits, idx, E, m.top_k), tok_axes)
        return out.astype(xt.dtype), aux

    xt = x.reshape(B * S, d)
    out, aux = compat.shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(
            PS(tok_axes, None),
            PS(t_ax, None, None),
            PS(t_ax, None, None),
            PS(t_ax, None, None),
            PS(None, None),
        ),
        out_specs=(PS(tok_axes, None), PS()),
        check_vma=False,
    )(xt, params["w_in"], params["w_gate"], params["w_out"], params["router"])
    y = out
    if "shared" in params:
        y = y + L.mlp_apply(params["shared"], xt)
    return y.reshape(B, S, d), aux


def moe_apply_tp(params, x, cfg):
    """TP-experts shard_map path (E ∤ tensor axis, e.g. mixtral's 8 on a
    16-wide axis): experts replicated, their FFN dims sharded over the
    tensor axis; dispatch is LOCAL (per data shard), the only collective
    is the per-layer psum of the d-dim partial outputs — no token
    all-gather (the pjit sparse path's scatter pulled the full global
    token set to every chip; see EXPERIMENTS.md §Perf cell A, iter 1)."""
    from repro.dist import sharding as SH
    from repro.runtime import compat
    from jax.sharding import PartitionSpec as PS

    rules, mesh = SH.active()
    m = cfg.moe
    E = m.num_experts
    t_ax = rules.tensor_axis
    b_ax = rules.batch_axes
    B, S, d = x.shape
    b_axes = b_ax if isinstance(b_ax, tuple) else (b_ax,)

    def local_fn(xt, w_in, w_gate, w_out, router):
        T_loc = xt.shape[0]
        logits = xt @ router
        w, idx = router_topk(logits, m.top_k, m.norm_topk_probs)
        C_loc = max(8, int(m.capacity_factor * T_loc * m.top_k / E))
        C_loc = -(-C_loc // 8) * 8
        flat_e = idx.reshape(-1)
        onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
        slot = ((jnp.cumsum(onehot, 0) - 1) * onehot).sum(-1)
        keep = slot < C_loc
        src = jnp.repeat(jnp.arange(T_loc), m.top_k)
        buf = jnp.zeros((E, C_loc, d), xt.dtype)
        buf = buf.at[flat_e, jnp.clip(slot, 0, C_loc - 1)].add(
            jnp.where(keep[:, None], xt[src], 0)
        )
        # w_in/w_gate local: (E, d, f/n); w_out local: (E, f/n, d)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_gate)) * jnp.einsum(
            "ecd,edf->ecf", buf, w_in
        )
        y_part = jnp.einsum("ecf,efd->ecd", h, w_out)  # partial over ff shards
        y_buf = jax.lax.psum(y_part.astype(xt.dtype), t_ax)
        out = jnp.zeros((T_loc, d), xt.dtype)
        g = y_buf[flat_e, jnp.clip(slot, 0, C_loc - 1)].astype(xt.dtype)
        out = out.at[src].add(
            jnp.where(keep[:, None], g * w.reshape(-1)[:, None].astype(g.dtype), 0)
        )
        aux = jax.lax.pmean(load_balance_loss(logits, idx, E, m.top_k), b_axes)
        return out.astype(xt.dtype), aux

    xt = x.reshape(B * S, d)
    out, aux = compat.shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(
            PS(b_ax, None),
            PS(None, None, t_ax),
            PS(None, None, t_ax),
            PS(None, t_ax, None),
            PS(None, None),
        ),
        out_specs=(PS(b_ax, None), PS()),
        check_vma=False,
    )(xt, params["w_in"], params["w_gate"], params["w_out"], params["router"])
    y = out
    if "shared" in params:
        y = y + L.mlp_apply(params["shared"], xt)
    return y.reshape(B, S, d), aux


def moe_apply_auto(params, x, cfg):
    """Pick the shard_map path matching the expert layout when a launcher
    registered rules; otherwise the sparse pjit path (single device)."""
    from repro.dist import sharding as SH

    act = SH.active()
    if act is not None:
        rules = act[0]
        T = x.shape[0] * x.shape[1]
        if rules.expert_parallel(cfg.moe.num_experts):
            if T % (rules.model_axis_size * rules.data_axis_size) == 0:
                return moe_apply_ep(params, x, cfg)
        elif T % rules.data_axis_size == 0:
            return moe_apply_tp(params, x, cfg)
    return moe_apply_sparse(params, x, cfg)


def load_balance_loss(logits, idx, E, k):
    """Switch-style aux loss: E · Σ_e f_e · p_e."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    p_mean = probs.mean(axis=0)
    f = jax.nn.one_hot(idx, E, dtype=jnp.float32).sum(axis=(0, 1)) / (idx.shape[0] * k)
    return E * jnp.sum(f * p_mean)


# ---------------------------------------------------------------------------
# Guest-embedded dispatch: the whole-array §3 form for multi-tenant serving.
#
# A tenant admitted as a D3(J,L) guest on a D3(K,M) host routes its expert
# dispatch+combine through a PROGRAM REPLAY instead of a shard_map
# collective: ``moe_guest_dispatch`` packs the batch's capacity buffers
# into an (n_guest, n_guest, E_loc, C, d) §3 dispatch array (all tokens
# sourced at guest device 0, expert shards spread over all guest devices),
# a backend ``run_alltoall_compute`` round trip computes each chunk's
# expert FFN AT its destination device (``guest_expert_ffn``), and
# ``moe_guest_combine`` gathers the returned buffers back per token. The
# routing math — top-k, running capacity slots, overflow drops — is the
# ``moe_apply_sparse`` formulation verbatim, in NumPy, because it runs
# host-side AROUND the replay (the replay itself carries N tenants at once
# through one combined host program; see serve/fleet.py).
# ---------------------------------------------------------------------------


def guest_capacity(m, T: int) -> int:
    """Per-expert capacity for T routed tokens — the ``moe_apply_sparse``
    bound (cf·T·k/E, rounded up to a multiple of 16)."""
    C = max(1, int(m.capacity_factor * T * m.top_k / m.num_experts))
    return -(-C // 16) * 16


def _np_softmax(v: np.ndarray) -> np.ndarray:
    v = v - v.max(axis=-1, keepdims=True)
    e = np.exp(v)
    return e / e.sum(axis=-1, keepdims=True)


def _np_silu(v: np.ndarray) -> np.ndarray:
    # x·sigmoid(x) via tanh — stable for both signs, no exp overflow
    return v * (0.5 * (1.0 + np.tanh(0.5 * v)))


@dataclasses.dataclass
class GuestDispatchState:
    """Everything ``moe_guest_combine`` needs to invert a dispatch: the
    router weights and capacity-slot assignment of each (token, k) pair,
    plus the shapes to unflatten back to."""

    w: np.ndarray        # (T, top_k) router weights
    flat_e: np.ndarray   # (T·top_k,) expert index per assignment
    slot: np.ndarray     # (T·top_k,) capacity slot within the expert buffer
    keep: np.ndarray     # (T·top_k,) False = dropped by the capacity bound
    src: np.ndarray      # (T·top_k,) source token index
    shape: tuple         # (B, S, d) of the dispatched activations
    C: int
    E_loc: int


def moe_guest_dispatch(params, x, cfg, n_guest: int):
    """Route (B, S, d) activations into the whole-array guest dispatch form.

    Returns ``(X, state)`` where X is (n_guest, n_guest, E_loc, C, d) with
    X[0, j] = the capacity chunks bound for guest device j's experts (all
    tokens live on guest source device 0 — a decode batch is one data
    shard) and zero elsewhere. A ``run_alltoall_compute`` round trip then
    yields back[0, j] = FFN_j(X[0, j]); feed that to ``moe_guest_combine``.
    Requires E % n_guest == 0 (each guest device owns E/n_guest experts).
    """
    m = cfg.moe
    x = np.asarray(x, np.float32)
    B, S, d = x.shape
    T = B * S
    E = m.num_experts
    if E % n_guest:
        raise ValueError(
            f"E={E} experts do not shard over {n_guest} guest devices"
        )
    E_loc = E // n_guest
    C = guest_capacity(m, T)
    xt = x.reshape(T, d)
    logits = xt @ np.asarray(params["router"], np.float32)
    probs = _np_softmax(logits)
    # stable argsort on -probs = first-index tie-break, same as lax.top_k
    idx = np.argsort(-probs, axis=-1, kind="stable")[:, : m.top_k]
    w = np.take_along_axis(probs, idx, axis=-1)
    if m.norm_topk_probs:
        w = w / np.clip(w.sum(-1, keepdims=True), 1e-9, None)
    flat_e = idx.reshape(-1)
    onehot = np.eye(E, dtype=np.int64)[flat_e]
    slot = ((np.cumsum(onehot, axis=0) - 1) * onehot).sum(-1)
    keep = slot < C
    src = np.repeat(np.arange(T), m.top_k)
    buf = np.zeros((E, C, d), np.float32)
    # (expert, slot) pairs are unique by construction (slot is the running
    # per-expert count), so this is a pure scatter, not an accumulation
    buf[flat_e[keep], slot[keep]] = xt[src[keep]]
    X = np.zeros((n_guest, n_guest, E_loc, C, d), np.float32)
    X[0] = buf.reshape(n_guest, E_loc, C, d)
    state = GuestDispatchState(
        w=w, flat_e=flat_e, slot=slot, keep=keep, src=src,
        shape=(B, S, d), C=C, E_loc=E_loc,
    )
    return X, state


def moe_guest_combine(back, state: GuestDispatchState, params, x):
    """Invert ``moe_guest_dispatch``: gather each token's expert outputs
    from the returned (n_guest, n_guest, E_loc, C, d) round-trip array
    (rows back[0, :]), weight by the router gates, add shared experts.
    Returns (B, S, d) float32."""
    B, S, d = state.shape
    T = B * S
    y_buf = np.asarray(back, np.float32)[0].reshape(-1, state.C, d)  # (E, C, d)
    y = np.zeros((T, d), np.float32)
    g = y_buf[state.flat_e[state.keep], state.slot[state.keep]]
    np.add.at(y, state.src[state.keep],
              g * state.w.reshape(-1)[state.keep, None])
    if "shared" in params:
        xt = np.asarray(x, np.float32).reshape(T, d)
        y = y + np.asarray(
            L.mlp_apply(params["shared"], jnp.asarray(xt)), np.float32
        )
    return y.reshape(B, S, d)


def guest_expert_shards(params, n_guest: int):
    """Per-guest-device expert weight shards as NumPy views:
    (w_in, w_gate) each (n_guest, E_loc, d, f) and w_out (n_guest, E_loc,
    f, d) — row g is what guest device g's ``guest_expert_ffn`` closes
    over."""
    E = params["w_in"].shape[0]
    if E % n_guest:
        raise ValueError(f"E={E} does not shard over {n_guest} guest devices")

    def shard(a):
        a = np.asarray(a, np.float32)
        return a.reshape(n_guest, E // n_guest, *a.shape[1:])

    return shard(params["w_in"]), shard(params["w_gate"]), shard(params["w_out"])


def guest_expert_ffn_np(chunks, w_in, w_gate, w_out):
    """One device's silu-gated expert FFN over arriving capacity chunks —
    the NumPy reference-replay compute. ``chunks`` (..., E_loc, C, d) with
    this device's (E_loc, d, f) / (E_loc, f, d) shards; batched over any
    leading dims (a replay hands the whole arrival stack at once)."""
    h = _np_silu(np.einsum("...ecd,edf->...ecf", chunks, w_gate)) * np.einsum(
        "...ecd,edf->...ecf", chunks, w_in
    )
    return np.einsum("...ecf,efd->...ecd", h, w_out)


def guest_expert_ffn(chunks, w_in, w_gate, w_out):
    """``guest_expert_ffn_np`` in jnp — the stable compute callable for the
    JAX backend's ``run_alltoall_compute(weights=...)`` path (module-level
    so the compiled shard_map closure caches across calls)."""
    h = jax.nn.silu(jnp.einsum("...ecd,edf->...ecf", chunks, w_gate)) * jnp.einsum(
        "...ecd,edf->...ecf", chunks, w_in
    )
    return jnp.einsum("...ecf,efd->...ecd", h, w_out)
