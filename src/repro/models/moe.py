"""Mixture-of-Experts: top-k router, shared experts, dense-dispatch einsum
formulation (shardable over the expert axis by pjit), plus the shard_map
expert-parallel path that uses the paper's doubly-parallel all-to-all.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L


def moe_init(key, cfg, dtype):
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    s_in = d ** -0.5
    s_out = m.d_ff_expert ** -0.5
    p = {
        "router": L.truncated_normal(ks[0], (d, m.num_experts), dtype, s_in),
        "w_in": L.truncated_normal(ks[1], (m.num_experts, d, m.d_ff_expert), dtype, s_in),
        "w_gate": L.truncated_normal(ks[2], (m.num_experts, d, m.d_ff_expert), dtype, s_in),
        "w_out": L.truncated_normal(ks[3], (m.num_experts, m.d_ff_expert, d), dtype, s_out),
    }
    if m.shared_experts:
        p["shared"] = L.mlp_init(
            jax.random.fold_in(key, 7), d, m.d_ff_expert * m.shared_experts, dtype
        )
    return p


def moe_specs(cfg, rules):
    E = cfg.moe.num_experts
    p = {
        "router": P(None, None),
        "w_in": rules.expert((E, 0, 0), ff_dim=2, n_experts=E),
        "w_gate": rules.expert((E, 0, 0), ff_dim=2, n_experts=E),
        "w_out": rules.expert((E, 0, 0), ff_dim=1, n_experts=E),
    }
    if cfg.moe.shared_experts:
        p["shared"] = L.mlp_specs(rules)
    return p


def router_topk(logits: jax.Array, k: int, norm_probs: bool):
    """logits: (..., E) -> (weights (..., k), indices (..., k))."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    w, idx = jax.lax.top_k(probs, k)
    if norm_probs:  # mixtral/deepseek renormalize the selected gates
        w = w / jnp.clip(w.sum(-1, keepdims=True), 1e-9)
    return w, idx


def moe_apply(params, x, cfg):
    """Dense-dispatch formulation: one-hot combine weights -> einsum over
    experts. The expert dim shards over the 'model' axis (EP); XLA turns
    the dispatch/combine contractions into all-to-alls on that axis —
    the §3 collective in fused form. O(T·E) routing memory, exact top-k
    (no capacity drops) — the reference semantics for the EP fast path.
    """
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    logits = xt @ params["router"]
    w, idx = router_topk(logits, m.top_k, m.norm_topk_probs)
    # combine[t, e] = sum_k w[t,k] * [idx[t,k] == e]
    combine = jnp.zeros((T, m.num_experts), jnp.float32)
    onehot = jax.nn.one_hot(idx, m.num_experts, dtype=jnp.float32)  # (T, k, E)
    combine = (onehot * w[..., None]).sum(axis=1)  # (T, E)
    # dispatch: every expert sees all tokens weighted by membership.
    # grouped einsum keeps peak memory at (E, T, ff) tiles XLA can shard.
    h_in = jnp.einsum("td,edf->etf", xt, params["w_in"])
    h_gate = jnp.einsum("td,edf->etf", xt, params["w_gate"])
    h = jax.nn.silu(h_gate) * h_in
    y_e = jnp.einsum("etf,efd->etd", h, params["w_out"])  # (E, T, d)
    y = jnp.einsum("etd,te->td", y_e.astype(jnp.float32), combine)
    y = y.astype(x.dtype)
    if "shared" in params:
        y = y + L.mlp_apply(params["shared"], xt)
    aux = load_balance_loss(logits, idx, m.num_experts, m.top_k)
    return y.reshape(B, S, d), aux


def moe_apply_sparse(params, x, cfg, capacity_factor: float | None = None):
    """Capacity-bounded sparse dispatch (production path): tokens gather
    into per-expert buffers of size C = cf·T·k/E; overflow drops (standard
    Switch/Mixtral-style). This is the formulation whose dispatch IS an
    all-to-all over the EP axis — bound to dragonfly_all_to_all in the
    shard_map training variant (train/step_dragonfly.py)."""
    from repro.dist import sharding as SH

    m = cfg.moe
    if capacity_factor is None:
        capacity_factor = m.capacity_factor
    B, S, d = x.shape
    T = B * S
    E = m.num_experts
    C = max(1, int(capacity_factor * T * m.top_k / E))
    C = -(-C // 16) * 16  # round up so the capacity dim shards evenly
    # expert-buffer sharding: EP puts experts on the tensor axis and
    # capacity on the batch axes; the TP fallback (E ∤ axis) shards the
    # hidden dims instead. Constraints are no-ops outside a launcher.
    act = SH.active()
    ep = act is not None and act[0].expert_parallel(E)
    t_ax = act[0].tensor_axis if act else None
    b_ax = act[0].batch_axes if act else None
    xt = x.reshape(T, d)
    logits = xt @ params["router"]
    w, idx = router_topk(logits, m.top_k, m.norm_topk_probs)  # (T,k)
    flat_e = idx.reshape(-1)  # (T*k,)
    # position of each (t, k) within its expert's buffer
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (T*k, E)
    pos_in_e = (jnp.cumsum(onehot, axis=0) - 1) * onehot  # (T*k, E)
    slot = pos_in_e.sum(-1)  # (T*k,)
    keep = slot < C
    buf = jnp.zeros((E, C, d), xt.dtype)
    src_tok = jnp.repeat(jnp.arange(T), m.top_k)
    buf = buf.at[flat_e, jnp.clip(slot, 0, C - 1)].add(
        jnp.where(keep[:, None], xt[src_tok], 0)
    )
    if act:  # the §3 all-to-all boundary: tokens -> expert-major buffers
        buf = SH.constrain(buf, t_ax if ep else None, b_ax, None)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])) * jnp.einsum(
        "ecd,edf->ecf", buf, params["w_in"]
    )
    if act:
        h = SH.constrain(h, t_ax if ep else None, b_ax, None if ep else t_ax)
    y_buf = jnp.einsum("ecf,efd->ecd", h, params["w_out"])  # (E, C, d)
    if act:  # combine all-to-all boundary
        y_buf = SH.constrain(y_buf, t_ax if ep else None, b_ax, None)
    y = jnp.zeros((T, d), jnp.float32)
    gathered = y_buf[flat_e, jnp.clip(slot, 0, C - 1)]
    y = y.at[src_tok].add(
        jnp.where(keep[:, None], gathered.astype(jnp.float32) * w.reshape(-1)[:, None], 0)
    )
    y = y.astype(x.dtype)
    if "shared" in params:
        y = y + L.mlp_apply(params["shared"], xt)
    aux = load_balance_loss(logits, idx, E, m.top_k)
    return y.reshape(B, S, d), aux


def moe_apply_ep(params, x, cfg):
    """Expert-parallel MoE via shard_map: the dispatch/combine are EXPLICIT
    all-to-alls over the tensor axis — the §3 collective boundary. Used
    when the active rules report E % model_axis == 0 (deepseek: 256/16,
    jamba: 16/16); each model shard owns E/n_model experts outright and
    token buffers travel (E, C_loc, d) -> (E_loc, n_model·C_loc, d).

    The ``--collectives dragonfly`` variant swaps lax.all_to_all for the
    doubly-parallel ppermute schedule: the §3 Schedule IR emitted by
    core/alltoall.py, lowered to a CollectiveProgram by
    runtime/lowering.py, replayed by the jax_ppermute backend (via
    dist/collectives.py) — same payload, K·M²/s visible rounds (see
    EXPERIMENTS.md §Perf). ``dragonfly_overlap`` replays the same program
    in start_step order so independent ppermutes overlap.
    ``dragonfly_overlap_fused`` goes further: dispatch, expert FFN and
    combine become ONE fused round trip (``dragonfly_all_to_all_compute``
    on the §3 pipelined schedule) where each wave's ppermutes issue while
    the previous wave's arrivals run through the experts. ``auto`` asks
    the price-driven autotuner (runtime/autotune.py) which of the four
    wins at this site's key — D3 view of the axis, per-destination buffer
    bytes, the expert FFN's ``moe_compute_us`` — and runs that; the
    decision happens here in Python, BEFORE shard_map, so the traced
    collective is whichever fixed path the tuner picked.
    """
    from repro.dist import sharding as SH
    from repro.runtime import compat
    from jax.sharding import PartitionSpec as PS

    rules, mesh = SH.active()
    m = cfg.moe
    E = m.num_experts
    t_ax = rules.tensor_axis
    b_ax = rules.batch_axes
    B, S, d = x.shape
    n_model = rules.model_axis_size
    E_loc = E // n_model
    # tokens shard over BOTH the batch axes and the tensor axis (sequence-
    # parallel dispatch): each chip routes its own T/(data·model) slice —
    # without this the model-axis replicas all dispatch identical buffers
    # and the expert compute is n_model-times redundant.
    b_axes = b_ax if isinstance(b_ax, tuple) else (b_ax,)
    tok_axes = (*b_axes, t_ax)

    moe_coll = rules.moe_collectives
    if moe_coll == "auto":
        # resolve the strategy OUTSIDE shard_map (tuner runs real closures;
        # it cannot measure inside a trace). Key: the dispatch/combine
        # all-to-all over the model axis' D3 view at this config's
        # per-destination buffer size, C_loc from the capacity bound.
        from repro.runtime import autotune

        t_loc = max(1, (B * S) // max(1, rules.data_axis_size * n_model))
        c_loc = max(8, int(m.capacity_factor * t_loc * m.top_k / E))
        c_loc = -(-c_loc // 8) * 8
        chunk = E_loc * c_loc * d * jnp.dtype(x.dtype).itemsize
        dec = autotune.get_autotuner().decide(
            "alltoall", autotune.layout_for(n_model), chunk,
            dtype=str(x.dtype), site="shard",
            compute_us=autotune.moe_compute_us(
                E_loc, c_loc, n_model, d, m.d_ff_expert))
        moe_coll = {"xla": "xla", "loop": "dragonfly",
                    "overlap": "dragonfly_overlap",
                    "overlap_fused": "dragonfly_overlap_fused"}[dec.strategy]

    def local_fn(xt, w_in, w_gate, w_out, router):
        T_loc = xt.shape[0]
        logits = xt @ router
        w, idx = router_topk(logits, m.top_k, m.norm_topk_probs)
        C_loc = max(8, int(m.capacity_factor * T_loc * m.top_k / E))
        C_loc = -(-C_loc // 8) * 8
        flat_e = idx.reshape(-1)
        onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
        slot = ((jnp.cumsum(onehot, 0) - 1) * onehot).sum(-1)
        keep = slot < C_loc
        src = jnp.repeat(jnp.arange(T_loc), m.top_k)
        buf = jnp.zeros((E, C_loc, d), xt.dtype)
        buf = buf.at[flat_e, jnp.clip(slot, 0, C_loc - 1)].add(
            jnp.where(keep[:, None], xt[src], 0)
        )
        # ---- dispatch all-to-all (paper §3 boundary). "dragonfly" uses
        # the doubly-parallel round schedule (K·M²/s conflict-free rounds
        # of ppermutes on the D3 view of the axis) via the program
        # executor; "dragonfly_overlap" the same program replayed in
        # start_step order (cross-round ppermute overlap, hiding round
        # latency behind per-round compute); "dragonfly_overlap_fused"
        # the whole dispatch -> expert FFN -> combine round trip as ONE
        # Schedules 1-3 pipeline (expert compute for arrived capacity
        # chunks overlaps the next wave's ppermutes); "xla" the fused op.
        buf = buf.reshape(n_model, E_loc, C_loc, d)
        if moe_coll == "dragonfly_overlap_fused":
            from repro.dist.collectives import dragonfly_all_to_all_compute
            from repro.dist.mesh import dragonfly_layout
            from repro.runtime.backends.jax_ppermute import JaxPpermuteBackend

            def expert_chunk(chunks):
                # one wave's arrivals, (V, E_loc, C_loc, d): the same
                # silu-gated FFN as the sequential path, batched over the
                # wave — bit-exact vs the big-batch contraction
                h = jax.nn.silu(
                    jnp.einsum("...ecd,edf->...ecf", chunks, w_gate)
                ) * jnp.einsum("...ecd,edf->...ecf", chunks, w_in)
                return jnp.einsum("...ecf,efd->...ecd", h, w_out)

            back = dragonfly_all_to_all_compute(
                buf, t_ax, dragonfly_layout(n_model), expert_chunk,
                backend=JaxPpermuteBackend(overlap_fused=True),
            ).reshape(E, C_loc, d)
        else:
            if moe_coll.startswith("dragonfly"):
                from repro.dist.collectives import dragonfly_all_to_all
                from repro.dist.mesh import dragonfly_layout
                from repro.runtime.backends.jax_ppermute import JaxPpermuteBackend

                layout = dragonfly_layout(n_model)
                a2a_backend = JaxPpermuteBackend(
                    overlap=moe_coll == "dragonfly_overlap"
                )
                recv = dragonfly_all_to_all(buf, t_ax, layout,
                                            backend=a2a_backend)
            else:
                recv = jax.lax.all_to_all(buf, t_ax, split_axis=0,
                                          concat_axis=0)
            recv = recv.transpose(1, 0, 2, 3).reshape(E_loc, n_model * C_loc, d)
            h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", recv, w_gate)) * jnp.einsum(
                "ecd,edf->ecf", recv, w_in
            )
            y = jnp.einsum("ecf,efd->ecd", h, w_out)
            # ---- combine all-to-all
            y = y.reshape(E_loc, n_model, C_loc, d).transpose(1, 0, 2, 3)
            if moe_coll.startswith("dragonfly"):
                back = dragonfly_all_to_all(y, t_ax, layout,
                                            backend=a2a_backend)
            else:
                back = jax.lax.all_to_all(y, t_ax, split_axis=0, concat_axis=0)
            back = back.reshape(E, C_loc, d)
        out = jnp.zeros((T_loc, d), xt.dtype)
        g = back[flat_e, jnp.clip(slot, 0, C_loc - 1)]
        out = out.at[src].add(
            jnp.where(keep[:, None], g * w.reshape(-1)[:, None].astype(g.dtype), 0)
        )
        aux = jax.lax.pmean(load_balance_loss(logits, idx, E, m.top_k), tok_axes)
        return out.astype(xt.dtype), aux

    xt = x.reshape(B * S, d)
    out, aux = compat.shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(
            PS(tok_axes, None),
            PS(t_ax, None, None),
            PS(t_ax, None, None),
            PS(t_ax, None, None),
            PS(None, None),
        ),
        out_specs=(PS(tok_axes, None), PS()),
        check_vma=False,
    )(xt, params["w_in"], params["w_gate"], params["w_out"], params["router"])
    y = out
    if "shared" in params:
        y = y + L.mlp_apply(params["shared"], xt)
    return y.reshape(B, S, d), aux


def moe_apply_tp(params, x, cfg):
    """TP-experts shard_map path (E ∤ tensor axis, e.g. mixtral's 8 on a
    16-wide axis): experts replicated, their FFN dims sharded over the
    tensor axis; dispatch is LOCAL (per data shard), the only collective
    is the per-layer psum of the d-dim partial outputs — no token
    all-gather (the pjit sparse path's scatter pulled the full global
    token set to every chip; see EXPERIMENTS.md §Perf cell A, iter 1)."""
    from repro.dist import sharding as SH
    from repro.runtime import compat
    from jax.sharding import PartitionSpec as PS

    rules, mesh = SH.active()
    m = cfg.moe
    E = m.num_experts
    t_ax = rules.tensor_axis
    b_ax = rules.batch_axes
    B, S, d = x.shape
    b_axes = b_ax if isinstance(b_ax, tuple) else (b_ax,)

    def local_fn(xt, w_in, w_gate, w_out, router):
        T_loc = xt.shape[0]
        logits = xt @ router
        w, idx = router_topk(logits, m.top_k, m.norm_topk_probs)
        C_loc = max(8, int(m.capacity_factor * T_loc * m.top_k / E))
        C_loc = -(-C_loc // 8) * 8
        flat_e = idx.reshape(-1)
        onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
        slot = ((jnp.cumsum(onehot, 0) - 1) * onehot).sum(-1)
        keep = slot < C_loc
        src = jnp.repeat(jnp.arange(T_loc), m.top_k)
        buf = jnp.zeros((E, C_loc, d), xt.dtype)
        buf = buf.at[flat_e, jnp.clip(slot, 0, C_loc - 1)].add(
            jnp.where(keep[:, None], xt[src], 0)
        )
        # w_in/w_gate local: (E, d, f/n); w_out local: (E, f/n, d)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_gate)) * jnp.einsum(
            "ecd,edf->ecf", buf, w_in
        )
        y_part = jnp.einsum("ecf,efd->ecd", h, w_out)  # partial over ff shards
        y_buf = jax.lax.psum(y_part.astype(xt.dtype), t_ax)
        out = jnp.zeros((T_loc, d), xt.dtype)
        g = y_buf[flat_e, jnp.clip(slot, 0, C_loc - 1)].astype(xt.dtype)
        out = out.at[src].add(
            jnp.where(keep[:, None], g * w.reshape(-1)[:, None].astype(g.dtype), 0)
        )
        aux = jax.lax.pmean(load_balance_loss(logits, idx, E, m.top_k), b_axes)
        return out.astype(xt.dtype), aux

    xt = x.reshape(B * S, d)
    out, aux = compat.shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(
            PS(b_ax, None),
            PS(None, None, t_ax),
            PS(None, None, t_ax),
            PS(None, t_ax, None),
            PS(None, None),
        ),
        out_specs=(PS(b_ax, None), PS()),
        check_vma=False,
    )(xt, params["w_in"], params["w_gate"], params["w_out"], params["router"])
    y = out
    if "shared" in params:
        y = y + L.mlp_apply(params["shared"], xt)
    return y.reshape(B, S, d), aux


def moe_apply_auto(params, x, cfg):
    """Pick the shard_map path matching the expert layout when a launcher
    registered rules; otherwise the sparse pjit path (single device)."""
    from repro.dist import sharding as SH

    act = SH.active()
    if act is not None:
        rules = act[0]
        T = x.shape[0] * x.shape[1]
        if rules.expert_parallel(cfg.moe.num_experts):
            if T % (rules.model_axis_size * rules.data_axis_size) == 0:
                return moe_apply_ep(params, x, cfg)
        elif T % rules.data_axis_size == 0:
            return moe_apply_tp(params, x, cfg)
    return moe_apply_sparse(params, x, cfg)


def load_balance_loss(logits, idx, E, k):
    """Switch-style aux loss: E · Σ_e f_e · p_e."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    p_mean = probs.mean(axis=0)
    f = jax.nn.one_hot(idx, E, dtype=jnp.float32).sum(axis=(0, 1)) / (idx.shape[0] * k)
    return E * jnp.sum(f * p_mean)
