"""Attention variants: GQA (+ sliding window), MLA (DeepSeek latent
attention), M-RoPE (Qwen2-VL). Train path (full sequence, flash kernel)
and decode path (single token, KV/latent cache).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.kernels.flash_attention.ops import gqa_attention
from repro.kernels.flash_attention.ref import attention_ref


# =========================================================== GQA / SWA
def gqa_init(key, cfg, dtype):
    d = cfg.d_model
    hd = cfg.head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    s = d ** -0.5
    return {
        "wq": L.truncated_normal(kq, (d, cfg.n_heads * hd), dtype, s),
        "wk": L.truncated_normal(kk, (d, cfg.n_kv_heads * hd), dtype, s),
        "wv": L.truncated_normal(kv, (d, cfg.n_kv_heads * hd), dtype, s),
        "wo": L.truncated_normal(ko, (cfg.n_heads * hd, d), dtype, (cfg.n_heads * hd) ** -0.5),
    }


def gqa_specs(cfg, rules):
    return {
        "wq": rules.attn_in((0, 0)),
        "wk": rules.attn_in((0, 0)),
        "wv": rules.attn_in((0, 0)),
        "wo": rules.attn_out((0, 0)),
    }


def _project_qkv(params, x, cfg, positions, mrope_positions=None):
    B, S, _ = x.shape
    hd = cfg.head_dim
    q = (x @ params["wq"]).reshape(B, S, cfg.n_heads, hd)
    k = (x @ params["wk"]).reshape(B, S, cfg.n_kv_heads, hd)
    v = (x @ params["wv"]).reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.rope == "mrope":
        q = L.apply_mrope(q, mrope_positions, cfg.mrope_sections, cfg.rope_theta)
        k = L.apply_mrope(k, mrope_positions, cfg.mrope_sections, cfg.rope_theta)
    elif cfg.rope == "rope":
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_train(params, x, cfg, positions, mrope_positions=None, use_kernel=True):
    q, k, v = _project_qkv(params, x, cfg, positions, mrope_positions)
    o = gqa_attention(
        q, k, v, causal=True, window=cfg.sliding_window, use_kernel=use_kernel
    )
    B, S = x.shape[:2]
    return o.reshape(B, S, -1) @ params["wo"]


def gqa_decode(params, x, cache, cfg, position, mrope_positions=None):
    """x: (B, 1, d); cache: {'k','v'}: (B, kv_heads, max_seq, hd); position
    scalar int OR (B,) array (per-slot positions — continuous batching)."""
    B = x.shape[0]
    hd = cfg.head_dim
    pos_b = jnp.broadcast_to(jnp.asarray(position, jnp.int32), (B,))
    q, k, v = _project_qkv(
        params, x, cfg,
        positions=pos_b[:, None],
        mrope_positions=mrope_positions,
    )
    bidx = jnp.arange(B)
    ck = cache["k"].at[bidx, :, pos_b].set(k[:, 0].astype(cache["k"].dtype))
    cv = cache["v"].at[bidx, :, pos_b].set(v[:, 0].astype(cache["v"].dtype))
    # masked single-query attention over the cache (memory-bound: jnp path)
    G = cfg.n_heads // cfg.n_kv_heads
    qh = q.reshape(B, 1, cfg.n_kv_heads, G, hd)
    s = jnp.einsum("bqhgd,bhkd->bhgk", qh.astype(jnp.float32), ck.astype(jnp.float32))
    s = s * (hd ** -0.5)
    kpos = jnp.arange(ck.shape[2])
    valid = kpos[None, :] <= pos_b[:, None]  # (B, S)
    if cfg.sliding_window is not None:
        valid &= kpos[None, :] > pos_b[:, None] - cfg.sliding_window
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bhkd->bhgd", p, cv.astype(jnp.float32))
    o = o.reshape(B, 1, cfg.n_heads * hd).astype(x.dtype)
    return o @ params["wo"], {"k": ck, "v": cv}


def gqa_cache_init(cfg, batch, max_seq, dtype):
    hd = cfg.head_dim
    return {
        "k": jnp.zeros((batch, cfg.n_kv_heads, max_seq, hd), dtype),
        "v": jnp.zeros((batch, cfg.n_kv_heads, max_seq, hd), dtype),
    }


# ================================================================= MLA
# DeepSeek-V3 Multi-head Latent Attention: queries via a low-rank path,
# keys/values reconstructed from a compressed latent c_kv (cached) plus a
# shared rotary key k_rope. Decode caches ONLY (c_kv, k_rope).
def mla_init(key, cfg, dtype):
    d = cfg.d_model
    m = cfg.mla
    ks = jax.random.split(key, 7)
    s = d ** -0.5
    qh = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq_a": L.truncated_normal(ks[0], (d, m.q_lora_rank), dtype, s),
        "wq_b": L.truncated_normal(
            ks[1], (m.q_lora_rank, cfg.n_heads * qh), dtype, m.q_lora_rank ** -0.5
        ),
        "wkv_a": L.truncated_normal(
            ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim), dtype, s
        ),
        "wkv_b": L.truncated_normal(
            ks[3],
            (m.kv_lora_rank, cfg.n_heads * (m.qk_nope_head_dim + m.v_head_dim)),
            dtype,
            m.kv_lora_rank ** -0.5,
        ),
        "wo": L.truncated_normal(
            ks[4], (cfg.n_heads * m.v_head_dim, d), dtype, (cfg.n_heads * m.v_head_dim) ** -0.5
        ),
        "q_norm": L.rmsnorm_init(m.q_lora_rank, dtype),
        "kv_norm": L.rmsnorm_init(m.kv_lora_rank, dtype),
    }


def mla_specs(cfg, rules):
    return {
        "wq_a": P(None, None),
        "wq_b": rules.attn_in((0, 0)),
        "wkv_a": P(None, None),
        "wkv_b": rules.attn_in((0, 0)),
        "wo": rules.attn_out((0, 0)),
        "q_norm": {"scale": P(None)},
        "kv_norm": {"scale": P(None)},
    }


def _mla_qkv(params, x, cfg, positions):
    B, S, _ = x.shape
    m = cfg.mla
    H = cfg.n_heads
    q_lat = L.rmsnorm(params["q_norm"], x @ params["wq_a"])
    q = (q_lat @ params["wq_b"]).reshape(B, S, H, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = L.apply_rope(q_rope, positions, cfg.rope_theta)
    kv_a = x @ params["wkv_a"]
    c_kv, k_rope = jnp.split(kv_a, [m.kv_lora_rank], axis=-1)
    c_kv = L.rmsnorm(params["kv_norm"], c_kv)
    k_rope = L.apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)  # 1 shared head
    return q_nope, q_rope, c_kv, k_rope[:, :, 0, :]


def _mla_expand_kv(params, c_kv, cfg):
    m = cfg.mla
    H = cfg.n_heads
    B, S, _ = c_kv.shape
    kv = (c_kv @ params["wkv_b"]).reshape(B, S, H, m.qk_nope_head_dim + m.v_head_dim)
    k_nope, v = jnp.split(kv, [m.qk_nope_head_dim], axis=-1)
    return k_nope, v


def mla_train(params, x, cfg, positions, use_kernel=True):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(params, x, cfg, positions)
    k_nope, v = _mla_expand_kv(params, c_kv, cfg)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, m.qk_rope_head_dim))],
        axis=-1,
    )
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    # v head dim differs from qk head dim -> pad v for the kernel path
    qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
    if m.v_head_dim == qk_hd and use_kernel:
        o = gqa_attention(q, k, v, causal=True, use_kernel=True)
    else:
        qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, qk_hd)
        kf = k.transpose(0, 2, 1, 3).reshape(B * H, S, qk_hd)
        vf = v.transpose(0, 2, 1, 3).reshape(B * H, S, m.v_head_dim)
        if use_kernel and m.v_head_dim < qk_hd:
            vf = jnp.pad(vf, ((0, 0), (0, 0), (0, qk_hd - m.v_head_dim)))
            o = gqa_attention(
                qf.reshape(B, H, S, qk_hd).transpose(0, 2, 1, 3),
                kf.reshape(B, H, S, qk_hd).transpose(0, 2, 1, 3),
                vf.reshape(B, H, S, qk_hd).transpose(0, 2, 1, 3),
                causal=True, use_kernel=True,
            )[..., : m.v_head_dim].reshape(B, S, H, m.v_head_dim)
        else:
            o = attention_ref(qf, kf, vf, causal=True, scale=scale)
            o = o.reshape(B, H, S, m.v_head_dim).transpose(0, 2, 1, 3)
    return o.reshape(B, S, H * m.v_head_dim) @ params["wo"]


def mla_decode(params, x, cache, cfg, position):
    """Latent cache: {'c_kv': (B, max_seq, r), 'k_rope': (B, max_seq, dr)}."""
    m = cfg.mla
    B = x.shape[0]
    H = cfg.n_heads
    pos_b = jnp.broadcast_to(jnp.asarray(position, jnp.int32), (B,))
    q_nope, q_rope, c_kv_new, k_rope_new = _mla_qkv(params, x, cfg, pos_b[:, None])
    bidx = jnp.arange(B)
    c = cache["c_kv"].at[bidx, pos_b].set(c_kv_new[:, 0].astype(cache["c_kv"].dtype))
    kr = cache["k_rope"].at[bidx, pos_b].set(
        k_rope_new[:, 0].astype(cache["k_rope"].dtype)
    )
    # absorbed-matmul decode: reconstruct k_nope/v from latent (memory-bound)
    k_nope, v = _mla_expand_kv(params, c, cfg)  # (B, S, H, ·)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    s = jnp.einsum("bqhd,bkhd->bhqk", q_nope.astype(jnp.float32), k_nope.astype(jnp.float32))
    s += jnp.einsum("bqhd,bkd->bhqk", q_rope.astype(jnp.float32), kr.astype(jnp.float32))
    s *= scale
    valid = jnp.arange(c.shape[1])[None, :] <= pos_b[:, None]  # (B, S)
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    o = o.reshape(B, 1, H * m.v_head_dim).astype(x.dtype)
    return o @ params["wo"], {"c_kv": c, "k_rope": kr}


def mla_cache_init(cfg, batch, max_seq, dtype):
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, max_seq, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_seq, m.qk_rope_head_dim), dtype),
    }
