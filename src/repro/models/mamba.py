"""Mamba (S6 selective SSM) block — Jamba's recurrent layer.

Training: associative-scan parallel form over the sequence.
Decode: O(1) single-step state update (conv window + SSM state).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L


def mamba_init(key, cfg, dtype):
    d = cfg.d_model
    m = cfg.mamba
    di = m.expand * d
    ks = jax.random.split(key, 8)
    s = d ** -0.5
    p = {
        "w_in": L.truncated_normal(ks[0], (d, 2 * di), dtype, s),       # x and z
        "conv_w": L.truncated_normal(ks[1], (m.d_conv, di), dtype, m.d_conv ** -0.5),
        "conv_b": jnp.zeros((di,), dtype),
        "w_bcdt": L.truncated_normal(ks[2], (di, 2 * m.d_state + m.dt_rank), dtype, di ** -0.5),
        "w_dt": L.truncated_normal(ks[3], (m.dt_rank, di), dtype, m.dt_rank ** -0.5),
        "dt_bias": jnp.asarray(
            jnp.log(jnp.expm1(jnp.exp(jax.random.uniform(
                ks[4], (di,), minval=jnp.log(0.001), maxval=jnp.log(0.1))))),
            dtype,
        ),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, m.d_state + 1, dtype=jnp.float32), (di, 1))).astype(dtype),
        "D": jnp.ones((di,), dtype),
        "w_out": L.truncated_normal(ks[5], (di, d), dtype, di ** -0.5),
    }
    return p


def mamba_specs(cfg, rules):
    t = rules.tensor_axis
    return {
        "w_in": P(None, t),
        "conv_w": P(None, t),
        "conv_b": P(t),
        "w_bcdt": P(t, None),
        "w_dt": P(None, t),
        "dt_bias": P(t),
        "A_log": P(t, None),
        "D": P(t),
        "w_out": P(t, None),
    }


def _ssm_params(params, xc, m):
    """xc: (..., di) conv output -> dt (..., di), B, C (..., d_state)."""
    bcdt = xc @ params["w_bcdt"]
    Bm, Cm, dt_in = jnp.split(bcdt, [m.d_state, 2 * m.d_state], axis=-1)
    dt = jax.nn.softplus(dt_in @ params["w_dt"] + params["dt_bias"])
    return dt, Bm, Cm


def mamba_train(params, x, cfg):
    """x: (B, S, d) -> (B, S, d). Parallel scan over S."""
    m = cfg.mamba
    B, S, d = x.shape
    di = m.expand * d
    xz = x @ params["w_in"]
    xi, z = jnp.split(xz, 2, axis=-1)  # (B, S, di)
    # depthwise causal conv1d
    pad = jnp.pad(xi, ((0, 0), (m.d_conv - 1, 0), (0, 0)))
    xc = sum(
        pad[:, i : i + S, :] * params["conv_w"][i][None, None, :]
        for i in range(m.d_conv)
    ) + params["conv_b"]
    xc = jax.nn.silu(xc)
    dt, Bm, Cm = _ssm_params(params, xc, m)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))  # (di, N)
    # discretize: a_t = exp(dt*A) (B,S,di,N); b_t = dt*B*x
    a = jnp.exp(dt.astype(jnp.float32)[..., None] * A[None, None])
    bx = (dt * xc).astype(jnp.float32)[..., None] * Bm.astype(jnp.float32)[..., None, :]

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, bx), axis=1)  # (B,S,di,N)
    y = (h * Cm.astype(jnp.float32)[..., None, :]).sum(-1)  # (B,S,di)
    y = y + params["D"].astype(jnp.float32) * xc.astype(jnp.float32)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    return y @ params["w_out"]


def mamba_decode(params, x, state, cfg):
    """x: (B, 1, d); state: {'conv': (B, d_conv-1, di), 'ssm': (B, di, N)}."""
    m = cfg.mamba
    B = x.shape[0]
    xz = x[:, 0] @ params["w_in"]
    xi, z = jnp.split(xz, 2, axis=-1)  # (B, di)
    window = jnp.concatenate([state["conv"], xi[:, None]], axis=1)  # (B, d_conv, di)
    xc = (window * params["conv_w"][None]).sum(1) + params["conv_b"]
    xc = jax.nn.silu(xc)
    dt, Bm, Cm = _ssm_params(params, xc, m)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    a = jnp.exp(dt.astype(jnp.float32)[..., None] * A[None])  # (B, di, N)
    bx = (dt * xc).astype(jnp.float32)[..., None] * Bm.astype(jnp.float32)[:, None, :]
    h = a * state["ssm"].astype(jnp.float32) + bx
    y = (h * Cm.astype(jnp.float32)[:, None, :]).sum(-1)
    y = y + params["D"].astype(jnp.float32) * xc.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = (y @ params["w_out"])[:, None]
    return out, {"conv": window[:, 1:], "ssm": h.astype(state["ssm"].dtype)}


def mamba_state_init(cfg, batch, dtype):
    m = cfg.mamba
    di = m.expand * cfg.d_model
    return {
        "conv": jnp.zeros((batch, m.d_conv - 1, di), dtype),
        "ssm": jnp.zeros((batch, di, m.d_state), dtype),
    }
