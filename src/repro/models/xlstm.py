"""xLSTM blocks (Beck et al. 2024): mLSTM (matrix memory, attention-like
parallel form) and sLSTM (scalar memory, sequential scan).

xlstm-1.3b uses an [m:s] interleave (7 mLSTM : 1 sLSTM per group of 8).
Decode is O(1): mLSTM carries (C, n, m_state) per head; sLSTM (c, n, h, m).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L


# ---------------------------------------------------------------- mLSTM
def mlstm_init(key, cfg, dtype):
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    ks = jax.random.split(key, 8)
    s = d ** -0.5
    pf = cfg.xlstm.proj_factor_mlstm
    dp = int(pf * d)
    dh = dp // H
    # q/k/v are BLOCK-DIAGONAL per head (xLSTM paper's BlockLinear) —
    # (H, dh, dh) instead of (dp, dp): 1/H the parameters and FLOPs.
    return {
        "w_up": L.truncated_normal(ks[0], (d, 2 * dp), dtype, s),  # x and gate
        "wq": L.truncated_normal(ks[1], (H, dh, dh), dtype, dh ** -0.5),
        "wk": L.truncated_normal(ks[2], (H, dh, dh), dtype, dh ** -0.5),
        "wv": L.truncated_normal(ks[3], (H, dh, dh), dtype, dh ** -0.5),
        "w_if": L.truncated_normal(ks[4], (dp, 2 * cfg.n_heads), dtype, dp ** -0.5),
        "b_if": jnp.zeros((2 * cfg.n_heads,), dtype),
        "ogate_norm": L.rmsnorm_init(dp, dtype),
        "w_down": L.truncated_normal(ks[5], (dp, d), dtype, dp ** -0.5),
    }


def mlstm_specs(cfg, rules):
    t = rules.tensor_axis
    return {
        "w_up": P(None, t),
        # block-diagonal per-head weights: head count (4) is below the
        # tensor-axis cardinality, so these stay replicated (ZeRO shards
        # their optimizer state over the data axes instead).
        "wq": P(None, None, None),
        "wk": P(None, None, None),
        "wv": P(None, None, None),
        "w_if": P(t, None),
        "b_if": P(None),
        "ogate_norm": {"scale": P(None)},
        "w_down": P(t, None),
    }


def _mlstm_heads(params, xu, cfg):
    dp = xu.shape[-1]
    H = cfg.n_heads
    dh = dp // H
    xh = xu.reshape(*xu.shape[:-1], H, dh)
    q = jnp.einsum("...hd,hde->...he", xh, params["wq"])
    k = jnp.einsum("...hd,hde->...he", xh, params["wk"]) * (dh ** -0.5)
    v = jnp.einsum("...hd,hde->...he", xh, params["wv"])
    if_ = xu @ params["w_if"] + params["b_if"]
    i_pre, f_pre = jnp.split(if_, 2, axis=-1)  # (..., H)
    return q, k, v, i_pre.astype(jnp.float32), f_pre.astype(jnp.float32)


def mlstm_parallel_inner(q, k, v, i_pre, f_pre):
    """Quadratic stabilized parallel form — reference/oracle and the
    intra-chunk compute of the chunkwise form. Shapes (B,S,H,·)."""
    B, S, H, dh = q.shape
    logf = jax.nn.log_sigmoid(f_pre)  # (B,S,H)
    F = jnp.cumsum(logf, axis=1)
    Dmat = F[:, :, None, :] - F[:, None, :, :] + i_pre[:, None, :, :]  # (B,S,S,H)
    mask = jnp.tril(jnp.ones((S, S), bool))
    Dmat = jnp.where(mask[None, :, :, None], Dmat, -jnp.inf)
    m_state = jnp.max(Dmat, axis=2)  # (B,S,H)
    Dw = jnp.exp(Dmat - m_state[:, :, None, :])
    scores = jnp.einsum("bthd,bshd->btsh", q.astype(jnp.float32), k.astype(jnp.float32))
    w = scores * Dw
    denom = jnp.abs(w.sum(2)) + jnp.exp(-m_state)  # (B,S,H)
    hnum = jnp.einsum("btsh,bshd->bthd", w, v.astype(jnp.float32))
    return hnum / jnp.maximum(denom, 1.0)[..., None]


def mlstm_chunked_inner(q, k, v, i_pre, f_pre, chunk: int):
    """Chunkwise-parallel mLSTM (xLSTM's training form): scan over chunks
    carrying the recurrent (C, n, m) state; quadratic only within a chunk.
    Peak score tile is (B, c, c, H) instead of (B, S, S, H).

    Exactness: equals the fully-parallel form up to the stabilizer (the
    running max is per-chunk-prefix rather than per-row over the full
    past, a monotone refinement of the same max — results match to fp
    tolerance; see tests/test_xlstm_forms.py)."""
    B, S, H, dh = q.shape
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    qc = jnp.moveaxis(q.reshape(B, nc, chunk, H, dh), 1, 0)
    kc = jnp.moveaxis(k.reshape(B, nc, chunk, H, dh), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, nc, chunk, H, dh), 1, 0)
    ic = jnp.moveaxis(i_pre.reshape(B, nc, chunk, H), 1, 0)
    fc = jnp.moveaxis(f_pre.reshape(B, nc, chunk, H), 1, 0)

    def step(carry, inp):
        C, n, m = carry  # (B,H,dh,dh), (B,H,dh), (B,H)
        qq, kk, vv, ii, ff = inp  # (B,c,H,·)
        logf = jax.nn.log_sigmoid(ff.astype(jnp.float32))  # (B,c,H)
        F = jnp.cumsum(logf, axis=1)  # within-chunk cumulative
        Ftot = F[:, -1]  # (B,H)
        # stabilizer per row: max(inter m + F_t, intra max)
        Dmat = F[:, :, None, :] - F[:, None, :, :] + ii[:, None, :, :].astype(jnp.float32)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        Dmat = jnp.where(mask[None, :, :, None], Dmat, -jnp.inf)
        m_intra = jnp.max(Dmat, axis=2)  # (B,c,H)
        m_inter = m[:, None, :] + F  # (B,c,H)
        m_row = jnp.maximum(m_intra, m_inter)
        Dw = jnp.exp(Dmat - m_row[:, :, None, :])
        qf = qq.astype(jnp.float32)
        kf = kk.astype(jnp.float32)
        vf = vv.astype(jnp.float32)
        scores = jnp.einsum("bthd,bshd->btsh", qf, kf)
        w = scores * Dw
        intra_num = jnp.einsum("btsh,bshd->bthd", w, vf)
        intra_den = w.sum(2)  # (B,c,H)
        inter_w = jnp.exp(m_inter - m_row)  # (B,c,H)
        inter_num = jnp.einsum("bthd,bhde->bthe", qf, C) * inter_w[..., None]
        inter_den = jnp.einsum("bthd,bhd->bth", qf, n) * inter_w
        den = jnp.abs(intra_den + inter_den) + jnp.exp(-m_row)
        h = (intra_num + inter_num) / jnp.maximum(den, 1.0)[..., None]
        # ---- state update to end of chunk: new stabilizer is the max of
        # (carried max, decayed to chunk end) and the chunk's own keys'
        # (Ftot - F_s + i_s)
        m_new = jnp.maximum(
            m + Ftot, jnp.max(Ftot[:, None] - F + ii.astype(jnp.float32), axis=1)
        )
        # decay for keys within chunk: from position s to chunk end:
        # Ftot - F_s + i_s, stabilized by m_new
        kw = jnp.exp(Ftot[:, None] - F + ii.astype(jnp.float32) - m_new[:, None])  # (B,c,H)
        C_new = C * jnp.exp(m + Ftot - m_new)[..., None, None] + jnp.einsum(
            "bshd,bshe,bsh->bhde", kf, vf, kw
        )
        n_new = n * jnp.exp(m + Ftot - m_new)[..., None] + jnp.einsum(
            "bshd,bsh->bhd", kf, kw
        )
        return (C_new, n_new, m_new), h

    init = (
        jnp.zeros((B, H, dh, dh), jnp.float32),
        jnp.zeros((B, H, dh), jnp.float32),
        jnp.full((B, H), 0.0, jnp.float32),
    )
    _, hs = jax.lax.scan(step, init, (qc, kc, vc, ic, fc))
    return jnp.moveaxis(hs, 0, 1).reshape(B, S, H, dh)


def mlstm_train(params, x, cfg, chunk: int = 256):
    """Chunkwise-parallel mLSTM block."""
    B, S, d = x.shape
    xz = x @ params["w_up"]
    xu, z = jnp.split(xz, 2, axis=-1)
    q, k, v, i_pre, f_pre = _mlstm_heads(params, xu, cfg)
    if S <= chunk:
        h = mlstm_parallel_inner(q, k, v, i_pre, f_pre)
    else:
        h = mlstm_chunked_inner(q, k, v, i_pre, f_pre, chunk)
    h = h.reshape(B, S, -1).astype(x.dtype)
    h = L.rmsnorm(params["ogate_norm"], h) * jax.nn.silu(z)
    return h @ params["w_down"]


def mlstm_decode(params, x, state, cfg):
    """state: {'C': (B,H,dh,dh), 'n': (B,H,dh), 'm': (B,H)}."""
    B = x.shape[0]
    H = cfg.n_heads
    xz = x[:, 0] @ params["w_up"]
    xu, z = jnp.split(xz, 2, axis=-1)
    q, k, v, i_pre, f_pre = _mlstm_heads(params, xu, cfg)
    logf = jax.nn.log_sigmoid(f_pre)  # (B,H)
    m_new = jnp.maximum(logf + state["m"], i_pre)
    fw = jnp.exp(logf + state["m"] - m_new)[..., None]
    iw = jnp.exp(i_pre - m_new)[..., None]
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    C = state["C"].astype(jnp.float32) * fw[..., None] + iw[..., None] * (
        kf[..., :, None] * vf[..., None, :]
    )
    n = state["n"].astype(jnp.float32) * fw + iw * kf
    qf = q.astype(jnp.float32)
    num = jnp.einsum("bhd,bhde->bhe", qf, C)
    den = jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n)) + jnp.exp(-m_new)
    h = (num / jnp.maximum(den, 1.0)[..., None]).reshape(B, -1).astype(x.dtype)
    h = L.rmsnorm(params["ogate_norm"], h) * jax.nn.silu(z)
    out = (h @ params["w_down"])[:, None]
    return out, {"C": C.astype(state["C"].dtype), "n": n.astype(state["n"].dtype), "m": m_new}


def mlstm_state_init(cfg, batch, dtype):
    H = cfg.n_heads
    dp = int(cfg.xlstm.proj_factor_mlstm * cfg.d_model)
    dh = dp // H
    return {
        "C": jnp.zeros((batch, H, dh, dh), dtype),
        "n": jnp.zeros((batch, H, dh), dtype),
        "m": jnp.zeros((batch, H), jnp.float32),
    }


# ---------------------------------------------------------------- sLSTM
def slstm_init(key, cfg, dtype):
    d = cfg.d_model
    H = cfg.n_heads
    ks = jax.random.split(key, 3)
    s = d ** -0.5
    return {
        "w_gates": L.truncated_normal(ks[0], (d, 4 * d), dtype, s),  # i,f,z,o
        "r_gates": L.truncated_normal(ks[1], (d, 4 * d), dtype, s * 0.5),
        "b_gates": jnp.zeros((4 * d,), dtype),
        "w_out": L.truncated_normal(ks[2], (d, d), dtype, s),
    }


def slstm_specs(cfg, rules):
    t = rules.tensor_axis
    return {
        "w_gates": P(None, t),
        "r_gates": P(None, t),
        "b_gates": P(t),
        "w_out": P(t, None),
    }


def _slstm_step(params, carry, xt):
    """carry: (c, n, h, m) each (B, d) fp32; xt: (B, d)."""
    c, n, h, m = carry
    gates = (
        xt @ params["w_gates"] + h.astype(xt.dtype) @ params["r_gates"] + params["b_gates"]
    ).astype(jnp.float32)
    i_pre, f_pre, z_pre, o_pre = jnp.split(gates, 4, axis=-1)
    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + m, i_pre)
    iw = jnp.exp(i_pre - m_new)
    fw = jnp.exp(logf + m - m_new)
    z = jnp.tanh(z_pre)
    o = jax.nn.sigmoid(o_pre)
    c_new = fw * c + iw * z
    n_new = fw * n + iw
    h_new = o * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, h_new, m_new), h_new


def slstm_train(params, x, cfg):
    B, S, d = x.shape
    init = tuple(jnp.zeros((B, d), jnp.float32) for _ in range(4))

    def step(carry, xt):
        return _slstm_step(params, carry, xt)

    _, hs = jax.lax.scan(step, init, x.transpose(1, 0, 2))
    h = hs.transpose(1, 0, 2).astype(x.dtype)
    return h @ params["w_out"]


def slstm_decode(params, x, state, cfg):
    carry = (state["c"], state["n"], state["h"], state["m"])
    carry, h = _slstm_step(params, carry, x[:, 0])
    out = (h.astype(x.dtype) @ params["w_out"])[:, None]
    c, n, hh, m = carry
    return out, {"c": c, "n": n, "h": hh, "m": m}


def slstm_state_init(cfg, batch, dtype):
    d = cfg.d_model
    z = lambda: jnp.zeros((batch, d), jnp.float32)
    return {"c": z(), "n": z(), "h": z(), "m": z()}
