"""Shared layers: norms, embeddings, RoPE/M-RoPE, gated MLPs.

Functional style: each layer is (init(key, cfg) -> params, apply(params, x))
plus specs(cfg, rules) -> PartitionSpec tree mirroring params.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def truncated_normal(key, shape, dtype, scale):
    return (scale * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


# ----------------------------------------------------------------- norms
def rmsnorm_init(d, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d, dtype, elementwise=True):
    if not elementwise:  # olmo's non-parametric LN
        return {}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    if "scale" in params:
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def make_norm(kind: str, d: int, dtype):
    if kind == "rmsnorm":
        return rmsnorm_init(d, dtype), rmsnorm
    if kind == "layernorm":
        return layernorm_init(d, dtype), layernorm
    if kind == "nonparametric":  # olmo
        return layernorm_init(d, dtype, elementwise=False), layernorm
    raise ValueError(kind)


def norm_specs(kind: str):
    if kind == "rmsnorm":
        return {"scale": P(None)}
    if kind == "layernorm":
        return {"scale": P(None), "bias": P(None)}
    return {}


# ------------------------------------------------------------------ RoPE
def rope_freqs(head_dim: int, theta: float = 10000.0):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0):
    """x: (..., seq, heads, head_dim); positions: (..., seq) int."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    ang = ang[..., None, :]  # broadcast over heads
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array, positions3: jax.Array, sections: tuple[int, int, int],
    theta: float = 10000.0,
):
    """Qwen2-VL multimodal RoPE. positions3: (3, ..., seq) — temporal,
    height, width position ids; sections: per-axis frequency-pair counts
    summing to head_dim/2 (e.g. (16, 24, 24) for head_dim 128)."""
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, (sections, hd)
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    # split frequency pairs among the three position streams
    sec_ids = jnp.concatenate(
        [jnp.full((s,), i, jnp.int32) for i, s in enumerate(sections)]
    )  # (hd/2,)
    pos = jnp.take(positions3, sec_ids, axis=0)  # (hd/2, ..., seq)
    pos = jnp.moveaxis(pos, 0, -1)  # (..., seq, hd/2)
    ang = pos.astype(jnp.float32) * freqs
    ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------- MLP
def mlp_init(key, d_model, d_ff, dtype, gated=True):
    k1, k2, k3 = jax.random.split(key, 3)
    scale_in = d_model ** -0.5
    scale_out = d_ff ** -0.5
    p = {
        "w_in": truncated_normal(k1, (d_model, d_ff), dtype, scale_in),
        "w_out": truncated_normal(k2, (d_ff, d_model), dtype, scale_out),
    }
    if gated:
        p["w_gate"] = truncated_normal(k3, (d_model, d_ff), dtype, scale_in)
    return p


def mlp_apply(params, x, act=jax.nn.silu):
    h = x @ params["w_in"]
    if "w_gate" in params:
        h = act(x @ params["w_gate"]) * h
    else:
        h = act(h)
    return h @ params["w_out"]


def mlp_specs(rules, gated=True):
    p = {"w_in": rules.mlp_in((0, 0)), "w_out": rules.mlp_out((0, 0))}
    if gated:
        p["w_gate"] = rules.mlp_in((0, 0))
    return p


# ------------------------------------------------------------- embedding
def embed_init(key, vocab, d_model, dtype):
    return {"table": truncated_normal(key, (vocab, d_model), dtype, 1.0)}


def embed_apply(params, tokens):
    return jnp.take(params["table"], tokens, axis=0)


def unembed_apply(params, x):
    return x @ params["table"].T
