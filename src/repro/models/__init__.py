"""Model zoo: composable decoder covering dense / MoE / hybrid (Mamba+attn)
/ ssm (xLSTM) / audio / VLM backbones — the 10 assigned architectures."""

from repro.models.model import (
    init_params,
    param_specs,
    forward_train,
    loss_fn,
    init_cache,
    cache_specs,
    decode_step,
)

__all__ = [
    "init_params",
    "param_specs",
    "forward_train",
    "loss_fn",
    "init_cache",
    "cache_specs",
    "decode_step",
]
