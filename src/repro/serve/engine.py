"""Serving engine: batched decode with CONTINUOUS BATCHING — requests
join/leave slots at step boundaries; per-slot positions flow into the
decode step (scalar-or-(B,) position support in the attention caches).

The engine drives the pure ``decode_step``; prefill feeds prompt tokens
through the same cached path (functionally exact). Pod-scale shapes are
exercised via the dry-run; this engine runs for real on CPU-scale configs.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (len,) int32
    max_new_tokens: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, cfg, params, batch_slots: int, max_seq: int):
        self.cfg = cfg
        self.params = params
        self.slots = batch_slots
        self.max_seq = max_seq
        self.cache = M.init_cache(cfg, batch_slots, max_seq, dtype=jnp.float32)
        self.positions = np.zeros(batch_slots, np.int32)  # next write index
        self.pending_tok = np.zeros(batch_slots, np.int32)
        self.slot_req: dict[int, Request] = {}
        self._step = jax.jit(
            lambda p, c, b, pos: M.decode_step(p, c, b, pos, self.cfg)
        )
        self.steps_run = 0
        self.tokens_out = 0  # decoded (committed) tokens, for tokens/s

    @property
    def free_slots(self):
        return [s for s in range(self.slots) if s not in self.slot_req]

    # ------------------------------------------------------------- admit
    def admit(self, req: Request) -> bool:
        """Seat ``req`` in a free slot and prefill its prompt.

        CO-ADVANCE SEMANTICS (intended, tested): prefill feeds the prompt
        through the same batched decode path, one engine step per prompt
        token, and every OTHER active slot DECODES during those steps —
        continuous batching has no prefill stall, so the tokens the other
        slots emit while a prompt streams in are real output, identical to
        what they would have produced solo, and they count against those
        requests' ``max_new_tokens`` budgets exactly like any decoded
        token (a request can even finish mid-prefill; its slot frees for
        the next ``admit``). Prefill steps are NOT charged to the admitted
        request's budget — its ``out`` stays empty until the first decode
        step after admission.
        """
        free = self.free_slots
        if not free:
            return False
        slot = free[0]
        self.slot_req[slot] = req
        self.positions[slot] = 0
        # prefill: feed prompt tokens through the cached decode path; the
        # other slots advance with their own pending tokens (no stalls).
        for tok in req.prompt[:-1]:
            self.pending_tok[slot] = int(tok)
            self._advance(decode_slots=[s for s in self.slot_req if s != slot])
        self.pending_tok[slot] = int(req.prompt[-1])
        return True

    # -------------------------------------------------------------- step
    def _forward(self) -> np.ndarray:
        """One batched model forward over all slots (the seam subclasses
        override — ``serve.fleet.FleetEngine`` runs the staged decode here
        so MoE boundaries can be serviced by a combined host program).
        Returns host logits (slots, vocab) and updates ``self.cache``."""
        batch = {"token": jnp.asarray(self.pending_tok)}
        logits, self.cache = self._step(
            self.params, self.cache, batch, jnp.asarray(self.positions)
        )
        return np.asarray(logits, np.float32)

    def _advance(self, decode_slots):
        return self._commit(self._forward(), decode_slots)

    def _commit(self, logits, decode_slots):
        """Book one forward's results: bump positions, argmax-append for the
        decoding slots, retire finished requests and free their slots."""
        self.steps_run += 1
        self.positions[list(self.slot_req)] += 1
        for slot in decode_slots:
            req = self.slot_req[slot]
            nxt = int(np.argmax(logits[slot]))
            req.out.append(nxt)
            self.tokens_out += 1
            self.pending_tok[slot] = nxt
            if len(req.out) >= req.max_new_tokens or self.positions[slot] >= self.max_seq - 1:
                req.done = True
                del self.slot_req[slot]
        return logits

    def step(self):
        """One decode step for every active slot (batched)."""
        if not self.slot_req:
            return
        self._advance(decode_slots=list(self.slot_req))

    def run_to_completion(self, max_steps=4096):
        for _ in range(max_steps):
            if not self.slot_req:
                break
            self.step()

    # ---------------------------------------------------------- reporting
    def collective_report(self, rules=None, tuner=None) -> dict:
        """What the price-driven autotuner picks for this engine's MoE
        dispatch site (the §3 all-to-all boundary): chosen strategy, its
        source (measured/cache/analytic/forced), and the paper's priced
        rounds. ``rules`` defaults to the active sharding rules; an
        unsharded engine (single device, no launcher) reports n/a."""
        from repro.dist import sharding as SH
        from repro.runtime import autotune

        if rules is None:
            act = SH.active()
            rules = act[0] if act else None
        if rules is None:
            return {"status": "n/a", "reason": "no active sharding rules"}
        return autotune.moe_site_report(
            self.cfg, rules, n_tokens=self.slots, tuner=tuner
        )
