"""Multi-tenant serving: N models share one mesh through ONE combined host
program.

Paper Property 2 packs disjoint D3(J,L) guests onto a D3(K,M) host;
``runtime.combine`` proved the program-level consequence (N guests'
collectives at makespan max(T_i) instead of ΣT_i). This module serves
THROUGH it:

* Every tenant model decodes via the staged generator forward
  (``models.model.decode_step_staged``), which suspends at each MoE
  boundary instead of computing the expert FFN inline.
* ``TenantFleet.step`` drives all tenants' generators in lockstep: at each
  boundary round it collects every paused tenant's dispatch array
  (``models.moe.moe_guest_dispatch``), scatters them to their guests' host
  slots (``runtime.combine.scatter_guests``), and issues ONE
  ``run_alltoall_compute`` replay of the combined pipelined program
  (``dist.collectives.concurrent_program(..., pipelined=1)``) — each chunk
  is processed AT its destination device with THAT tenant's expert shard
  and returned to its sender. One ppermute wave set carries all tenants'
  chunks; on the JAX backend the waves overlap the expert compute
  (PR 7's ``overlap_fused`` pipeline).
* Admission prefill services the single admitting tenant through the same
  combined program immediately (other guests' slots carry zeros — still
  bit-exact, by guest isolation), so tenants join mid-traffic without
  stalling the fleet.
* Churn is rewrite-only: ``evict`` / ``plan_eviction`` unseat tenants via
  ``MultiTenantCluster`` (cached re-combine) and the next boundary round
  replays the survivors' combined program. Surviving tenants' in-flight
  requests continue BIT-EXACT across the swap: engines and caches are
  per-tenant, and each survivor's stages inside any combined program are
  its own solo stages (the ``combine`` contract), so the re-combine is
  invisible to its tokens.

``combined=False`` is the time-multiplexed control: the same tenants, the
same staged decode, but each boundary round replays every tenant's SOLO
emulated program sequentially — ΣT_i rounds, the arm
``bench_multitenant_serving`` measures the combined fleet against.

Tenant compatibility: one combined replay moves one host-shaped array, so
all seated tenants must share the dispatch chunk signature
(E_loc, C, d, d_ff_expert) — same experts-per-guest-device, capacity,
model width and expert FFN width. Guest shapes and layer counts may
differ (a tenant with fewer MoE boundaries simply drops out of later
rounds of a step).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.emulation import Embedding, embed
from repro.core.topology import D3
from repro.dist.mesh import DeviceLayout
from repro.models import model as M
from repro.models import moe as MOE
from repro.serve.engine import Engine, Request
from repro.train.fault_tolerance import MultiTenantCluster


class FleetEngine(Engine):
    """An ``Engine`` whose forward is the staged eager decode: it pauses at
    every MoE boundary and hands ``(ffn_params, h2)`` to a service callable
    instead of computing the expert FFN inline. Driven two ways: the
    inherited ``_advance`` path (admission prefill, solo stepping) services
    each boundary immediately via ``service``; ``TenantFleet.step`` drives
    ``begin_forward``/``pump`` directly to interleave N tenants' boundaries
    into shared combined replays."""

    def __init__(self, cfg, params, batch_slots: int, max_seq: int, service):
        super().__init__(cfg, params, batch_slots, max_seq)
        self._service = service     # (ffn_params, h2) -> y
        self._gen = None
        self._last_logits = None

    def begin_forward(self):
        """Start one staged forward over all slots; returns the first MoE
        boundary's ``(ffn_params, h2)`` or None if the step completed."""
        batch = {"token": jnp.asarray(self.pending_tok)}
        self._gen = M.decode_step_staged(
            self.params, self.cache, batch, jnp.asarray(self.positions), self.cfg
        )
        return self.pump(None)

    def pump(self, y):
        """Resume the staged forward with expert output ``y`` (None to
        start). Returns the next boundary's item, or None when the forward
        finished — logits are then in ``_last_logits`` and the cache is
        committed."""
        try:
            item = next(self._gen) if y is None else self._gen.send(y)
        except StopIteration as stop:
            logits, self.cache = stop.value
            self._last_logits = np.asarray(logits, np.float32)
            self._gen = None
            return None
        return item

    def _forward(self):
        item = self.begin_forward()
        while item is not None:
            item = self.pump(jnp.asarray(self._service(*item)))
        return self._last_logits


@dataclasses.dataclass
class Tenant:
    """One seated model: its engine, its guest embedding, its traffic."""

    tid: int
    cfg: object
    engine: FleetEngine
    embedding: Embedding
    n_guest: int
    sig: tuple                 # (E_loc, C, d, d_ff_expert) dispatch signature
    queue: list = dataclasses.field(default_factory=list)
    requests: list = dataclasses.field(default_factory=list)


class TenantFleet:
    """N small models as disjoint guests on one D3(K,M) host mesh, every
    tenant's MoE dispatch+combine routed through the single combined host
    program (module docstring has the full story).

    ``backend``: ``"reference"`` (device-free NumPy replay) or ``"jax"``
    (device-backed ``run_alltoall_compute`` — needs ``host_n`` devices).
    ``combined=False`` switches to the time-multiplexed control (one solo
    emulated replay per tenant per boundary round).
    """

    def __init__(self, host=(2, 2), *, backend="reference", max_seq: int = 64,
                 combined: bool = True):
        K, M_ = host
        self.cluster = MultiTenantCluster(DeviceLayout(D3(K, M_)))
        self.host = self.cluster.layout.topo
        self.max_seq = max_seq
        self.combined = combined
        self.backend = self._make_backend(backend)
        self.tenants: dict[int, Tenant] = {}   # insertion order = seat order
        self._next_tid = 0
        self._next_rid = 0
        self._owner = None          # host device -> (tid, guest device) cache
        self.steps_run = 0
        self.replays = 0            # program replays issued at boundaries
        self.rounds_replayed = 0    # Σ num_rounds over those replays
        self._tokens_evicted = 0

    @staticmethod
    def _make_backend(backend):
        if backend == "reference":
            from repro.runtime.backends.reference import NumpyReferenceBackend

            return NumpyReferenceBackend()
        if backend == "jax":
            from repro.runtime.backends.jax_ppermute import JaxPpermuteBackend

            return JaxPpermuteBackend()
        return backend

    # -------------------------------------------------------------- admission
    def _free_cabinets(self):
        used = set()
        for t in self.tenants.values():
            used |= set(t.embedding.c_set)
        return [c for c in range(self.host.K) if c not in used]

    def _place(self, J: int, L: int) -> Embedding:
        """Cabinet-regime first-fit: each guest takes J whole free cabinets
        (disjoint cabinet sets need no position bookkeeping), so an evicted
        tenant's cabinets immediately free up for re-admission."""
        free = self._free_cabinets()
        if L > self.host.M or len(free) < J:
            raise ValueError(
                f"guest D3({J},{L}) does not fit: {len(free)} free cabinets "
                f"of {self.host.K}, host positions {self.host.M}"
            )
        return embed(self.host, J, L, c_set=tuple(free[:J]))

    def admit_model(self, cfg, params, *, guest=(1, 2), slots: int = 2) -> int:
        """Seat a model as a D3(J,L) guest: first-fit placement, cluster
        validation (image disjointness + derive-once program suite), and
        the uniform dispatch-signature check. Returns the tenant id."""
        m = getattr(cfg, "moe", None)
        if m is None:
            raise ValueError(
                "fleet tenants serve their expert dispatch through the "
                "combined program; a config without MoE has no dispatch "
                "to combine — serve it on a plain Engine"
            )
        J, L = guest
        n_guest = J * L * L
        if m.num_experts % n_guest:
            raise ValueError(
                f"E={m.num_experts} experts do not shard over the "
                f"D3({J},{L}) guest's {n_guest} devices"
            )
        sig = (m.num_experts // n_guest, MOE.guest_capacity(m, slots),
               cfg.d_model, m.d_ff_expert)
        for t in self.tenants.values():
            if t.sig != sig:
                raise ValueError(
                    "one combined replay moves one host-shaped array, so "
                    "every tenant must share the dispatch chunk signature "
                    f"(E_loc, C, d, f); seated tenants have {t.sig}, new "
                    f"tenant has {sig}"
                )
        emb = self._place(J, L)
        self.cluster.admit(emb)
        tid = self._next_tid
        self._next_tid += 1
        service = lambda fp, h2, _tid=tid: self._service_single(_tid, fp, h2)
        eng = FleetEngine(cfg, params, slots, self.max_seq, service)
        self.tenants[tid] = Tenant(tid=tid, cfg=cfg, engine=eng,
                                   embedding=emb, n_guest=n_guest, sig=sig)
        self._owner = None
        return tid

    # ---------------------------------------------------------------- traffic
    def submit(self, tid: int, prompt, max_new_tokens: int) -> Request:
        """Enqueue a request for tenant ``tid``; admitted immediately if a
        slot is free (prefill services its boundaries through the combined
        program right away), queued otherwise."""
        t = self.tenants[tid]
        req = Request(rid=self._next_rid, prompt=np.asarray(prompt, np.int32),
                      max_new_tokens=int(max_new_tokens))
        self._next_rid += 1
        t.requests.append(req)
        if not t.engine.admit(req):
            t.queue.append(req)
        return req

    def step(self):
        """One lockstep decode step for every tenant with active slots: all
        staged forwards advance together, and each MoE boundary round is
        serviced by ONE combined replay carrying every paused tenant's
        chunks (``combined=False``: one solo replay per tenant instead)."""
        for t in self.tenants.values():
            while t.queue and t.engine.free_slots:
                t.engine.admit(t.queue.pop(0))
        active = {tid: t for tid, t in self.tenants.items() if t.engine.slot_req}
        if not active:
            return
        items = {}
        for tid, t in active.items():
            it = t.engine.begin_forward()
            if it is not None:
                items[tid] = it
        while items:
            ys = self._dispatch(items)
            nxt = {}
            for tid in items:
                it = active[tid].engine.pump(jnp.asarray(ys[tid]))
                if it is not None:
                    nxt[tid] = it
            items = nxt
        for t in active.values():
            t.engine._commit(t.engine._last_logits,
                             decode_slots=list(t.engine.slot_req))
        self.steps_run += 1

    def run_to_completion(self, max_steps: int = 4096):
        for _ in range(max_steps):
            if not any(t.engine.slot_req or t.queue
                       for t in self.tenants.values()):
                break
            self.step()

    @property
    def tokens_out(self) -> int:
        return self._tokens_evicted + sum(
            t.engine.tokens_out for t in self.tenants.values())

    # ------------------------------------------------------------------ churn
    def evict(self, tid: int):
        """Voluntarily unseat tenant ``tid`` mid-traffic (its unfinished
        requests are dropped, ``done`` stays False) and re-combine the
        survivors via ``MultiTenantCluster.release`` — cached emulate +
        cached combine, so churn back to a previously-seen tenant set is
        free. Returns the cluster's ``TenantPlan``."""
        seat = list(self.tenants).index(tid)
        t = self.tenants.pop(tid)
        self._tokens_evicted += t.engine.tokens_out
        self._owner = None
        return self.cluster.release(seat)

    def fail(self, host_device: int) -> None:
        """Mark a host device failed (bookkeeping only; call
        ``plan_eviction`` to act on it)."""
        self.cluster.fail(host_device)

    def plan_eviction(self):
        """Failure-driven churn: evict exactly the tenants whose guest
        images contain a failed device (``MultiTenantCluster.plan_eviction``)
        and drop them from the fleet; survivors keep serving through the
        re-combined program from the next boundary round on."""
        seats = list(self.tenants)
        plan = self.cluster.plan_eviction()
        for pos in plan.evicted:
            t = self.tenants.pop(seats[pos])
            self._tokens_evicted += t.engine.tokens_out
        self._owner = None
        return plan

    # -------------------------------------------------------------- dispatch
    def _embeddings(self) -> tuple[Embedding, ...]:
        return tuple(t.embedding for t in self.tenants.values())

    def program(self):
        """The current tenant set's combined pipelined §3 program (cached
        in ``dist.collectives``, so churn re-combines are lookups)."""
        from repro.dist import collectives as coll

        return coll.concurrent_program("alltoall", self._embeddings(),
                                       pipelined=1)

    def _solo_program(self, emb: Embedding):
        from repro.dist import collectives as coll

        return coll.alltoall_program(DeviceLayout(emb.guest), emb, pipelined=1)

    def _host_owner(self) -> dict:
        if self._owner is None:
            self._owner = {}
            for tid, t in self.tenants.items():
                for gdev, hdev in enumerate(t.embedding.device_map):
                    self._owner[int(hdev)] = (tid, gdev)
        return self._owner

    def _service_single(self, tid: int, ffn_params, h2):
        """Service ONE tenant's boundary (admission prefill / solo
        stepping) — still through the fleet's replay path, other guests'
        slots zero."""
        return self._dispatch({tid: (ffn_params, h2)})[tid]

    def _dispatch(self, items: dict) -> dict:
        """items: {tid: (ffn_params, h2)} — one boundary round. Returns
        {tid: y} with y the (B, S, d) expert output for that tenant."""
        Xs, states = {}, {}
        for tid, (fp, h2) in items.items():
            t = self.tenants[tid]
            X, st = MOE.moe_guest_dispatch(fp, np.asarray(h2, np.float32),
                                           t.cfg, t.n_guest)
            Xs[tid], states[tid] = X, st
        backs = (self._replay_combined(items, Xs) if self.combined
                 else self._replay_muxed(items, Xs))
        out = {}
        for tid, (fp, h2) in items.items():
            out[tid] = MOE.moe_guest_combine(
                backs[tid], states[tid], fp, np.asarray(h2, np.float32))
        return out

    def _replay_combined(self, items: dict, Xs: dict) -> dict:
        from repro.runtime.combine import extract_guest, scatter_guests

        proto = next(iter(Xs.values()))
        chunk_shape = proto.shape[2:]          # (E_loc, C, d), sig-uniform
        arrays, guests, order = [], [], []
        for tid, t in self.tenants.items():
            arrays.append(Xs.get(tid, np.zeros(
                (t.n_guest, t.n_guest, *chunk_shape), np.float32)))
            guests.append(t.embedding)
            order.append(tid)
        Xh = scatter_guests(arrays, guests, axes=(0, 1))
        prog = self.program()
        out = self._replay(prog, items, Xh)
        self.replays += 1
        self.rounds_replayed += prog.num_rounds
        return {tid: extract_guest(out, emb, axes=(0, 1))
                for tid, emb in zip(order, guests) if tid in Xs}

    def _replay_muxed(self, items: dict, Xs: dict) -> dict:
        """Time-multiplexed control: each tenant's chunks through its own
        solo emulated program, sequentially — the ΣT_i arm."""
        from repro.runtime.combine import extract_guest, scatter_guests

        backs = {}
        for tid in items:
            t = self.tenants[tid]
            prog = self._solo_program(t.embedding)
            Xh = scatter_guests([Xs[tid]], [t.embedding], axes=(0, 1))
            out = self._replay(prog, {tid: items[tid]}, Xh)
            self.replays += 1
            self.rounds_replayed += prog.num_rounds
            backs[tid] = extract_guest(out, t.embedding, axes=(0, 1))
        return backs

    def _replay(self, prog, items: dict, Xh: np.ndarray) -> np.ndarray:
        """One ``run_alltoall_compute`` round trip of ``Xh`` through
        ``prog``, computing each arriving chunk's expert FFN with the
        owning tenant's weights for THAT destination device."""
        if getattr(self.backend, "name", "") == "reference":
            owner = self._host_owner()
            shards = {tid: MOE.guest_expert_shards(items[tid][0],
                                                   self.tenants[tid].n_guest)
                      for tid in items}
            # the reference oracle stacks chunks from EVERY active source at
            # each destination; in a combined program the other guests'
            # slots are structural zeros (no cross-guest links exist), so
            # restrict the FFN to the owner guest's source rows
            act = (np.flatnonzero(prog.active_mask_np)
                   if prog.active_devices is not None
                   else np.arange(prog.n))
            pos = {int(d): k for k, d in enumerate(act)}
            rows = {tid: np.asarray(
                [pos[int(d)] for d in self.tenants[tid].embedding.device_map],
                np.intp) for tid in items}

            def compute(j, chunks):
                own = owner.get(int(j))
                if own is None or own[0] not in shards:
                    return np.zeros_like(chunks)
                wi, wg, wo = shards[own[0]]
                g, r = own[1], rows[own[0]]
                out = np.zeros_like(chunks)
                out[r] = MOE.guest_expert_ffn_np(chunks[r], wi[g], wg[g], wo[g])
                return out

            return self.backend.run_alltoall_compute(Xh, prog, compute)

        # device-backed path: per-device weight rows scattered host-sized,
        # the stable module-level compute keeps the compiled closure cached
        from repro.runtime.combine import scatter_guests

        ws, guests = [], []
        for tid in items:
            t = self.tenants[tid]
            ws.append(MOE.guest_expert_shards(items[tid][0], t.n_guest))
            guests.append(t.embedding)
        WI, WG, WO = (scatter_guests([w[i] for w in ws], guests, axes=(0,))
                      for i in range(3))
        out = self.backend.run_alltoall_compute(
            jnp.asarray(Xh), prog, MOE.guest_expert_ffn,
            weights=(jnp.asarray(WI), jnp.asarray(WG), jnp.asarray(WO)))
        return np.asarray(out, np.float32)

    # ------------------------------------------------------------- reporting
    def collective_report(self, tuner=None) -> dict:
        """The combined-site autotuner decision for this tenant set plus
        the fleet's replay evidence: combined vs time-muxed round counts
        and the replays issued so far."""
        from repro.runtime import autotune

        embs = self._embeddings()
        if not embs:
            return {"status": "n/a", "reason": "no tenants seated"}
        t0 = next(iter(self.tenants.values()))
        E_loc, C, d = t0.sig[:3]
        nbytes = E_loc * C * d * 4
        tuner = tuner or autotune.get_autotuner()
        dec = tuner.decide_combined("alltoall", embs, nbytes=nbytes,
                                    dtype="float32")
        comb = self.program()
        mux_rounds = sum(self._solo_program(e).num_rounds for e in embs)
        return {
            "status": "ok",
            "tenants": len(embs),
            "key": str(dec.key),
            "strategy": dec.strategy,
            "source": dec.source,
            "combined_rounds": comb.num_rounds,
            "time_mux_rounds": int(mux_rounds),
            "replays": self.replays,
            "rounds_replayed": self.rounds_replayed,
            "analytic_us": {k: round(v, 1) for k, v in dec.analytic_us.items()},
            "measured_us": {k: round(v, 1) for k, v in dec.measured_us.items()},
        }
