"""Serving substrate: batched decode engine with continuous batching."""

from repro.serve.engine import Engine, Request

__all__ = ["Engine", "Request"]
