"""Serving substrate: batched decode engine with continuous batching, and
the multi-tenant fleet that serves N models through one combined host
program."""

from repro.serve.engine import Engine, Request
from repro.serve.fleet import FleetEngine, Tenant, TenantFleet

__all__ = ["Engine", "Request", "FleetEngine", "Tenant", "TenantFleet"]
