"""Device layouts: flat device axis <-> Swapped Dragonfly coordinates.

A ``DeviceLayout`` pins device index i of a 1-D mesh axis to router
``topo.id_router(i)`` (the c·M²+d·M+p linear order). Everything the paper's
algorithms need at runtime hangs off it: the doubly-parallel all-to-all
parameters (s = gcd(K, M) — the largest legal disagreeable-array stride)
and, when K and M are powers of two, the SBH hypercube view for ascend-
descend all-reduce.
"""

from __future__ import annotations

import dataclasses
import math

from repro.core.alltoall import DAParams
from repro.core.emulation import Embedding, embed
from repro.core.hypercube import SBH
from repro.core.topology import D3


@dataclasses.dataclass(frozen=True)
class DeviceLayout:
    """A D3 view of a flat device axis."""

    topo: D3

    @property
    def n(self) -> int:
        return self.topo.num_routers

    @property
    def da_params(self) -> DAParams:
        s = math.gcd(self.topo.K, self.topo.M)
        return DAParams(self.topo.K, self.topo.M, s)

    @property
    def sbh(self) -> SBH | None:
        k = self.topo.K.bit_length() - 1
        m = self.topo.M.bit_length() - 1
        if (1 << k) == self.topo.K and (1 << m) == self.topo.M:
            return SBH(k, m)
        return None

    def embed_onto(self, host: "DeviceLayout | D3", c_set=None, p_set=None) -> Embedding:
        """Property-2 embedding of THIS layout (as guest) into ``host``.

        The returned ``Embedding`` is what ``dist.collectives`` and
        ``runtime.rewrite.emulate`` take to run this layout's collectives
        guest-sized on the host's (larger) mesh axis. Defaults to the
        canonical prefix subsets; pass ``c_set``/``p_set`` for survivor
        sets (elastic failover)."""
        host_topo = host.topo if isinstance(host, DeviceLayout) else host
        return embed(host_topo, self.topo.K, self.topo.M, c_set=c_set, p_set=p_set)


def dragonfly_layout(n: int) -> DeviceLayout:
    """Factor an n-device axis as D3(K, M) with n = K·M².

    Among legal factorizations with K ≥ 2 and M ≥ 2 we pick the most
    balanced (minimal |K − M|, ties to larger M): 16 -> (4,2), 64 -> (4,4),
    256 -> (4,8), 512 -> (8,8). Falls back to the degenerate D3(n, 1) when
    no square factor exists (prime counts)."""
    best: tuple[int, int] | None = None
    for M in range(2, int(math.isqrt(n)) + 1):
        if n % (M * M):
            continue
        K = n // (M * M)
        if K < 2:
            continue
        if best is None or (abs(K - M), -M) < (abs(best[0] - best[1]), -best[1]):
            best = (K, M)
    if best is None:
        best = (n, 1)
    return DeviceLayout(D3(*best))
