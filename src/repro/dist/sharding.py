"""Sharding rule-set + process-wide active (rules, mesh) registration.

``ShardRules`` is the single source of PartitionSpecs for every
architecture: tensor-parallel projections (Megatron column/row split over
the ``model`` axis), token/batch sharding over the data axes, and the MoE
expert placement (expert-parallel when E divides the model axis, TP-experts
otherwise). Launchers call ``set_active(rules, mesh)`` so model-internal
code (MoE dispatch, sequence parallelism) can fetch the live rules without
threading them through every call signature; outside a launcher everything
degrades to single-device no-ops.
"""

from __future__ import annotations

import dataclasses

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardRules:
    """Axis names + derived PartitionSpec builders.

    Spec builders take the parameter's shape tuple (dims may be dummy 0s —
    specs are shape-independent; the structural test asserts every sharded
    dim actually divides the axis cardinality)."""

    tensor_axis: str = "model"
    data_axis: str = "data"
    pod_axis: str | None = None
    fsdp: bool = False
    zero1: bool = False
    seq_parallel: bool = False
    # "xla" (fused op) | "dragonfly" (§3 program on the ppermute backend)
    # | "dragonfly_overlap" (same program, start_step-ordered replay)
    # | "dragonfly_overlap_fused" (dispatch + expert FFN + combine as ONE
    #   Schedules-1-3 wave pipeline, compute overlapping the rounds)
    # | "auto" (runtime.autotune picks the cheapest per site)
    moe_collectives: str = "xla"
    model_axis_size: int = 16
    data_axis_size: int = 16

    # ------------------------------------------------------------- axes
    @property
    def batch_axes(self):
        if self.pod_axis:
            return (self.pod_axis, self.data_axis)
        return self.data_axis

    # ------------------------------------------------------ activations
    def tokens(self) -> P:
        """(B·S,) or (B, S) token ids: sharded over the batch axes."""
        return P(self.batch_axes, None)

    def activations(self) -> P:
        """(B, S, d) activations: batch over data axes, d replicated."""
        return P(self.batch_axes, None, None)

    # ----------------------------------------------------- dense params
    def attn_in(self, shape) -> P:
        """Column-parallel input projection (d, heads·hd): shard dim 1."""
        return P(None, self.tensor_axis)

    def attn_out(self, shape) -> P:
        """Row-parallel output projection (heads·hd, d): shard dim 0."""
        return P(self.tensor_axis, None)

    def mlp_in(self, shape) -> P:
        return P(None, self.tensor_axis)

    def mlp_out(self, shape) -> P:
        return P(self.tensor_axis, None)

    def embed(self, shape) -> P:
        """(vocab, d) table: shard the model dim (gather-free lookup)."""
        return P(None, self.tensor_axis)

    # ------------------------------------------------------------- MoE
    def expert_parallel(self, n_experts: int) -> bool:
        return n_experts % self.model_axis_size == 0

    def expert(self, shape, ff_dim: int | None = None, n_experts: int | None = None) -> P:
        """Per-expert stacked weights (E, ..., ...).

        Expert-parallel (E divides the model axis): shard the expert dim —
        each model shard owns E/n_model experts outright and dispatch is
        the §3 all-to-all. TP fallback: experts replicated, their ff dim
        sharded over the tensor axis."""
        ndim = len(shape)
        if n_experts is not None and self.expert_parallel(n_experts):
            return P(self.tensor_axis, *([None] * (ndim - 1)))
        axes: list = [None] * ndim
        axes[ff_dim if ff_dim is not None else ndim - 1] = self.tensor_axis
        return P(*axes)

    # ------------------------------------------------------------ FSDP
    def _maybe_fsdp(self, spec: P, shape, zero: bool = False) -> P:
        """Additionally shard the first spec-free dim divisible by the data
        axis over the batch axes (ZeRO-1/3 partitioning)."""
        if not (self.fsdp or zero):
            return spec
        axes = list(spec) + [None] * (len(shape) - len(spec))
        for i, (ax, dim) in enumerate(zip(axes, shape)):
            if ax is None and dim and dim % self.data_axis_size == 0:
                axes[i] = self.batch_axes
                return P(*axes)
        return spec


# --------------------------------------------------------------------------
# Active-rules registry (set by launchers, read by model internals).
# --------------------------------------------------------------------------

_ACTIVE: tuple[ShardRules, object] | None = None


def set_active(rules: ShardRules, mesh) -> None:
    """Register the live (rules, mesh); axis sizes are re-derived from the
    mesh so rule defaults never lie about the actual hardware."""
    global _ACTIVE
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    repl = {}
    if rules.tensor_axis in sizes:
        repl["model_axis_size"] = sizes[rules.tensor_axis]
    if rules.data_axis in sizes:
        repl["data_axis_size"] = sizes[rules.data_axis]
    if repl:
        rules = dataclasses.replace(rules, **repl)
    _ACTIVE = (rules, mesh)


def clear_active() -> None:
    global _ACTIVE
    _ACTIVE = None


def active() -> tuple[ShardRules, object] | None:
    return _ACTIVE


def constrain(x, *axes):
    """with_sharding_constraint(P(*axes ... padded)) against the active
    mesh; a no-op outside a launcher (single-device tests)."""
    if _ACTIVE is None:
        return x
    _, mesh = _ACTIVE
    padded = tuple(axes) + (None,) * (x.ndim - len(axes))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*padded)))
