"""Distributed substrate: device layouts, sharding rules, collectives.

``mesh`` maps a flat device count onto the Swapped Dragonfly D3(K, M);
``sharding`` holds the PartitionSpec rule-set and the process-wide active
(rules, mesh) registration; ``collectives`` are the §2–§5 algorithms run as
real device collectives, lowered from the core Schedule IR by ``runtime``.
"""
