"""The paper's four algorithms as real device collectives.

Each ``dragonfly_*`` entry point is the §2–§5 schedule, emitted by the core
algorithm module as a ``Schedule``, lowered once per layout by
``runtime.lowering.lower`` into a backend-neutral ``CollectiveProgram``
(cached — lowering is pure Python), and replayed by a runtime backend
(default: ``jax_ppermute``) inside the caller's shard_map. The HLO of
``dragonfly_all_to_all`` therefore shows the round structure literally:
one collective-permute per source vector, K·M² in total — and
``dragonfly_matmul`` shows Theorem 1's 4-phase rounds (no ``all_gather``).

All functions run INSIDE shard_map over a 1-D axis of ``program.n``
devices, device i = router ``layout.topo.id_router(i)``. Pass ``backend``
to retarget (e.g. ``JaxPpermuteBackend(overlap=True)`` for cross-round
overlap on pipelined schedules).

Every entry point also takes an optional Property-2 ``embedding``
(``DeviceLayout.embed_onto``): the lowered guest program is then rewritten
through ``runtime.rewrite.emulate`` onto the embedding's host, so a
guest-sized collective runs on the HOST mesh axis (``embedding.host``
routers) with non-participating devices idle — the §2 matmul and §3
all-to-all of a D3(J,L) workload on a D3(K,M) pod without re-deriving
anything. Rewrites are cached alongside the native programs.

The cached ``*_program`` getters take ``optimized=True`` to return the
``runtime.optimize`` fused-table form instead (same cache discipline; the
fusion itself is memoized per program). Whole-array callers hand those to
any backend's ``run_*``; the per-shard ``dragonfly_*`` entry points replay
stages and therefore take ordinary programs.

Multi-tenancy: ``concurrent_program(kind, embeddings)`` merges the guest
programs of N pairwise-disjoint embeddings (``core.emulation.
disjoint_embeddings``) into ONE host program through ``runtime.combine``,
so N tenants' collectives run in max(T_i) rounds instead of Σ T_i;
``concurrent_programs`` builds the whole suite at once. Per-guest inputs
and results move through ``runtime.combine.scatter_guests`` /
``gather_guests``.

Every cached program also exports to a versioned per-device send/recv op
trace through ``device_trace`` (``runtime.export``); ``backend="sendrecv"``
replays that exported form bit-exactly, so the JSON a non-XLA substrate
would consume is differential-testable right here.
"""

from __future__ import annotations

import functools

import jax

from repro.core import alltoall as a2a
from repro.core import broadcast as bc
from repro.core import hypercube as hc
from repro.core import matmul as mm
from repro.core.emulation import Embedding
from repro.core.topology import D3
from repro.dist.mesh import DeviceLayout
from repro.runtime import lowering
from repro.runtime.backends.jax_ppermute import JaxPpermuteBackend
from repro.runtime.optimize import optimize
from repro.runtime.program import CollectiveProgram
from repro.runtime.rewrite import emulate

_DEFAULT_BACKEND = JaxPpermuteBackend()


def _resolve_backend(backend):
    """None -> the default ppermute backend; a string -> the registered
    backend of that name (``"auto"`` routes each call through the
    price-driven autotuner, ``runtime.autotune``); anything else is taken
    to already be a backend instance."""
    if backend is None:
        return _DEFAULT_BACKEND
    if isinstance(backend, str):
        from repro.runtime.backends import get_backend

        return get_backend(backend)
    return backend


def _emulated(prog: CollectiveProgram, guest: D3, embedding: Embedding | None):
    """Rewrite ``prog`` onto the embedding's host (no-op without one).
    ``emulate`` is itself lru-cached on (program, embedding), so the rewrite
    cost is paid once per (host, guest, c_set, p_set, program) key."""
    if embedding is None:
        return prog
    if embedding.guest != guest:
        raise ValueError(
            f"embedding guest D3({embedding.guest.K},{embedding.guest.M}) "
            f"does not match the program's D3({guest.K},{guest.M})"
        )
    return emulate(prog, embedding)


# ----------------------------------------------------------- cached lowering
@functools.lru_cache(maxsize=None)
def alltoall_program(
    layout: DeviceLayout, embedding: Embedding | None = None,
    *, optimized: bool = False, pipelined: int = 0,
) -> CollectiveProgram:
    """``pipelined=0`` lowers the barrier §3 schedule (every stage stamped
    start_step 0). ``pipelined=offset >= 1`` lowers the Schedule-``offset``
    pipelined variant instead: stages carry the measured ``round_starts``
    launch stamps, which is what gives the overlapped executors
    (``overlap``/``overlap_fused`` replay, ``alltoall_compute``) real waves
    to interleave."""
    sched = (a2a.pipelined_schedule(layout.da_params, pipelined, layout.topo)
             if pipelined else a2a.schedule(layout.da_params, layout.topo))
    prog = lowering.lower(sched)
    prog = _emulated(prog, layout.topo, embedding)
    return optimize(prog) if optimized else prog


@functools.lru_cache(maxsize=None)
def allreduce_program(
    layout: DeviceLayout, embedding: Embedding | None = None,
    *, optimized: bool = False,
) -> CollectiveProgram:
    sbh = layout.sbh
    if sbh is None:
        raise ValueError(
            f"D3({layout.topo.K},{layout.topo.M}) is not a power-of-two SBH; "
            "no hypercube all-reduce schedule exists"
        )
    prog = lowering.lower(hc.allreduce_schedule(sbh))
    prog = _emulated(prog, layout.topo, embedding)
    return optimize(prog) if optimized else prog


@functools.lru_cache(maxsize=None)
def broadcast_program(
    layout: DeviceLayout, root: int, embedding: Embedding | None = None,
    *, optimized: bool = False,
) -> CollectiveProgram:
    prog = lowering.lower(
        bc.depth3_schedule(layout.topo, layout.topo.id_router(root))
    )
    prog = _emulated(prog, layout.topo, embedding)
    return optimize(prog) if optimized else prog


@functools.lru_cache(maxsize=None)
def matmul_program(
    K: int, M: int, embedding: Embedding | None = None,
    *, optimized: bool = False,
) -> CollectiveProgram:
    """§2 program for the K×K array of M×M blocks (K²M² devices); with an
    embedding, the guest D3(K², M) program rewritten onto its host."""
    g = mm.MatmulGrid(K, M)
    prog = _emulated(lowering.lower(mm.schedule(g)), g.topo, embedding)
    return optimize(prog) if optimized else prog


# -------------------------------------------------- concurrent guests
@functools.lru_cache(maxsize=None)
def concurrent_program(
    kind: str, embeddings: tuple[Embedding, ...],
    *, roots: tuple[int, ...] | None = None, optimized: bool = False,
    pipelined: int = 0,
) -> CollectiveProgram:
    """One combined host program multiplexing every embedding's guest
    ``kind`` collective (``runtime.combine.combine`` of the cached
    per-guest rewrites). ``roots`` gives each broadcast guest its own
    root (guest device ids, default 0). ``optimized=True`` returns the
    fused-table form — the stacked-σ tables then span all guests.
    ``pipelined`` (alltoall guests only) combines each guest's
    Schedule-``offset`` pipelined variant, so the combined program's stages
    keep real launch stamps for the overlapped executors — this is the form
    the multi-tenant serving fleet replays at every MoE boundary."""
    from repro.runtime.combine import combine

    if roots is not None and len(roots) != len(embeddings):
        raise ValueError(f"{len(roots)} roots for {len(embeddings)} guests")
    guests: list[CollectiveProgram] = []
    for gi, emb in enumerate(embeddings):
        layout = DeviceLayout(emb.guest)
        if kind == "alltoall":
            guests.append(alltoall_program(layout, emb, pipelined=pipelined))
        elif kind == "allreduce":
            guests.append(allreduce_program(layout, emb))
        elif kind == "broadcast":
            root = roots[gi] if roots is not None else 0
            guests.append(broadcast_program(layout, root, emb))
        elif kind == "matmul":
            k = int(round(emb.guest.K ** 0.5))
            if k * k != emb.guest.K:
                raise ValueError(
                    f"guest {gi} D3({emb.guest.K},{emb.guest.M}) is not a "
                    "§2 grid (K must be a perfect square)"
                )
            guests.append(matmul_program(k, emb.guest.M, emb))
        else:
            raise ValueError(f"unknown program kind {kind!r}")
    prog = combine(guests)
    return optimize(prog) if optimized else prog


def _kind_supported(kind: str, emb: Embedding) -> bool:
    """Structural capability check: can this guest SHAPE emit ``kind``?
    (Mirrors the skips in ``train.fault_tolerance.lower_layout_programs``;
    kept structural so genuine errors — overlapping images, mismatched
    hosts — still propagate out of ``concurrent_programs``.)"""
    if kind == "allreduce":
        sbh = DeviceLayout(emb.guest).sbh
        return sbh is not None and sbh.dims > 0  # no cube on 1 router
    if kind == "matmul":
        k = int(round(emb.guest.K ** 0.5))
        return k * k == emb.guest.K
    return kind in ("alltoall", "broadcast")


def concurrent_programs(
    embeddings: tuple[Embedding, ...], kinds=("alltoall", "allreduce",
                                              "broadcast"),
    *, roots=None, optimized: bool = False,
) -> dict[str, CollectiveProgram]:
    """The combined-program suite for one tenant set: {kind: program} for
    every requested kind all guest SHAPES support (e.g. allreduce off
    powers of two is skipped). Anything else — overlapping images,
    mismatched hosts, bad roots — raises rather than thinning the suite."""
    if roots is not None and len(roots) != len(embeddings):
        raise ValueError(f"{len(roots)} roots for {len(embeddings)} guests")
    out: dict[str, CollectiveProgram] = {}
    for kind in kinds:
        if not all(_kind_supported(kind, e) for e in embeddings):
            continue
        if kind == "matmul" and len({e.guest for e in embeddings}) > 1:
            # individually capable but differently-shaped guests cannot
            # share one local-contract skeleton — skip, don't crash
            continue
        out[kind] = concurrent_program(
            kind, tuple(embeddings),
            roots=None if roots is None else tuple(roots),
            optimized=optimized,
        )
    return out


# ----------------------------------------------------------- trace export
def device_trace(program):
    """The versioned per-device send/recv op trace of any program the
    getters above return (``runtime.export``), statically validated for
    link-conflict-freedom and send/recv pairing — the form a non-XLA
    substrate consumes, and what ``backend="sendrecv"`` replays. Memoized
    per program alongside the lowering caches; accepts the
    ``optimized=True`` fused form too (same trace as its source)."""
    from repro.runtime.backends.sendrecv import SendRecvBackend

    return SendRecvBackend.trace(program)


# ------------------------------------------------------------- collectives
def xla_all_to_all(x, axis_name: str):
    """Reference: the fused XLA op, same (n, ...) chunk layout."""
    return jax.lax.all_to_all(x, axis_name, split_axis=0, concat_axis=0)


def dragonfly_all_to_all(x, axis_name: str, layout: DeviceLayout, backend=None,
                         embedding: Embedding | None = None):
    """§3 doubly-parallel all-to-all: K·M²/s rounds of s ppermutes.

    ``x``: (n, ...) with x[j] = chunk for device j; returns (n, ...) with
    out[j] = chunk from device j (the lax.all_to_all 0/0 layout). With an
    ``embedding``, ``layout`` is the guest and the exchange runs on the
    host mesh axis (n = host routers); idle devices pass zeros through."""
    be = _resolve_backend(backend)
    pipelined = 1 if getattr(be, "overlap_fused", False) else 0
    return be.alltoall(
        x, axis_name, alltoall_program(layout, embedding, pipelined=pipelined))


def dragonfly_all_to_all_compute(x, axis_name: str, layout: DeviceLayout,
                                 compute, backend=None,
                                 embedding: Embedding | None = None,
                                 offset: int = 1):
    """Fused §3 dispatch + per-destination compute + combine round trip:
    out[j] = compute_j(x[j]) — every chunk processed AT device j and
    returned to its sender, replacing a dispatch all-to-all, a batched
    local transform, and a combine all-to-all with ONE overlapped pipeline
    (Schedules 1–3: wave w's ppermutes fly while wave w-1's arrivals are
    contracted). ``compute`` is THIS shard's batched chunk transform
    (called with the (V, ...) stack of one wave's arrivals — close it over
    the shard's weights); ``offset`` picks the launch schedule. Bit-exact
    vs the sequential three-step form for chunk-batchable ``compute``.

    With an ``embedding``, ``layout`` is the guest and the round trip runs
    on the host mesh axis; idle devices contribute nothing and their rows
    stay zero."""
    be = _resolve_backend(backend)
    return be.alltoall_compute(
        x, axis_name,
        alltoall_program(layout, embedding, pipelined=offset), compute)


def dragonfly_all_reduce(x, axis_name: str, layout: DeviceLayout, backend=None,
                         embedding: Embedding | None = None):
    """§4 ascend all-reduce (sum) over the emulated hypercube; with an
    ``embedding``, guest-sized on the host mesh (idle devices unchanged)."""
    be = _resolve_backend(backend)
    return be.allreduce(x, axis_name, allreduce_program(layout, embedding))


def dragonfly_broadcast(x, axis_name: str, layout: DeviceLayout, root: int = 0,
                        backend=None, embedding: Embedding | None = None):
    """§5 depth-3 spanning-tree broadcast from GUEST device ``root`` (the
    rewrite maps it to its host device when an ``embedding`` is given)."""
    be = _resolve_backend(backend)
    return be.broadcast(x, axis_name, broadcast_program(layout, root, embedding))


def dragonfly_matmul(b_block, a_block, axis_name: str, grid: tuple[int, int],
                     backend=None, embedding: Embedding | None = None):
    """§2 block matrix product on the K×K array of M×M blocks, executed by
    the program executor — the paper's rounds on the wire, no gather.

    Runs INSIDE shard_map over a 1-D axis of K²M² devices in router order.
    Device r holds the (X, X) blocks ``b_block``/``a_block`` of B and A
    under the §2 storage map (``core.matmul.block_of_router``) and returns
    its block of B @ A in the same map. Each round broadcasts one row
    strip of B (phases 2.1/2.2), forms the local block products, and
    converges them over the mirrored accumulation paths (ReduceCombine
    matchings + the Z-fix storage hop) — Theorem 1's √n-round structure,
    visible in the HLO as collective-permutes. With an ``embedding`` the
    guest D3(K²,M) product runs on the host mesh axis: active devices hold
    the guest blocks at their ``active_devices`` slots, idle blocks are
    ignored and their output stays zero."""
    be = _resolve_backend(backend)
    return be.matmul(b_block, a_block, axis_name, matmul_program(*grid, embedding))
