"""The paper's four algorithms as real device collectives.

Each ``dragonfly_*`` entry point is the §2–§5 schedule, emitted by the core
algorithm module as a ``Schedule``, lowered once per layout by
``runtime.lowering`` (cached — lowering is pure Python), and replayed by
``runtime.executor`` as ppermutes inside the caller's shard_map. The HLO of
``dragonfly_all_to_all`` therefore shows the round structure literally:
one collective-permute per source vector, K·M² in total.

All functions run INSIDE shard_map over a 1-D axis of ``layout.n`` devices,
device i = router ``layout.topo.id_router(i)``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import alltoall as a2a
from repro.core import broadcast as bc
from repro.core import hypercube as hc
from repro.dist.mesh import DeviceLayout
from repro.runtime import executor, lowering


# ----------------------------------------------------------- cached lowering
@functools.lru_cache(maxsize=None)
def _lowered_alltoall(layout: DeviceLayout) -> lowering.LoweredAllToAll:
    return lowering.lower_alltoall(a2a.schedule(layout.da_params, layout.topo))


@functools.lru_cache(maxsize=None)
def _lowered_allreduce(layout: DeviceLayout) -> lowering.LoweredExchange:
    sbh = layout.sbh
    if sbh is None:
        raise ValueError(
            f"D3({layout.topo.K},{layout.topo.M}) is not a power-of-two SBH; "
            "no hypercube all-reduce schedule exists"
        )
    return lowering.lower_exchange(hc.allreduce_schedule(sbh))


@functools.lru_cache(maxsize=None)
def _lowered_broadcast(layout: DeviceLayout, root: int) -> lowering.LoweredBroadcast:
    return lowering.lower_broadcast(
        bc.depth3_schedule(layout.topo, layout.topo.id_router(root))
    )


# ------------------------------------------------------------- collectives
def xla_all_to_all(x, axis_name: str):
    """Reference: the fused XLA op, same (n, ...) chunk layout."""
    return jax.lax.all_to_all(x, axis_name, split_axis=0, concat_axis=0)


def dragonfly_all_to_all(x, axis_name: str, layout: DeviceLayout):
    """§3 doubly-parallel all-to-all: K·M²/s rounds of s ppermutes.

    ``x``: (n, ...) with x[j] = chunk for device j; returns (n, ...) with
    out[j] = chunk from device j (the lax.all_to_all 0/0 layout)."""
    return executor.alltoall_on_axis(x, axis_name, _lowered_alltoall(layout))


def dragonfly_all_reduce(x, axis_name: str, layout: DeviceLayout):
    """§4 ascend all-reduce (sum) over the emulated hypercube."""
    return executor.allreduce_on_axis(x, axis_name, _lowered_allreduce(layout))


def dragonfly_broadcast(x, axis_name: str, layout: DeviceLayout, root: int = 0):
    """§5 depth-3 spanning-tree broadcast from device ``root``."""
    return executor.broadcast_on_axis(x, axis_name, _lowered_broadcast(layout, root))


def dragonfly_matmul(b_block, a_block, row_axis: str, col_axis: str):
    """§2 block matrix product on the K×K array of M×M blocks, viewed as an
    (N, N) device grid with N = KM.

    Device (i, j) holds blocks B[i, j] and A[i, j] and must produce
    C[i, j] = Σ_k B[i, k] A[k, j]. The paper's round broadcasts row
    vectors of B across the grid (phases 2.1/2.2) and converges partial
    products (2.3); on the mesh that data movement is the row/column
    exchange below — gather B's row i over the column axis and A's column
    j over the row axis, then contract the X×X blocks locally (the
    off-network compute of Theorem 2)."""
    b_row = jax.lax.all_gather(b_block, col_axis)  # (N, X, X): B[i, k] ∀k
    a_col = jax.lax.all_gather(a_block, row_axis)  # (N, X, X): A[k, j] ∀k
    return jnp.einsum("kab,kbc->ac", b_row, a_col)
