"""Gradient compression: int8 block quantization with error feedback.

Composes with the A3 all-reduce: quantize -> all-reduce int8 (4× fewer
bytes on the wire) -> dequantize; the residual (quantization error) is
carried into the next step's gradient (error feedback keeps convergence).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


BLOCK = 256


def _pad_to_block(x):
    n = x.size
    pad = (-n) % BLOCK
    return jnp.pad(x.reshape(-1), (0, pad)), n


def quantize(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    """-> (int8 codes (nblocks, BLOCK), fp32 scales (nblocks,))."""
    flat, n = _pad_to_block(g.astype(jnp.float32))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array, shape, size) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)[:size]
    return flat.reshape(shape)


def compress_tree(grads, err):
    """Error-feedback quantization over a gradient pytree.

    Returns (codes_tree, new_err_tree) where codes are (q, scale) pairs.
    """
    def leaf(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = quantize(corrected)
        deq = dequantize(q, s, g.shape, g.size)
        return (q, s), corrected - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(err)
    pairs = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
    codes = jax.tree.unflatten(tdef, [p[0] for p in pairs])
    new_err = jax.tree.unflatten(tdef, [p[1] for p in pairs])
    return codes, new_err


def decompress_tree(codes, like):
    flat_c, tdef = jax.tree.flatten(like)
    flat_codes = jax.tree.unflatten(jax.tree.structure(like), jax.tree.leaves(codes, is_leaf=lambda x: isinstance(x, tuple)))
    # simpler: walk in parallel
    def leaf(code, g):
        q, s = code
        return dequantize(q, s, g.shape, g.size).astype(g.dtype)

    return jax.tree.map(
        leaf, codes, like, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
    )


def init_error(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
