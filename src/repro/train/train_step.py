"""Training step factory: loss -> grads (with microbatch accumulation) ->
optional int8 error-feedback compression -> AdamW/AdaFactor update.

The returned function is pjit-ready: pair it with the sharding trees from
``train_shardings`` and XLA inserts the collectives (the dragonfly-
scheduled variant lives in the shard_map path, step_dragonfly)."""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import model as M
from repro.train import optimizer as O
from repro.train import compression as C


@dataclasses.dataclass(frozen=True)
class TrainSettings:
    microbatches: int = 1          # gradient accumulation steps
    use_kernel: bool = True
    remat: bool = True
    compress_grads: bool = False   # int8 + error feedback
    unroll: bool = False           # unroll layer groups (cost-analysis compiles)


def make_train_step(cfg, opt_cfg: O.OptConfig, settings: TrainSettings):
    """-> train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    batch leading dim = global batch; microbatching splits it on-device
    (scan over accumulation steps keeps the compile size constant)."""

    def loss_of(p, mb):
        return M.loss_fn(
            p, mb, cfg, use_kernel=settings.use_kernel, remat=settings.remat,
            unroll=settings.unroll,
        )

    def grads_of(p, batch):
        if settings.microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_of, has_aux=True)(p, batch)
            return loss, metrics, grads

        mb_n = settings.microbatches

        def split(name, x):
            if name == "mrope_positions":  # (3, B, S): batch on axis 1
                return x.reshape(3, mb_n, -1, *x.shape[2:]).swapaxes(0, 1)
            return x.reshape(mb_n, -1, *x.shape[1:])

        mbs = {k: split(k, v) for k, v in batch.items()}
        zero_g = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), p)

        def acc_fn(carry, mb):
            g_acc, loss_acc = carry
            (loss, metrics), g = jax.value_and_grad(loss_of, has_aux=True)(p, mb)
            g_acc = jax.tree.map(lambda a, b_: a + b_.astype(jnp.float32), g_acc, g)
            return (g_acc, loss_acc + loss), metrics

        (g_sum, loss_sum), metrics = jax.lax.scan(acc_fn, (zero_g, 0.0), mbs)
        grads = jax.tree.map(lambda g: g / mb_n, g_sum)
        last_metrics = jax.tree.map(lambda m: m[-1], metrics)
        return loss_sum / mb_n, last_metrics, grads

    def train_step(params, opt_state, batch):
        loss, metrics, grads = grads_of(params, batch)
        if settings.compress_grads:
            codes, new_err = C.compress_tree(grads, opt_state["err"])
            grads = C.decompress_tree(codes, grads)
            opt_state = dict(opt_state, err=new_err)
        inner = {k: v for k, v in opt_state.items() if k != "err"}
        params, inner, opt_metrics = O.apply_updates(params, grads, inner, cfg=opt_cfg)
        new_state = dict(inner)
        if settings.compress_grads:
            new_state["err"] = opt_state["err"]
        metrics = dict(metrics, **opt_metrics)
        metrics["loss"] = loss  # microbatch-averaged (not last-microbatch)
        return params, new_state, metrics

    return train_step


def split_microbatches(batch, mb_n: int):
    """Host-side microbatch split — same layout as the in-step scan split
    (mrope_positions carries batch on axis 1), but returning a list of
    per-microbatch dicts so the launcher can time and drop individual
    microbatches (the straggler path)."""

    def split(name, x):
        if name == "mrope_positions":  # (3, B, S): batch on axis 1
            return x.reshape(3, mb_n, -1, *x.shape[2:]).swapaxes(0, 1)
        return x.reshape(mb_n, -1, *x.shape[1:])

    mbs = {k: split(k, v) for k, v in batch.items()}
    return [{k: v[i] for k, v in mbs.items()} for i in range(mb_n)]


def make_microbatch_grads(cfg, settings: TrainSettings):
    """-> mb_grads(params, microbatch) -> (loss, metrics, grads_f32).

    One microbatch's contribution in isolation, so the launcher can time
    each accumulation step on the host and drop stragglers before they
    enter the sum (``make_train_step`` fuses the whole accumulation into
    one scan — nothing can be dropped after the fact)."""

    def loss_of(p, mb):
        return M.loss_fn(
            p, mb, cfg, use_kernel=settings.use_kernel, remat=settings.remat,
            unroll=settings.unroll,
        )

    def mb_grads(params, mb):
        (loss, metrics), g = jax.value_and_grad(loss_of, has_aux=True)(params, mb)
        return loss, metrics, jax.tree.map(lambda x: x.astype(jnp.float32), g)

    return mb_grads


def make_apply_step(cfg, opt_cfg: O.OptConfig, settings: TrainSettings):
    """-> apply_step(params, opt_state, grads, loss, metrics) -> (params,
    opt_state, metrics): the optimizer tail of ``make_train_step`` on
    pre-accumulated (already averaged/renormalized) gradients."""

    def apply_step(params, opt_state, grads, loss, metrics):
        if settings.compress_grads:
            codes, new_err = C.compress_tree(grads, opt_state["err"])
            grads = C.decompress_tree(codes, grads)
            opt_state = dict(opt_state, err=new_err)
        inner = {k: v for k, v in opt_state.items() if k != "err"}
        params, inner, opt_metrics = O.apply_updates(params, grads, inner, cfg=opt_cfg)
        new_state = dict(inner)
        if settings.compress_grads:
            new_state["err"] = opt_state["err"]
        metrics = dict(metrics, **opt_metrics)
        metrics["loss"] = loss
        return params, new_state, metrics

    return apply_step


def init_train_state(key, cfg, opt_cfg: O.OptConfig, settings: TrainSettings):
    params = M.init_params(key, cfg)
    opt_state = O.init_state(params, opt_cfg)
    if settings.compress_grads:
        opt_state = dict(opt_state, err=C.init_error(params))
    return params, opt_state


def train_shardings(cfg, rules, opt_cfg: O.OptConfig, settings: TrainSettings):
    """(param_specs, opt_specs, batch_specs, metric_specs) for pjit."""
    pspecs = M.param_specs(cfg, rules)
    params_shapes = jax.eval_shape(lambda: M.init_params(jax.random.key(0), cfg))

    def zero_tree(specs):
        return jax.tree.map(
            lambda sp, sh: rules._maybe_fsdp(sp, sh.shape, zero=True),
            specs, params_shapes, is_leaf=lambda x: isinstance(x, P),
        )

    if rules.fsdp:
        # ZeRO-3: params themselves sharded over the data axes too
        pspecs = zero_tree(pspecs)
        ospecs = O.state_specs(pspecs, opt_cfg, param_shapes=params_shapes)
    elif getattr(rules, "zero1", False):
        # ZeRO-1: optimizer state sharded over the data axes; params TP-only
        ospecs = O.state_specs(zero_tree(pspecs), opt_cfg, param_shapes=params_shapes)
    else:
        ospecs = O.state_specs(pspecs, opt_cfg, param_shapes=params_shapes)
    if settings.compress_grads:
        ospecs = dict(ospecs, err=pspecs)
    bspecs = {}
    if cfg.embeds_input:
        bspecs["embeds"] = rules.activations()
        bspecs["labels"] = rules.tokens()
    else:
        bspecs["tokens"] = rules.tokens()
        bspecs["labels"] = rules.tokens()
    if cfg.rope == "mrope":
        bspecs["mrope_positions"] = P(None, rules.batch_axes, None)
    mspecs = None  # metrics replicated
    return pspecs, ospecs, bspecs, mspecs
