"""Data pipeline: deterministic synthetic LM stream with restartable
sharded iteration state (host shard, epoch, offset) — checkpointable so a
restarted job resumes mid-epoch without sample repetition/loss."""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class DataState:
    seed: int
    batch: int
    seq: int
    vocab: int
    shard: int = 0
    num_shards: int = 1
    step: int = 0

    def to_dict(self):
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d):
        """Typed restore: checkpoint round-trips hand back numpy scalars
        (np.savez boxes every int), so coerce each field through its
        declared type — the iterator must resume with real Python ints."""
        fields = {f.name: f.type for f in dataclasses.fields(cls)}
        return cls(**{k: int(v) for k, v in d.items() if k in fields})


class SyntheticLM:
    """Markov-ish synthetic tokens: deterministic per (seed, shard, step) —
    the content is reproducible across restarts and host re-layouts."""

    def __init__(self, state: DataState):
        self.state = state

    def _rng(self, step):
        s = self.state
        return np.random.default_rng(
            np.random.SeedSequence([s.seed, s.shard, step])
        )

    def next_batch(self):
        s = self.state
        rng = self._rng(s.step)
        # structured stream (zipf-ish marginals + local repetition) so the
        # loss curve is non-trivial for the examples
        base = rng.zipf(1.3, size=(s.batch, s.seq)).astype(np.int64)
        tokens = (base % (s.vocab - 2)) + 1
        rep = rng.random((s.batch, s.seq)) < 0.3
        tokens[:, 1:] = np.where(rep[:, 1:], tokens[:, :-1], tokens[:, 1:])
        s.step += 1
        return {
            "tokens": tokens.astype(np.int32),
            "labels": tokens.astype(np.int32),
        }

    def next_embeds_batch(self, d_model, dtype=np.float32):
        s = self.state
        rng = self._rng(s.step)
        b = self.next_batch()
        b["embeds"] = rng.standard_normal((s.batch, s.seq, d_model)).astype(dtype)
        del b["tokens"]
        return b
