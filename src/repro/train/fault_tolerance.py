"""Fault tolerance & elasticity.

* Failure handling: on detected chip/host loss, remap to the largest
  embeddable D3(J, L) subnetwork (paper Property 2 — core/emulation.py)
  and REWRITE the already-lowered guest programs onto the survivors
  (``runtime.rewrite.emulate``). Recovery never calls back into the
  ``core.{matmul,alltoall,broadcast,hypercube}`` derivations: schedules
  are derived + lowered ONCE, ahead of failures, into a per-shape program
  library (``prepare_fallbacks``), and ``plan_recovery`` is a pure lookup
  + relabel — cheap enough to run inside the failover window, and cached
  (``emulate`` memoizes per (program, embedding)) so repeated failovers
  onto the same survivor set are free.
* Straggler mitigation: deadline-based microbatch accounting — rounds are
  deterministic (the paper's conflict-free schedules have no stochastic
  congestion), so a late participant is detected by round index; the
  runner drops the straggler's microbatch and renormalizes the gradient.
"""

from __future__ import annotations

import dataclasses

from repro.core.emulation import Embedding, embed, largest_embeddable
from repro.core.schedule import Schedule
from repro.core.topology import D3
from repro.dist.mesh import DeviceLayout
from repro.runtime.program import CollectiveProgram
from repro.runtime.rewrite import emulate, emulate_schedule


class UnpreparedShapeError(LookupError):
    """plan_recovery needed a guest shape the library doesn't hold.

    Recovery is rewrite-only by design — it will not fall back to deriving
    schedules. Call ``ClusterState.prepare_fallbacks()`` (or
    ``prepare_shape(J, L)``) ahead of failures.
    """


@dataclasses.dataclass(frozen=True)
class LoweredSuite:
    """The derive-once artifacts for one guest shape: the Schedule IRs (for
    host-graph verification via ``emulate_schedule``) and their lowered
    ``CollectiveProgram``s (for execution via ``emulate``)."""

    schedules: dict[str, Schedule]
    programs: dict[str, CollectiveProgram]


@dataclasses.dataclass(frozen=True)
class RecoveryPlan:
    """Everything failover needs, produced WITHOUT re-deriving schedules.

    ``programs`` are host-sized rewrites of the guest suite (replayable on
    the surviving mesh as-is, ``active_devices`` = survivor ids in guest
    order); ``schedules`` are the matching host-graph Schedule views for
    ``core.simulator.verify``; ``index_map`` maps guest device id → host
    device id (= ``embedding.device_map``).
    """

    layout: DeviceLayout           # the guest D3(J, L) view
    embedding: Embedding
    index_map: dict[int, int]
    programs: dict[str, CollectiveProgram]
    schedules: dict[str, Schedule]


def lower_layout_programs(layout: DeviceLayout, *, root: int = 0) -> LoweredSuite:
    """Derive + lower the paper's algorithm suite for one layout.

    This is the ONLY recovery-adjacent function that calls into the core
    algorithm modules — it runs at preparation time (cluster bring-up),
    never inside ``plan_recovery``. Kinds a shape cannot support are
    skipped: no SBH all-reduce off powers of two, no §2 grid when K is not
    a perfect square, and degenerate shapes (single drawer/cabinet) skip
    whichever derivations reject them.
    """
    from repro.core import alltoall as a2a
    from repro.core import broadcast as bc
    from repro.core import hypercube as hc
    from repro.core import matmul as mm
    from repro.runtime import lowering

    topo = layout.topo
    schedules: dict[str, Schedule] = {}
    try:
        schedules["alltoall"] = a2a.schedule(layout.da_params, topo)
    except (ValueError, AssertionError):
        pass
    if layout.sbh is not None:
        schedules["allreduce"] = hc.allreduce_schedule(layout.sbh)
    try:
        schedules["broadcast"] = bc.depth3_schedule(topo, topo.id_router(root))
    except (ValueError, AssertionError):
        pass
    k = int(round(topo.K ** 0.5))
    if k * k == topo.K:
        schedules["matmul"] = mm.schedule(mm.MatmulGrid(k, topo.M))
    programs = {kind: lowering.lower(s) for kind, s in schedules.items()}
    return LoweredSuite(schedules=schedules, programs=programs)


@dataclasses.dataclass
class ClusterState:
    layout: DeviceLayout
    dead: set = dataclasses.field(default_factory=set)
    #: guest shape (J, L) -> derive-once suite; filled by prepare_*.
    library: dict = dataclasses.field(default_factory=dict)

    def fail(self, device_index: int):
        self.dead.add(self.layout.topo.id_router(device_index))

    # ----------------------------------------------------- preparation time
    def prepare_shape(self, J: int, L: int, *, root: int = 0) -> LoweredSuite:
        """Derive + lower the suite for guest D3(J, L) (idempotent)."""
        key = (J, L)
        if key not in self.library:
            self.library[key] = lower_layout_programs(DeviceLayout(D3(J, L)), root=root)
        return self.library[key]

    def fallback_shapes(self) -> list[tuple[int, int]]:
        """Every shape ``largest_embeddable`` can return on this pod: the
        cabinet-drop ladder (j, M) and the position-drop ladder (K, l),
        including the healthy (K, M) itself."""
        K, M = self.layout.topo.K, self.layout.topo.M
        shapes = [(j, M) for j in range(K, 0, -1)]
        shapes += [(K, l) for l in range(M - 1, 0, -1)]
        return shapes

    def prepare_fallbacks(self, shapes=None, *, root: int = 0) -> None:
        """Populate the program library ahead of failures — the derive/lower
        cost is paid here, once, so the failover window never pays it."""
        for J, L in (shapes if shapes is not None else self.fallback_shapes()):
            self.prepare_shape(J, L, root=root)

    # --------------------------------------------------------- failure time
    def plan_recovery(self) -> RecoveryPlan:
        """Rewrite-only failover: largest embeddable survivor network, then
        relabel the prepared guest suite through the embedding. Zero calls
        into core schedule derivations and zero re-lowering — raises
        ``UnpreparedShapeError`` if the shape was never prepared."""
        J, L, c_set, p_set = largest_embeddable(self.layout.topo, self.dead)
        emb = embed(self.layout.topo, J, L, c_set=c_set, p_set=p_set)
        suite = self.library.get((J, L))
        if suite is None:
            raise UnpreparedShapeError(
                f"no prepared programs for guest D3({J},{L}); call "
                f"prepare_fallbacks() (or prepare_shape({J}, {L})) before "
                "failures — recovery does not re-derive schedules"
            )
        programs = {kind: emulate(prog, emb) for kind, prog in suite.programs.items()}
        schedules = {kind: emulate_schedule(s, emb) for kind, s in suite.schedules.items()}
        index_map = {g: int(h) for g, h in enumerate(emb.device_map)}
        return RecoveryPlan(
            layout=DeviceLayout(emb.guest),
            embedding=emb,
            index_map=index_map,
            programs=programs,
            schedules=schedules,
        )


@dataclasses.dataclass
class StragglerPolicy:
    deadline_factor: float = 3.0   # × median step time
    min_participants: float = 0.75  # refuse to proceed below this fraction

    def judge(self, durations_s: list[float]) -> list[bool]:
        """True = keep, False = drop (straggler)."""
        if not durations_s:
            return []
        med = sorted(durations_s)[len(durations_s) // 2]
        keep = [d <= self.deadline_factor * max(med, 1e-9) for d in durations_s]
        if sum(keep) < self.min_participants * len(keep):
            # too many stragglers: likely a systemic stall — keep everyone
            return [True] * len(keep)
        return keep


def renormalized_scale(kept: int, total: int) -> float:
    """Gradient renormalization when microbatches are dropped."""
    return total / max(kept, 1)
