"""Fault tolerance & elasticity.

* Failure handling: on detected chip/host loss, remap to the largest
  embeddable D3(J, L) subnetwork (paper Property 2 — core/emulation.py),
  rebuild the mesh and re-shard from the latest checkpoint.
* Straggler mitigation: deadline-based microbatch accounting — rounds are
  deterministic (the paper's conflict-free schedules have no stochastic
  congestion), so a late participant is detected by round index; the
  runner drops the straggler's microbatch and renormalizes the gradient.
"""

from __future__ import annotations

import dataclasses
import time

from repro.core.topology import D3, Router
from repro.core.emulation import largest_embeddable, embed
from repro.dist.mesh import DeviceLayout


@dataclasses.dataclass
class ClusterState:
    layout: DeviceLayout
    dead: set = dataclasses.field(default_factory=set)

    def fail(self, device_index: int):
        self.dead.add(self.layout.topo.id_router(device_index))

    def plan_recovery(self):
        """-> (new_layout, device_index_map old->new) after failures."""
        J, L, c_set, p_set = largest_embeddable(self.layout.topo, self.dead)
        emb = embed(self.layout.topo, J, L, c_set=c_set, p_set=p_set)
        new_layout = DeviceLayout(emb.guest)
        index_map = {
            emb.guest.router_id(r): self.layout.topo.router_id(emb.map_router(r))
            for r in emb.guest.routers()
        }
        return new_layout, index_map


@dataclasses.dataclass
class StragglerPolicy:
    deadline_factor: float = 3.0   # × median step time
    min_participants: float = 0.75  # refuse to proceed below this fraction

    def judge(self, durations_s: list[float]) -> list[bool]:
        """True = keep, False = drop (straggler)."""
        if not durations_s:
            return []
        med = sorted(durations_s)[len(durations_s) // 2]
        keep = [d <= self.deadline_factor * max(med, 1e-9) for d in durations_s]
        if sum(keep) < self.min_participants * len(keep):
            # too many stragglers: likely a systemic stall — keep everyone
            return [True] * len(keep)
        return keep


def renormalized_scale(kept: int, total: int) -> float:
    """Gradient renormalization when microbatches are dropped."""
    return total / max(kept, 1)
