"""Fault tolerance & elasticity.

* Failure handling: on detected chip/host loss, remap to the largest
  embeddable D3(J, L) subnetwork (paper Property 2 — core/emulation.py)
  and REWRITE the already-lowered guest programs onto the survivors
  (``runtime.rewrite.emulate``). Recovery never calls back into the
  ``core.{matmul,alltoall,broadcast,hypercube}`` derivations: schedules
  are derived + lowered ONCE, ahead of failures, into a per-shape program
  library (``prepare_fallbacks``), and ``plan_recovery`` is a pure lookup
  + relabel — cheap enough to run inside the failover window, and cached
  (``emulate`` memoizes per (program, embedding)) so repeated failovers
  onto the same survivor set are free.
* Multi-tenant failure handling: ``MultiTenantCluster`` runs N disjoint
  guests on one host via the ``runtime.combine`` combinator. When chips
  die, only the tenants whose images were hit are EVICTED; the survivors'
  already-rewritten programs are RE-COMBINED (``plan_eviction``) — lookup
  + relabel + merge, every step memoized, zero re-derivation and zero
  re-lowering — so the unaffected tenants keep their schedules, stamps
  and bits while the failed tenant drains.
* Straggler mitigation: deadline-based microbatch accounting — rounds are
  deterministic (the paper's conflict-free schedules have no stochastic
  congestion), so a late participant is detected by round index; the
  runner drops the straggler's microbatch and renormalizes the gradient.
"""

from __future__ import annotations

import dataclasses

from repro.core.emulation import Embedding, embed, largest_embeddable
from repro.core.schedule import Schedule
from repro.core.topology import D3
from repro.dist.mesh import DeviceLayout
from repro.runtime.program import CollectiveProgram
from repro.runtime.rewrite import emulate, emulate_schedule


class UnpreparedShapeError(LookupError):
    """plan_recovery needed a guest shape the library doesn't hold.

    Recovery is rewrite-only by design — it will not fall back to deriving
    schedules. Call ``ClusterState.prepare_fallbacks()`` (or
    ``prepare_shape(J, L)``) ahead of failures.
    """


@dataclasses.dataclass(frozen=True)
class LoweredSuite:
    """The derive-once artifacts for one guest shape: the Schedule IRs (for
    host-graph verification via ``emulate_schedule``) and their lowered
    ``CollectiveProgram``s (for execution via ``emulate``). ``root`` is the
    guest broadcast root the suite was derived with — the shape library
    refuses to serve a cached suite under a different root."""

    schedules: dict[str, Schedule]
    programs: dict[str, CollectiveProgram]
    root: int = 0


@dataclasses.dataclass(frozen=True)
class RecoveryPlan:
    """Everything failover needs, produced WITHOUT re-deriving schedules.

    ``programs`` are host-sized rewrites of the guest suite (replayable on
    the surviving mesh as-is, ``active_devices`` = survivor ids in guest
    order); ``schedules`` are the matching host-graph Schedule views for
    ``core.simulator.verify``; ``index_map`` maps guest device id → host
    device id (= ``embedding.device_map``).
    """

    layout: DeviceLayout           # the guest D3(J, L) view
    embedding: Embedding
    index_map: dict[int, int]
    programs: dict[str, CollectiveProgram]
    schedules: dict[str, Schedule]


#: monotone count of derive+lower suite builds — the hook behind the
#: rewrite-only assertion: ``train.elastic`` snapshots it around every
#: failover and asserts the delta is zero (recovery must be pure lookup
#: + relabel, never a call back into the core schedule derivations).
_derivations = 0


def derivation_count() -> int:
    """How many times ``lower_layout_programs`` has run in this process."""
    return _derivations


def lower_layout_programs(layout: DeviceLayout, *, root: int = 0) -> LoweredSuite:
    """Derive + lower the paper's algorithm suite for one layout.

    This is the ONLY recovery-adjacent function that calls into the core
    algorithm modules — it runs at preparation time (cluster bring-up),
    never inside ``plan_recovery``. Kinds a shape cannot support are
    skipped: no SBH all-reduce off powers of two, no §2 grid when K is not
    a perfect square, and degenerate shapes (single drawer/cabinet) skip
    whichever derivations reject them.
    """
    global _derivations
    _derivations += 1
    from repro.core import alltoall as a2a
    from repro.core import broadcast as bc
    from repro.core import hypercube as hc
    from repro.core import matmul as mm
    from repro.runtime import lowering

    topo = layout.topo
    schedules: dict[str, Schedule] = {}
    try:
        schedules["alltoall"] = a2a.schedule(layout.da_params, topo)
    except (ValueError, AssertionError):
        pass
    if layout.sbh is not None and layout.sbh.dims > 0:
        # dims == 0 is the degenerate single-router D3(1,1) guest: its
        # "hypercube" has no dimensions and would lower to an empty program
        schedules["allreduce"] = hc.allreduce_schedule(layout.sbh)
    try:
        schedules["broadcast"] = bc.depth3_schedule(topo, topo.id_router(root))
    except (ValueError, AssertionError):
        pass
    k = int(round(topo.K ** 0.5))
    if k * k == topo.K:
        schedules["matmul"] = mm.schedule(mm.MatmulGrid(k, topo.M))
    programs = {kind: lowering.lower(s) for kind, s in schedules.items()}
    return LoweredSuite(schedules=schedules, programs=programs, root=root)


@dataclasses.dataclass
class _HostState:
    """Shared failure bookkeeping + derive-once program library: the host
    layout, the dead-router set, and the guest-shape suite cache that both
    the single-workload ``ClusterState`` and the multi-tenant cluster
    maintain identically."""

    layout: DeviceLayout
    dead: set = dataclasses.field(default_factory=set)
    #: guest shape (J, L) -> derive-once suite; filled by prepare_shape.
    library: dict = dataclasses.field(default_factory=dict)

    def fail(self, device_index: int) -> None:
        self.dead.add(self.layout.topo.id_router(device_index))

    def prepare_shape(self, J: int, L: int, *, root: int = 0) -> LoweredSuite:
        """Derive + lower the suite for guest D3(J, L) (idempotent) — the
        only recovery-adjacent call into the core derivations. A cache hit
        under a DIFFERENT broadcast root is refused rather than silently
        serving the wrong root's programs."""
        key = (J, L)
        suite = self.library.get(key)
        if suite is None:
            suite = self.library[key] = lower_layout_programs(
                DeviceLayout(D3(J, L)), root=root)
        elif suite.root != root:
            raise ValueError(
                f"suite for D3({J},{L}) was prepared with broadcast root "
                f"{suite.root}; re-preparing with root {root} would serve "
                "mixed roots — use a separate library"
            )
        return suite


@dataclasses.dataclass
class ClusterState(_HostState):
    def fallback_shapes(self) -> list[tuple[int, int]]:
        """Every shape ``largest_embeddable`` can return on this pod —
        the full mixed ladder. The pure regimes reach only the cabinet-
        drop column (j, M) and the position-drop row (K, l); the mixed
        cabinet×position search can land on ANY (j, l) with 1 ≤ j ≤ K,
        1 ≤ l ≤ M (e.g. striped failures dropping one cabinet and one
        position), so the library pre-lowers the whole grid, largest
        survivors first (ties toward whole drawers, mirroring the
        search's own tie-break), the healthy (K, M) included."""
        K, M = self.layout.topo.K, self.layout.topo.M
        return sorted(
            ((j, l) for j in range(1, K + 1) for l in range(1, M + 1)),
            key=lambda jl: (-(jl[0] * jl[1] * jl[1]), -jl[1], -jl[0]),
        )

    def prepare_fallbacks(self, shapes=None, *, root: int = 0) -> None:
        """Populate the program library ahead of failures — the derive/lower
        cost is paid here, once, so the failover window never pays it."""
        for J, L in (shapes if shapes is not None else self.fallback_shapes()):
            self.prepare_shape(J, L, root=root)

    # --------------------------------------------------------- failure time
    def plan_recovery(self) -> RecoveryPlan:
        """Rewrite-only failover: largest embeddable survivor network, then
        relabel the prepared guest suite through the embedding. Zero calls
        into core schedule derivations and zero re-lowering — raises
        ``UnpreparedShapeError`` if the shape was never prepared."""
        J, L, c_set, p_set = largest_embeddable(self.layout.topo, self.dead)
        emb = embed(self.layout.topo, J, L, c_set=c_set, p_set=p_set)
        suite = self.library.get((J, L))
        if suite is None:
            raise UnpreparedShapeError(
                f"no prepared programs for guest D3({J},{L}); call "
                f"prepare_fallbacks() (or prepare_shape({J}, {L})) before "
                "failures — recovery does not re-derive schedules"
            )
        programs = {kind: emulate(prog, emb) for kind, prog in suite.programs.items()}
        schedules = {kind: emulate_schedule(s, emb) for kind, s in suite.schedules.items()}
        index_map = {g: int(h) for g, h in enumerate(emb.device_map)}
        return RecoveryPlan(
            layout=DeviceLayout(emb.guest),
            embedding=emb,
            index_map=index_map,
            programs=programs,
            schedules=schedules,
        )


# ---------------------------------------------------------------------------
# Concurrent guests: N tenants on one host, eviction by re-combination.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TenantPlan:
    """One eviction step's output: who stays, who goes, and the combined
    programs the survivors keep running — produced WITHOUT re-deriving or
    re-lowering anything (``emulate`` and ``combine`` are both memoized,
    so repeat failovers onto the same tenant set are cache hits)."""

    surviving: tuple[int, ...]            # tenant ids kept, admission order
    evicted: tuple[int, ...]
    embeddings: tuple[Embedding, ...]     # survivors' (unchanged) embeddings
    programs: dict[str, CollectiveProgram]  # combined, over the survivors
    index_maps: tuple[dict[int, int], ...]  # per survivor: guest id -> host id


@dataclasses.dataclass
class MultiTenantCluster(_HostState):
    """N disjoint D3(J,L) guests time-sharing NOTHING: their rewritten
    programs interleave on one host mesh (``runtime.combine``).

    ``admit`` validates image-disjointness against the sitting tenants and
    derives + lowers the guest's suite ONCE (the only time core
    derivations run); ``fail`` marks host chips dead; ``plan_eviction``
    evicts exactly the tenants whose images were hit and re-combines the
    survivors' programs — the other guests keep running with their
    schedules, stamps and bits unchanged. Failure bookkeeping and the
    shape library are the inherited ``_HostState``.
    """

    tenants: list = dataclasses.field(default_factory=list)  # Embeddings

    # ------------------------------------------------------ admission time
    def admit(self, embedding: Embedding) -> int:
        """Seat a tenant: reject image overlaps, prepare its program suite
        (derive + lower, idempotent per shape). Returns the tenant id."""
        if embedding.host != self.layout.topo:
            raise ValueError(
                f"tenant embeds into D3({embedding.host.K},{embedding.host.M})"
                f", host is D3({self.layout.topo.K},{self.layout.topo.M})"
            )
        image = set(int(h) for h in embedding.device_map)
        dead_ids = {self.layout.topo.router_id(r) for r in self.dead}
        if image & dead_ids:
            raise ValueError(
                f"tenant image includes failed host devices "
                f"{sorted(image & dead_ids)[:4]}"
            )
        for tid, sitting in enumerate(self.tenants):
            clash = image & {int(h) for h in sitting.device_map}
            if clash:
                raise ValueError(
                    f"tenant overlaps tenant {tid} on host devices "
                    f"{sorted(clash)[:4]}"
                )
        self.prepare_shape(embedding.guest.K, embedding.guest.M)
        self.tenants.append(embedding)
        return len(self.tenants) - 1

    # --------------------------------------------------------- failure time
    def plan_eviction(self, kinds=None) -> TenantPlan:
        """Evict the tenants whose images contain a dead chip; re-combine
        the survivors (rewrite-only: cached ``emulate`` + cached
        ``combine``, no derivations, no lowering). ``kinds`` defaults to
        every kind all survivors' suites support.

        Evicted tenants are UNSEATED: their embeddings leave
        ``self.tenants``, so a replacement tenant can later ``admit`` onto
        the freed healthy routers. The returned plan reports survivor and
        evictee ids as positions at call time.
        """
        dead_ids = {self.layout.topo.router_id(r) for r in self.dead}
        surviving, evicted = [], []
        for tid, emb in enumerate(self.tenants):
            hit = dead_ids & {int(h) for h in emb.device_map}
            (evicted if hit else surviving).append(tid)
        if not surviving:
            raise RuntimeError("no tenant survives the failure set")
        return self._recombine(surviving, evicted, kinds)

    def release(self, tenant_index: int, kinds=None) -> TenantPlan:
        """Voluntary churn: unseat tenant ``tenant_index`` (a position in
        admission order at call time, no failure involved) and re-combine
        the remaining tenants — the same cached-rewrite path as
        ``plan_eviction``, so releasing back to a previously-seen tenant
        set costs a cache lookup. Unlike failure-driven eviction, releasing
        the LAST tenant is legal: the plan simply carries no survivors and
        an empty program dict."""
        if not 0 <= tenant_index < len(self.tenants):
            raise IndexError(
                f"tenant index {tenant_index} out of range "
                f"({len(self.tenants)} seated)"
            )
        surviving = [t for t in range(len(self.tenants)) if t != tenant_index]
        return self._recombine(surviving, [tenant_index], kinds)

    def _recombine(self, surviving, evicted, kinds) -> TenantPlan:
        """Unseat ``evicted`` and combine the survivors' programs — the
        shared rewrite-only tail of ``plan_eviction`` and ``release``
        (cached ``emulate`` + cached ``combine``, zero derivations)."""
        from repro.runtime.combine import GuestConflictError, combine

        embs = tuple(self.tenants[t] for t in surviving)
        self.tenants = list(embs)  # unseat the evicted tenants
        programs: dict[str, CollectiveProgram] = {}
        if embs:
            suites = [self.library[(e.guest.K, e.guest.M)] for e in embs]
            supported = set(suites[0].programs)
            for s in suites[1:]:
                supported &= set(s.programs)
            # explicit kinds intersect with what every survivor supports,
            # the same skip-unsupported semantics as lower_layout_programs
            kinds = supported if kinds is None else set(kinds) & supported
            for kind in sorted(kinds):
                try:
                    programs[kind] = combine(
                        [emulate(s.programs[kind], e)
                         for s, e in zip(suites, embs)]
                    )
                except GuestConflictError:
                    if kind == "matmul":  # shape-mixed tenants can't share
                        continue          # the local-contract skeleton
                    raise
        return TenantPlan(
            surviving=tuple(surviving),
            evicted=tuple(evicted),
            embeddings=embs,
            programs=programs,
            index_maps=tuple(
                {g: int(h) for g, h in enumerate(e.device_map)} for e in embs
            ),
        )


@dataclasses.dataclass
class StragglerPolicy:
    deadline_factor: float = 3.0   # × median step time
    min_participants: float = 0.75  # refuse to proceed below this fraction

    def judge(self, durations_s: list[float]) -> list[bool]:
        """True = keep, False = drop (straggler)."""
        if not durations_s:
            return []
        med = sorted(durations_s)[len(durations_s) // 2]
        keep = [d <= self.deadline_factor * max(med, 1e-9) for d in durations_s]
        if sum(keep) < self.min_participants * len(keep):
            # too many stragglers: likely a systemic stall — keep everyone
            return [True] * len(keep)
        return keep


def renormalized_scale(kept: int, total: int) -> float:
    """Gradient renormalization when microbatches are dropped."""
    return total / max(kept, 1)
