"""Training substrate: optimizer, train step factory, checkpointing,
data pipeline, fault tolerance, gradient compression."""

from repro.train.optimizer import OptConfig, init_state, apply_updates
from repro.train.train_step import TrainSettings, make_train_step, init_train_state, train_shardings
from repro.train import checkpoint
from repro.train.data import DataState, SyntheticLM

__all__ = [
    "OptConfig",
    "init_state",
    "apply_updates",
    "TrainSettings",
    "make_train_step",
    "init_train_state",
    "train_shardings",
    "checkpoint",
    "DataState",
    "SyntheticLM",
]
