"""Checkpointing: sharded-friendly npz snapshots with manifest, step
provenance, integrity digests, atomic rename, and retention. Pure numpy —
restores on any host count (re-sharding happens at load via pjit)."""

from __future__ import annotations

import hashlib
import json
import os
import pathlib
import shutil
import tempfile
import time

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (tuple, list)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}__{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: dict):
    root: dict = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val

    def fix(node):
        if not isinstance(node, dict):
            return node
        if node and all(k.startswith("__") for k in node):
            idx = sorted(node, key=lambda s: int(s[2:]))
            return tuple(fix(node[k]) for k in idx)
        return {k: fix(v) for k, v in node.items()}

    return fix(root)


def save(ckpt_dir, step: int, state_tree, keep: int = 3) -> str:
    """Atomic checkpoint write: tmp dir -> fsync -> rename."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    flat = {k: np.asarray(v) for k, v in _flatten(state_tree).items()}
    tmp = pathlib.Path(tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_"))
    try:
        arrays_path = tmp / "arrays.npz"
        np.savez(arrays_path, **{k.replace("/", "|"): v for k, v in flat.items()})
        digest = hashlib.sha256(arrays_path.read_bytes()).hexdigest()
        manifest = {
            "step": step,
            "time": time.time(),
            "digest": digest,
            "num_arrays": len(flat),
            "total_bytes": int(sum(v.nbytes for v in flat.values())),
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        final = ckpt_dir / f"step_{step:08d}"
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _retain(ckpt_dir, keep)
    return str(final)


def _retain(ckpt_dir: pathlib.Path, keep: int):
    ckpts = sorted(d for d in ckpt_dir.glob("step_*") if d.is_dir())
    for d in ckpts[:-keep]:
        shutil.rmtree(d, ignore_errors=True)


def latest_step(ckpt_dir) -> int | None:
    """Newest checkpoint step, or None. Only step_<int> DIRECTORIES count
    (the same filter ``_retain`` applies): stray files or unparseable
    names next to the checkpoints — a ``step_tmp`` leftover, an editor
    backup — are skipped instead of crashing the restore path."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    steps = []
    for d in ckpt_dir.glob("step_*"):
        if not d.is_dir():
            continue
        try:
            steps.append(int(d.name.split("_", 1)[1]))
        except ValueError:
            continue
    return max(steps) if steps else None


def restore(ckpt_dir, step: int | None = None, verify: bool = True):
    """-> (step, state_tree). Verifies the integrity digest by default —
    a truncated/corrupt checkpoint raises instead of silently loading."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    if verify:
        digest = hashlib.sha256((d / "arrays.npz").read_bytes()).hexdigest()
        if digest != manifest["digest"]:
            raise IOError(f"checkpoint {d} digest mismatch")
    with np.load(d / "arrays.npz") as z:
        flat = {k.replace("|", "/"): z[k] for k in z.files}
    return manifest["step"], _unflatten(flat)
