"""Always-on elastic training: survive chip failures mid-run without a
process restart.

The paper gives both halves of the mechanism. Property 2 (§1/§6) makes the
pod elastic: D3(K, M) contains a dilation-1 copy of every D3(J, L), so when
chips die the run shrinks to the largest embeddable survivor network and
every prepared schedule transfers verbatim through ``plan_recovery``'s
rewrite (no re-derivation — asserted via ``derivation_count``). The §5
depth-3 broadcast is the redistribution primitive: the latest checkpointed
parameters are replayed through the REWRITTEN broadcast program, so the
payload travels the exact conflict-free routes the survivor network will
keep using for training collectives, landing on every device of
``RecoveryPlan.index_map``.

Failover sequence (``ElasticTrainer._failover``):

1. mark the injected/detected devices dead on the ``ClusterState``;
2. ``plan_recovery()`` — pure library lookup + relabel (zero calls into
   the core schedule derivations; the delta of ``derivation_count`` across
   the whole failover is asserted to be 0);
3. if every newly-dead device lies OUTSIDE the current active image the
   failure is *absorbed*: the sitting plan stays valid and training
   continues without a rewind;
4. otherwise restore the latest checkpoint (``verify=True`` — a corrupt
   snapshot raises before anything loads), flatten the parameters, seat
   them at the rewritten broadcast root (host row ``index_map[0]``) and
   replay the §5 program; every survivor row is asserted to equal the
   payload and the resumed parameters are REBUILT from a non-root
   survivor's row, proving they actually travelled the broadcast;
5. rebuild the jitted step function for the shrunken D3(J, L) layout,
   restore the data-iterator state (typed ``DataState.from_dict``), rewind
   to the checkpoint step and keep stepping.

Because data, init and optimizer are deterministic, the post-failover loss
curve must match an uninterrupted run at equal data-state —
``max_loss_divergence`` measures exactly that and the drill asserts it.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np
from jax.flatten_util import ravel_pytree

from repro.core.topology import D3
from repro.dist.mesh import DeviceLayout
from repro.runtime.backends.reference import NumpyReferenceBackend
from repro.train import checkpoint as ckpt
from repro.train.data import DataState, SyntheticLM
from repro.train.fault_tolerance import (
    ClusterState,
    RecoveryPlan,
    derivation_count,
)
from repro.train.optimizer import OptConfig
from repro.train.train_step import TrainSettings, init_train_state, make_train_step


@dataclasses.dataclass(frozen=True)
class FailoverEvent:
    """One failure's recovery record — what the drill and the benchmark
    inspect: the survivor shape, the broadcast accounting, the wall time
    from detection to resume, and the (must-be-zero) derivation count."""

    step: int                      # step at which the failure was detected
    failed: tuple[int, ...]        # newly-dead host device ids
    shape: tuple[int, int]         # survivor guest (J, L)
    survivors: tuple[int, ...]     # host ids in guest order (index_map values)
    resumed_from: int              # checkpoint step training rewound to
    broadcast_rounds: int          # rounds of the §5 redistribution program
    bytes_redistributed: int       # payload bytes moved per survivor
    wall_s: float                  # detection -> resume
    derivations: int               # derive+lower calls during failover (== 0)
    absorbed: bool                 # failure outside active image: no rewind


class FaultInjector:
    """Deterministic, consume-once failure schedule.

    Build from an explicit ``{step: [device_id, ...]}`` plan or sample one
    from a seed (``FaultInjector.sample``). ``take(step)`` returns the
    devices to kill at ``step`` exactly once: after a failover rewinds to
    the checkpoint and the loop passes the same step again, the injection
    does not re-fire (otherwise recovery would loop forever).
    """

    def __init__(self, plan: dict[int, list[int]] | None = None):
        self._plan = {
            int(s): tuple(int(d) for d in devs)
            for s, devs in (plan or {}).items()
        }
        self._fired: set[int] = set()

    @classmethod
    def sample(
        cls, host: D3, steps: int, failures: int, seed: int, *, min_step: int = 1
    ) -> "FaultInjector":
        """``failures`` distinct (step, device) kills, deterministic per
        seed: steps drawn without replacement from [min_step, steps),
        devices without replacement from the host pod (a device dies once)."""
        if failures > steps - min_step or failures > host.num_routers:
            raise ValueError("more failures than available steps or devices")
        rng = np.random.default_rng(seed)
        kill_steps = rng.choice(
            np.arange(min_step, steps), size=failures, replace=False)
        devices = rng.choice(host.num_routers, size=failures, replace=False)
        plan: dict[int, list[int]] = {}
        for s, d in zip(sorted(int(s) for s in kill_steps), devices):
            plan.setdefault(s, []).append(int(d))
        return cls(plan)

    @property
    def schedule(self) -> dict[int, tuple[int, ...]]:
        return dict(self._plan)

    def take(self, step: int) -> tuple[int, ...]:
        if step in self._fired:
            return ()
        devs = self._plan.get(int(step), ())
        if devs:
            self._fired.add(step)
        return devs


class ElasticTrainer:
    """The step loop of ``launch/train.py`` wrapped with failure injection
    and the rewrite-only recovery path. ``backend`` replays the
    redistribution broadcast: the numpy reference backend by default, or a
    ``JaxPpermuteBackend`` to move the payload through a real device mesh
    (both expose ``run_broadcast(x, program)``)."""

    def __init__(
        self,
        cfg,
        opt_cfg: OptConfig,
        settings: TrainSettings,
        *,
        ckpt_dir,
        host: D3 = D3(2, 2),
        injector: FaultInjector | None = None,
        backend=None,
        batch: int = 8,
        seq: int = 16,
        seed: int = 0,
        ckpt_every: int = 5,
    ):
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.settings = settings
        self.ckpt_dir = str(ckpt_dir)
        self.injector = injector or FaultInjector()
        self.backend = backend or NumpyReferenceBackend()
        self.batch, self.seq, self.seed = batch, seq, seed
        self.ckpt_every = ckpt_every
        self.cluster = ClusterState(DeviceLayout(host))
        self.cluster.prepare_fallbacks()   # derive/lower paid here, once
        self.plan: RecoveryPlan | None = None  # sitting survivor plan
        self.events: list[FailoverEvent] = []
        self.losses: dict[int, float] = {}
        self._step_fn = None
        self._params = None
        self._opt_state = None
        self._data = None

    # ------------------------------------------------------------ plumbing
    def _build_step_fn(self):
        """Fresh jit for the current (possibly shrunken) layout — the old
        executable held donated buffers sized for the previous mesh."""
        return jax.jit(
            make_train_step(self.cfg, self.opt_cfg, self.settings),
            donate_argnums=(0, 1),
        )

    def _active_devices(self) -> set[int]:
        if self.plan is None:
            return set(range(self.cluster.layout.topo.num_routers))
        return set(self.plan.index_map.values())

    def _save(self, step: int) -> str:
        return ckpt.save(
            self.ckpt_dir,
            step,
            {
                "params": jax.tree.map(np.asarray, self._params),
                "opt": jax.tree.map(np.asarray, self._opt_state),
                "data": self._data.state.to_dict(),
            },
        )

    # ------------------------------------------------------------ failover
    def _failover(self, step: int, failed: tuple[int, ...]) -> int:
        """-> the step to resume from. Rewrite-only: the derivation-count
        delta across the whole failover is asserted to be zero."""
        t0 = time.perf_counter()
        d0 = derivation_count()
        active = self._active_devices()
        for dev in failed:
            self.cluster.fail(dev)
        plan = self.cluster.plan_recovery()   # lookup + relabel, no derive
        self.plan = plan
        survivors = tuple(plan.index_map[g] for g in sorted(plan.index_map))
        shape = (plan.layout.topo.K, plan.layout.topo.M)

        if not (set(failed) & active):
            # absorbed: the dead chips were already outside the image the
            # run is using — adopt the (possibly smaller) plan for future
            # collectives but keep stepping without a rewind.
            self.events.append(FailoverEvent(
                step=step, failed=tuple(failed), shape=shape,
                survivors=survivors, resumed_from=step, broadcast_rounds=0,
                bytes_redistributed=0, wall_s=time.perf_counter() - t0,
                derivations=derivation_count() - d0, absorbed=True,
            ))
            assert self.events[-1].derivations == 0, "failover re-derived"
            return step

    # -- rewind: checkpoint -> §5 broadcast redistribution -> rebuild ----
        ck_step, tree = ckpt.restore(self.ckpt_dir, verify=True)
        params_np = tree["params"]
        vec, unravel = ravel_pytree(params_np)
        payload = np.asarray(vec, np.float32)

        program = plan.programs["broadcast"]
        x = np.zeros((program.n, payload.size), np.float32)
        x[plan.index_map[0]] = payload        # rewritten root's host row
        out = np.asarray(self.backend.run_broadcast(x, program))
        for g, h in plan.index_map.items():
            if not np.array_equal(out[h], payload):
                raise AssertionError(
                    f"survivor {h} (guest {g}) did not receive the payload")
        # resume from a NON-root survivor's row: the parameters the run
        # continues with demonstrably travelled the broadcast (on a
        # single-survivor plan the root is the only row there is).
        landing = plan.index_map[max(plan.index_map)]
        self._params = jax.tree.map(
            jax.numpy.asarray, unravel(out[landing].astype(vec.dtype)))
        self._opt_state = jax.tree.map(jax.numpy.asarray, tree["opt"])
        self._data = SyntheticLM(DataState.from_dict(tree["data"]))
        self._step_fn = self._build_step_fn()

        self.events.append(FailoverEvent(
            step=step, failed=tuple(failed), shape=shape,
            survivors=survivors, resumed_from=ck_step,
            broadcast_rounds=program.num_rounds,
            bytes_redistributed=int(payload.nbytes),
            wall_s=time.perf_counter() - t0,
            derivations=derivation_count() - d0, absorbed=False,
        ))
        assert self.events[-1].derivations == 0, "failover re-derived"
        return ck_step

    # ----------------------------------------------------------- main loop
    def run(self, steps: int) -> dict[int, float]:
        """Train ``steps`` steps, surviving every injected failure; ->
        {step: loss} with post-failover steps overwriting their rewound
        predecessors (identical values when recovery is exact)."""
        self._params, self._opt_state = init_train_state(
            jax.random.key(self.seed), self.cfg, self.opt_cfg, self.settings)
        self._data = SyntheticLM(DataState(
            seed=self.seed, batch=self.batch, seq=self.seq,
            vocab=self.cfg.vocab))
        self._step_fn = self._build_step_fn()
        self._save(0)   # step-0 snapshot: failures before the first
        # periodic checkpoint must still be recoverable

        step = 0
        while step < steps:
            failed = self.injector.take(step)
            if failed:
                step = self._failover(step, failed)
                continue
            if self.cfg.embeds_input:
                batch = self._data.next_embeds_batch(self.cfg.d_model)
            else:
                batch = self._data.next_batch()
            batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
            self._params, self._opt_state, metrics = self._step_fn(
                self._params, self._opt_state, batch)
            self.losses[step] = float(metrics["loss"])
            step += 1
            if step % self.ckpt_every == 0 or step == steps:
                self._save(step)
        return dict(self.losses)


def max_loss_divergence(a: dict[int, float], b: dict[int, float]) -> float:
    """Largest |a[s] - b[s]| over the common steps — the loss-continuity
    metric: an elastic run vs. an uninterrupted run of the same seed must
    agree everywhere, failovers included."""
    common = sorted(set(a) & set(b))
    if not common:
        raise ValueError("no common steps to compare")
    return max(abs(a[s] - b[s]) for s in common)
