"""Optimizers: AdamW (full) and AdaFactor-style factored second moment
(for the 400-700B archs where full Adam state would not fit), with global
gradient-norm clipping and cosine LR schedule. Pure-functional: no optax
dependency (offline container).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    betas: tuple = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    factored: bool = False       # AdaFactor-style v factorization
    state_dtype: str = "float32"


def lr_at(cfg: OptConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def _factorable(shape) -> bool:
    return len(shape) >= 2 and shape[-1] >= 128 and shape[-2] >= 128


def init_state(params, cfg: OptConfig):
    dt = jnp.dtype(cfg.state_dtype)

    def leaf(p):
        if cfg.factored and _factorable(p.shape):
            return {
                "m": jnp.zeros(p.shape, dt),
                "vr": jnp.zeros(p.shape[:-1], dt),      # row stats
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], dt),  # col stats
            }
        return {"m": jnp.zeros(p.shape, dt), "v": jnp.zeros(p.shape, dt)}

    return {"mu": jax.tree.map(leaf, params), "step": jnp.zeros((), jnp.int32)}


def state_specs(param_specs, cfg: OptConfig, param_shapes=None, zero_fn=None):
    """Optimizer-state PartitionSpecs mirror the param specs (optionally
    ZeRO-extended by zero_fn: spec -> spec). ``param_shapes`` (a matching
    tree of ShapeDtypeStructs) decides per-leaf factorability — it must
    match init_state's structure exactly."""
    zf = zero_fn or (lambda s: s)

    def leaf(spec, shaped=None):
        full = zf(spec)
        if cfg.factored and shaped is not None and _factorable(shaped.shape):
            # factored leaves: row/col stats drop one axis each; vr keeps
            # the spec minus its last axis, vc minus its second-to-last.
            axes = list(spec) + [None] * (len(shaped.shape) - len(spec))
            vr = P(*axes[:-1])
            vc = P(*(axes[:-2] + axes[-1:]))
            return {"m": full, "vr": vr, "vc": vc}
        return {"m": full, "v": full}

    if param_shapes is None:
        mu = jax.tree.map(leaf, param_specs, is_leaf=lambda x: isinstance(x, P))
    else:
        mu = jax.tree.map(
            leaf, param_specs, param_shapes, is_leaf=lambda x: isinstance(x, P)
        )
    return {"mu": mu, "step": P()}


def global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def apply_updates(params, grads, state, cfg: OptConfig):
    """One AdamW/AdaFactor step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    b1, b2 = cfg.betas
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = lr_at(cfg, step)

    def leaf(p, g, s):
        g = g.astype(jnp.float32) * scale
        m = b1 * s["m"].astype(jnp.float32) + (1 - b1) * g
        mhat = m / (1 - b1 ** step.astype(jnp.float32))
        if "v" in s:
            v = b2 * s["v"].astype(jnp.float32) + (1 - b2) * g * g
            vhat = v / (1 - b2 ** step.astype(jnp.float32))
            upd = mhat / (jnp.sqrt(vhat) + cfg.eps)
            new_s = {"m": m.astype(s["m"].dtype), "v": v.astype(s["v"].dtype)}
        else:
            vr = b2 * s["vr"].astype(jnp.float32) + (1 - b2) * jnp.mean(g * g, axis=-1)
            vc = b2 * s["vc"].astype(jnp.float32) + (1 - b2) * jnp.mean(g * g, axis=-2)
            rc = vr[..., None] * vc[..., None, :] / jnp.maximum(
                jnp.mean(vr, axis=-1)[..., None, None], 1e-30
            )
            upd = mhat / (jnp.sqrt(rc / (1 - b2 ** step.astype(jnp.float32))) + cfg.eps)
            new_s = {
                "m": m.astype(s["m"].dtype),
                "vr": vr.astype(s["vr"].dtype),
                "vc": vc.astype(s["vc"].dtype),
            }
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * upd).astype(p.dtype), new_s

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_s = treedef.flatten_up_to(state["mu"])
    new_p, new_s = zip(*[leaf(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)])
    return (
        jax.tree.unflatten(treedef, new_p),
        {"mu": jax.tree.unflatten(treedef, new_s), "step": step},
        {"grad_norm": gnorm, "lr": lr},
    )
