"""Generate the EXPERIMENTS.md §Dry-run + §Roofline tables from the
per-cell JSONs in experiments/dryrun/.

    PYTHONPATH=src python experiments/make_report.py > experiments/roofline.md
"""

import glob
import json
import pathlib

HERE = pathlib.Path(__file__).resolve().parent


def fmt_s(x):
    if x is None:
        return "—"
    return f"{x:.3g}"


def load_cells():
    cells = {}
    variants = {}
    for f in glob.glob(str(HERE / "dryrun" / "*.json")):
        d = json.load(open(f))
        parts = pathlib.Path(f).stem.split("__")
        if len(parts) > 3 or "variant" in d:  # tagged hillclimb variant
            variants[(d["arch"], d["shape"], d["mesh"], parts[-1])] = d
        else:
            cells[(d["arch"], d["shape"], d["mesh"])] = d
    return cells, variants


def dryrun_table(cells):
    lines = [
        "| arch | shape | mesh | status | compile s | bytes/device (arg+tmp) | HLO collective ops |",
        "|---|---|---|---|---|---|---|",
    ]
    for (a, s, m), d in sorted(cells.items()):
        if d["status"] == "ok":
            mem = d["memory"]
            per_dev = (mem["argument_bytes"] + mem["temp_bytes"]) / 1e9
            counts = d.get("collectives_full_compile", {}).get("_counts", {})
            cstr = " ".join(f"{k.split('-')[-1] if False else k}:{v}" for k, v in sorted(counts.items()))
            lines.append(
                f"| {a} | {s} | {m} | ok | {d['compile_seconds']} | {per_dev:.1f} GB | {cstr} |"
            )
        else:
            lines.append(
                f"| {a} | {s} | {m} | {d['status']} | — | — | {d.get('reason', d.get('error', ''))[:60]} |"
            )
    return "\n".join(lines)


def roofline_table(cells):
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | MODEL_FLOPS | useful/HLO | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for (a, s, m), d in sorted(cells.items()):
        if m != "pod1" or d["status"] != "ok" or "roofline" not in d:
            continue
        r = d["roofline"]
        lines.append(
            f"| {a} | {s} | {fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} | "
            f"{fmt_s(r['collective_s'])} | **{r['dominant']}** | {r['model_flops']:.3g} | "
            f"{r['useful_flops_ratio']:.3f} | {r['roofline_fraction']:.3f} |"
        )
    return "\n".join(lines)


def variants_table(cells, variants):
    lines = [
        "| arch | shape | variant | compute s | memory s | collective s | dominant |",
        "|---|---|---|---|---|---|---|",
    ]
    for (a, s, m, tag), d in sorted(variants.items()):
        if d["status"] != "ok" or "roofline" not in d:
            continue
        base = cells.get((a, s, m), {}).get("roofline")
        r = d["roofline"]
        def delta(key):
            if not base:
                return fmt_s(r[key])
            return f"{r[key]:.3g} ({r[key] / max(base[key], 1e-12):.2f}×)"
        lines.append(
            f"| {a} | {s} | {tag} | {delta('compute_s')} | {delta('memory_s')} | "
            f"{delta('collective_s')} | {r['dominant']} |"
        )
    return "\n".join(lines)


def main():
    cells, variants = load_cells()
    n_ok = sum(1 for d in cells.values() if d["status"] == "ok")
    n_fail = sum(1 for d in cells.values() if d["status"] == "FAILED")
    n_skip = sum(1 for d in cells.values() if d["status"] == "skipped")
    print(f"## §Dry-run  ({n_ok} ok / {n_skip} skipped / {n_fail} failed)\n")
    print(dryrun_table(cells))
    print("\n## §Roofline (single-pod, 256 × v5e)\n")
    print(roofline_table(cells))
    print("\n## §Perf hillclimb variants (vs baseline)\n")
    print(variants_table(cells, variants))


if __name__ == "__main__":
    main()
